"""Build-time compile package: L2 jax models + L1 pallas kernels + AOT."""
