"""Layer-2: the evaluation networks as JAX functions calling the L1
Pallas kernels.

Three models (matching the paper's Table I workloads, scaled per DESIGN.md
§Substitutions):

* ``digits``       — Dense(784->512) ReLU Dense(512->256) ReLU
                     Dense(256->10) Softmax (the paper's MNIST MLP shape).
* ``mobilenet_mini`` — Conv/BN/ReLU + depthwise-separable stages + Dense +
                     Softmax on 16x16x3 images (the MobileNet layer mix).
* ``pendulum``     — Dense tanh Dense tanh on R^2 (the neural Lyapunov
                     function of Chang et al.: two Dense, two tanh).

Each forward function takes ``(params, x, k=None)``; with ``k`` set, every
materialized tensor (weights on entry, activations after each layer) is
rounded to k mantissa bits via the Pallas ``roundk`` kernel — *storage
emulation* of a precision-k format (compute in f32, store in k bits), the
deployment model of bfloat16-style hardware. The Rust `quant::EmulatedFp`
provides the stricter per-operation emulation; CAA bounds cover both.
"""

import math

import jax
import jax.numpy as jnp

from .kernels import dense as dense_kernel
from .kernels import round_to_precision, softmax


def _maybe_round(x, k):
    return x if k is None else round_to_precision(x, k)


# ---------------------------------------------------------------------------
# layer helpers (all take/return channels-last single-sample tensors)
# ---------------------------------------------------------------------------

def _same_pads(size: int, kernel: int, stride: int):
    out = -(-size // stride)
    pad = max((out - 1) * stride + kernel - size, 0)
    return pad // 2, pad - pad // 2, out


def im2col(x, kh: int, kw: int, stride: int, padding: str):
    """``x: [h, w, cin]`` -> (patches ``[oh*ow, kh*kw*cin]``, oh, ow).
    Patch feature order is (ky, kx, cin) — matching an HWIO kernel
    reshaped to ``[kh*kw*cin, cout]``."""
    h, w, cin = x.shape
    if padding.upper() == "SAME":
        pt, pb, oh = _same_pads(h, kh, stride)
        pl_, pr, ow = _same_pads(w, kw, stride)
        x = jnp.pad(x, ((pt, pb), (pl_, pr), (0, 0)))
    else:
        oh = (h - kh) // stride + 1
        ow = (w - kw) // stride + 1
    cols = []
    for ky in range(kh):
        for kx in range(kw):
            patch = x[ky : ky + (oh - 1) * stride + 1 : stride,
                      kx : kx + (ow - 1) * stride + 1 : stride, :]
            cols.append(patch)
    stacked = jnp.stack(cols, axis=2)  # [oh, ow, kh*kw, cin]
    return stacked.reshape(oh * ow, kh * kw * cin), oh, ow


def conv2d(x, kernel, bias, stride: int, padding: str):
    """Convolution as im2col + the tiled Pallas GEMM (the TPU mapping of
    the paper's convolutional dot products). ``kernel: [kh, kw, cin, cout]``."""
    kh, kw, cin, cout = kernel.shape
    patches, oh, ow = im2col(x, kh, kw, stride, padding)
    w2 = kernel.reshape(kh * kw * cin, cout)
    y = dense_kernel(patches, w2, bias)
    return y.reshape(oh, ow, cout)


def depthwise2d(x, kernel, bias, stride: int, padding: str):
    """Depthwise convolution; ``kernel: [kh, kw, c]``. The per-channel
    contraction is a small einsum (VPU work, not MXU; the GEMMs dominate)."""
    kh, kw, c = kernel.shape
    h, w, _ = x.shape
    if padding.upper() == "SAME":
        pt, pb, oh = _same_pads(h, kh, stride)
        pl_, pr, ow = _same_pads(w, kw, stride)
        xp = jnp.pad(x, ((pt, pb), (pl_, pr), (0, 0)))
    else:
        oh = (h - kh) // stride + 1
        ow = (w - kw) // stride + 1
        xp = x
    cols = []
    for ky in range(kh):
        for kx in range(kw):
            cols.append(
                xp[ky : ky + (oh - 1) * stride + 1 : stride,
                   kx : kx + (ow - 1) * stride + 1 : stride, :]
            )
    stacked = jnp.stack(cols, axis=2)  # [oh, ow, kh*kw, c]
    return jnp.einsum("abkc,kc->abc", stacked, kernel.reshape(kh * kw, c)) + bias


def max_pool(x, ph: int, pw: int):
    h, w, c = x.shape
    return x.reshape(h // ph, ph, w // pw, pw, c).max(axis=(1, 3))


def batch_norm_infer(x, g):
    """Inference-mode BN with stored statistics ``g = (gamma, beta, mean, var, eps)``."""
    gamma, beta, mean, var, eps = g
    return gamma * (x - mean) / jnp.sqrt(var + eps) + beta


# ---------------------------------------------------------------------------
# parameter initialization
# ---------------------------------------------------------------------------

def _glorot(rng, fan_in, fan_out, shape):
    limit = math.sqrt(6.0 / (fan_in + fan_out))
    return rng.uniform(-limit, limit, size=shape).astype("float32")


def init_digits(rng):
    return {
        "w1": _glorot(rng, 784, 512, (784, 512)),
        "b1": jnp.zeros(512, jnp.float32),
        "w2": _glorot(rng, 512, 256, (512, 256)),
        "b2": jnp.zeros(256, jnp.float32),
        "w3": _glorot(rng, 256, 10, (256, 10)),
        "b3": jnp.zeros(10, jnp.float32),
    }


def init_mobilenet_mini(rng):
    def bn(c):
        return {
            "gamma": jnp.ones(c, jnp.float32),
            "beta": jnp.zeros(c, jnp.float32),
            "mean": jnp.zeros(c, jnp.float32),
            "var": jnp.ones(c, jnp.float32),
        }

    return {
        "c1": _glorot(rng, 27, 8, (3, 3, 3, 8)),
        "c1b": jnp.zeros(8, jnp.float32),
        "bn1": bn(8),
        "dw2": _glorot(rng, 9, 1, (3, 3, 8)),
        "dw2b": jnp.zeros(8, jnp.float32),
        "pw2": _glorot(rng, 8, 16, (1, 1, 8, 16)),
        "pw2b": jnp.zeros(16, jnp.float32),
        "bn2": bn(16),
        "dw3": _glorot(rng, 9, 1, (3, 3, 16)),
        "dw3b": jnp.zeros(16, jnp.float32),
        "pw3": _glorot(rng, 16, 32, (1, 1, 16, 32)),
        "pw3b": jnp.zeros(32, jnp.float32),
        "bn3": bn(32),
        "w_out": _glorot(rng, 512, 10, (512, 10)),
        "b_out": jnp.zeros(10, jnp.float32),
    }


def init_pendulum(rng):
    # The paper's Pendulum topology: two Dense layers, two tanh activations
    # (Chang et al. NeurIPS'19).
    return {
        "w1": _glorot(rng, 2, 16, (2, 16)),
        "b1": jnp.zeros(16, jnp.float32),
        "w2": _glorot(rng, 16, 1, (16, 1)),
        "b2": jnp.zeros(1, jnp.float32),
    }


def init_residual_mlp(rng):
    # One additive skip block (Keras-functional style): the topology the
    # Rust zoo's `residual_mlp` uses, exported through the graph-wired JSON
    # channel (aot.export_residual_mlp).
    return {
        "w1": _glorot(rng, 8, 8, (8, 8)),
        "b1": jnp.zeros(8, jnp.float32),
        "w2": _glorot(rng, 8, 8, (8, 8)),
        "b2": jnp.zeros(8, jnp.float32),
        "w3": _glorot(rng, 8, 3, (8, 3)),
        "b3": jnp.zeros(3, jnp.float32),
    }


# ---------------------------------------------------------------------------
# forward passes (single-sample; batched training wrappers use vmap)
# ---------------------------------------------------------------------------

BN_EPS = 1e-3


def digits_fwd(params, x, k=None):
    """``x: [784]`` raw pixels (the /255 normalization is folded into w1 at
    export time — see train.fold_input_scale)."""
    p = {n: _maybe_round(v, k) for n, v in params.items()}
    h = _maybe_round(jnp.maximum(dense_kernel(x, p["w1"], p["b1"]), 0.0), k)
    h = _maybe_round(jnp.maximum(dense_kernel(h, p["w2"], p["b2"]), 0.0), k)
    logits = _maybe_round(dense_kernel(h, p["w3"], p["b3"]), k)
    return _maybe_round(softmax(logits), k)


def _round_tree(params, k):
    def rec(v):
        if isinstance(v, dict):
            return {n: rec(x) for n, x in v.items()}
        return _maybe_round(v, k)

    return rec(params)


def mobilenet_mini_fwd(params, x, k=None):
    """``x: [16, 16, 3]`` raw pixels (normalization folded into c1)."""
    p = _round_tree(params, k)

    def bn(x, g):
        return batch_norm_infer(x, (g["gamma"], g["beta"], g["mean"], g["var"], BN_EPS))

    r = lambda t: _maybe_round(t, k)
    h = r(jnp.maximum(bn(conv2d(x, p["c1"], p["c1b"], 1, "SAME"), p["bn1"]), 0.0))
    h = r(jnp.maximum(depthwise2d(h, p["dw2"], p["dw2b"], 1, "SAME"), 0.0))
    h = r(jnp.maximum(bn(conv2d(h, p["pw2"], p["pw2b"], 1, "SAME"), p["bn2"]), 0.0))
    h = r(jnp.maximum(depthwise2d(h, p["dw3"], p["dw3b"], 2, "SAME"), 0.0))
    h = r(jnp.maximum(bn(conv2d(h, p["pw3"], p["pw3b"], 1, "SAME"), p["bn3"]), 0.0))
    h = r(max_pool(h, 2, 2))  # [4, 4, 32]
    logits = r(dense_kernel(h.reshape(-1), p["w_out"], p["b_out"]))
    return r(softmax(logits))


def pendulum_fwd(params, x, k=None):
    """``x: [2]`` -> scalar Lyapunov value ``[1]`` (Dense tanh Dense tanh)."""
    p = {n: _maybe_round(v, k) for n, v in params.items()}
    h = _maybe_round(jnp.tanh(dense_kernel(x, p["w1"], p["b1"])), k)
    return _maybe_round(jnp.tanh(dense_kernel(h, p["w2"], p["b2"])), k)


def residual_mlp_fwd(params, x, k=None):
    """``x: [8]`` -> 3-class softmax through one additive residual block:
    ``a1 = relu(d1(x)); a2 = relu(d2(a1) + a1); softmax(d3(a2))``. The skip
    add accumulates left to right in declared inbound order — the rounding
    profile the Rust merge kernel (`layers::merge::add_assign_into`) pins."""
    p = {n: _maybe_round(v, k) for n, v in params.items()}
    a1 = _maybe_round(jnp.maximum(dense_kernel(x, p["w1"], p["b1"]), 0.0), k)
    d2 = _maybe_round(dense_kernel(a1, p["w2"], p["b2"]), k)
    a2 = _maybe_round(jnp.maximum(d2 + a1, 0.0), k)
    logits = _maybe_round(dense_kernel(a2, p["w3"], p["b3"]), k)
    return _maybe_round(softmax(logits), k)


MODELS = {
    "digits": {"fwd": digits_fwd, "init": init_digits, "input_shape": (784,), "output_shape": (10,)},
    "mobilenet_mini": {
        "fwd": mobilenet_mini_fwd,
        "init": init_mobilenet_mini,
        "input_shape": (16, 16, 3),
        "output_shape": (10,),
    },
    "pendulum": {
        "fwd": pendulum_fwd,
        "init": init_pendulum,
        "input_shape": (2,),
        "output_shape": (1,),
    },
    "residual_mlp": {
        "fwd": residual_mlp_fwd,
        "init": init_residual_mlp,
        "input_shape": (8,),
        "output_shape": (3,),
    },
}
