"""Layer-1 Pallas kernels (build-time only; lowered into the AOT HLO).

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode is the correctness path; real-TPU
performance is *estimated structurally* from the BlockSpec tiling (see
DESIGN.md §Hardware-Adaptation and EXPERIMENTS.md §Perf).
"""

from .dense import dense, DENSE_BLOCK_M, DENSE_BLOCK_N, DENSE_BLOCK_K
from .roundk import round_to_precision
from .softmax import softmax

__all__ = [
    "dense",
    "round_to_precision",
    "softmax",
    "DENSE_BLOCK_M",
    "DENSE_BLOCK_N",
    "DENSE_BLOCK_K",
]
