"""Pallas kernel: round f32 values to k mantissa bits (RTNE).

The numeric-format primitive of the reproduction: emulates storing a tensor
in a precision-k floating-point format (k counts the implicit leading 1, so
k = 24 is the f32 identity) with round-to-nearest-even, exponent range
unchanged. This is the Rust `quant::round_to_precision` twin; the two are
cross-checked through the PJRT runtime in `rust/tests/runtime_e2e.rs`.

TPU mapping: a pure VPU elementwise bit-twiddle (bitcast + mask + add); it
fuses into the surrounding computation and is memory-bound, so the BlockSpec
keeps whole rows resident in VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _roundk_kernel(x_ref, o_ref, *, drop: int):
    """Round the block in x_ref to (24 - drop) mantissa bits."""
    x = x_ref[...]
    bits = jax.lax.bitcast_convert_type(x, jnp.int32)
    mask = jnp.int32((1 << drop) - 1)
    tail = jnp.bitwise_and(bits, mask)
    truncated = jnp.bitwise_and(bits, jnp.bitwise_not(mask))
    half = jnp.int32(1 << (drop - 1))
    kept_lsb = jnp.bitwise_and(jax.lax.shift_right_logical(truncated, drop), 1)
    round_up = (tail > half) | ((tail == half) & (kept_lsb == 1))
    out_bits = truncated + jnp.where(round_up, jnp.int32(1 << drop), jnp.int32(0))
    out = jax.lax.bitcast_convert_type(out_bits, jnp.float32)
    # Zero stays exactly zero (and keeps its sign); non-finite pass through.
    o_ref[...] = jnp.where(jnp.isfinite(x), jnp.where(x == 0.0, x, out), x)


@functools.partial(jax.jit, static_argnames=("k",))
def round_to_precision(x, k: int):
    """Round an f32 array to ``k`` mantissa bits, round-to-nearest-even.

    ``k`` must be in [2, 24]; ``k = 24`` is the identity.
    """
    if not 2 <= k <= 24:
        raise ValueError(f"k must be in [2, 24], got {k}")
    if k == 24:
        return jnp.asarray(x, jnp.float32)
    x = jnp.asarray(x, jnp.float32)
    kernel = functools.partial(_roundk_kernel, drop=24 - k)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x)
