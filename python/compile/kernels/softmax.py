"""Pallas kernel: numerically-stable softmax over the last axis.

The max-subtraction + exp + normalize pattern — the exact code shape the
CAA analysis instruments on the Rust side (decorrelated subtraction, exp of
a nonpositive value, positive summation). One VMEM-resident block per row;
class counts are tiny (<= 1000 in the paper), so a row always fits.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    s = jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = e / s


@jax.jit
def softmax(x):
    """Softmax over the last axis of ``x`` (any rank >= 1)."""
    x = jnp.asarray(x, jnp.float32)
    flat = x.reshape((-1, x.shape[-1]))
    out = pl.pallas_call(
        _softmax_kernel,
        out_shape=jax.ShapeDtypeStruct(flat.shape, jnp.float32),
        interpret=True,
    )(flat)
    return out.reshape(x.shape)
