"""Pallas kernel: tiled dense layer ``y = x @ W + b``.

TPU mapping (DESIGN.md §Hardware-Adaptation): the GEMM is tiled into
(BLOCK_M, BLOCK_K) x (BLOCK_K, BLOCK_N) VMEM-resident blocks via BlockSpec,
with an MXU-aligned 128-lane inner dimension; the K loop is the innermost
grid axis so partial products accumulate in the output block across grid
steps (the standard Pallas accumulation idiom). Inputs whose dimensions are
not multiples of the block sizes are zero-padded by the wrapper and the
result is sliced back — zero padding is exact for a matmul.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned default tile sizes (f32: 8x128 VPU lanes, 128x128 MXU).
DENSE_BLOCK_M = 8
DENSE_BLOCK_N = 128
DENSE_BLOCK_K = 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (M, K) x (K, N) tile; accumulates over the K grid axis."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(a, rows, cols):
    return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))


@jax.jit
def dense(x, w, b):
    """``x: [batch, n_in] (or [n_in])``, ``w: [n_in, n_out]``, ``b: [n_out]``.

    Returns ``x @ w + b`` with the matmul computed by the tiled Pallas
    kernel.
    """
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"dense: x has {k} features, w expects {k2}"

    bm = min(DENSE_BLOCK_M, _ceil_mult(m))
    bn = min(DENSE_BLOCK_N, _ceil_mult(n))
    bk = min(DENSE_BLOCK_K, _ceil_mult(k))
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)

    xp = _pad_to(x.astype(jnp.float32), mp, kp)
    wp = _pad_to(w.astype(jnp.float32), kp, np_)

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)

    y = out[:m, :n] + b[None, :]
    return y[0] if squeeze else y


def _round_up(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


def _ceil_mult(v: int) -> int:
    """Smallest power of two >= v (tiles for tiny dimensions)."""
    p = 1
    while p < v:
        p *= 2
    return p


@functools.partial(jax.jit, static_argnames=())
def dense_ref_free(x, w, b):
    """Non-Pallas fallback used while debugging lowering issues."""
    return x @ w + b
