"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal:
pytest asserts kernel == ref across shapes and dtypes via hypothesis)."""

import numpy as np
import jax
import jax.numpy as jnp


def dense_ref(x, w, b):
    """y = x @ w + b."""
    return jnp.asarray(x, jnp.float32) @ jnp.asarray(w, jnp.float32) + b


def softmax_ref(x):
    x = jnp.asarray(x, jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def conv2d_ref(x, kernel, bias, stride=1, padding="SAME"):
    """NHWC x HWIO conv. ``x: [h, w, cin]`` (single sample) or NHWC batch."""
    squeeze = x.ndim == 3
    if squeeze:
        x = x[None]
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        kernel.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ) + bias
    return y[0] if squeeze else y


def depthwise_ref(x, kernel, bias, stride=1, padding="SAME"):
    """Depthwise conv; ``kernel: [kh, kw, c]``."""
    c = kernel.shape[-1]
    k = kernel[..., None, :] * np.eye(c, dtype=np.float32)[None, None, :, :]
    # Equivalent grouped formulation: HWIO with feature_group_count = c.
    squeeze = x.ndim == 3
    if squeeze:
        x = x[None]
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        kernel[:, :, None, :].astype(jnp.float32),  # HW1C -> HWIO, groups=c
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    ) + bias
    del k
    return y[0] if squeeze else y


def max_pool_ref(x, ph, pw):
    """``x: [h, w, c]``; non-overlapping windows (stride = pool)."""
    h, w, c = x.shape
    x = x.reshape(h // ph, ph, w // pw, pw, c)
    return x.max(axis=(1, 3))


def avg_pool_ref(x, ph, pw):
    h, w, c = x.shape
    x = x.reshape(h // ph, ph, w // pw, pw, c)
    return x.mean(axis=(1, 3))


def batch_norm_ref(x, gamma, beta, mean, var, eps):
    return gamma * (x - mean) / jnp.sqrt(var + eps) + beta


def roundk_ref(x, k: int):
    """NumPy reference for round-to-k-mantissa-bits (RTNE) on f32."""
    x = np.asarray(x, np.float32)
    if k == 24:
        return x
    drop = 24 - k
    bits = x.view(np.int32)
    mask = np.int32((1 << drop) - 1)
    tail = bits & mask
    truncated = bits & ~mask
    half = np.int32(1 << (drop - 1))
    kept_lsb = (truncated >> drop) & 1
    round_up = (tail > half) | ((tail == half) & (kept_lsb == 1))
    out_bits = truncated + np.where(round_up, np.int32(1 << drop), np.int32(0))
    out = out_bits.view(np.float32)
    return np.where(np.isfinite(x), np.where(x == 0.0, x, out), x).astype(np.float32)
