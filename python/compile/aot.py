"""AOT build driver: train the evaluation networks once, export everything
the Rust coordinator needs, and lower the inference functions to HLO text.

Outputs (under ``--out-dir``, default ``../artifacts``):

* ``models/<name>.json``    — weights in the Rust engine's exchange format
* ``data/<name>_eval.json`` — evaluation datasets (raw exact-integer pixels)
* ``<name>.<variant>.hlo.txt`` — AOT artifacts: ``f32`` reference inference
  plus ``k<bits>`` storage-emulated precision variants (Pallas roundk baked
  into the graph)
* ``manifest.json``         — the artifact index the Rust runtime loads

HLO **text** is the interchange format (not ``.serialize()``): jax >= 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
the text parser reassigns ids (see /opt/xla-example/README.md).

Run via ``make artifacts`` — a no-op if the manifest is newer than the
compile sources.
"""

import argparse
import functools
import json
import os
import sys

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import datagen, model, train

PRECISION_VARIANTS = [4, 6, 8, 10, 12, 16, 20]


# ---------------------------------------------------------------------------
# HLO lowering (see /opt/xla-example/gen_hlo.py)
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_model(fwd, params, input_shape, k=None) -> str:
    def fn(x):
        return (fwd(params, x, k=k),)

    spec = jax.ShapeDtypeStruct(input_shape, jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


# ---------------------------------------------------------------------------
# weight export (Rust model JSON format; see rust/src/model/json_fmt.rs)
# ---------------------------------------------------------------------------

def _np(a):
    return np.asarray(a, np.float64)


def _dense_layer(w, b):
    """jax convention w: [in, units] -> rust convention [units, in]."""
    w = _np(w).T
    return {
        "type": "dense",
        "units": int(w.shape[0]),
        "in": int(w.shape[1]),
        "weights": w.reshape(-1).tolist(),
        "bias": _np(b).tolist(),
    }


def _conv_layer(k, b, stride, padding):
    k = _np(k)
    kh, kw, cin, cout = k.shape
    return {
        "type": "conv2d",
        "kh": kh, "kw": kw, "cin": cin, "cout": cout,
        "stride": stride, "padding": padding,
        "weights": k.reshape(-1).tolist(),
        "bias": _np(b).tolist(),
    }


def _dw_layer(k, b, stride, padding):
    k = _np(k)
    kh, kw, c = k.shape
    return {
        "type": "depthwise_conv2d",
        "kh": kh, "kw": kw, "c": c,
        "stride": stride, "padding": padding,
        "weights": k.reshape(-1).tolist(),
        "bias": _np(b).tolist(),
    }


def _bn_layer(g):
    return {
        "type": "batch_norm",
        "gamma": _np(g["gamma"]).tolist(),
        "beta": _np(g["beta"]).tolist(),
        "mean": _np(g["mean"]).tolist(),
        "variance": np.maximum(_np(g["var"]), 0.0).tolist(),
        "eps": model.BN_EPS,
    }


def export_digits(params):
    return {
        "name": "digits",
        "input_shape": [784],
        "layers": [
            _dense_layer(params["w1"], params["b1"]),
            {"type": "relu"},
            _dense_layer(params["w2"], params["b2"]),
            {"type": "relu"},
            _dense_layer(params["w3"], params["b3"]),
            {"type": "softmax"},
        ],
    }


def export_mobilenet_mini(params):
    return {
        "name": "mobilenet_mini",
        "input_shape": [16, 16, 3],
        "layers": [
            _conv_layer(params["c1"], params["c1b"], 1, "same"),
            _bn_layer(params["bn1"]),
            {"type": "relu"},
            _dw_layer(params["dw2"], params["dw2b"], 1, "same"),
            {"type": "relu"},
            _conv_layer(params["pw2"], params["pw2b"], 1, "same"),
            _bn_layer(params["bn2"]),
            {"type": "relu"},
            _dw_layer(params["dw3"], params["dw3b"], 2, "same"),
            {"type": "relu"},
            _conv_layer(params["pw3"], params["pw3b"], 1, "same"),
            _bn_layer(params["bn3"]),
            {"type": "relu"},
            {"type": "max_pool2d", "ph": 2, "pw": 2},
            {"type": "flatten"},
            _dense_layer(params["w_out"], params["b_out"]),
            {"type": "softmax"},
        ],
    }


def export_pendulum(params):
    return {
        "name": "pendulum",
        "input_shape": [2],
        "layers": [
            _dense_layer(params["w1"], params["b1"]),
            {"type": "tanh"},
            _dense_layer(params["w2"], params["b2"]),
            {"type": "tanh"},
        ],
    }


# ---------------------------------------------------------------------------
# graph (non-sequential) wiring — frugally-deep-style name/inbound edges,
# matching rust/src/model/json_fmt.rs ("Graph (non-sequential) models")
# ---------------------------------------------------------------------------

def wired(layer, name, inbound):
    """Attach graph wiring to a layer dict: a unique ``name`` and the
    ``inbound`` list of producer names (the reserved name ``"input"`` is
    the model input). Returns a new dict; wiring keys come first so the
    exported JSON reads topology-first."""
    out = {"name": name, "inbound": list(inbound)}
    out.update(layer)
    return out


def export_graph_model(name, input_shape, layers, output):
    """Assemble a graph-wired model JSON. The Rust loader's contract is
    all-or-nothing wiring, so every layer must have passed through
    :func:`wired`; ``output`` names the output node."""
    for i, layer in enumerate(layers):
        if "name" not in layer or "inbound" not in layer:
            raise ValueError(
                f"graph models need 'name'/'inbound' on every layer (layer {i} lacks them)"
            )
    names = [l["name"] for l in layers]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate layer names in graph export: {names}")
    if output not in names:
        raise ValueError(f"output node '{output}' is not a layer name")
    return {
        "name": name,
        "input_shape": list(input_shape),
        "output": output,
        "layers": list(layers),
    }


def export_residual_mlp(params):
    """The residual block (`model.residual_mlp_fwd`) through the same JSON
    channel as the zoo builders: an `add` merge joins the block output with
    its skip source, inbound order pinning the accumulation order."""
    return export_graph_model(
        "residual_mlp",
        [8],
        [
            wired(_dense_layer(params["w1"], params["b1"]), "d1", ["input"]),
            wired({"type": "relu"}, "a1", ["d1"]),
            wired(_dense_layer(params["w2"], params["b2"]), "d2", ["a1"]),
            wired({"type": "add"}, "add1", ["d2", "a1"]),
            wired({"type": "relu"}, "a2", ["add1"]),
            wired(_dense_layer(params["w3"], params["b3"]), "d3", ["a2"]),
            wired({"type": "softmax"}, "out", ["d3"]),
        ],
        "out",
    )


def _dataset_json(input_shape, inputs, labels=None):
    d = {
        "input_shape": list(input_shape),
        "inputs": [np.asarray(i, np.float64).reshape(-1).tolist() for i in inputs],
    }
    if labels is not None:
        d["labels"] = [int(l) for l in labels]
    return d


# ---------------------------------------------------------------------------
# build
# ---------------------------------------------------------------------------

def build(out_dir: str, quick: bool = False, ks=None, verbose=True):
    ks = PRECISION_VARIANTS if ks is None else ks
    os.makedirs(out_dir, exist_ok=True)
    os.makedirs(os.path.join(out_dir, "models"), exist_ok=True)
    os.makedirs(os.path.join(out_dir, "data"), exist_ok=True)
    log = print if verbose else (lambda *a, **k: None)
    scale = 0.1 if quick else 1.0

    manifest = {"artifacts": []}

    def emit(name, fwd, params, input_shape, output_shape):
        for variant, k in [("f32", None)] + [(f"k{k}", k) for k in ks]:
            hlo = lower_model(fwd, params, input_shape, k=k)
            fname = f"{name}.{variant}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(hlo)
            manifest["artifacts"].append(
                {
                    "name": name,
                    "variant": variant,
                    "path": fname,
                    "input_shape": list(input_shape),
                    "output_shape": list(output_shape),
                }
            )
            log(f"  lowered {name}:{variant} ({len(hlo)//1024} KiB)")

    rng = np.random.RandomState(12345)

    # ---- digits -----------------------------------------------------------
    log("[digits] training ...")
    params = model.init_digits(rng)
    params, acc = train.train_digits(
        params,
        steps=int(400 * scale) or 40,
        n_per_class=int(40 * scale) or 6,
    )
    log(f"[digits] train accuracy = {acc:.3f}")
    params = train.fold_input_scale(params, "w1", 255.0)
    with open(os.path.join(out_dir, "models", "digits.json"), "w") as f:
        json.dump(export_digits(params), f)
    eval_rng = np.random.RandomState(777)
    x_eval, y_eval = datagen.digits(eval_rng, 28, 10 if not quick else 2)
    with open(os.path.join(out_dir, "data", "digits_eval.json"), "w") as f:
        json.dump(_dataset_json([784], x_eval, y_eval), f)
    emit("digits", model.digits_fwd, params, (784,), (10,))

    # ---- mobilenet_mini ---------------------------------------------------
    log("[mobilenet_mini] training ...")
    params = model.init_mobilenet_mini(rng)
    params, acc = train.train_mobilenet_mini(
        params,
        steps=int(300 * scale) or 30,
        n_per_class=int(30 * scale) or 4,
    )
    log(f"[mobilenet_mini] train accuracy = {acc:.3f}")
    params = train.fold_input_scale(params, "c1", 255.0)
    with open(os.path.join(out_dir, "models", "mobilenet_mini.json"), "w") as f:
        json.dump(export_mobilenet_mini(params), f)
    eval_rng = np.random.RandomState(778)
    x_eval, y_eval = datagen.color_blobs(eval_rng, 16, 10, 6 if not quick else 1)
    with open(os.path.join(out_dir, "data", "mobilenet_mini_eval.json"), "w") as f:
        json.dump(_dataset_json([16, 16, 3], x_eval, y_eval), f)
    emit("mobilenet_mini", model.mobilenet_mini_fwd, params, (16, 16, 3), (10,))

    # ---- pendulum ---------------------------------------------------------
    log("[pendulum] training ...")
    params = model.init_pendulum(rng)
    params, mse = train.train_pendulum(params, steps=int(600 * scale) or 60)
    log(f"[pendulum] train mse = {mse:.5f}")
    with open(os.path.join(out_dir, "models", "pendulum.json"), "w") as f:
        json.dump(export_pendulum(params), f)
    x_eval = datagen.pendulum_grid(9)
    with open(os.path.join(out_dir, "data", "pendulum_eval.json"), "w") as f:
        json.dump(_dataset_json([2], x_eval), f)
    emit("pendulum", model.pendulum_fwd, params, (2,), (1,))

    # ---- residual_mlp (graph-wired JSON channel) --------------------------
    # A Keras-functional-style residual block exported with name/inbound
    # wiring — the same channel the Rust zoo's graph models use. Weights
    # are Glorot-initialized (the workload here is the topology and the
    # export path, not accuracy); the HLO variants exercise the identical
    # skip-add computation under storage emulation.
    log("[residual_mlp] exporting graph-wired block ...")
    params = model.init_residual_mlp(rng)
    with open(os.path.join(out_dir, "models", "residual_mlp.json"), "w") as f:
        json.dump(export_residual_mlp(params), f)
    eval_rng = np.random.RandomState(779)
    x_eval = eval_rng.uniform(0.0, 1.0, size=(12, 8)).astype("float32")
    y_eval = [i % 3 for i in range(12)]
    with open(os.path.join(out_dir, "data", "residual_mlp_eval.json"), "w") as f:
        json.dump(_dataset_json([8], x_eval, y_eval), f)
    emit("residual_mlp", model.residual_mlp_fwd, params, (8,), (3,))

    # ---- standalone roundk kernel artifacts (Rust <-> Pallas cross-check)
    from .kernels import round_to_precision

    for k in ks:
        def rk(x, _k=k):
            return (round_to_precision(x, _k),)

        spec = jax.ShapeDtypeStruct((64,), jnp.float32)
        hlo = to_hlo_text(jax.jit(rk).lower(spec))
        fname = f"roundk.k{k}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(hlo)
        manifest["artifacts"].append(
            {
                "name": "roundk",
                "variant": f"k{k}",
                "path": fname,
                "input_shape": [64],
                "output_shape": [64],
            }
        )
    log(f"  lowered roundk kernels for k in {ks}")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    log(f"wrote {len(manifest['artifacts'])} artifacts to {out_dir}")
    return manifest


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true", help="tiny training run (CI/tests)")
    ap.add_argument(
        "--ks",
        default=",".join(str(k) for k in PRECISION_VARIANTS),
        help="comma-separated precision variants",
    )
    args = ap.parse_args(argv)
    ks = [int(s) for s in args.ks.split(",") if s]
    build(args.out_dir, quick=args.quick, ks=ks)


if __name__ == "__main__":
    main()
