"""Synthetic dataset generators (build-time).

Substitutes for the paper's MNIST / ImageNet / pendulum data (DESIGN.md
§Substitutions): the error analysis measures arithmetic, not learning
quality, so any trained classifier with the right topology exercises the
same rounding paths. Pixel data is generated as **integers in [0, 255]** so
the deployed inputs are exactly representable in every format with k >= 8
(the paper annotates image data as 8-bit unsigned); the `exact_inputs`
analysis mode depends on this.
"""

import numpy as np


def digit_prototype(d: int, s: int) -> np.ndarray:
    """Seven-segment-style stroke prototype of digit ``d`` on an s x s grid."""
    img = np.zeros((s, s), np.float64)
    lo, hi, mid = s // 5, s - 1 - s // 5, s // 2
    segs = {
        0: [0, 1, 2, 3, 4, 5],
        1: [1, 2],
        2: [0, 1, 6, 4, 3],
        3: [0, 1, 6, 2, 3],
        4: [5, 6, 1, 2],
        5: [0, 5, 6, 2, 3],
        6: [0, 5, 4, 3, 2, 6],
        7: [0, 1, 2],
        8: [0, 1, 2, 3, 4, 5, 6],
        9: [6, 5, 0, 1, 2, 3],
    }[d % 10]
    for seg in segs:
        if seg == 0:
            img[lo, lo : hi + 1] = 1.0
        elif seg == 1:
            img[lo : mid + 1, hi] = 1.0
        elif seg == 2:
            img[mid : hi + 1, hi] = 1.0
        elif seg == 3:
            img[hi, lo : hi + 1] = 1.0
        elif seg == 4:
            img[mid : hi + 1, lo] = 1.0
        elif seg == 5:
            img[lo : mid + 1, lo] = 1.0
        elif seg == 6:
            img[mid, lo : hi + 1] = 1.0
    return img


def digits(rng: np.random.RandomState, s: int, n_per_class: int, noise: float = 0.08):
    """Noisy shifted digits; returns (X_raw_uint8_as_f32, y). X in [0, 255]."""
    xs, ys = [], []
    for d in range(10):
        proto = digit_prototype(d, s)
        for _ in range(n_per_class):
            dx, dy = rng.randint(-2, 3), rng.randint(-2, 3)
            img = np.roll(np.roll(proto, dy, axis=0), dx, axis=1)
            img = np.clip(img + noise * rng.randn(s, s), 0.0, 1.0)
            raw = np.rint(img * 255.0)  # integer pixels: exact for k >= 8
            xs.append(raw.reshape(-1))
            ys.append(d)
    x = np.asarray(xs, np.float32)
    y = np.asarray(ys, np.int32)
    perm = rng.permutation(len(y))
    return x[perm], y[perm]


def color_blobs(rng: np.random.RandomState, s: int, classes: int, n_per_class: int):
    """Class-colored radial blobs, s x s x 3, integer pixels in [0, 255]."""
    xs, ys = [], []
    for c in range(classes):
        phase = c / classes
        for _ in range(n_per_class):
            cx, cy = rng.uniform(0.3, 0.7, 2) * s
            yy, xx = np.mgrid[0:s, 0:s]
            d = np.sqrt((xx - cx) ** 2 + (yy - cy) ** 2) / s
            base = np.maximum(1.0 - d, 0.0)
            img = np.stack(
                [
                    base * (0.3 + 0.7 * phase),
                    base * (1.0 - phase),
                    0.5 * base,
                ],
                axis=-1,
            )
            img = np.clip(img + 0.05 * rng.randn(s, s, 3), 0.0, 1.0)
            xs.append(np.rint(img * 255.0))
            ys.append(c)
    x = np.asarray(xs, np.float32)
    y = np.asarray(ys, np.int32)
    perm = rng.permutation(len(y))
    return x[perm], y[perm]


def lyapunov_target(x: np.ndarray) -> np.ndarray:
    """A Lyapunov-like positive-definite target on the pendulum box:
    V(x) = 0.6 x1^2 + 0.4 x2^2 + 0.25 x1 x2 + 0.05 (1 - cos x1)."""
    x1, x2 = x[..., 0], x[..., 1]
    return 0.6 * x1**2 + 0.4 * x2**2 + 0.25 * x1 * x2 + 0.05 * (1.0 - np.cos(x1))


def pendulum(rng: np.random.RandomState, n: int):
    """Random training points in [-6, 6]^2 with Lyapunov targets."""
    x = rng.uniform(-6.0, 6.0, size=(n, 2)).astype(np.float32)
    v = lyapunov_target(x).astype(np.float32)[:, None]
    return x, v


def pendulum_grid(per_axis: int):
    """Evaluation grid over [-6, 6]^2; per_axis = 2^m + 1 keeps every
    coordinate exactly representable at small k."""
    t = np.linspace(-6.0, 6.0, per_axis)
    xx, yy = np.meshgrid(t, t)
    return np.stack([xx.reshape(-1), yy.reshape(-1)], axis=-1).astype(np.float32)
