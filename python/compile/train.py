"""Build-time training of the three evaluation networks.

Hand-rolled Adam on ``jax.grad`` (no optax in the build image). Training
uses fast pure-XLA forwards (lax.conv / jnp.matmul); the Pallas-kernel
forwards in :mod:`compile.model` are the *inference* path that gets
AOT-lowered — pytest asserts the two agree on the trained parameters.

After training, the input normalization (/255 on integer pixels) is folded
into the first linear layer (`fold_input_scale`), so the deployed network
consumes raw [0, 255] data — which is exactly representable for k >= 8,
enabling the paper-faithful `exact_inputs` analysis mode.
"""

import numpy as np
import jax
import jax.numpy as jnp

from . import datagen
from .model import BN_EPS


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return params, {"m": m, "v": v, "t": t}


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


# ---------------------------------------------------------------------------
# digits MLP
# ---------------------------------------------------------------------------

def _digits_logits(params, xb):
    h = jnp.maximum(xb @ params["w1"] + params["b1"], 0.0)
    h = jnp.maximum(h @ params["w2"] + params["b2"], 0.0)
    return h @ params["w3"] + params["b3"]


def train_digits(params, seed=0, steps=400, batch=64, n_per_class=40, lr=2e-3):
    """Returns (params, final_accuracy). Trains on *normalized* pixels."""
    rng = np.random.RandomState(seed)
    x_raw, y = datagen.digits(rng, 28, n_per_class)
    x = jnp.asarray(x_raw / 255.0)
    y = jnp.asarray(y)

    @jax.jit
    def loss_fn(p, xb, yb):
        return cross_entropy(_digits_logits(p, xb), yb)

    grad_fn = jax.jit(jax.grad(loss_fn))
    state = adam_init(params)
    n = x.shape[0]
    for step in range(steps):
        idx = rng.randint(0, n, size=batch)
        grads = grad_fn(params, x[idx], y[idx])
        params, state = adam_step(params, grads, state, lr=lr)
    preds = jnp.argmax(_digits_logits(params, x), axis=1)
    acc = float(jnp.mean((preds == y).astype(jnp.float32)))
    return params, acc


def fold_input_scale(params, first_weight_key: str, scale: float):
    """Fold ``x/scale`` normalization into the first linear layer so the
    deployed network consumes raw integer pixels."""
    p = dict(params)
    p[first_weight_key] = params[first_weight_key] / scale
    return p


# ---------------------------------------------------------------------------
# mobilenet-mini CNN
# ---------------------------------------------------------------------------

def _conv(x, k, b, stride):
    return jax.lax.conv_general_dilated(
        x, k, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    ) + b


def _dwconv(x, k, b, stride):
    c = k.shape[-1]
    return jax.lax.conv_general_dilated(
        x,
        k[:, :, None, :],
        (stride, stride),
        "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    ) + b


def _bn_train(x, g, axes=(0, 1, 2)):
    mu = jnp.mean(x, axis=axes)
    var = jnp.var(x, axis=axes)
    y = g["gamma"] * (x - mu) / jnp.sqrt(var + BN_EPS) + g["beta"]
    return y, mu, var


def _mobilenet_forward_train(params, xb):
    """Batched training forward. Returns (logits, stats dict of (mu, var))."""
    stats = {}

    def bn(x, name):
        y, mu, var = _bn_train(x, params[name])
        stats[name] = (mu, var)
        return y

    h = jnp.maximum(bn(_conv(xb, params["c1"], params["c1b"], 1), "bn1"), 0.0)
    h = jnp.maximum(_dwconv(h, params["dw2"], params["dw2b"], 1), 0.0)
    h = jnp.maximum(bn(_conv(h, params["pw2"], params["pw2b"], 1), "bn2"), 0.0)
    h = jnp.maximum(_dwconv(h, params["dw3"], params["dw3b"], 2), 0.0)
    h = jnp.maximum(bn(_conv(h, params["pw3"], params["pw3b"], 1), "bn3"), 0.0)
    b, hh, ww, c = h.shape
    h = h.reshape(b, hh // 2, 2, ww // 2, 2, c).max(axis=(2, 4))
    logits = h.reshape(b, -1) @ params["w_out"] + params["b_out"]
    return logits, stats


def batchnorm_apply(x, g, stats):
    mu, var = stats
    return g["gamma"] * (x - mu) / jnp.sqrt(var + BN_EPS) + g["beta"]


def train_mobilenet_mini(params, seed=1, steps=300, batch=32, n_per_class=30, lr=2e-3):
    """Returns (params-with-running-stats, accuracy). Normalized pixels."""
    rng = np.random.RandomState(seed)
    x_raw, y = datagen.color_blobs(rng, 16, 10, n_per_class)
    x = jnp.asarray(x_raw / 255.0)
    y = jnp.asarray(y)

    def loss_fn(p, xb, yb):
        logits, _ = _mobilenet_forward_train(p, xb)
        return cross_entropy(logits, yb)

    grad_fn = jax.jit(jax.grad(loss_fn))
    fwd = jax.jit(_mobilenet_forward_train)
    state = adam_init(params)
    running = {name: None for name in ("bn1", "bn2", "bn3")}
    momentum = 0.9
    n = x.shape[0]
    for step in range(steps):
        idx = rng.randint(0, n, size=batch)
        grads = grad_fn(params, x[idx], y[idx])
        # BN statistics are not trained by gradient.
        for name in running:
            grads[name] = jax.tree_util.tree_map(jnp.zeros_like, grads[name])
        params, state = adam_step(params, grads, state, lr=lr)
        _, stats = fwd(params, x[idx])
        for name, (mu, var) in stats.items():
            if running[name] is None:
                running[name] = (mu, var)
            else:
                rm, rv = running[name]
                running[name] = (
                    momentum * rm + (1 - momentum) * mu,
                    momentum * rv + (1 - momentum) * var,
                )
    for name, (mu, var) in running.items():
        params[name] = dict(params[name])
        params[name]["mean"] = mu
        params[name]["var"] = var
    logits, _ = fwd(params, x)
    acc = float(jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32)))
    return params, acc


# ---------------------------------------------------------------------------
# pendulum Lyapunov net
# ---------------------------------------------------------------------------

def _pendulum_out(params, xb):
    h = jnp.tanh(xb @ params["w1"] + params["b1"])
    return jnp.tanh(h @ params["w2"] + params["b2"])


def train_pendulum(params, seed=2, steps=600, batch=128, n=4000, lr=3e-3):
    """Returns (params, final MSE)."""
    rng = np.random.RandomState(seed)
    x, v = datagen.pendulum(rng, n)
    x = jnp.asarray(x)
    v = jnp.asarray(v / 64.0)  # 2^6 rescale keeps targets in tanh range, exactly invertible

    @jax.jit
    def loss_fn(p, xb, vb):
        return jnp.mean((_pendulum_out(p, xb) - vb) ** 2)

    grad_fn = jax.jit(jax.grad(loss_fn))
    state = adam_init(params)
    for step in range(steps):
        idx = rng.randint(0, x.shape[0], size=batch)
        grads = grad_fn(params, x[idx], v[idx])
        params, state = adam_step(params, grads, state, lr=lr)
    mse = float(loss_fn(params, x, v))
    return params, mse
