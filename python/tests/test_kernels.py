"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles (ref.py).

hypothesis sweeps shapes and value regimes — the CORE correctness signal
for the compile path.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dense, round_to_precision, softmax
from compile.kernels import ref

settings.register_profile("kernels", max_examples=40, deadline=None)
settings.load_profile("kernels")


def _arr(rng, *shape, scale=2.0):
    return (rng.randn(*shape) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------

@given(
    m=st.integers(1, 17),
    k=st.integers(1, 200),
    n=st.integers(1, 150),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_matches_ref(m, k, n, seed):
    rng = np.random.RandomState(seed)
    x, w, b = _arr(rng, m, k), _arr(rng, k, n), _arr(rng, n)
    got = np.asarray(dense(x, w, b))
    want = np.asarray(ref.dense_ref(x, w, b))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


def test_dense_vector_input():
    rng = np.random.RandomState(0)
    x, w, b = _arr(rng, 33), _arr(rng, 33, 5), _arr(rng, 5)
    got = np.asarray(dense(x, w, b))
    assert got.shape == (5,)
    np.testing.assert_allclose(got, np.asarray(x @ w + b), rtol=2e-5, atol=2e-4)


def test_dense_blocked_path_exercised():
    # Dimensions above one block force a multi-step K accumulation.
    rng = np.random.RandomState(1)
    x, w, b = _arr(rng, 16, 700), _arr(rng, 700, 300), _arr(rng, 300)
    got = np.asarray(dense(x, w, b))
    want = np.asarray(ref.dense_ref(x, w, b))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-3)


# ---------------------------------------------------------------------------
# softmax
# ---------------------------------------------------------------------------

@given(
    rows=st.integers(1, 8),
    n=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([0.1, 1.0, 10.0, 80.0]),
)
def test_softmax_matches_ref(rows, n, seed, scale):
    rng = np.random.RandomState(seed)
    x = _arr(rng, rows, n, scale=scale)
    got = np.asarray(softmax(x))
    want = np.asarray(ref.softmax_ref(x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got.sum(axis=-1), 1.0, rtol=1e-5)


def test_softmax_extreme_logits_stable():
    x = np.array([[1000.0, 0.0, -1000.0]], np.float32)
    got = np.asarray(softmax(x))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got[0, 0], 1.0, rtol=1e-6)


# ---------------------------------------------------------------------------
# roundk
# ---------------------------------------------------------------------------

@given(
    k=st.integers(2, 24),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1e-6, 1.0, 255.0, 1e6]),
)
def test_roundk_matches_ref(k, seed, scale):
    rng = np.random.RandomState(seed)
    x = _arr(rng, 64, scale=scale)
    got = np.asarray(round_to_precision(x, k))
    want = ref.roundk_ref(x, k)
    np.testing.assert_array_equal(got, want)


@given(k=st.integers(2, 23), seed=st.integers(0, 2**31 - 1))
def test_roundk_idempotent(k, seed):
    rng = np.random.RandomState(seed)
    x = _arr(rng, 32)
    once = np.asarray(round_to_precision(x, k))
    twice = np.asarray(round_to_precision(once, k))
    np.testing.assert_array_equal(once, twice)


@given(k=st.integers(4, 23), seed=st.integers(0, 2**31 - 1))
def test_roundk_half_ulp(k, seed):
    rng = np.random.RandomState(seed)
    x = _arr(rng, 64)
    x = x[x != 0.0]
    got = np.asarray(round_to_precision(x, k))
    u = 2.0 ** (1 - k)
    assert (np.abs(got - x) <= 0.5 * u * np.abs(x) * (1 + 1e-6)).all()


def test_roundk_known_values():
    for k in (8, 11):
        u = 2.0 ** (1 - k)
        x = np.array([1.0 + u / 4, 1.0 + 0.76 * u, 1.0 + u / 2], np.float32)
        got = np.asarray(round_to_precision(x, k))
        np.testing.assert_array_equal(got, np.array([1.0, 1.0 + u, 1.0], np.float32))


def test_roundk_identity_at_24():
    x = np.array([0.1, -3.7, 1e-30], np.float32)
    np.testing.assert_array_equal(np.asarray(round_to_precision(x, 24)), x)


def test_roundk_preserves_zero_and_rejects_bad_k():
    x = np.array([0.0, -0.0], np.float32)
    got = np.asarray(round_to_precision(x, 8))
    np.testing.assert_array_equal(got, x)
    with pytest.raises(ValueError):
        round_to_precision(x, 1)
    with pytest.raises(ValueError):
        round_to_precision(x, 25)


# ---------------------------------------------------------------------------
# conv / pooling / batchnorm oracles vs the model-layer implementations
# ---------------------------------------------------------------------------

@given(
    h=st.integers(4, 12),
    w=st.integers(4, 12),
    cin=st.integers(1, 4),
    cout=st.integers(1, 6),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["SAME", "VALID"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_im2col_conv_matches_lax(h, w, cin, cout, stride, padding, seed):
    from compile.model import conv2d

    rng = np.random.RandomState(seed)
    if padding == "VALID" and (h < 3 or w < 3):
        return
    x = _arr(rng, h, w, cin, scale=1.0)
    kern = _arr(rng, 3, 3, cin, cout, scale=0.5)
    b = _arr(rng, cout, scale=0.1)
    got = np.asarray(conv2d(x, kern, b, stride, padding))
    want = np.asarray(ref.conv2d_ref(x, kern, b, stride, padding))
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


@given(
    h=st.integers(4, 10),
    w=st.integers(4, 10),
    c=st.integers(1, 6),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_depthwise_matches_lax(h, w, c, stride, seed):
    from compile.model import depthwise2d

    rng = np.random.RandomState(seed)
    x = _arr(rng, h, w, c, scale=1.0)
    kern = _arr(rng, 3, 3, c, scale=0.5)
    b = _arr(rng, c, scale=0.1)
    got = np.asarray(depthwise2d(x, kern, b, stride, "SAME"))
    want = np.asarray(ref.depthwise_ref(x, kern, b, stride, "SAME"))
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


def test_max_pool_matches_ref():
    rng = np.random.RandomState(3)
    x = _arr(rng, 8, 8, 3)
    from compile.model import max_pool

    np.testing.assert_array_equal(
        np.asarray(max_pool(x, 2, 2)), np.asarray(ref.max_pool_ref(x, 2, 2))
    )
