"""L2 model tests: shapes, probability outputs, precision-emulated
variants, and agreement between the training forward (pure XLA) and the
inference forward (Pallas kernels) on shared parameters."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import datagen, model, train


@pytest.fixture(scope="module")
def rngs():
    return np.random.RandomState(42)


def test_digits_fwd_shapes_and_probs(rngs):
    p = model.init_digits(rngs)
    x = jnp.asarray(np.abs(rngs.randn(784)).astype(np.float32))
    y = np.asarray(model.digits_fwd(p, x))
    assert y.shape == (10,)
    assert np.all(y >= 0) and abs(y.sum() - 1.0) < 1e-5


def test_mobilenet_fwd_shapes_and_probs(rngs):
    p = model.init_mobilenet_mini(rngs)
    x = jnp.asarray(np.abs(rngs.randn(16, 16, 3)).astype(np.float32))
    y = np.asarray(model.mobilenet_mini_fwd(p, x))
    assert y.shape == (10,)
    assert np.all(y >= 0) and abs(y.sum() - 1.0) < 1e-5


def test_pendulum_fwd_shape(rngs):
    p = model.init_pendulum(rngs)
    y = np.asarray(model.pendulum_fwd(p, jnp.asarray(np.float32([1.0, -2.0]))))
    assert y.shape == (1,)
    assert np.isfinite(y).all()


def test_precision_variant_deviates_but_tracks(rngs):
    p = model.init_digits(rngs)
    x = jnp.asarray((np.abs(rngs.randn(784)) * 50).astype(np.float32))
    y = np.asarray(model.digits_fwd(p, x))
    y8 = np.asarray(model.digits_fwd(p, x, k=8))
    y20 = np.asarray(model.digits_fwd(p, x, k=20))
    assert not np.array_equal(y, y8), "k=8 must actually round"
    assert np.abs(y20 - y).max() < np.abs(y8 - y).max() + 1e-6, \
        "higher precision must not be worse"
    assert np.abs(y8 - y).max() < 0.05, "k=8 softmax outputs stay close"


def test_train_fwd_matches_infer_fwd_digits(rngs):
    # The pure-XLA training forward and the Pallas inference forward must
    # agree on the same parameters.
    p = model.init_digits(rngs)
    xb = (np.abs(rngs.randn(4, 784)) * 0.5).astype(np.float32)
    logits = np.asarray(train._digits_logits(p, jnp.asarray(xb)))
    for i in range(4):
        probs = np.asarray(model.digits_fwd(p, jnp.asarray(xb[i])))
        want = np.exp(logits[i] - logits[i].max())
        want /= want.sum()
        np.testing.assert_allclose(probs, want, rtol=1e-4, atol=1e-6)


def test_infer_fwd_matches_lax_reference_mobilenet(rngs):
    # Rebuild the inference forward with lax-based oracles (stored BN
    # stats) and compare to the Pallas/im2col forward.
    from compile.kernels import ref

    p = model.init_mobilenet_mini(rngs)
    x = (np.abs(rngs.randn(16, 16, 3)) * 0.5).astype(np.float32)

    def bn(h, g):
        return np.asarray(
            ref.batch_norm_ref(h, g["gamma"], g["beta"], g["mean"], g["var"], model.BN_EPS)
        )

    h = np.maximum(bn(np.asarray(ref.conv2d_ref(x, p["c1"], p["c1b"], 1)), p["bn1"]), 0)
    h = np.maximum(np.asarray(ref.depthwise_ref(h, p["dw2"], p["dw2b"], 1)), 0)
    h = np.maximum(bn(np.asarray(ref.conv2d_ref(h, p["pw2"], p["pw2b"], 1)), p["bn2"]), 0)
    h = np.maximum(np.asarray(ref.depthwise_ref(h, p["dw3"], p["dw3b"], 2)), 0)
    h = np.maximum(bn(np.asarray(ref.conv2d_ref(h, p["pw3"], p["pw3b"], 1)), p["bn3"]), 0)
    h = np.asarray(ref.max_pool_ref(h, 2, 2))
    logits = h.reshape(-1) @ np.asarray(p["w_out"]) + np.asarray(p["b_out"])
    want = np.exp(logits - logits.max())
    want /= want.sum()

    probs = np.asarray(model.mobilenet_mini_fwd(p, jnp.asarray(x)))
    np.testing.assert_allclose(probs, want, rtol=5e-4, atol=5e-5)


def test_fold_input_scale_equivalence(rngs):
    p = model.init_digits(rngs)
    raw = np.rint(np.abs(rngs.randn(784)) * 80).astype(np.float32)
    y_norm = np.asarray(model.digits_fwd(p, jnp.asarray(raw / 255.0)))
    folded = train.fold_input_scale(p, "w1", 255.0)
    y_raw = np.asarray(model.digits_fwd(folded, jnp.asarray(raw)))
    np.testing.assert_allclose(y_raw, y_norm, rtol=1e-4, atol=1e-6)


def test_datagen_pixels_are_exact_integers():
    rng = np.random.RandomState(0)
    x, y = datagen.digits(rng, 28, 2)
    assert x.shape == (20, 784)
    assert np.array_equal(x, np.rint(x)), "pixels must be integers"
    assert x.min() >= 0 and x.max() <= 255
    xb, yb = datagen.color_blobs(rng, 16, 10, 1)
    assert np.array_equal(xb, np.rint(xb))


def test_pendulum_grid_endpoints():
    g = datagen.pendulum_grid(9)
    assert g.shape == (81, 2)
    assert g.min() == -6.0 and g.max() == 6.0


def test_training_reduces_loss_quickly(rngs):
    p = model.init_pendulum(rngs)
    p2, mse = train.train_pendulum(p, steps=150)
    assert mse < 0.05, f"pendulum must fit its quadratic target, mse={mse}"
