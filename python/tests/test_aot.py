"""AOT build tests: lowering produces loadable HLO text, exports match the
Rust exchange format, and the quick build is self-consistent."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def quick_build(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(out, quick=True, ks=[8], verbose=False)
    return out, manifest


def test_hlo_text_has_entry_computation():
    rng = np.random.RandomState(0)
    p = model.init_pendulum(rng)
    hlo = aot.lower_model(model.pendulum_fwd, p, (2,))
    assert "ENTRY" in hlo and "HloModule" in hlo
    # Text format, not proto bytes.
    assert hlo.isprintable() or "\n" in hlo


def test_quick_build_writes_everything(quick_build):
    out, manifest = quick_build
    names = {(a["name"], a["variant"]) for a in manifest["artifacts"]}
    for m in ("digits", "mobilenet_mini", "pendulum"):
        assert (m, "f32") in names
        assert (m, "k8") in names
    assert ("roundk", "k8") in names
    for a in manifest["artifacts"]:
        path = os.path.join(out, a["path"])
        assert os.path.exists(path), a["path"]
        assert os.path.getsize(path) > 1000
    with open(os.path.join(out, "manifest.json")) as f:
        assert json.load(f) == manifest


def test_model_json_matches_rust_format(quick_build):
    out, _ = quick_build
    with open(os.path.join(out, "models", "digits.json")) as f:
        m = json.load(f)
    assert m["name"] == "digits"
    assert m["input_shape"] == [784]
    d0 = m["layers"][0]
    assert d0["type"] == "dense"
    assert d0["units"] == 512 and d0["in"] == 784
    assert len(d0["weights"]) == 512 * 784
    assert len(d0["bias"]) == 512
    assert [l["type"] for l in m["layers"]] == [
        "dense", "relu", "dense", "relu", "dense", "softmax",
    ]


def test_dense_export_transposition():
    # jax w[in, units] -> rust row-major [units, in]: w_rust[j*in + i] == w_jax[i, j]
    w = np.arange(6, dtype=np.float32).reshape(2, 3)  # in=2, units=3
    layer = aot._dense_layer(w, np.zeros(3, np.float32))
    assert layer["units"] == 3 and layer["in"] == 2
    assert layer["weights"] == [0.0, 3.0, 1.0, 4.0, 2.0, 5.0]


def test_datasets_written(quick_build):
    out, _ = quick_build
    with open(os.path.join(out, "data", "digits_eval.json")) as f:
        d = json.load(f)
    assert d["input_shape"] == [784]
    assert len(d["inputs"]) == len(d["labels"])
    flat = np.asarray(d["inputs"][0])
    assert np.array_equal(flat, np.rint(flat)), "eval pixels must be exact integers"


def test_residual_export_graph_wiring_matches_rust_format():
    # The name/inbound/output channel of rust/src/model/json_fmt.rs
    # ("Graph (non-sequential) models"): all-or-nothing wiring, reserved
    # "input" source, merge layers listing 2+ inbound nodes.
    rng = np.random.RandomState(3)
    p = model.init_residual_mlp(rng)
    m = aot.export_residual_mlp(p)
    assert m["output"] == "out"
    names = [l["name"] for l in m["layers"]]
    assert names == ["d1", "a1", "d2", "add1", "a2", "d3", "out"]
    for l in m["layers"]:
        assert "name" in l and "inbound" in l, l
    add = m["layers"][3]
    assert add["type"] == "add"
    assert add["inbound"] == ["d2", "a1"], "skip-add accumulation order is part of the contract"
    dangling = {n for l in m["layers"] for n in l["inbound"]} - set(names) - {"input"}
    assert not dangling, f"dangling inbound edges: {dangling}"


def test_residual_export_roundtrips_and_matches_jax_forward():
    rng = np.random.RandomState(4)
    p = model.init_residual_mlp(rng)
    m = aot.export_residual_mlp(p)
    # The JSON text channel is a fixed point.
    assert json.loads(json.dumps(m)) == m

    # Re-evaluate the exported weights with plain numpy by *walking the
    # wiring* (the way the Rust plan compiler does), and compare against
    # the jax forward on the same params.
    x = np.float32([0.2, -0.1, 0.7, 0.4, 0.0, 0.9, -0.3, 0.5])
    values = {"input": x}
    for layer in m["layers"]:
        ins = [values[n] for n in layer["inbound"]]
        if layer["type"] == "dense":
            w = np.asarray(layer["weights"], np.float32).reshape(layer["units"], layer["in"])
            values[layer["name"]] = w @ ins[0] + np.asarray(layer["bias"], np.float32)
        elif layer["type"] == "relu":
            values[layer["name"]] = np.maximum(ins[0], 0.0)
        elif layer["type"] == "add":
            acc = ins[0]
            for extra in ins[1:]:
                acc = acc + extra
            values[layer["name"]] = acc
        elif layer["type"] == "softmax":
            e = np.exp(ins[0] - ins[0].max())
            values[layer["name"]] = e / e.sum()
        else:
            raise AssertionError(layer["type"])
    h = values[m["output"]]
    y = np.asarray(model.residual_mlp_fwd(p, jnp.asarray(x)))
    np.testing.assert_allclose(h, y, rtol=1e-5, atol=1e-6)


def test_graph_export_helpers_validate():
    with pytest.raises(ValueError):
        aot.export_graph_model("m", [2], [{"type": "relu"}], "x")  # unwired layer
    a = aot.wired({"type": "relu"}, "a", ["input"])
    with pytest.raises(ValueError):
        aot.export_graph_model("m", [2], [a], "missing")  # unknown output node
    dup = aot.wired({"type": "relu"}, "a", ["a"])
    with pytest.raises(ValueError):
        aot.export_graph_model("m", [2], [a, dup], "a")  # duplicate names
    # wired() never mutates its input layer dict.
    base = {"type": "relu"}
    w = aot.wired(base, "r", ["input"])
    assert "name" not in base and w["name"] == "r"


def test_exported_model_consistent_with_fwd(quick_build):
    # The JSON export and the lowered fwd must describe the same function:
    # re-evaluate the JSON weights with plain numpy and compare.
    out, _ = quick_build
    with open(os.path.join(out, "models", "pendulum.json")) as f:
        m = json.load(f)
    x = np.float32([1.5, -2.0])

    h = x
    for layer in m["layers"]:
        if layer["type"] == "dense":
            w = np.asarray(layer["weights"], np.float32).reshape(layer["units"], layer["in"])
            h = w @ h + np.asarray(layer["bias"], np.float32)
        elif layer["type"] == "tanh":
            h = np.tanh(h)
        else:
            raise AssertionError(layer["type"])

    # Compare against the jax fwd on the same (already-folded) params, via
    # the weights themselves: rebuild params from JSON (two Dense layers,
    # two tanh activations).
    assert [l["type"] for l in m["layers"]] == ["dense", "tanh", "dense", "tanh"]
    params = {
        "w1": np.asarray(m["layers"][0]["weights"], np.float32)
        .reshape(m["layers"][0]["units"], m["layers"][0]["in"]).T,
        "b1": np.asarray(m["layers"][0]["bias"], np.float32),
        "w2": np.asarray(m["layers"][2]["weights"], np.float32)
        .reshape(m["layers"][2]["units"], m["layers"][2]["in"]).T,
        "b2": np.asarray(m["layers"][2]["bias"], np.float32),
    }
    y = np.asarray(model.pendulum_fwd(params, jnp.asarray(x)))
    np.testing.assert_allclose(h, y, rtol=1e-5, atol=1e-6)
