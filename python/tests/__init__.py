"""pytest suite for the build-time Python layers."""
