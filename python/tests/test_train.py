"""Training-path tests: optimizer behavior, determinism, generators."""

import numpy as np
import jax.numpy as jnp

from compile import datagen, model, train


def test_adam_reduces_quadratic():
    params = {"x": jnp.asarray(np.float32([5.0, -3.0]))}
    state = train.adam_init(params)
    import jax

    grad = jax.grad(lambda p: jnp.sum(p["x"] ** 2))
    for _ in range(300):
        params, state = train.adam_step(params, grad(params), state, lr=5e-2)
    assert float(jnp.abs(params["x"]).max()) < 0.1


def test_cross_entropy_matches_manual():
    logits = jnp.asarray(np.float32([[2.0, 0.0, -1.0]]))
    labels = jnp.asarray(np.int32([0]))
    got = float(train.cross_entropy(logits, labels))
    p = np.exp([2.0, 0.0, -1.0])
    want = -np.log(p[0] / p.sum())
    assert abs(got - want) < 1e-6


def test_datagen_deterministic_by_seed():
    a1, l1 = datagen.digits(np.random.RandomState(5), 12, 3)
    a2, l2 = datagen.digits(np.random.RandomState(5), 12, 3)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(l1, l2)


def test_digits_training_improves_over_chance():
    rng = np.random.RandomState(0)
    p = model.init_digits(rng)
    p, acc = train.train_digits(p, steps=80, n_per_class=8)
    assert acc > 0.5, f"10-class accuracy {acc} barely above chance"


def test_bn_running_stats_exported():
    rng = np.random.RandomState(1)
    p = model.init_mobilenet_mini(rng)
    p, _ = train.train_mobilenet_mini(p, steps=10, n_per_class=3)
    for name in ("bn1", "bn2", "bn3"):
        mean = np.asarray(p[name]["mean"])
        var = np.asarray(p[name]["var"])
        assert mean.shape == np.asarray(p[name]["gamma"]).shape
        assert (var >= 0).all()
        assert not np.allclose(mean, 0.0), "running mean never updated"


def test_lyapunov_target_positive_definite_away_from_origin():
    g = datagen.pendulum_grid(9)
    v = datagen.lyapunov_target(g)
    off_origin = np.abs(g).sum(axis=1) > 1.0
    assert (v[off_origin] > 0).all()
