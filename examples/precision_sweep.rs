//! E-acc-vs-k, engine edition: the motivating observation of the paper —
//! top-1 agreement with the reference stays high down to "ridiculously
//! low" precision — measured entirely through the **batched** execution
//! subsystem:
//!
//! * bulk per-sample CAA outcomes via [`Session::run_batch`] (one
//!   micro-batched service call instead of re-driving the plan per
//!   sample),
//! * the emulated-k witness sweep via [`Plan::execute_batch`] (one plan
//!   drive per precision for the whole sample set, f64 reference
//!   included).
//!
//! Runs offline on zoo models — no `pjrt` feature or AOT artifacts needed
//! (the PJRT sweep over trained artifacts lives in `rigor sweep` /
//! `benches/precision_sweep.rs`).
//! Run: `cargo run --release --example precision_sweep`

use rigor::api::{AnalysisRequest, ExecMode, Session};
use rigor::data::Dataset;
use rigor::model::zoo;
use rigor::plan::{Arena, Plan};
use rigor::quant::{unit_roundoff, EmulatedFp};
use rigor::tensor::EmuCtx;
use rigor::util::Rng;

fn main() -> anyhow::Result<()> {
    let session = Session::new();
    for model in [zoo::scaled_mlp(7, 64, 48, 10), zoo::residual_mlp(9)] {
        let n: usize = model.input_shape.iter().product();
        let classes = *model.output_shape()?.last().unwrap();
        let mut rng = Rng::new(17);
        let samples: Vec<Vec<f64>> = (0..48)
            .map(|_| (0..n).map(|_| rng.range(0.0, 1.0)).collect())
            .collect();
        let labels: Vec<usize> = (0..samples.len()).map(|i| i % classes).collect();
        let data = Dataset {
            input_shape: model.input_shape.clone(),
            inputs: samples.clone(),
            labels,
        };
        println!("\n== {} ({} samples) ==", model.name, samples.len());

        // Bulk per-sample CAA analysis: one service call, chunked into
        // micro-batches of 16 and fanned over the session pool.
        let req = AnalysisRequest::builder()
            .model(model.clone())
            .data(data)
            .max_batch(16)
            .mode(ExecMode::Pooled { workers: 0 })
            .build()?;
        let outcomes = session.run_batch(&req)?;
        let worst_abs = outcomes
            .iter()
            .map(|o| o.analysis.max_abs_u)
            .fold(0.0f64, f64::max);
        let certified = outcomes.iter().filter(|o| o.required_k().is_some()).count();
        let worst_k = outcomes.iter().filter_map(|o| o.required_k()).max();
        println!(
            "per-sample CAA: worst abs bound {worst_abs:.3e} u; {certified}/{} samples \
             certify a precision (worst required k = {worst_k:?})",
            outcomes.len()
        );

        // Witness sweep: emulated precision-k vs the f64 reference, each
        // pass one batched plan drive over all samples (unfused plan: the
        // witness must match the analyzed computation).
        let plan = Plan::unfused(&model)?;
        let b = samples.len();
        let m = plan.output_len();
        let flat: Vec<f64> = samples.concat();
        let mut ref_arena: Arena<f64> = Arena::new();
        let yr = plan.execute_batch::<f64>(&(), &flat, b, &mut ref_arena)?.to_vec();
        println!(
            "{:>4} {:>12} {:>16} {:>16}",
            "k", "u=2^(1-k)", "top-1 agreement", "max |dev|"
        );
        let mut emu_arena: Arena<EmulatedFp> = Arena::new();
        for k in [4u32, 6, 8, 10, 12, 16, 20] {
            let ec = EmuCtx { k };
            let xe: Vec<EmulatedFp> = flat.iter().map(|&v| EmulatedFp::new(v, k)).collect();
            let ye = plan.execute_batch::<EmulatedFp>(&ec, &xe, b, &mut emu_arena)?;
            let mut agree = 0usize;
            let mut max_dev = 0.0f64;
            for s in 0..b {
                let r = &yr[s * m..(s + 1) * m];
                let e = &ye[s * m..(s + 1) * m];
                if argmax(r) == argmax_emulated(e) {
                    agree += 1;
                }
                for (a, c) in r.iter().zip(e) {
                    max_dev = max_dev.max((a - c.v).abs());
                }
            }
            println!(
                "{k:>4} {:>12.3e} {agree:>13}/{b:<3} {max_dev:>16.3e}",
                unit_roundoff(k)
            );
        }
    }
    println!("\nExpected shape: agreement ~100% down to k≈8, degrading only below (paper §I/§IV).");
    Ok(())
}

fn argmax(xs: &[f64]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}

fn argmax_emulated(xs: &[EmulatedFp]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.v.partial_cmp(&b.1.v).unwrap())
        .unwrap()
        .0
}
