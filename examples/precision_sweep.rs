//! E-acc-vs-k: the motivating observation of the paper — top-1 agreement
//! with the f32 reference stays high down to "ridiculously low" precision —
//! measured over the AOT-compiled emulated-precision artifacts (Pallas
//! roundk baked into the graph) for all three models, served through the
//! PJRT runtime.
//!
//! Needs the `pjrt` feature, which also requires adding the `xla`
//! dependency by hand first (see the feature comment in rust/Cargo.toml —
//! the offline registry snapshot does not carry it).
//! Run: `make artifacts && cargo run --release --features pjrt --example precision_sweep`

use rigor::data::Dataset;
use rigor::quant::unit_roundoff;
use rigor::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    if !rigor::runtime::artifacts_available() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }
    let dir = rigor::runtime::default_dir();
    let mut rt = Runtime::open(&dir)?;

    for name in ["digits", "mobilenet_mini"] {
        let data = Dataset::load(&dir.join("data").join(format!("{name}_eval.json")))?;
        let ks = rt.precision_variants(name);
        println!("\n== {name} ({} samples) ==", data.len());
        println!(
            "{:>4} {:>12} {:>16} {:>16} {:>12}",
            "k", "u=2^(1-k)", "top-1 agreement", "max |prob dev|", "top-1 acc"
        );
        for &k in &ks {
            let mut agree = 0;
            let mut correct = 0;
            let mut max_dev = 0.0f32;
            for (sample, label) in data.inputs.iter().zip(&data.labels) {
                let s: Vec<f32> = sample.iter().map(|&v| v as f32).collect();
                let r = rt.run(name, "f32", &s)?;
                let e = rt.run(name, &format!("k{k}"), &s)?;
                if argmax(&r) == argmax(&e) {
                    agree += 1;
                }
                if argmax(&e) == *label {
                    correct += 1;
                }
                for (a, b) in r.iter().zip(&e) {
                    max_dev = max_dev.max((a - b).abs());
                }
            }
            println!(
                "{k:>4} {:>12.3e} {:>13}/{:<3} {max_dev:>16.3e} {:>9}/{:<3}",
                unit_roundoff(k),
                agree,
                data.len(),
                correct,
                data.len()
            );
        }
    }

    // Pendulum: regression deviation instead of classification agreement.
    let data = Dataset::load(&dir.join("data/pendulum_eval.json"))?;
    let ks = rt.precision_variants("pendulum");
    println!("\n== pendulum ({} grid points) ==", data.len());
    println!("{:>4} {:>12} {:>16}", "k", "u=2^(1-k)", "max |V dev|");
    for &k in &ks {
        let mut max_dev = 0.0f32;
        for sample in &data.inputs {
            let s: Vec<f32> = sample.iter().map(|&v| v as f32).collect();
            let r = rt.run("pendulum", "f32", &s)?;
            let e = rt.run("pendulum", &format!("k{k}"), &s)?;
            max_dev = max_dev.max((r[0] - e[0]).abs());
        }
        println!("{k:>4} {:>12.3e} {max_dev:>16.3e}", unit_roundoff(k));
    }
    println!("\nExpected shape: agreement ~100% down to k≈8, degrading only below (paper §I/§IV).");
    Ok(())
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}
