//! **End-to-end driver** (the repository's flagship example): the full
//! three-layer stack on the Digits workload.
//!
//! 1. loads the *trained* Digits MLP (exported by `python/compile/aot.py`),
//! 2. runs the paper's per-class CAA analysis through an `api::Session`
//!    fanned out over the session's worker pool (L3),
//! 3. derives the minimum safe precision k from the p* margin (§IV),
//! 4. validates the guarantee *empirically* against the AOT-compiled
//!    JAX/Pallas inference (L2/L1) through the PJRT runtime: classification
//!    at the k-variant artifacts must agree with f32 on confident samples,
//! 5. prints the Table-I-style row.
//!
//! Needs the `pjrt` feature, which also requires adding the `xla`
//! dependency by hand first (see the feature comment in rust/Cargo.toml —
//! the offline registry snapshot does not carry it).
//! Run: `make artifacts && cargo run --release --features pjrt --example digits_analysis`

use rigor::api::{AnalysisRequest, ExecMode, Session};
use rigor::data::Dataset;
use rigor::quant::unit_roundoff;
use rigor::report::{per_class_console, table1_console};
use rigor::runtime::Runtime;
use rigor::tensor::Tensor;
use rigor::util::Stopwatch;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    if !rigor::runtime::artifacts_available() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }
    let dir = rigor::runtime::default_dir();
    let session = Session::new();
    let model = session.load_model(&dir.join("models/digits.json"))?;
    let data = Arc::new(Dataset::load(&dir.join("data/digits_eval.json"))?);
    println!(
        "digits MLP: {} parameters, {} eval samples, {} classes",
        model.param_count(),
        data.len(),
        data.class_representatives().len()
    );

    // ---- L3: per-class CAA analysis on the session pool -----------------
    let req = AnalysisRequest::builder()
        .model_path(dir.join("models/digits.json"))
        .data_arc(Arc::clone(&data))
        .p_star(0.60)
        .exact_inputs(true) // integer pixels in [0, 255]: exact for k >= 8
        .mode(ExecMode::Pooled { workers: 0 })
        .build()?;
    let sw = Stopwatch::start();
    let outcome = session.run(&req)?;
    let analysis = &outcome.analysis;
    println!(
        "\nCAA analysis over {} classes in {:.2} s (pool: {} workers)",
        analysis.per_class.len(),
        sw.secs(),
        session.pool().worker_count()
    );
    println!("{}", per_class_console(analysis));
    println!("{}", table1_console(&[outcome.table_row()], req.p_star()));

    // The fixed-u_max run above may be vacuous for a deep 784-dim net (its
    // worst-case logit error times 2^-7 swamps the softmax exponentials);
    // the paper's semi-automatic workflow then *tailors u*: re-analyze per
    // candidate k until the p* margin certifies.
    let (required_k, certified) = session
        .certify_min_precision(&req, 8..=24)?
        .ok_or_else(|| anyhow::anyhow!("no k in [8, 24] certifies — cannot proceed"))?;
    println!(
        "=> precision tailoring: smallest certified k = {required_k} \
         (bounds there: {:.1}u abs / {} rel)",
        certified.analysis.max_abs_u,
        rigor::report::fmt_bound_u(certified.analysis.max_rel_u)
    );

    // ---- L2/L1 empirical validation through PJRT ------------------------
    let mut rt = Runtime::open(&dir)?;
    println!("\nPJRT platform: {}", rt.platform());
    let ks = rt.precision_variants("digits");
    println!("validating against emulated-precision artifacts k in {ks:?}");

    let mut rows = Vec::new();
    for &k in &ks {
        let mut flips_confident = 0;
        let mut flips_all = 0;
        let mut max_dev = 0.0f64;
        for sample in &data.inputs {
            let s: Vec<f32> = sample.iter().map(|&v| v as f32).collect();
            let r = rt.run("digits", "f32", &s)?;
            let e = rt.run("digits", &format!("k{k}"), &s)?;
            let (tr, te) = (argmax(&r), argmax(&e));
            if tr != te {
                flips_all += 1;
                if r[tr] >= req.p_star() as f32 {
                    flips_confident += 1;
                }
            }
            for (a, b) in r.iter().zip(&e) {
                max_dev = max_dev.max((a - b).abs() as f64);
            }
        }
        // The certified analysis's bounds hold for every u <= 2^(1-required_k),
        // i.e. for every k >= required_k.
        let bound = if k >= required_k {
            certified.analysis.max_abs_u * unit_roundoff(k)
        } else {
            f64::INFINITY
        };
        rows.push((k, max_dev, bound, flips_all, flips_confident));
    }

    println!(
        "\n{:>4} {:>14} {:>14} {:>12} {:>18}",
        "k", "max |dev|", "CAA bound·u", "argmax flips", "confident flips"
    );
    for (k, dev, bound, fa, fc) in &rows {
        let cert = if *k >= required_k { " (certified)" } else { "" };
        println!("{k:>4} {dev:>14.3e} {bound:>14.3e} {fa:>12} {fc:>15}{cert}");
    }

    // The §IV contract: at k >= required_k no confident prediction flips.
    for (k, _, _, _, fc) in &rows {
        if *k >= required_k && *fc > 0 {
            anyhow::bail!("guarantee violated at k={k}: {fc} confident flips");
        }
    }
    println!("\nguarantee holds: no confident misclassification at k >= {required_k}");

    // ---- cross-check the engines on one sample ---------------------------
    let sample = &data.inputs[0];
    let s32: Vec<f32> = sample.iter().map(|&v| v as f32).collect();
    let pjrt = rt.run("digits", "f32", &s32)?;
    let rust =
        model.forward::<f64>(&(), Tensor::new(model.input_shape.clone(), sample.clone()))?;
    let agree = pjrt
        .iter()
        .zip(rust.data())
        .all(|(a, b)| ((*a as f64) - b).abs() < 1e-3);
    println!(
        "rust engine vs PJRT agreement on sample 0: {}",
        if agree { "OK" } else { "MISMATCH" }
    );
    anyhow::ensure!(agree, "engine mismatch");
    Ok(())
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}
