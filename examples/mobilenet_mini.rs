//! Table I row 2 workload: per-class CAA analysis of the MobileNet-mini
//! CNN (Conv / BatchNorm / ReLU / depthwise-separable stages / Softmax —
//! the layer mix of the paper's 27M-parameter MobileNet run, scaled per
//! DESIGN.md §Substitutions), with the CAA-vs-IA-only comparison, all
//! driven through the `api::Session` service layer.
//!
//! Run: `make artifacts && cargo run --release --example mobilenet_mini`

use rigor::analysis::{analyze_class, baseline};
use rigor::api::{AnalysisRequest, ExecMode, Session};
use rigor::data::Dataset;
use rigor::report::{fmt_bound_u, per_class_console, table1_console};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    if !rigor::runtime::artifacts_available() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }
    let dir = rigor::runtime::default_dir();
    let session = Session::new();
    let model = session.load_model(&dir.join("models/mobilenet_mini.json"))?;
    let data = Arc::new(Dataset::load(&dir.join("data/mobilenet_mini_eval.json"))?);
    println!(
        "mobilenet_mini: {} parameters, layer stack:",
        model.param_count()
    );
    for (i, l) in model.layers.iter().enumerate() {
        println!("  {i:2}: {}", l.type_name());
    }

    let req = AnalysisRequest::builder()
        .model_path(dir.join("models/mobilenet_mini.json"))
        .data_arc(Arc::clone(&data))
        .p_star(0.60)
        .exact_inputs(true)
        .mode(ExecMode::Pooled { workers: 0 })
        .build()?;
    let outcome = session.run(&req)?;
    let analysis = &outcome.analysis;
    println!("\n{}", per_class_console(analysis));
    println!("{}", table1_console(&[outcome.table_row()], req.p_star()));
    println!(
        "(paper's full MobileNet: 22.4u abs / 11.5u rel, 4.2 h per class on MPFI;\n\
         the by-value CAA engine analyzes this CNN in {:.2} s per class)",
        analysis.secs_per_class()
    );

    // Precision tailoring (paper §V): find the smallest certified k.
    match session.certify_min_precision(&req, 8..=26)? {
        Some((k, o)) => println!(
            "precision tailoring: smallest certified k = {k} \
             ({:.1}u abs / {} rel at u_max = 2^{})",
            o.analysis.max_abs_u,
            fmt_bound_u(o.analysis.max_rel_u),
            1 - k as i32
        ),
        None => println!("no k in [8, 26] certifies at p* = {}", req.p_star()),
    }

    // CAA vs IA-only on one class (the A-caa-vs-ia ablation, small cut).
    // The baselines speak the engine vocabulary; their config comes from
    // the same request.
    let cfg = req.analysis_config();
    let rep = data.class_representatives()[0];
    let caa = analyze_class(&model, &cfg, rep.0, &data.inputs[rep.1])?;
    let ia = baseline::ia_only_class(&model, &cfg, rep.0, &data.inputs[rep.1])?;
    println!(
        "\nclass {} bounds:  CAA abs {}  |  IA-only abs {}",
        rep.0,
        fmt_bound_u(caa.max_abs_u),
        fmt_bound_u(ia.max_abs_u)
    );

    // Sampling (non-rigorous) estimate brackets CAA from below at k = 8.
    let (obs_abs, _) = baseline::sampling_estimate(&model, 8, &data.inputs)?;
    println!(
        "observed worst deviation at k=8 (sampling, non-rigorous): {:.2}u  <=  CAA bound {}",
        obs_abs,
        fmt_bound_u(analysis.max_abs_u)
    );
    anyhow::ensure!(obs_abs <= analysis.max_abs_u, "sampling exceeded the rigorous bound");
    Ok(())
}
