//! Table I row 2 workload: per-class CAA analysis of the MobileNet-mini
//! CNN (Conv / BatchNorm / ReLU / depthwise-separable stages / Softmax —
//! the layer mix of the paper's 27M-parameter MobileNet run, scaled per
//! DESIGN.md §Substitutions), with the CAA-vs-IA-only comparison.
//!
//! Run: `make artifacts && cargo run --release --example mobilenet_mini`

use rigor::analysis::{analyze_class, baseline, certify_min_precision, AnalysisConfig};
use rigor::coordinator::{analyze_model_parallel, Pool};
use rigor::data::Dataset;
use rigor::model::Model;
use rigor::report::{fmt_bound_u, per_class_console, table1_console, TableRow};
use rigor::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    if !Runtime::artifacts_available() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }
    let dir = Runtime::default_dir();
    let model = Model::load(&dir.join("models/mobilenet_mini.json"))?;
    let data = Dataset::load(&dir.join("data/mobilenet_mini_eval.json"))?;
    println!(
        "mobilenet_mini: {} parameters, layer stack:",
        model.param_count()
    );
    for (i, l) in model.layers.iter().enumerate() {
        println!("  {i:2}: {}", l.type_name());
    }

    let mut cfg = AnalysisConfig::default();
    cfg.exact_inputs = true;
    cfg.p_star = 0.60;
    let pool = Pool::default_for_host();
    let analysis = analyze_model_parallel(&model, &data, &cfg, &pool)?;
    println!("\n{}", per_class_console(&analysis));
    println!("{}", table1_console(&[TableRow::from_analysis(&analysis)], cfg.p_star));
    println!(
        "(paper's full MobileNet: 22.4u abs / 11.5u rel, 4.2 h per class on MPFI;\n\
         the by-value CAA engine analyzes this CNN in {:.2} s per class)",
        analysis.secs_per_class()
    );

    // Precision tailoring (paper §V): find the smallest certified k.
    match certify_min_precision(&model, &data, &cfg, 8..=26)? {
        Some((k, a)) => println!(
            "precision tailoring: smallest certified k = {k} \
             ({:.1}u abs / {} rel at u_max = 2^{})",
            a.max_abs_u,
            fmt_bound_u(a.max_rel_u),
            1 - k as i32
        ),
        None => println!("no k in [8, 26] certifies at p* = {}", cfg.p_star),
    }

    // CAA vs IA-only on one class (the A-caa-vs-ia ablation, small cut).
    let rep = data.class_representatives()[0];
    let caa = analyze_class(&model, &cfg, rep.0, &data.inputs[rep.1])?;
    let ia = baseline::ia_only_class(&model, &cfg, rep.0, &data.inputs[rep.1])?;
    println!(
        "\nclass {} bounds:  CAA abs {}  |  IA-only abs {}",
        rep.0,
        fmt_bound_u(caa.max_abs_u),
        fmt_bound_u(ia.max_abs_u)
    );

    // Sampling (non-rigorous) estimate brackets CAA from below at k = 8.
    let (obs_abs, _) = baseline::sampling_estimate(&model, 8, &data.inputs)?;
    println!(
        "observed worst deviation at k=8 (sampling, non-rigorous): {:.2}u  <=  CAA bound {}",
        obs_abs,
        fmt_bound_u(analysis.max_abs_u)
    );
    anyhow::ensure!(obs_abs <= analysis.max_abs_u, "sampling exceeded the rigorous bound");
    Ok(())
}
