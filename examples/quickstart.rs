//! Quickstart: rigorous FP error analysis of the Pendulum network in a few
//! lines — the paper's smallest example (Table I row 3).
//!
//! Run: `cargo run --release --example quickstart`
//! (uses the trained artifact model if `make artifacts` has run; falls back
//! to a randomly-initialized net with the same topology otherwise.)

use rigor::analysis::{analyze_model, AnalysisConfig};
use rigor::data::{synthetic, Dataset};
use rigor::model::{zoo, Model};
use rigor::report::{fmt_bound_u, per_class_console};
use rigor::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // 1. A trained model (JSON exported by the build path), or a zoo net.
    let model_path = Runtime::default_dir().join("models/pendulum.json");
    let (model, source) = if model_path.exists() {
        (Model::load(&model_path)?, "trained artifact")
    } else {
        (
            zoo::tiny_pendulum(7),
            "randomly initialized (run `make artifacts` for the trained one)",
        )
    };
    println!("model: {} ({source}), {} parameters", model.name, model.param_count());

    // 2. The verification workload: the whole input box [-6, 6]^2, queried
    //    at exactly-representable points (the paper's Pendulum setting).
    let data = Dataset {
        input_shape: vec![2],
        inputs: vec![vec![0.0, 0.0]],
        labels: vec![],
    };
    let mut cfg = AnalysisConfig::default();
    cfg.input_radius = 6.0;
    cfg.exact_inputs = true;

    // 3. One CAA analysis run = rigorous bounds for every u = 2^(1-k) <= 2^-7.
    let a = analyze_model(&model, &data, &cfg)?;
    println!(
        "\nabsolute error bound : {} (in units of u = 2^(1-k))",
        fmt_bound_u(a.max_abs_u)
    );
    println!(
        "relative error bound : {}   <- '-' is expected: the output range contains zero",
        fmt_bound_u(a.max_rel_u)
    );
    println!("analysis time        : {:.1} ms", a.total_secs * 1e3);
    println!("\nper-class detail:\n{}", per_class_console(&a));

    // 4. Turn the bound into a concrete guarantee: at precision k the
    //    computed Lyapunov value differs from the ideal one by at most
    //    δ̄ · 2^(1-k) — pluggable into the SAT-based verification of
    //    Chang et al. as an interval widening.
    for k in [8u32, 12, 16, 24] {
        let u = rigor::quant::unit_roundoff(k);
        println!(
            "k = {k:2}  =>  |V̂(x) - V(x)| <= {:.3e}  for all x in [-6, 6]^2",
            a.max_abs_u * u
        );
    }
    println!("\n(Table I reports 1.7u and ~100 ms for this network.)");

    // 5. The synthetic grid is also available for spot checks.
    let grid = synthetic::pendulum_grid(5);
    println!("grid spot-check over {} points: OK", grid.len());
    Ok(())
}
