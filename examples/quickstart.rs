//! Quickstart: rigorous FP error analysis of the Pendulum network in a few
//! lines through the service API — the paper's smallest example (Table I
//! row 3).
//!
//! Run: `cargo run --release --example quickstart`
//! (uses the trained artifact model if `make artifacts` has run; falls back
//! to a randomly-initialized net with the same topology otherwise.)

use rigor::api::{AnalysisRequest, Session};
use rigor::data::synthetic;
use rigor::model::zoo;
use rigor::report::{fmt_bound_u, per_class_console};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // 1. A session: the service front door (worker pool + model cache).
    let session = Session::new();

    // 2. The model: a trained artifact (JSON exported by the build path),
    //    or a zoo net with the same topology.
    let model_path = rigor::runtime::default_dir().join("models/pendulum.json");
    let (builder, model, source) = if model_path.exists() {
        (
            AnalysisRequest::builder().model_path(&model_path),
            session.load_model(&model_path)?,
            "trained artifact",
        )
    } else {
        let model = Arc::new(zoo::tiny_pendulum(7));
        (
            AnalysisRequest::builder().model_arc(Arc::clone(&model)),
            model,
            "randomly initialized (run `make artifacts` for the trained one)",
        )
    };
    println!("model: {} ({source}), {} parameters", model.name, model.param_count());

    // 3. The verification workload: the whole input box [-6, 6]^2, queried
    //    at exactly-representable points (the paper's Pendulum setting).
    let req = builder
        .input_box()
        .input_radius(6.0)
        .exact_inputs(true)
        .build()?;

    // 4. One CAA analysis run = rigorous bounds for every u = 2^(1-k) <= 2^-7.
    let outcome = session.run(&req)?;
    let a = &outcome.analysis;
    println!(
        "\nabsolute error bound : {} (in units of u = 2^(1-k))",
        fmt_bound_u(a.max_abs_u)
    );
    println!(
        "relative error bound : {}   <- '-' is expected: the output range contains zero",
        fmt_bound_u(a.max_rel_u)
    );
    println!("analysis time        : {:.1} ms", a.total_secs * 1e3);
    println!("\nper-class detail:\n{}", per_class_console(a));

    // 5. Turn the bound into a concrete guarantee: at precision k the
    //    computed Lyapunov value differs from the ideal one by at most
    //    δ̄ · 2^(1-k) — pluggable into the SAT-based verification of
    //    Chang et al. as an interval widening.
    for k in [8u32, 12, 16, 24] {
        let u = rigor::quant::unit_roundoff(k);
        println!(
            "k = {k:2}  =>  |V̂(x) - V(x)| <= {:.3e}  for all x in [-6, 6]^2",
            a.max_abs_u * u
        );
    }
    println!("\n(Table I reports 1.7u and ~100 ms for this network.)");

    // 6. The stable wire form of the same result (schema_version: 1).
    println!("\noutcome JSON:\n{}", outcome.to_json_string());

    // 7. The synthetic grid is also available for spot checks.
    let grid = synthetic::pendulum_grid(5);
    println!("grid spot-check over {} points: OK", grid.len());
    Ok(())
}
