//! **T1 — the paper's Table I**, regenerated end to end: per-class CAA
//! analysis of the three trained workloads, reporting max absolute /
//! relative error bounds (units of u), analysis time per class, and the
//! minimum precision preventing misclassification at p* = 0.60.
//!
//! Paper values for comparison (their testbed, MPFI backend):
//!   Digits     1.1u   3.4u    12 s/class   k = 8
//!   MobileNet  22.4u  11.5u   4.2 h/class  k = 8
//!   Pendulum   1.7u   -       100 ms       -

mod common;

use rigor::analysis::{analyze_model, certify_min_precision, AnalysisConfig, Margins};
use rigor::data::Dataset;
use rigor::model::zoo;
use rigor::report::{table1_console, table1_markdown, TableRow};

/// Analyze at the paper's u_max = 2^-7; when the worst-case bounds are
/// vacuous there (deep nets), run the paper's §V precision-tailoring loop
/// and report the row at the certified u_max instead (footnoted).
fn analyze_tailored(
    model: &rigor::model::Model,
    data: &Dataset,
    cfg: &AnalysisConfig,
) -> (TableRow, Option<u32>) {
    let a = analyze_model(model, data, cfg).expect("analysis");
    if a.required_k.is_some() {
        return (TableRow::from_analysis(&a), None);
    }
    match certify_min_precision(model, data, cfg, 8..=26).expect("certify") {
        Some((k, a2)) => {
            let mut row = TableRow::from_analysis(&a2);
            row.time_per_class = std::time::Duration::from_secs_f64(a.secs_per_class());
            (row, Some(k))
        }
        None => (TableRow::from_analysis(&a), None),
    }
}

fn main() {
    let mut rows = Vec::new();
    let mut notes = Vec::new();

    // -- Digits ------------------------------------------------------------
    let (model, data) = common::trained("digits").unwrap_or_else(|| {
        let mut rng = rigor::util::Rng::new(1);
        (
            zoo::scaled_mlp(1, 784, 128, 10),
            rigor::data::synthetic::digits(&mut rng, 28, 1, 0.05),
        )
    });
    let mut cfg = AnalysisConfig::default();
    cfg.exact_inputs = true; // integer pixels
    let (row, tailored) = analyze_tailored(&model, &data, &cfg);
    println!(
        "digits: {} params, {} classes, {:?}/class (paper: 12 s/class)",
        model.param_count(),
        data.class_representatives().len(),
        row.time_per_class
    );
    if let Some(k) = tailored {
        notes.push(format!("digits: bounds at tailored u_max = 2^{}", 1 - k as i32));
    }
    rows.push(row);

    // -- MobileNet-mini ------------------------------------------------------
    let (model, data) = common::trained("mobilenet_mini").unwrap_or_else(|| {
        let mut rng = rigor::util::Rng::new(2);
        let blobs = rigor::data::synthetic::color_blobs(&mut rng, 6, 3, 1);
        let inputs = blobs
            .inputs
            .iter()
            .map(|i| i.iter().step_by(3).cloned().collect())
            .collect();
        (
            zoo::tiny_cnn(2),
            Dataset { input_shape: vec![6, 6, 1], inputs, labels: blobs.labels },
        )
    });
    let (row, tailored) = analyze_tailored(&model, &data, &cfg);
    println!(
        "mobilenet_mini: {} params, {:?}/class (paper's 27M-param MobileNet: 4.2 h/class)",
        model.param_count(),
        row.time_per_class
    );
    if let Some(k) = tailored {
        notes.push(format!("mobilenet_mini: bounds at tailored u_max = 2^{}", 1 - k as i32));
    }
    rows.push(row);

    // -- Pendulum (whole verification box, sequential like the paper) -------
    let model = common::trained("pendulum")
        .map(|(m, _)| m)
        .unwrap_or_else(|| zoo::tiny_pendulum(3));
    let box_data = Dataset { input_shape: vec![2], inputs: vec![vec![0.0, 0.0]], labels: vec![] };
    let mut pcfg = AnalysisConfig::default();
    pcfg.input_radius = 6.0;
    pcfg.exact_inputs = true;
    let a = analyze_model(&model, &box_data, &pcfg).expect("pendulum analysis");
    println!(
        "pendulum: {} params, {:.1} ms (paper: 100 ms)",
        model.param_count(),
        a.total_secs * 1e3
    );
    rows.push(TableRow::from_analysis(&a));

    // -- the table -----------------------------------------------------------
    println!("\n================= TABLE I (reproduced) =================");
    println!("{}", table1_console(&rows, 0.60));
    println!("{}", table1_markdown(&rows, 0.60, -7));
    for n in &notes {
        println!("note: {n}");
    }
    println!("paper reference:  digits 1.1u/3.4u/12s/k=8 | mobilenet 22.4u/11.5u/4.2h/k=8 | pendulum 1.7u/-/100ms");

    // -- §IV worked example (E-margin) ----------------------------------------
    let m = Margins::new(0.60).unwrap();
    println!("\n§IV worked example: p* = 0.60");
    println!("  ν = {:.5} (paper: > 0.0909, ~3.45 valid bits)", m.rel_margin());
    println!("  abs margin on softmax input = ν/5.5 = {:.4e} (paper: > 1.65e-2 ~ 2^-6)", m.rel_margin() / 5.5);
    assert!(m.rel_margin() > 0.0909);
    assert!(m.rel_margin() / 5.5 > 1.65e-2);
}
