//! **T1 — the paper's Table I**, regenerated end to end through the
//! `api::Session` service layer: per-class CAA analysis of the three
//! trained workloads, reporting max absolute / relative error bounds
//! (units of u), analysis time per class, and the minimum precision
//! preventing misclassification at p* = 0.60.
//!
//! Paper values for comparison (their testbed, MPFI backend):
//!   Digits     1.1u   3.4u    12 s/class   k = 8
//!   MobileNet  22.4u  11.5u   4.2 h/class  k = 8
//!   Pendulum   1.7u   -       100 ms       -

mod common;

use rigor::analysis::Margins;
use rigor::api::{AnalysisRequest, AnalysisRequestBuilder, Session};
use rigor::data::Dataset;
use rigor::model::zoo;
use rigor::report::{table1_console, table1_markdown, TableRow};

/// Analyze at the paper's u_max = 2^-7; when the worst-case bounds are
/// vacuous there (deep nets), run the paper's §V precision-tailoring loop
/// and report the row at the certified u_max instead (footnoted).
fn analyze_tailored(session: &Session, builder: AnalysisRequestBuilder) -> (TableRow, Option<u32>) {
    let req = builder.build().expect("request");
    let out = session.run(&req).expect("analysis");
    if out.required_k().is_some() {
        return (out.table_row(), None);
    }
    match session.certify_min_precision(&req, 8..=26).expect("certify") {
        Some((k, o2)) => {
            let mut row = o2.table_row();
            row.time_per_class =
                std::time::Duration::from_secs_f64(out.analysis.secs_per_class());
            (row, Some(k))
        }
        None => (out.table_row(), None),
    }
}

fn main() {
    let session = Session::new();
    let mut rows = Vec::new();
    let mut notes = Vec::new();

    // -- Digits ------------------------------------------------------------
    let (model, data) = common::trained("digits").unwrap_or_else(|| {
        let mut rng = rigor::util::Rng::new(1);
        (
            zoo::scaled_mlp(1, 784, 128, 10),
            rigor::data::synthetic::digits(&mut rng, 28, 1, 0.05),
        )
    });
    let classes = data.class_representatives().len();
    let params = model.param_count();
    let (row, tailored) = analyze_tailored(
        &session,
        AnalysisRequest::builder()
            .model(model)
            .data(data)
            .exact_inputs(true), // integer pixels
    );
    println!(
        "digits: {params} params, {classes} classes, {:?}/class (paper: 12 s/class)",
        row.time_per_class
    );
    if let Some(k) = tailored {
        notes.push(format!("digits: bounds at tailored u_max = 2^{}", 1 - k as i32));
    }
    rows.push(row);

    // -- MobileNet-mini ------------------------------------------------------
    let (model, data) = common::trained("mobilenet_mini").unwrap_or_else(|| {
        let mut rng = rigor::util::Rng::new(2);
        let blobs = rigor::data::synthetic::color_blobs(&mut rng, 6, 3, 1);
        let inputs = blobs
            .inputs
            .iter()
            .map(|i| i.iter().step_by(3).cloned().collect())
            .collect();
        (
            zoo::tiny_cnn(2),
            Dataset { input_shape: vec![6, 6, 1], inputs, labels: blobs.labels },
        )
    });
    let params = model.param_count();
    let (row, tailored) = analyze_tailored(
        &session,
        AnalysisRequest::builder()
            .model(model)
            .data(data)
            .exact_inputs(true),
    );
    println!(
        "mobilenet_mini: {params} params, {:?}/class (paper's 27M-param MobileNet: 4.2 h/class)",
        row.time_per_class
    );
    if let Some(k) = tailored {
        notes.push(format!("mobilenet_mini: bounds at tailored u_max = 2^{}", 1 - k as i32));
    }
    rows.push(row);

    // -- Pendulum (whole verification box, sequential like the paper) -------
    let model = common::trained("pendulum")
        .map(|(m, _)| m)
        .unwrap_or_else(|| zoo::tiny_pendulum(3));
    let params = model.param_count();
    let preq = AnalysisRequest::builder()
        .model(model)
        .input_box()
        .input_radius(6.0)
        .exact_inputs(true)
        .build()
        .expect("pendulum request");
    let a = session.run(&preq).expect("pendulum analysis");
    println!(
        "pendulum: {params} params, {:.1} ms (paper: 100 ms)",
        a.analysis.total_secs * 1e3
    );
    rows.push(a.table_row());

    // -- the table -----------------------------------------------------------
    println!("\n================= TABLE I (reproduced) =================");
    println!("{}", table1_console(&rows, 0.60));
    println!("{}", table1_markdown(&rows, 0.60, -7));
    for n in &notes {
        println!("note: {n}");
    }
    println!("paper reference:  digits 1.1u/3.4u/12s/k=8 | mobilenet 22.4u/11.5u/4.2h/k=8 | pendulum 1.7u/-/100ms");

    // -- §IV worked example (E-margin) ----------------------------------------
    let m = Margins::new(0.60).unwrap();
    println!("\n§IV worked example: p* = 0.60");
    println!("  ν = {:.5} (paper: > 0.0909, ~3.45 valid bits)", m.rel_margin());
    println!("  abs margin on softmax input = ν/5.5 = {:.4e} (paper: > 1.65e-2 ~ 2^-6)", m.rel_margin() / 5.5);
    assert!(m.rel_margin() > 0.0909);
    assert!(m.rel_margin() / 5.5 > 1.65e-2);
}
