//! **A-scale (coordinator)** — throughput and parallel speedup of the L3
//! job runtime: raw job throughput, backpressure behavior, and the
//! end-to-end speedup of pooled per-class analysis over serial, measured
//! through the `api::Session` service layer.

use rigor::api::{AnalysisRequest, ExecMode, Session};
use rigor::bench::Bencher;
use rigor::coordinator::Pool;
use rigor::data::synthetic;
use rigor::model::zoo;
use rigor::util::Rng;

fn main() {
    let mut b = Bencher::new("coordinator");

    // ---- raw job throughput (the pool substrate itself) ---------------------
    for workers in [1usize, 2, 4, 8] {
        let pool = Pool::new(workers, workers * 4);
        let stats = b.bench(&format!("throughput/noop-jobs/w={workers}"), || {
            pool.run_batch((0..256).collect::<Vec<_>>(), |i| i)
        });
        let jps = 256.0 / stats.mean.as_secs_f64();
        println!("workers={workers}: {:.0} k noop jobs/s", jps / 1e3);
    }

    // ---- parallel analysis speedup -------------------------------------------
    let model = zoo::scaled_mlp(3, 128, 96, 10);
    let mut rng = Rng::new(5);
    let data = synthetic::digits(&mut rng, 12, 2, 0.05)
        .inputs
        .iter()
        .map(|i| i[..128].to_vec())
        .collect::<Vec<_>>();
    let data = rigor::data::Dataset {
        input_shape: vec![128],
        inputs: data,
        labels: (0..20).map(|i| i % 10).collect(),
    };
    let request = |mode: ExecMode| {
        AnalysisRequest::builder()
            .model(model.clone())
            .data(data.clone())
            .mode(mode)
            .build()
            .expect("request")
    };

    let serial_session = Session::builder().workers(1).build();
    let seq = b
        .bench_once("analysis/sequential", || {
            serial_session.run(&request(ExecMode::Serial)).unwrap()
        })
        .1
        .mean;
    println!("\nsequential 10-class analysis: {seq:.2?}");
    for workers in [2usize, 4, 8] {
        let session = Session::builder().workers(workers).build();
        let par = b
            .bench_once(&format!("analysis/parallel/w={workers}"), || {
                session.run(&request(ExecMode::Pooled { workers: 0 })).unwrap()
            })
            .1
            .mean;
        println!(
            "parallel w={workers}: {par:.2?}  speedup {:.2}x  (queue high-water {})",
            seq.as_secs_f64() / par.as_secs_f64(),
            session.pool().metrics().queue_high_water
        );
    }

    b.report();
}
