//! **E-soundness** — the rigor contract, measured: for each model and each
//! precision k, the worst observed deviation of an emulated precision-k
//! run from the high-precision reference must stay below the CAA bound
//! `δ̄·u`. Reported as the ratio bound/observed (the "rigor margin" —
//! >= 1 always; close to 1 means the bound is tight).
//!
//! Two emulation paths are exercised:
//! * Rust `EmulatedFp` (per-operation rounding — the model CAA covers), and
//! * the AOT Pallas `roundk` artifacts through PJRT (storage rounding),
//!   when artifacts are available.

mod common;

use rigor::analysis::{analyze_class, AnalysisConfig};
use rigor::bench::Bencher;
use rigor::model::zoo;
use rigor::quant::{unit_roundoff, EmulatedFp};
use rigor::runtime::Runtime;
use rigor::tensor::{EmuCtx, Tensor};

fn main() {
    let mut b = Bencher::new("soundness_sweep");

    let (model, data) = common::trained("digits").unwrap_or_else(|| {
        let mut rng = rigor::util::Rng::new(4);
        (
            zoo::scaled_mlp(4, 64, 48, 10),
            rigor::data::synthetic::digits(&mut rng, 8, 2, 0.05),
        )
    });

    println!("per-op emulation (Rust EmulatedFp) vs CAA bound, {}:", model.name);
    println!("{:>4} {:>14} {:>14} {:>12}", "k", "observed", "bound·u", "margin");
    let samples: Vec<&Vec<f64>> = data.inputs.iter().take(8).collect();
    for &k in &[8u32, 10, 12, 16, 20, 24] {
        // Analyze *at* this precision (u_max = 2^(1-k)) — the paper's
        // tailoring workflow; the parametric bound then applies to k.
        let mut cfg = AnalysisConfig::default();
        cfg.exact_inputs = true;
        cfg.ctx = rigor::caa::Ctx::with_u_max(2f64.powi(1 - k as i32));
        let mut worst_obs = 0.0f64;
        let mut worst_bound = 0.0f64;
        let (_, _stats) = b.bench_once(&format!("emulated/k={k}"), || {
            for sample in &samples {
                let a = analyze_class(&model, &cfg, 0, sample).unwrap();
                let xr = Tensor::new(model.input_shape.clone(), (*sample).clone());
                let yr = model.forward::<f64>(&(), xr).unwrap();
                let ec = EmuCtx { k };
                let xe = Tensor::new(
                    model.input_shape.clone(),
                    sample.iter().map(|&v| EmulatedFp::new(v, k)).collect(),
                );
                let ye = model.forward::<EmulatedFp>(&ec, xe).unwrap();
                for i in 0..yr.len() {
                    let err = (ye.data()[i].v - yr.data()[i]).abs();
                    worst_obs = worst_obs.max(err);
                }
                worst_bound = worst_bound.max(a.max_abs_u * unit_roundoff(k));
            }
        });
        let margin = if worst_obs > 0.0 { worst_bound / worst_obs } else { f64::INFINITY };
        println!("{k:>4} {worst_obs:>14.3e} {worst_bound:>14.3e} {margin:>11.1e}x");
        assert!(worst_obs <= worst_bound, "SOUNDNESS VIOLATION at k={k}");
    }

    // Small well-conditioned net: margins here show the *tightness* of the
    // bounds (the deep 784-dim net above shows worst-case-vs-average gap).
    let small = zoo::tiny_mlp(42);
    let mut rng = rigor::util::Rng::new(11);
    let small_samples: Vec<Vec<f64>> =
        (0..6).map(|_| (0..8).map(|_| rng.range(0.0, 1.0)).collect()).collect();
    println!("\nper-op emulation vs CAA bound, tiny_mlp (well-conditioned):");
    println!("{:>4} {:>14} {:>14} {:>12}", "k", "observed", "bound·u", "margin");
    for &k in &[8u32, 12, 16, 20, 24] {
        let mut cfg = AnalysisConfig::default();
        cfg.ctx = rigor::caa::Ctx::with_u_max(2f64.powi(1 - k as i32));
        let mut worst_obs = 0.0f64;
        let mut worst_bound = 0.0f64;
        for sample in &small_samples {
            let a = analyze_class(&small, &cfg, 0, sample).unwrap();
            let xr = Tensor::new(small.input_shape.clone(), sample.clone());
            let yr = small.forward::<f64>(&(), xr).unwrap();
            let ec = EmuCtx { k };
            let xe = Tensor::new(
                small.input_shape.clone(),
                sample.iter().map(|&v| EmulatedFp::new(v, k)).collect(),
            );
            let ye = small.forward::<EmulatedFp>(&ec, xe).unwrap();
            for i in 0..yr.len() {
                worst_obs = worst_obs.max((ye.data()[i].v - yr.data()[i]).abs());
            }
            worst_bound = worst_bound.max(a.max_abs_u * unit_roundoff(k));
        }
        let margin = if worst_obs > 0.0 { worst_bound / worst_obs } else { f64::INFINITY };
        println!("{k:>4} {worst_obs:>14.3e} {worst_bound:>14.3e} {margin:>11.1e}x");
        assert!(worst_obs <= worst_bound, "SOUNDNESS VIOLATION (tiny) at k={k}");
    }

    // Storage emulation through the AOT artifacts.
    if Runtime::artifacts_available() {
        let dir = Runtime::default_dir();
        let mut rt = Runtime::open(&dir).expect("runtime");
        println!("\nstorage emulation (PJRT roundk artifacts) vs CAA bound, digits:");
        println!("{:>4} {:>14} {:>14} {:>12}", "k", "observed", "bound·u", "margin");
        for k in rt.precision_variants("digits") {
            if k < 8 {
                continue; // coarser than any certifiable precision here
            }
            let mut cfg = AnalysisConfig::default();
            cfg.exact_inputs = true;
            cfg.ctx = rigor::caa::Ctx::with_u_max(2f64.powi(1 - k as i32));
            let a = analyze_class(&model, &cfg, 0, &data.inputs[0]).unwrap();
            let mut worst = 0.0f64;
            for sample in data.inputs.iter().take(10) {
                let s: Vec<f32> = sample.iter().map(|&v| v as f32).collect();
                let r = rt.run("digits", "f32", &s).unwrap();
                let e = rt.run("digits", &format!("k{k}"), &s).unwrap();
                for (x, y) in r.iter().zip(&e) {
                    worst = worst.max((x - y).abs() as f64);
                }
            }
            let bound = a.max_abs_u * unit_roundoff(k);
            let margin = if worst > 0.0 { bound / worst } else { f64::INFINITY };
            println!("{k:>4} {worst:>14.3e} {bound:>14.3e} {margin:>11.1e}x");
            // Storage rounding also stays within the per-op bound in
            // practice; report (not assert) since the emulation models
            // differ (DESIGN.md).
        }
    }

    b.report();
}
