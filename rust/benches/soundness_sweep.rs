//! **E-soundness** — the rigor contract, measured: for each model and each
//! precision k, the worst observed deviation of an emulated precision-k
//! run from the high-precision reference must stay below the CAA bound
//! `δ̄·u`. Reported as the ratio bound/observed (the "rigor margin" —
//! >= 1 always; close to 1 means the bound is tight).
//!
//! Analyses are served by an `api::Session`; each sample is submitted as
//! its own "class" so the outcome carries per-sample bounds. Two emulation
//! paths are exercised:
//! * Rust `EmulatedFp` (per-operation rounding — the model CAA covers), and
//! * the AOT Pallas `roundk` artifacts through PJRT (storage rounding),
//!   when the `pjrt` feature and artifacts are available.

mod common;

use rigor::api::{AnalysisRequest, Session};
use rigor::bench::Bencher;
use rigor::data::Dataset;
use rigor::model::{zoo, Model};
use rigor::plan::{Arena, Plan};
use rigor::quant::{unit_roundoff, EmulatedFp};
use rigor::tensor::EmuCtx;
use std::sync::Arc;

/// One sample per "class": the per-class results of the outcome are then
/// per-sample bounds.
fn per_sample_dataset(model: &Model, samples: &[Vec<f64>]) -> Dataset {
    Dataset {
        input_shape: model.input_shape.clone(),
        inputs: samples.to_vec(),
        labels: (0..samples.len()).collect(),
    }
}

/// Worst observed emulated-vs-reference deviation over the samples, driven
/// through a precompiled **unfused** plan (the witness must execute the
/// analyzed computation; the plan is compiled once for the whole sweep).
fn worst_observed(plan: &Plan, samples: &[Vec<f64>], k: u32) -> f64 {
    let ec = EmuCtx { k };
    let mut ref_arena: Arena<f64> = Arena::new();
    let mut emu_arena: Arena<EmulatedFp> = Arena::new();
    let mut worst = 0.0f64;
    for sample in samples {
        let yr = plan.execute::<f64>(&(), sample, &mut ref_arena).unwrap().to_vec();
        let xe: Vec<EmulatedFp> = sample.iter().map(|&v| EmulatedFp::new(v, k)).collect();
        let ye = plan.execute::<EmulatedFp>(&ec, &xe, &mut emu_arena).unwrap();
        for i in 0..yr.len() {
            worst = worst.max((ye[i].v - yr[i]).abs());
        }
    }
    worst
}

fn sweep(
    b: &mut Bencher,
    session: &Session,
    tag: &str,
    model: &Arc<Model>,
    samples: &[Vec<f64>],
    ks: &[u32],
    exact_inputs: bool,
) {
    let data = Arc::new(per_sample_dataset(model, samples));
    let witness_plan = Plan::unfused(model).expect("compile");
    println!("{:>4} {:>14} {:>14} {:>12}", "k", "observed", "bound·u", "margin");
    for &k in ks {
        // Analyze *at* this precision (u_max = 2^(1-k)) — the paper's
        // tailoring workflow; the parametric bound then applies to k.
        let req = AnalysisRequest::builder()
            .model_arc(Arc::clone(model))
            .data_arc(Arc::clone(&data))
            .exact_inputs(exact_inputs)
            .u_max(2f64.powi(1 - k as i32))
            .build()
            .expect("request");
        let mut worst_obs = 0.0f64;
        let mut worst_bound = 0.0f64;
        let (_, _stats) = b.bench_once(&format!("{tag}/k={k}"), || {
            let outcome = session.run(&req).unwrap();
            worst_bound = outcome.analysis.max_abs_u * unit_roundoff(k);
            worst_obs = worst_observed(&witness_plan, samples, k);
        });
        let margin = if worst_obs > 0.0 { worst_bound / worst_obs } else { f64::INFINITY };
        println!("{k:>4} {worst_obs:>14.3e} {worst_bound:>14.3e} {margin:>11.1e}x");
        assert!(worst_obs <= worst_bound, "SOUNDNESS VIOLATION at k={k}");
    }
}

fn main() {
    let mut b = Bencher::new("soundness_sweep");
    let session = Session::new();

    let (model, data) = common::trained("digits").unwrap_or_else(|| {
        let mut rng = rigor::util::Rng::new(4);
        (
            zoo::scaled_mlp(4, 64, 48, 10),
            rigor::data::synthetic::digits(&mut rng, 8, 2, 0.05),
        )
    });
    let model = Arc::new(model);

    println!("per-op emulation (Rust EmulatedFp) vs CAA bound, {}:", model.name);
    let samples: Vec<Vec<f64>> = data.inputs.iter().take(8).cloned().collect();
    sweep(&mut b, &session, "emulated", &model, &samples, &[8, 10, 12, 16, 20, 24], true);

    // Small well-conditioned net: margins here show the *tightness* of the
    // bounds (the deep 784-dim net above shows worst-case-vs-average gap).
    let small = Arc::new(zoo::tiny_mlp(42));
    let mut rng = rigor::util::Rng::new(11);
    let small_samples: Vec<Vec<f64>> =
        (0..6).map(|_| (0..8).map(|_| rng.range(0.0, 1.0)).collect()).collect();
    println!("\nper-op emulation vs CAA bound, tiny_mlp (well-conditioned):");
    sweep(&mut b, &session, "tiny", &small, &small_samples, &[8, 12, 16, 20, 24], false);

    // Graph topology: the soundness contract holds across merge points
    // (residual Add, branch Concat) exactly as on chains — every pass
    // executes the same compiled buffer-pool plan.
    let residual = Arc::new(zoo::residual_cnn(7));
    let mut rng = rigor::util::Rng::new(13);
    let res_samples: Vec<Vec<f64>> = (0..6)
        .map(|_| (0..36).map(|_| rng.range(0.0, 1.0)).collect())
        .collect();
    println!("\nper-op emulation vs CAA bound, residual_cnn (graph topology):");
    sweep(&mut b, &session, "residual", &residual, &res_samples, &[8, 12, 16, 20], false);

    // Storage emulation through the AOT artifacts (pjrt builds only).
    #[cfg(feature = "pjrt")]
    if rigor::runtime::artifacts_available() {
        use rigor::runtime::Runtime;
        let dir = rigor::runtime::default_dir();
        let mut rt = Runtime::open(&dir).expect("runtime");
        println!("\nstorage emulation (PJRT roundk artifacts) vs CAA bound, digits:");
        println!("{:>4} {:>14} {:>14} {:>12}", "k", "observed", "bound·u", "margin");
        for k in rt.precision_variants("digits") {
            if k < 8 {
                continue; // coarser than any certifiable precision here
            }
            let req = AnalysisRequest::builder()
                .model_arc(Arc::clone(&model))
                .data(per_sample_dataset(&model, &data.inputs[..1]))
                .exact_inputs(true)
                .u_max(2f64.powi(1 - k as i32))
                .build()
                .expect("request");
            let a = session.run(&req).unwrap().analysis;
            let mut worst = 0.0f64;
            for sample in data.inputs.iter().take(10) {
                let s: Vec<f32> = sample.iter().map(|&v| v as f32).collect();
                let r = rt.run("digits", "f32", &s).unwrap();
                let e = rt.run("digits", &format!("k{k}"), &s).unwrap();
                for (x, y) in r.iter().zip(&e) {
                    worst = worst.max((x - y).abs() as f64);
                }
            }
            let bound = a.max_abs_u * unit_roundoff(k);
            let margin = if worst > 0.0 { bound / worst } else { f64::INFINITY };
            println!("{k:>4} {worst:>14.3e} {bound:>14.3e} {margin:>11.1e}x");
            // Storage rounding also stays within the per-op bound in
            // practice; report (not assert) since the emulation models
            // differ (DESIGN.md).
        }
    }

    b.report();
}
