//! **A-scale** — analysis-cost scaling and the allocation ablation.
//!
//! The paper's tool needed 4.2 h/class for 27M parameters and blamed
//! "memory allocation deep in MPFI". This bench measures:
//! 1. per-CAA-operation cost of the flat value-type design,
//! 2. the same dot-product loop with per-op heap boxing (an MPFI-style
//!    allocation pattern) for comparison,
//! 3. analysis-time scaling vs parameter count (should be ~linear),
//! 4. projected time for the paper's 27M-parameter MobileNet.

use rigor::analysis::analyze_class;
use rigor::api::AnalysisRequest;
use rigor::bench::Bencher;
use rigor::caa::{Caa, Ctx};
use rigor::model::zoo;
use rigor::util::Rng;

fn main() {
    let mut b = Bencher::new("perf_scaling");
    let ctx = Ctx::new();
    let mut rng = Rng::new(7);

    // ---- 1+2: per-op cost, flat vs boxed -----------------------------------
    let n = 4096;
    let ws: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
    let xs: Vec<Caa> = (0..n).map(|_| Caa::param(&ctx, rng.range(0.0, 1.0))).collect();

    let flat = b
        .bench("dot4096/flat-caa", || {
            let mut acc = Caa::exact(0.0);
            for (w, x) in ws.iter().zip(&xs) {
                let t = Caa::param(&ctx, *w).mul(x, &ctx);
                acc = acc.add(&t, &ctx);
            }
            acc.abs_bound()
        })
        .mean;

    // MPFI-style: every intermediate boxed on the heap (plus the clone an
    // arbitrary-precision library would do internally).
    let boxed = b
        .bench("dot4096/boxed-caa (MPFI-style)", || {
            let mut acc = Box::new(Caa::exact(0.0));
            for (w, x) in ws.iter().zip(&xs) {
                let w = Box::new(Caa::param(&ctx, *w));
                let t = Box::new(w.mul(x, &ctx));
                acc = Box::new(acc.add(&t.clone(), &ctx));
            }
            acc.abs_bound()
        })
        .mean;
    println!(
        "per-op cost: flat {:.0} ns/op, boxed {:.0} ns/op ({:.2}x)",
        flat.as_nanos() as f64 / (2.0 * n as f64),
        boxed.as_nanos() as f64 / (2.0 * n as f64),
        boxed.as_secs_f64() / flat.as_secs_f64()
    );

    // ---- 3: scaling vs parameter count -------------------------------------
    println!("\nanalysis time vs parameters (3-dense MLP, one class):");
    println!("{:>10} {:>12} {:>14}", "params", "time", "ns/param");
    let mut per_param = Vec::new();
    for hidden in [32usize, 64, 128, 256, 512] {
        let model = zoo::scaled_mlp(1, 256, hidden, 10);
        let params = model.param_count();
        let sample: Vec<f64> = (0..256).map(|i| (i % 7) as f64 / 7.0).collect();
        let cfg = AnalysisRequest::builder().build_config().expect("config");
        let mut out = None;
        let (_, stats) = b.bench_once(&format!("analyze/mlp-h{hidden}"), || {
            out = Some(analyze_class(&model, &cfg, 0, &sample).unwrap())
        });
        let nspp = stats.mean.as_nanos() as f64 / params as f64;
        per_param.push(nspp);
        println!("{params:>10} {:>12.1?} {nspp:>14.1}", stats.mean);
    }
    let spread = per_param.iter().cloned().fold(0.0f64, f64::max)
        / per_param.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("ns/param spread across sizes: {spread:.2}x (1.0 = perfectly linear)");

    // ---- 4: projection to the paper's MobileNet ---------------------------
    let nspp = per_param.last().unwrap();
    let projected = nspp * 27e6 * 2.0 / 1e9; // ~2 ops per parameter
    println!(
        "\nprojected 27M-parameter MobileNet analysis at {nspp:.0} ns/param: \
         ~{projected:.0} s/class (paper: 15120 s/class on MPFI)"
    );

    b.report();
}
