//! **A-scale** — analysis-cost scaling and the allocation ablation.
//!
//! The paper's tool needed 4.2 h/class for 27M parameters and blamed
//! "memory allocation deep in MPFI". This bench measures:
//! 1. per-CAA-operation cost of the flat value-type design,
//! 2. the same dot-product loop with per-op heap boxing (an MPFI-style
//!    allocation pattern) for comparison,
//! 3. analysis-time scaling vs parameter count (should be ~linear),
//! 4. projected time for the paper's 27M-parameter MobileNet,
//! 5. the legacy per-layer interpreter vs the compiled `plan::Plan`
//!    executor, side by side per arithmetic (f64 reference, emulated-k
//!    witness, CAA analysis) — written to `BENCH_plan.json` so the perf
//!    trajectory of the compiled path is machine-trackable,
//! 6. the per-sample execution loop vs the batched executor
//!    (`Plan::execute_batch`) at B=32 for the f64 reference and the
//!    sampling-baseline workload (plus an informational CAA row backing
//!    the "CAA stays B=1" design note). These rows are pinned to
//!    `KernelPath::Scalar` so they keep measuring what their floors were
//!    calibrated on — the batching win over the serial scalar loop —
//!    independent of the blocked kernels,
//! 7. the scalar vs the **blocked** kernel path (`layers/gemm.rs`:
//!    register-tiled dense GEMM + im2col conv-as-GEMM) at B=32 — the
//!    conv zoo models carry a 2x speedup floor; the emulated-k row is
//!    informational (EmulatedFp pays per-op rounding, so blocking buys
//!    cache/ILP effects only),
//! 8. multi-model fleet serving: a 2-model mixed f64/emulated-k12 load
//!    through one `fleet::Fleet` (four concurrent precision-tagged
//!    queues on a shared pool) vs the serialized single-model baseline
//!    (one `MicroBatcher` per lane, lanes run back to back). The fleet
//!    row carries a 0.8x floor — multiplexing overhead must stay
//!    bounded even where the box is too loaded for cross-model overlap
//!    to pay.
//! 9. serial vs **parallel** pooled plan drives
//!    (`Plan::execute_batch_pooled`: intra-op tile sharding plus
//!    inter-op branch overlap, bit-identical to the serial drive) on the
//!    residual CNN at B=32, W in {1, 2, 4}. The W=4 row carries a 2.5x
//!    floor, enforced only on hosts with >= 4 hardware threads.
//! 10. the observability tax: the instrumented `execute_batch` drive
//!    loop under `ObsPolicy::Disabled` vs the same work driven through
//!    the uninstrumented per-step entry point
//!    (`load_batch` + `execute_step_batch_path`). The disabled row
//!    carries a 0.98x floor — the mark/record sites must cost <= 2% —
//!    while the Counters and Full rows report what each level actually
//!    costs (informational, no floor).
//!
//! The bench then **checks thresholds** — the plan must not run slower
//! than the interpreter, and the f64/sampling batched paths, the
//! blocked conv kernels, and the fleet row must clear their speedup
//! floors — printing any
//! regression and recording it in `BENCH_plan.json`; set
//! `RIGOR_BENCH_ENFORCE=1` to turn regressions into a nonzero exit (CI
//! uploads the JSON per commit either way).

#![allow(deprecated)] // forward_interpreted is the baseline under test

use rigor::analysis::analyze_class;
use rigor::api::AnalysisRequest;
use rigor::bench::Bencher;
use rigor::caa::{Caa, Ctx};
use rigor::interval::Interval;
use rigor::json::Value;
use rigor::model::zoo;
use rigor::plan::{Arena, Fusion, KernelPath, Plan};
use rigor::quant::EmulatedFp;
use rigor::tensor::{EmuCtx, Tensor};
use rigor::util::Rng;

fn main() {
    let mut b = Bencher::new("perf_scaling");
    let ctx = Ctx::new();
    let mut rng = Rng::new(7);

    // ---- 1+2: per-op cost, flat vs boxed -----------------------------------
    let n = 4096;
    let ws: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
    let xs: Vec<Caa> = (0..n).map(|_| Caa::param(&ctx, rng.range(0.0, 1.0))).collect();

    let flat = b
        .bench("dot4096/flat-caa", || {
            let mut acc = Caa::exact(0.0);
            for (w, x) in ws.iter().zip(&xs) {
                let t = Caa::param(&ctx, *w).mul(x, &ctx);
                acc = acc.add(&t, &ctx);
            }
            acc.abs_bound()
        })
        .mean;

    // MPFI-style: every intermediate boxed on the heap (plus the clone an
    // arbitrary-precision library would do internally).
    let boxed = b
        .bench("dot4096/boxed-caa (MPFI-style)", || {
            let mut acc = Box::new(Caa::exact(0.0));
            for (w, x) in ws.iter().zip(&xs) {
                let w = Box::new(Caa::param(&ctx, *w));
                let t = Box::new(w.mul(x, &ctx));
                acc = Box::new(acc.add(&t.clone(), &ctx));
            }
            acc.abs_bound()
        })
        .mean;
    println!(
        "per-op cost: flat {:.0} ns/op, boxed {:.0} ns/op ({:.2}x)",
        flat.as_nanos() as f64 / (2.0 * n as f64),
        boxed.as_nanos() as f64 / (2.0 * n as f64),
        boxed.as_secs_f64() / flat.as_secs_f64()
    );

    // ---- 3: scaling vs parameter count -------------------------------------
    println!("\nanalysis time vs parameters (3-dense MLP, one class):");
    println!("{:>10} {:>12} {:>14}", "params", "time", "ns/param");
    let mut per_param = Vec::new();
    for hidden in [32usize, 64, 128, 256, 512] {
        let model = zoo::scaled_mlp(1, 256, hidden, 10);
        let params = model.param_count();
        let sample: Vec<f64> = (0..256).map(|i| (i % 7) as f64 / 7.0).collect();
        let cfg = AnalysisRequest::builder().build_config().expect("config");
        let mut out = None;
        let (_, stats) = b.bench_once(&format!("analyze/mlp-h{hidden}"), || {
            out = Some(analyze_class(&model, &cfg, 0, &sample).unwrap())
        });
        let nspp = stats.mean.as_nanos() as f64 / params as f64;
        per_param.push(nspp);
        println!("{params:>10} {:>12.1?} {nspp:>14.1}", stats.mean);
    }
    let spread = per_param.iter().cloned().fold(0.0f64, f64::max)
        / per_param.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("ns/param spread across sizes: {spread:.2}x (1.0 = perfectly linear)");

    // ---- 4: projection to the paper's MobileNet ---------------------------
    let nspp = per_param.last().unwrap();
    let projected = nspp * 27e6 * 2.0 / 1e9; // ~2 ops per parameter
    println!(
        "\nprojected 27M-parameter MobileNet analysis at {nspp:.0} ns/param: \
         ~{projected:.0} s/class (paper: 15120 s/class on MPFI)"
    );

    // ---- 5: interpreter vs compiled plan ------------------------------------
    // Same model, same arithmetic; only the execution substrate differs:
    // the legacy Vec<Layer> walk (shape checks + a fresh tensor per layer)
    // vs the compiled plan (AOT shapes, fusion, arena reuse).
    println!("\ninterpreter vs compiled plan:");
    let mut comparisons: Vec<(String, f64, f64)> = Vec::new();

    let mlp = zoo::scaled_mlp(2, 256, 256, 10);
    let cnn = zoo::tiny_cnn(3);
    let mlp_x: Vec<f64> = (0..256).map(|i| (i % 11) as f64 / 11.0).collect();
    let cnn_n: usize = cnn.input_shape.iter().product();
    let cnn_x: Vec<f64> = (0..cnn_n).map(|i| (i % 7) as f64 / 7.0).collect();

    // f64 reference trace (the fused witness path: BN folded, acts paired).
    for (name, model, x) in [("f64/mlp-256", &mlp, &mlp_x), ("f64/tiny-cnn", &cnn, &cnn_x)] {
        let interp = b
            .bench(&format!("{name}/interpreter"), || {
                model
                    .forward_interpreted::<f64>(
                        &(),
                        Tensor::new(model.input_shape.clone(), x.clone()),
                    )
                    .unwrap()
            })
            .mean;
        let plan = Plan::for_reference(model).expect("compile");
        let mut arena: Arena<f64> = Arena::new();
        let planned = b
            .bench(&format!("{name}/plan"), || {
                plan.execute::<f64>(&(), x, &mut arena).unwrap().len()
            })
            .mean;
        comparisons.push((name.to_string(), interp.as_nanos() as f64, planned.as_nanos() as f64));
    }

    // Emulated precision-k witness run (unfused: must match the analyzed
    // computation).
    {
        let k = 12u32;
        let ec = EmuCtx { k };
        let xe: Vec<EmulatedFp> = cnn_x.iter().map(|&v| EmulatedFp::new(v, k)).collect();
        let interp = b
            .bench("emu-k12/tiny-cnn/interpreter", || {
                cnn.forward_interpreted::<EmulatedFp>(
                    &ec,
                    Tensor::new(cnn.input_shape.clone(), xe.clone()),
                )
                .unwrap()
            })
            .mean;
        let plan = Plan::unfused(&cnn).expect("compile");
        let mut arena: Arena<EmulatedFp> = Arena::new();
        let planned = b
            .bench("emu-k12/tiny-cnn/plan", || {
                plan.execute::<EmulatedFp>(&ec, &xe, &mut arena).unwrap().len()
            })
            .mean;
        let row = ("emu-k12/tiny-cnn".into(), interp.as_nanos() as f64, planned.as_nanos() as f64);
        comparisons.push(row);
    }

    // CAA analysis run (Fusion::Pair — bit-identical bounds).
    {
        let interp = b
            .bench("caa/tiny-cnn/interpreter", || {
                let input = Tensor::new(
                    cnn.input_shape.clone(),
                    cnn_x
                        .iter()
                        .map(|&v| Caa::input(&ctx, Interval::point(v), v))
                        .collect::<Vec<_>>(),
                );
                cnn.forward_interpreted::<Caa>(&ctx, input).unwrap()
            })
            .mean;
        let plan = Plan::for_analysis(&cnn).expect("compile");
        let mut arena: Arena<Caa> = Arena::new();
        let planned = b
            .bench("caa/tiny-cnn/plan", || {
                let input: Vec<Caa> = cnn_x
                    .iter()
                    .map(|&v| Caa::input(&ctx, Interval::point(v), v))
                    .collect();
                plan.execute::<Caa>(&ctx, &input, &mut arena).unwrap().len()
            })
            .mean;
        let row = ("caa/tiny-cnn".into(), interp.as_nanos() as f64, planned.as_nanos() as f64);
        comparisons.push(row);
    }

    // Graph topology (plan-only: the legacy interpreter cannot walk
    // residual models — the buffer-pool plan is the only executor).
    {
        let res = zoo::residual_cnn(5);
        let res_n: usize = res.input_shape.iter().product();
        let res_x: Vec<f64> = (0..res_n).map(|i| (i % 5) as f64 / 5.0).collect();
        let plan = Plan::for_analysis(&res).expect("compile");
        let mut arena: Arena<f64> = Arena::new();
        b.bench("f64/residual-cnn/plan", || {
            plan.execute::<f64>(&(), &res_x, &mut arena).unwrap().len()
        });
        let mut caa_arena: Arena<Caa> = Arena::new();
        let caa_input: Vec<Caa> = res_x
            .iter()
            .map(|&v| Caa::input(&ctx, Interval::point(v), v))
            .collect();
        b.bench("caa/residual-cnn/plan", || {
            plan.execute::<Caa>(&ctx, &caa_input, &mut caa_arena).unwrap().len()
        });
    }

    println!("{:<20} {:>14} {:>14} {:>9}", "workload", "interpreter", "plan", "speedup");
    for (name, i_ns, p_ns) in &comparisons {
        println!(
            "{name:<20} {:>12.1} us {:>12.1} us {:>8.2}x",
            i_ns / 1e3,
            p_ns / 1e3,
            i_ns / p_ns
        );
    }

    // ---- 6: per-sample loop vs batched executor -----------------------------
    // The bulk-serving/sampling scenario: B samples through one plan pass
    // (`execute_batch`) vs B independent `execute` calls. Rows carry a
    // speedup floor the threshold check enforces: 2x for the f64 workloads
    // (batching overlaps the latency-bound accumulation chains and
    // amortizes dispatch), none for the informational CAA row (per-op CAA
    // cost dwarfs what batching amortizes — the measured ~1x is exactly
    // why the analysis paths keep CAA at B=1). Both sides are pinned to
    // KernelPath::Scalar: these floors quantify the *batching* win over
    // the serial scalar loop; the blocked-kernel win is section 7's.
    println!("\nper-sample loop vs batched executor (B = {BATCH}, scalar kernels):");
    const BATCH: usize = 32;
    // (name, batch size, per-sample ns, batched ns, speedup floor)
    let mut batch_rows: Vec<(String, usize, f64, f64, f64)> = Vec::new();

    {
        let plan = Plan::for_reference(&mlp).expect("compile");
        let samples: Vec<Vec<f64>> = (0..BATCH)
            .map(|s| mlp_x.iter().map(|v| (v + s as f64 / 97.0) % 1.0).collect())
            .collect();
        let mut arena: Arena<f64> = Arena::new();
        let per = b
            .bench(&format!("f64/mlp-256/per-sample-x{BATCH}"), || {
                let mut acc = 0usize;
                for s in &samples {
                    acc += plan
                        .execute_path::<f64>(&(), s, &mut arena, KernelPath::Scalar)
                        .unwrap()
                        .len();
                }
                acc
            })
            .mean;
        let flat: Vec<f64> = samples.concat();
        let mut batch_arena: Arena<f64> = Arena::new();
        let batched = b
            .bench(&format!("f64/mlp-256/batched-x{BATCH}"), || {
                plan.execute_batch_path::<f64>(
                    &(),
                    &flat,
                    BATCH,
                    &mut batch_arena,
                    KernelPath::Scalar,
                )
                .unwrap()
                .len()
            })
            .mean;
        batch_rows.push((
            "f64/mlp-256".into(),
            BATCH,
            per.as_nanos() as f64,
            batched.as_nanos() as f64,
            2.0,
        ));
    }

    // The sampling-baseline workload (f64 reference + emulated-k witness
    // per sample) — the loop `analysis::baseline::sampling_estimate` now
    // drives through the batched executor.
    {
        let plan = Plan::unfused(&mlp).expect("compile");
        let k = 12u32;
        let ec = EmuCtx { k };
        let samples: Vec<Vec<f64>> = (0..BATCH)
            .map(|s| mlp_x.iter().map(|v| (v + s as f64 / 89.0) % 1.0).collect())
            .collect();
        let mut ra: Arena<f64> = Arena::new();
        let mut ea: Arena<EmulatedFp> = Arena::new();
        let per = b
            .bench(&format!("sampling-k12/mlp-256/per-sample-x{BATCH}"), || {
                let mut acc = 0usize;
                for s in &samples {
                    acc += plan
                        .execute_path::<f64>(&(), s, &mut ra, KernelPath::Scalar)
                        .unwrap()
                        .len();
                    let xe: Vec<EmulatedFp> =
                        s.iter().map(|&v| EmulatedFp::new(v, k)).collect();
                    acc += plan
                        .execute_path::<EmulatedFp>(&ec, &xe, &mut ea, KernelPath::Scalar)
                        .unwrap()
                        .len();
                }
                acc
            })
            .mean;
        let flat: Vec<f64> = samples.concat();
        let mut rba: Arena<f64> = Arena::new();
        let mut eba: Arena<EmulatedFp> = Arena::new();
        let mut xe: Vec<EmulatedFp> = Vec::with_capacity(flat.len());
        let batched = b
            .bench(&format!("sampling-k12/mlp-256/batched-x{BATCH}"), || {
                // Same work as sampling_estimate's chunk body: the input
                // conversion is part of the timed workload on both sides.
                let a = plan
                    .execute_batch_path::<f64>(&(), &flat, BATCH, &mut rba, KernelPath::Scalar)
                    .unwrap()
                    .len();
                xe.clear();
                xe.extend(flat.iter().map(|&v| EmulatedFp::new(v, k)));
                let c = plan
                    .execute_batch_path::<EmulatedFp>(
                        &ec,
                        &xe,
                        BATCH,
                        &mut eba,
                        KernelPath::Scalar,
                    )
                    .unwrap()
                    .len();
                a + c
            })
            .mean;
        batch_rows.push((
            "sampling-k12/mlp-256".into(),
            BATCH,
            per.as_nanos() as f64,
            batched.as_nanos() as f64,
            1.2,
        ));
    }

    // Informational: CAA batching buys ~nothing (and costs B x memory) —
    // the data behind the "analysis keeps CAA at B=1" contract. No floor.
    {
        let caa_batch = 8usize;
        let plan = Plan::for_analysis(&cnn).expect("compile");
        let samples: Vec<Vec<Caa>> = (0..caa_batch)
            .map(|s| {
                cnn_x
                    .iter()
                    .map(|&v| {
                        let v = (v + s as f64 / 83.0) % 1.0;
                        Caa::input(&ctx, Interval::point(v), v)
                    })
                    .collect()
            })
            .collect();
        let mut arena: Arena<Caa> = Arena::new();
        let per = b
            .bench(&format!("caa/tiny-cnn/per-sample-x{caa_batch}"), || {
                let mut acc = 0usize;
                for s in &samples {
                    acc += plan.execute::<Caa>(&ctx, s, &mut arena).unwrap().len();
                }
                acc
            })
            .mean;
        let flat: Vec<Caa> = samples.iter().flatten().cloned().collect();
        let mut batch_arena: Arena<Caa> = Arena::new();
        let batched = b
            .bench(&format!("caa/tiny-cnn/batched-x{caa_batch}"), || {
                plan.execute_batch::<Caa>(&ctx, &flat, caa_batch, &mut batch_arena)
                    .unwrap()
                    .len()
            })
            .mean;
        batch_rows.push((
            "caa/tiny-cnn".into(),
            caa_batch,
            per.as_nanos() as f64,
            batched.as_nanos() as f64,
            0.0,
        ));
    }

    println!(
        "{:<24} {:>3} {:>14} {:>14} {:>9} {:>7}",
        "workload", "B", "per-sample", "batched", "speedup", "floor"
    );
    for (name, bsz, per_ns, batch_ns, floor) in &batch_rows {
        println!(
            "{name:<24} {bsz:>3} {:>12.1} us {:>12.1} us {:>8.2}x {floor:>6.1}x",
            per_ns / 1e3,
            batch_ns / 1e3,
            per_ns / batch_ns
        );
    }

    // ---- 7: scalar vs blocked kernel path -----------------------------------
    // Same plan, same batched drive, only the kernel family differs: the
    // textbook scalar loops vs layers/gemm.rs (register-tiled dense GEMM,
    // im2col conv-as-GEMM) — bit-identical outputs, so this is pure
    // throughput. The conv zoo models carry the enforced 2x floor from
    // the kernel-dispatch work; the dense-only and emulated-k rows are
    // informational (EmulatedFp's per-op rounding dominates, so blocking
    // buys only cache/ILP effects there).
    println!("\nscalar vs blocked kernels (B = {BATCH}):");
    // (name, batch, scalar ns, blocked ns, speedup floor)
    let mut kernel_rows: Vec<(String, usize, f64, f64, f64)> = Vec::new();
    let res = zoo::residual_cnn(5);
    {
        let f64_workloads: [(&str, &rigor::model::Model, f64); 3] = [
            ("kernels-f64/mlp-256", &mlp, 0.0),
            ("kernels-f64/tiny-cnn", &cnn, 2.0),
            ("kernels-f64/residual-cnn", &res, 2.0),
        ];
        for (name, model, floor) in f64_workloads {
            let plan = Plan::build_with_kernels(model, Fusion::Full, KernelPath::Blocked)
                .expect("compile");
            let n: usize = model.input_shape.iter().product();
            let flat: Vec<f64> = (0..BATCH * n).map(|i| (i % 17) as f64 / 17.0).collect();
            let mut sa: Arena<f64> = Arena::new();
            let scalar = b
                .bench(&format!("{name}/scalar-x{BATCH}"), || {
                    plan.execute_batch_path::<f64>(&(), &flat, BATCH, &mut sa, KernelPath::Scalar)
                        .unwrap()
                        .len()
                })
                .mean;
            let mut ba: Arena<f64> = Arena::new();
            let blocked = b
                .bench(&format!("{name}/blocked-x{BATCH}"), || {
                    plan.execute_batch_path::<f64>(&(), &flat, BATCH, &mut ba, KernelPath::Blocked)
                        .unwrap()
                        .len()
                })
                .mean;
            kernel_rows.push((
                name.to_string(),
                BATCH,
                scalar.as_nanos() as f64,
                blocked.as_nanos() as f64,
                floor,
            ));
        }
    }
    {
        // Emulated-k witness on the conv model (unfused, like the real
        // witness runs). Informational: no floor.
        let k = 12u32;
        let ec = EmuCtx { k };
        let plan =
            Plan::build_with_kernels(&cnn, Fusion::None, KernelPath::Blocked).expect("compile");
        let xe: Vec<EmulatedFp> = (0..BATCH * cnn_n)
            .map(|i| EmulatedFp::new((i % 17) as f64 / 17.0, k))
            .collect();
        let mut sa: Arena<EmulatedFp> = Arena::new();
        let scalar = b
            .bench(&format!("kernels-emu-k12/tiny-cnn/scalar-x{BATCH}"), || {
                plan.execute_batch_path::<EmulatedFp>(&ec, &xe, BATCH, &mut sa, KernelPath::Scalar)
                    .unwrap()
                    .len()
            })
            .mean;
        let mut ba: Arena<EmulatedFp> = Arena::new();
        let blocked = b
            .bench(&format!("kernels-emu-k12/tiny-cnn/blocked-x{BATCH}"), || {
                plan.execute_batch_path::<EmulatedFp>(&ec, &xe, BATCH, &mut ba, KernelPath::Blocked)
                    .unwrap()
                    .len()
            })
            .mean;
        kernel_rows.push((
            "kernels-emu-k12/tiny-cnn".into(),
            BATCH,
            scalar.as_nanos() as f64,
            blocked.as_nanos() as f64,
            0.0,
        ));
    }

    println!(
        "{:<28} {:>3} {:>14} {:>14} {:>9} {:>7}",
        "workload", "B", "scalar", "blocked", "speedup", "floor"
    );
    for (name, bsz, s_ns, k_ns, floor) in &kernel_rows {
        println!(
            "{name:<28} {bsz:>3} {:>12.1} us {:>12.1} us {:>8.2}x {floor:>6.1}x",
            s_ns / 1e3,
            k_ns / 1e3,
            s_ns / k_ns
        );
    }

    // ---- 8: multi-model fleet vs serialized single-model serving ------------
    // The same 2-model mixed-precision load (tiny-cnn + residual-cnn, each
    // serving f64 AND emulated-k12 traffic) pushed through ONE Fleet with
    // four concurrent submitters, versus the serialized baseline: one
    // single-model MicroBatcher per (model, format) lane, lanes run one
    // after another. Results are bit-identical by construction (same plans,
    // same batch job); this row measures what the fleet's fair multiplexing
    // buys — cross-model overlap on the shared pool — net of its scheduler
    // overhead. Floor 0.8x: the fleet must never cost more than 20% of the
    // serialized throughput even on a loaded single-core CI box (it
    // typically lands well above 1x by overlapping lanes).
    // (name, total tickets, serialized ns, fleet ns, speedup floor)
    let mut fleet_rows: Vec<(String, usize, f64, f64, f64)> = Vec::new();
    {
        use rigor::coordinator::Pool;
        use rigor::fleet::{Fleet, FleetPolicy};
        use rigor::plan::ServeFormat;
        use rigor::serve::{BatchPolicy, MicroBatcher};
        use std::sync::Arc;
        use std::time::Duration;

        const FLEET_REQS: usize = 24;
        fn lane_sample(n: usize, i: usize) -> Vec<f64> {
            (0..n).map(|j| ((i * n + j) % 17) as f64 / 17.0).collect()
        }

        println!("\nfleet scheduling (2 models x 2 formats, {FLEET_REQS} tickets/queue):");
        let emu = ServeFormat::Emulated { k: 12 };
        let res_n: usize = res.input_shape.iter().product();
        let lanes: [(&'static str, ServeFormat, usize); 4] = [
            ("tiny-cnn", ServeFormat::F64, cnn_n),
            ("tiny-cnn", emu, cnn_n),
            ("residual-cnn", ServeFormat::F64, res_n),
            ("residual-cnn", emu, res_n),
        ];
        let model_for = |id: &str| if id == "tiny-cnn" { &cnn } else { &res };

        let serialized = b
            .bench("fleet/serialized-baseline", || {
                let mut total = 0usize;
                for &(id, fmt, n) in &lanes {
                    let plan = Arc::new(Plan::for_format(model_for(id), fmt).unwrap());
                    let kernels = plan.kernel_path();
                    let batcher = MicroBatcher::with_format(
                        plan,
                        Arc::new(Pool::new(4, 32)),
                        BatchPolicy {
                            max_batch: 8,
                            max_wait: Duration::from_micros(200),
                            max_pending: 256,
                            ..BatchPolicy::default()
                        },
                        kernels,
                        fmt,
                    );
                    let tickets: Vec<_> = (0..FLEET_REQS)
                        .map(|i| batcher.submit(lane_sample(n, i)).unwrap())
                        .collect();
                    total += tickets.into_iter().map(|t| t.wait().unwrap().len()).sum::<usize>();
                }
                total
            })
            .mean;

        let fleet_mean = b
            .bench("fleet/mixed-2model", || {
                let fleet = Arc::new(Fleet::new(
                    Arc::new(Pool::new(4, 32)),
                    FleetPolicy {
                        max_batch: 8,
                        max_wait: Duration::from_micros(200),
                        max_queue_pending: 256,
                        max_fleet_pending: 1024,
                        ..FleetPolicy::default()
                    },
                ));
                fleet.deploy("tiny-cnn", &cnn).unwrap();
                fleet.deploy("residual-cnn", &res).unwrap();
                let handles: Vec<_> = lanes
                    .iter()
                    .map(|&(id, fmt, n)| {
                        let f = Arc::clone(&fleet);
                        std::thread::spawn(move || {
                            let tickets: Vec<_> = (0..FLEET_REQS)
                                .map(|i| f.submit_blocking(id, fmt, lane_sample(n, i)).unwrap())
                                .collect();
                            tickets.into_iter().map(|t| t.wait().unwrap().len()).sum::<usize>()
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).sum::<usize>()
            })
            .mean;

        fleet_rows.push((
            "fleet/2-model-mixed-precision".into(),
            4 * FLEET_REQS,
            serialized.as_nanos() as f64,
            fleet_mean.as_nanos() as f64,
            0.8,
        ));

        println!(
            "{:<28} {:>7} {:>14} {:>14} {:>9} {:>7}",
            "workload", "tickets", "serialized", "fleet", "speedup", "floor"
        );
        for (name, tickets, base_ns, fleet_ns, floor) in &fleet_rows {
            println!(
                "{name:<28} {tickets:>7} {:>12.1} us {:>12.1} us {:>8.2}x {floor:>6.1}x",
                base_ns / 1e3,
                fleet_ns / 1e3,
                base_ns / fleet_ns
            );
        }
    }

    // ---- 9: serial vs parallel pooled plan drives ---------------------------
    // One plan drive using the whole machine: `execute_batch_pooled`
    // shards each step's independent tile ranges across the coordinator
    // pool and overlaps independent residual branches, bit-identical to
    // the serial drive. The W=4 row carries a 2.5x floor — enforced only
    // when the host actually has >= 4 cores (the floor is meaningless on
    // a 1-core CI box, where the rows stay informational).
    // (name, workers, serial ns, parallel ns, speedup floor)
    let mut parallel_rows: Vec<(String, usize, f64, f64, f64)> = Vec::new();
    {
        use rigor::coordinator::Pool;
        use rigor::plan::Parallelism;

        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        println!("\nserial vs parallel pooled drives (B = {BATCH}, {hw} hardware threads):");
        let plan =
            Plan::build_with_kernels(&res, Fusion::Full, KernelPath::Blocked).expect("compile");
        let res_n: usize = res.input_shape.iter().product();
        let flat: Vec<f64> = (0..BATCH * res_n).map(|i| (i % 17) as f64 / 17.0).collect();
        let mut sa: Arena<f64> = Arena::new();
        let serial = b
            .bench(&format!("parallel-f64/residual-cnn/serial-x{BATCH}"), || {
                plan.execute_batch_path::<f64>(&(), &flat, BATCH, &mut sa, KernelPath::Blocked)
                    .unwrap()
                    .len()
            })
            .mean;
        let pool = Pool::new(4, 32);
        for workers in [1usize, 2, 4] {
            let par = Parallelism::with_workers(workers);
            let mut pa: Arena<f64> = Arena::new();
            let pooled = b
                .bench(&format!("parallel-f64/residual-cnn/pooled-w{workers}-x{BATCH}"), || {
                    plan.execute_batch_pooled::<f64>(
                        &(),
                        &flat,
                        BATCH,
                        &mut pa,
                        KernelPath::Blocked,
                        &pool,
                        par,
                    )
                    .unwrap()
                    .len()
                })
                .mean;
            let floor = if workers == 4 && hw >= 4 { 2.5 } else { 0.0 };
            parallel_rows.push((
                format!("parallel-f64/residual-cnn/w{workers}"),
                workers,
                serial.as_nanos() as f64,
                pooled.as_nanos() as f64,
                floor,
            ));
        }
        println!(
            "{:<32} {:>3} {:>14} {:>14} {:>9} {:>7}",
            "workload", "W", "serial", "parallel", "speedup", "floor"
        );
        for (name, workers, s_ns, p_ns, floor) in &parallel_rows {
            println!(
                "{name:<32} {workers:>3} {:>12.1} us {:>12.1} us {:>8.2}x {floor:>6.1}x",
                s_ns / 1e3,
                p_ns / 1e3,
                s_ns / p_ns
            );
        }
        if hw < 4 {
            println!("(host has {hw} hardware threads — parallel floors not enforced)");
        }
    }

    // ---- 10: observability overhead -----------------------------------------
    // The per-step span/histogram sites live in the `execute_batch` drive
    // loop; `load_batch` + `execute_step_batch_path` is the same work with
    // no instrumentation at all, so the pair isolates exactly what the
    // obs layer costs. Disabled must be free (each site is one relaxed
    // load + branch): that row carries a 0.98x floor. Counters and Full
    // price the real recording (two clock reads + atomics per step) —
    // informational, no floor.
    // (name, uninstrumented ns, instrumented ns, ratio floor)
    let mut obs_rows: Vec<(String, f64, f64, f64)> = Vec::new();
    {
        use rigor::obs::{self, ObsPolicy};

        println!("\nobservability overhead (B = {BATCH}, residual-cnn blocked drive):");
        let plan =
            Plan::build_with_kernels(&res, Fusion::Full, KernelPath::Blocked).expect("compile");
        let res_n: usize = res.input_shape.iter().product();
        let flat: Vec<f64> = (0..BATCH * res_n).map(|i| (i % 17) as f64 / 17.0).collect();

        obs::set_policy(ObsPolicy::Disabled);
        let mut ua: Arena<f64> = Arena::new();
        let steps = plan.steps().len();
        let bare = b
            .bench(&format!("obs-f64/residual-cnn/uninstrumented-x{BATCH}"), || {
                ua.load_batch(&plan, &flat, BATCH);
                for idx in 0..steps {
                    plan.execute_step_batch_path::<f64>(idx, BATCH, &(), &mut ua, KernelPath::Blocked);
                }
                steps
            })
            .mean;

        for (policy, floor) in
            [(ObsPolicy::Disabled, 0.98), (ObsPolicy::Counters, 0.0), (ObsPolicy::Full, 0.0)]
        {
            obs::set_policy(policy);
            let mut ia: Arena<f64> = Arena::new();
            let inst = b
                .bench(&format!("obs-f64/residual-cnn/{}-x{BATCH}", policy.name()), || {
                    plan.execute_batch_path::<f64>(&(), &flat, BATCH, &mut ia, KernelPath::Blocked)
                        .unwrap()
                        .len()
                })
                .mean;
            obs_rows.push((
                format!("obs-f64/residual-cnn/{}", policy.name()),
                bare.as_nanos() as f64,
                inst.as_nanos() as f64,
                floor,
            ));
        }
        obs::set_policy(ObsPolicy::Disabled);

        println!(
            "{:<32} {:>14} {:>14} {:>9} {:>7}",
            "policy", "uninstrumented", "instrumented", "ratio", "floor"
        );
        for (name, bare_ns, inst_ns, floor) in &obs_rows {
            println!(
                "{name:<32} {:>12.1} us {:>12.1} us {:>8.2}x {floor:>6.2}x",
                bare_ns / 1e3,
                inst_ns / 1e3,
                bare_ns / inst_ns
            );
        }
    }

    // ---- threshold check ----------------------------------------------------
    let mut regressions: Vec<String> = Vec::new();
    for (name, i_ns, p_ns) in &comparisons {
        let speedup = i_ns / p_ns;
        if speedup < 1.0 {
            regressions
                .push(format!("{name}: compiled plan slower than interpreter ({speedup:.2}x)"));
        }
    }
    for (name, _bsz, per_ns, batch_ns, floor) in &batch_rows {
        let speedup = per_ns / batch_ns;
        if *floor > 0.0 && speedup < *floor {
            regressions.push(format!(
                "{name}: batched speedup {speedup:.2}x below the {floor:.1}x floor"
            ));
        }
    }
    for (name, _bsz, s_ns, k_ns, floor) in &kernel_rows {
        let speedup = s_ns / k_ns;
        if *floor > 0.0 && speedup < *floor {
            regressions.push(format!(
                "{name}: blocked-kernel speedup {speedup:.2}x below the {floor:.1}x floor"
            ));
        }
    }
    for (name, _tickets, base_ns, fleet_ns, floor) in &fleet_rows {
        let speedup = base_ns / fleet_ns;
        if *floor > 0.0 && speedup < *floor {
            regressions.push(format!(
                "{name}: fleet speedup {speedup:.2}x vs serialized serving below the {floor:.1}x floor"
            ));
        }
    }
    for (name, _workers, s_ns, p_ns, floor) in &parallel_rows {
        let speedup = s_ns / p_ns;
        if *floor > 0.0 && speedup < *floor {
            regressions.push(format!(
                "{name}: parallel speedup {speedup:.2}x vs the serial drive below the {floor:.1}x floor"
            ));
        }
    }
    for (name, bare_ns, inst_ns, floor) in &obs_rows {
        let ratio = bare_ns / inst_ns;
        if *floor > 0.0 && ratio < *floor {
            regressions.push(format!(
                "{name}: instrumented drive at {ratio:.3}x of the uninstrumented loop, \
                 below the {floor:.2}x floor (disabled obs must cost <= 2%)"
            ));
        }
    }
    for r in &regressions {
        eprintln!("[regression] {r}");
    }

    // Machine-readable trajectory record.
    let json = Value::obj(vec![
        ("schema_version", Value::from(1usize)),
        ("bench", Value::from("perf_scaling")),
        (
            "comparisons",
            Value::arr(
                comparisons
                    .iter()
                    .map(|(name, i_ns, p_ns)| {
                        Value::obj(vec![
                            ("name", Value::from(name.clone())),
                            ("interpreter_ns", Value::from(*i_ns)),
                            ("plan_ns", Value::from(*p_ns)),
                            ("speedup", Value::from(i_ns / p_ns)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "batched",
            Value::arr(
                batch_rows
                    .iter()
                    .map(|(name, bsz, per_ns, batch_ns, floor)| {
                        Value::obj(vec![
                            ("name", Value::from(name.clone())),
                            ("batch", Value::from(*bsz)),
                            ("per_sample_ns", Value::from(*per_ns)),
                            ("batched_ns", Value::from(*batch_ns)),
                            ("speedup", Value::from(per_ns / batch_ns)),
                            ("floor", Value::from(*floor)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "kernels",
            Value::arr(
                kernel_rows
                    .iter()
                    .map(|(name, bsz, s_ns, k_ns, floor)| {
                        Value::obj(vec![
                            ("name", Value::from(name.clone())),
                            ("batch", Value::from(*bsz)),
                            ("scalar_ns", Value::from(*s_ns)),
                            ("blocked_ns", Value::from(*k_ns)),
                            ("speedup", Value::from(s_ns / k_ns)),
                            ("floor", Value::from(*floor)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "fleet",
            Value::arr(
                fleet_rows
                    .iter()
                    .map(|(name, tickets, base_ns, fleet_ns, floor)| {
                        Value::obj(vec![
                            ("name", Value::from(name.clone())),
                            ("tickets", Value::from(*tickets)),
                            ("serialized_ns", Value::from(*base_ns)),
                            ("fleet_ns", Value::from(*fleet_ns)),
                            ("speedup", Value::from(base_ns / fleet_ns)),
                            ("floor", Value::from(*floor)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "parallel",
            Value::arr(
                parallel_rows
                    .iter()
                    .map(|(name, workers, s_ns, p_ns, floor)| {
                        Value::obj(vec![
                            ("name", Value::from(name.clone())),
                            ("workers", Value::from(*workers)),
                            ("serial_ns", Value::from(*s_ns)),
                            ("parallel_ns", Value::from(*p_ns)),
                            ("speedup", Value::from(s_ns / p_ns)),
                            ("floor", Value::from(*floor)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "observability",
            Value::arr(
                obs_rows
                    .iter()
                    .map(|(name, bare_ns, inst_ns, floor)| {
                        Value::obj(vec![
                            ("name", Value::from(name.clone())),
                            ("uninstrumented_ns", Value::from(*bare_ns)),
                            ("instrumented_ns", Value::from(*inst_ns)),
                            ("ratio", Value::from(bare_ns / inst_ns)),
                            ("floor", Value::from(*floor)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "regressions",
            Value::arr(regressions.iter().map(|r| Value::from(r.clone())).collect()),
        ),
        ("ns_per_param_largest_mlp", Value::from(*nspp)),
    ]);
    let out_path = std::env::var("RIGOR_BENCH_OUT").unwrap_or_else(|_| "BENCH_plan.json".into());
    match std::fs::write(&out_path, rigor::json::to_string_pretty(&json)) {
        Ok(()) => println!(
            "\nwrote {} (cwd {})",
            out_path,
            std::env::current_dir().map(|d| d.display().to_string()).unwrap_or_default()
        ),
        Err(e) => eprintln!("[warn] could not write {out_path}: {e}"),
    }

    b.report();

    if !regressions.is_empty() && std::env::var_os("RIGOR_BENCH_ENFORCE").is_some() {
        eprintln!(
            "RIGOR_BENCH_ENFORCE set and {} perf regression(s) detected — failing",
            regressions.len()
        );
        std::process::exit(1);
    }
}
