//! Shared helpers for the bench targets.

use rigor::data::Dataset;
use rigor::model::Model;

/// Load a trained artifact model + its eval dataset, or `None` (with a
/// notice) when `make artifacts` has not run — benches then fall back to
/// zoo models so `cargo bench` always produces output.
#[allow(dead_code)]
pub fn trained(name: &str) -> Option<(Model, Dataset)> {
    if !rigor::runtime::artifacts_available() {
        eprintln!("[note] artifacts missing — run `make artifacts` for trained-model benches");
        return None;
    }
    let dir = rigor::runtime::default_dir();
    let model = Model::load(&dir.join("models").join(format!("{name}.json"))).ok()?;
    let data = Dataset::load(&dir.join("data").join(format!("{name}_eval.json"))).ok()?;
    Some((model, data))
}

#[allow(dead_code)]
pub fn argmax32(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}
