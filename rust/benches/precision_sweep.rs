//! **E-acc-vs-k** — the motivating observation (paper §I): classification
//! agreement with the reference stays high down to very low precision.
//! With the `pjrt` feature and artifacts built, sweeps the AOT k-variant
//! artifacts through PJRT; otherwise falls back to the Rust per-op
//! emulation and reports agreement per k.

mod common;

use rigor::bench::Bencher;

fn main() {
    #[cfg(feature = "pjrt")]
    {
        if rigor::runtime::artifacts_available() {
            pjrt_sweep();
            return;
        }
        eprintln!("[skip] artifacts missing — run `make artifacts`; falling back to engine sweep");
    }
    #[cfg(not(feature = "pjrt"))]
    eprintln!("[note] built without the `pjrt` feature — running the engine-only sweep");
    engine_fallback();
}

#[cfg(feature = "pjrt")]
fn pjrt_sweep() {
    use rigor::quant::unit_roundoff;
    use rigor::runtime::Runtime;

    let mut b = Bencher::new("precision_sweep");
    let dir = rigor::runtime::default_dir();
    let mut rt = Runtime::open(&dir).expect("runtime");

    for name in ["digits", "mobilenet_mini"] {
        let data = rigor::data::Dataset::load(&dir.join("data").join(format!("{name}_eval.json")))
            .expect("eval data");
        println!("\n== {name}: agreement vs precision ({} samples) ==", data.len());
        println!("{:>4} {:>12} {:>12} {:>14}", "k", "u", "agreement", "max |dev|");
        for k in rt.precision_variants(name) {
            let mut agree = 0usize;
            let mut max_dev = 0.0f32;
            let (_, stats) = b.bench_once(&format!("{name}/k={k}"), || {
                for sample in &data.inputs {
                    let s: Vec<f32> = sample.iter().map(|&v| v as f32).collect();
                    let r = rt.run(name, "f32", &s).unwrap();
                    let e = rt.run(name, &format!("k{k}"), &s).unwrap();
                    if common::argmax32(&r) == common::argmax32(&e) {
                        agree += 1;
                    }
                    for (a, c) in r.iter().zip(&e) {
                        max_dev = max_dev.max((a - c).abs());
                    }
                }
            });
            let _ = stats;
            println!(
                "{k:>4} {:>12.2e} {:>9}/{:<3} {max_dev:>14.3e}",
                unit_roundoff(k),
                agree,
                data.len()
            );
        }
    }
    println!("\nexpected shape (paper): near-perfect agreement down to k~8, cliff below.");
    b.report();
}

/// Engine-only fallback: per-op emulation over a zoo model, driven
/// through a plan compiled once for the whole sweep (unfused, so the
/// emulated run matches the analyzed computation).
fn engine_fallback() {
    use rigor::model::zoo;
    use rigor::plan::{Arena, Plan};
    use rigor::quant::EmulatedFp;
    use rigor::tensor::EmuCtx;

    let mut b = Bencher::new("precision_sweep_engine");
    let model = zoo::scaled_mlp(7, 64, 48, 10);
    let plan = Plan::unfused(&model).expect("compile");
    let mut ref_arena: Arena<f64> = Arena::new();
    let mut emu_arena: Arena<EmulatedFp> = Arena::new();
    let mut rng = rigor::util::Rng::new(9);
    let data = rigor::data::synthetic::digits(&mut rng, 8, 4, 0.05);
    println!("{:>4} {:>12}", "k", "agreement");
    for k in [4u32, 6, 8, 10, 12, 16, 20] {
        let ec = EmuCtx { k };
        let mut agree = 0;
        let (_, _stats) = b.bench_once(&format!("engine/k={k}"), || {
            for input in &data.inputs {
                let yr = plan.execute::<f64>(&(), input, &mut ref_arena).unwrap().to_vec();
                let xe: Vec<EmulatedFp> =
                    input.iter().map(|&v| EmulatedFp::new(v, k)).collect();
                let ye = plan.execute::<EmulatedFp>(&ec, &xe, &mut emu_arena).unwrap();
                let am_r = yr
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                let am_e = ye
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.v.partial_cmp(&b.1.v).unwrap())
                    .unwrap()
                    .0;
                if am_r == am_e {
                    agree += 1;
                }
            }
        });
        println!("{k:>4} {:>9}/{:<3}", agree, data.inputs.len());
    }
    b.report();
}
