//! **E-softmax11/2** — empirical verification of the paper's eq. (11):
//! the softmax layer turns an absolute input error bound δ̄ into a
//! relative output error bounded by (11/2)·max|δ|, independent of the
//! vector length n. We sweep n and δ̄, report the worst observed
//! amplification, and check it against 5.5 and against the CAA softmax's
//! own bounds.

use rigor::analysis::softmax_theory::{eta_bound, max_amplification};
use rigor::bench::Bencher;
use rigor::caa::{Caa, Ctx};
use rigor::interval::Interval;
use rigor::layers::softmax_vec;

fn main() {
    let mut b = Bencher::new("softmax_bound");

    println!("observed relative-error amplification of softmax (bound: 11/2 = 5.5)");
    println!("{:>8} {:>12} {:>16} {:>10}", "n", "δ̄", "observed amp", "<= 5.5");
    let mut worst_overall = 0.0f64;
    for &n in &[2usize, 10, 100, 1000] {
        for &delta in &[1e-6, 1e-4, 1e-2] {
            let trials = if n >= 1000 { 30 } else { 120 };
            let (amp, stats) = {
                let mut amp = 0.0;
                let s = b.bench(&format!("amplification/n={n}/delta={delta:.0e}"), || {
                    amp = max_amplification(42, n, delta, trials);
                    amp
                });
                (amp, s.mean)
            };
            let _ = stats;
            worst_overall = worst_overall.max(amp);
            println!("{n:>8} {delta:>12.0e} {amp:>16.4} {:>10}", amp <= 5.5);
            assert!(amp <= 5.5, "eq. (11) violated: {amp} > 5.5");
        }
    }
    println!("worst overall: {worst_overall:.4} (first-order theory: ~2)");
    println!("η bound at δ̄=1e-2: {:.4e}", eta_bound(1e-2));

    // CAA's own softmax bounds obey the same law: feed logits carrying
    // δ̄ = 2u of absolute error, expect output rel bounds <~ 5.5·δ̄ + rounding.
    let ctx = Ctx::new();
    let delta_u = 2.0;
    let logits: Vec<Caa> = [0.3f64, -1.2, 0.9, 2.0, -0.4]
        .iter()
        .map(|&v| {
            Caa::from_parts(
                &ctx,
                v,
                Interval::point(v),
                Interval::new(v - delta_u * ctx.u_max, v + delta_u * ctx.u_max),
                delta_u,
                f64::INFINITY,
            )
        })
        .collect();
    let out = softmax_vec(&ctx, &logits);
    println!("\nCAA softmax with δ̄ = {delta_u}u input error:");
    for (i, o) in out.iter().enumerate() {
        println!(
            "  out[{i}]: rel bound {:.2}u (law scale: 5.5·δ̄ = {:.1}u + rounding)",
            o.rel_bound(),
            5.5 * delta_u
        );
        assert!(o.rel_bound().is_finite());
    }

    b.report();
}
