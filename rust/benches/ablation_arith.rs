//! **A-caa-vs-ia** — ablation of the arithmetic: full CAA vs IA-only vs
//! abs-only vs rel-only, on the Digits MLP (point-input classification)
//! and the Pendulum net (box-input verification). Shows *why* the combined
//! arithmetic is the paper's contribution:
//! * IA-only cannot separate data range from rounding error (catastrophic
//!   on box inputs),
//! * rel-only dies at the first cancellation (softmax max-subtraction),
//! * abs-only survives but cannot serve relative margins,
//! * CAA keeps both.
//!
//! The ablations drive the engine's `analyze_class` directly with
//! feature-toggled contexts; the configs come from the api request builder
//! (its ablation escape hatch), not hand-rolled `AnalysisConfig`s.

mod common;

use rigor::analysis::baseline::ia_only_class;
use rigor::analysis::{analyze_class, AnalysisConfig};
use rigor::api::AnalysisRequest;
use rigor::bench::Bencher;
use rigor::caa::Ctx;
use rigor::model::zoo;
use rigor::report::fmt_bound_u;

fn cfg_with(ctx: Ctx, radius: f64) -> AnalysisConfig {
    AnalysisRequest::builder()
        .ctx(ctx)
        .p_star(0.6)
        .input_radius(radius)
        .exact_inputs(true)
        .build_config()
        .expect("ablation config")
}

fn main() {
    let mut b = Bencher::new("ablation_arith");

    let (digits, ddata) = common::trained("digits").unwrap_or_else(|| {
        let mut rng = rigor::util::Rng::new(4);
        (
            zoo::scaled_mlp(4, 64, 48, 10),
            rigor::data::synthetic::digits(&mut rng, 8, 1, 0.05),
        )
    });
    let pendulum = common::trained("pendulum")
        .map(|(m, _)| m)
        .unwrap_or_else(|| zoo::tiny_pendulum(3));

    println!("{:<34} {:>12} {:>12}", "configuration", "abs bound", "rel bound");
    println!("{}", "-".repeat(60));

    // ---- digits, point input ---------------------------------------------
    let sample = &ddata.inputs[0];
    // Deep 784-dim nets are vacuous at the paper's u_max = 2^-7 (every
    // configuration returns inf) — compare at the tailored u_max = 2^-21
    // where the full CAA certifies (see the table1 bench).
    let u21 = 2f64.powi(-21);
    let variants: Vec<(&str, Ctx)> = vec![
        ("digits/CAA (full)", Ctx::with_u_max(u21)),
        ("digits/abs-only", Ctx::with_u_max(u21).abs_only()),
        ("digits/rel-only", Ctx::with_u_max(u21).rel_only()),
    ];
    for (name, ctx) in variants {
        let cfg = cfg_with(ctx, 0.0);
        let mut out = None;
        b.bench_once(name, || out = Some(analyze_class(&digits, &cfg, 0, sample).unwrap()));
        let a = out.unwrap();
        println!(
            "{name:<34} {:>12} {:>12}",
            fmt_bound_u(a.max_abs_u),
            fmt_bound_u(a.max_rel_u)
        );
    }
    let cfg = cfg_with(Ctx::with_u_max(u21), 0.0);
    let mut ia = None;
    b.bench_once("digits/IA-only", || ia = Some(ia_only_class(&digits, &cfg, 0, sample).unwrap()));
    let ia = ia.unwrap();
    println!(
        "{:<34} {:>12} {:>12}",
        "digits/IA-only (single interval)",
        fmt_bound_u(ia.max_abs_u),
        fmt_bound_u(ia.max_rel_u)
    );

    // ---- pendulum, whole box ----------------------------------------------
    println!();
    let center = vec![0.0, 0.0];
    for (name, ctx) in [
        ("pendulum-box/CAA (full)", Ctx::new()),
        ("pendulum-box/abs-only", Ctx::new().abs_only()),
        ("pendulum-box/rel-only", Ctx::new().rel_only()),
    ] {
        let cfg = cfg_with(ctx, 6.0);
        let mut out = None;
        b.bench_once(name, || out = Some(analyze_class(&pendulum, &cfg, 0, &center).unwrap()));
        let a = out.unwrap();
        println!(
            "{name:<34} {:>12} {:>12}",
            fmt_bound_u(a.max_abs_u),
            fmt_bound_u(a.max_rel_u)
        );
    }
    let cfg = cfg_with(Ctx::new(), 6.0);
    let mut iab = None;
    b.bench_once("pendulum-box/IA-only", || {
        iab = Some(ia_only_class(&pendulum, &cfg, 0, &center).unwrap())
    });
    let iab = iab.unwrap();
    println!(
        "{:<34} {:>12} {:>12}",
        "pendulum-box/IA-only",
        fmt_bound_u(iab.max_abs_u),
        fmt_bound_u(iab.max_rel_u)
    );

    println!("\nexpected shape: CAA <= abs-only << IA-only; rel-only '-' after cancellation.");
    b.report();
}
