//! **A-decorr** — ablation of the paper's two "global insight" fixes:
//! id-based decorrelation and min/max bound labels (§III). Measured on the
//! structure that needs them most: the max-subtracted softmax.

mod common;

use rigor::analysis::analyze_class;
use rigor::api::AnalysisRequest;
use rigor::bench::Bencher;
use rigor::caa::{max_many, Caa, Ctx};
use rigor::interval::Interval;
use rigor::model::zoo;
use rigor::report::fmt_bound_u;

fn main() {
    let mut b = Bencher::new("ablation_decorr");

    // ---- micro: the softmax exp-input range with/without labels -----------
    println!("softmax exp-input knowledge (x - max(x), ranged logits):");
    for (name, ctx) in [
        ("labels + decorrelation", Ctx::new()),
        ("no labels", Ctx::new().no_labels()),
        ("no decorrelation", Ctx::new().no_decorrelation()),
        ("neither", Ctx::new().no_labels().no_decorrelation()),
    ] {
        let mut xs = vec![
            Caa::input(&ctx, Interval::new(0.0, 4.0), 3.0),
            Caa::input(&ctx, Interval::new(0.0, 4.0), 1.0),
            Caa::input(&ctx, Interval::new(0.0, 4.0), 2.0),
        ];
        let m = max_many(&ctx, &mut xs);
        let e = xs[0].sub(&m, &ctx).exp(&ctx);
        println!(
            "  {name:<28} exp range hi = {:>12.4e}  (1.0 is ideal)",
            e.ideal().hi()
        );
    }

    // ---- micro: x - x decorrelation ---------------------------------------
    println!("\nthe paper's decorrelation example (y = x; z = x - y), x in [-1,1]:");
    for (name, ctx) in [("decorrelation on", Ctx::new()), ("decorrelation off", Ctx::new().no_decorrelation())] {
        let x = Caa::input(&ctx, Interval::new(-1.0, 1.0), 0.5);
        let y = x.clone(); // assignment copies the id
        let z = x.sub(&y, &ctx);
        println!(
            "  {name:<28} z range = {}, δ̄ = {}, ε̄ = {}",
            z.ideal(),
            fmt_bound_u(z.abs_bound()),
            fmt_bound_u(z.rel_bound())
        );
    }

    // ---- macro: full model bounds with the features toggled ---------------
    let (model, data) = common::trained("digits").unwrap_or_else(|| {
        let mut rng = rigor::util::Rng::new(4);
        (
            zoo::scaled_mlp(4, 64, 48, 10),
            rigor::data::synthetic::digits(&mut rng, 8, 1, 0.05),
        )
    });
    let sample = &data.inputs[0];
    println!("\nfull digits analysis with features toggled:");
    println!("{:<28} {:>12} {:>12} {:>10}", "configuration", "abs bound", "rel bound", "time");
    // Tailored u_max = 2^-21 (see table1 bench) keeps the rows finite.
    let u21 = 2f64.powi(-21);
    for (name, ctx) in [
        ("full CAA", Ctx::with_u_max(u21)),
        ("no labels", Ctx::with_u_max(u21).no_labels()),
        ("no decorrelation", Ctx::with_u_max(u21).no_decorrelation()),
        ("neither", Ctx::with_u_max(u21).no_labels().no_decorrelation()),
    ] {
        let cfg = AnalysisRequest::builder()
            .ctx(ctx)
            .p_star(0.6)
            .exact_inputs(true)
            .build_config()
            .expect("ablation config");
        let mut out = None;
        let (_, stats) = b.bench_once(&format!("digits/{name}"), || {
            out = Some(analyze_class(&model, &cfg, 0, sample).unwrap())
        });
        let a = out.unwrap();
        println!(
            "{name:<28} {:>12} {:>12} {:>10.1?}",
            fmt_bound_u(a.max_abs_u),
            fmt_bound_u(a.max_rel_u),
            stats.mean
        );
    }

    b.report();
}
