//! PJRT execution (the `pjrt` feature): loads the AOT artifacts
//! (`artifacts/*.hlo.txt`, produced once by `python/compile/aot.py`) and
//! executes them on the PJRT CPU client via the `xla` crate. This is the
//! only place the L2/L1 output is touched at runtime — Python itself is
//! never on this path.
//!
//! This is the *external* compiled-execution path (XLA-compiled f32
//! kernels for throughput measurements); its in-process sibling is
//! [`crate::plan::Plan`], which compiles a model into shape-resolved
//! steps that the analysis arithmetics (f64 / CAA / emulated-k) execute
//! directly. Both follow the same compile-once-run-many design; the
//! PJRT cache here is keyed by `(model, variant)` the way the session's
//! model cache is keyed by path + content hash.
//!
//! Interchange is **HLO text**, not serialized protos: jax >= 0.5 emits
//! protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).

use super::manifest::{ArtifactEntry, Manifest};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled artifact ready to execute.
pub struct LoadedExec {
    exe: xla::PjRtLoadedExecutable,
    /// The manifest entry this executable was compiled from.
    pub entry: ArtifactEntry,
}

impl LoadedExec {
    /// Run on one f32 input vector; returns the flat f32 output.
    pub fn run_f32(&self, input: &[f32]) -> Result<Vec<f32>> {
        let n: usize = self.entry.input_shape.iter().product();
        if input.len() != n {
            anyhow::bail!(
                "artifact '{}:{}' expects {} input values, got {}",
                self.entry.name,
                self.entry.variant,
                n,
                input.len()
            );
        }
        let dims: Vec<i64> = self.entry.input_shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape input literal: {e:?}"))?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("untuple result: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("read result: {e:?}"))
    }
}

/// The artifact runtime: a PJRT CPU client plus a compile cache keyed by
/// (model, variant).
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    /// The artifact manifest the runtime serves.
    pub manifest: Manifest,
    cache: HashMap<(String, String), std::rc::Rc<LoadedExec>>,
}

impl Runtime {
    /// Open an artifacts directory (must contain `manifest.json`).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, dir: dir.to_path_buf(), manifest, cache: HashMap::new() })
    }

    /// Default artifacts location (`$RIGOR_ARTIFACTS` or `./artifacts`).
    pub fn default_dir() -> PathBuf {
        super::default_dir()
    }

    /// True if the default artifacts directory exists with a manifest
    /// (lets tests and benches skip gracefully before `make artifacts`).
    pub fn artifacts_available() -> bool {
        super::artifacts_available()
    }

    /// Name of the PJRT platform serving the runtime.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (compile-once, cached) an artifact by model name and variant
    /// (`"f32"`, `"k8"`, ...).
    pub fn load(&mut self, name: &str, variant: &str) -> Result<std::rc::Rc<LoadedExec>> {
        let key = (name.to_string(), variant.to_string());
        if let Some(e) = self.cache.get(&key) {
            return Ok(std::rc::Rc::clone(e));
        }
        let entry = self
            .manifest
            .find(name, variant)
            .ok_or_else(|| anyhow!("artifact '{name}:{variant}' not in manifest"))?
            .clone();
        let path = self.dir.join(&entry.path);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile '{name}:{variant}': {e:?}"))?;
        let loaded = std::rc::Rc::new(LoadedExec { exe, entry });
        self.cache.insert(key, std::rc::Rc::clone(&loaded));
        Ok(loaded)
    }

    /// Convenience: load + run.
    pub fn run(&mut self, name: &str, variant: &str, input: &[f32]) -> Result<Vec<f32>> {
        self.load(name, variant)?
            .run_f32(input)
            .with_context(|| format!("running {name}:{variant}"))
    }

    /// The k-variants available for a model, sorted ascending.
    pub fn precision_variants(&self, name: &str) -> Vec<u32> {
        let mut ks: Vec<u32> = self
            .manifest
            .artifacts
            .iter()
            .filter(|a| a.name == name)
            .filter_map(|a| a.variant.strip_prefix('k').and_then(|s| s.parse().ok()))
            .collect();
        ks.sort_unstable();
        ks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full runtime round-trips are exercised by `rust/tests/runtime_e2e.rs`
    // and the examples once artifacts exist.

    #[test]
    fn open_missing_dir_errors() {
        let r = Runtime::open(Path::new("/nonexistent/rigor-artifacts"));
        assert!(r.is_err());
    }
}
