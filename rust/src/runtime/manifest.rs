//! The artifact manifest (`artifacts/manifest.json`), written by
//! `python/compile/aot.py`:
//!
//! ```json
//! {
//!   "artifacts": [
//!     {"name": "digits", "variant": "f32", "path": "digits.f32.hlo.txt",
//!      "input_shape": [784], "output_shape": [10]},
//!     {"name": "digits", "variant": "k8", "path": "digits.k8.hlo.txt",
//!      "input_shape": [784], "output_shape": [10]}
//!   ]
//! }
//! ```

use crate::json::Value;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// One AOT-compiled computation.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    /// Model name the artifact belongs to.
    pub name: String,
    /// `"f32"` for the reference inference, `"k<bits>"` for emulated
    /// precision-k variants (the Pallas roundk kernel baked into the HLO).
    pub variant: String,
    /// HLO text file, relative to the artifacts directory.
    pub path: String,
    /// Shape of the computation's input.
    pub input_shape: Vec<usize>,
    /// Shape of the computation's output.
    pub output_shape: Vec<usize>,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// All exported computations, in export order.
    pub artifacts: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Parse the manifest JSON the Python exporter writes.
    pub fn from_json(v: &Value) -> Result<Manifest> {
        let arr = v
            .get("artifacts")
            .and_then(|a| a.as_array())
            .ok_or_else(|| anyhow!("manifest missing 'artifacts' array"))?;
        let mut artifacts = Vec::with_capacity(arr.len());
        for (i, e) in arr.iter().enumerate() {
            let get_str = |k: &str| -> Result<String> {
                e.get(k)
                    .and_then(|x| x.as_str())
                    .map(|s| s.to_string())
                    .ok_or_else(|| anyhow!("artifact {i}: missing string '{k}'"))
            };
            let get_shape = |k: &str| -> Result<Vec<usize>> {
                e.get(k)
                    .and_then(|x| x.as_usize_vec())
                    .ok_or_else(|| anyhow!("artifact {i}: missing shape '{k}'"))
            };
            artifacts.push(ArtifactEntry {
                name: get_str("name")?,
                variant: get_str("variant")?,
                path: get_str("path")?,
                input_shape: get_shape("input_shape")?,
                output_shape: get_shape("output_shape")?,
            });
        }
        Ok(Manifest { artifacts })
    }

    /// Load and parse a manifest file.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Manifest::from_json(&crate::json::parse(&text)?)
    }

    /// The entry for `(name, variant)`, if exported.
    pub fn find(&self, name: &str, variant: &str) -> Option<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|a| a.name == name && a.variant == variant)
    }

    /// Distinct model names, in manifest order.
    pub fn model_names(&self) -> Vec<String> {
        let mut names = Vec::new();
        for a in &self.artifacts {
            if !names.contains(&a.name) {
                names.push(a.name.clone());
            }
        }
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    #[test]
    fn parse_and_lookup() {
        let v = json::parse(
            r#"{"artifacts": [
                {"name": "digits", "variant": "f32", "path": "d.f32.hlo.txt",
                 "input_shape": [784], "output_shape": [10]},
                {"name": "digits", "variant": "k8", "path": "d.k8.hlo.txt",
                 "input_shape": [784], "output_shape": [10]},
                {"name": "pendulum", "variant": "f32", "path": "p.hlo.txt",
                 "input_shape": [2], "output_shape": [1]}
            ]}"#,
        )
        .unwrap();
        let m = Manifest::from_json(&v).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        assert!(m.find("digits", "k8").is_some());
        assert!(m.find("digits", "k9").is_none());
        assert_eq!(m.model_names(), vec!["digits", "pendulum"]);
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            r#"{}"#,
            r#"{"artifacts": [{"name": "x"}]}"#,
            r#"{"artifacts": [{"name": "x", "variant": "f32", "path": "p",
                "input_shape": ["a"], "output_shape": [1]}]}"#,
        ] {
            assert!(Manifest::from_json(&json::parse(bad).unwrap()).is_err());
        }
    }
}
