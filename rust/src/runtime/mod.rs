//! AOT artifact handling: the manifest schema and artifact-directory
//! helpers are always available; actually *executing* the artifacts on the
//! PJRT CPU client (the [`Runtime`] in [`exec`]) needs the `xla` crate and
//! is gated behind the off-by-default `pjrt` cargo feature, so the default
//! build has no native dependencies.

mod manifest;

pub use manifest::{ArtifactEntry, Manifest};

#[cfg(feature = "pjrt")]
mod exec;
#[cfg(feature = "pjrt")]
pub use exec::{LoadedExec, Runtime};

use std::path::PathBuf;

/// Default artifacts location (`$RIGOR_ARTIFACTS` or `./artifacts`).
pub fn default_dir() -> PathBuf {
    std::env::var_os("RIGOR_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// True if the default artifacts directory exists with a manifest
/// (lets tests and benches skip gracefully before `make artifacts`).
pub fn artifacts_available() -> bool {
    default_dir().join("manifest.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_dir_env_override() {
        // Don't mutate the process env (tests run in parallel); just check
        // the fallback.
        if std::env::var_os("RIGOR_ARTIFACTS").is_none() {
            assert_eq!(default_dir(), PathBuf::from("artifacts"));
        }
    }
}
