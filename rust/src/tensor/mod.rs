//! Tensors generic over the scalar arithmetic.
//!
//! The inference engine (replacing frugally-deep + Eigen) is written once,
//! generic over [`Scalar`]; binding a different arithmetic into the same
//! network evaluation is exactly the original tool's trick of overloading
//! Eigen's scalar type. Three arithmetics are provided:
//!
//! * `f64` — the plain high-precision trace (the "reference" run),
//! * [`crate::quant::EmulatedFp`] — emulated precision-k FP (witness runs),
//! * [`crate::caa::Caa`] — the paper's analysis arithmetic.

use crate::caa::{self, Caa};
use crate::quant::EmulatedFp;

/// Scalar arithmetic the inference engine is generic over. `Ctx` carries
/// per-analysis configuration (the CAA context; `()` for plain floats; the
/// precision for emulated FP).
pub trait Scalar: Clone {
    /// Per-analysis configuration threaded through every operation.
    type Ctx: Sync;

    /// Whether the plan executor may route this arithmetic through the
    /// blocked (register-tiled, autovectorization-friendly) kernels in
    /// [`crate::layers::gemm`]. The blocked kernels perform exactly the
    /// same operations as the scalar kernels, only reordered across
    /// *independent* reduction chains, so any concrete arithmetic could
    /// legally opt in — but only the cheap concrete scalars (`f64`
    /// reference traces, [`crate::quant::EmulatedFp`] witness runs)
    /// benefit. CAA stays `false` by design: each CAA operation dwarfs
    /// the loop overhead blocking amortizes, and the analysis contract
    /// is simplest when the analyzed pass is the textbook scalar loop
    /// (see DESIGN.md "Kernel dispatch").
    const BLOCKED_ELIGIBLE: bool = false;

    /// Embed a learned parameter (pays a representation rounding).
    fn param(ctx: &Self::Ctx, x: f64) -> Self;
    /// Embed an exactly-representable constant (0, 1, small integers).
    fn exact(ctx: &Self::Ctx, x: f64) -> Self;

    /// Addition in the target arithmetic.
    fn add(&self, o: &Self, ctx: &Self::Ctx) -> Self;
    /// Subtraction in the target arithmetic.
    fn sub(&self, o: &Self, ctx: &Self::Ctx) -> Self;
    /// Multiplication in the target arithmetic.
    fn mul(&self, o: &Self, ctx: &Self::Ctx) -> Self;
    /// Division in the target arithmetic.
    fn div(&self, o: &Self, ctx: &Self::Ctx) -> Self;
    /// Exponential in the target arithmetic.
    fn exp(&self, ctx: &Self::Ctx) -> Self;
    /// Square root in the target arithmetic.
    fn sqrt(&self, ctx: &Self::Ctx) -> Self;
    /// Hyperbolic tangent in the target arithmetic.
    fn tanh(&self, ctx: &Self::Ctx) -> Self;
    /// Logistic sigmoid in the target arithmetic.
    fn sigmoid(&self, ctx: &Self::Ctx) -> Self;
    /// ReLU in the target arithmetic.
    fn relu(&self, ctx: &Self::Ctx) -> Self;
    /// Binary maximum in the target arithmetic.
    fn max(&self, o: &Self, ctx: &Self::Ctx) -> Self;

    /// Maximum over a slice. The CAA implementation additionally labels
    /// every element with the result (the paper's control-flow insight),
    /// which is why this takes `&mut`.
    fn max_many(ctx: &Self::Ctx, xs: &mut [Self]) -> Self {
        assert!(!xs.is_empty());
        let mut m = xs[0].clone();
        for x in &xs[1..] {
            m = m.max(x, ctx);
        }
        m
    }

    /// Multiply by a learned scalar parameter (pays the parameter's
    /// representation rounding plus the multiplication rounding). The dot
    /// product hot path; CAA overrides it with a fused implementation.
    fn mul_param(&self, w: f64, ctx: &Self::Ctx) -> Self {
        Self::param(ctx, w).mul(self, ctx)
    }

    /// Clamp the *knowledge* about this value to `[0, 1]` without touching
    /// the value itself: a no-op for concrete arithmetics; for CAA it
    /// intersects the range enclosures. Callers may only use it where the
    /// membership is mathematically guaranteed (e.g. softmax outputs: each
    /// summand of the denominator is nonnegative and RN summation of
    /// nonnegatives dominates every summand, so the computed quotient is
    /// `<= 1`, and rounding is monotone).
    fn clamp01(&self, _ctx: &Self::Ctx) -> Self {
        self.clone()
    }

    /// The concrete trace value (for argmax / reporting).
    fn value(&self) -> f64;
}

impl Scalar for f64 {
    type Ctx = ();

    const BLOCKED_ELIGIBLE: bool = true;

    fn param(_: &(), x: f64) -> f64 {
        x
    }
    fn exact(_: &(), x: f64) -> f64 {
        x
    }
    fn add(&self, o: &f64, _: &()) -> f64 {
        self + o
    }
    fn sub(&self, o: &f64, _: &()) -> f64 {
        self - o
    }
    fn mul(&self, o: &f64, _: &()) -> f64 {
        self * o
    }
    fn div(&self, o: &f64, _: &()) -> f64 {
        self / o
    }
    fn exp(&self, _: &()) -> f64 {
        f64::exp(*self)
    }
    fn sqrt(&self, _: &()) -> f64 {
        f64::sqrt(*self)
    }
    fn tanh(&self, _: &()) -> f64 {
        f64::tanh(*self)
    }
    fn sigmoid(&self, _: &()) -> f64 {
        1.0 / (1.0 + f64::exp(-self))
    }
    fn relu(&self, _: &()) -> f64 {
        f64::max(*self, 0.0)
    }
    fn max(&self, o: &f64, _: &()) -> f64 {
        f64::max(*self, *o)
    }
    fn value(&self) -> f64 {
        *self
    }
}

/// Context for emulated precision-k runs: the mantissa bit count.
#[derive(Clone, Copy, Debug)]
pub struct EmuCtx {
    /// Mantissa width of the emulated format.
    pub k: u32,
}

impl Scalar for EmulatedFp {
    type Ctx = EmuCtx;

    const BLOCKED_ELIGIBLE: bool = true;

    fn param(c: &EmuCtx, x: f64) -> Self {
        EmulatedFp::new(x, c.k)
    }
    fn exact(c: &EmuCtx, x: f64) -> Self {
        debug_assert_eq!(crate::quant::round_to_precision(x, c.k), x);
        EmulatedFp { v: x, k: c.k }
    }
    fn add(&self, o: &Self, _: &EmuCtx) -> Self {
        EmulatedFp::add(*self, *o)
    }
    fn sub(&self, o: &Self, _: &EmuCtx) -> Self {
        EmulatedFp::sub(*self, *o)
    }
    fn mul(&self, o: &Self, _: &EmuCtx) -> Self {
        EmulatedFp::mul(*self, *o)
    }
    fn div(&self, o: &Self, _: &EmuCtx) -> Self {
        EmulatedFp::div(*self, *o)
    }
    fn exp(&self, _: &EmuCtx) -> Self {
        EmulatedFp::exp(*self)
    }
    fn sqrt(&self, _: &EmuCtx) -> Self {
        EmulatedFp::sqrt(*self)
    }
    fn tanh(&self, _: &EmuCtx) -> Self {
        EmulatedFp::tanh(*self)
    }
    fn sigmoid(&self, _: &EmuCtx) -> Self {
        EmulatedFp::sigmoid(*self)
    }
    fn relu(&self, _: &EmuCtx) -> Self {
        EmulatedFp::relu(*self)
    }
    fn max(&self, o: &Self, _: &EmuCtx) -> Self {
        EmulatedFp::max(*self, *o)
    }
    fn value(&self) -> f64 {
        self.v
    }
}

impl Scalar for Caa {
    type Ctx = caa::Ctx;

    fn param(ctx: &caa::Ctx, x: f64) -> Self {
        Caa::param(ctx, x)
    }
    fn exact(_: &caa::Ctx, x: f64) -> Self {
        Caa::exact(x)
    }
    fn add(&self, o: &Self, ctx: &caa::Ctx) -> Self {
        Caa::add(self, o, ctx)
    }
    fn sub(&self, o: &Self, ctx: &caa::Ctx) -> Self {
        Caa::sub(self, o, ctx)
    }
    fn mul(&self, o: &Self, ctx: &caa::Ctx) -> Self {
        Caa::mul(self, o, ctx)
    }
    fn div(&self, o: &Self, ctx: &caa::Ctx) -> Self {
        Caa::div(self, o, ctx)
    }
    fn exp(&self, ctx: &caa::Ctx) -> Self {
        Caa::exp(self, ctx)
    }
    fn sqrt(&self, ctx: &caa::Ctx) -> Self {
        Caa::sqrt(self, ctx)
    }
    fn tanh(&self, ctx: &caa::Ctx) -> Self {
        Caa::tanh(self, ctx)
    }
    fn sigmoid(&self, ctx: &caa::Ctx) -> Self {
        Caa::sigmoid(self, ctx)
    }
    fn relu(&self, ctx: &caa::Ctx) -> Self {
        Caa::relu(self, ctx)
    }
    fn max(&self, o: &Self, ctx: &caa::Ctx) -> Self {
        Caa::max(self, o, ctx)
    }
    fn max_many(ctx: &caa::Ctx, xs: &mut [Self]) -> Self {
        crate::caa::max_many(ctx, xs)
    }
    fn mul_param(&self, w: f64, ctx: &caa::Ctx) -> Self {
        Caa::mul_const(self, w, ctx)
    }
    fn clamp01(&self, _ctx: &caa::Ctx) -> Self {
        self.clamp_range(crate::interval::Interval::new(0.0, 1.0))
    }
    fn value(&self) -> f64 {
        self.fp()
    }
}

/// A dense row-major tensor.
#[derive(Clone, Debug)]
pub struct Tensor<S> {
    shape: Vec<usize>,
    data: Vec<S>,
}

impl<S: Clone> Tensor<S> {
    /// A tensor from a shape and its row-major data (lengths must agree).
    pub fn new(shape: Vec<usize>, data: Vec<S>) -> Tensor<S> {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data }
    }

    /// A tensor with every element set to `v`.
    pub fn filled(shape: Vec<usize>, v: S) -> Tensor<S> {
        let n = shape.iter().product();
        Tensor { shape, data: vec![v; n] }
    }

    /// The tensor's shape.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// The flat row-major data.
    pub fn data(&self) -> &[S] {
        &self.data
    }

    /// Mutable flat row-major data.
    pub fn data_mut(&mut self) -> &mut [S] {
        &mut self.data
    }

    /// Consume the tensor, yielding its data vector.
    pub fn into_data(self) -> Vec<S> {
        self.data
    }

    /// Flat offset of a multi-index.
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&ix, &dim)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(ix < dim, "index {ix} out of bound {dim} at axis {i}");
            off = off * dim + ix;
        }
        off
    }

    /// Element at a multi-index.
    pub fn at(&self, idx: &[usize]) -> &S {
        &self.data[self.offset(idx)]
    }

    /// Mutable element at a multi-index.
    pub fn at_mut(&mut self, idx: &[usize]) -> &mut S {
        let off = self.offset(idx);
        &mut self.data[off]
    }

    /// Reshape without moving data (sizes must agree).
    pub fn reshape(mut self, shape: Vec<usize>) -> Tensor<S> {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape size mismatch"
        );
        self.shape = shape;
        self
    }

    /// Elementwise map into a new tensor of the same shape.
    pub fn map<T: Clone>(&self, f: impl Fn(&S) -> T) -> Tensor<T> {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(f).collect() }
    }
}

impl Tensor<f64> {
    /// Lift an f64 tensor into another arithmetic as parameters.
    pub fn lift_params<S: Scalar>(&self, ctx: &S::Ctx) -> Tensor<S> {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| S::param(ctx, x)).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offsets_row_major() {
        let t = Tensor::new(vec![2, 3, 4], (0..24).map(|x| x as f64).collect());
        assert_eq!(*t.at(&[0, 0, 0]), 0.0);
        assert_eq!(*t.at(&[0, 0, 3]), 3.0);
        assert_eq!(*t.at(&[0, 1, 0]), 4.0);
        assert_eq!(*t.at(&[1, 0, 0]), 12.0);
        assert_eq!(*t.at(&[1, 2, 3]), 23.0);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = t.reshape(vec![6]);
        assert_eq!(*r.at(&[4]), 5.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let _ = Tensor::new(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn scalar_f64_roundtrip() {
        let c = ();
        let a = <f64 as Scalar>::param(&c, 2.0);
        let b = a.mul(&a, &c).add(&<f64 as Scalar>::exact(&c, 1.0), &c);
        assert_eq!(b.value(), 5.0);
    }

    #[test]
    fn scalar_emulated_rounds() {
        let c = EmuCtx { k: 8 };
        let third = Scalar::div(
            &<EmulatedFp as Scalar>::param(&c, 1.0),
            &<EmulatedFp as Scalar>::param(&c, 3.0),
            &c,
        );
        assert_ne!(third.value(), 1.0 / 3.0, "8-bit third differs from f64 third");
        assert!((third.value() - 1.0 / 3.0).abs() < 3e-3);
    }

    #[test]
    fn scalar_caa_same_engine_code() {
        let ctx = crate::caa::Ctx::new();
        let a = <Caa as Scalar>::param(&ctx, 1.5);
        let b = Scalar::tanh(&Scalar::relu(&a, &ctx), &ctx);
        assert!((b.value() - f64::tanh(1.5)).abs() < 1e-15);
        assert!(b.abs_bound().is_finite());
    }

    #[test]
    fn lift_params() {
        let t = Tensor::new(vec![2], vec![0.5, -0.25]);
        let ctx = crate::caa::Ctx::new();
        let l: Tensor<Caa> = t.lift_params(&ctx);
        assert_eq!(l.at(&[1]).fp(), -0.25);
    }

    #[test]
    fn max_many_default_impl() {
        let c = ();
        let mut xs = vec![1.0f64, 5.0, 3.0];
        let m = <f64 as Scalar>::max_many(&c, &mut xs);
        assert_eq!(m, 5.0);
    }
}
