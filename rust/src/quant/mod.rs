//! Precision-k floating-point **emulation**.
//!
//! Rounds an f64 to a binary floating-point format with `k` mantissa bits
//! (k includes the implicit leading 1, matching the paper's convention:
//! binary32 has k = 24, so `u = 2^(1-k) = 2^-23`), round-to-nearest-even,
//! unbounded exponent range (the paper's analysis excludes over/underflow;
//! §IV argues DNN values are bounded so the exponent range is not the
//! issue — precision is).
//!
//! This is the Rust twin of the Pallas `roundk` kernel
//! (`python/compile/kernels/roundk.py`); `tests/` cross-check the two on
//! the same inputs via the PJRT runtime, and the CAA soundness property
//! tests use it to *witness* that real rounding errors stay below the CAA
//! bounds.

use crate::caa::Caa;

/// The unit roundoff `u = 2^(1-k)` for precision `k`.
pub fn unit_roundoff(k: u32) -> f64 {
    debug_assert!((2..=53).contains(&k));
    2f64.powi(1 - k as i32)
}

/// Round `x` to `k` mantissa bits (round-to-nearest-even), exponent range
/// unbounded. `k = 53` is the identity on finite doubles.
pub fn round_to_precision(x: f64, k: u32) -> f64 {
    debug_assert!((2..=53).contains(&k));
    if !x.is_finite() || x == 0.0 || k == 53 {
        return x;
    }
    let drop = 53 - k; // mantissa bits to discard
    let bits = x.to_bits();
    let mantissa_mask = (1u64 << drop) - 1;
    let tail = bits & mantissa_mask;
    let truncated = bits & !mantissa_mask;
    let half = 1u64 << (drop - 1);
    // Round-to-nearest, ties to even (on the kept mantissa's LSB).
    let round_up = tail > half || (tail == half && (truncated >> drop) & 1 == 1);
    let out = if round_up {
        truncated + (1u64 << drop) // may carry into the exponent: correct
    } else {
        truncated
    };
    f64::from_bits(out)
    // NOTE on subnormals: because we interpret k against the f64
    // representation, values down at the f64 subnormal floor lose the
    // unbounded-exponent property; DNN quantities (|x| in ~[1e-45, 1e4])
    // never get near it.
}

/// A scalar evaluated under emulated precision-k arithmetic: every binary
/// operation result is re-rounded to `k` bits. Used by the soundness sweeps
/// to *execute* the network the way a precision-k FPU would.
#[derive(Clone, Copy, Debug)]
pub struct EmulatedFp {
    /// The current value (always exactly representable in k bits).
    pub v: f64,
    /// Mantissa width this scalar rounds to.
    pub k: u32,
}

impl EmulatedFp {
    /// Round `x` into the k-bit format.
    pub fn new(x: f64, k: u32) -> Self {
        EmulatedFp { v: round_to_precision(x, k), k }
    }

    fn wrap(&self, x: f64) -> Self {
        EmulatedFp { v: round_to_precision(x, self.k), k: self.k }
    }

    /// Rounded addition.
    pub fn add(self, o: Self) -> Self {
        self.wrap(self.v + o.v)
    }

    /// Rounded subtraction.
    pub fn sub(self, o: Self) -> Self {
        self.wrap(self.v - o.v)
    }

    /// Rounded multiplication.
    pub fn mul(self, o: Self) -> Self {
        self.wrap(self.v * o.v)
    }

    /// Rounded division.
    pub fn div(self, o: Self) -> Self {
        self.wrap(self.v / o.v)
    }

    /// Rounded exponential.
    pub fn exp(self) -> Self {
        self.wrap(self.v.exp())
    }

    /// Rounded natural logarithm.
    pub fn ln(self) -> Self {
        self.wrap(self.v.ln())
    }

    /// Rounded square root.
    pub fn sqrt(self) -> Self {
        self.wrap(self.v.sqrt())
    }

    /// Rounded hyperbolic tangent.
    pub fn tanh(self) -> Self {
        self.wrap(self.v.tanh())
    }

    /// Rounded logistic sigmoid.
    pub fn sigmoid(self) -> Self {
        self.wrap(1.0 / (1.0 + (-self.v).exp()))
    }

    /// Exact maximum (selection never rounds).
    pub fn max(self, o: Self) -> Self {
        EmulatedFp { v: self.v.max(o.v), k: self.k }
    }

    /// Exact minimum (selection never rounds).
    pub fn min(self, o: Self) -> Self {
        EmulatedFp { v: self.v.min(o.v), k: self.k }
    }

    /// Exact ReLU (max with the representable 0).
    pub fn relu(self) -> Self {
        EmulatedFp { v: self.v.max(0.0), k: self.k }
    }

    /// Exact negation (sign flips never round).
    pub fn neg(self) -> Self {
        EmulatedFp { v: -self.v, k: self.k }
    }
}

/// Execute a compiled plan under emulated precision-k arithmetic — the
/// witness run the soundness sweeps compare against CAA bounds. Uses this
/// worker thread's arena, so sweeping many `k` values over the same plan
/// is allocation-free at the tensor level. Pass an **unfused** plan when
/// the run witnesses analysis bounds (batch-norm folding changes the
/// rounding profile; see [`crate::plan::Fusion`]).
pub fn emulated_forward(
    plan: &crate::plan::Plan,
    k: u32,
    sample: &[f64],
) -> anyhow::Result<Vec<f64>> {
    let ec = crate::tensor::EmuCtx { k };
    let input: Vec<EmulatedFp> = sample.iter().map(|&v| EmulatedFp::new(v, k)).collect();
    crate::coordinator::with_worker_scratch(
        |arena: &mut crate::plan::Arena<EmulatedFp>| {
            let out = plan.execute::<EmulatedFp>(&ec, &input, arena)?;
            Ok(out.iter().map(|e| e.v).collect())
        },
    )
}

/// Check a concrete emulated run against CAA output bounds: given the CAA
/// result for a quantity, the plain-f64 reference value `ref_v` for the same
/// concrete input, and the emulated precision-k value `emu_v`, verify
/// `|emu - ref| <= δ̄·u` and, when applicable, `|emu - ref|/|ref| <= ε̄·u`.
/// The tiny `slack` covers the f64 reference's own roundoff (f64 is the
/// "ideal" stand-in; its error is ~2^-52 per op, negligible vs u >= 2^-23).
pub fn check_against_bounds(caa: &Caa, ref_v: f64, emu_v: f64, k: u32, slack: f64) -> Result<(), String> {
    let u = unit_roundoff(k);
    let err = (emu_v - ref_v).abs();
    let abs_limit = caa.abs_bound() * u * (1.0 + 1e-9) + slack;
    if caa.abs_bound().is_finite() && err > abs_limit {
        return Err(format!(
            "absolute error {err:.3e} exceeds δ̄·u = {:.3e} (δ̄ = {}, k = {k})",
            caa.abs_bound() * u,
            caa.abs_bound()
        ));
    }
    if caa.rel_bound().is_finite() && ref_v != 0.0 {
        let rel_err = err / ref_v.abs();
        let rel_limit = caa.rel_bound() * u * (1.0 + 1e-9) + slack / ref_v.abs();
        if rel_err > rel_limit {
            return Err(format!(
                "relative error {rel_err:.3e} exceeds ε̄·u = {:.3e} (ε̄ = {}, k = {k})",
                caa.rel_bound() * u,
                caa.rel_bound()
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    #[test]
    fn unit_roundoff_values() {
        assert_eq!(unit_roundoff(24), 2f64.powi(-23)); // binary32
        assert_eq!(unit_roundoff(53), 2f64.powi(-52)); // binary64
        assert_eq!(unit_roundoff(8), 2f64.powi(-7)); // the paper's Table I u
    }

    #[test]
    fn round_is_idempotent() {
        prop::check("roundk-idempotent", |rng| {
            let x = prop::gen_f64(rng);
            let k = 2 + rng.below(52) as u32;
            let r = round_to_precision(x, k);
            assert_eq!(round_to_precision(r, k), r, "x={x} k={k}");
        });
    }

    #[test]
    fn round_error_within_half_ulp() {
        prop::check("roundk-halfulp", |rng| {
            let x = prop::gen_f64(rng);
            if x == 0.0 {
                return;
            }
            let k = 4 + rng.below(50) as u32;
            let r = round_to_precision(x, k);
            let u = unit_roundoff(k);
            // |r - x| <= (u/2)|x| up to the next-power-of-2 boundary niceties:
            // use |x| (sound since |r-x| <= ulp(x)/2 <= u|x|/2 for normals).
            assert!(
                (r - x).abs() <= 0.5 * u * x.abs() * (1.0 + 1e-15),
                "x={x:e} k={k} r={r:e} err={:e} lim={:e}",
                (r - x).abs(),
                0.5 * u * x.abs()
            );
        });
    }

    #[test]
    fn round_monotone() {
        prop::check("roundk-monotone", |rng| {
            let a = prop::gen_f64(rng);
            let b = prop::gen_f64(rng);
            let k = 2 + rng.below(52) as u32;
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            assert!(round_to_precision(lo, k) <= round_to_precision(hi, k));
        });
    }

    #[test]
    fn known_values() {
        // 1 + 2^-k rounds to 1 (tie to even); 1 + 1.5*2^-k rounds up.
        for k in [8u32, 11, 24] {
            let u = unit_roundoff(k); // 2^(1-k); mantissa step at 1.0 is u
            assert_eq!(round_to_precision(1.0 + u / 4.0, k), 1.0);
            assert_eq!(round_to_precision(1.0 + 0.76 * u, k), 1.0 + u);
            // tie at exactly half a step: to even (stays 1.0)
            assert_eq!(round_to_precision(1.0 + u / 2.0, k), 1.0);
        }
    }

    #[test]
    fn carry_into_exponent() {
        // Just below a power of two, rounding up must carry cleanly.
        let k = 8;
        let x = 2.0 - 1e-12;
        let r = round_to_precision(x, k);
        assert_eq!(r, 2.0);
    }

    #[test]
    fn k53_is_identity() {
        prop::check("round53-id", |rng| {
            let x = prop::gen_f64(rng);
            assert_eq!(round_to_precision(x, 53), x);
        });
    }

    #[test]
    fn ties_to_even() {
        let k = 4; // mantissa: 1.xxx
        // 1.0625 = 1 + 1/16 is exactly between 1.000 and 1.125 (step 1/8):
        // kept LSB of 1.000 is even -> stays down; of 1.125 we test next tie.
        assert_eq!(round_to_precision(1.0625, k), 1.0);
        // 1.1875 = 1.125 + 1/16, between 1.125 (odd LSB) and 1.25 -> up.
        assert_eq!(round_to_precision(1.1875, k), 1.25);
    }

    #[test]
    fn emulated_ops_round_each_step() {
        let k = 8;
        let a = EmulatedFp::new(1.0, k);
        let b = EmulatedFp::new(3.0, k);
        let q = a.div(b);
        // 1/3 at 8 bits: error vs exact must be <= u/2 * |1/3|.
        assert!((q.v - 1.0 / 3.0).abs() <= 0.5 * unit_roundoff(k) / 3.0 * 1.0001);
        // And q.v must be representable at k bits.
        assert_eq!(round_to_precision(q.v, k), q.v);
    }
}
