//! Micro/macro benchmark harness (the registry snapshot has no criterion).
//!
//! Usage in a `harness = false` bench target:
//!
//! ```ignore
//! let mut b = bench::Bencher::new("table1");
//! b.bench("digits/analysis", || { ... });
//! b.report();
//! ```
//!
//! Each benchmark is warmed up, then timed over enough iterations to pass
//! a minimum measuring window; mean / p50 / p95 are reported. For
//! long-running experiment benches (whole-model analyses) use
//! [`Bencher::bench_once`], which times a single run.

use crate::util::Stopwatch;
use std::fmt::Write as _;
use std::time::Duration;

/// One benchmark's statistics.
#[derive(Clone, Debug)]
pub struct Stats {
    /// Benchmark name.
    pub name: String,
    /// Measured iterations.
    pub iters: usize,
    /// Mean iteration time.
    pub mean: Duration,
    /// Median iteration time.
    pub p50: Duration,
    /// 95th-percentile iteration time.
    pub p95: Duration,
}

/// Benchmark runner + result table.
pub struct Bencher {
    /// Group name printed over the result table.
    pub group: String,
    min_window: Duration,
    max_iters: usize,
    results: Vec<Stats>,
}

impl Bencher {
    /// A bencher with the default window (200 ms, up to 1000 iters).
    pub fn new(group: &str) -> Bencher {
        Bencher {
            group: group.to_string(),
            min_window: Duration::from_millis(200),
            max_iters: 1000,
            results: Vec::new(),
        }
    }

    /// Customize the measuring window (per benchmark).
    pub fn with_window(mut self, window: Duration, max_iters: usize) -> Bencher {
        self.min_window = window;
        self.max_iters = max_iters;
        self
    }

    /// Time `f` repeatedly; returns the recorded stats.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Stats {
        // Warmup.
        let _ = std::hint::black_box(f());
        let mut samples: Vec<Duration> = Vec::new();
        let window = Stopwatch::start();
        while window.elapsed() < self.min_window && samples.len() < self.max_iters {
            let sw = Stopwatch::start();
            let _ = std::hint::black_box(f());
            samples.push(sw.elapsed());
        }
        self.push_stats(name, samples)
    }

    /// Time a single execution (for expensive end-to-end runs).
    pub fn bench_once<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> (T, &Stats) {
        let sw = Stopwatch::start();
        let out = std::hint::black_box(f());
        let d = sw.elapsed();
        let stats = self.push_stats(name, vec![d]);
        (out, stats)
    }

    fn push_stats(&mut self, name: &str, mut samples: Vec<Duration>) -> &Stats {
        assert!(!samples.is_empty());
        samples.sort_unstable();
        let total: Duration = samples.iter().sum();
        let stats = Stats {
            name: name.to_string(),
            iters: samples.len(),
            mean: total / samples.len() as u32,
            p50: samples[samples.len() / 2],
            p95: samples[(samples.len() * 95 / 100).min(samples.len() - 1)],
        };
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Record an externally produced metric (e.g. a bound value) as a note.
    pub fn note(&mut self, text: &str) {
        println!("  [note] {text}");
    }

    /// Print the result table.
    pub fn report(&self) {
        println!("\n== bench group: {} ==", self.group);
        println!(
            "{:<44} {:>8} {:>12} {:>12} {:>12}",
            "benchmark", "iters", "mean", "p50", "p95"
        );
        for s in &self.results {
            println!(
                "{:<44} {:>8} {:>12} {:>12} {:>12}",
                s.name,
                s.iters,
                crate::util::timing::human_duration(s.mean),
                crate::util::timing::human_duration(s.p50),
                crate::util::timing::human_duration(s.p95)
            );
        }
    }

    /// Render the table to a string (for writing into EXPERIMENTS.md).
    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "| benchmark | iters | mean | p50 | p95 |");
        let _ = writeln!(s, "|---|---|---|---|---|");
        for r in &self.results {
            let _ = writeln!(
                s,
                "| {} | {} | {} | {} | {} |",
                r.name,
                r.iters,
                crate::util::timing::human_duration(r.mean),
                crate::util::timing::human_duration(r.p50),
                crate::util::timing::human_duration(r.p95)
            );
        }
        s
    }

    /// All statistics measured so far.
    pub fn results(&self) -> &[Stats] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut b = Bencher::new("test").with_window(Duration::from_millis(5), 50);
        b.bench("noop", || 1 + 1);
        let (v, stats) = b.bench_once("once", || 40 + 2);
        assert_eq!(v, 42);
        assert_eq!(stats.iters, 1);
        assert_eq!(b.results().len(), 2);
        let md = b.to_markdown();
        assert!(md.contains("noop") && md.contains("once"));
    }

    #[test]
    fn stats_ordering() {
        let mut b = Bencher::new("t").with_window(Duration::from_millis(5), 64);
        let s = b.bench("spin", || {
            std::hint::black_box((0..100).sum::<u64>())
        });
        assert!(s.p50 <= s.p95);
        assert!(s.iters >= 1);
    }
}
