//! Interval Arithmetic (IA) substrate.
//!
//! Replaces MPFI/MPFR/GMP from the original tool (see DESIGN.md
//! §Substitutions). Endpoints are `f64` and every operation rounds
//! *outwards*: the result of the `f64` round-to-nearest computation is
//! bumped by at least one ulp in each unsafe direction
//! ([`round::bump_down`], [`round::bump_up`]), so the returned interval is a
//! rigorous enclosure of the exact image set. Elementary functions (`exp`,
//! `ln`, `tanh`, `sigmoid`) use the platform libm, which is faithful to
//! within a couple of ulps; we bump those by [`round::ELEM_SLACK_ULPS`]
//! (documented, conservative) ulps.
//!
//! f64 endpoints make enclosures slightly wider than MPFI's arbitrary
//! precision, but the analysis consumes *bounds*, so wider is still sound —
//! and the flat value representation removes the per-operation heap
//! allocation that the paper itself identified as its MobileNet bottleneck.

mod arith;
mod elem;
pub mod round;

pub use round::{bump_down, bump_up};

/// A closed interval `[lo, hi]` with `lo <= hi`; endpoints may be infinite.
/// NaN endpoints are forbidden (checked in debug builds).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Interval {
    lo: f64,
    hi: f64,
}

impl Interval {
    /// The whole real line.
    pub const ENTIRE: Interval = Interval { lo: f64::NEG_INFINITY, hi: f64::INFINITY };
    /// The singleton `[0, 0]`.
    pub const ZERO: Interval = Interval { lo: 0.0, hi: 0.0 };
    /// The singleton `[1, 1]`.
    pub const ONE: Interval = Interval { lo: 1.0, hi: 1.0 };

    /// Construct from endpoints. Panics (debug) on NaN or `lo > hi`.
    pub fn new(lo: f64, hi: f64) -> Interval {
        debug_assert!(!lo.is_nan() && !hi.is_nan(), "NaN interval endpoint");
        debug_assert!(lo <= hi, "inverted interval [{lo}, {hi}]");
        Interval { lo, hi }
    }

    /// The singleton interval `[x, x]` (exact — `x` is representable).
    pub fn point(x: f64) -> Interval {
        Interval::new(x, x)
    }

    /// `[-r, r]`.
    pub fn symmetric(r: f64) -> Interval {
        debug_assert!(r >= 0.0);
        Interval::new(-r, r)
    }

    /// Lower endpoint.
    pub fn lo(&self) -> f64 {
        self.lo
    }

    /// Upper endpoint.
    pub fn hi(&self) -> f64 {
        self.hi
    }

    /// Width `hi - lo` (may be infinite).
    pub fn width(&self) -> f64 {
        if self.lo == self.hi {
            return 0.0;
        }
        // Round up: width used as an error radius must not shrink.
        bump_up(self.hi - self.lo, 1).max(0.0)
    }

    /// An (approximate) midpoint; always a finite member for finite
    /// intervals.
    pub fn mid(&self) -> f64 {
        if self.lo == f64::NEG_INFINITY && self.hi == f64::INFINITY {
            0.0
        } else if self.lo == f64::NEG_INFINITY {
            self.hi
        } else if self.hi == f64::INFINITY {
            self.lo
        } else {
            let m = 0.5 * (self.lo + self.hi);
            if m.is_finite() {
                m
            } else {
                0.5 * self.lo + 0.5 * self.hi
            }
        }
    }

    /// Magnitude `sup |x|`.
    pub fn mag(&self) -> f64 {
        self.lo.abs().max(self.hi.abs())
    }

    /// Mignitude `inf |x|` (0 if the interval straddles 0).
    pub fn mig(&self) -> f64 {
        if self.contains(0.0) {
            0.0
        } else {
            self.lo.abs().min(self.hi.abs())
        }
    }

    /// Whether `x` lies in the interval.
    pub fn contains(&self, x: f64) -> bool {
        self.lo <= x && x <= self.hi
    }

    /// Whether `other` lies entirely in the interval.
    pub fn contains_interval(&self, other: &Interval) -> bool {
        self.lo <= other.lo && other.hi <= self.hi
    }

    /// Whether both endpoints are finite.
    pub fn is_finite(&self) -> bool {
        self.lo.is_finite() && self.hi.is_finite()
    }

    /// Whether the interval is a single point.
    pub fn is_point(&self) -> bool {
        self.lo == self.hi
    }

    /// True iff every element is strictly positive.
    pub fn is_strictly_pos(&self) -> bool {
        self.lo > 0.0
    }

    /// True iff every element is strictly negative.
    pub fn is_strictly_neg(&self) -> bool {
        self.hi < 0.0
    }

    /// True iff 0 is not a member.
    pub fn excludes_zero(&self) -> bool {
        self.lo > 0.0 || self.hi < 0.0
    }

    /// Convex hull of two intervals.
    pub fn hull(&self, other: &Interval) -> Interval {
        Interval::new(self.lo.min(other.lo), self.hi.max(other.hi))
    }

    /// Intersection, or `None` if disjoint.
    pub fn intersect(&self, other: &Interval) -> Option<Interval> {
        let lo = self.lo.max(other.lo);
        let hi = self.hi.min(other.hi);
        if lo <= hi {
            Some(Interval::new(lo, hi))
        } else {
            None
        }
    }

    /// Widen both endpoints outward by `r >= 0` (rounded outward).
    pub fn inflate(&self, r: f64) -> Interval {
        debug_assert!(r >= 0.0);
        Interval::new(bump_down(self.lo - r, 1), bump_up(self.hi + r, 1))
    }
}

impl std::fmt::Display for Interval {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{:.6e}, {:.6e}]", self.lo, self.hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_and_predicates() {
        let i = Interval::new(-1.0, 2.0);
        assert!(i.contains(0.0) && i.contains(-1.0) && i.contains(2.0));
        assert!(!i.contains(2.0000001));
        assert!(!i.excludes_zero());
        assert!(Interval::new(0.5, 3.0).is_strictly_pos());
        assert!(Interval::new(-3.0, -0.5).is_strictly_neg());
        assert!(Interval::point(4.0).is_point());
        assert_eq!(i.mag(), 2.0);
        assert_eq!(i.mig(), 0.0);
        assert_eq!(Interval::new(1.0, 3.0).mig(), 1.0);
        assert_eq!(Interval::new(-3.0, -1.0).mig(), 1.0);
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn inverted_panics() {
        let _ = Interval::new(1.0, 0.0);
    }

    #[test]
    fn hull_and_intersect() {
        let a = Interval::new(0.0, 2.0);
        let b = Interval::new(1.0, 3.0);
        assert_eq!(a.hull(&b), Interval::new(0.0, 3.0));
        assert_eq!(a.intersect(&b), Some(Interval::new(1.0, 2.0)));
        let c = Interval::new(5.0, 6.0);
        assert_eq!(a.intersect(&c), None);
    }

    #[test]
    fn mid_is_member() {
        for (lo, hi) in [(-1.0, 2.0), (1e300, 1.7e308), (-1.7e308, 1.7e308)] {
            let i = Interval::new(lo, hi);
            let m = i.mid();
            assert!(m.is_finite());
            assert!(i.contains(m), "mid {m} outside {i}");
        }
        assert_eq!(Interval::ENTIRE.mid(), 0.0);
    }

    #[test]
    fn width_nonneg_and_outward() {
        let i = Interval::new(1.0, 1.0 + f64::EPSILON);
        assert!(i.width() >= f64::EPSILON);
        assert_eq!(Interval::point(3.0).width(), 0.0);
    }

    #[test]
    fn inflate_widens() {
        let i = Interval::new(-1.0, 1.0).inflate(0.5);
        assert!(i.lo <= -1.5 && i.hi >= 1.5);
    }
}
