//! Directed-rounding helpers.
//!
//! Rust gives no portable access to the FPU rounding mode, so outward
//! rounding is implemented by *ulp bumping*: a round-to-nearest result is at
//! most 0.5 ulp away from the exact value for the IEEE basic operations
//! (+, -, ×, /, √), so moving one ulp in the unsafe direction yields a
//! rigorous directed bound. Elementary libm functions (`exp`, `log`,
//! `tanh`, ...) are not correctly rounded but are faithful to within ~1-2
//! ulps on every libm we target; [`ELEM_SLACK_ULPS`] = 4 gives a documented
//! safety margin (glibc's published worst-case errors for these functions
//! are <= 2 ulps).

/// Ulp slack applied to libm elementary-function results.
pub const ELEM_SLACK_ULPS: u32 = 4;

/// Largest-magnitude finite f64.
const MAX: f64 = f64::MAX;

/// Move `x` down by `n` ulps (towards -inf).
///
/// `-inf` stays `-inf`. `+inf` maps to `MAX` after the first step: if a
/// round-to-nearest computation overflowed to `+inf`, the exact value is
/// `> MAX`, so `MAX` is a valid lower bound.
#[inline(always)]
pub fn bump_down(x: f64, n: u32) -> f64 {
    debug_assert!(!x.is_nan());
    let mut v = x;
    for _ in 0..n {
        if v == f64::NEG_INFINITY {
            return v;
        }
        v = if v == f64::INFINITY { MAX } else { v.next_down() };
    }
    v
}

/// Move `x` up by `n` ulps (towards +inf).
#[inline(always)]
pub fn bump_up(x: f64, n: u32) -> f64 {
    debug_assert!(!x.is_nan());
    let mut v = x;
    for _ in 0..n {
        if v == f64::INFINITY {
            return v;
        }
        v = if v == f64::NEG_INFINITY { -MAX } else { v.next_up() };
    }
    v
}

/// Lower bound for the exact value of an RN basic operation that returned
/// `x` (1 ulp down).
#[inline(always)]
pub fn rn_lo(x: f64) -> f64 {
    bump_down(x, 1)
}

/// Upper bound for the exact value of an RN basic operation that returned
/// `x` (1 ulp up).
#[inline(always)]
pub fn rn_hi(x: f64) -> f64 {
    bump_up(x, 1)
}

/// Lower bound for the exact value of a libm elementary function call.
pub fn elem_lo(x: f64) -> f64 {
    bump_down(x, ELEM_SLACK_ULPS)
}

/// Upper bound for the exact value of a libm elementary function call.
pub fn elem_hi(x: f64) -> f64 {
    bump_up(x, ELEM_SLACK_ULPS)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bump_brackets_value() {
        for x in [0.0, 1.0, -1.0, 1e-300, -1e300, f64::MIN_POSITIVE, 5e-324] {
            assert!(bump_down(x, 1) < x || x == f64::NEG_INFINITY);
            assert!(bump_up(x, 1) > x || x == f64::INFINITY);
            assert!(bump_down(x, 3) <= bump_down(x, 1));
            assert!(bump_up(x, 3) >= bump_up(x, 1));
        }
    }

    #[test]
    fn infinity_handling() {
        assert_eq!(bump_down(f64::INFINITY, 1), f64::MAX);
        assert_eq!(bump_up(f64::INFINITY, 1), f64::INFINITY);
        assert_eq!(bump_up(f64::NEG_INFINITY, 1), -f64::MAX);
        assert_eq!(bump_down(f64::NEG_INFINITY, 1), f64::NEG_INFINITY);
    }

    #[test]
    fn zero_crossing() {
        assert!(bump_down(0.0, 1) < 0.0);
        assert!(bump_up(0.0, 1) > 0.0);
        assert_eq!(bump_down(5e-324, 1), 0.0);
    }

    #[test]
    fn rn_bounds_tight_one_ulp() {
        // For RN +: exact a+b lies within [rn_lo, rn_hi] of the computed sum.
        let a = 0.1f64;
        let b = 0.2f64;
        let s = a + b; // not exactly 0.3
        assert!(rn_lo(s) < 0.1 + 0.2 && 0.1 + 0.2 < rn_hi(s) || s == a + b);
        assert!(rn_lo(s) <= s && s <= rn_hi(s));
    }
}
