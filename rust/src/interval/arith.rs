//! Arithmetic operations on [`Interval`], all outward-rounded.

use super::round::{rn_hi, rn_lo};
use super::Interval;
use std::ops::{Add, Div, Mul, Neg, Sub};

/// Was the f64 addition `a + b = s` exact? (2Sum error-term test.) When it
/// was, no outward bump is needed — this keeps point arithmetic on exactly
/// representable data (integers, max-subtracted logits, ...) point-tight.
fn add_exact(a: f64, b: f64, s: f64) -> bool {
    if !s.is_finite() {
        return false;
    }
    let bb = s - a;
    let err = (a - (s - bb)) + (b - bb);
    err == 0.0
}

/// Was the f64 multiplication `a * b = p` exact? (FMA residual test.)
fn mul_exact(a: f64, b: f64, p: f64) -> bool {
    p.is_finite() && a.mul_add(b, -p) == 0.0
}

/// Lower endpoint of an addition result, bumped only if inexact.
fn add_lo(a: f64, b: f64) -> f64 {
    let s = a + b;
    if add_exact(a, b, s) {
        s
    } else {
        rn_lo(s)
    }
}

/// Upper endpoint of an addition result, bumped only if inexact.
fn add_hi(a: f64, b: f64) -> f64 {
    let s = a + b;
    if add_exact(a, b, s) {
        s
    } else {
        rn_hi(s)
    }
}

impl Add for Interval {
    type Output = Interval;
    fn add(self, rhs: Interval) -> Interval {
        Interval::new(add_lo(self.lo, rhs.lo), add_hi(self.hi, rhs.hi))
    }
}

impl Sub for Interval {
    type Output = Interval;
    fn sub(self, rhs: Interval) -> Interval {
        Interval::new(add_lo(self.lo, -rhs.hi), add_hi(self.hi, -rhs.lo))
    }
}

impl Neg for Interval {
    type Output = Interval;
    fn neg(self) -> Interval {
        Interval::new(-self.hi, -self.lo)
    }
}

/// Product of two endpoint values for interval multiplication, with the IEEE
/// `0 * inf = NaN` case resolved to 0 (the exact image of `0 * anything` over
/// a closed set containing finite points is 0).
fn iprod(a: f64, b: f64) -> f64 {
    if a == 0.0 || b == 0.0 {
        0.0
    } else {
        a * b
    }
}

impl Mul for Interval {
    type Output = Interval;
    fn mul(self, rhs: Interval) -> Interval {
        let cands = [
            iprod(self.lo, rhs.lo),
            iprod(self.lo, rhs.hi),
            iprod(self.hi, rhs.lo),
            iprod(self.hi, rhs.hi),
        ];
        let args = [
            (self.lo, rhs.lo),
            (self.lo, rhs.hi),
            (self.hi, rhs.lo),
            (self.hi, rhs.hi),
        ];
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        let mut lo_args = args[0];
        let mut hi_args = args[0];
        for (c, a) in cands.iter().zip(args) {
            if *c < lo {
                lo = *c;
                lo_args = a;
            }
            if *c > hi {
                hi = *c;
                hi_args = a;
            }
        }
        let lo = if mul_exact(lo_args.0, lo_args.1, lo) { lo } else { rn_lo(lo) };
        let hi = if mul_exact(hi_args.0, hi_args.1, hi) { hi } else { rn_hi(hi) };
        Interval::new(lo, hi)
    }
}

impl Div for Interval {
    type Output = Interval;
    /// Division. If the divisor contains 0, the exact image is unbounded;
    /// we return [`Interval::ENTIRE`] (sound, maximally pessimistic), which
    /// is how "no relative bound exists" propagates through the CAA layer.
    fn div(self, rhs: Interval) -> Interval {
        if rhs.contains(0.0) {
            return Interval::ENTIRE;
        }
        let args = [
            (self.lo, rhs.lo),
            (self.lo, rhs.hi),
            (self.hi, rhs.lo),
            (self.hi, rhs.hi),
        ];
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        let mut lo_args = args[0];
        let mut hi_args = args[0];
        for (n, d) in args {
            // inf/inf -> NaN cannot occur: rhs excludes 0 hence is bounded
            // away from it, but rhs endpoints may be +-inf; a/inf = 0 is fine.
            let c = n / d;
            let c = if c.is_nan() { 0.0 } else { c };
            if c < lo {
                lo = c;
                lo_args = (n, d);
            }
            if c > hi {
                hi = c;
                hi_args = (n, d);
            }
        }
        // Exactness witness: q = n/d is exact iff fma(q, d, -n) == 0.
        let lo = if lo.is_finite() && lo.mul_add(lo_args.1, -lo_args.0) == 0.0 {
            lo
        } else {
            rn_lo(lo)
        };
        let hi = if hi.is_finite() && hi.mul_add(hi_args.1, -hi_args.0) == 0.0 {
            hi
        } else {
            rn_hi(hi)
        };
        Interval::new(lo, hi)
    }
}

impl Interval {
    /// Elementwise absolute value image.
    pub fn abs(&self) -> Interval {
        if self.lo >= 0.0 {
            *self
        } else if self.hi <= 0.0 {
            -*self
        } else {
            Interval::new(0.0, self.mag())
        }
    }

    /// Image of `x^2` (tighter than `self * self`, no decorrelation loss).
    pub fn square(&self) -> Interval {
        let a = self.abs();
        Interval::new(rn_lo(a.lo * a.lo).max(0.0), rn_hi(a.hi * a.hi))
    }

    /// Image of `max(x, y)` over both intervals.
    pub fn max_i(&self, other: &Interval) -> Interval {
        Interval::new(self.lo.max(other.lo), self.hi.max(other.hi))
    }

    /// Image of `min(x, y)` over both intervals.
    pub fn min_i(&self, other: &Interval) -> Interval {
        Interval::new(self.lo.min(other.lo), self.hi.min(other.hi))
    }

    /// Multiply by an exact scalar.
    pub fn scale(&self, c: f64) -> Interval {
        *self * Interval::point(c)
    }

    /// Add an exact scalar.
    pub fn shift(&self, c: f64) -> Interval {
        *self + Interval::point(c)
    }

    /// Reciprocal `1/x`; [`Interval::ENTIRE`] if 0 is contained.
    pub fn recip(&self) -> Interval {
        Interval::ONE / *self
    }

    /// Image of `sqrt(x)`. Negative parts of the operand are clipped (the
    /// caller guarantees the ideal operand is in-domain; the clipped
    /// enclosure is sound for the in-domain subset). Panics (debug) if the
    /// whole interval is negative.
    pub fn sqrt(&self) -> Interval {
        debug_assert!(self.hi >= 0.0, "sqrt of all-negative interval {self}");
        let lo = self.lo.max(0.0);
        Interval::new(rn_lo(lo.sqrt()).max(0.0), rn_hi(self.hi.sqrt()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sample(i: &Interval, r: &mut Rng) -> f64 {
        if i.is_point() {
            return i.lo();
        }
        let lo = i.lo().max(-1e300);
        let hi = i.hi().min(1e300);
        r.range(lo, hi)
    }

    /// Enclosure property: for random member points x in X, y in Y,
    /// x op y must be inside X op Y.
    #[test]
    fn enclosure_random_points() {
        let mut r = Rng::new(2024);
        for _ in 0..2_000 {
            let (a, b, c, d) = (
                r.range(-10.0, 10.0),
                r.range(-10.0, 10.0),
                r.range(-10.0, 10.0),
                r.range(-10.0, 10.0),
            );
            let x = Interval::new(a.min(b), a.max(b));
            let y = Interval::new(c.min(d), c.max(d));
            let px = sample(&x, &mut r);
            let py = sample(&y, &mut r);
            assert!((x + y).contains(px + py), "add");
            assert!((x - y).contains(px - py), "sub");
            assert!((x * y).contains(px * py), "mul");
            if y.excludes_zero() {
                assert!((x / y).contains(px / py), "div");
            }
            assert!(x.abs().contains(px.abs()), "abs");
            assert!(x.square().contains(px * px), "square");
            assert!(x.max_i(&y).contains(px.max(py)), "max");
            assert!(x.min_i(&y).contains(px.min(py)), "min");
            if x.hi() >= 0.0 && px >= 0.0 {
                assert!(x.sqrt().contains(px.sqrt()), "sqrt");
            }
        }
    }

    #[test]
    fn mul_sign_cases() {
        let pos = Interval::new(2.0, 3.0);
        let neg = Interval::new(-3.0, -2.0);
        let mix = Interval::new(-1.0, 4.0);
        assert!((pos * pos).contains(6.0));
        assert!((pos * neg).contains(-9.0) && (pos * neg).hi() <= bumped(-4.0));
        assert!((mix * pos).contains(-3.0) && (mix * pos).contains(12.0));
        assert!((neg * neg).contains(4.0) && (neg * neg).contains(9.0));
    }

    fn bumped(x: f64) -> f64 {
        crate::interval::round::bump_up(x, 2)
    }

    #[test]
    fn mul_with_infinite_endpoint() {
        let z = Interval::new(0.0, 1.0);
        let e = Interval::ENTIRE;
        let p = z * e;
        // 0 * ENTIRE must contain 0 and be well-formed (no NaN endpoints).
        assert!(p.contains(0.0));
        assert!(!p.lo().is_nan() && !p.hi().is_nan());
        let zz = Interval::ZERO * e;
        assert!(zz.contains(0.0));
    }

    #[test]
    fn div_by_zero_containing_is_entire() {
        let x = Interval::new(1.0, 2.0);
        let y = Interval::new(-1.0, 1.0);
        assert_eq!(x / y, Interval::ENTIRE);
        assert_eq!(x / Interval::ZERO, Interval::ENTIRE);
    }

    #[test]
    fn square_nonneg() {
        let m = Interval::new(-2.0, 1.0);
        let s = m.square();
        assert!(s.lo() >= 0.0);
        assert!(s.contains(4.0) && s.contains(0.0));
    }

    #[test]
    fn outward_rounding_strict() {
        // 0.1 + 0.2 in f64 is not 0.3; the interval sum of points must
        // contain the *exact* rational 0.3. The f64 literal 0.3 is *below*
        // exact 0.3, so lo <= f64(0.3) < exact 0.3 < hi certifies it.
        let s = Interval::point(0.1) + Interval::point(0.2);
        assert!(s.lo() <= 0.3 && 0.3 < s.hi());
        assert!(s.lo() < s.hi(), "inexact sum must widen");
    }

    #[test]
    fn exact_ops_stay_points() {
        // Exactly representable arithmetic must not widen (2Sum/FMA
        // exactness witnesses).
        let a = Interval::point(3.0);
        let b = Interval::point(4.0);
        assert!((a + b).is_point());
        assert!((a - b).is_point());
        assert!((a * b).is_point());
        assert!((Interval::point(1.0) / Interval::point(4.0)).is_point());
        assert_eq!((a + b).lo(), 7.0);
        assert_eq!((a * b).lo(), 12.0);
        assert_eq!((Interval::point(1.0) / Interval::point(4.0)).lo(), 0.25);
    }

    #[test]
    fn neg_reverses() {
        let i = Interval::new(-1.0, 5.0);
        assert_eq!(-i, Interval::new(-5.0, 1.0));
    }

    #[test]
    fn scale_shift() {
        let i = Interval::new(1.0, 2.0);
        assert!(i.scale(-3.0).contains(-6.0) && i.scale(-3.0).contains(-3.0));
        assert!(i.shift(10.0).contains(11.5));
    }

    #[test]
    fn recip() {
        let i = Interval::new(2.0, 4.0);
        let r = i.recip();
        assert!(r.contains(0.25) && r.contains(0.5));
        assert_eq!(Interval::new(-1.0, 1.0).recip(), Interval::ENTIRE);
    }
}
