//! Elementary functions on intervals: `exp`, `ln`, `log1p`, `expm1`,
//! `tanh`, `sigmoid`. All are monotone increasing, so the image is
//! `[f(lo), f(hi)]` widened by the libm slack ([`round::ELEM_SLACK_ULPS`]).
//! Range clamps (e.g. `tanh ⊂ [-1,1]`) are applied after widening — they
//! are mathematically exact so clamping preserves the enclosure.

use super::round::{elem_hi, elem_lo};
use super::Interval;

impl Interval {
    /// Image of `exp(x)`. Result is always `>= 0`. `exp(0) = 1` is treated
    /// exactly — this keeps the softmax pattern `e^{x - max(x)} <= 1` tight.
    pub fn exp(&self) -> Interval {
        let lo = if self.lo == f64::NEG_INFINITY {
            0.0
        } else if self.lo == 0.0 {
            1.0
        } else {
            elem_lo(self.lo.exp()).max(0.0)
        };
        let hi = if self.hi == f64::INFINITY {
            f64::INFINITY
        } else if self.hi == 0.0 {
            1.0
        } else {
            elem_hi(self.hi.exp())
        };
        Interval::new(lo, hi)
    }

    /// Image of `ln(x)` for the in-domain part of the operand. The operand
    /// must reach into `(0, inf)`; parts `<= 0` map the lower endpoint to
    /// `-inf` (sound for the in-domain subset).
    pub fn ln(&self) -> Interval {
        debug_assert!(self.hi > 0.0, "ln of non-positive interval {self}");
        let lo = if self.lo <= 0.0 {
            f64::NEG_INFINITY
        } else if self.lo == 1.0 {
            0.0
        } else {
            elem_lo(self.lo.ln())
        };
        let hi = if self.hi == f64::INFINITY {
            f64::INFINITY
        } else if self.hi == 1.0 {
            0.0
        } else {
            elem_hi(self.hi.ln())
        };
        Interval::new(lo, hi)
    }

    /// Image of `exp(x) - 1`, computed with `expm1` for accuracy near 0.
    /// Result is always `>= -1`.
    pub fn expm1(&self) -> Interval {
        let lo = if self.lo == f64::NEG_INFINITY {
            -1.0
        } else {
            elem_lo(self.lo.exp_m1()).max(-1.0)
        };
        let hi = if self.hi == f64::INFINITY {
            f64::INFINITY
        } else {
            elem_hi(self.hi.exp_m1())
        };
        Interval::new(lo, hi)
    }

    /// Image of `ln(1 + x)` for the in-domain part (`x > -1`).
    pub fn ln_1p(&self) -> Interval {
        debug_assert!(self.hi > -1.0, "ln_1p out of domain {self}");
        let lo = if self.lo <= -1.0 {
            f64::NEG_INFINITY
        } else {
            elem_lo(self.lo.ln_1p())
        };
        let hi = if self.hi == f64::INFINITY {
            f64::INFINITY
        } else {
            elem_hi(self.hi.ln_1p())
        };
        Interval::new(lo, hi)
    }

    /// Image of `tanh(x)`; clamped to `[-1, 1]`; `tanh(0) = 0` exact.
    pub fn tanh(&self) -> Interval {
        let lo = if self.lo == 0.0 { 0.0 } else { elem_lo(self.lo.tanh()).max(-1.0) };
        let hi = if self.hi == 0.0 { 0.0 } else { elem_hi(self.hi.tanh()).min(1.0) };
        Interval::new(lo, hi)
    }

    /// Image of the logistic sigmoid `1 / (1 + exp(-x))`; clamped to
    /// `[0, 1]`. Evaluated monotonically endpoint-wise (not via composed
    /// interval ops, which would decorrelate).
    pub fn sigmoid(&self) -> Interval {
        fn sig(x: f64) -> f64 {
            if x >= 0.0 {
                1.0 / (1.0 + (-x).exp())
            } else {
                let e = x.exp();
                e / (1.0 + e)
            }
        }
        // Two roundings (exp then add/div) => double slack is conservative.
        let lo = elem_lo(elem_lo(sig(self.lo))).max(0.0);
        let hi = elem_hi(elem_hi(sig(self.hi))).min(1.0);
        Interval::new(lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn exp_encloses_random_points() {
        let mut r = Rng::new(31);
        for _ in 0..2_000 {
            let a = r.range(-50.0, 50.0);
            let b = r.range(-50.0, 50.0);
            let i = Interval::new(a.min(b), a.max(b));
            let p = r.range(i.lo(), i.hi());
            assert!(i.exp().contains(p.exp()));
            assert!(i.tanh().contains(p.tanh()));
            if i.lo() > 0.0 {
                assert!(i.ln().contains(p.abs().max(i.lo()).ln()));
            }
        }
    }

    #[test]
    fn exp_nonneg_and_infinite_ends() {
        assert!(Interval::ENTIRE.exp().lo() >= 0.0);
        assert_eq!(Interval::ENTIRE.exp().hi(), f64::INFINITY);
        let big = Interval::new(0.0, 1000.0).exp();
        assert_eq!(big.hi(), f64::INFINITY); // overflow becomes +inf, sound
        assert!(big.lo() <= 1.0);
    }

    #[test]
    fn ln_domain_edges() {
        let i = Interval::new(0.0, 1.0).ln();
        assert_eq!(i.lo(), f64::NEG_INFINITY);
        assert!(i.hi() >= 0.0);
        let j = Interval::new(1.0, std::f64::consts::E).ln();
        assert!(j.contains(0.0) && j.contains(1.0));
    }

    #[test]
    fn tanh_clamped() {
        let i = Interval::new(-1e9, 1e9).tanh();
        assert!(i.lo() >= -1.0 && i.hi() <= 1.0);
        assert!(i.contains(-1.0 + 1e-15) && i.contains(1.0 - 1e-15));
        let z = Interval::ZERO.tanh();
        assert!(z.contains(0.0) && z.width() < 1e-14);
    }

    #[test]
    fn sigmoid_range_and_monotone() {
        let i = Interval::new(-100.0, 100.0).sigmoid();
        assert!(i.lo() >= 0.0 && i.hi() <= 1.0);
        let z = Interval::ZERO.sigmoid();
        assert!(z.contains(0.5));
        let mut r = Rng::new(77);
        for _ in 0..1_000 {
            let a = r.range(-30.0, 30.0);
            let b = r.range(-30.0, 30.0);
            let i = Interval::new(a.min(b), a.max(b));
            let p = r.range(i.lo(), i.hi());
            let s = 1.0 / (1.0 + (-p).exp());
            assert!(i.sigmoid().contains(s), "sigmoid({p}) = {s} not in {}", i.sigmoid());
        }
    }

    #[test]
    fn expm1_ln1p_inverse_ish() {
        let i = Interval::new(-0.5, 0.5);
        let fwd = i.expm1();
        assert!(fwd.contains(0.0));
        let back = fwd.ln_1p();
        assert!(back.contains_interval(&Interval::new(-0.49, 0.49)));
    }
}
