//! Unified observability: span tracing, latency histograms, and
//! error-bound telemetry, threaded through every execution layer.
//!
//! The engine's instruments used to be three disconnected counter
//! structs ([`PoolMetrics`], [`crate::serve::ServeMetrics`],
//! [`crate::fleet::FleetSnapshot`]). This module adds the missing
//! layers and unifies the reporting surface:
//!
//! * **Span tracing** — a lock-free, preallocated ring of spans
//!   ([`TraceSink`]) recorded at request, flush, plan-drive, wave, and
//!   step granularity. Per-request trace ids are minted at
//!   [`crate::serve::MicroBatcher::submit`] / [`crate::fleet::Fleet`]
//!   admission and carried on [`crate::serve::Ticket`]; step spans are
//!   tagged with the [`crate::plan::StepKind`] token, the
//!   [`crate::plan::KernelPath`], the batch size, and the tile/worker
//!   counts of the sharded executor. [`TraceSink::export`] renders
//!   Chrome-trace-compatible JSON (load it at `chrome://tracing` or
//!   [ui.perfetto.dev](https://ui.perfetto.dev)).
//! * **Metrics registry** — [`Registry`] holds fixed log-bucket atomic
//!   [`Histogram`]s (p50/p95/p99 for submit→resolve, queue wait, and
//!   per-step execute) plus pool-utilization gauges (drives, waves,
//!   busy workers per wave, helpers recruited by
//!   [`crate::coordinator::Pool::scope`]). [`Snapshot`] folds the
//!   registry and the three legacy counter structs into one text/JSON
//!   report (the legacy structs remain as compatibility shims).
//! * **Error-bound telemetry** — CAA passes can record a per-step
//!   [`BoundProfile`] (max absolute/relative bound width after each
//!   step) into the registry; `rigor profile` prints it next to
//!   wall-clock cost, making the paper's signature per-layer shape
//!   (convolutions widen relative error, well-conditioned activations
//!   re-contract it) directly observable.
//!
//! # Overhead contract
//!
//! Everything is gated by [`ObsPolicy`], default [`ObsPolicy::Disabled`]
//! (env `RIGOR_TRACE`, parsed by [`ObsPolicy::from_env_value`]). The
//! disabled path is **one relaxed atomic load and a branch** per
//! instrumentation site: no clock reads, no allocation, no stores. The
//! counting-allocator test in `tests/obs.rs` pins zero steady-state
//! allocations on the serve hot path, and `benches/perf_scaling`
//! section 10 enforces a ≤2% wall-clock ceiling for the instrumented
//! (disabled) drive against an uninstrumented step loop. At
//! [`ObsPolicy::Counters`] each site adds a monotonic clock read and a
//! handful of relaxed atomic increments; [`ObsPolicy::Full`]
//! additionally writes one span record into the preallocated ring —
//! still allocation-free after the first recorded span.

use crate::coordinator::PoolMetrics;
use crate::json::Value;
use crate::serve::ServeMetrics;
use std::ffi::OsStr;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

// ---------------------------------------------------------------------------
// Policy
// ---------------------------------------------------------------------------

/// How much the observability layer records — the tri-state sampling
/// gate every instrumentation site consults first.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ObsPolicy {
    /// Record nothing. The contract: one relaxed atomic load + branch
    /// per site, zero allocations, zero clock reads.
    Disabled,
    /// Latency histograms and utilization gauges, no spans.
    Counters,
    /// Counters plus span records in the [`TraceSink`] ring.
    Full,
}

impl ObsPolicy {
    /// Resolve the process-default policy from the `RIGOR_TRACE`
    /// environment variable (read once, then cached — see
    /// [`set_policy`] for the runtime override).
    pub fn from_env() -> ObsPolicy {
        ObsPolicy::from_env_value(std::env::var_os("RIGOR_TRACE").as_deref())
    }

    /// The testable core of [`ObsPolicy::from_env`] (same shape as
    /// `KernelPath::from_env_value` / `Parallelism::from_env_value`):
    /// `full`/`trace`/`2` → [`ObsPolicy::Full`], `counters`/`1` →
    /// [`ObsPolicy::Counters`], anything else — unset, empty, `0`,
    /// `off`, garbage — stays [`ObsPolicy::Disabled`].
    pub fn from_env_value(v: Option<&OsStr>) -> ObsPolicy {
        match v.and_then(OsStr::to_str).map(str::trim) {
            Some("full") | Some("trace") | Some("2") => ObsPolicy::Full,
            Some("counters") | Some("1") => ObsPolicy::Counters,
            _ => ObsPolicy::Disabled,
        }
    }

    /// Canonical token (`disabled` / `counters` / `full`).
    pub fn name(self) -> &'static str {
        match self {
            ObsPolicy::Disabled => "disabled",
            ObsPolicy::Counters => "counters",
            ObsPolicy::Full => "full",
        }
    }
}

impl std::str::FromStr for ObsPolicy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<ObsPolicy, Self::Err> {
        match s.trim() {
            "disabled" | "off" | "0" | "" => Ok(ObsPolicy::Disabled),
            "counters" | "1" => Ok(ObsPolicy::Counters),
            "full" | "trace" | "2" => Ok(ObsPolicy::Full),
            other => anyhow::bail!("unknown trace policy '{other}' (disabled|counters|full)"),
        }
    }
}

/// Sentinel: policy not yet resolved from the environment.
const POLICY_UNSET: u8 = u8::MAX;
static POLICY: AtomicU8 = AtomicU8::new(POLICY_UNSET);

/// The process-wide [`ObsPolicy`]. First call resolves `RIGOR_TRACE`;
/// after that it is exactly one relaxed atomic load.
#[inline]
pub fn policy() -> ObsPolicy {
    match POLICY.load(Ordering::Relaxed) {
        0 => ObsPolicy::Disabled,
        1 => ObsPolicy::Counters,
        2 => ObsPolicy::Full,
        _ => {
            let p = ObsPolicy::from_env();
            set_policy(p);
            p
        }
    }
}

/// Override the process-wide policy at runtime (tests, the `rigor
/// stats` command). Takes effect at the next instrumentation site.
pub fn set_policy(p: ObsPolicy) {
    POLICY.store(p as u8, Ordering::Relaxed);
}

/// `true` when counters (and possibly spans) are being recorded —
/// gates every clock read on the instrumented paths.
#[inline]
pub fn measuring() -> bool {
    policy() != ObsPolicy::Disabled
}

/// `true` when span records are being written to the ring.
#[inline]
pub fn tracing() -> bool {
    policy() == ObsPolicy::Full
}

/// Capture a timestamp for a site that will call one of the `*_done`
/// helpers — `None` (and no clock read) when observability is off.
#[inline]
pub fn mark() -> Option<Instant> {
    if measuring() {
        Some(Instant::now())
    } else {
        None
    }
}

// ---------------------------------------------------------------------------
// Trace ids, tags, clock
// ---------------------------------------------------------------------------

static NEXT_TRACE: AtomicU64 = AtomicU64::new(1);

/// Mint a per-request trace id (nonzero, process-unique) when tracing
/// is on; `0` — the "untraced" id — otherwise. Called at
/// `MicroBatcher::submit` / fleet admission; the id rides on
/// [`crate::serve::Ticket`] and tags the request span.
#[inline]
pub fn next_trace_id() -> u64 {
    if tracing() {
        NEXT_TRACE.fetch_add(1, Ordering::Relaxed)
    } else {
        0
    }
}

/// Interned span tags: spans store a `u16` index, export resolves it
/// back. Tags are `&'static str` (step-kind tokens, fixed site names),
/// so the table is tiny and append-only; interning happens only while
/// tracing is on, never on the disabled path.
static TAGS: Mutex<Vec<&'static str>> = Mutex::new(Vec::new());

fn intern(tag: &'static str) -> u16 {
    let mut tags = TAGS.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(i) = tags.iter().position(|t| *t == tag) {
        return i as u16;
    }
    if tags.len() >= u16::MAX as usize {
        return 0;
    }
    tags.push(tag);
    (tags.len() - 1) as u16
}

fn tag_name(i: u16) -> &'static str {
    let tags = TAGS.lock().unwrap_or_else(|e| e.into_inner());
    tags.get(i as usize).copied().unwrap_or("?")
}

/// All span timestamps are microseconds since this process-wide epoch
/// (first observed instant), keeping them small and export-friendly.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn us_since_epoch(t: Instant) -> u64 {
    t.saturating_duration_since(epoch()).as_micros() as u64
}

/// Small dense thread ids for the Chrome-trace `tid` field (std's
/// `ThreadId` has no stable integer form).
fn obs_tid() -> u32 {
    static NEXT_TID: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    }
    TID.with(|t| *t) as u32
}

// ---------------------------------------------------------------------------
// Span ring
// ---------------------------------------------------------------------------

/// Spans the ring holds before wrapping (latest-wins).
pub const TRACE_CAPACITY: usize = 16 * 1024;

/// Granularity of a recorded span.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// One request, submit → resolve (tagged with its trace id).
    Request,
    /// One micro-batch flush job (gather + drive + scatter).
    Flush,
    /// One plan drive (`execute_batch` / pooled wave schedule).
    Drive,
    /// One wave of the pooled scheduler.
    Wave,
    /// One plan step (serial, sharded-wide, or in-wave).
    Step,
}

impl SpanKind {
    fn code(self) -> u8 {
        match self {
            SpanKind::Request => 0,
            SpanKind::Flush => 1,
            SpanKind::Drive => 2,
            SpanKind::Wave => 3,
            SpanKind::Step => 4,
        }
    }

    fn from_code(c: u8) -> SpanKind {
        match c {
            0 => SpanKind::Request,
            1 => SpanKind::Flush,
            2 => SpanKind::Drive,
            3 => SpanKind::Wave,
            _ => SpanKind::Step,
        }
    }

    /// Chrome-trace category token.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Request => "request",
            SpanKind::Flush => "flush",
            SpanKind::Drive => "drive",
            SpanKind::Wave => "wave",
            SpanKind::Step => "step",
        }
    }
}

/// Kernel-path token carried on step spans.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum PathTag {
    None,
    Scalar,
    Blocked,
}

impl PathTag {
    fn name(self) -> &'static str {
        match self {
            PathTag::None => "-",
            PathTag::Scalar => "scalar",
            PathTag::Blocked => "blocked",
        }
    }
}

/// One exported span (a decoded ring record).
#[derive(Clone, Debug)]
pub struct Span {
    /// Granularity.
    pub kind: SpanKind,
    /// Site tag: step-kind token for steps, flush cause, etc.
    pub tag: &'static str,
    /// Kernel-path token for step spans (`-` elsewhere).
    pub path: &'static str,
    /// Trace id (`0` = not tied to one request).
    pub trace: u64,
    /// Microseconds since the process trace epoch.
    pub start_us: u64,
    /// Duration in microseconds.
    pub dur_us: u64,
    /// Batch size in flight (0 when not applicable).
    pub batch: u32,
    /// Kind-specific: tile count (step), wave width (wave), sample
    /// count (flush), step count (drive).
    pub a: u32,
    /// Kind-specific: busy workers (step/wave), wave index.
    pub b: u32,
    /// Recording thread (dense per-process id).
    pub tid: u32,
}

/// One ring slot. All fields are individual atomics so recording stays
/// safe code: the writer publishes with a release store of `seq` after
/// the payload stores. Two writers can only collide on a slot when one
/// laps the other by the full ring capacity mid-record; the collision
/// garbles one diagnostic span, never memory.
struct Slot {
    /// `1 + global index` of the occupying span; `0` = empty.
    seq: AtomicU64,
    trace: AtomicU64,
    start_us: AtomicU64,
    dur_us: AtomicU64,
    /// `kind | path << 8 | tag << 16 | tid << 32`.
    meta: AtomicU64,
    /// `batch | a << 32`.
    dims: AtomicU64,
    extra: AtomicU64,
}

struct Ring {
    slots: Box<[Slot]>,
    head: AtomicU64,
}

fn ring() -> &'static Ring {
    static RING: OnceLock<Ring> = OnceLock::new();
    RING.get_or_init(|| Ring {
        slots: (0..TRACE_CAPACITY)
            .map(|_| Slot {
                seq: AtomicU64::new(0),
                trace: AtomicU64::new(0),
                start_us: AtomicU64::new(0),
                dur_us: AtomicU64::new(0),
                meta: AtomicU64::new(0),
                dims: AtomicU64::new(0),
                extra: AtomicU64::new(0),
            })
            .collect(),
        head: AtomicU64::new(0),
    })
}

#[allow(clippy::too_many_arguments)]
fn record_span(
    kind: SpanKind,
    tag: &'static str,
    path: PathTag,
    trace: u64,
    start_us: u64,
    dur_us: u64,
    batch: u32,
    a: u32,
    b: u32,
) {
    let r = ring();
    let t = r.head.fetch_add(1, Ordering::Relaxed);
    let slot = &r.slots[(t % TRACE_CAPACITY as u64) as usize];
    let meta = kind.code() as u64
        | (path as u64) << 8
        | (intern(tag) as u64) << 16
        | (obs_tid() as u64) << 32;
    slot.seq.store(0, Ordering::Release); // in-flight: readers skip
    slot.trace.store(trace, Ordering::Relaxed);
    slot.start_us.store(start_us, Ordering::Relaxed);
    slot.dur_us.store(dur_us, Ordering::Relaxed);
    slot.meta.store(meta, Ordering::Relaxed);
    slot.dims.store(batch as u64 | (a as u64) << 32, Ordering::Relaxed);
    slot.extra.store(b, Ordering::Relaxed);
    slot.seq.store(t + 1, Ordering::Release);
}

/// The global span ring: a facade over the process-wide preallocated
/// buffer every instrumented site records into when the policy is
/// [`ObsPolicy::Full`].
pub struct TraceSink;

impl TraceSink {
    /// Spans currently in the ring, oldest first (the ring keeps the
    /// latest [`TRACE_CAPACITY`] records; earlier ones were
    /// overwritten). Slots mid-record are skipped.
    pub fn spans() -> Vec<Span> {
        let r = ring();
        let head = r.head.load(Ordering::Acquire);
        let mut out = Vec::new();
        for slot in r.slots.iter() {
            let seq = slot.seq.load(Ordering::Acquire);
            if seq == 0 || seq > head {
                continue;
            }
            let meta = slot.meta.load(Ordering::Relaxed);
            let dims = slot.dims.load(Ordering::Relaxed);
            let span = Span {
                kind: SpanKind::from_code((meta & 0xff) as u8),
                tag: tag_name(((meta >> 16) & 0xffff) as u16),
                path: match (meta >> 8) & 0xff {
                    1 => PathTag::Scalar.name(),
                    2 => PathTag::Blocked.name(),
                    _ => PathTag::None.name(),
                },
                trace: slot.trace.load(Ordering::Relaxed),
                start_us: slot.start_us.load(Ordering::Relaxed),
                dur_us: slot.dur_us.load(Ordering::Relaxed),
                batch: (dims & 0xffff_ffff) as u32,
                a: (dims >> 32) as u32,
                b: slot.extra.load(Ordering::Relaxed) as u32,
                tid: (meta >> 32) as u32,
            };
            if slot.seq.load(Ordering::Acquire) == seq {
                out.push(span);
            }
        }
        out.sort_by_key(|s| (s.start_us, std::cmp::Reverse(s.dur_us)));
        out
    }

    /// Total spans ever recorded (including ones the ring has since
    /// overwritten).
    pub fn recorded() -> u64 {
        ring().head.load(Ordering::Relaxed)
    }

    /// Render the ring as Chrome-trace JSON (`traceEvents` with
    /// complete `"ph": "X"` events). Nesting is by time containment per
    /// `tid`, so request → flush → drive → wave → step fall out of the
    /// recorded timestamps.
    pub fn export() -> String {
        let events = TraceSink::spans()
            .into_iter()
            .map(|s| {
                let mut args = vec![("trace", Value::from(s.trace as usize))];
                if s.batch > 0 {
                    args.push(("batch", Value::from(s.batch as usize)));
                }
                if s.a > 0 {
                    args.push(("a", Value::from(s.a as usize)));
                }
                if s.b > 0 {
                    args.push(("b", Value::from(s.b as usize)));
                }
                if s.path != "-" {
                    args.push(("path", Value::from(s.path)));
                }
                Value::obj(vec![
                    ("name", Value::from(s.tag)),
                    ("cat", Value::from(s.kind.name())),
                    ("ph", Value::from("X")),
                    ("ts", Value::from(s.start_us as usize)),
                    ("dur", Value::from(s.dur_us.max(1) as usize)),
                    ("pid", Value::from(1usize)),
                    ("tid", Value::from(s.tid as usize)),
                    ("args", Value::obj(args)),
                ])
            })
            .collect();
        crate::json::to_string_pretty(&Value::obj(vec![("traceEvents", Value::arr(events))]))
    }

    /// Drop every recorded span (start a fresh trace window).
    pub fn clear() {
        let r = ring();
        for slot in r.slots.iter() {
            slot.seq.store(0, Ordering::Release);
        }
        r.head.store(0, Ordering::Release);
    }
}

// ---------------------------------------------------------------------------
// Histograms
// ---------------------------------------------------------------------------

/// Log-bucket count: bucket `i` covers `[2^i, 2^(i+1))` nanoseconds,
/// so 48 buckets span 1 ns to ~78 hours.
pub const HISTO_BUCKETS: usize = 48;

/// A fixed log-bucket atomic latency histogram (nanoseconds). Recording
/// is two relaxed `fetch_add`s plus one bucket increment — lock-free
/// and allocation-free.
pub struct Histogram {
    count: AtomicU64,
    sum_ns: AtomicU64,
    buckets: [AtomicU64; HISTO_BUCKETS],
}

/// Decoded percentiles of one [`Histogram`]. Quantiles are bucket
/// upper edges (a ≤2x overestimate by construction — stable and cheap,
/// which is what a serving dashboard wants).
#[derive(Clone, Copy, Debug, Default)]
pub struct HistogramStats {
    /// Samples recorded.
    pub count: u64,
    /// Mean in nanoseconds.
    pub mean_ns: f64,
    /// 50th percentile (bucket upper edge), nanoseconds.
    pub p50_ns: u64,
    /// 95th percentile (bucket upper edge), nanoseconds.
    pub p95_ns: u64,
    /// 99th percentile (bucket upper edge), nanoseconds.
    pub p99_ns: u64,
}

impl Histogram {
    fn new() -> Histogram {
        Histogram {
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Record one latency observation.
    pub fn record(&self, ns: u64) {
        let bucket = (63 - (ns | 1).leading_zeros() as usize).min(HISTO_BUCKETS - 1);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Decode counts into mean and percentile estimates.
    pub fn stats(&self) -> HistogramStats {
        let count = self.count.load(Ordering::Relaxed);
        if count == 0 {
            return HistogramStats::default();
        }
        let sum = self.sum_ns.load(Ordering::Relaxed);
        let edge = |q: f64| -> u64 {
            let target = (q * count as f64).ceil() as u64;
            let mut seen = 0u64;
            for (i, b) in self.buckets.iter().enumerate() {
                seen += b.load(Ordering::Relaxed);
                if seen >= target {
                    return 1u64 << (i + 1);
                }
            }
            1u64 << HISTO_BUCKETS
        };
        HistogramStats {
            count,
            mean_ns: sum as f64 / count as f64,
            p50_ns: edge(0.50),
            p95_ns: edge(0.95),
            p99_ns: edge(0.99),
        }
    }

    fn reset(&self) {
        self.count.store(0, Ordering::Relaxed);
        self.sum_ns.store(0, Ordering::Relaxed);
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// One step of a CAA [`BoundProfile`]: the widest bound in the step's
/// output buffer, the per-layer quantity the paper plots.
#[derive(Clone, Debug)]
pub struct BoundStep {
    /// Step index in the plan.
    pub index: usize,
    /// Step-kind token (`conv2d`, `relu`, …).
    pub kind: &'static str,
    /// Output elements inspected.
    pub out_len: usize,
    /// Max absolute bound width after this step.
    pub abs_u: f64,
    /// Max relative bound width after this step.
    pub rel_u: f64,
    /// Wall-clock seconds of this step's CAA execution.
    pub secs: f64,
}

/// A per-step error-bound profile recorded during a CAA pass.
#[derive(Clone, Debug, Default)]
pub struct BoundProfile {
    /// Model the profiled plan was compiled from.
    pub model: String,
    /// One entry per plan step, in execution order.
    pub steps: Vec<BoundStep>,
}

/// The process-wide metrics registry: latency histograms plus
/// pool-utilization gauges, all atomics (recording never locks), plus
/// the last recorded [`BoundProfile`].
pub struct Registry {
    /// Submit → resolve latency of served requests.
    pub submit_to_resolve: Histogram,
    /// Time a sample waited in a micro-batch queue before its flush.
    pub queue_wait: Histogram,
    /// Wall-clock of individual plan-step executions.
    pub step_exec: Histogram,
    drives: AtomicU64,
    waves: AtomicU64,
    wave_busy: AtomicU64,
    helpers: AtomicU64,
    panics_caught: AtomicU64,
    deadline_missed: AtomicU64,
    nonfinite_inputs: AtomicU64,
    nonfinite_outputs: AtomicU64,
    degraded_entered: AtomicU64,
    quarantines: AtomicU64,
    tickets_dropped: AtomicU64,
    bounds: Mutex<Option<BoundProfile>>,
}

/// Utilization gauges decoded from the [`Registry`].
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    /// Plan drives observed.
    pub drives: u64,
    /// Scheduler waves executed (pooled drives only).
    pub waves: u64,
    /// Busy workers summed over waves (`/ waves` = mean utilization).
    pub wave_busy: u64,
    /// Helper jobs recruited by `Pool::scope` barriers.
    pub helpers: u64,
}

/// Fault-containment counters decoded from the [`Registry`].
///
/// These count *contained* faults: every increment corresponds to a failure
/// that was absorbed at a containment boundary instead of propagating.
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultStats {
    /// Drive panics caught at the `catch_unwind` boundary.
    pub panics_caught: u64,
    /// Tickets resolved as `DeadlineExceeded` instead of occupying a batch slot.
    pub deadline_missed: u64,
    /// Samples rejected at admission because an input value was non-finite.
    pub nonfinite_inputs: u64,
    /// Drives whose output tripped the batch-level finiteness check.
    pub nonfinite_outputs: u64,
    /// Queues that entered degraded (scalar/serial) mode.
    pub degraded_entered: u64,
    /// Queues quarantined after exceeding their fault budget.
    pub quarantines: u64,
    /// Scatters into a dropped [`crate::serve::Ticket`] (counted no-ops).
    pub tickets_dropped: u64,
}

/// The process-wide [`Registry`].
pub fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(|| Registry {
        submit_to_resolve: Histogram::new(),
        queue_wait: Histogram::new(),
        step_exec: Histogram::new(),
        drives: AtomicU64::new(0),
        waves: AtomicU64::new(0),
        wave_busy: AtomicU64::new(0),
        helpers: AtomicU64::new(0),
        panics_caught: AtomicU64::new(0),
        deadline_missed: AtomicU64::new(0),
        nonfinite_inputs: AtomicU64::new(0),
        nonfinite_outputs: AtomicU64::new(0),
        degraded_entered: AtomicU64::new(0),
        quarantines: AtomicU64::new(0),
        tickets_dropped: AtomicU64::new(0),
        bounds: Mutex::new(None),
    })
}

impl Registry {
    /// Decode the utilization gauges.
    pub fn exec_stats(&self) -> ExecStats {
        ExecStats {
            drives: self.drives.load(Ordering::Relaxed),
            waves: self.waves.load(Ordering::Relaxed),
            wave_busy: self.wave_busy.load(Ordering::Relaxed),
            helpers: self.helpers.load(Ordering::Relaxed),
        }
    }

    /// Decode the fault-containment counters.
    pub fn fault_stats(&self) -> FaultStats {
        FaultStats {
            panics_caught: self.panics_caught.load(Ordering::Relaxed),
            deadline_missed: self.deadline_missed.load(Ordering::Relaxed),
            nonfinite_inputs: self.nonfinite_inputs.load(Ordering::Relaxed),
            nonfinite_outputs: self.nonfinite_outputs.load(Ordering::Relaxed),
            degraded_entered: self.degraded_entered.load(Ordering::Relaxed),
            quarantines: self.quarantines.load(Ordering::Relaxed),
            tickets_dropped: self.tickets_dropped.load(Ordering::Relaxed),
        }
    }

    /// Store a CAA bound profile (kept until the next one; shown by
    /// [`Snapshot`] and `rigor profile`).
    pub fn record_bounds(&self, profile: BoundProfile) {
        *self.bounds.lock().unwrap_or_else(|e| e.into_inner()) = Some(profile);
    }

    /// The last recorded bound profile, if any.
    pub fn bounds(&self) -> Option<BoundProfile> {
        self.bounds.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Zero every histogram and gauge and drop the bound profile
    /// (tests and fresh measurement windows).
    pub fn reset(&self) {
        self.submit_to_resolve.reset();
        self.queue_wait.reset();
        self.step_exec.reset();
        self.drives.store(0, Ordering::Relaxed);
        self.waves.store(0, Ordering::Relaxed);
        self.wave_busy.store(0, Ordering::Relaxed);
        self.helpers.store(0, Ordering::Relaxed);
        self.panics_caught.store(0, Ordering::Relaxed);
        self.deadline_missed.store(0, Ordering::Relaxed);
        self.nonfinite_inputs.store(0, Ordering::Relaxed);
        self.nonfinite_outputs.store(0, Ordering::Relaxed);
        self.degraded_entered.store(0, Ordering::Relaxed);
        self.quarantines.store(0, Ordering::Relaxed);
        self.tickets_dropped.store(0, Ordering::Relaxed);
        *self.bounds.lock().unwrap_or_else(|e| e.into_inner()) = None;
    }
}

// ---------------------------------------------------------------------------
// Instrumentation sites
// ---------------------------------------------------------------------------

/// Convert a [`crate::plan::KernelPath`] to the span token.
fn path_tag(path: crate::plan::KernelPath) -> PathTag {
    match path {
        crate::plan::KernelPath::Scalar => PathTag::Scalar,
        crate::plan::KernelPath::Blocked => PathTag::Blocked,
    }
}

/// Close a step site opened with [`mark`]: step-execute histogram at
/// [`ObsPolicy::Counters`]+, a step span (kind token, kernel path,
/// batch, tiles, busy workers) at [`ObsPolicy::Full`].
#[inline]
pub fn step_done(
    t0: Option<Instant>,
    tag: &'static str,
    path: crate::plan::KernelPath,
    batch: usize,
    tiles: usize,
    busy: usize,
) {
    let Some(t0) = t0 else { return };
    let ns = t0.elapsed().as_nanos() as u64;
    registry().step_exec.record(ns);
    if tracing() {
        record_span(
            SpanKind::Step,
            tag,
            path_tag(path),
            0,
            us_since_epoch(t0),
            ns / 1_000,
            batch as u32,
            tiles as u32,
            busy as u32,
        );
    }
}

/// Close a plan-drive site (`tag` is `serial` or `pooled`; `steps` the
/// step count).
#[inline]
pub fn drive_done(t0: Option<Instant>, tag: &'static str, batch: usize, steps: usize) {
    let Some(t0) = t0 else { return };
    let reg = registry();
    reg.drives.fetch_add(1, Ordering::Relaxed);
    if tracing() {
        record_span(
            SpanKind::Drive,
            tag,
            PathTag::None,
            0,
            us_since_epoch(t0),
            t0.elapsed().as_micros() as u64,
            batch as u32,
            steps as u32,
            0,
        );
    }
}

/// Close a scheduler-wave site: wave/utilization gauges, plus a wave
/// span (`width` steps, `busy` workers, wave `index`) when tracing.
#[inline]
pub fn wave_done(t0: Option<Instant>, batch: usize, width: usize, busy: usize, index: usize) {
    let Some(t0) = t0 else { return };
    let reg = registry();
    reg.waves.fetch_add(1, Ordering::Relaxed);
    reg.wave_busy.fetch_add(busy as u64, Ordering::Relaxed);
    if tracing() {
        record_span(
            SpanKind::Wave,
            "wave",
            PathTag::None,
            0,
            us_since_epoch(t0),
            t0.elapsed().as_micros() as u64,
            batch as u32,
            width as u32,
            index as u32,
        );
    }
}

/// Close a micro-batch flush site (`trace` = first sample's id, so the
/// flush is findable from any of its requests).
#[inline]
pub fn flush_done(t0: Option<Instant>, tag: &'static str, trace: u64, samples: usize) {
    let Some(t0) = t0 else { return };
    if tracing() {
        record_span(
            SpanKind::Flush,
            tag,
            PathTag::None,
            trace,
            us_since_epoch(t0),
            t0.elapsed().as_micros() as u64,
            samples as u32,
            samples as u32,
            0,
        );
    }
}

/// A sample's flush began: record its queue wait (enqueue → flush).
#[inline]
pub fn queue_wait_done(enqueued: Instant) {
    if measuring() {
        registry().queue_wait.record(enqueued.elapsed().as_nanos() as u64);
    }
}

/// A request resolved: submit→resolve histogram plus (when tracing) the
/// request span covering enqueue → resolution, tagged with its trace id.
#[inline]
pub fn request_done(trace: u64, enqueued: Instant) {
    if !measuring() {
        return;
    }
    let ns = enqueued.elapsed().as_nanos() as u64;
    registry().submit_to_resolve.record(ns);
    if tracing() {
        record_span(
            SpanKind::Request,
            "request",
            PathTag::None,
            trace,
            us_since_epoch(enqueued),
            ns / 1_000,
            1,
            0,
            0,
        );
    }
}

/// `Pool::scope` recruited the calling thread as a helper worker.
#[inline]
pub fn helper_recruited() {
    if measuring() {
        registry().helpers.fetch_add(1, Ordering::Relaxed);
    }
}

// Fault counters are recorded unconditionally (no `measuring()` gate): they
// feed the containment report, every one of them sits on a cold failure path,
// and losing a fault because observability happened to be off would defeat
// the point. This is a deliberate exception to the zero-overhead contract.

/// A drive panic was caught at the `catch_unwind` boundary.
#[inline]
pub fn panic_caught() {
    registry().panics_caught.fetch_add(1, Ordering::Relaxed);
}

/// `n` tickets expired in the queue and resolved as `DeadlineExceeded`.
#[inline]
pub fn deadlines_missed(n: usize) {
    if n > 0 {
        registry().deadline_missed.fetch_add(n as u64, Ordering::Relaxed);
    }
}

/// A sample was rejected at admission for a non-finite input value.
#[inline]
pub fn nonfinite_input() {
    registry().nonfinite_inputs.fetch_add(1, Ordering::Relaxed);
}

/// A drive's output tripped the batch-level finiteness check.
#[inline]
pub fn nonfinite_output() {
    registry().nonfinite_outputs.fetch_add(1, Ordering::Relaxed);
}

/// A queue entered degraded (scalar/serial) mode after repeated faults.
#[inline]
pub fn degraded_entered() {
    registry().degraded_entered.fetch_add(1, Ordering::Relaxed);
}

/// A queue was quarantined after exceeding its fault budget.
#[inline]
pub fn quarantine_tripped() {
    registry().quarantines.fetch_add(1, Ordering::Relaxed);
}

/// A batch result was scattered into a dropped ticket (counted no-op).
#[inline]
pub fn ticket_dropped() {
    registry().tickets_dropped.fetch_add(1, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Unified snapshot
// ---------------------------------------------------------------------------

/// One micro-batch queue in a [`Snapshot`] — the legacy
/// [`ServeMetrics`] counters plus identity and live depth.
#[derive(Clone, Debug)]
pub struct QueueStat {
    /// Queue name (`model/format` for fleet queues, the model name for
    /// a standalone [`crate::serve::MicroBatcher`]).
    pub name: String,
    /// Samples currently pending.
    pub pending: usize,
    /// Lifetime counters.
    pub metrics: ServeMetrics,
}

/// Fleet-level counters in a [`Snapshot`].
#[derive(Clone, Copy, Debug, Default)]
pub struct FleetStat {
    /// Deployed models.
    pub models: usize,
    /// Pending samples across all queues.
    pub total_pending: usize,
    /// Hot swaps performed.
    pub swaps: usize,
    /// Admissions rejected.
    pub rejected: usize,
    /// Queues currently quarantined.
    pub quarantined: usize,
}

/// The unified observability snapshot: one structure (one text form,
/// one JSON form) that folds the coordinator pool, serve/fleet queues,
/// the registry's histograms and gauges, the trace ring state, and the
/// last bound profile. [`PoolMetrics`], [`ServeMetrics`], and
/// [`crate::fleet::FleetSnapshot`] remain available as shims; this is
/// the reporting surface.
#[derive(Debug, Default)]
pub struct Snapshot {
    /// Policy at capture time.
    pub policy_name: &'static str,
    /// Coordinator pool counters, when a pool is in scope.
    pub pool: Option<PoolMetrics>,
    /// Per-queue serve counters.
    pub queues: Vec<QueueStat>,
    /// Fleet-level counters, when captured from a fleet.
    pub fleet: Option<FleetStat>,
    /// Latency histograms, `(name, stats)`.
    pub latency: Vec<(&'static str, HistogramStats)>,
    /// Executor utilization gauges.
    pub exec: ExecStats,
    /// Fault-containment counters.
    pub faults: FaultStats,
    /// Spans recorded so far (ring keeps the last [`TRACE_CAPACITY`]).
    pub spans_recorded: u64,
    /// Last CAA bound profile, if one was recorded.
    pub bounds: Option<BoundProfile>,
}

impl Snapshot {
    /// Capture the registry-global parts (histograms, gauges, trace
    /// state, bound profile). Pool/queue/fleet sections are attached by
    /// the owning layer via the `with_*` builders.
    pub fn capture() -> Snapshot {
        let reg = registry();
        Snapshot {
            policy_name: policy().name(),
            pool: None,
            queues: Vec::new(),
            fleet: None,
            latency: vec![
                ("submit_to_resolve", reg.submit_to_resolve.stats()),
                ("queue_wait", reg.queue_wait.stats()),
                ("step_execute", reg.step_exec.stats()),
            ],
            exec: reg.exec_stats(),
            faults: reg.fault_stats(),
            spans_recorded: TraceSink::recorded(),
            bounds: reg.bounds(),
        }
    }

    /// Attach coordinator-pool counters.
    pub fn with_pool(mut self, m: PoolMetrics) -> Snapshot {
        self.pool = Some(m);
        self
    }

    /// Attach one micro-batch queue.
    pub fn with_queue(mut self, name: impl Into<String>, pending: usize, m: ServeMetrics) -> Snapshot {
        self.queues.push(QueueStat { name: name.into(), pending, metrics: m });
        self
    }

    /// Attach fleet-level counters.
    pub fn with_fleet(mut self, f: FleetStat) -> Snapshot {
        self.fleet = Some(f);
        self
    }

    /// Render the human-readable form (the `rigor stats` / `rigor
    /// fleet` output).
    pub fn to_text(&self) -> String {
        let mut s = String::new();
        let push = |s: &mut String, line: String| {
            s.push_str(&line);
            s.push('\n');
        };
        push(&mut s, format!("observability snapshot (policy: {})", self.policy_name));
        if let Some(p) = &self.pool {
            push(
                &mut s,
                format!(
                    "pool      workers={} submitted={} completed={} panicked={} high_water={}",
                    p.workers, p.submitted, p.completed, p.panicked, p.queue_high_water
                ),
            );
        }
        for q in &self.queues {
            let m = &q.metrics;
            push(
                &mut s,
                format!(
                    "queue {:<24} pending={} submitted={} batches={} full={} timer={} drain={} \
                     largest={} high_water={} deadlines={} faults={}",
                    q.name,
                    q.pending,
                    m.submitted,
                    m.batches,
                    m.flushed_full,
                    m.flushed_timer,
                    m.flushed_drain,
                    m.max_batch_observed,
                    m.queue_high_water,
                    m.deadline_missed,
                    m.drive_faults
                ),
            );
        }
        if let Some(f) = &self.fleet {
            push(
                &mut s,
                format!(
                    "fleet     models={} pending={} swaps={} rejected={} quarantined={}",
                    f.models, f.total_pending, f.swaps, f.rejected, f.quarantined
                ),
            );
        }
        push(
            &mut s,
            format!("{:<26} {:>8} {:>12} {:>10} {:>10} {:>10}", "latency", "count", "mean", "p50", "p95", "p99"),
        );
        for (name, h) in &self.latency {
            push(
                &mut s,
                format!(
                    "{:<26} {:>8} {:>12} {:>10} {:>10} {:>10}",
                    name,
                    h.count,
                    fmt_ns(h.mean_ns),
                    fmt_ns(h.p50_ns as f64),
                    fmt_ns(h.p95_ns as f64),
                    fmt_ns(h.p99_ns as f64)
                ),
            );
        }
        let e = &self.exec;
        let mean_busy =
            if e.waves > 0 { e.wave_busy as f64 / e.waves as f64 } else { 0.0 };
        push(
            &mut s,
            format!(
                "executor  drives={} waves={} mean_busy_workers={:.2} helpers_recruited={}",
                e.drives, e.waves, mean_busy, e.helpers
            ),
        );
        let f = &self.faults;
        push(
            &mut s,
            format!(
                "faults    panics={} deadlines={} nonfinite_in={} nonfinite_out={} degraded={} \
                 quarantined={} dropped_tickets={}",
                f.panics_caught,
                f.deadline_missed,
                f.nonfinite_inputs,
                f.nonfinite_outputs,
                f.degraded_entered,
                f.quarantines,
                f.tickets_dropped
            ),
        );
        push(
            &mut s,
            format!("trace     spans={} (ring capacity {})", self.spans_recorded, TRACE_CAPACITY),
        );
        if let Some(b) = &self.bounds {
            push(&mut s, format!("bounds    model={} ({} steps)", b.model, b.steps.len()));
            for st in &b.steps {
                push(
                    &mut s,
                    format!(
                        "  s{:<3} {:<18} abs_u={:<12.3e} rel_u={:<12.3e} {:>9.1}µs",
                        st.index,
                        st.kind,
                        st.abs_u,
                        st.rel_u,
                        st.secs * 1e6
                    ),
                );
            }
        }
        s
    }

    /// Render the machine-readable form.
    pub fn to_json(&self) -> Value {
        let histo = |h: &HistogramStats| {
            Value::obj(vec![
                ("count", Value::from(h.count as usize)),
                ("mean_ns", Value::from(h.mean_ns)),
                ("p50_ns", Value::from(h.p50_ns as usize)),
                ("p95_ns", Value::from(h.p95_ns as usize)),
                ("p99_ns", Value::from(h.p99_ns as usize)),
            ])
        };
        let mut fields = vec![("policy", Value::from(self.policy_name))];
        if let Some(p) = &self.pool {
            fields.push((
                "pool",
                Value::obj(vec![
                    ("workers", Value::from(p.workers)),
                    ("submitted", Value::from(p.submitted)),
                    ("completed", Value::from(p.completed)),
                    ("panicked", Value::from(p.panicked)),
                    ("queue_high_water", Value::from(p.queue_high_water)),
                ]),
            ));
        }
        fields.push((
            "queues",
            Value::arr(
                self.queues
                    .iter()
                    .map(|q| {
                        let m = &q.metrics;
                        Value::obj(vec![
                            ("name", Value::from(q.name.as_str())),
                            ("pending", Value::from(q.pending)),
                            ("submitted", Value::from(m.submitted)),
                            ("batches", Value::from(m.batches)),
                            ("flushed_full", Value::from(m.flushed_full)),
                            ("flushed_timer", Value::from(m.flushed_timer)),
                            ("flushed_drain", Value::from(m.flushed_drain)),
                            ("max_batch_observed", Value::from(m.max_batch_observed)),
                            ("queue_high_water", Value::from(m.queue_high_water)),
                            ("deadline_missed", Value::from(m.deadline_missed)),
                            ("drive_faults", Value::from(m.drive_faults)),
                        ])
                    })
                    .collect(),
            ),
        ));
        if let Some(f) = &self.fleet {
            fields.push((
                "fleet",
                Value::obj(vec![
                    ("models", Value::from(f.models)),
                    ("total_pending", Value::from(f.total_pending)),
                    ("swaps", Value::from(f.swaps)),
                    ("rejected", Value::from(f.rejected)),
                    ("quarantined", Value::from(f.quarantined)),
                ]),
            ));
        }
        fields.push((
            "latency",
            Value::obj(self.latency.iter().map(|(n, h)| (*n, histo(h))).collect()),
        ));
        fields.push((
            "executor",
            Value::obj(vec![
                ("drives", Value::from(self.exec.drives as usize)),
                ("waves", Value::from(self.exec.waves as usize)),
                ("wave_busy", Value::from(self.exec.wave_busy as usize)),
                ("helpers_recruited", Value::from(self.exec.helpers as usize)),
            ]),
        ));
        let fa = &self.faults;
        fields.push((
            "faults",
            Value::obj(vec![
                ("panics_caught", Value::from(fa.panics_caught as usize)),
                ("deadline_missed", Value::from(fa.deadline_missed as usize)),
                ("nonfinite_inputs", Value::from(fa.nonfinite_inputs as usize)),
                ("nonfinite_outputs", Value::from(fa.nonfinite_outputs as usize)),
                ("degraded_entered", Value::from(fa.degraded_entered as usize)),
                ("quarantines", Value::from(fa.quarantines as usize)),
                ("tickets_dropped", Value::from(fa.tickets_dropped as usize)),
            ]),
        ));
        fields.push(("spans_recorded", Value::from(self.spans_recorded as usize)));
        if let Some(b) = &self.bounds {
            fields.push((
                "bounds",
                Value::obj(vec![
                    ("model", Value::from(b.model.as_str())),
                    (
                        "steps",
                        Value::arr(
                            b.steps
                                .iter()
                                .map(|st| {
                                    Value::obj(vec![
                                        ("index", Value::from(st.index)),
                                        ("kind", Value::from(st.kind)),
                                        ("out_len", Value::from(st.out_len)),
                                        ("abs_u", Value::from(st.abs_u)),
                                        ("rel_u", Value::from(st.rel_u)),
                                        ("secs", Value::from(st.secs)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ]),
            ));
        }
        Value::obj(fields)
    }
}

/// Render nanoseconds with an adaptive unit (`ns`/`µs`/`ms`/`s`).
fn fmt_ns(ns: f64) -> String {
    if ns <= 0.0 {
        "0".to_string()
    } else if ns < 1_000.0 {
        format!("{ns:.0}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.1}µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2}ms", ns / 1_000_000.0)
    } else {
        format!("{:.2}s", ns / 1_000_000_000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::ffi::OsString;

    /// Policy-mutating tests share this lock (the policy is process
    /// state; the suite runs tests concurrently).
    pub(crate) fn policy_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn env_value_parser_matches_the_documented_grammar() {
        let p = |s: Option<&str>| ObsPolicy::from_env_value(s.map(OsStr::new).as_deref());
        assert_eq!(p(None), ObsPolicy::Disabled);
        assert_eq!(p(Some("")), ObsPolicy::Disabled);
        assert_eq!(p(Some("0")), ObsPolicy::Disabled);
        assert_eq!(p(Some("off")), ObsPolicy::Disabled);
        assert_eq!(p(Some("garbage")), ObsPolicy::Disabled);
        assert_eq!(p(Some("counters")), ObsPolicy::Counters);
        assert_eq!(p(Some("1")), ObsPolicy::Counters);
        assert_eq!(p(Some("full")), ObsPolicy::Full);
        assert_eq!(p(Some("trace")), ObsPolicy::Full);
        assert_eq!(p(Some("2")), ObsPolicy::Full);
        assert_eq!(p(Some(" full ")), ObsPolicy::Full);
        // Non-UTF-8 degrades to Disabled, like the other env parsers.
        #[cfg(unix)]
        {
            use std::os::unix::ffi::OsStringExt;
            let bad = OsString::from_vec(vec![0xff, 0xfe]);
            assert_eq!(ObsPolicy::from_env_value(Some(&bad)), ObsPolicy::Disabled);
        }
    }

    #[test]
    fn policy_round_trips_through_fromstr_and_name() {
        for p in [ObsPolicy::Disabled, ObsPolicy::Counters, ObsPolicy::Full] {
            assert_eq!(p.name().parse::<ObsPolicy>().unwrap(), p);
        }
        assert!("bogus".parse::<ObsPolicy>().is_err());
    }

    #[test]
    fn disabled_mints_zero_trace_ids_and_skips_marks() {
        let _g = policy_lock();
        let prev = policy();
        set_policy(ObsPolicy::Disabled);
        assert_eq!(next_trace_id(), 0);
        assert!(mark().is_none());
        set_policy(ObsPolicy::Full);
        let a = next_trace_id();
        let b = next_trace_id();
        assert!(a > 0 && b > a);
        assert!(mark().is_some());
        set_policy(prev);
    }

    #[test]
    fn histogram_percentiles_are_log_bucket_upper_edges() {
        let h = Histogram::new();
        for _ in 0..99 {
            h.record(100); // bucket 6 ([64, 128)), edge 128
        }
        h.record(1_000_000); // bucket 19, edge 2^20
        let s = h.stats();
        assert_eq!(s.count, 100);
        assert_eq!(s.p50_ns, 128);
        assert_eq!(s.p95_ns, 128);
        assert_eq!(s.p99_ns, 128);
        assert!((s.mean_ns - (99.0 * 100.0 + 1e6) / 100.0).abs() < 1e-9);
        let full = Histogram::new();
        full.record(1_000_000);
        assert_eq!(full.stats().p50_ns, 1 << 20);
        full.reset();
        assert_eq!(full.stats().count, 0);
    }

    #[test]
    fn spans_record_and_export_as_chrome_trace() {
        let _g = policy_lock();
        let prev = policy();
        set_policy(ObsPolicy::Full);
        TraceSink::clear();
        let t0 = mark();
        std::thread::sleep(std::time::Duration::from_micros(50));
        step_done(t0, "conv2d", crate::plan::KernelPath::Blocked, 8, 12, 4);
        flush_done(mark(), "flush", 7, 8);
        let spans = TraceSink::spans();
        assert!(spans.iter().any(|s| s.kind == SpanKind::Step
            && s.tag == "conv2d"
            && s.path == "blocked"
            && s.batch == 8
            && s.a == 12
            && s.b == 4));
        assert!(spans.iter().any(|s| s.kind == SpanKind::Flush && s.trace == 7));
        let json = TraceSink::export();
        let v = crate::json::parse(&json).expect("export parses");
        let events = v.get("traceEvents").unwrap().as_array().unwrap();
        assert!(!events.is_empty());
        assert!(events.iter().all(|e| e.get("ph").unwrap().as_str() == Some("X")));
        TraceSink::clear();
        assert_eq!(TraceSink::spans().len(), 0);
        set_policy(prev);
    }

    #[test]
    fn ring_wraps_without_growing() {
        let _g = policy_lock();
        let prev = policy();
        set_policy(ObsPolicy::Full);
        TraceSink::clear();
        for _ in 0..(TRACE_CAPACITY + 100) {
            flush_done(mark(), "wrap", 0, 1);
        }
        assert!(TraceSink::recorded() >= (TRACE_CAPACITY + 100) as u64);
        assert!(TraceSink::spans().len() <= TRACE_CAPACITY);
        TraceSink::clear();
        set_policy(prev);
    }

    #[test]
    fn snapshot_renders_text_and_json() {
        let _g = policy_lock();
        let snap = Snapshot::capture()
            .with_pool(PoolMetrics {
                submitted: 10,
                completed: 10,
                panicked: 0,
                queue_high_water: 3,
                workers: 4,
            })
            .with_queue("digits/f64", 0, ServeMetrics::default())
            .with_fleet(FleetStat {
                models: 1,
                total_pending: 0,
                swaps: 0,
                rejected: 2,
                quarantined: 1,
            });
        let text = snap.to_text();
        assert!(text.contains("pool      workers=4"));
        assert!(text.contains("queue digits/f64"));
        assert!(text.contains("rejected=2"));
        assert!(text.contains("quarantined=1"));
        assert!(text.contains("faults    panics="));
        assert!(text.contains("latency"));
        let v = snap.to_json();
        assert_eq!(v.path(&["pool", "workers"]).unwrap().as_usize(), Some(4));
        assert_eq!(v.path(&["fleet", "rejected"]).unwrap().as_usize(), Some(2));
        assert_eq!(v.path(&["fleet", "quarantined"]).unwrap().as_usize(), Some(1));
        assert!(v.path(&["faults", "panics_caught"]).is_some());
        assert!(v.get("latency").is_some());
    }
}
