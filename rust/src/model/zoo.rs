//! Builder zoo: small randomly-initialized models with the topologies the
//! paper evaluates, used by unit tests, property tests and ablation benches
//! (the *trained* models come from `python/compile/aot.py` via JSON).

use crate::layers::{Layer, Padding};
use crate::model::{Graph, Model};
use crate::tensor::Tensor;
use crate::util::Rng;
use std::sync::Arc;

fn glorot(rng: &mut Rng, fan_in: usize, fan_out: usize, n: usize) -> Vec<f64> {
    let limit = (6.0 / (fan_in + fan_out) as f64).sqrt();
    (0..n).map(|_| rng.range(-limit, limit)).collect()
}

/// Dense layer with Glorot-uniform weights.
pub fn dense(rng: &mut Rng, input: usize, units: usize) -> Layer {
    Layer::Dense {
        w: Arc::new(Tensor::new(vec![units, input], glorot(rng, input, units, units * input))),
        b: (0..units).map(|_| rng.range(-0.05, 0.05)).collect(),
    }
}

/// Conv2D layer with Glorot-uniform weights.
pub fn conv2d(
    rng: &mut Rng,
    kh: usize,
    kw: usize,
    cin: usize,
    cout: usize,
    stride: usize,
    padding: Padding,
) -> Layer {
    let n = kh * kw * cin * cout;
    Layer::Conv2D {
        kernel: Arc::new(Tensor::new(vec![kh, kw, cin, cout], glorot(rng, kh * kw * cin, cout, n))),
        bias: (0..cout).map(|_| rng.range(-0.05, 0.05)).collect(),
        stride,
        padding,
    }
}

/// Depthwise Conv2D layer.
pub fn depthwise(rng: &mut Rng, kh: usize, kw: usize, c: usize, stride: usize, padding: Padding) -> Layer {
    let n = kh * kw * c;
    Layer::DepthwiseConv2D {
        kernel: Arc::new(Tensor::new(vec![kh, kw, c], glorot(rng, kh * kw, 1, n))),
        bias: (0..c).map(|_| rng.range(-0.05, 0.05)).collect(),
        stride,
        padding,
    }
}

/// BatchNorm with benign random statistics.
pub fn batch_norm(rng: &mut Rng, c: usize) -> Layer {
    Layer::BatchNorm {
        gamma: (0..c).map(|_| rng.range(0.5, 1.5)).collect(),
        beta: (0..c).map(|_| rng.range(-0.2, 0.2)).collect(),
        mean: (0..c).map(|_| rng.range(-0.3, 0.3)).collect(),
        variance: (0..c).map(|_| rng.range(0.2, 2.0)).collect(),
        eps: 1e-3,
    }
}

/// A 3-dense MLP classifier: `[8] -> 6 -> 4 -> 3` with ReLU + Softmax —
/// the Digits topology in miniature.
pub fn tiny_mlp(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    Model {
        name: "tiny_mlp".into(),
        input_shape: vec![8],
        layers: vec![
            dense(&mut rng, 8, 6),
            Layer::Relu,
            dense(&mut rng, 6, 4),
            Layer::Relu,
            dense(&mut rng, 4, 3),
            Layer::Softmax,
        ],
        graph: None,
    }
}

/// A small CNN: conv/batchnorm/relu, depthwise stage, pooling, dense,
/// softmax — the MobileNet layer mix in miniature (`[6,6,1]` input).
pub fn tiny_cnn(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    Model {
        name: "tiny_cnn".into(),
        input_shape: vec![6, 6, 1],
        layers: vec![
            conv2d(&mut rng, 3, 3, 1, 4, 1, Padding::Same),
            batch_norm(&mut rng, 4),
            Layer::Relu,
            depthwise(&mut rng, 3, 3, 4, 1, Padding::Same),
            Layer::Relu,
            Layer::MaxPool2D { ph: 2, pw: 2 },
            Layer::Flatten,
            dense(&mut rng, 3 * 3 * 4, 5),
            Layer::Softmax,
        ],
        graph: None,
    }
}

/// A small CNN with an average-pooling head in place of [`tiny_cnn`]'s max
/// pool — the summation pooling path (conv/batchnorm/relu, depthwise
/// stage, `AvgPool2D`, dense, softmax; `[6,6,1]` input).
pub fn avgpool_cnn(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    Model {
        name: "avgpool_cnn".into(),
        input_shape: vec![6, 6, 1],
        layers: vec![
            conv2d(&mut rng, 3, 3, 1, 4, 1, Padding::Same),
            batch_norm(&mut rng, 4),
            Layer::Relu,
            depthwise(&mut rng, 3, 3, 4, 1, Padding::Same),
            Layer::Relu,
            Layer::AvgPool2D { ph: 2, pw: 2 },
            Layer::Flatten,
            dense(&mut rng, 3 * 3 * 4, 5),
            Layer::Softmax,
        ],
        graph: None,
    }
}

/// The Pendulum topology (paper: two Dense layers, two tanh activations):
/// `[2] -> Dense -> tanh -> Dense[1] -> tanh`.
pub fn tiny_pendulum(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    Model {
        name: "tiny_pendulum".into(),
        input_shape: vec![2],
        layers: vec![
            dense(&mut rng, 2, 8),
            Layer::Tanh,
            dense(&mut rng, 8, 1),
            Layer::Tanh,
        ],
        graph: None,
    }
}

/// An MLP with configurable hidden width (perf-scaling experiments).
pub fn scaled_mlp(seed: u64, input: usize, hidden: usize, classes: usize) -> Model {
    let mut rng = Rng::new(seed);
    Model {
        name: format!("mlp_{input}_{hidden}_{classes}"),
        input_shape: vec![input],
        layers: vec![
            dense(&mut rng, input, hidden),
            Layer::Relu,
            dense(&mut rng, hidden, hidden),
            Layer::Relu,
            dense(&mut rng, hidden, classes),
            Layer::Softmax,
        ],
        graph: None,
    }
}

/// Per-layer node names and inbound lists for graph builders.
fn wires(names: &[&str], inbound: &[&[&str]], output: &str) -> Graph {
    Graph {
        names: names.iter().map(|s| s.to_string()).collect(),
        inbound: inbound
            .iter()
            .map(|ins| ins.iter().map(|s| s.to_string()).collect())
            .collect(),
        output: Some(output.to_string()),
    }
}

/// A residual (skip-connection) MLP — the smallest graph-topology model:
/// `[8] -> Dense+ReLU -> Dense -> Add(·, skip) -> ReLU -> Dense[3] ->
/// Softmax`, where the skip connection feeds the first block's activation
/// straight into the merge.
pub fn residual_mlp(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    Model {
        name: "residual_mlp".into(),
        input_shape: vec![8],
        layers: vec![
            dense(&mut rng, 8, 8),  // d1
            Layer::Relu,            // a1 (skip source)
            dense(&mut rng, 8, 8),  // d2
            Layer::Add,             // add1 = d2 + a1
            Layer::Relu,            // a2
            dense(&mut rng, 8, 3),  // d3
            Layer::Softmax,         // out
        ],
        graph: Some(wires(
            &["d1", "a1", "d2", "add1", "a2", "d3", "out"],
            &[
                &["input"],
                &["d1"],
                &["a1"],
                &["d2", "a1"],
                &["add1"],
                &["a2"],
                &["d3"],
            ],
            "out",
        )),
    }
}

/// A mini residual convnet exercising both merge ops: a conv/batch-norm
/// stem, one additive residual block, an inception-style two-branch
/// (1x1 conv ++ 3x3 conv) `Concat`, then pool/flatten/dense/softmax
/// (`[6,6,1]` input, 5 classes).
pub fn residual_cnn(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    Model {
        name: "residual_cnn".into(),
        input_shape: vec![6, 6, 1],
        layers: vec![
            conv2d(&mut rng, 3, 3, 1, 4, 1, Padding::Same), // c1
            batch_norm(&mut rng, 4),                        // b1
            Layer::Relu,                                    // r1 (skip source)
            conv2d(&mut rng, 3, 3, 4, 4, 1, Padding::Same), // c2
            Layer::Add,                                     // add1 = c2 + r1
            Layer::Relu,                                    // r2
            conv2d(&mut rng, 1, 1, 4, 2, 1, Padding::Same), // c3 (1x1 branch)
            conv2d(&mut rng, 3, 3, 4, 2, 1, Padding::Same), // c4 (3x3 branch)
            Layer::Concat,                                  // cat1 = c3 ++ c4
            Layer::Relu,                                    // r3
            Layer::MaxPool2D { ph: 2, pw: 2 },              // p1
            Layer::Flatten,                                 // f1
            dense(&mut rng, 3 * 3 * 4, 5),                  // d1
            Layer::Softmax,                                 // out
        ],
        graph: Some(wires(
            &[
                "c1", "b1", "r1", "c2", "add1", "r2", "c3", "c4", "cat1", "r3", "p1",
                "f1", "d1", "out",
            ],
            &[
                &["input"],
                &["c1"],
                &["b1"],
                &["r1"],
                &["c2", "r1"],
                &["add1"],
                &["r2"],
                &["r2"],
                &["c3", "c4"],
                &["cat1"],
                &["r3"],
                &["p1"],
                &["f1"],
                &["d1"],
            ],
            "out",
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_models_are_consistent() {
        for m in [
            tiny_mlp(1),
            tiny_cnn(2),
            avgpool_cnn(7),
            tiny_pendulum(3),
            scaled_mlp(4, 16, 32, 5),
            residual_mlp(5),
            residual_cnn(6),
        ] {
            let out = m.output_shape().expect("valid stack");
            assert!(!out.is_empty());
            assert!(m.param_count() > 0);
        }
    }

    #[test]
    fn residual_zoo_shapes() {
        assert_eq!(residual_mlp(1).output_shape().unwrap(), vec![3]);
        assert_eq!(residual_cnn(2).output_shape().unwrap(), vec![5]);
        // The concat joins a 2-channel and a 2-channel branch into 4.
        let m = residual_cnn(2);
        let topo_out = m.output_shape().unwrap();
        assert_eq!(topo_out, vec![5]);
    }

    #[test]
    fn zoo_deterministic_by_seed() {
        let a = tiny_mlp(9);
        let b = tiny_mlp(9);
        let (Layer::Dense { w: wa, .. }, Layer::Dense { w: wb, .. }) = (&a.layers[0], &b.layers[0])
        else {
            panic!()
        };
        assert_eq!(wa.data(), wb.data());
    }
}
