//! Builder zoo: small randomly-initialized models with the topologies the
//! paper evaluates, used by unit tests, property tests and ablation benches
//! (the *trained* models come from `python/compile/aot.py` via JSON).

use crate::layers::{Layer, Padding};
use crate::model::Model;
use crate::tensor::Tensor;
use crate::util::Rng;

fn glorot(rng: &mut Rng, fan_in: usize, fan_out: usize, n: usize) -> Vec<f64> {
    let limit = (6.0 / (fan_in + fan_out) as f64).sqrt();
    (0..n).map(|_| rng.range(-limit, limit)).collect()
}

/// Dense layer with Glorot-uniform weights.
pub fn dense(rng: &mut Rng, input: usize, units: usize) -> Layer {
    Layer::Dense {
        w: Tensor::new(vec![units, input], glorot(rng, input, units, units * input)),
        b: (0..units).map(|_| rng.range(-0.05, 0.05)).collect(),
    }
}

/// Conv2D layer with Glorot-uniform weights.
pub fn conv2d(
    rng: &mut Rng,
    kh: usize,
    kw: usize,
    cin: usize,
    cout: usize,
    stride: usize,
    padding: Padding,
) -> Layer {
    let n = kh * kw * cin * cout;
    Layer::Conv2D {
        kernel: Tensor::new(vec![kh, kw, cin, cout], glorot(rng, kh * kw * cin, cout, n)),
        bias: (0..cout).map(|_| rng.range(-0.05, 0.05)).collect(),
        stride,
        padding,
    }
}

/// Depthwise Conv2D layer.
pub fn depthwise(rng: &mut Rng, kh: usize, kw: usize, c: usize, stride: usize, padding: Padding) -> Layer {
    let n = kh * kw * c;
    Layer::DepthwiseConv2D {
        kernel: Tensor::new(vec![kh, kw, c], glorot(rng, kh * kw, 1, n)),
        bias: (0..c).map(|_| rng.range(-0.05, 0.05)).collect(),
        stride,
        padding,
    }
}

/// BatchNorm with benign random statistics.
pub fn batch_norm(rng: &mut Rng, c: usize) -> Layer {
    Layer::BatchNorm {
        gamma: (0..c).map(|_| rng.range(0.5, 1.5)).collect(),
        beta: (0..c).map(|_| rng.range(-0.2, 0.2)).collect(),
        mean: (0..c).map(|_| rng.range(-0.3, 0.3)).collect(),
        variance: (0..c).map(|_| rng.range(0.2, 2.0)).collect(),
        eps: 1e-3,
    }
}

/// A 3-dense MLP classifier: `[8] -> 6 -> 4 -> 3` with ReLU + Softmax —
/// the Digits topology in miniature.
pub fn tiny_mlp(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    Model {
        name: "tiny_mlp".into(),
        input_shape: vec![8],
        layers: vec![
            dense(&mut rng, 8, 6),
            Layer::Relu,
            dense(&mut rng, 6, 4),
            Layer::Relu,
            dense(&mut rng, 4, 3),
            Layer::Softmax,
        ],
    }
}

/// A small CNN: conv/batchnorm/relu, depthwise stage, pooling, dense,
/// softmax — the MobileNet layer mix in miniature (`[6,6,1]` input).
pub fn tiny_cnn(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    Model {
        name: "tiny_cnn".into(),
        input_shape: vec![6, 6, 1],
        layers: vec![
            conv2d(&mut rng, 3, 3, 1, 4, 1, Padding::Same),
            batch_norm(&mut rng, 4),
            Layer::Relu,
            depthwise(&mut rng, 3, 3, 4, 1, Padding::Same),
            Layer::Relu,
            Layer::MaxPool2D { ph: 2, pw: 2 },
            Layer::Flatten,
            dense(&mut rng, 3 * 3 * 4, 5),
            Layer::Softmax,
        ],
    }
}

/// The Pendulum topology (paper: two Dense layers, two tanh activations):
/// `[2] -> Dense -> tanh -> Dense[1] -> tanh`.
pub fn tiny_pendulum(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    Model {
        name: "tiny_pendulum".into(),
        input_shape: vec![2],
        layers: vec![
            dense(&mut rng, 2, 8),
            Layer::Tanh,
            dense(&mut rng, 8, 1),
            Layer::Tanh,
        ],
    }
}

/// An MLP with configurable hidden width (perf-scaling experiments).
pub fn scaled_mlp(seed: u64, input: usize, hidden: usize, classes: usize) -> Model {
    let mut rng = Rng::new(seed);
    Model {
        name: format!("mlp_{input}_{hidden}_{classes}"),
        input_shape: vec![input],
        layers: vec![
            dense(&mut rng, input, hidden),
            Layer::Relu,
            dense(&mut rng, hidden, hidden),
            Layer::Relu,
            dense(&mut rng, hidden, classes),
            Layer::Softmax,
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_models_are_consistent() {
        for m in [tiny_mlp(1), tiny_cnn(2), tiny_pendulum(3), scaled_mlp(4, 16, 32, 5)] {
            let out = m.output_shape().expect("valid stack");
            assert!(!out.is_empty());
            assert!(m.param_count() > 0);
        }
    }

    #[test]
    fn zoo_deterministic_by_seed() {
        let a = tiny_mlp(9);
        let b = tiny_mlp(9);
        let (Layer::Dense { w: wa, .. }, Layer::Dense { w: wb, .. }) = (&a.layers[0], &b.layers[0])
        else {
            panic!()
        };
        assert_eq!(wa.data(), wb.data());
    }
}
