//! Non-sequential model wiring: the [`Graph`] spec (frugally-deep-style
//! `inbound_nodes` naming) and its validated topological order.
//!
//! A [`crate::model::Model`] with `graph: None` is the classic sequential
//! chain — layer `i` feeds layer `i + 1`. Setting `graph: Some(..)` names
//! every layer and lists, per layer, the *nodes* feeding it; the reserved
//! node name `"input"` denotes the model input. This is the minimal
//! structure needed for residual (skip-connection) and multi-branch
//! networks, the topologies where low-precision behavior is most
//! interesting.
//!
//! Everything downstream speaks **values**, not names: value `0` is the
//! model input and value `l + 1` is the output of layer `l`. `Topo`
//! (produced by `Model::toposort`, the one validation chokepoint) carries
//! a topological evaluation order plus the resolved per-layer input value
//! ids; the plan compiler, shape inference, and the JSON loader all
//! consume it. Validation rejects: duplicate or reserved names, unknown
//! inbound references (dangling edges), wrong merge arity, cycles,
//! layers that do not contribute to the output, and a missing or
//! ambiguous output node.

use crate::layers::Layer;
use crate::model::Model;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::collections::VecDeque;

/// Non-sequential wiring for a [`Model`]: per-layer node names and
/// inbound connections. All three vectors in the owning model
/// (`layers`, `names`, `inbound`) are index-aligned.
#[derive(Clone, Debug, PartialEq)]
pub struct Graph {
    /// One entry per layer: the layer's node name. `"input"` is reserved
    /// for the model input.
    pub names: Vec<String>,
    /// One entry per layer: the names of the nodes feeding it, in
    /// argument order (order matters for `Concat`, and pins the
    /// accumulation order of `Add`).
    pub inbound: Vec<Vec<String>>,
    /// The node whose output is the model output. `None` means "the
    /// unique sink" — the single layer no other layer consumes.
    pub output: Option<String>,
}

/// A validated topological view of a model: evaluation order plus
/// name-free value wiring. Value `0` is the model input; value `l + 1`
/// is the output of layer `l`.
#[derive(Clone, Debug)]
pub(crate) struct Topo {
    /// Layer indices in a valid evaluation order (for sequential models,
    /// simply `0..n`).
    pub order: Vec<usize>,
    /// Per layer (indexed by *original* layer index): the value ids it
    /// reads, in declared inbound order.
    pub inputs: Vec<Vec<usize>>,
    /// The value id holding the model output.
    pub output_val: usize,
}

impl Model {
    /// Validate this model's wiring and return its topological view.
    /// Sequential models (`graph: None`) trivially succeed; graph models
    /// get the full structural validation described in [`crate::model::graph`].
    pub(crate) fn toposort(&self) -> Result<Topo> {
        let n = self.layers.len();
        let Some(g) = &self.graph else {
            return Ok(Topo {
                order: (0..n).collect(),
                inputs: (0..n).map(|i| vec![i]).collect(),
                output_val: n,
            });
        };
        if g.names.len() != n || g.inbound.len() != n {
            bail!(
                "graph wiring must cover all {n} layers (got {} names, {} inbound lists)",
                g.names.len(),
                g.inbound.len()
            );
        }

        // Resolve names to value ids.
        let mut idx: HashMap<&str, usize> = HashMap::with_capacity(n + 1);
        idx.insert("input", 0);
        for (i, name) in g.names.iter().enumerate() {
            if name == "input" {
                bail!("layer name 'input' is reserved for the model input");
            }
            if idx.insert(name.as_str(), i + 1).is_some() {
                bail!("duplicate layer name '{name}'");
            }
        }

        // Per-layer input values + arity validation.
        let mut inputs: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, inb) in g.inbound.iter().enumerate() {
            let merge = matches!(self.layers[i], Layer::Add | Layer::Concat);
            if merge && inb.len() < 2 {
                bail!(
                    "merge layer '{}' ({}) needs at least 2 inbound nodes, got {}",
                    g.names[i],
                    self.layers[i].type_name(),
                    inb.len()
                );
            }
            if !merge && inb.len() != 1 {
                bail!(
                    "layer '{}' ({}) takes exactly 1 inbound node, got {}",
                    g.names[i],
                    self.layers[i].type_name(),
                    inb.len()
                );
            }
            for nm in inb {
                let Some(&v) = idx.get(nm.as_str()) else {
                    bail!(
                        "layer '{}' references unknown inbound node '{}' (dangling edge)",
                        g.names[i],
                        nm
                    );
                };
                inputs[i].push(v);
            }
        }

        // Resolve the output value.
        let output_val = match &g.output {
            Some(nm) => {
                let v = *idx
                    .get(nm.as_str())
                    .ok_or_else(|| anyhow!("output node '{nm}' does not exist"))?;
                if v == 0 {
                    bail!("the model output cannot be the input itself");
                }
                v
            }
            None => {
                let mut consumed = vec![false; n + 1];
                for ins in &inputs {
                    for &v in ins {
                        consumed[v] = true;
                    }
                }
                let sinks: Vec<usize> = (1..=n).filter(|&v| !consumed[v]).collect();
                match sinks.as_slice() {
                    [one] => *one,
                    [] => bail!(
                        "every layer output is consumed (the graph has a cycle \
                         or no sink); set 'output' explicitly"
                    ),
                    many => bail!(
                        "graph has {} sinks ({}); set 'output' to pick one",
                        many.len(),
                        many.iter()
                            .map(|&v| g.names[v - 1].as_str())
                            .collect::<Vec<_>>()
                            .join(", ")
                    ),
                }
            }
        };

        // Kahn's algorithm over layer→layer edges ("input" has indegree 0
        // contributions). FIFO over ascending seeds keeps the order stable
        // and close to the declared layer order.
        let mut indeg = vec![0usize; n];
        let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); n + 1];
        for (i, ins) in inputs.iter().enumerate() {
            for &v in ins {
                consumers[v].push(i);
                if v > 0 {
                    indeg[i] += 1;
                }
            }
        }
        let mut queue: VecDeque<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(i) = queue.pop_front() {
            order.push(i);
            for &c in &consumers[i + 1] {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    queue.push_back(c);
                }
            }
        }
        if order.len() != n {
            let stuck: Vec<&str> = (0..n)
                .filter(|&i| indeg[i] > 0)
                .map(|i| g.names[i].as_str())
                .collect();
            bail!("graph contains a cycle involving: {}", stuck.join(", "));
        }

        // Liveness: every layer must contribute to the output (dead
        // branches would silently skew buffer liveness and provenance).
        let mut live = vec![false; n + 1];
        live[output_val] = true;
        for &i in order.iter().rev() {
            if live[i + 1] {
                for &v in &inputs[i] {
                    live[v] = true;
                }
            }
        }
        if let Some(i) = (0..n).find(|&i| !live[i + 1]) {
            bail!(
                "layer '{}' does not contribute to the output '{}'",
                g.names[i],
                g.names[output_val - 1]
            );
        }

        Ok(Topo { order, inputs, output_val })
    }
}
