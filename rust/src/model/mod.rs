//! Model = named layer stack + input shape + optional non-sequential
//! wiring ([`Graph`]). JSON (de)serialization of the exchange format
//! `python/compile/aot.py` emits (replacing the frugally-deep
//! Keras-to-JSON converter), plus a small builder zoo used by tests and
//! ablation benches. Graph validation and topological ordering live in
//! [`graph`]; both sequential and graph models compile to the same
//! buffer-pool [`crate::plan::Plan`].

pub mod graph;
pub mod json_fmt;
pub mod zoo;

pub use graph::Graph;
pub use json_fmt::{model_from_json, model_to_json};

pub(crate) use graph::Topo;

use crate::layers::Layer;
use crate::tensor::{Scalar, Tensor};
use anyhow::{bail, Context, Result};

/// A DNN model: a layer stack plus, for residual/branchy networks, the
/// [`Graph`] wiring that connects the layers. `graph: None` means the
/// classic sequential chain (layer `i` feeds layer `i + 1`).
#[derive(Clone, Debug)]
pub struct Model {
    /// Model name (diagnostics, reports, cache keys).
    pub name: String,
    /// Shape of the model input (channels-last for images).
    pub input_shape: Vec<usize>,
    /// The layers, in declaration order. For graph models this order is
    /// only a listing order; evaluation order comes from the validated
    /// topological sort.
    pub layers: Vec<Layer>,
    /// Non-sequential wiring, or `None` for a sequential chain.
    pub graph: Option<Graph>,
}

impl Model {
    /// Validate the layer stack/graph and return the output shape. This is
    /// the model-level validation chokepoint: wiring errors (cycles,
    /// dangling edges, merge arity) and shape incompatibilities both
    /// surface here.
    pub fn output_shape(&self) -> Result<Vec<usize>> {
        let topo = self.toposort()?;
        let shapes = self.value_shapes(&topo)?;
        Ok(shapes[topo.output_val].clone())
    }

    /// Shape of every value in the model (value `0` = the input, value
    /// `l + 1` = layer `l`'s output), inferred in topological order.
    /// Shared by [`Model::output_shape`] and the plan compiler so merge
    /// shape rules exist in exactly one place
    /// ([`Layer::output_shape_multi`]).
    pub(crate) fn value_shapes(&self, topo: &Topo) -> Result<Vec<Vec<usize>>> {
        let mut val_shape: Vec<Vec<usize>> = vec![Vec::new(); self.layers.len() + 1];
        val_shape[0] = self.input_shape.clone();
        for &l in &topo.order {
            let in_shapes: Vec<&[usize]> =
                topo.inputs[l].iter().map(|&v| val_shape[v].as_slice()).collect();
            val_shape[l + 1] = self.layers[l]
                .output_shape_multi(&in_shapes)
                .with_context(|| format!("layer {l} ({})", self.layers[l].type_name()))?;
        }
        Ok(val_shape)
    }

    /// Total learned parameter count.
    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.param_count()).sum()
    }

    /// Run the network in the arithmetic `S`.
    ///
    /// Convenience path: compiles a throwaway **unfused**
    /// [`Plan`](crate::plan::Plan) (exact legacy interpreter semantics) and
    /// executes it. Hot loops should compile once with
    /// [`Model::compile`] and drive [`crate::plan::Plan::execute`] with a
    /// reused [`crate::plan::Arena`].
    pub fn forward<S: Scalar>(&self, ctx: &S::Ctx, input: Tensor<S>) -> Result<Tensor<S>> {
        // Input-shape validation (same message as before) happens in
        // `Plan::forward`.
        crate::plan::Plan::unfused(self)?.forward(ctx, input)
    }

    /// Compile this model into an execution plan at the given fusion
    /// level (see [`crate::plan`] for the soundness contract per level).
    /// Works for sequential and graph models alike.
    ///
    /// ```
    /// use rigor::model::zoo;
    /// use rigor::plan::Fusion;
    ///
    /// let plan = zoo::residual_mlp(7).compile(Fusion::Pair)?;
    /// // The skip connection forces a third live buffer; sequential
    /// // models compile to exactly two.
    /// assert_eq!(plan.buffer_count(), 3);
    /// assert_eq!(zoo::tiny_mlp(7).compile(Fusion::Pair)?.buffer_count(), 2);
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn compile(&self, fusion: crate::plan::Fusion) -> Result<crate::plan::Plan> {
        crate::plan::Plan::build(self, fusion)
    }

    /// The pre-plan reference interpreter: walks `Vec<Layer>` directly,
    /// re-deriving shapes and allocating a fresh tensor per layer. Kept as
    /// the independent oracle the plan executor is regression-tested
    /// against (bit-identical CAA bounds) and benchmarked over.
    #[deprecated(
        since = "0.3.0",
        note = "legacy interpreter; compile a `plan::Plan` and use its executor"
    )]
    pub fn forward_interpreted<S: Scalar>(
        &self,
        ctx: &S::Ctx,
        input: Tensor<S>,
    ) -> Result<Tensor<S>> {
        if self.graph.is_some() {
            bail!(
                "model '{}': the legacy interpreter only walks sequential chains; \
                 graph models execute through a compiled plan (Model::compile)",
                self.name
            );
        }
        if input.shape() != self.input_shape {
            bail!(
                "model '{}' expects input {:?}, got {:?}",
                self.name,
                self.input_shape,
                input.shape()
            );
        }
        let mut t = input;
        for (i, layer) in self.layers.iter().enumerate() {
            t = layer
                .apply(ctx, &t)
                .with_context(|| format!("layer {i} ({})", layer.type_name()))?;
        }
        Ok(t)
    }

    /// Load from a JSON file.
    pub fn load(path: &std::path::Path) -> Result<Model> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading model file {}", path.display()))?;
        let v = crate::json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        model_from_json(&v)
    }

    /// Save to a JSON file.
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        let v = model_to_json(self);
        std::fs::write(path, crate::json::to_string_pretty(&v))
            .with_context(|| format!("writing model file {}", path.display()))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::caa::{Caa, Ctx};
    use crate::interval::Interval;
    use crate::quant::EmulatedFp;
    use crate::tensor::EmuCtx;
    use crate::util::Rng;

    #[test]
    fn forward_shapes_validate() {
        let m = zoo::tiny_mlp(7);
        assert_eq!(m.output_shape().unwrap(), vec![3]);
        let bad = Tensor::filled(vec![5], 0.0f64);
        assert!(m.forward::<f64>(&(), bad).is_err());
    }

    #[test]
    fn json_roundtrip_preserves_forward() {
        let m = zoo::tiny_cnn(11);
        let v = model_to_json(&m);
        let text = crate::json::to_string_pretty(&v);
        let m2 = model_from_json(&crate::json::parse(&text).unwrap()).unwrap();
        assert_eq!(m.name, m2.name);
        assert_eq!(m.param_count(), m2.param_count());

        let mut rng = Rng::new(5);
        let n: usize = m.input_shape.iter().product();
        let x: Vec<f64> = (0..n).map(|_| rng.range(0.0, 1.0)).collect();
        let y1 = m
            .forward::<f64>(&(), Tensor::new(m.input_shape.clone(), x.clone()))
            .unwrap();
        let y2 = m
            .forward::<f64>(&(), Tensor::new(m2.input_shape.clone(), x))
            .unwrap();
        assert_eq!(y1.data(), y2.data(), "weights must round-trip bit-exactly");
    }

    #[test]
    fn full_model_caa_sound_vs_emulated() {
        // End-to-end soundness over a complete model with conv, pool,
        // batchnorm, dense and softmax layers.
        let m = zoo::tiny_cnn(23);
        let mut rng = Rng::new(77);
        let n: usize = m.input_shape.iter().product();
        let xf: Vec<f64> = (0..n).map(|_| rng.range(0.0, 1.0)).collect();

        let ctx = Ctx::new();
        let xc = Tensor::new(
            m.input_shape.clone(),
            xf.iter().map(|&v| Caa::input(&ctx, Interval::point(v), v)).collect(),
        );
        let yc = m.forward::<Caa>(&ctx, xc).unwrap();
        let yr = m
            .forward::<f64>(&(), Tensor::new(m.input_shape.clone(), xf.clone()))
            .unwrap();

        for k in [8u32, 12, 16] {
            let ec = EmuCtx { k };
            let xe = Tensor::new(
                m.input_shape.clone(),
                xf.iter().map(|&v| EmulatedFp::new(v, k)).collect(),
            );
            let ye = m.forward::<EmulatedFp>(&ec, xe).unwrap();
            for i in 0..yr.len() {
                crate::quant::check_against_bounds(
                    &yc.data()[i],
                    yr.data()[i],
                    ye.data()[i].v,
                    k,
                    1e-12,
                )
                .unwrap_or_else(|e| panic!("k={k} output {i}: {e}"));
            }
        }
    }

    #[test]
    fn mlp_caa_bounds_tight() {
        // Table-I-style sanity: a small trained-ish MLP analyzed on a point
        // input must give single-digit-u bounds.
        let m = zoo::tiny_mlp(3);
        let ctx = Ctx::new();
        let n: usize = m.input_shape.iter().product();
        let x = Tensor::new(
            m.input_shape.clone(),
            (0..n)
                .map(|i| {
                    let v = (i as f64) / (n as f64);
                    Caa::input(&ctx, Interval::point(v), v)
                })
                .collect(),
        );
        let y = m.forward::<Caa>(&ctx, x).unwrap();
        for v in y.data() {
            assert!(v.abs_bound().is_finite());
            assert!(v.abs_bound() < 100.0, "abs bound too loose: {}", v.abs_bound());
        }
    }

    #[test]
    fn load_save_tempfile() {
        let m = zoo::tiny_mlp(1);
        let dir = std::env::temp_dir().join("rigor_model_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.json");
        m.save(&path).unwrap();
        let l = Model::load(&path).unwrap();
        assert_eq!(l.name, m.name);
        assert_eq!(l.output_shape().unwrap(), m.output_shape().unwrap());
    }
}
