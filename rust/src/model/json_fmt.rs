//! JSON exchange format for models.
//!
//! The format mirrors what `python/compile/aot.py` exports:
//!
//! ```json
//! {
//!   "name": "digits_mlp",
//!   "input_shape": [784],
//!   "layers": [
//!     {"type": "dense", "units": 64, "in": 784, "weights": [...], "bias": [...]},
//!     {"type": "relu"},
//!     {"type": "conv2d", "kh": 3, "kw": 3, "cin": 1, "cout": 8,
//!      "stride": 1, "padding": "same", "weights": [...], "bias": [...]},
//!     {"type": "batch_norm", "gamma": [...], "beta": [...],
//!      "mean": [...], "variance": [...], "eps": 0.001},
//!     {"type": "max_pool2d", "ph": 2, "pw": 2},
//!     {"type": "flatten"},
//!     {"type": "softmax"}
//!   ]
//! }
//! ```
//!
//! Weight arrays are flat, row-major: dense `[units, in]`, conv
//! `[kh, kw, cin, cout]` (Keras layout).

use crate::json::Value;
use crate::layers::{Layer, Padding};
use crate::model::Model;
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Context, Result};

fn req<'a>(v: &'a Value, key: &str) -> Result<&'a Value> {
    v.get(key).ok_or_else(|| anyhow!("missing field '{key}'"))
}

fn req_usize(v: &Value, key: &str) -> Result<usize> {
    req(v, key)?
        .as_usize()
        .ok_or_else(|| anyhow!("field '{key}' must be a non-negative integer"))
}

fn req_f64(v: &Value, key: &str) -> Result<f64> {
    req(v, key)?
        .as_f64()
        .ok_or_else(|| anyhow!("field '{key}' must be a number"))
}

fn req_f64_vec(v: &Value, key: &str) -> Result<Vec<f64>> {
    req(v, key)?
        .as_f64_vec()
        .ok_or_else(|| anyhow!("field '{key}' must be a numeric array"))
}

fn layer_from_json(v: &Value) -> Result<Layer> {
    let ty = req(v, "type")?
        .as_str()
        .ok_or_else(|| anyhow!("layer 'type' must be a string"))?;
    Ok(match ty {
        "dense" => {
            let units = req_usize(v, "units")?;
            let input = req_usize(v, "in")?;
            let w = req_f64_vec(v, "weights")?;
            let b = req_f64_vec(v, "bias")?;
            if w.len() != units * input {
                bail!("dense weights: expected {} values, got {}", units * input, w.len());
            }
            if b.len() != units {
                bail!("dense bias: expected {units} values, got {}", b.len());
            }
            Layer::Dense { w: Tensor::new(vec![units, input], w), b }
        }
        "conv2d" => {
            let (kh, kw) = (req_usize(v, "kh")?, req_usize(v, "kw")?);
            let (cin, cout) = (req_usize(v, "cin")?, req_usize(v, "cout")?);
            let stride = req_usize(v, "stride")?;
            let padding = Padding::parse(
                req(v, "padding")?
                    .as_str()
                    .ok_or_else(|| anyhow!("'padding' must be a string"))?,
            )?;
            let w = req_f64_vec(v, "weights")?;
            let b = req_f64_vec(v, "bias")?;
            if w.len() != kh * kw * cin * cout {
                bail!("conv2d weights: expected {} values, got {}", kh * kw * cin * cout, w.len());
            }
            if b.len() != cout {
                bail!("conv2d bias: expected {cout} values, got {}", b.len());
            }
            if stride == 0 {
                bail!("conv2d stride must be >= 1");
            }
            Layer::Conv2D { kernel: Tensor::new(vec![kh, kw, cin, cout], w), bias: b, stride, padding }
        }
        "depthwise_conv2d" => {
            let (kh, kw, c) = (req_usize(v, "kh")?, req_usize(v, "kw")?, req_usize(v, "c")?);
            let stride = req_usize(v, "stride")?;
            let padding = Padding::parse(
                req(v, "padding")?
                    .as_str()
                    .ok_or_else(|| anyhow!("'padding' must be a string"))?,
            )?;
            let w = req_f64_vec(v, "weights")?;
            let b = req_f64_vec(v, "bias")?;
            if w.len() != kh * kw * c {
                bail!("depthwise weights: expected {} values, got {}", kh * kw * c, w.len());
            }
            if b.len() != c {
                bail!("depthwise bias: expected {c} values, got {}", b.len());
            }
            Layer::DepthwiseConv2D { kernel: Tensor::new(vec![kh, kw, c], w), bias: b, stride, padding }
        }
        "max_pool2d" => Layer::MaxPool2D { ph: req_usize(v, "ph")?, pw: req_usize(v, "pw")? },
        "avg_pool2d" => Layer::AvgPool2D { ph: req_usize(v, "ph")?, pw: req_usize(v, "pw")? },
        "batch_norm" => {
            let gamma = req_f64_vec(v, "gamma")?;
            let beta = req_f64_vec(v, "beta")?;
            let mean = req_f64_vec(v, "mean")?;
            let variance = req_f64_vec(v, "variance")?;
            let eps = req_f64(v, "eps")?;
            let c = gamma.len();
            if beta.len() != c || mean.len() != c || variance.len() != c {
                bail!("batch_norm parameter arrays must share a length");
            }
            if eps <= 0.0 {
                bail!("batch_norm eps must be positive");
            }
            if variance.iter().any(|&x| x < 0.0) {
                bail!("batch_norm variance must be nonnegative");
            }
            Layer::BatchNorm { gamma, beta, mean, variance, eps }
        }
        "flatten" => Layer::Flatten,
        "relu" => Layer::Relu,
        "leaky_relu" => Layer::LeakyRelu { alpha: req_f64(v, "alpha")? },
        "tanh" => Layer::Tanh,
        "sigmoid" => Layer::Sigmoid,
        "softmax" => Layer::Softmax,
        _ => bail!("unknown layer type '{ty}'"),
    })
}

fn layer_to_json(l: &Layer) -> Value {
    let mut pairs: Vec<(&str, Value)> = vec![("type", Value::from(l.type_name()))];
    match l {
        Layer::Dense { w, b } => {
            pairs.push(("units", Value::from(w.shape()[0])));
            pairs.push(("in", Value::from(w.shape()[1])));
            pairs.push(("weights", Value::nums(w.data())));
            pairs.push(("bias", Value::nums(b)));
        }
        Layer::Conv2D { kernel, bias, stride, padding } => {
            pairs.push(("kh", Value::from(kernel.shape()[0])));
            pairs.push(("kw", Value::from(kernel.shape()[1])));
            pairs.push(("cin", Value::from(kernel.shape()[2])));
            pairs.push(("cout", Value::from(kernel.shape()[3])));
            pairs.push(("stride", Value::from(*stride)));
            pairs.push(("padding", Value::from(padding.as_str())));
            pairs.push(("weights", Value::nums(kernel.data())));
            pairs.push(("bias", Value::nums(bias)));
        }
        Layer::DepthwiseConv2D { kernel, bias, stride, padding } => {
            pairs.push(("kh", Value::from(kernel.shape()[0])));
            pairs.push(("kw", Value::from(kernel.shape()[1])));
            pairs.push(("c", Value::from(kernel.shape()[2])));
            pairs.push(("stride", Value::from(*stride)));
            pairs.push(("padding", Value::from(padding.as_str())));
            pairs.push(("weights", Value::nums(kernel.data())));
            pairs.push(("bias", Value::nums(bias)));
        }
        Layer::MaxPool2D { ph, pw } | Layer::AvgPool2D { ph, pw } => {
            pairs.push(("ph", Value::from(*ph)));
            pairs.push(("pw", Value::from(*pw)));
        }
        Layer::BatchNorm { gamma, beta, mean, variance, eps } => {
            pairs.push(("gamma", Value::nums(gamma)));
            pairs.push(("beta", Value::nums(beta)));
            pairs.push(("mean", Value::nums(mean)));
            pairs.push(("variance", Value::nums(variance)));
            pairs.push(("eps", Value::Num(*eps)));
        }
        Layer::LeakyRelu { alpha } => {
            pairs.push(("alpha", Value::Num(*alpha)));
        }
        _ => {}
    }
    Value::obj(pairs)
}

/// Parse a model from its JSON value.
pub fn model_from_json(v: &Value) -> Result<Model> {
    let name = req(v, "name")?
        .as_str()
        .ok_or_else(|| anyhow!("'name' must be a string"))?
        .to_string();
    let input_shape = req(v, "input_shape")?
        .as_usize_vec()
        .ok_or_else(|| anyhow!("'input_shape' must be an integer array"))?;
    let layers_v = req(v, "layers")?
        .as_array()
        .ok_or_else(|| anyhow!("'layers' must be an array"))?;
    let mut layers = Vec::with_capacity(layers_v.len());
    for (i, lv) in layers_v.iter().enumerate() {
        layers.push(layer_from_json(lv).with_context(|| format!("layer {i}"))?);
    }
    let m = Model { name, input_shape, layers };
    m.output_shape().context("incompatible layer stack")?;
    Ok(m)
}

/// Serialize a model to a JSON value.
pub fn model_to_json(m: &Model) -> Value {
    Value::obj(vec![
        ("name", Value::from(m.name.as_str())),
        (
            "input_shape",
            Value::Array(m.input_shape.iter().map(|&d| Value::from(d)).collect()),
        ),
        ("layers", Value::Array(m.layers.iter().map(layer_to_json).collect())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    #[test]
    fn minimal_model_parses() {
        let text = r#"{
            "name": "m", "input_shape": [2],
            "layers": [
                {"type": "dense", "units": 2, "in": 2,
                 "weights": [1, 0, 0, 1], "bias": [0, 0]},
                {"type": "tanh"},
                {"type": "softmax"}
            ]
        }"#;
        let m = model_from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(m.layers.len(), 3);
        assert_eq!(m.output_shape().unwrap(), vec![2]);
    }

    #[test]
    fn rejects_bad_payloads() {
        let cases = [
            r#"{"input_shape": [2], "layers": []}"#,                   // no name
            r#"{"name": "m", "layers": []}"#,                           // no shape
            r#"{"name": "m", "input_shape": [2], "layers": [{"type": "nope"}]}"#,
            // wrong weight count
            r#"{"name": "m", "input_shape": [2], "layers": [
                {"type": "dense", "units": 2, "in": 2, "weights": [1], "bias": [0, 0]}]}"#,
            // incompatible stack: dense in=3 after input 2
            r#"{"name": "m", "input_shape": [2], "layers": [
                {"type": "dense", "units": 2, "in": 3,
                 "weights": [0,0,0,0,0,0], "bias": [0,0]}]}"#,
            // negative variance
            r#"{"name": "m", "input_shape": [2], "layers": [
                {"type": "batch_norm", "gamma": [1,1], "beta": [0,0],
                 "mean": [0,0], "variance": [-1,1], "eps": 0.001}]}"#,
        ];
        for c in cases {
            assert!(
                model_from_json(&json::parse(c).unwrap()).is_err(),
                "should reject: {c}"
            );
        }
    }

    #[test]
    fn zoo_builders_roundtrip_to_identical_json() {
        // Strong round-trip contract on every zoo topology:
        // model_to_json(model_from_json(x)) == x (Value equality, which is
        // bit-exact on weights since numbers stay f64 end to end).
        use crate::model::zoo;
        for m in [
            zoo::tiny_mlp(1),
            zoo::tiny_cnn(2),
            zoo::tiny_pendulum(3),
            zoo::scaled_mlp(4, 12, 8, 5),
        ] {
            let v = model_to_json(&m);
            let reparsed = model_from_json(&v).unwrap_or_else(|e| panic!("{}: {e}", m.name));
            assert_eq!(
                model_to_json(&reparsed),
                v,
                "{}: JSON value must be a fixed point of parse∘serialize",
                m.name
            );
            // And through text, too (writer + parser).
            let text = json::to_string_pretty(&v);
            let reparsed2 = model_from_json(&json::parse(&text).unwrap()).unwrap();
            assert_eq!(model_to_json(&reparsed2), v, "{}: text round-trip", m.name);
        }
    }

    #[test]
    fn rejects_malformed_layers_with_context() {
        // (payload, expected error fragment)
        let cases = [
            // missing 'type'
            (
                r#"{"name": "m", "input_shape": [2], "layers": [{"units": 2}]}"#,
                "type",
            ),
            // bad padding string
            (
                r#"{"name": "m", "input_shape": [4, 4, 1], "layers": [
                    {"type": "conv2d", "kh": 1, "kw": 1, "cin": 1, "cout": 1,
                     "stride": 1, "padding": "diagonal",
                     "weights": [1.0], "bias": [0.0]}]}"#,
                "padding",
            ),
            // dense weight length mismatch
            (
                r#"{"name": "m", "input_shape": [2], "layers": [
                    {"type": "dense", "units": 2, "in": 2,
                     "weights": [1, 2, 3], "bias": [0, 0]}]}"#,
                "weights",
            ),
            // dense bias length mismatch
            (
                r#"{"name": "m", "input_shape": [2], "layers": [
                    {"type": "dense", "units": 2, "in": 2,
                     "weights": [1, 2, 3, 4], "bias": [0]}]}"#,
                "bias",
            ),
            // conv2d weight length mismatch
            (
                r#"{"name": "m", "input_shape": [4, 4, 1], "layers": [
                    {"type": "conv2d", "kh": 3, "kw": 3, "cin": 1, "cout": 2,
                     "stride": 1, "padding": "same",
                     "weights": [1.0, 2.0], "bias": [0.0, 0.0]}]}"#,
                "weights",
            ),
            // depthwise weight length mismatch
            (
                r#"{"name": "m", "input_shape": [4, 4, 2], "layers": [
                    {"type": "depthwise_conv2d", "kh": 3, "kw": 3, "c": 2,
                     "stride": 1, "padding": "same",
                     "weights": [1.0], "bias": [0.0, 0.0]}]}"#,
                "weights",
            ),
        ];
        for (payload, fragment) in cases {
            let err = model_from_json(&json::parse(payload).unwrap())
                .expect_err(&format!("should reject: {payload}"));
            let chain = format!("{err:#}");
            assert!(
                chain.contains(fragment),
                "error for {payload}\nmust mention '{fragment}', got: {chain}"
            );
        }
    }

    #[test]
    fn conv_roundtrip() {
        let text = r#"{
            "name": "c", "input_shape": [4, 4, 1],
            "layers": [
                {"type": "conv2d", "kh": 3, "kw": 3, "cin": 1, "cout": 2,
                 "stride": 1, "padding": "same",
                 "weights": [0.1,0.2,0.1,0.2,0.1,0.2,0.1,0.2,0.1,0.2,0.1,0.2,0.1,0.2,0.1,0.2,0.3,0.4],
                 "bias": [0.5, -0.5]},
                {"type": "relu"},
                {"type": "max_pool2d", "ph": 2, "pw": 2},
                {"type": "flatten"}
            ]
        }"#;
        let m = model_from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(m.output_shape().unwrap(), vec![8]);
        let re = model_from_json(&json::parse(&json::to_string_pretty(&model_to_json(&m))).unwrap())
            .unwrap();
        assert_eq!(re.param_count(), m.param_count());
    }
}
