//! JSON exchange format for models.
//!
//! The format mirrors what `python/compile/aot.py` exports:
//!
//! ```json
//! {
//!   "name": "digits_mlp",
//!   "input_shape": [784],
//!   "layers": [
//!     {"type": "dense", "units": 64, "in": 784, "weights": [...], "bias": [...]},
//!     {"type": "relu"},
//!     {"type": "conv2d", "kh": 3, "kw": 3, "cin": 1, "cout": 8,
//!      "stride": 1, "padding": "same", "weights": [...], "bias": [...]},
//!     {"type": "batch_norm", "gamma": [...], "beta": [...],
//!      "mean": [...], "variance": [...], "eps": 0.001},
//!     {"type": "max_pool2d", "ph": 2, "pw": 2},
//!     {"type": "flatten"},
//!     {"type": "softmax"}
//!   ]
//! }
//! ```
//!
//! Weight arrays are flat, row-major: dense `[units, in]`, conv
//! `[kh, kw, cin, cout]` (Keras layout).
//!
//! ## Graph (non-sequential) models
//!
//! Residual/branchy topologies add frugally-deep-style `inbound_nodes`
//! wiring: **every** layer carries a `"name"` and an `"inbound"` array of
//! node names (the reserved name `"input"` is the model input), merge
//! layers (`"add"`, `"concat"`) list two or more inbound nodes, and an
//! optional top-level `"output"` picks the output node (defaulting to the
//! unique sink):
//!
//! ```json
//! {
//!   "name": "res_block", "input_shape": [8], "output": "out",
//!   "layers": [
//!     {"type": "dense", "units": 8, "in": 8, "weights": [...], "bias": [...],
//!      "name": "d1", "inbound": ["input"]},
//!     {"type": "relu", "name": "a1", "inbound": ["d1"]},
//!     {"type": "dense", "units": 8, "in": 8, "weights": [...], "bias": [...],
//!      "name": "d2", "inbound": ["a1"]},
//!     {"type": "add", "name": "s", "inbound": ["d2", "a1"]},
//!     {"type": "softmax", "name": "out", "inbound": ["s"]}
//!   ]
//! }
//! ```
//!
//! Wiring is all-or-nothing: a model either names every layer (graph
//! mode) or none (sequential mode). Structural validation — cycles,
//! dangling edges, merge arity, unreachable layers — happens on load via
//! [`Model::output_shape`].

use crate::json::Value;
use crate::layers::{Layer, Padding};
use crate::model::{Graph, Model};
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Context, Result};
use std::sync::Arc;

fn req<'a>(v: &'a Value, key: &str) -> Result<&'a Value> {
    v.get(key).ok_or_else(|| anyhow!("missing field '{key}'"))
}

fn req_usize(v: &Value, key: &str) -> Result<usize> {
    req(v, key)?
        .as_usize()
        .ok_or_else(|| anyhow!("field '{key}' must be a non-negative integer"))
}

fn req_f64(v: &Value, key: &str) -> Result<f64> {
    req(v, key)?
        .as_f64()
        .ok_or_else(|| anyhow!("field '{key}' must be a number"))
}

fn req_f64_vec(v: &Value, key: &str) -> Result<Vec<f64>> {
    req(v, key)?
        .as_f64_vec()
        .ok_or_else(|| anyhow!("field '{key}' must be a numeric array"))
}

fn layer_from_json(v: &Value) -> Result<Layer> {
    let ty = req(v, "type")?
        .as_str()
        .ok_or_else(|| anyhow!("layer 'type' must be a string"))?;
    Ok(match ty {
        "dense" => {
            let units = req_usize(v, "units")?;
            let input = req_usize(v, "in")?;
            let w = req_f64_vec(v, "weights")?;
            let b = req_f64_vec(v, "bias")?;
            if w.len() != units * input {
                bail!("dense weights: expected {} values, got {}", units * input, w.len());
            }
            if b.len() != units {
                bail!("dense bias: expected {units} values, got {}", b.len());
            }
            Layer::Dense { w: Arc::new(Tensor::new(vec![units, input], w)), b }
        }
        "conv2d" => {
            let (kh, kw) = (req_usize(v, "kh")?, req_usize(v, "kw")?);
            let (cin, cout) = (req_usize(v, "cin")?, req_usize(v, "cout")?);
            let stride = req_usize(v, "stride")?;
            let padding = Padding::parse(
                req(v, "padding")?
                    .as_str()
                    .ok_or_else(|| anyhow!("'padding' must be a string"))?,
            )?;
            let w = req_f64_vec(v, "weights")?;
            let b = req_f64_vec(v, "bias")?;
            if w.len() != kh * kw * cin * cout {
                bail!("conv2d weights: expected {} values, got {}", kh * kw * cin * cout, w.len());
            }
            if b.len() != cout {
                bail!("conv2d bias: expected {cout} values, got {}", b.len());
            }
            if stride == 0 {
                bail!("conv2d stride must be >= 1");
            }
            Layer::Conv2D {
                kernel: Arc::new(Tensor::new(vec![kh, kw, cin, cout], w)),
                bias: b,
                stride,
                padding,
            }
        }
        "depthwise_conv2d" => {
            let (kh, kw, c) = (req_usize(v, "kh")?, req_usize(v, "kw")?, req_usize(v, "c")?);
            let stride = req_usize(v, "stride")?;
            let padding = Padding::parse(
                req(v, "padding")?
                    .as_str()
                    .ok_or_else(|| anyhow!("'padding' must be a string"))?,
            )?;
            let w = req_f64_vec(v, "weights")?;
            let b = req_f64_vec(v, "bias")?;
            if w.len() != kh * kw * c {
                bail!("depthwise weights: expected {} values, got {}", kh * kw * c, w.len());
            }
            if b.len() != c {
                bail!("depthwise bias: expected {c} values, got {}", b.len());
            }
            Layer::DepthwiseConv2D {
                kernel: Arc::new(Tensor::new(vec![kh, kw, c], w)),
                bias: b,
                stride,
                padding,
            }
        }
        "max_pool2d" => Layer::MaxPool2D { ph: req_usize(v, "ph")?, pw: req_usize(v, "pw")? },
        "avg_pool2d" => Layer::AvgPool2D { ph: req_usize(v, "ph")?, pw: req_usize(v, "pw")? },
        "batch_norm" => {
            let gamma = req_f64_vec(v, "gamma")?;
            let beta = req_f64_vec(v, "beta")?;
            let mean = req_f64_vec(v, "mean")?;
            let variance = req_f64_vec(v, "variance")?;
            let eps = req_f64(v, "eps")?;
            let c = gamma.len();
            if beta.len() != c || mean.len() != c || variance.len() != c {
                bail!("batch_norm parameter arrays must share a length");
            }
            if eps <= 0.0 {
                bail!("batch_norm eps must be positive");
            }
            if variance.iter().any(|&x| x < 0.0) {
                bail!("batch_norm variance must be nonnegative");
            }
            Layer::BatchNorm { gamma, beta, mean, variance, eps }
        }
        "flatten" => Layer::Flatten,
        "relu" => Layer::Relu,
        "leaky_relu" => Layer::LeakyRelu { alpha: req_f64(v, "alpha")? },
        "tanh" => Layer::Tanh,
        "sigmoid" => Layer::Sigmoid,
        "softmax" => Layer::Softmax,
        "add" => Layer::Add,
        "concat" => Layer::Concat,
        _ => bail!("unknown layer type '{ty}'"),
    })
}

/// Extract the optional graph-wiring fields (`name`, `inbound`) of one
/// layer object.
fn layer_wiring_from_json(v: &Value) -> Result<(Option<String>, Option<Vec<String>>)> {
    let name = match v.get("name") {
        None => None,
        Some(x) => Some(
            x.as_str()
                .ok_or_else(|| anyhow!("layer 'name' must be a string"))?
                .to_string(),
        ),
    };
    let inbound = match v.get("inbound") {
        None => None,
        Some(x) => {
            let arr = x
                .as_array()
                .ok_or_else(|| anyhow!("'inbound' must be an array of node names"))?;
            let mut names = Vec::with_capacity(arr.len());
            for e in arr {
                names.push(
                    e.as_str()
                        .ok_or_else(|| anyhow!("'inbound' entries must be strings"))?
                        .to_string(),
                );
            }
            Some(names)
        }
    };
    Ok((name, inbound))
}

fn layer_to_json(l: &Layer, wiring: Option<(&str, &[String])>) -> Value {
    let mut pairs: Vec<(&str, Value)> = vec![("type", Value::from(l.type_name()))];
    if let Some((name, inbound)) = wiring {
        pairs.push(("name", Value::from(name)));
        pairs.push((
            "inbound",
            Value::Array(inbound.iter().map(|n| Value::from(n.as_str())).collect()),
        ));
    }
    match l {
        Layer::Dense { w, b } => {
            pairs.push(("units", Value::from(w.shape()[0])));
            pairs.push(("in", Value::from(w.shape()[1])));
            pairs.push(("weights", Value::nums(w.data())));
            pairs.push(("bias", Value::nums(b)));
        }
        Layer::Conv2D { kernel, bias, stride, padding } => {
            pairs.push(("kh", Value::from(kernel.shape()[0])));
            pairs.push(("kw", Value::from(kernel.shape()[1])));
            pairs.push(("cin", Value::from(kernel.shape()[2])));
            pairs.push(("cout", Value::from(kernel.shape()[3])));
            pairs.push(("stride", Value::from(*stride)));
            pairs.push(("padding", Value::from(padding.as_str())));
            pairs.push(("weights", Value::nums(kernel.data())));
            pairs.push(("bias", Value::nums(bias)));
        }
        Layer::DepthwiseConv2D { kernel, bias, stride, padding } => {
            pairs.push(("kh", Value::from(kernel.shape()[0])));
            pairs.push(("kw", Value::from(kernel.shape()[1])));
            pairs.push(("c", Value::from(kernel.shape()[2])));
            pairs.push(("stride", Value::from(*stride)));
            pairs.push(("padding", Value::from(padding.as_str())));
            pairs.push(("weights", Value::nums(kernel.data())));
            pairs.push(("bias", Value::nums(bias)));
        }
        Layer::MaxPool2D { ph, pw } | Layer::AvgPool2D { ph, pw } => {
            pairs.push(("ph", Value::from(*ph)));
            pairs.push(("pw", Value::from(*pw)));
        }
        Layer::BatchNorm { gamma, beta, mean, variance, eps } => {
            pairs.push(("gamma", Value::nums(gamma)));
            pairs.push(("beta", Value::nums(beta)));
            pairs.push(("mean", Value::nums(mean)));
            pairs.push(("variance", Value::nums(variance)));
            pairs.push(("eps", Value::Num(*eps)));
        }
        Layer::LeakyRelu { alpha } => {
            pairs.push(("alpha", Value::Num(*alpha)));
        }
        _ => {}
    }
    Value::obj(pairs)
}

/// Parse a model from its JSON value.
pub fn model_from_json(v: &Value) -> Result<Model> {
    let name = req(v, "name")?
        .as_str()
        .ok_or_else(|| anyhow!("'name' must be a string"))?
        .to_string();
    let input_shape = req(v, "input_shape")?
        .as_usize_vec()
        .ok_or_else(|| anyhow!("'input_shape' must be an integer array"))?;
    let layers_v = req(v, "layers")?
        .as_array()
        .ok_or_else(|| anyhow!("'layers' must be an array"))?;
    let mut layers = Vec::with_capacity(layers_v.len());
    let mut names: Vec<Option<String>> = Vec::with_capacity(layers_v.len());
    let mut inbound: Vec<Option<Vec<String>>> = Vec::with_capacity(layers_v.len());
    for (i, lv) in layers_v.iter().enumerate() {
        layers.push(layer_from_json(lv).with_context(|| format!("layer {i}"))?);
        let (n, inb) = layer_wiring_from_json(lv).with_context(|| format!("layer {i}"))?;
        names.push(n);
        inbound.push(inb);
    }
    let output = match v.get("output") {
        None => None,
        Some(o) => Some(
            o.as_str()
                .ok_or_else(|| anyhow!("'output' must be a string (a layer name)"))?
                .to_string(),
        ),
    };

    // Graph mode is all-or-nothing: every layer wired, or none.
    let wired = names.iter().filter(|n| n.is_some()).count()
        + inbound.iter().filter(|n| n.is_some()).count();
    let graph = if wired == 0 {
        if output.is_some() {
            bail!("'output' requires graph wiring (per-layer 'name' and 'inbound')");
        }
        None
    } else {
        let mut g_names = Vec::with_capacity(layers.len());
        let mut g_inbound = Vec::with_capacity(layers.len());
        for i in 0..layers.len() {
            let Some(n) = names[i].take() else {
                bail!("graph models need 'name' on every layer (layer {i} has none)");
            };
            let Some(inb) = inbound[i].take() else {
                bail!("graph models need 'inbound' on every layer (layer '{n}' has none)");
            };
            g_names.push(n);
            g_inbound.push(inb);
        }
        Some(Graph { names: g_names, inbound: g_inbound, output })
    };

    let m = Model { name, input_shape, layers, graph };
    m.output_shape().context("incompatible layer stack")?;
    Ok(m)
}

/// Serialize a model to a JSON value (graph wiring included when present).
pub fn model_to_json(m: &Model) -> Value {
    let layers = Value::Array(
        m.layers
            .iter()
            .enumerate()
            .map(|(i, l)| {
                let wiring = m
                    .graph
                    .as_ref()
                    .map(|g| (g.names[i].as_str(), g.inbound[i].as_slice()));
                layer_to_json(l, wiring)
            })
            .collect(),
    );
    let mut pairs = vec![
        ("name", Value::from(m.name.as_str())),
        (
            "input_shape",
            Value::Array(m.input_shape.iter().map(|&d| Value::from(d)).collect()),
        ),
        ("layers", layers),
    ];
    if let Some(out) = m.graph.as_ref().and_then(|g| g.output.as_deref()) {
        pairs.push(("output", Value::from(out)));
    }
    Value::obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    #[test]
    fn minimal_model_parses() {
        let text = r#"{
            "name": "m", "input_shape": [2],
            "layers": [
                {"type": "dense", "units": 2, "in": 2,
                 "weights": [1, 0, 0, 1], "bias": [0, 0]},
                {"type": "tanh"},
                {"type": "softmax"}
            ]
        }"#;
        let m = model_from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(m.layers.len(), 3);
        assert_eq!(m.output_shape().unwrap(), vec![2]);
    }

    #[test]
    fn rejects_bad_payloads() {
        let cases = [
            r#"{"input_shape": [2], "layers": []}"#,                   // no name
            r#"{"name": "m", "layers": []}"#,                           // no shape
            r#"{"name": "m", "input_shape": [2], "layers": [{"type": "nope"}]}"#,
            // wrong weight count
            r#"{"name": "m", "input_shape": [2], "layers": [
                {"type": "dense", "units": 2, "in": 2, "weights": [1], "bias": [0, 0]}]}"#,
            // incompatible stack: dense in=3 after input 2
            r#"{"name": "m", "input_shape": [2], "layers": [
                {"type": "dense", "units": 2, "in": 3,
                 "weights": [0,0,0,0,0,0], "bias": [0,0]}]}"#,
            // negative variance
            r#"{"name": "m", "input_shape": [2], "layers": [
                {"type": "batch_norm", "gamma": [1,1], "beta": [0,0],
                 "mean": [0,0], "variance": [-1,1], "eps": 0.001}]}"#,
        ];
        for c in cases {
            assert!(
                model_from_json(&json::parse(c).unwrap()).is_err(),
                "should reject: {c}"
            );
        }
    }

    #[test]
    fn zoo_builders_roundtrip_to_identical_json() {
        // Strong round-trip contract on every zoo topology:
        // model_to_json(model_from_json(x)) == x (Value equality, which is
        // bit-exact on weights since numbers stay f64 end to end).
        use crate::model::zoo;
        for m in [
            zoo::tiny_mlp(1),
            zoo::tiny_cnn(2),
            zoo::tiny_pendulum(3),
            zoo::scaled_mlp(4, 12, 8, 5),
            zoo::residual_mlp(5),
            zoo::residual_cnn(6),
        ] {
            let v = model_to_json(&m);
            let reparsed = model_from_json(&v).unwrap_or_else(|e| panic!("{}: {e}", m.name));
            assert_eq!(
                model_to_json(&reparsed),
                v,
                "{}: JSON value must be a fixed point of parse∘serialize",
                m.name
            );
            // And through text, too (writer + parser).
            let text = json::to_string_pretty(&v);
            let reparsed2 = model_from_json(&json::parse(&text).unwrap()).unwrap();
            assert_eq!(model_to_json(&reparsed2), v, "{}: text round-trip", m.name);
        }
    }

    #[test]
    fn rejects_malformed_layers_with_context() {
        // (payload, expected error fragment)
        let cases = [
            // missing 'type'
            (
                r#"{"name": "m", "input_shape": [2], "layers": [{"units": 2}]}"#,
                "type",
            ),
            // bad padding string
            (
                r#"{"name": "m", "input_shape": [4, 4, 1], "layers": [
                    {"type": "conv2d", "kh": 1, "kw": 1, "cin": 1, "cout": 1,
                     "stride": 1, "padding": "diagonal",
                     "weights": [1.0], "bias": [0.0]}]}"#,
                "padding",
            ),
            // dense weight length mismatch
            (
                r#"{"name": "m", "input_shape": [2], "layers": [
                    {"type": "dense", "units": 2, "in": 2,
                     "weights": [1, 2, 3], "bias": [0, 0]}]}"#,
                "weights",
            ),
            // dense bias length mismatch
            (
                r#"{"name": "m", "input_shape": [2], "layers": [
                    {"type": "dense", "units": 2, "in": 2,
                     "weights": [1, 2, 3, 4], "bias": [0]}]}"#,
                "bias",
            ),
            // conv2d weight length mismatch
            (
                r#"{"name": "m", "input_shape": [4, 4, 1], "layers": [
                    {"type": "conv2d", "kh": 3, "kw": 3, "cin": 1, "cout": 2,
                     "stride": 1, "padding": "same",
                     "weights": [1.0, 2.0], "bias": [0.0, 0.0]}]}"#,
                "weights",
            ),
            // depthwise weight length mismatch
            (
                r#"{"name": "m", "input_shape": [4, 4, 2], "layers": [
                    {"type": "depthwise_conv2d", "kh": 3, "kw": 3, "c": 2,
                     "stride": 1, "padding": "same",
                     "weights": [1.0], "bias": [0.0, 0.0]}]}"#,
                "weights",
            ),
        ];
        for (payload, fragment) in cases {
            let err = model_from_json(&json::parse(payload).unwrap())
                .expect_err(&format!("should reject: {payload}"));
            let chain = format!("{err:#}");
            assert!(
                chain.contains(fragment),
                "error for {payload}\nmust mention '{fragment}', got: {chain}"
            );
        }
    }

    #[test]
    fn graph_model_roundtrips_with_wiring() {
        let text = r#"{
            "name": "res", "input_shape": [2], "output": "out",
            "layers": [
                {"type": "dense", "units": 2, "in": 2,
                 "weights": [1, 0, 0, 1], "bias": [0, 0],
                 "name": "d1", "inbound": ["input"]},
                {"type": "relu", "name": "a1", "inbound": ["d1"]},
                {"type": "dense", "units": 2, "in": 2,
                 "weights": [0.5, 0, 0, 0.5], "bias": [0, 0],
                 "name": "d2", "inbound": ["a1"]},
                {"type": "add", "name": "s", "inbound": ["d2", "a1"]},
                {"type": "softmax", "name": "out", "inbound": ["s"]}
            ]
        }"#;
        let m = model_from_json(&json::parse(text).unwrap()).unwrap();
        let g = m.graph.as_ref().expect("graph wiring parsed");
        assert_eq!(g.names, vec!["d1", "a1", "d2", "s", "out"]);
        assert_eq!(g.inbound[3], vec!["d2", "a1"]);
        assert_eq!(g.output.as_deref(), Some("out"));
        assert_eq!(m.output_shape().unwrap(), vec![2]);
        // Fixed point through serialize∘parse.
        let v = model_to_json(&m);
        let re = model_from_json(&json::parse(&json::to_string_pretty(&v)).unwrap()).unwrap();
        assert_eq!(model_to_json(&re), v);
    }

    #[test]
    fn rejects_malformed_graphs_with_context() {
        // (payload, expected error fragment). Cycle and dangling-edge
        // rejection are covered by the graph acceptance tests in
        // `rust/tests/plan.rs`; these cases cover the rest of the
        // validation surface.
        let dense_id = r#"{"type": "dense", "units": 2, "in": 2,
                           "weights": [1, 0, 0, 1], "bias": [0, 0]"#;
        let cases = [
            // merge arity: add with one input
            (
                format!(
                    r#"{{"name": "m", "input_shape": [2],
                        "layers": [
                          {dense_id}, "name": "d1", "inbound": ["input"]}},
                          {{"type": "add", "name": "s", "inbound": ["d1"]}}
                        ]}}"#
                ),
                "at least 2",
            ),
            // partial wiring: second layer unnamed
            (
                format!(
                    r#"{{"name": "m", "input_shape": [2],
                        "layers": [
                          {dense_id}, "name": "d1", "inbound": ["input"]}},
                          {{"type": "softmax"}}
                        ]}}"#
                ),
                "every layer",
            ),
            // unknown output node
            (
                format!(
                    r#"{{"name": "m", "input_shape": [2], "output": "nope",
                        "layers": [
                          {dense_id}, "name": "d1", "inbound": ["input"]}}
                        ]}}"#
                ),
                "output",
            ),
            // unreachable branch: d2 feeds nothing on the path to output
            (
                format!(
                    r#"{{"name": "m", "input_shape": [2], "output": "d1",
                        "layers": [
                          {dense_id}, "name": "d1", "inbound": ["input"]}},
                          {dense_id}, "name": "d2", "inbound": ["input"]}}
                        ]}}"#
                ),
                "contribute",
            ),
        ];
        for (payload, fragment) in &cases {
            let err = model_from_json(&json::parse(payload).unwrap())
                .expect_err(&format!("should reject: {payload}"));
            let chain = format!("{err:#}");
            assert!(
                chain.contains(fragment),
                "error for {payload}\nmust mention '{fragment}', got: {chain}"
            );
        }
    }

    #[test]
    fn sequential_models_reject_stray_output_field() {
        let text = r#"{
            "name": "m", "input_shape": [2], "output": "x",
            "layers": [{"type": "softmax"}]
        }"#;
        let err = model_from_json(&json::parse(text).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("graph wiring"), "{err:#}");
    }

    #[test]
    fn conv_roundtrip() {
        let text = r#"{
            "name": "c", "input_shape": [4, 4, 1],
            "layers": [
                {"type": "conv2d", "kh": 3, "kw": 3, "cin": 1, "cout": 2,
                 "stride": 1, "padding": "same",
                 "weights": [0.1,0.2,0.1,0.2,0.1,0.2,0.1,0.2,0.1,0.2,0.1,0.2,0.1,0.2,0.1,0.2,0.3,0.4],
                 "bias": [0.5, -0.5]},
                {"type": "relu"},
                {"type": "max_pool2d", "ph": 2, "pw": 2},
                {"type": "flatten"}
            ]
        }"#;
        let m = model_from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(m.output_shape().unwrap(), vec![8]);
        let re = model_from_json(&json::parse(&json::to_string_pretty(&model_to_json(&m))).unwrap())
            .unwrap();
        assert_eq!(re.param_count(), m.param_count());
    }
}
