//! Deterministic xoshiro256** PRNG.
//!
//! The registry snapshot has no `rand` crate, and the analysis/test stack
//! needs *reproducible* randomness anyway (property tests print the seed of
//! a failing case, synthetic datasets must be bit-identical between the
//! Python build path and the Rust runtime). xoshiro256** is tiny, fast and
//! has well-understood statistical quality.

/// xoshiro256** deterministic random number generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed. Any seed (including 0) is valid: the
    /// state is initialized via SplitMix64, which never yields all-zeros.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next_sm(), next_sm(), next_sm(), next_sm()],
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        // 53 top bits -> [0,1) with full double resolution.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Modulo bias is negligible for n << 2^64 (all our uses).
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300); // avoid log(0)
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_is_roughly_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean = {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean = {mean}");
        assert!((var - 1.0).abs() < 0.03, "var = {var}");
    }

    #[test]
    fn below_covers_all_buckets() {
        let mut r = Rng::new(5);
        let mut hits = [0usize; 10];
        for _ in 0..10_000 {
            hits[r.below(10)] += 1;
        }
        for (i, &h) in hits.iter().enumerate() {
            assert!(h > 700, "bucket {i} underpopulated: {h}");
        }
    }

    #[test]
    fn int_range_inclusive() {
        let mut r = Rng::new(9);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            let v = r.int_range(-3, 3);
            assert!((-3..=3).contains(&v));
            saw_lo |= v == -3;
            saw_hi |= v == 3;
        }
        assert!(saw_lo && saw_hi);
    }
}
