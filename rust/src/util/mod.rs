//! Small shared utilities: deterministic PRNG, timing helpers.

pub mod rng;
pub mod timing;

pub use rng::Rng;
pub use timing::Stopwatch;
