//! Wall-clock timing helpers used by the analysis driver and the bench
//! harness (Table I reports an "analysis time" column).

use std::time::{Duration, Instant};

/// A simple stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Elapsed time since start.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed seconds as f64.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Restart and return the elapsed time of the lap just finished.
    pub fn lap(&mut self) -> Duration {
        let d = self.start.elapsed();
        self.start = Instant::now();
        d
    }
}

/// Render a duration in a human unit (`12.3 s`, `4.5 ms`, `780 µs`, `2.1 h`),
/// matching the mixed units the paper's Table I uses.
pub fn human_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 3600.0 {
        format!("{:.1} h", s / 3600.0)
    } else if s >= 60.0 {
        format!("{:.1} min", s / 60.0)
    } else if s >= 1.0 {
        format!("{:.2} s", s)
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.0} µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_units() {
        assert_eq!(human_duration(Duration::from_secs_f64(7200.0)), "2.0 h");
        assert_eq!(human_duration(Duration::from_secs_f64(90.0)), "1.5 min");
        assert_eq!(human_duration(Duration::from_secs_f64(12.0)), "12.00 s");
        assert_eq!(human_duration(Duration::from_secs_f64(0.1)), "100.00 ms");
        assert_eq!(human_duration(Duration::from_secs_f64(5e-5)), "50 µs");
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        let a = sw.secs();
        let b = sw.secs();
        assert!(b >= a);
    }
}
