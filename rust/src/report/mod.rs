//! Result reporting: Table-I-style tables (console + markdown).

use crate::analysis::ModelAnalysis;
use crate::util::timing::human_duration;
use std::fmt::Write as _;
use std::time::Duration;

/// Format a bound in units of u the way the paper prints them (`1.1u`,
/// `22.4u`, or `-` when none exists).
pub fn fmt_bound_u(b: f64) -> String {
    if b.is_infinite() {
        "-".to_string()
    } else if b == 0.0 {
        "0u".to_string()
    } else if b >= 100.0 {
        format!("{b:.0}u")
    } else {
        format!("{b:.1}u")
    }
}

/// One row of the Table-I reproduction.
#[derive(Clone, Debug)]
pub struct TableRow {
    /// Model name.
    pub name: String,
    /// Worst absolute bound, units of u.
    pub max_abs_u: f64,
    /// Worst relative bound, units of u (+inf prints as `-`).
    pub max_rel_u: f64,
    /// Average per-class analysis time.
    pub time_per_class: Duration,
    /// Minimum certified precision, if any.
    pub required_k: Option<u32>,
}

impl TableRow {
    /// Project a [`ModelAnalysis`] onto its Table-I row.
    pub fn from_analysis(a: &ModelAnalysis) -> TableRow {
        TableRow {
            name: a.model_name.clone(),
            max_abs_u: a.max_abs_u,
            max_rel_u: a.max_rel_u,
            time_per_class: Duration::from_secs_f64(a.secs_per_class()),
            required_k: a.required_k,
        }
    }
}

/// Render rows as the paper's Table I (markdown).
pub fn table1_markdown(rows: &[TableRow], p_star: f64, u_max_log2: i32) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "| model | max absolute error in u | max relative error in u | analysis time | required precision (p* = {p_star}) |"
    );
    let _ = writeln!(s, "|---|---|---|---|---|");
    for r in rows {
        let k = match r.required_k {
            Some(k) => format!("k = {k}"),
            None => "-".to_string(),
        };
        let _ = writeln!(
            s,
            "| {} | {} | {} | {} per class | {} |",
            r.name,
            fmt_bound_u(r.max_abs_u),
            fmt_bound_u(r.max_rel_u),
            human_duration(r.time_per_class),
            k
        );
    }
    let _ = writeln!(s, "\nNumerical results for experiments with u < 2^{u_max_log2}.");
    s
}

/// Render rows as an aligned console table.
pub fn table1_console(rows: &[TableRow], p_star: f64) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "{:<16} {:>12} {:>12} {:>16} {:>14}",
        "model", "max abs (u)", "max rel (u)", "time/class", "required k"
    );
    let _ = writeln!(s, "{}", "-".repeat(74));
    for r in rows {
        let k = match r.required_k {
            Some(k) => format!("k = {k}"),
            None => "-".into(),
        };
        let _ = writeln!(
            s,
            "{:<16} {:>12} {:>12} {:>16} {:>14}",
            r.name,
            fmt_bound_u(r.max_abs_u),
            fmt_bound_u(r.max_rel_u),
            human_duration(r.time_per_class),
            k
        );
    }
    let _ = writeln!(s, "(p* = {p_star})");
    s
}

/// Per-class detail table for one model analysis.
pub fn per_class_console(a: &ModelAnalysis) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "{:<8} {:>12} {:>12} {:>14} {:>10} {:>10}",
        "class", "abs (u)", "rel (u)", "top-1 rel (u)", "predicted", "ambiguous"
    );
    for c in &a.per_class {
        let _ = writeln!(
            s,
            "{:<8} {:>12} {:>12} {:>14} {:>10} {:>10}",
            c.class,
            fmt_bound_u(c.max_abs_u),
            fmt_bound_u(c.max_rel_u),
            fmt_bound_u(c.top1_rel_u),
            c.predicted,
            c.ambiguous
        );
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row() -> TableRow {
        TableRow {
            name: "digits".into(),
            max_abs_u: 1.1,
            max_rel_u: 3.4,
            time_per_class: Duration::from_secs(12),
            required_k: Some(8),
        }
    }

    #[test]
    fn bound_formatting() {
        assert_eq!(fmt_bound_u(1.1), "1.1u");
        assert_eq!(fmt_bound_u(22.43), "22.4u");
        assert_eq!(fmt_bound_u(f64::INFINITY), "-");
        assert_eq!(fmt_bound_u(0.0), "0u");
        assert_eq!(fmt_bound_u(12345.0), "12345u");
    }

    #[test]
    fn markdown_contains_paper_columns() {
        let md = table1_markdown(&[row()], 0.60, -7);
        assert!(md.contains("max absolute error in u"));
        assert!(md.contains("| digits | 1.1u | 3.4u | 12.00 s per class | k = 8 |"));
        assert!(md.contains("u < 2^-7"));
    }

    #[test]
    fn console_renders() {
        let c = table1_console(&[row()], 0.60);
        assert!(c.contains("digits") && c.contains("k = 8"));
    }
}
