//! Outward-rounded algebra on error *bounds*.
//!
//! CAA bounds (`δ̄`, `ε̄`) are non-negative f64s in units of
//! `u = 2^(1-k)`, with `+inf` meaning "no bound exists". Every arithmetic
//! step on bounds must round **up** so the result stays an upper bound;
//! the helpers here do that with one-ulp bumps. Second-order terms (the
//! `ε_r ε_s u` cross terms of the paper's eq. (8)) are kept, evaluated at
//! the context's `u_max`, never dropped.

use crate::interval::round::bump_up;

/// Upper-rounded addition of bounds. `inf + anything = inf`.
#[inline(always)]
pub fn badd(a: f64, b: f64) -> f64 {
    debug_assert!(a >= 0.0 && b >= 0.0);
    let s = a + b;
    if s.is_infinite() {
        f64::INFINITY
    } else {
        bump_up(s, 1)
    }
}

/// Upper-rounded multiplication of bounds with the convention
/// `0 * inf = 0` (a zero bound means the quantity/error is exactly zero,
/// which annihilates).
#[inline(always)]
pub fn bmul(a: f64, b: f64) -> f64 {
    debug_assert!(a >= 0.0 || a.is_nan(), "negative bound {a}");
    debug_assert!(b >= 0.0 || b.is_nan(), "negative bound {b}");
    if a == 0.0 || b == 0.0 {
        return 0.0;
    }
    let p = a * b;
    if p.is_infinite() {
        f64::INFINITY
    } else {
        bump_up(p, 1)
    }
}

/// Upper-rounded division `a / b` for `b > 0`.
#[inline(always)]
pub fn bdiv(a: f64, b: f64) -> f64 {
    debug_assert!(a >= 0.0 && b > 0.0);
    if a == 0.0 {
        return 0.0;
    }
    let q = a / b;
    if q.is_infinite() {
        f64::INFINITY
    } else {
        bump_up(q, 1)
    }
}

/// Relative-bound combination for a *chain of multiplicative error factors*:
/// given `ε̄_1, ..., ε̄_n`, returns `c` such that for all `|ε_i| <= ε̄_i` and
/// all `0 < u <= u_max`:
///
/// ```text
/// | Π (1 + ε_i u)  -  1 |  <=  c · u
/// ```
///
/// Recurrence: `c_0 = 0`, `c_{k+1} = c_k + ε̄_{k+1} (1 + c_k u_max)`,
/// since `P_{k+1} - 1 = (P_k - 1) + ε_{k+1} u P_k` and
/// `|P_k| <= 1 + c_k u_max`. Each step rounds up.
/// Two-factor specialization of [`rel_chain`] (the add/sub hot path).
#[inline(always)]
pub fn rel_chain2(a: f64, b: f64, u_max: f64) -> f64 {
    if a.is_infinite() || b.is_infinite() {
        return f64::INFINITY;
    }
    badd(a, bmul(b, badd(1.0, bmul(a, u_max))))
}

/// Three-factor specialization of [`rel_chain`] (the mul hot path).
#[inline(always)]
pub fn rel_chain3(a: f64, b: f64, c: f64, u_max: f64) -> f64 {
    rel_chain2(rel_chain2(a, b, u_max), c, u_max)
}

/// Chain an arbitrary sequence of relative bounds (in units of u):
/// `(1+c·u)(1+e·u) - 1` folded left to right, rounded up.
#[inline]
pub fn rel_chain(bounds: &[f64], u_max: f64) -> f64 {
    debug_assert!(u_max > 0.0 && u_max <= 0.5);
    let mut c: f64 = 0.0;
    for &e in bounds {
        if e.is_infinite() || c.is_infinite() {
            return f64::INFINITY;
        }
        let p = badd(1.0, bmul(c, u_max));
        c = badd(c, bmul(e, p));
    }
    c
}

/// Relative bound for the *inverse* factor `1 / (1 + ε u)`:
/// `|1/(1+εu) - 1| <= ε̄/(1 - ε̄ u_max) · u` provided `ε̄ u_max < 1`;
/// `+inf` otherwise.
pub fn rel_inverse(eps: f64, u_max: f64) -> f64 {
    if eps.is_infinite() {
        return f64::INFINITY;
    }
    let denom = 1.0 - eps * u_max;
    if denom <= 0.0 {
        return f64::INFINITY;
    }
    // Round the denominator *down* (it divides), the quotient up.
    let denom = crate::interval::round::bump_down(denom, 1);
    if denom <= 0.0 {
        return f64::INFINITY;
    }
    bdiv(eps, denom)
}

/// Relative bound induced on `exp` output by an *absolute* bound `δ̄` on its
/// input: `|e^{δu} - 1| <= (e^{δ̄ u_max} - 1)/u_max · u` for `0 < u <= u_max`
/// (the quotient `(e^{δ̄u}-1)/u` is increasing in `u`).
pub fn exp_abs_to_rel(delta: f64, u_max: f64) -> f64 {
    if delta.is_infinite() {
        return f64::INFINITY;
    }
    if delta == 0.0 {
        return 0.0;
    }
    let t = bump_up((delta * u_max).exp_m1(), crate::interval::round::ELEM_SLACK_ULPS);
    bdiv(t, u_max)
}

/// Absolute bound induced on `log` output by a *relative* bound `ε̄` on its
/// input: `|log(1 + εu)| <= |log(1 - ε̄ u_max)|/u_max · u` (worst case at the
/// negative edge), provided `ε̄ u_max < 1`.
pub fn log_rel_to_abs(eps: f64, u_max: f64) -> f64 {
    if eps.is_infinite() {
        return f64::INFINITY;
    }
    if eps == 0.0 {
        return 0.0;
    }
    let arg = 1.0 - eps * u_max;
    if arg <= 0.0 {
        return f64::INFINITY;
    }
    let t = bump_up((-arg.ln()).max(0.0), crate::interval::round::ELEM_SLACK_ULPS);
    bdiv(t, u_max)
}

/// Relative bound for `sqrt(1 + εu)`: `|sqrt(1+εu) - 1| <= c u` with
/// `c = ε̄ / (1 + sqrt(1 - ε̄ u_max))` (exact algebra; rounded up), provided
/// `ε̄ u_max <= 1`.
pub fn sqrt_rel(eps: f64, u_max: f64) -> f64 {
    if eps.is_infinite() {
        return f64::INFINITY;
    }
    if eps == 0.0 {
        return 0.0;
    }
    let arg = 1.0 - eps * u_max;
    if arg < 0.0 {
        return f64::INFINITY;
    }
    let denom = crate::interval::round::bump_down(1.0 + arg.sqrt(), 2);
    bdiv(eps, denom)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    const U: f64 = 0.0078125; // 2^-7, the paper's u bound

    #[test]
    fn badd_bmul_basics() {
        assert!(badd(1.0, 2.0) >= 3.0);
        assert_eq!(badd(f64::INFINITY, 1.0), f64::INFINITY);
        assert_eq!(bmul(0.0, f64::INFINITY), 0.0);
        assert_eq!(bmul(f64::INFINITY, 2.0), f64::INFINITY);
        assert!(bmul(3.0, 4.0) >= 12.0);
        assert!(bdiv(1.0, 3.0) >= 1.0 / 3.0);
    }

    #[test]
    fn rel_chain_empirical() {
        // For random ε_i within bounds and random u <= u_max the product
        // deviation must stay within rel_chain's answer.
        prop::check("rel-chain-sound", |rng| {
            let n = 1 + rng.below(6);
            let bounds: Vec<f64> = (0..n).map(|_| rng.range(0.0, 4.0)).collect();
            let c = rel_chain(&bounds, U);
            let u = rng.range(1e-9, U);
            let mut p = 1.0f64;
            for &b in &bounds {
                let e = rng.range(-b, b);
                p *= 1.0 + e * u;
            }
            assert!(
                (p - 1.0).abs() <= c * u * (1.0 + 1e-12),
                "|{p} - 1| > {c} * {u}"
            );
        });
    }

    #[test]
    fn rel_chain_first_order() {
        // c must be at least the sum of the bounds (first-order term).
        let c = rel_chain(&[0.5, 0.5, 1.0], U);
        assert!(c >= 2.0);
        assert!(c < 2.1, "second-order blowup too large: {c}");
        assert_eq!(rel_chain(&[f64::INFINITY], U), f64::INFINITY);
        assert_eq!(rel_chain(&[], U), 0.0);
    }

    #[test]
    fn rel_inverse_sound() {
        prop::check("rel-inverse-sound", |rng| {
            let eb = rng.range(0.0, 8.0);
            let c = rel_inverse(eb, U);
            let u = rng.range(1e-9, U);
            let e = rng.range(-eb, eb);
            let v = 1.0 / (1.0 + e * u) - 1.0;
            assert!(v.abs() <= c * u * (1.0 + 1e-12), "|{v}| > {c}*{u}");
        });
        assert_eq!(rel_inverse(1.0 / U + 1.0, U), f64::INFINITY);
    }

    #[test]
    fn exp_abs_to_rel_sound() {
        prop::check("exp-abs-rel-sound", |rng| {
            let db = rng.range(0.0, 10.0);
            let c = exp_abs_to_rel(db, U);
            let u = rng.range(1e-9, U);
            let d = rng.range(-db, db);
            let v = (d * u).exp_m1();
            assert!(v.abs() <= c * u * (1.0 + 1e-12));
        });
        // First-order: for small δ̄·u_max the factor is ~δ̄.
        let c = exp_abs_to_rel(1.0, U);
        assert!((1.0..1.01).contains(&c), "c = {c}");
    }

    #[test]
    fn log_rel_to_abs_sound() {
        prop::check("log-rel-abs-sound", |rng| {
            let eb = rng.range(0.0, 10.0);
            let c = log_rel_to_abs(eb, U);
            let u = rng.range(1e-9, U);
            let e = rng.range(-eb, eb);
            let v = (1.0 + e * u).ln();
            assert!(v.abs() <= c * u * (1.0 + 1e-12));
        });
    }

    #[test]
    fn sqrt_rel_sound() {
        prop::check("sqrt-rel-sound", |rng| {
            let eb = rng.range(0.0, 10.0);
            let c = sqrt_rel(eb, U);
            let u = rng.range(1e-9, U);
            let e = rng.range(-eb, eb);
            if 1.0 + e * u < 0.0 {
                return;
            }
            let v = (1.0 + e * u).sqrt() - 1.0;
            assert!(v.abs() <= c * u * (1.0 + 1e-12));
        });
        // sqrt halves relative error to first order.
        let c = sqrt_rel(2.0, U);
        assert!((1.0..1.02).contains(&c), "c = {c}");
    }
}
