//! Basic CAA operations: `+`, `-`, `×`, `/`, negation.
//!
//! Every operation produces all entries of the result object: the concrete
//! fp trace value, the ideal and rounded range enclosures (IA), and the
//! absolute/relative bounds combined per the paper's §III rules, with all
//! second-order terms kept (evaluated at `u_max`) and all bound arithmetic
//! rounded upward.

use super::bounds::{badd, bmul, rel_chain2, rel_chain3, rel_inverse};
use super::{relative_blowup, Caa, Ctx, RND_BASIC};
use crate::interval::Interval;

impl Caa {
    /// Is this quantity *exactly* zero (in both the ideal and every rounded
    /// execution)? Adding/multiplying by it is error-free.
    pub fn is_exact_zero(&self) -> bool {
        self.ideal == Interval::ZERO && self.abs == 0.0
    }

    /// Is this quantity exactly one?
    pub fn is_exact_one(&self) -> bool {
        self.ideal == Interval::ONE && self.abs == 0.0 && self.rel == 0.0
    }

    /// FP addition `self ⊕ other`.
    pub fn add(&self, other: &Caa, ctx: &Ctx) -> Caa {
        // x + 0 = x exactly (IEEE): no rounding, no bound change. This is
        // what keeps sparse inputs (background pixels) free.
        if other.is_exact_zero() {
            return self.clone();
        }
        if self.is_exact_zero() {
            return other.clone();
        }
        if ctx.decorrelation && self.id == other.id {
            // x + x = 2x: exact doubling of the error, no decorrelation loss;
            // the doubling itself is exact in binary FP (exponent bump).
            return Caa::make(
                ctx,
                self.fp + self.fp,
                self.ideal.scale(2.0),
                self.rounded.scale(2.0),
                bmul(2.0, self.abs),
                self.rel,
            );
        }
        self.linear_combine(other, ctx, /*sub=*/ false)
    }

    /// FP subtraction `self ⊖ other`. Decorrelation: `x - x = 0` exactly.
    /// Bound labels: if `other` is a known upper bound of `self`, the ideal
    /// and rounded ranges are clipped to `(-inf, 0]` (and symmetrically).
    pub fn sub(&self, other: &Caa, ctx: &Ctx) -> Caa {
        if other.is_exact_zero() {
            return self.clone();
        }
        if self.is_exact_zero() {
            return other.neg();
        }
        if ctx.decorrelation && self.id == other.id {
            return Caa::exact(0.0);
        }
        let mut r = self.linear_combine(other, ctx, /*sub=*/ true);
        if ctx.labels {
            let nonpos = Interval::new(f64::NEG_INFINITY, 0.0);
            let nonneg = Interval::new(0.0, f64::INFINITY);
            // self <= other (other is self's upper label, or self is
            // other's lower label) => self - other <= 0.
            let le = self.upper.as_ref().is_some_and(|m| m.id == other.id)
                || other.lower.as_ref().is_some_and(|m| m.id == self.id);
            // self >= other => self - other >= 0.
            let ge = self.lower.as_ref().is_some_and(|m| m.id == other.id)
                || other.upper.as_ref().is_some_and(|m| m.id == self.id);
            if le {
                r.ideal = r.ideal.intersect(&nonpos).unwrap_or(Interval::ZERO);
                r.rounded = r.rounded.intersect(&nonpos).unwrap_or(Interval::ZERO);
            }
            if ge {
                r.ideal = r.ideal.intersect(&nonneg).unwrap_or(Interval::ZERO);
                r.rounded = r.rounded.intersect(&nonneg).unwrap_or(Interval::ZERO);
            }
        }
        r
    }

    /// Shared implementation of ⊕ / ⊖ (paper eq. (7)–(8)).
    fn linear_combine(&self, other: &Caa, ctx: &Ctx, sub: bool) -> Caa {
        let (ob_ideal, ob_rounded) = if sub {
            (-other.ideal, -other.rounded)
        } else {
            (other.ideal, other.rounded)
        };
        let fp = if sub { self.fp - other.fp } else { self.fp + other.fp };
        let ideal = self.ideal + ob_ideal;
        let rounded_pre = self.rounded + ob_rounded;
        let rounded = relative_blowup(rounded_pre, RND_BASIC, ctx.u_max);

        // Absolute: errors add; rounding contributes (1/2)·sup|r̂+ŝ| in u.
        let abs = badd(
            badd(self.abs, other.abs),
            bmul(RND_BASIC, rounded_pre.mag()),
        );

        // Relative (paper eq. (8)): amplification factors α_r = r/(r+s),
        // α_s = s/(r+s) bounded by IA on the ideal ranges; no finite bound
        // when the ideal sum can vanish (catastrophic cancellation).
        // (sup|r/(r+s)| <= sup|r| / inf|r+s|, one rounded division — much
        // cheaper than a full interval division, identical bound.)
        let rel = if self.rel.is_finite() && other.rel.is_finite() && ideal.excludes_zero() {
            let denom = ideal.mig();
            let alpha_r = crate::caa::bdiv(self.ideal.mag(), denom);
            let alpha_s = crate::caa::bdiv(ob_ideal.mag(), denom);
            let eps_in = badd(bmul(alpha_r, self.rel), bmul(alpha_s, other.rel));
            rel_chain2(eps_in, RND_BASIC, ctx.u_max)
        } else {
            f64::INFINITY
        };

        Caa::make(ctx, fp, ideal, rounded, abs, rel)
    }

    /// FP multiplication `self ⊗ other`.
    pub fn mul(&self, other: &Caa, ctx: &Ctx) -> Caa {
        // x * 0 = 0 and x * 1 = x exactly. (The zero annihilation assumes
        // the runtime value is finite — guaranteed for DNNs, whose
        // quantities are bounded; the paper's analysis likewise excludes
        // overflow.)
        if self.is_exact_zero() || other.is_exact_zero() {
            return Caa::exact(0.0);
        }
        if other.is_exact_one() {
            return self.clone();
        }
        if self.is_exact_one() {
            return other.clone();
        }
        if ctx.decorrelation && self.id == other.id {
            // x * x = x²: use the square image (no decorrelation loss in
            // the range) and the doubled relative bound.
            let ideal = self.ideal.square();
            let rounded_pre = self.rounded.square();
            let rel = rel_chain3(self.rel, self.rel, RND_BASIC, ctx.u_max);
            let abs = badd(
                badd(bmul(2.0, bmul(self.ideal.mag(), self.abs)), bmul(bmul(self.abs, self.abs), ctx.u_max)),
                bmul(RND_BASIC, rounded_pre.mag()),
            );
            return Caa::make(
                ctx,
                self.fp * self.fp,
                ideal,
                relative_blowup(rounded_pre, RND_BASIC, ctx.u_max),
                abs,
                rel,
            );
        }
        let fp = self.fp * other.fp;
        let ideal = self.ideal * other.ideal;
        let rounded_pre = self.rounded * other.rounded;
        let rounded = relative_blowup(rounded_pre, RND_BASIC, ctx.u_max);

        // Relative: (1+ε_r u)(1+ε_s u)(1+ε_∘ u).
        let rel = rel_chain3(self.rel, other.rel, RND_BASIC, ctx.u_max);

        // Absolute, direct: r̂ŝ = rs + (r δ_s + s δ_r) u + δ_r δ_s u².
        let abs = badd(
            badd(
                badd(
                    bmul(self.ideal.mag(), other.abs),
                    bmul(other.ideal.mag(), self.abs),
                ),
                bmul(bmul(self.abs, other.abs), ctx.u_max),
            ),
            bmul(RND_BASIC, rounded_pre.mag()),
        );

        Caa::make(ctx, fp, ideal, rounded, abs, rel)
    }

    /// FP division `self ⊘ other`. Decorrelation: `x / x = 1` exactly
    /// (IEEE RN division of equal operands is exact).
    pub fn div(&self, other: &Caa, ctx: &Ctx) -> Caa {
        if ctx.decorrelation && self.id == other.id && self.ideal.excludes_zero() {
            return Caa::exact(1.0);
        }
        if self.is_exact_zero() {
            return Caa::exact(0.0);
        }
        if other.is_exact_one() {
            return self.clone();
        }
        let fp = self.fp / other.fp;
        let ideal = self.ideal / other.ideal;
        let rounded_pre = self.rounded / other.rounded;
        let rounded = relative_blowup(rounded_pre, RND_BASIC, ctx.u_max);

        // Relative: (1+ε_r u) / (1+ε_s u) · (1+ε_∘ u).
        let rel = rel_chain3(
            self.eff_rel(),
            rel_inverse(other.eff_rel(), ctx.u_max),
            RND_BASIC,
            ctx.u_max,
        );

        // Direct absolute rule (kicks in when the denominator's relative
        // bound collapses, ε̄_s·u_max >= 1 — e.g. softmax sums over noisy
        // exponentials at coarse u_max):
        //   ŷ = (r + δ_r u)/(s + δ_s u) = y + (δ_r - y·δ_s)·u/ŝ
        // so |δ_y| <= (δ̄_r + sup|y|·δ̄_s) / inf|ŝ|, with ŝ ranging over the
        // denominator's *rounded* enclosure; plus the division rounding.
        let abs = {
            let den_mig = other.rounded.mig();
            if den_mig > 0.0 && ideal.mag().is_finite() {
                let num = badd(self.eff_abs(), bmul(ideal.mag(), other.eff_abs()));
                badd(
                    crate::caa::bdiv(num, den_mig),
                    bmul(RND_BASIC, rounded_pre.mag()),
                )
            } else {
                f64::INFINITY
            }
        };
        Caa::make(ctx, fp, ideal, rounded, abs, rel)
    }

    /// Multiply by a learned scalar parameter `w` (the dot-product hot
    /// path): semantically identical to `Caa::param(ctx, w).mul(self, ctx)`
    /// but with the interval work reduced to scaling (w is a point) and
    /// without materializing the intermediate parameter object.
    pub fn mul_const(&self, w: f64, ctx: &Ctx) -> Caa {
        if w == 0.0 || self.is_exact_zero() {
            return Caa::exact(0.0);
        }
        if w == 1.0 {
            return self.clone();
        }
        let fp = self.fp * w;
        let ideal = self.ideal.scale(w);
        let rounded_pre = self.rounded.scale(w);
        let rounded = relative_blowup(rounded_pre, RND_BASIC, ctx.u_max);
        // Relative: (1+ε_x u)(1+ε_w u)(1+ε_∘ u), ε̄_w = 1/2 representation.
        let rel = rel_chain3(self.rel, RND_BASIC, RND_BASIC, ctx.u_max);
        // Absolute: ŵx̂ = wx + (w δ_x + x δ_w) u + δ_w δ_x u², δ̄_w = |w|/2.
        let aw = w.abs();
        let dw = 0.5 * aw;
        let abs = badd(
            badd(
                badd(bmul(aw, self.abs), bmul(self.ideal.mag(), dw)),
                bmul(bmul(self.abs, dw), ctx.u_max),
            ),
            bmul(RND_BASIC, rounded_pre.mag()),
        );
        Caa::make(ctx, fp, ideal, rounded, abs, rel)
    }

    /// Exact negation (sign flip is error-free in IEEE FP).
    pub fn neg(&self) -> Caa {
        let mut r = self.clone();
        r.id = super::fresh_id();
        r.fp = -r.fp;
        r.ideal = -r.ideal;
        r.rounded = -r.rounded;
        // A bound label x <= M becomes -x >= -M; we drop labels instead of
        // negating them (sound, only loses optional insight).
        r.upper = None;
        r.lower = None;
        r
    }

    /// Multiply by an exact constant scale that is a power of two
    /// (error-free in binary FP: exponent shift only).
    pub fn scale_pow2(&self, c: f64, ctx: &Ctx) -> Caa {
        debug_assert!(c != 0.0 && c.abs().log2().fract() == 0.0, "{c} is not a power of 2");
        Caa::make(
            ctx,
            self.fp * c,
            self.ideal.scale(c),
            self.rounded.scale(c),
            bmul(self.abs, c.abs()),
            self.rel,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> Ctx {
        Ctx::new()
    }

    #[test]
    fn add_params_carry_half_ulp_each() {
        let c = ctx();
        let a = Caa::param(&c, 1.5);
        let b = Caa::param(&c, 2.5);
        let s = a.add(&b, &c);
        assert_eq!(s.fp(), 4.0);
        assert!(s.ideal().contains(4.0));
        // δ̄ ~ 0.5*1.5 + 0.5*2.5 + 0.5*4 = 4.0 (± rounding slack)
        assert!(s.abs_bound() >= 4.0 && s.abs_bound() < 4.2, "{}", s.abs_bound());
        // ε̄ ~ α-weighted 1/2 + 1/2 rounding ~ 1.0
        assert!(s.rel_bound() >= 1.0 && s.rel_bound() < 1.1, "{}", s.rel_bound());
    }

    #[test]
    fn cancellation_kills_relative_not_absolute() {
        let c = ctx();
        let a = Caa::input(&c, Interval::new(0.0, 2.0), 1.0);
        let b = Caa::input(&c, Interval::new(0.0, 2.0), 1.0);
        let d = a.sub(&b, &c);
        assert!(d.rel_bound().is_infinite(), "cancelling sub must lose rel bound");
        assert!(d.abs_bound().is_finite(), "abs bound must survive");
        assert!(d.ideal().contains(0.0));
    }

    #[test]
    fn decorrelation_sub_is_exact_zero() {
        let c = ctx();
        let a = Caa::input(&c, Interval::new(-1.0, 1.0), 0.5);
        let z = a.sub(&a.clone(), &c); // clone shares the id (assignment)
        assert_eq!(z.ideal(), Interval::ZERO);
        assert_eq!(z.abs_bound(), 0.0);
        assert_eq!(z.rel_bound(), 0.0);

        let no = ctx().no_decorrelation();
        let a2 = Caa::input(&no, Interval::new(-1.0, 1.0), 0.5);
        let z2 = a2.sub(&a2.clone(), &no);
        assert!(z2.ideal().width() >= 4.0, "without decorrelation [-1,1]-[-1,1] = [-2,2]");
    }

    #[test]
    fn decorrelation_div_is_exact_one() {
        let c = ctx();
        let a = Caa::input(&c, Interval::new(1.0, 2.0), 1.5);
        let q = a.div(&a.clone(), &c);
        assert_eq!(q.ideal(), Interval::ONE);
        assert_eq!(q.rel_bound(), 0.0);
    }

    #[test]
    fn mul_rel_is_sum_plus_rounding() {
        let c = ctx();
        let a = Caa::param(&c, 3.0);
        let b = Caa::param(&c, -2.0);
        let p = a.mul(&b, &c);
        assert_eq!(p.fp(), -6.0);
        assert!(p.ideal().contains(-6.0));
        // ε̄ ~ 1/2 + 1/2 + 1/2 = 1.5 plus second order
        assert!(p.rel_bound() >= 1.5 && p.rel_bound() < 1.6, "{}", p.rel_bound());
        assert!(p.abs_bound().is_finite());
    }

    #[test]
    fn div_by_zero_straddling_interval() {
        let c = ctx();
        let a = Caa::param(&c, 1.0);
        let b = Caa::input(&c, Interval::new(-1.0, 1.0), 0.5);
        let q = a.div(&b, &c);
        // The value range is unbounded (divisor may vanish)...
        assert_eq!(q.ideal(), Interval::ENTIRE);
        // ...so no absolute bound exists; the *relative* bound is pointwise
        // and survives (for any input with b != 0 the quotient's relative
        // error is small even though its magnitude is unbounded).
        assert!(q.abs_bound().is_infinite());
        assert!(q.rel_bound().is_finite());
        // But a divisor whose own relative error is unbounded kills it.
        let bad = Caa::make(&c, 0.5, Interval::new(-1.0, 1.0), Interval::new(-1.0, 1.0), 1.0, f64::INFINITY);
        let q2 = a.div(&bad, &c);
        assert!(q2.rel_bound().is_infinite());
    }

    #[test]
    fn neg_is_exact() {
        let c = ctx();
        let a = Caa::param(&c, 7.0);
        let n = a.neg();
        assert_eq!(n.fp(), -7.0);
        assert_eq!(n.abs_bound(), a.abs_bound());
        assert_eq!(n.rel_bound(), a.rel_bound());
        assert!(n.ideal().contains(-7.0));
    }

    #[test]
    fn scale_pow2_no_rounding() {
        let c = ctx();
        let a = Caa::param(&c, 3.0);
        let s = a.scale_pow2(0.25, &c);
        assert_eq!(s.fp(), 0.75);
        assert_eq!(s.rel_bound(), a.rel_bound());
    }

    #[test]
    fn exact_constants_are_free() {
        let c = ctx();
        let one = Caa::exact(1.0);
        let x = Caa::param(&c, 5.0);
        let y = x.mul(&one, &c);
        // Only the multiplication rounding is added: 1/2 + 1/2 ~ 1.0.
        assert!(y.rel_bound() < 1.01, "{}", y.rel_bound());
    }

    #[test]
    fn label_clips_subtraction() {
        let c = ctx();
        let m = std::sync::Arc::new(Caa::input(&c, Interval::new(0.0, 10.0), 5.0));
        let mut x = Caa::input(&c, Interval::new(0.0, 10.0), 3.0);
        x.set_upper(&m);
        let d = x.sub(&m, &c); // x <= m, so x - m <= 0
        assert!(d.ideal().hi() <= 0.0, "ideal {} must be nonpositive", d.ideal());
        assert!(d.rounded().hi() <= 0.0);

        // Without labels the same subtraction spans [-10, 10].
        let nl = ctx().no_labels();
        let m2 = std::sync::Arc::new(Caa::input(&nl, Interval::new(0.0, 10.0), 5.0));
        let mut x2 = Caa::input(&nl, Interval::new(0.0, 10.0), 3.0);
        x2.set_upper(&m2);
        let d2 = x2.sub(&m2, &nl);
        assert!(d2.ideal().hi() > 0.0);
    }

    #[test]
    fn ia_only_ctx_tracks_no_bounds() {
        let c = ctx().ia_only();
        let a = Caa::param(&c, 2.0);
        let b = Caa::param(&c, 3.0);
        let s = a.add(&b, &c);
        assert!(s.abs_bound().is_infinite() && s.rel_bound().is_infinite());
        assert!(s.ideal().contains(5.0)); // ranges still tracked
    }
}
