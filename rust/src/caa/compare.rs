//! Comparison-based operations: `max`, `min`, `relu`, `abs`, and the
//! labeled vector max used by softmax.
//!
//! These are the `if`-statements the paper's §III control-flow discussion
//! covers: they select among values rather than computing new ones, are
//! error-free as operations (no rounding), and are 1-Lipschitz, so absolute
//! bounds propagate with `δ̄' = max(δ̄_i)` even when the rounded comparison
//! picks a different branch than the ideal one.

use super::{Caa, Ctx};
use crate::interval::Interval;
use std::sync::Arc;

impl Caa {
    /// `max(self, other)`. Comparison only — no rounding error.
    pub fn max(&self, other: &Caa, ctx: &Ctx) -> Caa {
        let fp = self.fp.max(other.fp);
        let ideal = self.ideal.max_i(&other.ideal);
        let rounded = self.rounded.max_i(&other.rounded);
        let abs = self.eff_abs().max(other.eff_abs());
        // Relative bound survives only when both operands are ideally
        // strictly positive (see module doc; sign flips break rel).
        let rel = if self.ideal.is_strictly_pos() && other.ideal.is_strictly_pos() {
            self.eff_rel().max(other.eff_rel())
        } else {
            f64::INFINITY
        };
        Caa::make(ctx, fp, ideal, rounded, abs, rel)
    }

    /// `min(self, other)`.
    pub fn min(&self, other: &Caa, ctx: &Ctx) -> Caa {
        let fp = self.fp.min(other.fp);
        let ideal = self.ideal.min_i(&other.ideal);
        let rounded = self.rounded.min_i(&other.rounded);
        let abs = self.eff_abs().max(other.eff_abs());
        let rel = if self.ideal.is_strictly_neg() && other.ideal.is_strictly_neg() {
            self.eff_rel().max(other.eff_rel())
        } else if self.ideal.is_strictly_pos() && other.ideal.is_strictly_pos() {
            self.eff_rel().max(other.eff_rel())
        } else {
            f64::INFINITY
        };
        Caa::make(ctx, fp, ideal, rounded, abs, rel)
    }

    /// `ReLU(x) = max(x, 0)` (paper eq. (2)). Error-free as an operation;
    /// 1-Lipschitz for the absolute bound. The relative bound survives only
    /// on inputs that are ideally strictly positive (where ReLU is the
    /// identity).
    pub fn relu(&self, ctx: &Ctx) -> Caa {
        if self.ideal.hi() <= 0.0 && self.rounded.hi() <= 0.0 {
            // Ideal and computed branch agree: the output is exactly 0.
            return Caa::exact(0.0);
        }
        if self.ideal.lo() > 0.0 && self.rounded.lo() > 0.0 {
            // ReLU is the identity on this value — including its id
            // (assignment), preserving decorrelation downstream.
            return self.clone();
        }
        let fp = self.fp.max(0.0);
        let zero = Interval::ZERO;
        let ideal = self.ideal.max_i(&zero);
        let rounded = self.rounded.max_i(&zero);
        let abs = self.eff_abs();
        Caa::make(ctx, fp, ideal, rounded, abs, f64::INFINITY)
    }

    /// `LeakyReLU(x) = x if x > 0 else α x` with exact power-of-two `α`
    /// treated exactly; otherwise the negative branch pays one rounding.
    pub fn leaky_relu(&self, alpha: f64, ctx: &Ctx) -> Caa {
        debug_assert!((0.0..1.0).contains(&alpha));
        let pos = self.relu(ctx);
        let neg = self.min(&Caa::exact(0.0), ctx);
        let scaled = if alpha == 0.0 {
            Caa::exact(0.0)
        } else if alpha.log2().fract() == 0.0 {
            neg.scale_pow2(alpha, ctx)
        } else {
            neg.mul(&Caa::param(ctx, alpha), ctx)
        };
        pos.add(&scaled, ctx)
    }

    /// `|x|`. Error-free; 1-Lipschitz.
    pub fn abs_val(&self, ctx: &Ctx) -> Caa {
        let fp = self.fp.abs();
        let ideal = self.ideal.abs();
        let rounded = self.rounded.abs();
        let abs = self.eff_abs();
        let rel = if self.ideal.excludes_zero() { self.eff_rel() } else { f64::INFINITY };
        Caa::make(ctx, fp, ideal, rounded, abs, rel)
    }
}

/// Maximum over a vector, **labeling every element with the result** (the
/// paper's control-flow insight): after `m = max_many(ctx, xs)`, each
/// `xs[i]` carries `upper = m`, so a later `xs[i] - m` is clipped to
/// `(-inf, 0]` — exactly what the max-subtraction softmax implementation
/// needs to keep its `exp` inputs nonpositive.
pub fn max_many(ctx: &Ctx, xs: &mut [Caa]) -> Caa {
    assert!(!xs.is_empty());
    let mut m = xs[0].clone();
    for x in xs.iter().skip(1) {
        m = m.max(x, ctx);
    }
    if ctx.labels {
        let shared = Arc::new(m.clone()); // clone shares m's id
        for x in xs.iter_mut() {
            x.set_upper(&shared);
        }
    }
    m
}

/// Minimum over a vector with lower-bound labeling.
pub fn min_many(ctx: &Ctx, xs: &mut [Caa]) -> Caa {
    assert!(!xs.is_empty());
    let mut m = xs[0].clone();
    for x in xs.iter().skip(1) {
        m = m.min(x, ctx);
    }
    if ctx.labels {
        let shared = Arc::new(m.clone());
        for x in xs.iter_mut() {
            x.set_lower(&shared);
        }
    }
    m
}

/// Index of the maximum *computed* (fp-trace) element — the final argmax of
/// a classification network (paper §IV). Returns the first index on ties,
/// like NumPy.
pub fn argmax_fp(xs: &[Caa]) -> usize {
    let mut best = 0;
    for (i, x) in xs.iter().enumerate().skip(1) {
        if x.fp() > xs[best].fp() {
            best = i;
        }
    }
    best
}

/// Can FP rounding error toggle the argmax? True iff the rounded range of
/// some non-top element overlaps the rounded range of the top element.
pub fn argmax_ambiguous(xs: &[Caa]) -> bool {
    let top = argmax_fp(xs);
    xs.iter()
        .enumerate()
        .filter(|(i, _)| *i != top)
        .any(|(_, x)| x.rounded().hi() >= xs[top].rounded().lo())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interval::Interval;

    fn ctx() -> Ctx {
        Ctx::new()
    }

    #[test]
    fn relu_identity_on_positive() {
        let c = ctx();
        let x = Caa::input(&c, Interval::new(1.0, 2.0), 1.5);
        let r = x.relu(&c);
        assert_eq!(r.id(), x.id(), "ReLU on strictly-positive input is assignment");
        assert_eq!(r.fp(), 1.5);
    }

    #[test]
    fn relu_zero_on_negative() {
        let c = ctx();
        let x = Caa::input(&c, Interval::new(-5.0, -1.0), -2.0);
        let r = x.relu(&c);
        assert_eq!(r.ideal(), Interval::ZERO);
        assert_eq!(r.abs_bound(), 0.0);
    }

    #[test]
    fn relu_mixed_keeps_abs_drops_rel() {
        let c = ctx();
        let x = Caa::make(
            &c,
            0.5,
            Interval::new(-1.0, 1.0),
            Interval::new(-1.1, 1.1),
            3.0,
            f64::INFINITY,
        );
        let r = x.relu(&c);
        assert_eq!(r.fp(), 0.5);
        assert!(r.abs_bound() <= 3.0 * (1.0 + 1e-12));
        assert!(r.ideal().lo() >= 0.0);
        assert!(r.rel_bound().is_infinite());
    }

    #[test]
    fn max_lipschitz_abs() {
        let c = ctx();
        let a = Caa::make(&c, 1.0, Interval::new(0.5, 1.5), Interval::new(0.4, 1.6), 2.0, f64::INFINITY);
        let b = Caa::make(&c, 0.9, Interval::new(0.1, 1.2), Interval::new(0.0, 1.3), 5.0, f64::INFINITY);
        let m = a.max(&b, &c);
        assert_eq!(m.fp(), 1.0);
        assert!(m.abs_bound() <= 5.0 * (1.0 + 1e-12));
        assert!(m.ideal().contains(1.5));
        // Both strictly positive => rel recovered via abs/mig in make().
        assert!(m.rel_bound().is_finite());
    }

    #[test]
    fn max_many_labels_operands() {
        let c = ctx();
        let mut xs = vec![
            Caa::input(&c, Interval::new(0.0, 4.0), 1.0),
            Caa::input(&c, Interval::new(0.0, 4.0), 3.0),
            Caa::input(&c, Interval::new(0.0, 4.0), 2.0),
        ];
        let m = max_many(&c, &mut xs);
        assert_eq!(m.fp(), 3.0);
        for x in &xs {
            assert_eq!(x.upper_label().unwrap().id(), m.id());
        }
        // The labeled subtraction clips to <= 0 (softmax pattern).
        let d = xs[0].sub(&m, &c);
        assert!(d.ideal().hi() <= 0.0);
        assert!(d.rounded().hi() <= 0.0);
    }

    #[test]
    fn min_many_labels_operands() {
        let c = ctx();
        let mut xs = vec![
            Caa::input(&c, Interval::new(1.0, 4.0), 2.0),
            Caa::input(&c, Interval::new(1.0, 4.0), 1.5),
        ];
        let m = min_many(&c, &mut xs);
        assert_eq!(m.fp(), 1.5);
        let d = xs[0].sub(&m, &c);
        assert!(d.ideal().lo() >= 0.0, "x - min(x..) >= 0, got {}", d.ideal());
    }

    #[test]
    fn argmax_and_ambiguity() {
        let c = ctx();
        let mk = |fp: f64, w: f64| {
            Caa::make(
                &c,
                fp,
                Interval::new(fp - w, fp + w),
                Interval::new(fp - w, fp + w),
                1.0,
                f64::INFINITY,
            )
        };
        let clear = vec![mk(0.1, 0.01), mk(0.8, 0.01), mk(0.1, 0.01)];
        assert_eq!(argmax_fp(&clear), 1);
        assert!(!argmax_ambiguous(&clear));

        let fuzzy = vec![mk(0.49, 0.05), mk(0.51, 0.05)];
        assert_eq!(argmax_fp(&fuzzy), 1);
        assert!(argmax_ambiguous(&fuzzy));
    }

    #[test]
    fn abs_val_cases() {
        let c = ctx();
        let neg = Caa::input(&c, Interval::new(-3.0, -1.0), -2.0);
        let a = neg.abs_val(&c);
        assert_eq!(a.fp(), 2.0);
        assert!(a.ideal().contains(3.0) && a.ideal().lo() >= 1.0);
        assert!(a.rel_bound().is_finite());

        let mixed = Caa::input(&c, Interval::new(-1.0, 2.0), 0.5);
        let am = mixed.abs_val(&c);
        assert!(am.ideal().lo() >= 0.0);
    }

    #[test]
    fn leaky_relu_negative_branch() {
        let c = ctx();
        let x = Caa::input(&c, Interval::new(-4.0, -2.0), -3.0);
        let l = x.leaky_relu(0.25, &c);
        assert_eq!(l.fp(), -0.75);
        assert!(l.ideal().contains(-1.0) && l.ideal().contains(-0.5));
    }
}
