//! **CAA — Combined (absolute + relative) Affine Arithmetic.**
//!
//! The paper's core contribution (§III). Every floating-point quantity of
//! the analyzed program is replaced by a [`Caa`] object carrying the eight
//! entries the paper lists:
//!
//! 1. a unique creation **id** (decorrelation: copies share it),
//! 2. the concrete **fp value** the plain-FP program would compute,
//! 3. an interval holding the **actual error** of that fp value
//!    (reference; derived — see [`Caa::fp_error`]),
//! 4. an **absolute error bound** `δ̄ ∈ R⁺ ∪ {+inf}` in units of `u`,
//! 5. a **relative error bound** `ε̄ ∈ R⁺ ∪ {+inf}` in units of `u`,
//! 6. an interval enclosing all **ideal** (roundoff-free) values,
//! 7. an interval enclosing all **rounded** (precision-k FP) values,
//! 8. optional **lower/upper bound labels** (other [`Caa`] objects; the
//!    "just enough global insight" that fixes control-flow cases like
//!    softmax's max-subtraction).
//!
//! Bounds are parametric in `u = 2^(1-k)`: the analysis is run once and the
//! output bounds hold *for every* precision `k` with `u <= u_max`
//! ([`Ctx::u_max`], the paper uses `u < 2^-7`). [`analysis`](crate::analysis)
//! then solves for the smallest safe `k`.

mod bounds;
mod compare;
mod elem;
mod ops;

pub use bounds::{badd, bdiv, bmul, exp_abs_to_rel, log_rel_to_abs, rel_chain, rel_chain2, rel_chain3, rel_inverse, sqrt_rel};
pub use compare::{argmax_ambiguous, argmax_fp, max_many, min_many};

use crate::interval::Interval;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Relative rounding bound, in units of u, of one correctly-rounded basic
/// operation (the first FP error model, paper eq. (5)).
pub const RND_BASIC: f64 = 0.5;

/// Relative rounding bound of one faithful elementary-function evaluation
/// (`exp`, `log`, `tanh`, ... are faithful but not correctly rounded on
/// real libms; 1 ulp covers them).
pub const RND_ELEM: f64 = 1.0;

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

fn fresh_id() -> u64 {
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

/// Analysis context: the symbolic-unit bound and feature toggles (the
/// toggles exist for the ablation experiments A-caa-vs-ia and A-decorr;
/// production analyses use [`Ctx::new`] which enables everything).
#[derive(Clone, Debug)]
pub struct Ctx {
    /// Upper bound on `u = 2^(1-k)`; bounds hold for all `u <= u_max`.
    pub u_max: f64,
    /// Id-based decorrelation (paper §III: `x - x = 0` exactly).
    pub decorrelation: bool,
    /// Bound-label control-flow insight (paper §III: `q ≤ M ⇒ q - M ≤ 0`).
    pub labels: bool,
    /// Propagate absolute bounds (ablation switch).
    pub track_abs: bool,
    /// Propagate relative bounds (ablation switch).
    pub track_rel: bool,
}

impl Ctx {
    /// Full CAA with the paper's default `u_max = 2^-7`.
    pub fn new() -> Ctx {
        Ctx::with_u_max(2f64.powi(-7))
    }

    /// Full CAA with a custom `u_max` (must be in `(0, 2^-2]`).
    pub fn with_u_max(u_max: f64) -> Ctx {
        assert!(u_max > 0.0 && u_max <= 0.25, "unreasonable u_max {u_max}");
        Ctx { u_max, decorrelation: true, labels: true, track_abs: true, track_rel: true }
    }

    /// IA-only ablation: no error bounds are propagated at all; the caller
    /// falls back to interval widths.
    pub fn ia_only(mut self) -> Ctx {
        self.track_abs = false;
        self.track_rel = false;
        self
    }

    /// Ablation: propagate only absolute error bounds.
    pub fn abs_only(mut self) -> Ctx {
        self.track_rel = false;
        self
    }

    /// Ablation: propagate only relative error bounds.
    pub fn rel_only(mut self) -> Ctx {
        self.track_abs = false;
        self
    }

    /// Ablation (A-decorr): disable id-based decorrelation.
    pub fn no_decorrelation(mut self) -> Ctx {
        self.decorrelation = false;
        self
    }

    /// Ablation: disable the bound-label control-flow insight.
    pub fn no_labels(mut self) -> Ctx {
        self.labels = false;
        self
    }
}

impl Default for Ctx {
    fn default() -> Self {
        Ctx::new()
    }
}

/// A CAA-analyzed floating-point quantity. Flat value type: ops do **not**
/// heap-allocate (bound labels are shared `Arc`s, attached only where the
/// control-flow insight needs them) — this by-value design is what removes
/// the MPFI allocation bottleneck the paper reports for MobileNet.
#[derive(Clone, Debug)]
pub struct Caa {
    id: u64,
    fp: f64,
    ideal: Interval,
    rounded: Interval,
    /// Absolute error bound `δ̄` in units of u (`rounded = ideal + δ u`).
    abs: f64,
    /// Relative error bound `ε̄` in units of u (`rounded = ideal (1 + ε u)`).
    rel: f64,
    /// Optional upper bound label: a quantity this one is `<=` to.
    upper: Option<Arc<Caa>>,
    /// Optional lower bound label.
    lower: Option<Arc<Caa>>,
}

impl Caa {
    // ------------------------------------------------------------------
    // Constructors
    // ------------------------------------------------------------------

    /// A constant that is **exactly representable in every analyzed format**
    /// (0, ±1, small integers, powers of two): no representation error.
    pub fn exact(x: f64) -> Caa {
        debug_assert!(x.is_finite());
        Caa {
            id: fresh_id(),
            fp: x,
            ideal: Interval::point(x),
            rounded: Interval::point(x),
            abs: 0.0,
            rel: 0.0,
            upper: None,
            lower: None,
        }
    }

    /// A learned parameter (weight/bias): the ideal value is the trained
    /// `x`, stored rounded to the target format, so it enters with the
    /// representation error of one rounding: `ε̄ = 1/2`, `δ̄ = |x|/2`.
    pub fn param(ctx: &Ctx, x: f64) -> Caa {
        debug_assert!(x.is_finite());
        if x == 0.0 {
            // Zero is exact in every binary FP format.
            return Caa::exact(0.0);
        }
        let ideal = Interval::point(x);
        Caa {
            id: fresh_id(),
            fp: x,
            ideal,
            rounded: relative_blowup(ideal, RND_BASIC, ctx.u_max),
            abs: if ctx.track_abs { bmul(RND_BASIC, x.abs()) } else { f64::INFINITY },
            rel: if ctx.track_rel { RND_BASIC } else { f64::INFINITY },
            upper: None,
            lower: None,
        }
    }

    /// An input quantity known only by a range (paper: image data annotated
    /// with `[0, 255]`), stored rounded to the target format. `fp_witness`
    /// is the concrete representative used for the reference fp trace.
    pub fn input(ctx: &Ctx, range: Interval, fp_witness: f64) -> Caa {
        debug_assert!(range.contains(fp_witness), "witness outside input range");
        Caa {
            id: fresh_id(),
            fp: fp_witness,
            ideal: range,
            rounded: relative_blowup(range, RND_BASIC, ctx.u_max),
            abs: if ctx.track_abs { bmul(RND_BASIC, range.mag()) } else { f64::INFINITY },
            rel: if ctx.track_rel { RND_BASIC } else { f64::INFINITY },
            upper: None,
            lower: None,
        }
    }

    /// An input that is exact in the target format (e.g. integer pixel
    /// values when the format has enough mantissa bits — 8-bit data in
    /// k >= 8 formats).
    pub fn input_exact(range: Interval, fp_witness: f64) -> Caa {
        debug_assert!(range.contains(fp_witness));
        Caa {
            id: fresh_id(),
            fp: fp_witness,
            ideal: range,
            rounded: range,
            abs: 0.0,
            rel: 0.0,
            upper: None,
            lower: None,
        }
    }

    /// Construct a quantity from externally-derived knowledge (fp trace
    /// value, range enclosures and error bounds in units of u). The caller
    /// is responsible for the soundness of the supplied entries; bounds are
    /// cross-refined and the rounded range tightened exactly as for
    /// operation results. This is the entry point for embedding analysis
    /// results from *other* tools (e.g. SafeAI-style range certificates).
    pub fn from_parts(
        ctx: &Ctx,
        fp: f64,
        ideal: Interval,
        rounded: Interval,
        abs: f64,
        rel: f64,
    ) -> Caa {
        Caa::make(ctx, fp, ideal, rounded, abs, rel)
    }

    /// Internal: assemble a result, refining each bound from the other and
    /// intersecting range information (called by every operation).
    pub(crate) fn make(
        ctx: &Ctx,
        fp: f64,
        ideal: Interval,
        rounded: Interval,
        abs: f64,
        rel: f64,
    ) -> Caa {
        let mut abs = if ctx.track_abs { abs } else { f64::INFINITY };
        let mut rel = if ctx.track_rel { rel } else { f64::INFINITY };
        debug_assert!(abs >= 0.0 || abs.is_nan());
        debug_assert!(rel >= 0.0 || rel.is_nan());
        if abs.is_nan() {
            abs = f64::INFINITY;
        }
        if rel.is_nan() {
            rel = f64::INFINITY;
        }

        // Cross-refinement (paper §III: "CAA improves the one bound using
        // the other whenever possible").
        if ctx.track_abs && rel.is_finite() {
            // δ = ε q  =>  δ̄ <= ε̄ sup|q|
            let via_rel = bmul(rel, ideal.mag());
            if via_rel < abs {
                abs = via_rel;
            }
        }
        if ctx.track_rel && abs.is_finite() {
            // ε = δ/q  =>  ε̄ <= δ̄ / inf|q| when q is bounded away from 0
            let mig = ideal.mig();
            if mig > 0.0 {
                let via_abs = bdiv(abs, mig);
                if via_abs < rel {
                    rel = via_abs;
                }
            }
        }

        // Tighten the rounded enclosure with the bounds.
        let mut rounded = rounded;
        if abs.is_finite() {
            let r = ideal.inflate(bmul(abs, ctx.u_max));
            rounded = rounded.intersect(&r).unwrap_or(rounded);
        }
        if rel.is_finite() {
            let r = relative_blowup(ideal, rel, ctx.u_max);
            rounded = rounded.intersect(&r).unwrap_or(rounded);
        }

        Caa { id: fresh_id(), fp, ideal, rounded, abs, rel, upper: None, lower: None }
    }

    // ------------------------------------------------------------------
    // Accessors (the eight entries)
    // ------------------------------------------------------------------

    /// Unique creation id (copies made with `clone()` share it — clone *is*
    /// the paper's assignment).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The concrete value the plain-FP (f64 trace) program computes.
    pub fn fp(&self) -> f64 {
        self.fp
    }

    /// Reference entry: interval enclosing the actual error of [`Caa::fp`]
    /// with respect to the unknown ideal value.
    pub fn fp_error(&self) -> Interval {
        Interval::point(self.fp) - self.ideal
    }

    /// Absolute error bound `δ̄` in units of u.
    pub fn abs_bound(&self) -> f64 {
        self.abs
    }

    /// Relative error bound `ε̄` in units of u.
    pub fn rel_bound(&self) -> f64 {
        self.rel
    }

    /// Enclosure of all ideal (roundoff-free) values.
    pub fn ideal(&self) -> Interval {
        self.ideal
    }

    /// Enclosure of all values computed with precision-k FP (any k with
    /// `u <= u_max`).
    pub fn rounded(&self) -> Interval {
        self.rounded
    }

    /// The quantity this one is labeled `<=` to, if any.
    pub fn upper_label(&self) -> Option<&Arc<Caa>> {
        self.upper.as_ref()
    }

    /// The quantity this one is labeled `>=` to, if any.
    pub fn lower_label(&self) -> Option<&Arc<Caa>> {
        self.lower.as_ref()
    }

    /// Label this quantity as `<=` the given one (shared).
    pub fn set_upper(&mut self, bound: &Arc<Caa>) {
        self.upper = Some(Arc::clone(bound));
    }

    /// Label this quantity as `>=` the given one (shared).
    pub fn set_lower(&mut self, bound: &Arc<Caa>) {
        self.lower = Some(Arc::clone(bound));
    }

    /// Intersect the ideal and rounded enclosures with externally-known
    /// range information (the paper's "just enough global insight": e.g.
    /// softmax outputs are probabilities in `[0, 1]` by construction).
    /// Sound only if the caller's claim holds for both the ideal and the
    /// computed value; the id is preserved (this is knowledge refinement,
    /// not a new quantity).
    pub fn clamp_range(&self, range: Interval) -> Caa {
        let mut r = self.clone();
        r.ideal = r.ideal.intersect(&range).unwrap_or(range);
        r.rounded = r.rounded.intersect(&range).unwrap_or(range);
        r
    }
}

/// `ideal * (1 + [-ε̄, ε̄] u)` for all `u <= u_max` — enclosure of the
/// rounded range given a relative bound. Specialized (hot path): for
/// `r = ε̄·u_max < 1` the factor interval `[1-r, 1+r]` is positive, so the
/// product endpoints are `lo·(1±r)` / `hi·(1±r)` by sign — two rounded
/// multiplications instead of a full interval multiplication.
pub(crate) fn relative_blowup(ideal: Interval, rel: f64, u_max: f64) -> Interval {
    if !rel.is_finite() {
        return Interval::ENTIRE;
    }
    let r = bmul(rel, u_max);
    if r >= 1.0 {
        return ideal * Interval::new(1.0 - r, 1.0 + r);
    }
    let (lo, hi) = (ideal.lo(), ideal.hi());
    let new_lo = if lo >= 0.0 { lo * (1.0 - r) } else { lo * (1.0 + r) };
    let new_hi = if hi >= 0.0 { hi * (1.0 + r) } else { hi * (1.0 - r) };
    Interval::new(
        crate::interval::bump_down(new_lo, 1),
        crate::interval::bump_up(new_hi, 1),
    )
}

#[cfg(test)]
mod tests;
