//! CAA soundness property tests.
//!
//! The central claim of the paper is that CAA bounds are *rigorous*: for
//! every precision `k` with `u = 2^(1-k) <= u_max`, the true rounding error
//! of a precision-k execution is below `δ̄·u` (absolutely) and `ε̄·u`
//! (relatively). We witness this by evaluating *random expression DAGs*
//! three ways — CAA, plain f64 (the ideal stand-in), and emulated
//! precision-k ([`crate::quant::EmulatedFp`]) — and checking the bounds at
//! every node, across random k in `[8, 24]`.

use super::compare::{argmax_ambiguous, argmax_fp, max_many};
use super::*;
use crate::prop;
use crate::quant::{check_against_bounds, round_to_precision, EmulatedFp};
use crate::util::Rng;

/// One value under the three interpretations.
#[derive(Clone)]
struct Tri {
    caa: Caa,
    ideal: f64,
    emu: EmulatedFp,
}

fn leaf(ctx: &Ctx, rng: &mut Rng, k: u32) -> Tri {
    let x = match rng.below(4) {
        0 => rng.range(-1.0, 1.0),
        1 => rng.range(-8.0, 8.0),
        2 => rng.range(0.0, 255.0),
        _ => rng.range(-0.05, 0.05),
    };
    Tri { caa: Caa::param(ctx, x), ideal: x, emu: EmulatedFp::new(x, k) }
}

/// Grow a random DAG, checking every freshly created node.
fn run_random_dag(ctx: &Ctx, rng: &mut Rng, k: u32, n_ops: usize) {
    let mut nodes: Vec<Tri> = (0..3).map(|_| leaf(ctx, rng, k)).collect();
    let slack = |r: f64| 1e-9 * (1.0 + r.abs());

    for step in 0..n_ops {
        let a = nodes[rng.below(nodes.len())].clone();
        let op = rng.below(12);
        let b = nodes[rng.below(nodes.len())].clone();
        let cand: Option<Tri> = match op {
            0 => Some(Tri {
                caa: a.caa.add(&b.caa, ctx),
                ideal: a.ideal + b.ideal,
                emu: a.emu.add(b.emu),
            }),
            1 => Some(Tri {
                caa: a.caa.sub(&b.caa, ctx),
                ideal: a.ideal - b.ideal,
                emu: a.emu.sub(b.emu),
            }),
            2 => Some(Tri {
                caa: a.caa.mul(&b.caa, ctx),
                ideal: a.ideal * b.ideal,
                emu: a.emu.mul(b.emu),
            }),
            3 => {
                if b.caa.ideal().excludes_zero() && b.caa.ideal().mig() > 1e-3 {
                    Some(Tri {
                        caa: a.caa.div(&b.caa, ctx),
                        ideal: a.ideal / b.ideal,
                        emu: a.emu.div(b.emu),
                    })
                } else {
                    None
                }
            }
            4 => {
                if a.caa.ideal().mag() < 20.0 {
                    Some(Tri { caa: a.caa.exp(ctx), ideal: a.ideal.exp(), emu: a.emu.exp() })
                } else {
                    None
                }
            }
            5 => {
                if a.caa.ideal().lo() > 1e-3 {
                    Some(Tri { caa: a.caa.ln(ctx), ideal: a.ideal.ln(), emu: a.emu.ln() })
                } else {
                    None
                }
            }
            6 => {
                if a.caa.ideal().lo() > 0.0 {
                    Some(Tri { caa: a.caa.sqrt(ctx), ideal: a.ideal.sqrt(), emu: a.emu.sqrt() })
                } else {
                    None
                }
            }
            7 => Some(Tri { caa: a.caa.tanh(ctx), ideal: a.ideal.tanh(), emu: a.emu.tanh() }),
            8 => Some(Tri {
                caa: a.caa.sigmoid(ctx),
                ideal: 1.0 / (1.0 + (-a.ideal).exp()),
                emu: a.emu.sigmoid(),
            }),
            9 => Some(Tri { caa: a.caa.relu(ctx), ideal: a.ideal.max(0.0), emu: a.emu.relu() }),
            10 => Some(Tri {
                caa: a.caa.max(&b.caa, ctx),
                ideal: a.ideal.max(b.ideal),
                emu: a.emu.max(b.emu),
            }),
            11 => Some(Tri { caa: a.caa.neg(), ideal: -a.ideal, emu: a.emu.neg() }),
            _ => unreachable!(),
        };
        let Some(t) = cand else { continue };
        if !t.ideal.is_finite() || t.ideal.abs() > 1e12 {
            continue; // keep magnitudes in a regime where f64 ref ~ ideal
        }
        // The ideal stand-in must be inside the CAA ideal enclosure...
        assert!(
            t.caa.ideal().inflate(slack(t.ideal)).contains(t.ideal),
            "step {step}: ideal {:.17e} outside {}",
            t.ideal,
            t.caa.ideal()
        );
        // ... the emulated value inside the rounded enclosure ...
        assert!(
            t.caa.rounded().inflate(slack(t.emu.v)).contains(t.emu.v),
            "step {step}: emulated {:.17e} outside rounded {}",
            t.emu.v,
            t.caa.rounded()
        );
        // ... and the error bounds must hold.
        if let Err(e) = check_against_bounds(&t.caa, t.ideal, t.emu.v, k, slack(t.ideal)) {
            panic!("step {step} (op {op}): {e}");
        }
        nodes.push(t);
        if nodes.len() > 24 {
            nodes.remove(0);
        }
    }
}

#[test]
fn soundness_random_dags_full_caa() {
    prop::check_with(
        prop::Config { cases: 150, base_seed: 0xABCD01 },
        "caa-soundness",
        |rng| {
            let k = 8 + rng.below(17) as u32; // u = 2^(1-k) <= 2^-7 = u_max
            let ctx = Ctx::new();
            run_random_dag(&ctx, rng, k, 40);
        },
    );
}

#[test]
fn soundness_random_dags_abs_only() {
    prop::check_with(
        prop::Config { cases: 60, base_seed: 0xABCD02 },
        "caa-soundness-absonly",
        |rng| {
            let k = 8 + rng.below(17) as u32;
            let ctx = Ctx::new().abs_only();
            run_random_dag(&ctx, rng, k, 30);
        },
    );
}

#[test]
fn soundness_random_dags_rel_only() {
    prop::check_with(
        prop::Config { cases: 60, base_seed: 0xABCD03 },
        "caa-soundness-relonly",
        |rng| {
            let k = 8 + rng.below(17) as u32;
            let ctx = Ctx::new().rel_only();
            run_random_dag(&ctx, rng, k, 30);
        },
    );
}

#[test]
fn soundness_without_decorrelation_or_labels() {
    // Disabling the global-insight features must stay sound (just looser).
    prop::check_with(
        prop::Config { cases: 60, base_seed: 0xABCD04 },
        "caa-soundness-nodecorr",
        |rng| {
            let k = 8 + rng.below(17) as u32;
            let ctx = Ctx::new().no_decorrelation().no_labels();
            run_random_dag(&ctx, rng, k, 30);
        },
    );
}

#[test]
fn soundness_small_u_max() {
    // Tighter u_max (float-16-like analyses, k >= 12).
    prop::check_with(
        prop::Config { cases: 60, base_seed: 0xABCD05 },
        "caa-soundness-umax11",
        |rng| {
            let k = 12 + rng.below(13) as u32;
            let ctx = Ctx::with_u_max(2f64.powi(-11));
            run_random_dag(&ctx, rng, k, 30);
        },
    );
}

#[test]
fn dot_product_bound_scales_linearly() {
    // An n-term dot product's absolute bound should grow ~linearly in n
    // (Wilkinson-style), not blow up: sanity of the summation rule.
    let ctx = Ctx::new();
    let mut rng = Rng::new(99);
    let mut prev = 0.0;
    for n in [4usize, 16, 64, 256] {
        let acc = (0..n)
            .map(|_| {
                let w = Caa::param(&ctx, rng.range(-1.0, 1.0));
                let x = Caa::param(&ctx, rng.range(0.0, 1.0));
                w.mul(&x, &ctx)
            })
            .reduce(|a, b| a.add(&b, &ctx))
            .unwrap();
        let bound = acc.abs_bound();
        assert!(bound.is_finite(), "n={n}");
        assert!(bound > prev, "bound must grow with n");
        // Linear-ish: bound/n stays within a small constant factor.
        assert!(bound / n as f64 <= 4.0, "n={n} bound={bound} — superlinear blowup");
        prev = bound;
    }
}

#[test]
fn softmax_pattern_end_to_end() {
    // The paper's §IV flagship pattern: max-subtracted softmax. With
    // decorrelation + labels the output keeps a finite relative bound.
    let ctx = Ctx::new();
    let softmax = |ctx: &Ctx, logits: &[f64]| -> Vec<Caa> {
        let mut xs: Vec<Caa> = logits.iter().map(|&v| Caa::param(ctx, v)).collect();
        let m = max_many(ctx, &mut xs);
        let exps: Vec<Caa> = xs.iter().map(|x| x.sub(&m, ctx).exp(ctx)).collect();
        let sum = exps.iter().cloned().reduce(|a, b| a.add(&b, ctx)).unwrap();
        exps.iter().map(|e| e.div(&sum, ctx)).collect()
    };
    let out = softmax(&ctx, &[2.0, -1.0, 0.5, 0.1]);
    let fp_sum: f64 = out.iter().map(|o| o.fp()).sum();
    assert!((fp_sum - 1.0).abs() < 1e-12, "softmax fp trace sums to 1");
    for (i, o) in out.iter().enumerate() {
        assert!(o.ideal().lo() >= 0.0, "prob {i} nonneg");
        assert!(o.ideal().hi() <= 1.0 + 1e-9, "prob {i} <= 1: {}", o.ideal());
        assert!(o.rel_bound().is_finite(), "prob {i} needs a finite rel bound");
        assert!(o.abs_bound().is_finite());
        // Bounds must be *tight-ish*: a handful of u, not thousands
        // (Table I reports 3.4u for a whole network).
        assert!(o.rel_bound() < 60.0, "prob {i} rel bound too loose: {}", o.rel_bound());
    }
    assert_eq!(argmax_fp(&out), 0);
    assert!(!argmax_ambiguous(&out), "confident logits must stay unambiguous");
}

#[test]
fn softmax_without_labels_loses_exp_input_bound() {
    // Ablation motivation (A-decorr): without labels, x - max(x..) is not
    // known nonpositive, so exp's ideal range inflates.
    let run = |ctx: &Ctx| -> f64 {
        // Ranged inputs (an input box, as in per-class analysis), where the
        // decorrelated x - max(x..) genuinely needs the label insight.
        let mut xs = vec![
            Caa::input(ctx, crate::interval::Interval::new(0.0, 4.0), 3.0),
            Caa::input(ctx, crate::interval::Interval::new(0.0, 4.0), 1.0),
        ];
        let m = max_many(ctx, &mut xs);
        let e = xs[0].sub(&m, ctx).exp(ctx);
        e.ideal().hi()
    };
    let with = run(&Ctx::new());
    let without = run(&Ctx::new().no_labels());
    assert!(with <= 1.0 + 1e-9, "with labels e^(x-max) <= 1, got {with}");
    assert!(without > with, "labels must tighten the softmax exp range");
}

#[test]
fn emulated_softmax_within_caa_bounds() {
    // Full softmax: CAA bound vs actual emulated-k error, many k.
    prop::check_with(
        prop::Config { cases: 80, base_seed: 0xABCD06 },
        "softmax-sound",
        |rng| {
            let k = 8 + rng.below(17) as u32;
            let ctx = Ctx::new();
            let n = 2 + rng.below(6);
            let logits: Vec<f64> = (0..n).map(|_| rng.range(-4.0, 4.0)).collect();

            // CAA + f64 reference + emulated-k, sharing the max-subtraction
            // structure.
            let mut xs: Vec<Caa> = logits.iter().map(|&v| Caa::param(&ctx, v)).collect();
            let m = max_many(&ctx, &mut xs);
            let exps: Vec<Caa> = xs.iter().map(|x| x.sub(&m, &ctx).exp(&ctx)).collect();
            let sum = exps.iter().cloned().reduce(|a, b| a.add(&b, &ctx)).unwrap();
            let caa_out: Vec<Caa> = exps.iter().map(|e| e.div(&sum, &ctx)).collect();

            let mref = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let eref: Vec<f64> = logits.iter().map(|&v| (v - mref).exp()).collect();
            let sref: f64 = eref.iter().sum();

            let el: Vec<EmulatedFp> = logits.iter().map(|&v| EmulatedFp::new(v, k)).collect();
            let memu = el.iter().fold(EmulatedFp::new(f64::NEG_INFINITY, k), |a, &b| a.max(b));
            let eemu: Vec<EmulatedFp> = el.iter().map(|&v| v.sub(memu).exp()).collect();
            let semu = eemu.iter().fold(EmulatedFp::new(0.0, k), |a, &b| a.add(b));

            for i in 0..n {
                let ideal = eref[i] / sref;
                let emu = eemu[i].div(semu).v;
                if let Err(e) =
                    check_against_bounds(&caa_out[i], ideal, emu, k, 1e-10)
                {
                    panic!("softmax[{i}] logits={logits:?} k={k}: {e}");
                }
            }
        },
    );
}

#[test]
fn param_representation_error_witnessed() {
    // Caa::param claims ε̄ = 1/2; rounding a param to k bits must stay
    // within it for every k.
    prop::check("param-repr", |rng| {
        let x = prop::gen_f64_in(rng, -100.0, 100.0);
        let k = 8 + rng.below(17) as u32;
        let ctx = Ctx::new();
        let p = Caa::param(&ctx, x);
        let r = round_to_precision(x, k);
        if let Err(e) = check_against_bounds(&p, x, r, k, 0.0) {
            panic!("param({x}) k={k}: {e}");
        }
    });
}

#[test]
fn ids_are_unique_and_clone_preserves() {
    let ctx = Ctx::new();
    let a = Caa::param(&ctx, 1.0);
    let b = Caa::param(&ctx, 1.0);
    assert_ne!(a.id(), b.id());
    assert_eq!(a.id(), a.clone().id());
    let s = a.add(&b, &ctx);
    assert_ne!(s.id(), a.id());
    assert_ne!(s.id(), b.id());
}

#[test]
fn fp_error_reference_interval() {
    let ctx = Ctx::new();
    let x = Caa::input(&ctx, crate::interval::Interval::new(0.0, 10.0), 4.0);
    let e = x.fp_error();
    // fp = 4, ideal in [0,10] => actual error in [-6, 4].
    assert!(e.contains(0.0) && e.contains(-6.0) && e.contains(4.0));
}
