//! Elementary functions on CAA values: `exp`, `ln`, `sqrt`, `tanh`,
//! `sigmoid` — the functions DNN activation layers need (paper §III).
//!
//! The characteristic behaviour the paper derives analytically is encoded
//! here: `exp` converts an *absolute* input bound into a *relative* output
//! bound; `log` does the inverse; `tanh`/`sigmoid` propagate absolute
//! bounds with Lipschitz factor <= 1 (resp. 1/4) and `tanh` propagates
//! relative bounds with the paper's factor 2.63 while `ε̄ u <= 1/4`.

use super::bounds::{badd, bdiv, bmul, exp_abs_to_rel, log_rel_to_abs, rel_chain, sqrt_rel};
use super::{relative_blowup, Caa, Ctx, RND_BASIC, RND_ELEM};
use crate::interval::Interval;

impl Caa {
    /// Best available *relative* input bound: the stored `ε̄` improved via
    /// `δ̄ / inf|q|` when the ideal range excludes zero.
    pub(crate) fn eff_rel(&self) -> f64 {
        let mig = self.ideal.mig();
        if self.abs.is_finite() && mig > 0.0 {
            self.rel.min(bdiv(self.abs, mig))
        } else {
            self.rel
        }
    }

    /// Best available *absolute* input bound: the stored `δ̄` improved via
    /// `ε̄ · sup|q|` when the ideal range is bounded.
    pub(crate) fn eff_abs(&self) -> f64 {
        let mag = self.ideal.mag();
        if self.rel.is_finite() && mag.is_finite() {
            self.abs.min(bmul(self.rel, mag))
        } else {
            self.abs
        }
    }

    /// FP exponential. Absolute error in → relative error out:
    /// `e^{q+δu} = e^q (1 + (e^{δu}-1))` (paper §III).
    pub fn exp(&self, ctx: &Ctx) -> Caa {
        let fp = self.fp.exp();
        let ideal = self.ideal.exp();
        let rounded = relative_blowup(self.rounded.exp(), RND_ELEM, ctx.u_max);
        let prop = exp_abs_to_rel(self.eff_abs(), ctx.u_max);
        let rel = rel_chain(&[prop, RND_ELEM], ctx.u_max);
        Caa::make(ctx, fp, ideal, rounded, f64::INFINITY, rel)
    }

    /// FP natural logarithm. Relative error in → absolute error out:
    /// `log(q(1+εu)) = log q + log(1+εu)`.
    pub fn ln(&self, ctx: &Ctx) -> Caa {
        let fp = self.fp.ln();
        let ideal = self.ideal.ln();
        let rounded_pre = self.rounded.ln();
        let rounded = relative_blowup(rounded_pre, RND_ELEM, ctx.u_max);
        let prop = log_rel_to_abs(self.eff_rel(), ctx.u_max);
        let abs = badd(prop, bmul(RND_ELEM, rounded_pre.mag()));
        Caa::make(ctx, fp, ideal, rounded, abs, f64::INFINITY)
    }

    /// FP square root (correctly rounded per IEEE754): halves the relative
    /// error to first order.
    pub fn sqrt(&self, ctx: &Ctx) -> Caa {
        let fp = self.fp.sqrt();
        let ideal = self.ideal.sqrt();
        let rounded = relative_blowup(self.rounded.sqrt(), RND_BASIC, ctx.u_max);
        let rel = rel_chain(&[sqrt_rel(self.eff_rel(), ctx.u_max), RND_BASIC], ctx.u_max);
        Caa::make(ctx, fp, ideal, rounded, f64::INFINITY, rel)
    }

    /// Sharp Lipschitz constant of `tanh` over the rounded input range:
    /// `sup (1 - tanh²ξ)` — attained at the point of smallest magnitude.
    /// 1 when the range straddles 0; far below 1 in the saturated tails
    /// (this is what keeps deep tanh networks' absolute bounds tiny).
    fn tanh_lipschitz(range: Interval) -> f64 {
        if range.contains(0.0) {
            return 1.0;
        }
        let t = range.mig().tanh();
        // Round up: 1 - t² computed downward-safe via bumping.
        crate::interval::round::bump_up(1.0 - crate::interval::round::bump_down(t * t, 3), 1)
            .clamp(0.0, 1.0)
    }

    /// FP hyperbolic tangent. Absolute bounds propagate with the sharp
    /// interval Lipschitz factor (`<= 1`); relative bounds propagate with
    /// the paper's factor 2.63 while `ε̄ u <= 1/4`.
    pub fn tanh(&self, ctx: &Ctx) -> Caa {
        let fp = self.fp.tanh();
        let ideal = self.ideal.tanh();
        let rounded = relative_blowup(self.rounded.tanh(), RND_ELEM, ctx.u_max)
            .intersect(&Interval::new(-1.0, 1.0))
            .expect("tanh rounded range");
        // Absolute: |tanh(q+δu) - tanh(q)| <= L·|δ|u with L the sup of
        // tanh' over everything the perturbed argument can reach; plus
        // evaluation rounding, relative RND_ELEM on an output <= 1.
        let reach = self.ideal.hull(&self.rounded);
        let lip = Self::tanh_lipschitz(reach);
        let abs = badd(bmul(lip, self.eff_abs()), bmul(RND_ELEM, rounded.mag()));
        // Relative: paper's factor 2.63 under its precondition.
        let er = self.eff_rel();
        let rel = if er.is_finite() && bmul(er, ctx.u_max) <= 0.25 {
            rel_chain(&[bmul(2.63, er), RND_ELEM], ctx.u_max)
        } else {
            f64::INFINITY
        };
        Caa::make(ctx, fp, ideal, rounded, abs, rel)
    }

    /// Logistic sigmoid `1/(1+e^{-x})`, evaluated as one faithful
    /// elementary function (the paper treats activation functions as unary
    /// operations with their own rounding bound). Absolute bounds propagate
    /// with the Lipschitz factor 1/4.
    pub fn sigmoid(&self, ctx: &Ctx) -> Caa {
        let fp = 1.0 / (1.0 + (-self.fp).exp());
        let ideal = self.ideal.sigmoid();
        let rounded = relative_blowup(self.rounded.sigmoid(), RND_ELEM, ctx.u_max)
            .intersect(&Interval::new(0.0, 1.0))
            .expect("sigmoid rounded range");
        // σ' = σ(1-σ) <= 1/4, attained at 0; on ranges away from 0 the sharp
        // constant is σ'(mig) (σ' decreases in |x|).
        let reach = self.ideal.hull(&self.rounded);
        let lip = if reach.contains(0.0) {
            0.25
        } else {
            let s = 1.0 / (1.0 + (-reach.mig()).exp());
            crate::interval::round::bump_up(s * (1.0 - s), 4).clamp(0.0, 0.25)
        };
        let abs = badd(bmul(lip, self.eff_abs()), bmul(RND_ELEM, rounded.mag()));
        // Relative bound recovered from abs via make(): sigmoid output is
        // bounded away from 0 whenever the input is bounded below.
        Caa::make(ctx, fp, ideal, rounded, abs, f64::INFINITY)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> Ctx {
        Ctx::new()
    }

    #[test]
    fn exp_turns_abs_into_rel() {
        let c = ctx();
        // Summation output: good absolute bound, bad relative bound.
        let x = Caa::make(
            &c,
            0.0,
            Interval::new(-3.0, 3.0),
            Interval::new(-3.1, 3.1),
            2.0,            // δ̄ = 2u
            f64::INFINITY,  // no rel bound (cancellation upstream)
        );
        let e = x.exp(&c);
        assert!(e.rel_bound().is_finite(), "exp must produce a relative bound");
        // ~ δ̄ + rounding = 3.0, first order.
        assert!(e.rel_bound() < 3.2, "rel = {}", e.rel_bound());
        assert!(e.ideal().lo() >= 0.0);
    }

    #[test]
    fn ln_turns_rel_into_abs() {
        let c = ctx();
        let x = Caa::make(
            &c,
            10.0,
            Interval::new(5.0, 20.0),
            Interval::new(4.9, 20.1),
            f64::INFINITY,
            3.0, // ε̄ = 3u
        );
        let l = x.ln(&c);
        assert!(l.abs_bound().is_finite());
        // ~ ε̄ + RND·|ln| <= 3 + ~3 = 6ish
        assert!(l.abs_bound() < 7.0, "abs = {}", l.abs_bound());
    }

    #[test]
    fn tanh_abs_unamplified() {
        let c = ctx();
        let x = Caa::make(
            &c,
            0.5,
            Interval::new(-6.0, 6.0),
            Interval::new(-6.1, 6.1),
            5.0,
            f64::INFINITY,
        );
        let t = x.tanh(&c);
        // δ̄' = δ̄ + RND·1 = 6 at most.
        assert!(t.abs_bound() <= 6.01, "abs = {}", t.abs_bound());
        assert!(t.ideal().lo() >= -1.0 && t.ideal().hi() <= 1.0);
    }

    #[test]
    fn tanh_rel_factor_263() {
        let c = ctx();
        let x = Caa::make(
            &c,
            1.0,
            Interval::new(0.5, 2.0),
            Interval::new(0.49, 2.01),
            f64::INFINITY,
            2.0,
        );
        let t = x.tanh(&c);
        assert!(t.rel_bound().is_finite());
        // 2.63 * 2 + 1 (rounding) = 6.26 first order.
        assert!(t.rel_bound() <= 6.4, "rel = {}", t.rel_bound());
    }

    #[test]
    fn tanh_rel_precondition() {
        // Enormous ε̄ (ε̄ u > 1/4) must refuse the 2.63 shortcut; rel may
        // still be recovered via abs if the range allows, so check against
        // a range straddling zero where no rel bound can exist.
        let c = ctx();
        let x = Caa::make(
            &c,
            0.0,
            Interval::new(-1.0, 1.0),
            Interval::ENTIRE,
            f64::INFINITY,
            1e6,
        );
        let t = x.tanh(&c);
        assert!(t.rel_bound().is_infinite());
    }

    #[test]
    fn sigmoid_quarters_abs() {
        let c = ctx();
        let x = Caa::make(
            &c,
            0.0,
            Interval::new(-4.0, 4.0),
            Interval::new(-4.1, 4.1),
            8.0,
            f64::INFINITY,
        );
        let s = x.sigmoid(&c);
        // 8/4 + 1 = 3 at most.
        assert!(s.abs_bound() <= 3.01, "abs = {}", s.abs_bound());
        // Output bounded away from 0 => rel recovered.
        assert!(s.rel_bound().is_finite());
        assert!(s.ideal().lo() >= 0.0 && s.ideal().hi() <= 1.0);
    }

    #[test]
    fn sqrt_halves_rel() {
        let c = ctx();
        let x = Caa::make(
            &c,
            4.0,
            Interval::new(1.0, 9.0),
            Interval::new(0.99, 9.01),
            f64::INFINITY,
            4.0,
        );
        let s = x.sqrt(&c);
        // 4/2 + 1/2 = 2.5 first order.
        assert!(s.rel_bound() <= 2.6, "rel = {}", s.rel_bound());
        assert!(s.ideal().contains(2.0));
    }

    #[test]
    fn exp_of_nonpositive_stays_in_unit_range() {
        // The softmax pattern: exp of a max-subtracted (<= 0) input.
        let c = ctx();
        let x = Caa::make(
            &c,
            -1.0,
            Interval::new(f64::NEG_INFINITY, 0.0),
            Interval::new(f64::NEG_INFINITY, 0.0),
            1.5,
            f64::INFINITY,
        );
        let e = x.exp(&c);
        assert!(e.ideal().hi() <= 1.0, "e^{{x<=0}} <= 1, got {}", e.ideal());
        assert!(e.rel_bound().is_finite());
    }
}
