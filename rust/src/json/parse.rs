//! Recursive-descent JSON parser.

use super::value::Value;
use std::collections::BTreeMap;
use std::fmt;

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone)]
pub struct ParseError {
    /// Byte offset the error was detected at.
    pub offset: usize,
    /// Human-readable description.
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Maximum nesting depth; protects against stack overflow on adversarial
/// inputs (model files are at most ~6 deep).
const MAX_DEPTH: usize = 256;

/// Parse a complete JSON document. Trailing whitespace is allowed; any other
/// trailing content is an error.
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing content"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Value, ParseError> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self, depth: usize) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        if (0xD800..0xDC00).contains(&cp) {
                            // High surrogate: must be followed by \uXXXX low.
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("unexpected low surrogate"));
                        } else {
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-by-byte.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c).ok_or_else(|| self.err("bad UTF-8"))?;
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(self.err("truncated UTF-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("bad UTF-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let digits_start = self.pos;
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.pos == digits_start {
            return Err(self.err("expected digit"));
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            let frac_start = self.pos;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
            if self.pos == frac_start {
                return Err(self.err("expected fraction digit"));
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            let exp_start = self.pos;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
            if self.pos == exp_start {
                return Err(self.err("expected exponent digit"));
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC2..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF4 => Some(4),
        _ => None,
    }
}
