//! Minimal JSON substrate (parser + writer).
//!
//! The original tool consumes Keras models converted to JSON by
//! frugally-deep; our models are exported to JSON by `python/compile/aot.py`
//! and loaded here. The offline registry snapshot has no `serde_json`, so we
//! implement the (small) subset of JSON we need: objects, arrays, strings
//! with escapes, f64 numbers, booleans, null. Numbers are kept as f64, which
//! round-trips every weight NumPy emits with `repr` precision.

mod parse;
mod value;
mod write;

pub use parse::{parse, ParseError};
pub use value::Value;
pub use write::to_string_pretty;

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(s: &str) -> Value {
        let v = parse(s).expect("parse");
        let s2 = to_string_pretty(&v);
        parse(&s2).expect("reparse")
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap().as_f64().unwrap(), 42.0);
        assert_eq!(parse("-1.5e3").unwrap().as_f64().unwrap(), -1500.0);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("\"hi\"").unwrap().as_str().unwrap(), "hi");
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        let a = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap().as_str().unwrap(), "c");
        assert_eq!(v.get("d"), Some(&Value::Null));
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\"b\\c\nd\teA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\c\nd\teA");
    }

    #[test]
    fn unicode_escape_surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{1F600}");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrips_weights_like_payload() {
        let v = roundtrip(
            r#"{"layers":[{"type":"dense","w":[[0.123456789012345,-1e-30],[3.5,4.25]],"b":[0,1]}]}"#,
        );
        let w = v.get("layers").unwrap().as_array().unwrap()[0]
            .get("w")
            .unwrap()
            .as_array()
            .unwrap();
        let row0 = w[0].as_array().unwrap();
        assert_eq!(row0[0].as_f64().unwrap(), 0.123456789012345);
        assert_eq!(row0[1].as_f64().unwrap(), -1e-30);
    }

    #[test]
    fn roundtrips_extreme_doubles() {
        for x in [
            f64::MIN_POSITIVE,
            f64::MAX,
            -f64::MAX,
            1.0 + f64::EPSILON,
            5e-324, // subnormal
            0.1,
        ] {
            let s = to_string_pretty(&Value::Num(x));
            let v = parse(&s).unwrap();
            assert_eq!(v.as_f64().unwrap(), x, "failed for {x:e} (text {s})");
        }
    }

    #[test]
    fn object_get_path() {
        let v = parse(r#"{"a":{"b":{"c":7}}}"#).unwrap();
        assert_eq!(v.path(&["a", "b", "c"]).unwrap().as_f64().unwrap(), 7.0);
        assert!(v.path(&["a", "x"]).is_none());
    }

    #[test]
    fn deep_nesting_depth_limited() {
        let deep = "[".repeat(10_000) + &"]".repeat(10_000);
        assert!(parse(&deep).is_err(), "must refuse pathological depth");
    }
}
