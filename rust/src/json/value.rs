//! The JSON value tree.

use std::collections::BTreeMap;

/// A parsed JSON value. Objects use `BTreeMap` so output ordering is
/// deterministic (important for artifact-manifest diffing in tests).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (always held as f64).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Array(Vec<Value>),
    /// JSON object (sorted keys for deterministic output).
    Object(BTreeMap<String, Value>),
}

impl Value {
    /// Member lookup on an object; `None` for other variants.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(m) => m.get(key),
            _ => None,
        }
    }

    /// Nested lookup following a path of object keys.
    pub fn path(&self, keys: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// As a number; `None` for other variants.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// As usize, requiring an exact non-negative integer.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= 2u64.pow(53) as f64 => {
                Some(*x as usize)
            }
            _ => None,
        }
    }

    /// As a string; `None` for other variants.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As a boolean; `None` for other variants.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As an array slice; `None` for other variants.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }

    /// As an object map; `None` for other variants.
    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(m) => Some(m),
            _ => None,
        }
    }

    /// Decode a JSON array of numbers into a Vec<f64>.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_array()?
            .iter()
            .map(|v| v.as_f64())
            .collect::<Option<Vec<f64>>>()
    }

    /// Decode a JSON array of non-negative integers.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_array()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Option<Vec<usize>>>()
    }

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array value.
    pub fn arr(items: Vec<Value>) -> Value {
        Value::Array(items)
    }

    /// Build a numeric array value from f64s.
    pub fn nums(xs: &[f64]) -> Value {
        Value::Array(xs.iter().map(|&x| Value::Num(x)).collect())
    }
}

impl From<f64> for Value {
    fn from(x: f64) -> Self {
        Value::Num(x)
    }
}

impl From<usize> for Value {
    fn from(x: usize) -> Self {
        Value::Num(x as f64)
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}

impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
