//! JSON writer. Numbers are emitted with shortest-round-trip formatting
//! (Rust's `{}` for f64 is shortest-representation since 1.0), so every f64
//! survives a write→parse cycle bit-exactly.

use super::value::Value;

/// Serialize with 2-space indentation.
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, 0, &mut out);
    out
}

fn write_value(v: &Value, indent: usize, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(x) => write_num(*x, out),
        Value::Str(s) => write_string(s, out),
        Value::Array(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            // Numeric-only arrays (weight rows) are written on one line.
            let flat = items.iter().all(|i| matches!(i, Value::Num(_)));
            if flat {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    write_value(item, indent, out);
                }
                out.push(']');
            } else {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(indent + 1, out);
                    write_value(item, indent + 1, out);
                }
                out.push('\n');
                push_indent(indent, out);
                out.push(']');
            }
        }
        Value::Object(m) => {
            if m.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                push_indent(indent + 1, out);
                write_string(k, out);
                out.push_str(": ");
                write_value(val, indent + 1, out);
            }
            out.push('\n');
            push_indent(indent, out);
            out.push('}');
        }
    }
}

fn push_indent(n: usize, out: &mut String) {
    for _ in 0..n {
        out.push_str("  ");
    }
}

fn write_num(x: f64, out: &mut String) {
    if x.is_nan() {
        // JSON has no NaN; the analysis never emits one, but don't produce
        // invalid documents if it does.
        out.push_str("null");
    } else if x.is_infinite() {
        out.push_str(if x > 0.0 { "1e999" } else { "-1e999" });
    } else if x == x.trunc() && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}
