//! Declarative command-line parsing substrate (replaces clap, unavailable
//! offline). Supports subcommands, `--flag value`, `--flag=value`, boolean
//! `--flag`, defaults, and generated `--help` text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Specification of one option.
#[derive(Clone)]
pub struct OptSpec {
    /// Option name (without the `--`).
    pub name: &'static str,
    /// One-line help text.
    pub help: &'static str,
    /// None => boolean flag; Some(default) => value option.
    pub default: Option<String>,
}

/// Specification of a subcommand.
#[derive(Clone)]
pub struct CmdSpec {
    /// Subcommand name.
    pub name: &'static str,
    /// One-line help text.
    pub help: &'static str,
    /// Options the subcommand accepts.
    pub opts: Vec<OptSpec>,
}

/// Parsed command line.
#[derive(Debug)]
pub struct Parsed {
    /// The matched subcommand.
    pub command: String,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    /// Arguments not belonging to any option.
    pub positionals: Vec<String>,
}

impl Parsed {
    /// Value of option `--name`, if present (or defaulted).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Value of option `--name` parsed as a float.
    pub fn get_f64(&self, name: &str) -> anyhow::Result<f64> {
        let s = self
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing option --{name}"))?;
        s.parse()
            .map_err(|_| anyhow::anyhow!("option --{name}: '{s}' is not a number"))
    }

    /// Value of option `--name` parsed as an unsigned integer.
    pub fn get_usize(&self, name: &str) -> anyhow::Result<usize> {
        let s = self
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing option --{name}"))?;
        s.parse()
            .map_err(|_| anyhow::anyhow!("option --{name}: '{s}' is not an integer"))
    }

    /// Whether boolean flag `--name` was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }
}

/// A CLI application definition.
pub struct App {
    /// Binary name (help header).
    pub name: &'static str,
    /// One-line description.
    pub about: &'static str,
    /// The subcommands.
    pub commands: Vec<CmdSpec>,
}

impl App {
    /// Render help text.
    pub fn help(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n", self.name, self.about);
        let _ = writeln!(s, "USAGE: {} <command> [options]\n", self.name);
        let _ = writeln!(s, "COMMANDS:");
        for c in &self.commands {
            let _ = writeln!(s, "  {:<18} {}", c.name, c.help);
            for o in &c.opts {
                let d = match &o.default {
                    Some(d) => format!(" (default: {d})"),
                    None => " (flag)".to_string(),
                };
                let _ = writeln!(s, "      --{:<14} {}{}", o.name, o.help, d);
            }
        }
        s
    }

    /// Parse an argv (excluding the program name).
    pub fn parse(&self, args: &[String]) -> anyhow::Result<Parsed> {
        let Some(cmd_name) = args.first() else {
            anyhow::bail!("no command given\n\n{}", self.help());
        };
        if cmd_name == "--help" || cmd_name == "-h" || cmd_name == "help" {
            anyhow::bail!("{}", self.help());
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| anyhow::anyhow!("unknown command '{cmd_name}'\n\n{}", self.help()))?;

        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: BTreeMap<String, bool> = BTreeMap::new();
        let mut positionals = Vec::new();
        for o in &cmd.opts {
            if let Some(d) = &o.default {
                values.insert(o.name.to_string(), d.clone());
            }
        }

        let mut i = 1;
        while i < args.len() {
            let a = &args[i];
            if let Some(raw) = a.strip_prefix("--") {
                let (name, inline_val) = match raw.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (raw, None),
                };
                let spec = cmd
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{name} for '{cmd_name}'"))?;
                if spec.default.is_none() {
                    // boolean flag
                    if inline_val.is_some() {
                        anyhow::bail!("flag --{name} takes no value");
                    }
                    flags.insert(name.to_string(), true);
                } else {
                    let v = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .ok_or_else(|| anyhow::anyhow!("option --{name} needs a value"))?
                                .clone()
                        }
                    };
                    values.insert(name.to_string(), v);
                }
            } else {
                positionals.push(a.clone());
            }
            i += 1;
        }

        Ok(Parsed { command: cmd_name.clone(), values, flags, positionals })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App {
            name: "rigor",
            about: "test",
            commands: vec![CmdSpec {
                name: "analyze",
                help: "run analysis",
                opts: vec![
                    OptSpec { name: "model", help: "model path", default: Some("m.json".into()) },
                    OptSpec { name: "k", help: "precision", default: Some("24".into()) },
                    OptSpec { name: "verbose", help: "chatty", default: None },
                ],
            }],
        }
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let p = app().parse(&argv(&["analyze"])).unwrap();
        assert_eq!(p.get("model"), Some("m.json"));
        assert_eq!(p.get_usize("k").unwrap(), 24);
        assert!(!p.flag("verbose"));
    }

    #[test]
    fn values_and_flags() {
        let p = app()
            .parse(&argv(&["analyze", "--model", "x.json", "--k=8", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(p.get("model"), Some("x.json"));
        assert_eq!(p.get_usize("k").unwrap(), 8);
        assert!(p.flag("verbose"));
        assert_eq!(p.positionals, vec!["pos1"]);
    }

    #[test]
    fn errors() {
        assert!(app().parse(&argv(&[])).is_err());
        assert!(app().parse(&argv(&["nope"])).is_err());
        assert!(app().parse(&argv(&["analyze", "--bogus", "1"])).is_err());
        assert!(app().parse(&argv(&["analyze", "--model"])).is_err());
        assert!(app().parse(&argv(&["analyze", "--verbose=1"])).is_err());
        assert!(app().parse(&argv(&["analyze", "--k", "abc"])).unwrap().get_f64("k").is_err());
    }

    #[test]
    fn help_renders() {
        let h = app().help();
        assert!(h.contains("analyze") && h.contains("--model"));
    }
}
