//! Canonical textual IR for compiled plans, with a structural
//! parser/differ and the plan memory report.
//!
//! [`Plan::to_text`] renders everything a plan commits to at compile
//! time — fusion level, kernel path, buffer pool with liveness, every
//! step with its geometry/wiring/hazard edges, and the per-step memory
//! footprint — as one deterministic text document. Determinism rules:
//!
//! - Rendering is a pure function of the compiled plan. Two compiles of
//!   the same model at the same `(Fusion, KernelPath)` produce
//!   byte-identical text (compilation itself is deterministic: ordered
//!   toposort, ordered buffer free-list, no hashing anywhere).
//! - Line endings are `\n`; exactly one trailing newline; sections are
//!   separated by single blank lines; every list is rendered in a
//!   deterministic order (step index, buffer index, declared input
//!   order) with `-` for "empty".
//! - No weight *values* appear — only element counts (`params=`) and
//!   provenance (`wsrc=shared|folded|panel`). The only floats printed
//!   are semantic attributes (`eps=`, `alpha=`), via Rust's shortest
//!   round-trip `{}` formatting.
//!
//! The golden snapshot suite (`rust/tests/golden.rs`) pins these
//! renderings for the model zoo and reports drift through
//! [`diff`] as per-step/per-buffer edits rather than a text dump; the
//! same differ powers any structural plan comparison. [`PlanText`]
//! stores parsed lines as ordered key/value tokens verbatim, so
//! `parse` -> `render` is byte-identity *by construction* — the
//! round-trip property `rust/tests/ir_props.rs` pins.
//!
//! Grammar (one line per item; tokens are space-separated, values never
//! contain spaces):
//!
//! ```text
//! plan <name>
//! fusion none|pair|full
//! kernels scalar|blocked
//! input b<i> <shape>            ; shape = 'x'-joined dims, e.g. 6x6x1
//! output b<i> <shape>
//!
//! buffers <n>
//! b<i> len=<elems> writers=<steps|-> readers=<steps|->
//!
//! steps <n>
//! s<i> <kind> in=<bufs> out=b<i> in_shapes=<shapes> out_shape=<shape>
//!      act=<act|-> layers=<lo>..<hi> deps=<steps|-> lower=<kernel|->
//!      [kind-specific: w=/k=/stride=/pad=/wsrc=/params=/window=/c=/eps=
//!       /alpha=/rows=/widths=]
//!
//! memory
//! s<i> <kind> weights=<B> shared=<B> panel=<B> table=<B>
//!      resident=<B> baseline=<B>
//! total weights=<B> shared=<B> panel=<B> table=<B> resident=<B>
//!       baseline=<B>
//! ```
//!
//! Memory-report fields (all bytes): `weights` = parameters the plan
//! *owns* (folded weight copies, biases, batch-norm vectors); `shared` =
//! weight tensors `Arc`-shared with the model's layers (not charged to
//! the plan); `panel` = packed dense panels; `table` = im2col/tap
//! tables (per-row-class form for convs); `resident` = `weights + panel
//! + table` (what a cached plan actually keeps alive beyond the model);
//! `baseline` = the pre-diet layout (every parameter cloned, full
//! per-pixel `O(oh*ow*K)` conv tables) the diet is measured against.

use super::{Act, BlockedStep, DenseWeights, Fusion, KernelPath, Plan, StepKind};
use anyhow::{bail, Context, Result};

/// Byte size of one stored parameter.
const F64B: usize = std::mem::size_of::<f64>();

/// Render an activation token (`-` when absent).
fn act_token(act: Option<Act>) -> String {
    match act {
        None => "-".into(),
        Some(Act::Relu) => "relu".into(),
        Some(Act::LeakyRelu { alpha }) => format!("leaky_relu:{alpha}"),
        Some(Act::Tanh) => "tanh".into(),
        Some(Act::Sigmoid) => "sigmoid".into(),
    }
}

/// `x`-joined shape token (`6x6x1`; rank-1 is just the length).
fn shape_token(shape: &[usize]) -> String {
    let dims: Vec<String> = shape.iter().map(|d| d.to_string()).collect();
    dims.join("x")
}

/// Comma-joined list token with `-` for empty.
fn list_token<I: IntoIterator<Item = String>>(items: I) -> String {
    let items: Vec<String> = items.into_iter().collect();
    if items.is_empty() {
        "-".into()
    } else {
        items.join(",")
    }
}

/// One parsed/rendered body line: an id (`b3`, `s0`, `total`), an
/// optional bare tag (the step kind), and ordered `key=value` fields,
/// all kept verbatim so re-rendering reproduces the source bytes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Line {
    /// Line id (`b<i>` / `s<i>` / `total`).
    pub id: String,
    /// Bare tag after the id (step kind; empty when absent).
    pub tag: String,
    /// Ordered `key=value` fields.
    pub fields: Vec<(String, String)>,
}

impl Line {
    fn new(id: String, tag: &str) -> Line {
        Line { id, tag: tag.into(), fields: Vec::new() }
    }

    fn push(&mut self, key: &str, value: String) {
        self.fields.push((key.into(), value));
    }

    /// The value of field `key`, if present.
    pub fn field(&self, key: &str) -> Option<&str> {
        self.fields.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    fn render(&self) -> String {
        let mut s = self.id.clone();
        if !self.tag.is_empty() {
            s.push(' ');
            s.push_str(&self.tag);
        }
        for (k, v) in &self.fields {
            s.push(' ');
            s.push_str(k);
            s.push('=');
            s.push_str(v);
        }
        s
    }

    fn parse(text: &str, want_tag: bool) -> Result<Line> {
        let mut toks = text.split_whitespace();
        let id = toks.next().context("empty line where an entry was expected")?.to_string();
        let mut line = Line::new(id, "");
        for (i, tok) in toks.enumerate() {
            match tok.split_once('=') {
                Some((k, v)) => line.push(k, v.into()),
                None if i == 0 && want_tag && line.id != "total" => line.tag = tok.into(),
                None => bail!("stray token '{tok}' in line '{text}'"),
            }
        }
        Ok(line)
    }
}

/// A parsed textual plan: the header fields plus the three body
/// sections, with every line's tokens preserved verbatim (so
/// [`PlanText::render`] of a [`PlanText::parse`] result is
/// byte-identical to the source).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlanText {
    /// Model name (`plan` header line).
    pub name: String,
    /// Fusion token (`none` / `pair` / `full`).
    pub fusion: String,
    /// Kernel-path token (`scalar` / `blocked`).
    pub kernels: String,
    /// Input wiring: `b<i> <shape>`.
    pub input: String,
    /// Output wiring: `b<i> <shape>`.
    pub output: String,
    /// Buffer lines (`b<i> len=... writers=... readers=...`).
    pub buffers: Vec<Line>,
    /// Step lines (`s<i> <kind> ...`).
    pub steps: Vec<Line>,
    /// Memory lines (`s<i> <kind> ...` plus the trailing `total`).
    pub memory: Vec<Line>,
}

impl PlanText {
    /// Build the structured text of a compiled plan (the typed form
    /// behind [`Plan::to_text`]).
    pub fn of(plan: &Plan) -> PlanText {
        let fusion = match plan.fusion {
            Fusion::None => "none",
            Fusion::Pair => "pair",
            Fusion::Full => "full",
        };
        let kernels = match plan.kernel_path {
            KernelPath::Scalar => "scalar",
            KernelPath::Blocked => "blocked",
        };

        // Buffer liveness: which steps write/read each pool buffer.
        let nbufs = plan.buf_lens.len();
        let mut writers: Vec<Vec<usize>> = vec![Vec::new(); nbufs];
        let mut readers: Vec<Vec<usize>> = vec![Vec::new(); nbufs];
        for (i, s) in plan.steps.iter().enumerate() {
            for &b in &s.inputs {
                if readers[b].last() != Some(&i) {
                    readers[b].push(i);
                }
            }
            writers[s.out].push(i);
        }
        let buffers = (0..nbufs)
            .map(|b| {
                let mut line = Line::new(format!("b{b}"), "");
                line.push("len", plan.buf_lens[b].to_string());
                line.push("writers", list_token(writers[b].iter().map(|s| format!("s{s}"))));
                line.push("readers", list_token(readers[b].iter().map(|s| format!("s{s}"))));
                line
            })
            .collect();

        let steps = plan.steps.iter().enumerate().map(|(i, s)| step_line(plan, i, s)).collect();

        let report = plan.memory_report();
        let mut memory: Vec<Line> = report
            .steps
            .iter()
            .map(|m| {
                let mut line = Line::new(format!("s{}", m.index), m.kind);
                push_mem_fields(
                    &mut line,
                    m.weight_bytes,
                    m.shared_bytes,
                    m.panel_bytes,
                    m.table_bytes,
                    m.baseline_bytes,
                );
                line
            })
            .collect();
        let mut total = Line::new("total".into(), "");
        push_mem_fields(
            &mut total,
            report.weight_bytes(),
            report.shared_bytes(),
            report.panel_bytes(),
            report.table_bytes(),
            report.baseline_bytes(),
        );
        memory.push(total);

        PlanText {
            name: plan.model_name.clone(),
            fusion: fusion.into(),
            kernels: kernels.into(),
            input: format!("b{} {}", plan.input_buf, shape_token(&plan.input_shape)),
            output: format!("b{} {}", plan.output_buf, shape_token(&plan.output_shape)),
            buffers,
            steps,
            memory,
        }
    }

    /// Render the canonical text (see the module docs for the grammar
    /// and determinism rules).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("plan {}\n", self.name));
        out.push_str(&format!("fusion {}\n", self.fusion));
        out.push_str(&format!("kernels {}\n", self.kernels));
        out.push_str(&format!("input {}\n", self.input));
        out.push_str(&format!("output {}\n", self.output));
        out.push_str(&format!("\nbuffers {}\n", self.buffers.len()));
        for b in &self.buffers {
            out.push_str(&b.render());
            out.push('\n');
        }
        out.push_str(&format!("\nsteps {}\n", self.steps.len()));
        for s in &self.steps {
            out.push_str(&s.render());
            out.push('\n');
        }
        out.push_str("\nmemory\n");
        for m in &self.memory {
            out.push_str(&m.render());
            out.push('\n');
        }
        out
    }

    /// Parse a rendered plan back into its structured form. Tokens are
    /// preserved verbatim, so `parse(text).render() == text` for any
    /// text this module rendered.
    pub fn parse(text: &str) -> Result<PlanText> {
        fn header(lines: &mut std::str::Lines<'_>, key: &str) -> Result<String> {
            let line = lines.next().with_context(|| format!("missing '{key}' header"))?;
            line.strip_prefix(key)
                .and_then(|rest| rest.strip_prefix(' '))
                .map(str::to_string)
                .with_context(|| format!("expected '{key} ...', got '{line}'"))
        }
        /// Consume the blank separator plus the `<keyword> <n>` (or bare
        /// `<keyword>`) section line, returning the count if present.
        fn section(lines: &mut std::str::Lines<'_>, keyword: &str) -> Result<Option<usize>> {
            match lines.next() {
                Some("") => {}
                other => bail!("expected blank line before '{keyword}', got {other:?}"),
            }
            let line = lines.next().with_context(|| format!("missing '{keyword}' section"))?;
            if line == keyword {
                return Ok(None); // uncounted section
            }
            let n = line
                .strip_prefix(keyword)
                .and_then(|rest| rest.strip_prefix(' '))
                .and_then(|n| n.parse::<usize>().ok())
                .with_context(|| format!("expected '{keyword} <n>', got '{line}'"))?;
            Ok(Some(n))
        }

        let mut lines = text.lines();
        let name = header(&mut lines, "plan")?;
        let fusion = header(&mut lines, "fusion")?;
        let kernels = header(&mut lines, "kernels")?;
        let input = header(&mut lines, "input")?;
        let output = header(&mut lines, "output")?;

        let nbufs = section(&mut lines, "buffers")?.context("'buffers' needs a count")?;
        let mut buffers = Vec::with_capacity(nbufs);
        for _ in 0..nbufs {
            buffers.push(Line::parse(lines.next().context("truncated buffers section")?, false)?);
        }
        let nsteps = section(&mut lines, "steps")?.context("'steps' needs a count")?;
        let mut steps = Vec::with_capacity(nsteps);
        for _ in 0..nsteps {
            steps.push(Line::parse(lines.next().context("truncated steps section")?, true)?);
        }
        if section(&mut lines, "memory")?.is_some() {
            bail!("'memory' section carries no count");
        }
        let mut memory = Vec::new();
        for line in lines {
            if line.is_empty() {
                bail!("unexpected blank line inside the memory section");
            }
            memory.push(Line::parse(line, true)?);
        }
        match memory.last() {
            Some(total) if total.id == "total" => {}
            _ => bail!("memory section must end with a 'total' line"),
        }
        Ok(PlanText { name, fusion, kernels, input, output, buffers, steps, memory })
    }
}

/// Append the memory-report fields shared by per-step and total lines.
fn push_mem_fields(
    line: &mut Line,
    weights: usize,
    shared: usize,
    panel: usize,
    table: usize,
    baseline: usize,
) {
    line.push("weights", weights.to_string());
    line.push("shared", shared.to_string());
    line.push("panel", panel.to_string());
    line.push("table", table.to_string());
    line.push("resident", (weights + panel + table).to_string());
    line.push("baseline", baseline.to_string());
}

/// Render one step line (wiring + geometry + kind-specific attributes).
fn step_line(plan: &Plan, i: usize, s: &super::Step) -> Line {
    let mut line = Line::new(format!("s{i}"), s.kind.name());
    line.push("in", list_token(s.inputs.iter().map(|b| format!("b{b}"))));
    line.push("out", format!("b{}", s.out));
    line.push("in_shapes", list_token(s.in_shapes.iter().map(|sh| shape_token(sh))));
    line.push("out_shape", shape_token(&s.out_shape));
    line.push("act", act_token(s.fused_act));
    line.push("layers", format!("{}..{}", s.layer_range.0, s.layer_range.1));
    line.push("deps", list_token(plan.deps[i].iter().map(|d| format!("s{d}"))));
    let lower = match &plan.blocked[i] {
        None => "-",
        Some(BlockedStep::Dense(_)) => "panel",
        Some(BlockedStep::Conv(_)) => "im2col",
        Some(BlockedStep::Depthwise(_)) => "taps",
        Some(BlockedStep::AvgPool(_)) => "pool",
    };
    line.push("lower", lower.into());
    match &s.kind {
        StepKind::Dense { w, b } => {
            let (m, n) = w.dims();
            line.push("w", format!("{m}x{n}"));
            let wsrc = match w {
                DenseWeights::Tensor(sw) if sw.folded() => "folded",
                DenseWeights::Tensor(_) => "shared",
                DenseWeights::PanelOnly { .. } => "panel",
            };
            line.push("wsrc", wsrc.into());
            line.push("params", (m * n + b.len()).to_string());
        }
        StepKind::Conv2D { kernel, bias, stride, padding } => {
            line.push("k", shape_token(kernel.shape()));
            line.push("stride", stride.to_string());
            line.push("pad", padding.as_str().into());
            line.push("wsrc", if kernel.folded() { "folded" } else { "shared" }.into());
            line.push("params", (kernel.len() + bias.len()).to_string());
        }
        StepKind::DepthwiseConv2D { kernel, bias, stride, padding } => {
            line.push("k", shape_token(kernel.shape()));
            line.push("stride", stride.to_string());
            line.push("pad", padding.as_str().into());
            line.push("wsrc", if kernel.folded() { "folded" } else { "shared" }.into());
            line.push("params", (kernel.len() + bias.len()).to_string());
        }
        StepKind::MaxPool2D { ph, pw } | StepKind::AvgPool2D { ph, pw } => {
            line.push("window", format!("{ph}x{pw}"));
        }
        StepKind::BatchNorm { gamma, beta, mean, variance, eps } => {
            line.push("c", gamma.len().to_string());
            line.push("eps", eps.to_string());
            line.push(
                "params",
                (gamma.len() + beta.len() + mean.len() + variance.len()).to_string(),
            );
        }
        StepKind::Act(Act::LeakyRelu { alpha }) => line.push("alpha", alpha.to_string()),
        StepKind::Concat { rows, widths } => {
            line.push("rows", rows.to_string());
            line.push("widths", list_token(widths.iter().map(|w| w.to_string())));
        }
        StepKind::Flatten
        | StepKind::Act(_)
        | StepKind::Softmax
        | StepKind::Add => {}
    }
    line
}

/// Per-step resident-bytes breakdown (all in bytes; see the module docs
/// for the field semantics).
#[derive(Clone, Copy, Debug)]
pub struct StepMemory {
    /// Step index.
    pub index: usize,
    /// Step kind tag.
    pub kind: &'static str,
    /// Parameter bytes the plan owns (folded weight copies, biases,
    /// batch-norm vectors).
    pub weight_bytes: usize,
    /// Weight-tensor bytes `Arc`-shared with the model's layers — kept
    /// alive by the model anyway, so not charged to `resident`.
    pub shared_bytes: usize,
    /// Packed dense-panel bytes ([`super::gemm::DensePanel`]).
    pub panel_bytes: usize,
    /// im2col / tap-table bytes.
    pub table_bytes: usize,
    /// What the pre-diet layout would hold resident for this step:
    /// every parameter cloned plus full per-pixel conv tables.
    pub baseline_bytes: usize,
}

impl StepMemory {
    /// Plan-owned resident bytes: `weights + panel + table`.
    pub fn resident_bytes(&self) -> usize {
        self.weight_bytes + self.panel_bytes + self.table_bytes
    }
}

/// The per-step memory accounting of one compiled plan
/// ([`Plan::memory_report`]); printed as the `memory` section of the
/// textual IR.
#[derive(Clone, Debug)]
pub struct MemoryReport {
    /// Per-step breakdown, index-aligned with the plan's step list.
    pub steps: Vec<StepMemory>,
}

impl MemoryReport {
    /// Total plan-owned parameter bytes.
    pub fn weight_bytes(&self) -> usize {
        self.steps.iter().map(|s| s.weight_bytes).sum()
    }

    /// Total `Arc`-shared (layer-owned) weight bytes.
    pub fn shared_bytes(&self) -> usize {
        self.steps.iter().map(|s| s.shared_bytes).sum()
    }

    /// Total packed dense-panel bytes.
    pub fn panel_bytes(&self) -> usize {
        self.steps.iter().map(|s| s.panel_bytes).sum()
    }

    /// Total im2col/tap-table bytes.
    pub fn table_bytes(&self) -> usize {
        self.steps.iter().map(|s| s.table_bytes).sum()
    }

    /// Total plan-owned resident bytes.
    pub fn resident_bytes(&self) -> usize {
        self.steps.iter().map(|s| s.resident_bytes()).sum()
    }

    /// Total pre-diet baseline bytes.
    pub fn baseline_bytes(&self) -> usize {
        self.steps.iter().map(|s| s.baseline_bytes).sum()
    }
}

impl Plan {
    /// Render the canonical textual IR (see the [module docs](self) for
    /// the grammar): header, buffer pool with liveness, steps with
    /// wiring/geometry/hazard edges, and the memory report. Two
    /// compiles of the same model at the same configuration render
    /// byte-identically.
    ///
    /// ```
    /// use rigor::model::zoo;
    /// use rigor::plan::{Fusion, Plan, PlanText};
    ///
    /// let plan = Plan::build(&zoo::tiny_mlp(1), Fusion::Pair)?;
    /// let text = plan.to_text();
    /// assert!(text.starts_with("plan tiny_mlp\nfusion pair\n"));
    /// // The parser round-trips the rendering byte-identically.
    /// assert_eq!(PlanText::parse(&text)?.render(), text);
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn to_text(&self) -> String {
        PlanText::of(self).render()
    }

    /// Per-step memory accounting: what this plan keeps resident
    /// (owned parameters, packed panels, gather tables), what it shares
    /// with the model's layers, and what the pre-diet layout would have
    /// held. See the [module docs](self) for field semantics.
    pub fn memory_report(&self) -> MemoryReport {
        let steps = self
            .steps
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let (mut weight, mut shared) = (0usize, 0usize);
                match &s.kind {
                    StepKind::Dense { w, b } => {
                        match w {
                            DenseWeights::Tensor(sw) if sw.folded() => weight += sw.param_bytes(),
                            DenseWeights::Tensor(sw) => shared += sw.param_bytes(),
                            DenseWeights::PanelOnly { .. } => {}
                        }
                        weight += b.len() * F64B;
                    }
                    StepKind::Conv2D { kernel, bias, .. }
                    | StepKind::DepthwiseConv2D { kernel, bias, .. } => {
                        if kernel.folded() {
                            weight += kernel.param_bytes();
                        } else {
                            shared += kernel.param_bytes();
                        }
                        weight += bias.len() * F64B;
                    }
                    StepKind::BatchNorm { gamma, beta, mean, variance, .. } => {
                        weight +=
                            (gamma.len() + beta.len() + mean.len() + variance.len()) * F64B;
                    }
                    _ => {}
                }
                let (panel, table, full_table) = match &self.blocked[i] {
                    Some(BlockedStep::Dense(pd)) => (pd.panel_bytes(), 0, 0),
                    Some(BlockedStep::Conv(ic)) => (0, ic.table_bytes(), ic.full_table_bytes()),
                    Some(BlockedStep::Depthwise(dw)) => {
                        (0, dw.table_bytes(), dw.full_table_bytes())
                    }
                    Some(BlockedStep::AvgPool(pt)) => (0, pt.table_bytes(), pt.full_table_bytes()),
                    None => (0, 0, 0),
                };
                // Pre-diet: every parameter cloned into the step, full
                // per-pixel conv/pool tables, same panels.
                let baseline = match &s.kind {
                    StepKind::Dense { w, b } => {
                        let (m, n) = w.dims();
                        (m * n + b.len()) * F64B + panel
                    }
                    StepKind::Conv2D { kernel, bias, .. }
                    | StepKind::DepthwiseConv2D { kernel, bias, .. } => {
                        (kernel.len() + bias.len()) * F64B + full_table
                    }
                    StepKind::AvgPool2D { .. } => full_table,
                    _ => weight + table,
                };
                StepMemory {
                    index: i,
                    kind: s.kind.name(),
                    weight_bytes: weight,
                    shared_bytes: shared,
                    panel_bytes: panel,
                    table_bytes: table,
                    baseline_bytes: baseline,
                }
            })
            .collect();
        MemoryReport { steps }
    }
}

/// Which body section of the textual IR an [`Edit`] refers to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Section {
    /// The buffer-pool section.
    Buffers,
    /// The step list.
    Steps,
    /// The memory report.
    Memory,
}

impl std::fmt::Display for Section {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Section::Buffers => "buffer",
            Section::Steps => "step",
            Section::Memory => "memory",
        })
    }
}

/// One structural mismatch between two textual plans — the unit the
/// golden suite reports instead of a raw text diff.
#[derive(Clone, Debug)]
pub enum Edit {
    /// A header field (`plan`/`fusion`/`kernels`/`input`/`output`)
    /// differs.
    Header {
        /// Header keyword.
        field: String,
        /// Old value.
        old: String,
        /// New value.
        new: String,
    },
    /// A line exists only in the old plan.
    Removed {
        /// Section the line belonged to.
        section: Section,
        /// The removed line, rendered.
        line: String,
    },
    /// A line exists only in the new plan.
    Added {
        /// Section the line belongs to.
        section: Section,
        /// The added line, rendered.
        line: String,
    },
    /// Two corresponding lines differ in specific fields.
    Changed {
        /// Section the lines belong to.
        section: Section,
        /// Id of the old line (ids can shift when steps are
        /// inserted/removed; pairing is structural, not positional).
        id: String,
        /// `(field, old, new)` per differing field (`-` = absent).
        fields: Vec<(String, String, String)>,
    },
}

impl std::fmt::Display for Edit {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Edit::Header { field, old, new } => {
                write!(f, "header {field}: '{old}' -> '{new}'")
            }
            Edit::Removed { section, line } => write!(f, "{section} removed: {line}"),
            Edit::Added { section, line } => write!(f, "{section} added: {line}"),
            Edit::Changed { section, id, fields } => {
                write!(f, "{section} {id} changed:")?;
                for (field, old, new) in fields {
                    write!(f, " {field} {old} -> {new};")?;
                }
                Ok(())
            }
        }
    }
}

/// Field-level differences between two matched lines (tag included as
/// the pseudo-field `kind`).
fn field_changes(old: &Line, new: &Line) -> Vec<(String, String, String)> {
    let mut out = Vec::new();
    if old.tag != new.tag {
        out.push(("kind".into(), old.tag.clone(), new.tag.clone()));
    }
    for (k, ov) in &old.fields {
        match new.field(k) {
            Some(nv) if nv == ov => {}
            Some(nv) => out.push((k.clone(), ov.clone(), nv.into())),
            None => out.push((k.clone(), ov.clone(), "-".into())),
        }
    }
    for (k, nv) in &new.fields {
        if old.field(k).is_none() {
            out.push((k.clone(), "-".into(), nv.clone()));
        }
    }
    out
}

/// Diff two sections whose line ids are stable (buffers, memory): match
/// by id, compare fields.
fn diff_by_id(section: Section, old: &[Line], new: &[Line], edits: &mut Vec<Edit>) {
    for o in old {
        match new.iter().find(|n| n.id == o.id) {
            None => edits.push(Edit::Removed { section, line: o.render() }),
            Some(n) => {
                let fields = field_changes(o, n);
                if !fields.is_empty() {
                    edits.push(Edit::Changed { section, id: o.id.clone(), fields });
                }
            }
        }
    }
    for n in new {
        if !old.iter().any(|o| o.id == n.id) {
            edits.push(Edit::Added { section, line: n.render() });
        }
    }
}

/// Diff the step lists structurally: longest-common-subsequence over
/// identical lines anchors the unchanged steps, then each unmatched run
/// pairs old/new steps of the same kind in order (reported as
/// field-level [`Edit::Changed`]) and the leftovers become
/// [`Edit::Added`]/[`Edit::Removed`]. Step ids shifting under an
/// insertion therefore do not cascade into noise: a de-fused step shows
/// up as one changed step plus one added step.
fn diff_steps(old: &[Line], new: &[Line], edits: &mut Vec<Edit>) {
    // LCS table over full line equality.
    let (n, m) = (old.len(), new.len());
    let mut lcs = vec![vec![0usize; m + 1]; n + 1];
    for i in (0..n).rev() {
        for j in (0..m).rev() {
            lcs[i][j] = if line_matches(&old[i], &new[j]) {
                lcs[i + 1][j + 1] + 1
            } else {
                lcs[i + 1][j].max(lcs[i][j + 1])
            };
        }
    }
    // Walk the table, collecting unmatched runs.
    let (mut i, mut j) = (0, 0);
    let mut pending_old: Vec<&Line> = Vec::new();
    let mut pending_new: Vec<&Line> = Vec::new();
    let flush =
        |pending_old: &mut Vec<&Line>, pending_new: &mut Vec<&Line>, edits: &mut Vec<Edit>| {
            // Pair same-kind steps in order; leftovers are adds/removes.
            let mut unused_new: Vec<Option<&Line>> =
                pending_new.drain(..).map(Some).collect();
            for o in pending_old.drain(..) {
                let slot = unused_new
                    .iter_mut()
                    .find(|slot| slot.is_some_and(|l| l.tag == o.tag));
                match slot {
                    Some(slot) => {
                        let l = slot.take().expect("checked is_some above");
                        let fields = field_changes(o, l);
                        if !fields.is_empty() {
                            edits.push(Edit::Changed {
                                section: Section::Steps,
                                id: o.id.clone(),
                                fields,
                            });
                        }
                    }
                    None => {
                        edits.push(Edit::Removed { section: Section::Steps, line: o.render() })
                    }
                }
            }
            for l in unused_new.into_iter().flatten() {
                edits.push(Edit::Added { section: Section::Steps, line: l.render() });
            }
        };
    while i < n && j < m {
        if line_matches(&old[i], &new[j]) {
            flush(&mut pending_old, &mut pending_new, edits);
            i += 1;
            j += 1;
        } else if lcs[i + 1][j] >= lcs[i][j + 1] {
            pending_old.push(&old[i]);
            i += 1;
        } else {
            pending_new.push(&new[j]);
            j += 1;
        }
    }
    pending_old.extend(old[i..].iter());
    pending_new.extend(new[j..].iter());
    flush(&mut pending_old, &mut pending_new, edits);
}

/// Anchor equality for the step LCS: identical tag and fields. The id
/// is deliberately ignored so a pure renumbering (steps shifted by an
/// insertion above them) still anchors.
fn line_matches(a: &Line, b: &Line) -> bool {
    a.tag == b.tag && a.fields == b.fields
}

/// Structurally compare two textual plans, reporting per-header,
/// per-buffer, per-step and per-memory-line edits (empty = identical up
/// to step renumbering). The golden suite prints these instead of a
/// text dump.
pub fn diff(old: &PlanText, new: &PlanText) -> Vec<Edit> {
    let mut edits = Vec::new();
    let headers = [
        ("plan", &old.name, &new.name),
        ("fusion", &old.fusion, &new.fusion),
        ("kernels", &old.kernels, &new.kernels),
        ("input", &old.input, &new.input),
        ("output", &old.output, &new.output),
    ];
    for (field, o, n) in headers {
        if o != n {
            edits.push(Edit::Header { field: field.into(), old: o.clone(), new: n.clone() });
        }
    }
    diff_by_id(Section::Buffers, &old.buffers, &new.buffers, &mut edits);
    diff_steps(&old.steps, &new.steps, &mut edits);
    diff_by_id(Section::Memory, &old.memory, &new.memory, &mut edits);
    edits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn render_parse_round_trips_across_zoo_and_configs() {
        for model in [
            zoo::tiny_mlp(1),
            zoo::tiny_cnn(2),
            zoo::avgpool_cnn(3),
            zoo::residual_mlp(4),
            zoo::residual_cnn(5),
        ] {
            for fusion in [Fusion::None, Fusion::Pair, Fusion::Full] {
                for kernels in [KernelPath::Scalar, KernelPath::Blocked] {
                    let plan = Plan::build_with_kernels(&model, fusion, kernels).unwrap();
                    let text = plan.to_text();
                    let parsed = PlanText::parse(&text).unwrap();
                    assert_eq!(parsed.render(), text, "{} {fusion:?} {kernels:?}", model.name);
                    assert!(diff(&parsed, &PlanText::of(&plan)).is_empty());
                }
            }
        }
    }

    #[test]
    fn consecutive_compiles_render_byte_identically() {
        for seed in [7, 8] {
            let model = zoo::residual_cnn(seed);
            let a = Plan::build_with_kernels(&model, Fusion::Full, KernelPath::Blocked).unwrap();
            let b = Plan::build_with_kernels(&model, Fusion::Full, KernelPath::Blocked).unwrap();
            assert_eq!(a.to_text(), b.to_text());
        }
    }

    #[test]
    fn differ_reports_defusion_as_step_level_edits() {
        // Hand-introduced de-fusion: the paired plan fuses activations
        // the unfused plan keeps as standalone steps. The differ must
        // report changed/added steps, not a wall of renumbering noise.
        let model = zoo::tiny_mlp(3);
        let fused = PlanText::of(&Plan::build(&model, Fusion::Pair).unwrap());
        let unfused = PlanText::of(&Plan::build(&model, Fusion::None).unwrap());
        let edits = diff(&fused, &unfused);
        assert!(!edits.is_empty());
        let changed_act = edits.iter().any(|e| match e {
            Edit::Changed { section: Section::Steps, fields, .. } => {
                fields.iter().any(|(f, o, _)| f == "act" && o == "relu")
            }
            _ => false,
        });
        let added_relu = edits.iter().any(
            |e| matches!(e, Edit::Added { section: Section::Steps, line } if line.contains("relu")),
        );
        assert!(changed_act, "de-fused step must surface as an act change: {edits:?}");
        assert!(added_relu, "standalone activation must surface as an added step: {edits:?}");
        // And identical plans diff clean.
        assert!(diff(&fused, &fused).is_empty());
    }

    #[test]
    fn memory_report_totals_match_text_total_line() {
        let model = zoo::residual_cnn(9);
        let plan = Plan::build_with_kernels(&model, Fusion::Full, KernelPath::Blocked).unwrap();
        let report = plan.memory_report();
        let text = PlanText::of(&plan);
        let total = text.memory.last().unwrap();
        assert_eq!(total.field("resident").unwrap(), report.resident_bytes().to_string());
        assert_eq!(total.field("baseline").unwrap(), report.baseline_bytes().to_string());
        assert!(report.baseline_bytes() >= 2 * report.resident_bytes());
    }
}
