//! **The compiled execution plan** — a model lowered, once per
//! `(model, input_shape)`, into a straight-line sequence of shape-resolved
//! [`Step`]s that a generic executor runs with a preallocated double-buffer
//! [`Arena`].
//!
//! This is the Rust analogue of the paper's compile-first design: the
//! original tool turns a Keras model into straight-line C++ (via
//! frugally-deep) precisely so the *same compiled evaluation* drives both
//! the FP inference and the error analysis. Here, [`Plan::build`]:
//!
//! 1. **Resolves all shapes ahead of time** — every geometry check that the
//!    per-layer interpreter re-ran inside the inner loop
//!    ([`Layer::output_shape`]'s `Result`s) happens once at build; the
//!    executor's steady state is check-free.
//! 2. **Fuses statically** per the requested [`Fusion`] level:
//!    * [`Fusion::Pair`] attaches elementwise activations to the preceding
//!      compute step (applied in place on its output buffer — the same
//!      operations in the same order, so CAA bounds are bit-identical to
//!      the interpreter; this level is safe for analysis).
//!    * [`Fusion::Full`] additionally folds `BatchNormalization` into the
//!      preceding `Conv2D`/`Dense`/`DepthwiseConv2D` affine form. Folding
//!      *changes the rounding profile* (the per-channel scale is absorbed
//!      into the weights at build time in f64), so it is reserved for the
//!      f64 reference trace and throughput-oriented witness runs — never
//!      for CAA, whose rounding-error bookkeeping must match the analyzed
//!      computation exactly (the "unfused-for-CAA" mode).
//!    * [`Fusion::None`] keeps a 1:1 step-per-layer mapping — the mode the
//!      mixed-precision path uses so per-layer format boundaries stay
//!      addressable.
//! 3. **Preallocates**: the executor ping-pongs between two arena buffers
//!    sized at first use; steady-state inference performs zero tensor
//!    allocations (`O(channels)`/`O(classes)` scalar temporaries remain for
//!    batch-norm parameter embedding and softmax rows).
//!
//! The executor ([`Plan::execute`]) is generic over [`Scalar`], so the f64
//! baseline, the interval/CAA analysis pass, and the emulated precision-k
//! witness runs all execute the same compiled steps. [`crate::api::Session`]
//! caches an `Arc<Plan>` next to each model in its content-hash LRU;
//! [`crate::coordinator`] hands every worker thread its own arena.
//!
//! The IR is deliberately sequential for now; the step list (rather than
//! the `Vec<Layer>` it replaces) is where graph topologies and per-step
//! precision assignments will hang (see ROADMAP.md "Open items").

mod exec;

pub use exec::Arena;

use crate::layers::{Layer, Padding};
use crate::model::Model;
use crate::tensor::Tensor;
use anyhow::{Context, Result};

/// Fusion level a plan is compiled at. See the module docs for the
/// soundness contract of each level.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fusion {
    /// One step per layer, no pairing — exact legacy interpreter
    /// semantics; required by the mixed-precision path (per-layer format
    /// boundaries address steps 1:1).
    None,
    /// Pair elementwise activations with the preceding compute step.
    /// Arithmetic is unchanged (CAA-safe).
    Pair,
    /// [`Fusion::Pair`] plus batch-norm folding into the preceding affine
    /// step. f64/witness executions only — **not** CAA-sound.
    Full,
}

/// An elementwise activation a compute step can apply in place on its
/// output buffer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Act {
    Relu,
    LeakyRelu { alpha: f64 },
    Tanh,
    Sigmoid,
}

/// What a step computes. Parameters are owned (folded copies where fusion
/// rewrote them), so a plan is self-contained and shareable via `Arc`.
#[derive(Clone, Debug)]
pub enum StepKind {
    /// `y = W x + b`, `w: [units, in]`.
    Dense { w: Tensor<f64>, b: Vec<f64> },
    /// 2-D convolution, kernel `[kh, kw, cin, cout]`.
    Conv2D { kernel: Tensor<f64>, bias: Vec<f64>, stride: usize, padding: Padding },
    /// Depthwise 2-D convolution, kernel `[kh, kw, c]`.
    DepthwiseConv2D { kernel: Tensor<f64>, bias: Vec<f64>, stride: usize, padding: Padding },
    /// Max pooling over `[ph, pw]` windows.
    MaxPool2D { ph: usize, pw: usize },
    /// Average pooling over `[ph, pw]` windows.
    AvgPool2D { ph: usize, pw: usize },
    /// Inference-mode batch normalization (kept materialized at
    /// [`Fusion::None`]/[`Fusion::Pair`]; folded away at [`Fusion::Full`]).
    BatchNorm { gamma: Vec<f64>, beta: Vec<f64>, mean: Vec<f64>, variance: Vec<f64>, eps: f64 },
    /// Shape-only: the executor treats this as a no-op on the flat buffer.
    Flatten,
    /// A standalone elementwise activation (not paired; applied in place).
    Act(Act),
    /// Numerically-stable softmax over the last axis.
    Softmax,
}

impl StepKind {
    /// Whether this step produces a fresh output buffer (as opposed to
    /// operating in place / being shape-only).
    fn writes_output(&self) -> bool {
        !matches!(self, StepKind::Flatten | StepKind::Act(_))
    }

    /// Whether an activation may be paired onto this step's output.
    fn accepts_fused_act(&self) -> bool {
        self.writes_output() && !matches!(self, StepKind::Softmax)
    }

    /// Short tag for diagnostics and plan dumps.
    pub fn name(&self) -> &'static str {
        match self {
            StepKind::Dense { .. } => "dense",
            StepKind::Conv2D { .. } => "conv2d",
            StepKind::DepthwiseConv2D { .. } => "depthwise_conv2d",
            StepKind::MaxPool2D { .. } => "max_pool2d",
            StepKind::AvgPool2D { .. } => "avg_pool2d",
            StepKind::BatchNorm { .. } => "batch_norm",
            StepKind::Flatten => "flatten",
            StepKind::Act(Act::Relu) => "relu",
            StepKind::Act(Act::LeakyRelu { .. }) => "leaky_relu",
            StepKind::Act(Act::Tanh) => "tanh",
            StepKind::Act(Act::Sigmoid) => "sigmoid",
            StepKind::Softmax => "softmax",
        }
    }
}

/// One compiled step: kind + statically resolved geometry + provenance.
#[derive(Clone, Debug)]
pub struct Step {
    pub kind: StepKind,
    /// Input shape, validated at build time.
    pub in_shape: Vec<usize>,
    /// Output shape (after the fused activation, which preserves shape).
    pub out_shape: Vec<usize>,
    /// Elementwise activation applied in place on this step's output
    /// buffer, if fusion paired one.
    pub fused_act: Option<Act>,
    /// Model layer indices `[lo, hi)` this step covers (provenance for
    /// diagnostics and per-layer precision maps).
    pub layer_range: (usize, usize),
}

impl Step {
    pub fn in_len(&self) -> usize {
        self.in_shape.iter().product()
    }

    pub fn out_len(&self) -> usize {
        self.out_shape.iter().product()
    }
}

/// A compiled, shape-resolved, optionally fused execution plan for one
/// model. Build once, execute many times (generic over [`crate::tensor::Scalar`]).
#[derive(Clone, Debug)]
pub struct Plan {
    model_name: String,
    input_shape: Vec<usize>,
    output_shape: Vec<usize>,
    steps: Vec<Step>,
    fusion: Fusion,
    max_buf: usize,
}

impl Plan {
    /// Compile `model` at the given fusion level. All shape inference and
    /// geometry validation happens here; a returned plan executes
    /// check-free.
    pub fn build(model: &Model, fusion: Fusion) -> Result<Plan> {
        let mut steps = Vec::with_capacity(model.layers.len());
        let mut shape = model.input_shape.clone();
        for (i, layer) in model.layers.iter().enumerate() {
            let out_shape = layer
                .output_shape(&shape)
                .with_context(|| format!("plan: layer {i} ({})", layer.type_name()))?;
            steps.push(Step {
                kind: lower_layer(layer),
                in_shape: shape,
                out_shape: out_shape.clone(),
                fused_act: None,
                layer_range: (i, i + 1),
            });
            shape = out_shape;
        }
        if fusion == Fusion::Full {
            fold_batch_norms(&mut steps);
        }
        if fusion != Fusion::None {
            pair_activations(&mut steps);
        }
        let max_buf = steps
            .iter()
            .map(Step::out_len)
            .chain(std::iter::once(model.input_shape.iter().product()))
            .max()
            .unwrap_or(0);
        Ok(Plan {
            model_name: model.name.clone(),
            input_shape: model.input_shape.clone(),
            output_shape: shape,
            steps,
            fusion,
            max_buf,
        })
    }

    /// The analysis plan: activation pairing only — arithmetic identical
    /// to the interpreter, so CAA bounds are unchanged.
    pub fn for_analysis(model: &Model) -> Result<Plan> {
        Plan::build(model, Fusion::Pair)
    }

    /// The reference/witness plan: batch norms folded into the preceding
    /// affine steps (f64 trace and throughput witness runs only).
    pub fn for_reference(model: &Model) -> Result<Plan> {
        Plan::build(model, Fusion::Full)
    }

    /// A 1:1 step-per-layer plan (legacy interpreter semantics; the
    /// mixed-precision path's addressing mode).
    pub fn unfused(model: &Model) -> Result<Plan> {
        Plan::build(model, Fusion::None)
    }

    pub fn model_name(&self) -> &str {
        &self.model_name
    }

    pub fn fusion(&self) -> Fusion {
        self.fusion
    }

    pub fn steps(&self) -> &[Step] {
        &self.steps
    }

    pub fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    pub fn output_shape(&self) -> &[usize] {
        &self.output_shape
    }

    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn output_len(&self) -> usize {
        self.output_shape.iter().product()
    }

    /// Largest element count any step buffer reaches (arena sizing).
    pub fn max_buffer_len(&self) -> usize {
        self.max_buf
    }
}

/// Lower one layer into its (unfused) step kind, cloning the parameters so
/// the plan owns them.
fn lower_layer(layer: &Layer) -> StepKind {
    match layer {
        Layer::Dense { w, b } => StepKind::Dense { w: w.clone(), b: b.clone() },
        Layer::Conv2D { kernel, bias, stride, padding } => StepKind::Conv2D {
            kernel: kernel.clone(),
            bias: bias.clone(),
            stride: *stride,
            padding: *padding,
        },
        Layer::DepthwiseConv2D { kernel, bias, stride, padding } => StepKind::DepthwiseConv2D {
            kernel: kernel.clone(),
            bias: bias.clone(),
            stride: *stride,
            padding: *padding,
        },
        Layer::MaxPool2D { ph, pw } => StepKind::MaxPool2D { ph: *ph, pw: *pw },
        Layer::AvgPool2D { ph, pw } => StepKind::AvgPool2D { ph: *ph, pw: *pw },
        Layer::BatchNorm { gamma, beta, mean, variance, eps } => StepKind::BatchNorm {
            gamma: gamma.clone(),
            beta: beta.clone(),
            mean: mean.clone(),
            variance: variance.clone(),
            eps: *eps,
        },
        Layer::Flatten => StepKind::Flatten,
        Layer::Relu => StepKind::Act(Act::Relu),
        Layer::LeakyRelu { alpha } => StepKind::Act(Act::LeakyRelu { alpha: *alpha }),
        Layer::Tanh => StepKind::Act(Act::Tanh),
        Layer::Sigmoid => StepKind::Act(Act::Sigmoid),
        Layer::Softmax => StepKind::Softmax,
    }
}

/// Fold every `BatchNorm` that directly follows a `Dense`/`Conv2D`/
/// `DepthwiseConv2D` into that step's weights and bias:
/// `y = s (W x + b - mu) + beta` with `s = gamma / sqrt(var + eps)`
/// becomes `W' = s W` (per output channel), `b' = s (b - mu) + beta`.
/// The scale is computed in f64 at build time — this changes the rounding
/// profile and is why [`Fusion::Full`] is not CAA-sound.
fn fold_batch_norms(steps: &mut Vec<Step>) {
    let mut i = 1;
    while i < steps.len() {
        let foldable = matches!(steps[i].kind, StepKind::BatchNorm { .. })
            && matches!(
                steps[i - 1].kind,
                StepKind::Dense { .. } | StepKind::Conv2D { .. } | StepKind::DepthwiseConv2D { .. }
            );
        if !foldable {
            i += 1;
            continue;
        }
        let bn = steps.remove(i);
        let StepKind::BatchNorm { gamma, beta, mean, variance, eps } = bn.kind else {
            unreachable!("checked above");
        };
        let scale: Vec<f64> = gamma
            .iter()
            .zip(&variance)
            .map(|(&g, &v)| g / (v + eps).sqrt())
            .collect();
        let prev = &mut steps[i - 1];
        match &mut prev.kind {
            StepKind::Dense { w, b } => {
                let (m, n) = (w.shape()[0], w.shape()[1]);
                let wd = w.data_mut();
                for j in 0..m {
                    for col in 0..n {
                        wd[j * n + col] *= scale[j];
                    }
                    b[j] = scale[j] * (b[j] - mean[j]) + beta[j];
                }
            }
            StepKind::Conv2D { kernel, bias, .. } => {
                let cout = *kernel.shape().last().expect("conv kernel rank 4");
                for (idx, v) in kernel.data_mut().iter_mut().enumerate() {
                    *v *= scale[idx % cout];
                }
                for co in 0..cout {
                    bias[co] = scale[co] * (bias[co] - mean[co]) + beta[co];
                }
            }
            StepKind::DepthwiseConv2D { kernel, bias, .. } => {
                let c = *kernel.shape().last().expect("depthwise kernel rank 3");
                for (idx, v) in kernel.data_mut().iter_mut().enumerate() {
                    *v *= scale[idx % c];
                }
                for ch in 0..c {
                    bias[ch] = scale[ch] * (bias[ch] - mean[ch]) + beta[ch];
                }
            }
            _ => unreachable!("checked above"),
        }
        prev.out_shape = bn.out_shape;
        prev.layer_range.1 = bn.layer_range.1;
    }
}

/// Pair each standalone elementwise activation with the compute step
/// directly before it. The activation is applied in place on that step's
/// finished output buffer — identical operations in identical order, just
/// without the extra buffer pass, so this is sound at every fusion level
/// that enables it.
fn pair_activations(steps: &mut Vec<Step>) {
    let mut i = 1;
    while i < steps.len() {
        let pairable = matches!(steps[i].kind, StepKind::Act(_))
            && steps[i - 1].kind.accepts_fused_act()
            && steps[i - 1].fused_act.is_none();
        if !pairable {
            i += 1;
            continue;
        }
        let act_step = steps.remove(i);
        let StepKind::Act(a) = act_step.kind else {
            unreachable!("checked above");
        };
        let prev = &mut steps[i - 1];
        prev.fused_act = Some(a);
        prev.out_shape = act_step.out_shape;
        prev.layer_range.1 = act_step.layer_range.1;
    }
}

#[cfg(test)]
mod tests;
