//! **The compiled execution plan** — a model lowered, once per
//! `(model, input_shape)`, into a graph of shape-resolved [`Step`]s over a
//! small, liveness-allocated **buffer pool** that a generic executor runs
//! with a preallocated [`Arena`].
//!
//! This is the Rust analogue of the paper's compile-first design: the
//! original tool turns a Keras model into straight-line C++ (via
//! frugally-deep) precisely so the *same compiled evaluation* drives both
//! the FP inference and the error analysis. Here, [`Plan::build`]:
//!
//! 1. **Orders and validates the topology** — sequential chains and graph
//!    models ([`crate::model::Graph`]: residual skips, multi-branch
//!    merges) lower through one topological pass; cycles, dangling edges
//!    and merge-arity errors are rejected before any step exists.
//! 2. **Resolves all shapes ahead of time** — every geometry check the
//!    per-layer interpreter re-ran inside the inner loop happens once at
//!    build; the executor's steady state is check-free.
//! 3. **Fuses statically** per the requested [`Fusion`] level:
//!    * [`Fusion::Pair`] attaches an elementwise activation to its
//!      producing compute step when that producer's output has no other
//!      consumer (applied in place on the producer's output buffer — the
//!      same operations in the same order, so CAA bounds are bit-identical
//!      to the unfused walk; this level is safe for analysis). Across a
//!      merge point the skip-connection value keeps a second consumer, so
//!      pairing never destroys a value another branch still needs.
//!    * [`Fusion::Full`] additionally folds `BatchNormalization` into a
//!      sole-consumer preceding `Conv2D`/`Dense`/`DepthwiseConv2D` affine
//!      form. Folding *changes the rounding profile* (the per-channel
//!      scale is absorbed into the weights at build time in f64), so it is
//!      reserved for the f64 reference trace and throughput-oriented
//!      witness runs — never for CAA, whose rounding-error bookkeeping
//!      must match the analyzed computation exactly (the "unfused-for-CAA"
//!      contract).
//!    * [`Fusion::None`] keeps a 1:1 step-per-layer mapping — the mode the
//!      mixed-precision path uses so per-layer format boundaries stay
//!      addressable (steps in topological order).
//! 4. **Assigns buffers register-style**: each step names explicit input
//!    buffer ids and an output buffer id ([`BufId`]) from a pool sized by
//!    liveness — a buffer is recycled the moment its last reader has run.
//!    Sequential models therefore still compile to the classic
//!    **two-buffer ping-pong** (never more; a degenerate chain of only
//!    in-place steps needs just one); a residual block briefly holds a
//!    third buffer for the live skip value. In-place-capable steps (standalone activations,
//!    `Flatten`) alias their dying input buffer outright. Steady-state
//!    execution performs zero tensor allocations (`O(channels)`/
//!    `O(classes)` scalar temporaries remain for batch-norm parameter
//!    embedding and softmax rows).
//!
//! The executor ([`Plan::execute`]) is generic over
//! [`Scalar`](crate::tensor::Scalar), so the f64 baseline, the
//! interval/CAA analysis pass, and the emulated precision-k witness runs
//! all execute the same compiled steps — merge ops included, which is how
//! interval/CAA bound propagation reaches residual topologies without any
//! per-arithmetic code. [`crate::api::Session`] caches an `Arc<Plan>` next
//! to each model in its content-hash LRU; [`crate::coordinator`] hands
//! every worker thread its own arena.
//!
//! The pool also carries a **batch axis**: [`Plan::execute_batch`] runs
//! `B` samples through one pass over the steps with every buffer scaled to
//! `buffer_lens[i] * B` (sample-major layout), bit-identical per sample to
//! `B` independent executions — the substrate for bulk serving
//! ([`crate::serve`]) and the sampling baseline. Per-step precision maps
//! across merge points are the next item to hang off this IR (see
//! ROADMAP.md "Open items").
//!
//! Compute steps additionally carry a compile-time **kernel path**
//! ([`KernelPath`]): at [`KernelPath::Blocked`] (the default), `Dense`
//! steps get register-tile-packed weight panels, `Conv2D` steps get a
//! precomputed im2col patch-index table, `DepthwiseConv2D` steps get a
//! spatial tap table, and the executor drives them
//! through the blocked kernels in [`crate::layers::gemm`] for `f64` and
//! `EmulatedFp` executions — **bit-identical** to the scalar kernels
//! (tiling crosses only independent reduction chains, never the inside
//! of a dot product), so CAA/interval passes (which always run scalar)
//! and blocked reference/witness passes describe the very same
//! computation. See DESIGN.md "Kernel dispatch".

mod exec;
pub mod ir;

pub use exec::{Arena, TileScratch};
pub use ir::{diff, Edit, MemoryReport, PlanText, StepMemory};

use crate::layers::{gemm, Layer, Padding};
use crate::model::Model;
use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::borrow::Cow;
use std::sync::Arc;

/// Which kernel family the executor drives a plan's compute steps with.
///
/// `Blocked` routes dense, conv and depthwise steps through the
/// register-tiled kernels in [`crate::layers::gemm`] — *only* for
/// arithmetics that opt in via
/// [`Scalar::BLOCKED_ELIGIBLE`](crate::tensor::Scalar::BLOCKED_ELIGIBLE)
/// (`f64`, `EmulatedFp`); CAA/interval executions always take the scalar
/// kernels regardless of this setting. The blocked kernels are
/// bit-identical to the scalar ones (tiling crosses only independent
/// reduction chains), so the choice is pure throughput, never semantics.
///
/// Escape hatches for debugging: set the env var `RIGOR_FORCE_SCALAR=1`
/// to compile every plan at `Scalar` (no blocked step data is built), or
/// flip a single request with
/// [`AnalysisRequestBuilder::force_scalar_kernels`](crate::api::AnalysisRequestBuilder::force_scalar_kernels).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelPath {
    /// The textbook scalar loops in `layers/{dense,conv}.rs` — the path
    /// every `S: Scalar` supports, and the only one CAA/interval run.
    Scalar,
    /// Cache-blocked, autovectorization-friendly kernels
    /// (`layers/gemm.rs`) for eligible concrete scalars.
    Blocked,
}

impl KernelPath {
    /// The process-default path: [`KernelPath::Blocked`] unless the
    /// `RIGOR_FORCE_SCALAR` env var is set (to anything but `0` or
    /// empty) — the global kill switch for the blocked kernels.
    pub fn from_env() -> KernelPath {
        KernelPath::from_env_value(std::env::var_os("RIGOR_FORCE_SCALAR").as_deref())
    }

    /// Pure parser behind [`KernelPath::from_env`] (unit-testable without
    /// mutating process state).
    pub fn from_env_value(v: Option<&std::ffi::OsStr>) -> KernelPath {
        match v {
            Some(s) if !s.is_empty() && s != "0" => KernelPath::Scalar,
            _ => KernelPath::Blocked,
        }
    }
}

/// Element-count threshold below which a step stays serial under a
/// pooled execution: sharding a tiny step costs more in scheduling than
/// the arithmetic saves. `work = out_len * batch` is compared against
/// this.
pub const DEFAULT_MIN_WORK: usize = 2048;

/// How much of the machine a pooled plan drive may use — the policy
/// [`Plan::execute_batch_pooled`](crate::plan::Plan) takes and the
/// serve/fleet flushers thread through every flush.
///
/// `workers <= 1` means *serial*: the drive runs exactly the
/// single-threaded path (no scope, no scheduler), which is also the
/// escape hatch (`RIGOR_WORKERS=1`, or
/// [`Parallelism::serial`]). Parallel drives are **bit-identical** to
/// serial ones by construction — sharding crosses only independent
/// reduction chains — so this knob is pure throughput, never semantics
/// (the parallel analogue of [`KernelPath`]'s contract).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Parallelism {
    /// Maximum concurrent jobs one plan drive fans out (intra-op shards
    /// or inter-op branch steps). `<= 1` disables fan-out entirely.
    pub workers: usize,
    /// Steps with `out_len * batch` below this stay serial even when
    /// `workers > 1` (see [`DEFAULT_MIN_WORK`]).
    pub min_work: usize,
}

impl Parallelism {
    /// Fan out over up to `workers` concurrent jobs, with the default
    /// min-work threshold.
    pub fn with_workers(workers: usize) -> Parallelism {
        Parallelism { workers: workers.max(1), min_work: DEFAULT_MIN_WORK }
    }

    /// Strictly serial execution (the single-threaded path, no scheduler).
    pub fn serial() -> Parallelism {
        Parallelism { workers: 1, min_work: DEFAULT_MIN_WORK }
    }

    /// The process-default policy for a pool of `default_workers`
    /// threads: `RIGOR_WORKERS` (if set to a positive integer) overrides
    /// the worker count; `RIGOR_WORKERS=1` forces serial; unset/empty/`0`
    /// means "use `default_workers`".
    pub fn from_env(default_workers: usize) -> Parallelism {
        Parallelism::from_env_value(std::env::var_os("RIGOR_WORKERS").as_deref(), default_workers)
    }

    /// Pure parser behind [`Parallelism::from_env`] (unit-testable
    /// without mutating process state). Unparseable values fall back to
    /// `default_workers`.
    pub fn from_env_value(v: Option<&std::ffi::OsStr>, default_workers: usize) -> Parallelism {
        let workers = match v {
            Some(s) if !s.is_empty() && s != "0" => s
                .to_str()
                .and_then(|s| s.trim().parse::<usize>().ok())
                .filter(|&n| n > 0)
                .unwrap_or(default_workers),
            _ => default_workers,
        };
        Parallelism::with_workers(workers)
    }
}

/// Per-step data for the blocked kernel path, compiled by [`Plan::build`]
/// alongside the step (present only on `Dense` / `Conv2D` /
/// `DepthwiseConv2D` / `AvgPool2D` steps of plans compiled at
/// [`KernelPath::Blocked`]).
#[derive(Clone, Debug)]
pub(crate) enum BlockedStep {
    /// Row-tile-packed dense weights.
    Dense(gemm::DensePanel),
    /// Patch-index table lowering the conv to im2col-as-GEMM.
    Conv(gemm::Im2col),
    /// Spatial tap table for the channel-lane depthwise kernel.
    Depthwise(gemm::DwTable),
    /// Spatial tap table for the channel-lane average-pool kernel.
    AvgPool(gemm::PoolTable),
}

/// The arithmetic a serving queue executes its batches under — the
/// precision tag a fleet ticket carries ([`crate::fleet`]). Each format
/// maps to its own separately-compiled plan via [`Plan::for_format`]:
/// `F64` takes the fully-fused reference plan (throughput), `Emulated`
/// the unfused plan (the witness convention of
/// [`crate::quant::emulated_forward`], so served results are
/// bit-identical to the offline emulated runs they stand in for).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ServeFormat {
    /// Plain binary64 — the reference arithmetic.
    F64,
    /// Emulated precision-k arithmetic (`k` mantissa bits, 2..=53).
    Emulated {
        /// Mantissa width every operation result is rounded to.
        k: u32,
    },
}

impl ServeFormat {
    /// Validate the format (`Emulated` requires `k` in `2..=53`).
    pub fn validate(&self) -> Result<()> {
        if let ServeFormat::Emulated { k } = self {
            anyhow::ensure!((2..=53).contains(k), "emulated precision k={k} outside 2..=53");
        }
        Ok(())
    }
}

impl std::fmt::Display for ServeFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeFormat::F64 => write!(f, "f64"),
            ServeFormat::Emulated { k } => write!(f, "emu-k{k}"),
        }
    }
}

impl std::str::FromStr for ServeFormat {
    type Err = anyhow::Error;

    /// Parse the [`Display`](std::fmt::Display) form back: `f64` or
    /// `emu-k<k>` (e.g. `emu-k12`) — the tags golden snapshot names and
    /// the CLI `plan --format` flag use.
    fn from_str(s: &str) -> Result<ServeFormat> {
        let fmt = match s {
            "f64" => ServeFormat::F64,
            _ => {
                let k = s
                    .strip_prefix("emu-k")
                    .and_then(|k| k.parse::<u32>().ok())
                    .ok_or_else(|| anyhow::anyhow!("unknown serve format '{s}' (f64 | emu-k<k>)"))?;
                ServeFormat::Emulated { k }
            }
        };
        fmt.validate()?;
        Ok(fmt)
    }
}

/// Index of a buffer in the plan's pool (and in the executing
/// [`Arena`]'s buffer vector).
pub type BufId = usize;

/// Fusion level a plan is compiled at. See the module docs for the
/// soundness contract of each level.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fusion {
    /// One step per layer, no pairing — exact unfused semantics; required
    /// by the mixed-precision path (per-layer format boundaries address
    /// steps 1:1, in topological order).
    None,
    /// Pair elementwise activations with their sole-consumed producing
    /// compute step. Arithmetic is unchanged (CAA-safe).
    Pair,
    /// [`Fusion::Pair`] plus batch-norm folding into the preceding affine
    /// step. f64/witness executions only — **not** CAA-sound.
    Full,
}

/// An elementwise activation a compute step can apply in place on its
/// output buffer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Act {
    /// `max(x, 0)`.
    Relu,
    /// `max(x, alpha * x)`.
    LeakyRelu {
        /// Negative-side slope.
        alpha: f64,
    },
    /// Hyperbolic tangent.
    Tanh,
    /// Logistic sigmoid.
    Sigmoid,
}

/// A weight tensor carried by a compiled step — the plan memory diet's
/// unit of accounting. Freshly lowered steps *share* the model layer's
/// tensor (an `Arc` refcount bump, no copy); a fusion pass that must
/// rewrite the weights (batch-norm folding) first detaches a private
/// copy via `make_mut` (copy-on-write) and marks the
/// weights `folded`, so provenance stays explicit and the model's own
/// parameters are never mutated.
#[derive(Clone, Debug)]
pub struct StepWeights {
    tensor: Arc<Tensor<f64>>,
    folded: bool,
}

impl StepWeights {
    /// Wrap a layer's weight tensor, sharing storage with it.
    pub fn shared(tensor: Arc<Tensor<f64>>) -> StepWeights {
        StepWeights { tensor, folded: false }
    }

    /// Whether fusion rewrote these weights (they are a plan-private
    /// copy, no longer the layer's storage).
    pub fn folded(&self) -> bool {
        self.folded
    }

    /// The weight tensor.
    pub fn tensor(&self) -> &Tensor<f64> {
        &self.tensor
    }

    /// Whether these weights still share storage with `layer_tensor`.
    pub fn shares(&self, layer_tensor: &Arc<Tensor<f64>>) -> bool {
        Arc::ptr_eq(&self.tensor, layer_tensor)
    }

    /// Mutable access for a fusion rewrite: detaches a private copy if
    /// the storage is shared (copy-on-write) and marks the weights
    /// folded.
    fn make_mut(&mut self) -> &mut Tensor<f64> {
        self.folded = true;
        Arc::make_mut(&mut self.tensor)
    }

    /// Resident parameter bytes ([`Plan::memory_report`] accounting);
    /// charged to the plan only when [`StepWeights::folded`].
    pub fn param_bytes(&self) -> usize {
        self.tensor.len() * std::mem::size_of::<f64>()
    }
}

impl std::ops::Deref for StepWeights {
    type Target = Tensor<f64>;

    fn deref(&self) -> &Tensor<f64> {
        &self.tensor
    }
}

/// A dense step's weight storage. Blocked plans drop the row-major
/// tensor entirely when folding already forced a private copy — the
/// packed [`gemm::DensePanel`] holds the exact same `f64` values (packing
/// only permutes them), and the scalar-path escape hatch derives its
/// view on demand via [`gemm::DensePanel::unpack`]. Shared (unfolded)
/// weights keep the tensor: it costs nothing (the layer owns it anyway).
#[derive(Clone, Debug)]
pub enum DenseWeights {
    /// Row-major `[m, n]` weights, shared with the layer or a folded
    /// private copy (see [`StepWeights`]).
    Tensor(StepWeights),
    /// The weights live only in this step's packed panel (the blocked
    /// step data at the same index). Only folded weights of blocked
    /// plans take this form.
    PanelOnly {
        /// Output units (weight rows).
        m: usize,
        /// Input features (weight columns).
        n: usize,
    },
}

impl DenseWeights {
    /// The tensor-backed weights, unless the diet dropped them to
    /// panel-only form.
    pub fn as_tensor(&self) -> Option<&StepWeights> {
        match self {
            DenseWeights::Tensor(sw) => Some(sw),
            DenseWeights::PanelOnly { .. } => None,
        }
    }

    /// Weight matrix dimensions `(m, n)` (`[units, in]`).
    pub fn dims(&self) -> (usize, usize) {
        match self {
            DenseWeights::Tensor(sw) => (sw.shape()[0], sw.shape()[1]),
            DenseWeights::PanelOnly { m, n } => (*m, *n),
        }
    }
}

/// What a step computes. Parameters are owned or `Arc`-shared with the
/// model's layers (folded private copies only where fusion rewrote them
/// — see [`StepWeights`]), so a plan is self-contained and shareable via
/// `Arc`.
#[derive(Clone, Debug)]
pub enum StepKind {
    /// `y = W x + b`, `w: [units, in]`.
    Dense {
        /// Weight matrix `[units, in]` (possibly panel-only, see
        /// [`DenseWeights`]).
        w: DenseWeights,
        /// Bias vector `[units]`.
        b: Vec<f64>,
    },
    /// 2-D convolution, kernel `[kh, kw, cin, cout]`.
    Conv2D {
        /// Convolution kernel `[kh, kw, cin, cout]` (Keras layout).
        kernel: StepWeights,
        /// Per-output-channel bias.
        bias: Vec<f64>,
        /// Spatial stride (same both axes).
        stride: usize,
        /// Padding mode.
        padding: Padding,
    },
    /// Depthwise 2-D convolution, kernel `[kh, kw, c]`.
    DepthwiseConv2D {
        /// Depthwise kernel `[kh, kw, c]`.
        kernel: StepWeights,
        /// Per-channel bias.
        bias: Vec<f64>,
        /// Spatial stride (same both axes).
        stride: usize,
        /// Padding mode.
        padding: Padding,
    },
    /// Max pooling over `[ph, pw]` windows.
    MaxPool2D {
        /// Pool height.
        ph: usize,
        /// Pool width.
        pw: usize,
    },
    /// Average pooling over `[ph, pw]` windows.
    AvgPool2D {
        /// Pool height.
        ph: usize,
        /// Pool width.
        pw: usize,
    },
    /// Inference-mode batch normalization (kept materialized at
    /// [`Fusion::None`]/[`Fusion::Pair`]; folded away at [`Fusion::Full`]).
    BatchNorm {
        /// Per-channel scale.
        gamma: Vec<f64>,
        /// Per-channel shift.
        beta: Vec<f64>,
        /// Per-channel running mean.
        mean: Vec<f64>,
        /// Per-channel running variance.
        variance: Vec<f64>,
        /// Variance stabilizer.
        eps: f64,
    },
    /// Shape-only: aliased to its input buffer when that buffer dies here
    /// (the common case — then a no-op); otherwise a plain copy.
    Flatten,
    /// A standalone elementwise activation (not paired; in place on its
    /// input buffer when that buffer dies here).
    Act(Act),
    /// Numerically-stable softmax over the last axis.
    Softmax,
    /// Elementwise sum of all input buffers (2+), accumulated left to
    /// right in declared inbound order — the residual merge.
    Add,
    /// Concatenation of all input buffers (2+) along the last axis.
    /// `rows` and per-input `widths` are resolved at build time so the
    /// executor's gather is geometry-check-free and allocation-free.
    Concat {
        /// Product of the leading (non-concatenated) axes.
        rows: usize,
        /// Last-axis width of each input, in input order.
        widths: Vec<usize>,
    },
}

impl StepKind {
    /// Whether this step produces a fresh output buffer (as opposed to
    /// being in-place-capable / shape-only).
    fn writes_output(&self) -> bool {
        !matches!(self, StepKind::Flatten | StepKind::Act(_))
    }

    /// Whether an activation may be paired onto this step's output.
    fn accepts_fused_act(&self) -> bool {
        self.writes_output() && !matches!(self, StepKind::Softmax)
    }

    /// Whether the buffer allocator may alias this step's output onto its
    /// (dying) input buffer.
    fn in_place_capable(&self) -> bool {
        matches!(self, StepKind::Flatten | StepKind::Act(_))
    }

    /// Short tag for diagnostics and plan dumps.
    pub fn name(&self) -> &'static str {
        match self {
            StepKind::Dense { .. } => "dense",
            StepKind::Conv2D { .. } => "conv2d",
            StepKind::DepthwiseConv2D { .. } => "depthwise_conv2d",
            StepKind::MaxPool2D { .. } => "max_pool2d",
            StepKind::AvgPool2D { .. } => "avg_pool2d",
            StepKind::BatchNorm { .. } => "batch_norm",
            StepKind::Flatten => "flatten",
            StepKind::Act(Act::Relu) => "relu",
            StepKind::Act(Act::LeakyRelu { .. }) => "leaky_relu",
            StepKind::Act(Act::Tanh) => "tanh",
            StepKind::Act(Act::Sigmoid) => "sigmoid",
            StepKind::Softmax => "softmax",
            StepKind::Add => "add",
            StepKind::Concat { .. } => "concat",
        }
    }
}

/// One compiled step: kind + statically resolved geometry + explicit
/// buffer wiring + provenance.
#[derive(Clone, Debug)]
pub struct Step {
    /// The operation.
    pub kind: StepKind,
    /// Pool buffers this step reads, in input order (merge steps have 2+;
    /// everything else exactly 1).
    pub inputs: Vec<BufId>,
    /// Pool buffer this step writes. Equal to `inputs[0]` only for
    /// in-place-aliased `Act`/`Flatten` steps.
    pub out: BufId,
    /// Input shapes (index-aligned with [`Step::inputs`]), validated at
    /// build time.
    pub in_shapes: Vec<Vec<usize>>,
    /// Output shape (after the fused activation, which preserves shape).
    pub out_shape: Vec<usize>,
    /// Elementwise activation applied in place on this step's output
    /// buffer, if fusion paired one.
    pub fused_act: Option<Act>,
    /// Covered model-layer index range `[lo, hi)` — provenance for
    /// diagnostics and per-layer precision maps. Exact and contiguous for
    /// sequential models; for graph models an enclosing range (fusion can
    /// join non-adjacent listing indices).
    pub layer_range: (usize, usize),
}

impl Step {
    /// The primary (first) input shape — the only one for non-merge steps.
    pub fn in_shape(&self) -> &[usize] {
        &self.in_shapes[0]
    }

    /// Element count of the primary input.
    pub fn in_len(&self) -> usize {
        self.in_shapes[0].iter().product()
    }

    /// Element count of the output.
    pub fn out_len(&self) -> usize {
        self.out_shape.iter().product()
    }
}

/// A compiled, shape-resolved, optionally fused execution plan for one
/// model. Build once, execute many times (generic over
/// [`crate::tensor::Scalar`]).
#[derive(Clone, Debug)]
pub struct Plan {
    model_name: String,
    input_shape: Vec<usize>,
    output_shape: Vec<usize>,
    steps: Vec<Step>,
    fusion: Fusion,
    /// Required element capacity of each pool buffer (the max any value
    /// placed in that slot reaches).
    buf_lens: Vec<usize>,
    input_buf: BufId,
    output_buf: BufId,
    /// Kernel family this plan was compiled for (the default the
    /// executor uses; callers can force [`KernelPath::Scalar`] per
    /// execution).
    kernel_path: KernelPath,
    /// Index-aligned with `steps`: blocked-kernel data for the steps that
    /// have a blocked lowering (`Dense`, `Conv2D`, `DepthwiseConv2D`),
    /// when compiled at [`KernelPath::Blocked`].
    blocked: Vec<Option<BlockedStep>>,
    /// Index-aligned with `steps`: predecessor step indices (deduped) this
    /// step must wait for under concurrent execution — RAW, WAW and WAR
    /// hazards over the *recycled* pool buffers, computed once at build.
    /// Steps whose lists are disjoint prefixes of the ready set can run
    /// concurrently (independent residual branches).
    deps: Vec<Vec<usize>>,
}

/// A step during compilation, wired by **value id** (0 = model input,
/// `l + 1` = layer `l`'s output) rather than buffer id; buffer assignment
/// happens after fusion.
struct DraftStep {
    kind: StepKind,
    inputs: Vec<usize>,
    out_val: usize,
    in_shapes: Vec<Vec<usize>>,
    out_shape: Vec<usize>,
    fused_act: Option<Act>,
    layer_lo: usize,
    layer_hi: usize,
}

impl Plan {
    /// Compile `model` at the given fusion level. All topology validation
    /// and shape inference happens here; a returned plan executes
    /// check-free. Works for sequential chains and graph models alike.
    ///
    /// ```
    /// use rigor::model::zoo;
    /// use rigor::plan::{Fusion, Plan};
    ///
    /// // A sequential model ping-pongs exactly two pool buffers ...
    /// let seq = Plan::build(&zoo::tiny_mlp(1), Fusion::Pair)?;
    /// assert_eq!(seq.buffer_count(), 2);
    /// // ... while a residual model holds a third for the live skip value.
    /// let res = Plan::build(&zoo::residual_mlp(1), Fusion::Pair)?;
    /// assert_eq!(res.buffer_count(), 3);
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn build(model: &Model, fusion: Fusion) -> Result<Plan> {
        Plan::build_with_kernels(model, fusion, KernelPath::from_env())
    }

    /// [`Plan::build`] with an explicit kernel family, bypassing the
    /// `RIGOR_FORCE_SCALAR` env check — the constructor tests, benches
    /// and tools use to pin a path deterministically. A plan compiled at
    /// [`KernelPath::Scalar`] carries no blocked step data at all, so a
    /// blocked execution request on it silently (and soundly) runs
    /// scalar.
    pub fn build_with_kernels(model: &Model, fusion: Fusion, kernels: KernelPath) -> Result<Plan> {
        let topo = model.toposort().with_context(|| format!("plan: model '{}'", model.name))?;
        let val_shape = model.value_shapes(&topo).context("plan")?;
        let n_vals = model.layers.len() + 1;

        // Lower layers into value-wired draft steps, in topological order.
        let mut drafts: Vec<DraftStep> = Vec::with_capacity(model.layers.len());
        for &l in &topo.order {
            let in_vals = topo.inputs[l].clone();
            let in_shapes: Vec<Vec<usize>> =
                in_vals.iter().map(|&v| val_shape[v].clone()).collect();
            let out_shape = val_shape[l + 1].clone();
            drafts.push(DraftStep {
                kind: lower_layer(&model.layers[l], &in_shapes, &out_shape),
                inputs: in_vals,
                out_val: l + 1,
                in_shapes,
                out_shape,
                fused_act: None,
                layer_lo: l,
                layer_hi: l + 1,
            });
        }

        // Per-value read counts; the output value gets one phantom read so
        // its buffer is never recycled and fusion never erases it.
        let mut uses = vec![0usize; n_vals];
        for d in &drafts {
            for &v in &d.inputs {
                uses[v] += 1;
            }
        }
        uses[topo.output_val] += 1;

        if fusion == Fusion::Full {
            fold_batch_norms(&mut drafts, &mut uses);
        }
        if fusion != Fusion::None {
            pair_activations(&mut drafts, &mut uses);
        }

        // Register-style buffer assignment over the liveness intervals.
        let mut remaining = uses;
        let mut buf_of_val: Vec<Option<BufId>> = vec![None; n_vals];
        let mut buf_lens: Vec<usize> = Vec::new();
        let mut free: Vec<BufId> = Vec::new();

        let input_buf: BufId = 0;
        buf_lens.push(model.input_shape.iter().product());
        buf_of_val[0] = Some(input_buf);

        let mut steps = Vec::with_capacity(drafts.len());
        for d in drafts {
            let in_bufs: Vec<BufId> = d
                .inputs
                .iter()
                .map(|&v| buf_of_val[v].expect("topological order: producer already placed"))
                .collect();
            let out_len: usize = d.out_shape.iter().product();
            // Alias in place when the sole input dies at this very step
            // (then `Act` mutates, `Flatten` becomes a no-op).
            let in_place =
                d.kind.in_place_capable() && d.inputs.len() == 1 && remaining[d.inputs[0]] == 1;
            let out_buf = if in_place {
                in_bufs[0]
            } else if let Some(b) = free.pop() {
                b
            } else {
                buf_lens.push(0);
                buf_lens.len() - 1
            };
            buf_lens[out_buf] = buf_lens[out_buf].max(out_len);
            buf_of_val[d.out_val] = Some(out_buf);
            // Release dead inputs only *after* the output got its buffer,
            // so a compute step can never write the buffer it reads.
            for (&v, &b) in d.inputs.iter().zip(&in_bufs) {
                remaining[v] -= 1;
                if remaining[v] == 0 && b != out_buf {
                    free.push(b);
                }
            }
            steps.push(Step {
                kind: d.kind,
                inputs: in_bufs,
                out: out_buf,
                in_shapes: d.in_shapes,
                out_shape: d.out_shape,
                fused_act: d.fused_act,
                layer_range: (d.layer_lo, d.layer_hi),
            });
        }

        let output_buf =
            buf_of_val[topo.output_val].expect("output value placed (empty model: the input)");

        // Blocked-path lowering: pack dense panels and resolve conv
        // patch-index tables once, at compile time. Shapes are already
        // validated above, so the gather tables are geometry-check-free.
        let blocked: Vec<Option<BlockedStep>> = match kernels {
            KernelPath::Scalar => vec![None; steps.len()],
            KernelPath::Blocked => steps
                .iter()
                .map(|s| match &s.kind {
                    StepKind::Dense { w, .. } => Some(BlockedStep::Dense(gemm::DensePanel::pack(
                        w.as_tensor().expect("dense weights tensor-backed before lowering"),
                    ))),
                    StepKind::Conv2D { kernel, stride, padding, .. } => {
                        Some(BlockedStep::Conv(gemm::Im2col::build(
                            kernel.shape(),
                            *stride,
                            *padding,
                            s.in_shape(),
                            &s.out_shape,
                        )))
                    }
                    StepKind::DepthwiseConv2D { kernel, stride, padding, .. } => {
                        Some(BlockedStep::Depthwise(gemm::DwTable::build(
                            kernel.shape(),
                            *stride,
                            *padding,
                            s.in_shape(),
                            &s.out_shape,
                        )))
                    }
                    StepKind::AvgPool2D { ph, pw } => Some(BlockedStep::AvgPool(
                        gemm::PoolTable::build(*ph, *pw, s.in_shape(), &s.out_shape),
                    )),
                    _ => None,
                })
                .collect(),
        };

        // Memory diet, dense steps: a fold-rewritten weight tensor lives
        // nowhere else (the layer kept its original parameters), and the
        // panel just built holds the exact same `f64` values — drop the
        // redundant row-major copy and let the scalar-path escape hatch
        // derive its view on demand ([`Plan::scalar_dense_w`]).
        if kernels == KernelPath::Blocked {
            for s in &mut steps {
                if let StepKind::Dense { w, .. } = &mut s.kind {
                    if w.as_tensor().is_some_and(StepWeights::folded) {
                        let (m, n) = w.dims();
                        *w = DenseWeights::PanelOnly { m, n };
                    }
                }
            }
        }

        let deps = compute_deps(&steps, buf_lens.len(), input_buf);

        Ok(Plan {
            model_name: model.name.clone(),
            input_shape: model.input_shape.clone(),
            output_shape: val_shape[topo.output_val].clone(),
            steps,
            fusion,
            buf_lens,
            input_buf,
            output_buf,
            kernel_path: kernels,
            blocked,
            deps,
        })
    }

    /// The analysis plan: activation pairing only — arithmetic identical
    /// to the unfused walk, so CAA bounds are unchanged.
    pub fn for_analysis(model: &Model) -> Result<Plan> {
        Plan::build(model, Fusion::Pair)
    }

    /// The reference/witness plan: batch norms folded into the preceding
    /// affine steps (f64 trace and throughput witness runs only).
    pub fn for_reference(model: &Model) -> Result<Plan> {
        Plan::build(model, Fusion::Full)
    }

    /// A 1:1 step-per-layer plan (exact unfused semantics; the
    /// mixed-precision path's addressing mode).
    pub fn unfused(model: &Model) -> Result<Plan> {
        Plan::build(model, Fusion::None)
    }

    /// The serving plan for one [`ServeFormat`]: [`Plan::for_reference`]
    /// for `F64` traffic, [`Plan::unfused`] for `Emulated` traffic (the
    /// witness convention — served emulated results stay bit-identical to
    /// [`crate::quant::emulated_forward`] on the same model).
    pub fn for_format(model: &Model, format: ServeFormat) -> Result<Plan> {
        format.validate()?;
        match format {
            ServeFormat::F64 => Plan::for_reference(model),
            ServeFormat::Emulated { .. } => Plan::unfused(model),
        }
    }

    /// [`Plan::for_format`] with an explicit kernel family (bypassing the
    /// `RIGOR_FORCE_SCALAR` env check) — what the golden snapshot suite
    /// and the CLI `plan` command use to pin both axes deterministically.
    pub fn for_format_with_kernels(
        model: &Model,
        format: ServeFormat,
        kernels: KernelPath,
    ) -> Result<Plan> {
        format.validate()?;
        let fusion = match format {
            ServeFormat::F64 => Fusion::Full,
            ServeFormat::Emulated { .. } => Fusion::None,
        };
        Plan::build_with_kernels(model, fusion, kernels)
    }

    /// Name of the compiled model.
    pub fn model_name(&self) -> &str {
        &self.model_name
    }

    /// The fusion level this plan was compiled at.
    pub fn fusion(&self) -> Fusion {
        self.fusion
    }

    /// The kernel family this plan was compiled for — the default its
    /// executions dispatch with ([`KernelPath::Blocked`] unless
    /// `RIGOR_FORCE_SCALAR` was set at build, or the plan was built via
    /// [`Plan::build_with_kernels`] at `Scalar`).
    pub fn kernel_path(&self) -> KernelPath {
        self.kernel_path
    }

    /// Blocked-kernel data for step `idx` under the (already
    /// arithmetic-resolved) `path`, if the step has a blocked lowering.
    pub(crate) fn blocked_step(&self, idx: usize, path: KernelPath) -> Option<&BlockedStep> {
        match path {
            KernelPath::Blocked => self.blocked[idx].as_ref(),
            KernelPath::Scalar => None,
        }
    }

    /// The compiled steps, in execution (topological) order.
    pub fn steps(&self) -> &[Step] {
        &self.steps
    }

    /// Predecessor step indices (deduped, ascending) each step must wait
    /// for before it may run concurrently with others: every read-after-
    /// write, write-after-write and write-after-read hazard over the
    /// recycled pool buffers. Steps with no path between them here are
    /// independent — the inter-op scheduler runs them as concurrent jobs.
    /// Serial execution (steps in index order) trivially satisfies every
    /// edge, which is why the serial path never consults this.
    pub fn step_deps(&self) -> &[Vec<usize>] {
        &self.deps
    }

    /// The model input shape.
    pub fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    /// The model output shape.
    pub fn output_shape(&self) -> &[usize] {
        &self.output_shape
    }

    /// Element count of the input.
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Element count of the output.
    pub fn output_len(&self) -> usize {
        self.output_shape.iter().product()
    }

    /// Number of pool buffers the plan executes over: at most 2 for any
    /// sequential model (exactly 2 once the chain has a buffer-producing
    /// step; a degenerate all-in-place chain of activations/`Flatten`
    /// stays at 1), +1 per concurrently-live skip/branch value.
    pub fn buffer_count(&self) -> usize {
        self.buf_lens.len()
    }

    /// Required element capacity of each pool buffer (arena sizing).
    pub fn buffer_lens(&self) -> &[usize] {
        &self.buf_lens
    }

    /// Largest element count any pool buffer reaches.
    pub fn max_buffer_len(&self) -> usize {
        self.buf_lens.iter().copied().max().unwrap_or(0)
    }

    /// The pool buffer the executor seeds with the model input.
    pub fn input_buf(&self) -> BufId {
        self.input_buf
    }

    /// The pool buffer holding the model output after execution.
    pub fn output_buf(&self) -> BufId {
        self.output_buf
    }

    /// The row-major weight view for a scalar-path dense execution of
    /// step `idx`: borrowed straight from the step when tensor-backed,
    /// reconstructed exactly from the packed panel
    /// ([`gemm::DensePanel::unpack`]) when the diet dropped the tensor.
    /// The unpack allocates — acceptable for what is a debugging escape
    /// hatch on blocked plans (CAA/interval analysis plans are built at
    /// fusion levels that never produce panel-only weights).
    pub(crate) fn scalar_dense_w<'a>(
        &'a self,
        idx: usize,
        w: &'a DenseWeights,
    ) -> Cow<'a, Tensor<f64>> {
        match w {
            DenseWeights::Tensor(sw) => Cow::Borrowed(sw.tensor()),
            DenseWeights::PanelOnly { .. } => {
                let Some(BlockedStep::Dense(pd)) = self.blocked[idx].as_ref() else {
                    unreachable!("panel-only dense weights imply a packed panel at the same index")
                };
                Cow::Owned(pd.unpack())
            }
        }
    }
}

/// Compute per-step predecessor lists over the recycled buffer pool: step
/// `i` depends on step `j < i` iff `i` reads a buffer `j` last wrote
/// (RAW), overwrites a buffer `j` wrote (WAW — buffer recycling aliases
/// unrelated values onto one buffer), or overwrites a buffer `j` still
/// reads (WAR). The executor's input load acts as the write of
/// `input_buf`, so a step that recycles the input buffer correctly waits
/// for every reader of the model input.
fn compute_deps(steps: &[Step], n_bufs: usize, _input_buf: BufId) -> Vec<Vec<usize>> {
    // Per buffer: the last step that wrote it (None = executor input
    // load / never written) and the steps that read it since that write.
    let mut last_writer: Vec<Option<usize>> = vec![None; n_bufs];
    let mut readers: Vec<Vec<usize>> = vec![Vec::new(); n_bufs];
    let mut deps: Vec<Vec<usize>> = Vec::with_capacity(steps.len());
    for (i, s) in steps.iter().enumerate() {
        let mut pred: Vec<usize> = Vec::new();
        for &b in &s.inputs {
            if let Some(w) = last_writer[b] {
                pred.push(w); // RAW
            }
        }
        if let Some(w) = last_writer[s.out] {
            pred.push(w); // WAW
        }
        pred.extend(readers[s.out].iter().copied()); // WAR
        pred.sort_unstable();
        pred.dedup();
        pred.retain(|&p| p != i);
        // Update bookkeeping: reads first, then the write.
        for &b in &s.inputs {
            if b != s.out {
                readers[b].push(i);
            }
        }
        last_writer[s.out] = Some(i);
        readers[s.out].clear();
        deps.push(pred);
    }
    deps
}

/// Lower one layer into its (unfused) step kind. Weight tensors are
/// `Arc`-shared with the layer (refcount bump, no copy — the memory
/// diet); small parameter vectors (biases, batch-norm statistics) are
/// cloned so the step stays self-describing. Geometry needed by merge
/// gathers is resolved here.
fn lower_layer(layer: &Layer, in_shapes: &[Vec<usize>], out_shape: &[usize]) -> StepKind {
    match layer {
        Layer::Dense { w, b } => StepKind::Dense {
            w: DenseWeights::Tensor(StepWeights::shared(w.clone())),
            b: b.clone(),
        },
        Layer::Conv2D { kernel, bias, stride, padding } => StepKind::Conv2D {
            kernel: StepWeights::shared(kernel.clone()),
            bias: bias.clone(),
            stride: *stride,
            padding: *padding,
        },
        Layer::DepthwiseConv2D { kernel, bias, stride, padding } => StepKind::DepthwiseConv2D {
            kernel: StepWeights::shared(kernel.clone()),
            bias: bias.clone(),
            stride: *stride,
            padding: *padding,
        },
        Layer::MaxPool2D { ph, pw } => StepKind::MaxPool2D { ph: *ph, pw: *pw },
        Layer::AvgPool2D { ph, pw } => StepKind::AvgPool2D { ph: *ph, pw: *pw },
        Layer::BatchNorm { gamma, beta, mean, variance, eps } => StepKind::BatchNorm {
            gamma: gamma.clone(),
            beta: beta.clone(),
            mean: mean.clone(),
            variance: variance.clone(),
            eps: *eps,
        },
        Layer::Flatten => StepKind::Flatten,
        Layer::Relu => StepKind::Act(Act::Relu),
        Layer::LeakyRelu { alpha } => StepKind::Act(Act::LeakyRelu { alpha: *alpha }),
        Layer::Tanh => StepKind::Act(Act::Tanh),
        Layer::Sigmoid => StepKind::Act(Act::Sigmoid),
        Layer::Softmax => StepKind::Softmax,
        Layer::Add => StepKind::Add,
        Layer::Concat => {
            // Shapes were validated by `Layer::output_shape_multi`; resolve
            // the row-major gather geometry once, at build time.
            let rank = out_shape.len();
            let rows: usize = out_shape[..rank - 1].iter().product();
            let widths: Vec<usize> =
                in_shapes.iter().map(|s| *s.last().expect("concat rank >= 1")).collect();
            StepKind::Concat { rows, widths }
        }
    }
}

/// Index of the draft producing value `v`, if any (the model input has no
/// producer). Producers always precede consumers in the topologically
/// ordered draft list.
fn producer_of(drafts: &[DraftStep], v: usize) -> Option<usize> {
    drafts.iter().position(|d| d.out_val == v)
}

/// Fold every `BatchNorm` whose sole-consumed input comes from a
/// `Dense`/`Conv2D`/`DepthwiseConv2D` into that producer's weights and
/// bias: `y = s (W x + b - mu) + beta` with `s = gamma / sqrt(var + eps)`
/// becomes `W' = s W` (per output channel), `b' = s (b - mu) + beta`.
/// The scale is computed in f64 at build time — this changes the rounding
/// profile and is why [`Fusion::Full`] is not CAA-sound.
fn fold_batch_norms(drafts: &mut Vec<DraftStep>, uses: &mut [usize]) {
    let mut i = 0;
    while i < drafts.len() {
        let fold_target = if matches!(drafts[i].kind, StepKind::BatchNorm { .. }) {
            let v = drafts[i].inputs[0];
            // `uses[v] == 1` also excludes the model output value (its
            // phantom read keeps it at >= 2 when a BN reads it).
            producer_of(drafts, v).filter(|&p| {
                uses[v] == 1
                    && drafts[p].fused_act.is_none()
                    && matches!(
                        drafts[p].kind,
                        StepKind::Dense { .. }
                            | StepKind::Conv2D { .. }
                            | StepKind::DepthwiseConv2D { .. }
                    )
            })
        } else {
            None
        };
        let Some(p) = fold_target else {
            i += 1;
            continue;
        };
        debug_assert!(p < i, "producer precedes consumer in topo order");
        let bn = drafts.remove(i);
        let folded_val = bn.inputs[0];
        let StepKind::BatchNorm { gamma, beta, mean, variance, eps } = bn.kind else {
            unreachable!("checked above");
        };
        let scale: Vec<f64> =
            gamma.iter().zip(&variance).map(|(&g, &v)| g / (v + eps).sqrt()).collect();
        let prev = &mut drafts[p];
        // `make_mut` detaches the step's weights from the layer's shared
        // storage (copy-on-write) before the rewrite — fold-on-write is
        // the only place a plan ever copies a weight tensor.
        match &mut prev.kind {
            StepKind::Dense { w, b } => {
                let DenseWeights::Tensor(sw) = w else {
                    unreachable!("panel-only form appears after fusion, at blocked lowering")
                };
                let wt = sw.make_mut();
                let (m, n) = (wt.shape()[0], wt.shape()[1]);
                let wd = wt.data_mut();
                for j in 0..m {
                    for col in 0..n {
                        wd[j * n + col] *= scale[j];
                    }
                    b[j] = scale[j] * (b[j] - mean[j]) + beta[j];
                }
            }
            StepKind::Conv2D { kernel, bias, .. } => {
                let kt = kernel.make_mut();
                let cout = *kt.shape().last().expect("conv kernel rank 4");
                for (idx, v) in kt.data_mut().iter_mut().enumerate() {
                    *v *= scale[idx % cout];
                }
                for co in 0..cout {
                    bias[co] = scale[co] * (bias[co] - mean[co]) + beta[co];
                }
            }
            StepKind::DepthwiseConv2D { kernel, bias, .. } => {
                let kt = kernel.make_mut();
                let c = *kt.shape().last().expect("depthwise kernel rank 3");
                for (idx, v) in kt.data_mut().iter_mut().enumerate() {
                    *v *= scale[idx % c];
                }
                for ch in 0..c {
                    bias[ch] = scale[ch] * (bias[ch] - mean[ch]) + beta[ch];
                }
            }
            _ => unreachable!("checked above"),
        }
        // The producer now emits the BN's value; the intermediate value
        // disappears.
        prev.out_val = bn.out_val;
        prev.out_shape = bn.out_shape;
        prev.layer_lo = prev.layer_lo.min(bn.layer_lo);
        prev.layer_hi = prev.layer_hi.max(bn.layer_hi);
        uses[folded_val] = 0;
    }
}

/// Pair each standalone elementwise activation with the compute step that
/// produces its (sole-consumed) input. The activation is applied in place
/// on that step's finished output buffer — identical operations in
/// identical order, just without the extra buffer pass, so this is sound
/// at every fusion level that enables it. Skip-connection values with a
/// second consumer are never paired away.
fn pair_activations(drafts: &mut Vec<DraftStep>, uses: &mut [usize]) {
    let mut i = 0;
    while i < drafts.len() {
        let pair_target = if matches!(drafts[i].kind, StepKind::Act(_)) {
            let v = drafts[i].inputs[0];
            producer_of(drafts, v).filter(|&p| {
                uses[v] == 1
                    && drafts[p].fused_act.is_none()
                    && drafts[p].kind.accepts_fused_act()
            })
        } else {
            None
        };
        let Some(p) = pair_target else {
            i += 1;
            continue;
        };
        debug_assert!(p < i, "producer precedes consumer in topo order");
        let act_step = drafts.remove(i);
        let paired_val = act_step.inputs[0];
        let StepKind::Act(a) = act_step.kind else {
            unreachable!("checked above");
        };
        let prev = &mut drafts[p];
        prev.fused_act = Some(a);
        prev.out_val = act_step.out_val;
        prev.out_shape = act_step.out_shape;
        prev.layer_lo = prev.layer_lo.min(act_step.layer_lo);
        prev.layer_hi = prev.layer_hi.max(act_step.layer_hi);
        uses[paired_val] = 0;
    }
}

#[cfg(test)]
mod tests;
