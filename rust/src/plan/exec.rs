//! The plan **executor**: runs a compiled [`Plan`] over any
//! [`Scalar`] arithmetic with a caller-owned double-buffer [`Arena`].
//!
//! The executor ping-pongs between `cur` and `next`: compute steps read
//! `cur`, write `next`, then the buffers swap; shape-only steps
//! (`Flatten`) and standalone activations operate in place on `cur`.
//! All buffers keep their capacity between calls, so repeated execution of
//! the same plan (the per-class analysis loop, witness sweeps, serving
//! traffic) performs zero tensor allocations after the first run.

use super::{Act, Plan, StepKind};
use crate::layers::{activation, conv, dense, norm, pool};
use crate::tensor::{Scalar, Tensor};
use anyhow::{bail, Result};

/// Reusable executor scratch: two ping-pong layer buffers plus a row
/// scratch (softmax). One arena per worker thread — obtain a per-thread
/// one with [`crate::coordinator::with_worker_scratch`].
#[derive(Clone, Debug)]
pub struct Arena<S> {
    pub(crate) cur: Vec<S>,
    pub(crate) next: Vec<S>,
    pub(crate) scratch: Vec<S>,
}

impl<S> Arena<S> {
    pub fn new() -> Arena<S> {
        Arena { cur: Vec::new(), next: Vec::new(), scratch: Vec::new() }
    }

    /// Pre-size the buffers for `plan` so even the first execution does
    /// not reallocate mid-run.
    pub fn reserve_for(&mut self, plan: &Plan) {
        let n = plan.max_buffer_len();
        if self.cur.capacity() < n {
            self.cur.reserve(n - self.cur.len());
        }
        if self.next.capacity() < n {
            self.next.reserve(n - self.next.len());
        }
    }

    /// The buffer currently holding the latest step output.
    pub fn current(&self) -> &[S] {
        &self.cur
    }

    /// Mutable view of the current buffer — for drivers that transform
    /// values between steps (mixed-precision rescaling, per-layer storage
    /// rounding).
    pub fn current_mut(&mut self) -> &mut [S] {
        &mut self.cur
    }

    /// Seed the arena with an input vector (used by callers that drive
    /// steps one at a time, e.g. the mixed-precision analysis).
    pub fn load(&mut self, input: &[S])
    where
        S: Clone,
    {
        self.cur.clear();
        self.cur.extend_from_slice(input);
    }
}

impl<S> Default for Arena<S> {
    fn default() -> Arena<S> {
        Arena::new()
    }
}

impl Plan {
    /// Execute the whole plan on `input`, returning a borrow of the arena
    /// buffer holding the output (length [`Plan::output_len`]). The only
    /// runtime check is the input length — every shape was resolved at
    /// build time.
    pub fn execute<'a, S: Scalar>(
        &self,
        ctx: &S::Ctx,
        input: &[S],
        arena: &'a mut Arena<S>,
    ) -> Result<&'a [S]> {
        if input.len() != self.input_len() {
            bail!(
                "plan '{}' expects input {:?} ({} values), got {}",
                self.model_name(),
                self.input_shape(),
                self.input_len(),
                input.len()
            );
        }
        arena.reserve_for(self);
        arena.load(input);
        for idx in 0..self.steps().len() {
            self.execute_step(idx, ctx, arena);
        }
        Ok(&arena.cur)
    }

    /// Execute one step against the arena (input in `arena.current()`,
    /// result left in `arena.current()`). Exposed for drivers that
    /// interleave per-step work — the mixed-precision analysis rescales
    /// bounds and switches contexts between steps.
    pub fn execute_step<S: Scalar>(&self, idx: usize, ctx: &S::Ctx, arena: &mut Arena<S>) {
        let step = &self.steps()[idx];
        debug_assert_eq!(arena.cur.len(), step.in_len(), "step {idx} input length");
        match &step.kind {
            StepKind::Flatten => {}
            StepKind::Act(a) => apply_act_inplace(ctx, a, &mut arena.cur),
            kind => {
                arena.next.clear();
                match kind {
                    StepKind::Dense { w, b } => {
                        dense::apply_into(ctx, w, b, &arena.cur, &mut arena.next)
                    }
                    StepKind::Conv2D { kernel, bias, stride, padding } => conv::conv2d_into(
                        ctx,
                        kernel,
                        bias,
                        *stride,
                        *padding,
                        &arena.cur,
                        &step.in_shape,
                        &step.out_shape,
                        &mut arena.next,
                    ),
                    StepKind::DepthwiseConv2D { kernel, bias, stride, padding } => {
                        conv::depthwise_into(
                            ctx,
                            kernel,
                            bias,
                            *stride,
                            *padding,
                            &arena.cur,
                            &step.in_shape,
                            &step.out_shape,
                            &mut arena.next,
                        )
                    }
                    StepKind::MaxPool2D { ph, pw } => pool::max_pool_into(
                        ctx,
                        *ph,
                        *pw,
                        &arena.cur,
                        &step.in_shape,
                        &step.out_shape,
                        &mut arena.next,
                    ),
                    StepKind::AvgPool2D { ph, pw } => pool::avg_pool_into(
                        ctx,
                        *ph,
                        *pw,
                        &arena.cur,
                        &step.in_shape,
                        &step.out_shape,
                        &mut arena.next,
                    ),
                    StepKind::BatchNorm { gamma, beta, mean, variance, eps } => {
                        let c = *step.in_shape.last().expect("batch_norm rank >= 1");
                        norm::batch_norm_into(
                            ctx,
                            gamma,
                            beta,
                            mean,
                            variance,
                            *eps,
                            &arena.cur,
                            c,
                            &mut arena.next,
                        )
                    }
                    StepKind::Softmax => {
                        let n = *step.in_shape.last().expect("softmax rank >= 1");
                        activation::softmax_into(
                            ctx,
                            n,
                            &arena.cur,
                            &mut arena.scratch,
                            &mut arena.next,
                        )
                    }
                    StepKind::Flatten | StepKind::Act(_) => unreachable!("handled above"),
                }
                if let Some(a) = &step.fused_act {
                    apply_act_inplace(ctx, a, &mut arena.next);
                }
                std::mem::swap(&mut arena.cur, &mut arena.next);
            }
        }
        debug_assert_eq!(arena.cur.len(), step.out_len(), "step {idx} output length");
    }

    /// Convenience tensor-in/tensor-out execution with a throwaway arena —
    /// the compatibility path behind [`crate::model::Model::forward`].
    /// Hot paths should hold an [`Arena`] and call [`Plan::execute`].
    pub fn forward<S: Scalar>(&self, ctx: &S::Ctx, input: Tensor<S>) -> Result<Tensor<S>> {
        if input.shape() != self.input_shape() {
            bail!(
                "model '{}' expects input {:?}, got {:?}",
                self.model_name(),
                self.input_shape(),
                input.shape()
            );
        }
        let mut arena = Arena::new();
        let out = self.execute(ctx, input.data(), &mut arena)?.to_vec();
        Ok(Tensor::new(self.output_shape().to_vec(), out))
    }
}

/// Apply an elementwise activation in place, mirroring the interpreter's
/// per-element operation order exactly (bit-identical CAA bounds).
fn apply_act_inplace<S: Scalar>(ctx: &S::Ctx, act: &Act, buf: &mut [S]) {
    match act {
        Act::Relu => {
            for v in buf.iter_mut() {
                let y = v.relu(ctx);
                *v = y;
            }
        }
        Act::LeakyRelu { alpha } => {
            // Same form as layers::activation::leaky_relu:
            // leaky(x) = max(x, alpha * x) with alpha embedded once.
            let a = S::param(ctx, *alpha);
            for v in buf.iter_mut() {
                let scaled = v.mul(&a, ctx);
                let y = v.max(&scaled, ctx);
                *v = y;
            }
        }
        Act::Tanh => {
            for v in buf.iter_mut() {
                let y = v.tanh(ctx);
                *v = y;
            }
        }
        Act::Sigmoid => {
            for v in buf.iter_mut() {
                let y = v.sigmoid(ctx);
                *v = y;
            }
        }
    }
}
