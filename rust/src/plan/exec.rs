//! The plan **executor**: runs a compiled [`Plan`] over any
//! [`Scalar`] arithmetic with a caller-owned buffer-pool [`Arena`].
//!
//! Every step names its input buffer ids and its output buffer id
//! ([`super::BufId`]), assigned at compile time by liveness — the executor
//! just dispatches kernels over the pool, with no topology logic of its
//! own. For sequential models the pool degenerates to the classic
//! two-buffer ping-pong; residual/branchy models address however many
//! buffers their widest live set needs. All buffers keep their capacity
//! between calls, so repeated execution of the same plan (the per-class
//! analysis loop, witness sweeps, serving traffic) performs zero tensor
//! allocations after the first run.
//!
//! In-place-aliased steps (`out == inputs[0]`: standalone activations and
//! `Flatten` whose input dies at the step) mutate or no-op their buffer
//! directly; every other step temporarily takes its output `Vec` out of
//! the pool (a pointer swap), writes it while reading the input buffers,
//! and puts it back.
//!
//! ## Kernel dispatch
//!
//! Dense, conv and depthwise steps dispatch through the plan's compiled
//! [`KernelPath`]: for arithmetics with
//! [`BLOCKED_ELIGIBLE`](crate::tensor::Scalar::BLOCKED_ELIGIBLE) (`f64`
//! reference, `EmulatedFp` witness) the blocked kernels in
//! [`crate::layers::gemm`] run — register-tiled over packed panels held
//! in this arena, **bit-identical** to the scalar kernels (same terms,
//! same per-chain order; see the gemm module docs for the contract) —
//! while CAA/interval executions always take the scalar loops. The
//! `*_path` method variants force a path per execution (the debugging
//! escape hatch); pooling, normalization, activations and merges are
//! scalar on every path (no reduction to tile).
//!
//! ## The batch axis
//!
//! [`Plan::execute_batch`] runs `B` samples through **one pass over the
//! steps**: every pool buffer grows a leading batch dimension
//! (`buffer_lens[i] * B` elements, sample-major — sample `s`'s value in
//! buffer `i` occupies `[s * len, (s + 1) * len)`), and each step
//! dispatches **once** for the whole batch. Elementwise and row-structured
//! kernels (activations, batch norm, softmax, `Add`) are batch-transparent
//! — the flat sample-major layout is just a longer slice of independent
//! elements/rows; dense/conv/pool get explicit `*_batch_into` entry points
//! that loop the samples inside the single dispatch. Buffer assignment,
//! liveness and aliasing are untouched: the batch dimension scales every
//! buffer uniformly, so the register-style allocation stays valid.
//!
//! Per-sample results are **bit-identical** to `B` independent
//! [`Plan::execute`] calls (and `B = 1` *is* the single-sample kernel
//! path): samples are mathematically independent, so batched kernels may
//! interleave work across samples but never reorder the operations
//! *within* one sample. The win is for the cheap scalars — f64 reference
//! traces and emulated-k witness runs amortize step dispatch, buffer swaps
//! and parameter embedding, and the batched dense kernel overlaps the
//! samples' (independent) accumulation chains instead of serializing on
//! one latency-bound dot product. CAA analysis stays at `B = 1` in the
//! service paths: each CAA op costs orders of magnitude more than the
//! dispatch being amortized, and a `B`-wide arena of [`crate::caa::Caa`]
//! values multiplies peak memory for no measurable speedup (see
//! `benches/perf_scaling.rs`), though the batched path is arithmetically
//! valid — and tested — for every scalar.

use super::{Act, BlockedStep, BufId, KernelPath, Parallelism, Plan, StepKind};
use crate::coordinator::{with_worker_scratch, Pool};
use crate::layers::{activation, conv, dense, gemm, merge, norm, pool};
use crate::obs;
use crate::tensor::{Scalar, Tensor};
use anyhow::{bail, Result};

/// Per-worker scratch for the **pooled** execution paths
/// ([`Plan::execute_batch_pooled`]): the blocked kernels' panel/accumulator
/// scratch plus the softmax row scratch, owned per thread via
/// [`crate::coordinator::with_worker_scratch`]. Deliberately a distinct
/// type from [`Arena`] so a sharded job running *on the thread that holds
/// the arena checked out* (the caller-helps scope rule) gets its own
/// scratch instead of colliding with the arena checkout.
#[derive(Clone, Debug)]
pub struct TileScratch<S> {
    /// Packed sample/patch panels (doubles as the depthwise/pool
    /// accumulator strip, mirroring `Arena::pack`).
    pub pack: Vec<S>,
    /// Conv pad mask (mirrors `Arena::pack_mask`).
    pub mask: Vec<bool>,
    /// Softmax row scratch (mirrors `Arena::scratch`).
    pub scratch: Vec<S>,
}

impl<S> Default for TileScratch<S> {
    fn default() -> TileScratch<S> {
        TileScratch { pack: Vec::new(), mask: Vec::new(), scratch: Vec::new() }
    }
}

/// Reusable executor scratch: the plan's buffer pool plus a row scratch
/// (softmax) and the blocked kernels' panel scratch (packed sample/patch
/// panels and the conv pad mask). One arena per worker thread — obtain a
/// per-thread one with [`crate::coordinator::with_worker_scratch`]. An
/// arena is plan-agnostic: it grows to the largest pool any executed plan
/// needs and is reused across plans and requests.
///
/// Reservation is **monotonic high-water**: once a pool buffer has been
/// sized for a batch of `B`, later smaller batches never re-reserve (or
/// shrink) it, so steady-state execution with fluctuating batch sizes
/// performs zero arena allocations (asserted by an allocation-counter
/// test in `rust/tests/kernels.rs`).
#[derive(Clone, Debug)]
pub struct Arena<S> {
    pub(crate) bufs: Vec<Vec<S>>,
    pub(crate) scratch: Vec<S>,
    pub(crate) pack: Vec<S>,
    pub(crate) pack_mask: Vec<bool>,
    /// High-water element reservation per pool buffer (what
    /// [`Arena::reserve_for_batch`] has ever been asked for).
    reserved: Vec<usize>,
}

impl<S> Arena<S> {
    /// A fresh, empty arena (buffers materialize on first use).
    pub fn new() -> Arena<S> {
        Arena {
            bufs: Vec::new(),
            scratch: Vec::new(),
            pack: Vec::new(),
            pack_mask: Vec::new(),
            reserved: Vec::new(),
        }
    }

    /// Pre-size the pool for `plan` so even the first execution does not
    /// reallocate mid-run.
    pub fn reserve_for(&mut self, plan: &Plan) {
        self.reserve_for_batch(plan, 1);
    }

    /// Read a pool buffer (drivers that interleave per-step work — the
    /// mixed-precision analysis — inspect step outputs through this).
    pub fn buffer(&self, id: BufId) -> &[S] {
        &self.bufs[id]
    }

    /// Mutable view of a pool buffer — for drivers that transform values
    /// between steps (mixed-precision rescaling, per-layer storage
    /// rounding).
    pub fn buffer_mut(&mut self, id: BufId) -> &mut [S] {
        &mut self.bufs[id]
    }

    /// Seed the plan's input buffer with a sample (sizing the pool first).
    /// Length is the caller's responsibility; [`Plan::execute`] checks it.
    pub fn load_input(&mut self, plan: &Plan, input: &[S])
    where
        S: Clone,
    {
        self.reserve_for(plan);
        let buf = &mut self.bufs[plan.input_buf()];
        buf.clear();
        buf.extend_from_slice(input);
    }

    /// Pre-size the pool for `plan` executed with a leading batch
    /// dimension: every buffer reserves `buffer_lens[i] * batch` elements
    /// (the sample-major batched layout), so even the first batched
    /// execution does not reallocate mid-run. The reservation is a
    /// monotonic high-water mark: a shrinking `batch` (micro-batch
    /// flushes are rarely full) leaves earlier, larger reservations
    /// untouched instead of re-deriving capacity per flush — steady-state
    /// serving therefore never allocates, whatever the batch-size
    /// sequence.
    pub fn reserve_for_batch(&mut self, plan: &Plan, batch: usize) {
        while self.bufs.len() < plan.buffer_count() {
            self.bufs.push(Vec::new());
        }
        if self.reserved.len() < self.bufs.len() {
            self.reserved.resize(self.bufs.len(), 0);
        }
        for (i, &n) in plan.buffer_lens().iter().enumerate() {
            let want = n * batch;
            if want > self.reserved[i] {
                self.reserved[i] = want;
            }
            let hw = self.reserved[i];
            let buf = &mut self.bufs[i];
            if buf.capacity() < hw {
                buf.reserve(hw - buf.len());
            }
        }
    }

    /// The high-water element reservation of pool buffer `id` (test /
    /// diagnostics hook for the monotonic-reservation contract).
    pub fn reserved_len(&self, id: BufId) -> usize {
        self.reserved.get(id).copied().unwrap_or(0)
    }

    /// Seed the plan's input buffer with `batch` samples laid out
    /// sample-major (`input.len() == batch * plan.input_len()`; sample `s`
    /// occupies `[s * input_len, (s + 1) * input_len)`), sizing the pool
    /// for the batch first. Length is the caller's responsibility;
    /// [`Plan::execute_batch`] checks it.
    pub fn load_batch(&mut self, plan: &Plan, input: &[S], batch: usize)
    where
        S: Clone,
    {
        self.reserve_for_batch(plan, batch);
        let buf = &mut self.bufs[plan.input_buf()];
        buf.clear();
        buf.extend_from_slice(input);
    }
}

impl<S> Default for Arena<S> {
    fn default() -> Arena<S> {
        Arena::new()
    }
}

impl Plan {
    /// Execute the whole plan on `input`, returning a borrow of the pool
    /// buffer holding the output (length [`Plan::output_len`]). The only
    /// runtime check is the input length — every shape and every buffer
    /// assignment was resolved at build time. Dispatches kernels per the
    /// plan's compiled [`KernelPath`]; use [`Plan::execute_path`] to
    /// force a path per execution.
    pub fn execute<'a, S: Scalar>(
        &self,
        ctx: &S::Ctx,
        input: &[S],
        arena: &'a mut Arena<S>,
    ) -> Result<&'a [S]> {
        self.execute_path(ctx, input, arena, self.kernel_path())
    }

    /// [`Plan::execute`] with an explicit kernel path — the per-execution
    /// escape hatch ([`KernelPath::Scalar`] forces the textbook loops for
    /// debugging; results are bit-identical either way). A `Blocked`
    /// request degrades to scalar when the plan carries no blocked data
    /// or the arithmetic is not
    /// [`BLOCKED_ELIGIBLE`](crate::tensor::Scalar::BLOCKED_ELIGIBLE).
    pub fn execute_path<'a, S: Scalar>(
        &self,
        ctx: &S::Ctx,
        input: &[S],
        arena: &'a mut Arena<S>,
        path: KernelPath,
    ) -> Result<&'a [S]> {
        if input.len() != self.input_len() {
            bail!(
                "plan '{}' expects input {:?} ({} values), got {}",
                self.model_name(),
                self.input_shape(),
                self.input_len(),
                input.len()
            );
        }
        arena.load_input(self, input);
        // Instrumentation lives in this drive loop (not inside
        // `execute_step_path`) so an uninstrumented baseline remains
        // reachable through the public step API — that is what the
        // `perf_scaling` disabled-overhead floor compares against.
        let span_path = if S::BLOCKED_ELIGIBLE { path } else { KernelPath::Scalar };
        for idx in 0..self.steps().len() {
            let t0 = obs::mark();
            self.execute_step_path(idx, ctx, arena, path);
            obs::step_done(t0, self.steps()[idx].kind.name(), span_path, 1, 0, 1);
        }
        Ok(&arena.bufs[self.output_buf()])
    }

    /// Execute one step against the arena pool (inputs read from the
    /// step's input buffers, result left in its output buffer). Exposed
    /// for drivers that interleave per-step work — the mixed-precision
    /// analysis rescales bounds and switches contexts between steps.
    /// Dispatches per the plan's compiled [`KernelPath`].
    pub fn execute_step<S: Scalar>(&self, idx: usize, ctx: &S::Ctx, arena: &mut Arena<S>) {
        self.execute_step_path(idx, ctx, arena, self.kernel_path());
    }

    /// [`Plan::execute_step`] with an explicit kernel path (see
    /// [`Plan::execute_path`] for the degradation rules).
    pub fn execute_step_path<S: Scalar>(
        &self,
        idx: usize,
        ctx: &S::Ctx,
        arena: &mut Arena<S>,
        path: KernelPath,
    ) {
        // Resolve the path for this arithmetic once: CAA/interval (and
        // any scalar that did not opt in) always run the scalar kernels.
        let path = if S::BLOCKED_ELIGIBLE { path } else { KernelPath::Scalar };
        let step = &self.steps()[idx];
        debug_assert_eq!(arena.bufs[step.inputs[0]].len(), step.in_len(), "step {idx} input");

        // In-place alias: the input buffer dies here and becomes the
        // output. `Flatten` is then a pure no-op (row-major data is
        // already the flattened vector); `Act` mutates elementwise.
        if step.out == step.inputs[0] {
            debug_assert!(step.fused_act.is_none(), "in-place steps never carry a fused act");
            match &step.kind {
                StepKind::Flatten => {}
                StepKind::Act(a) => apply_act_inplace(ctx, a, &mut arena.bufs[step.out]),
                other => unreachable!("{} steps are never in-place-aliased", other.name()),
            }
            return;
        }

        // Take the output vec out of the pool (pointer swap) so kernels
        // can write it while reading other pool buffers. The allocator
        // guarantees `step.out` differs from every live input buffer.
        let mut out = std::mem::take(&mut arena.bufs[step.out]);
        out.clear();
        match &step.kind {
            StepKind::Dense { w, b } => match self.blocked_step(idx, path) {
                Some(BlockedStep::Dense(pd)) => gemm::dense_blocked(
                    ctx,
                    pd,
                    b,
                    &arena.bufs[step.inputs[0]],
                    1,
                    &mut arena.pack,
                    &mut out,
                ),
                _ => {
                    let wt = self.scalar_dense_w(idx, w);
                    dense::apply_into(ctx, &wt, b, &arena.bufs[step.inputs[0]], &mut out)
                }
            },
            StepKind::Conv2D { kernel, bias, stride, padding } => {
                match self.blocked_step(idx, path) {
                    Some(BlockedStep::Conv(ic)) => gemm::conv_blocked(
                        ctx,
                        ic,
                        kernel.data(),
                        bias,
                        &arena.bufs[step.inputs[0]],
                        1,
                        &mut arena.pack,
                        &mut arena.pack_mask,
                        &mut out,
                    ),
                    _ => conv::conv2d_into(
                        ctx,
                        kernel,
                        bias,
                        *stride,
                        *padding,
                        &arena.bufs[step.inputs[0]],
                        step.in_shape(),
                        &step.out_shape,
                        &mut out,
                    ),
                }
            }
            StepKind::DepthwiseConv2D { kernel, bias, stride, padding } => {
                match self.blocked_step(idx, path) {
                    Some(BlockedStep::Depthwise(dw)) => gemm::depthwise_blocked(
                        ctx,
                        dw,
                        kernel.data(),
                        bias,
                        &arena.bufs[step.inputs[0]],
                        1,
                        &mut arena.pack,
                        &mut out,
                    ),
                    _ => conv::depthwise_into(
                        ctx,
                        kernel,
                        bias,
                        *stride,
                        *padding,
                        &arena.bufs[step.inputs[0]],
                        step.in_shape(),
                        &step.out_shape,
                        &mut out,
                    ),
                }
            }
            StepKind::MaxPool2D { ph, pw } => pool::max_pool_into(
                ctx,
                *ph,
                *pw,
                &arena.bufs[step.inputs[0]],
                step.in_shape(),
                &step.out_shape,
                &mut out,
            ),
            StepKind::AvgPool2D { ph, pw } => match self.blocked_step(idx, path) {
                Some(BlockedStep::AvgPool(pt)) => gemm::avg_pool_blocked(
                    ctx,
                    pt,
                    &arena.bufs[step.inputs[0]],
                    1,
                    &mut arena.pack,
                    &mut out,
                ),
                _ => pool::avg_pool_into(
                    ctx,
                    *ph,
                    *pw,
                    &arena.bufs[step.inputs[0]],
                    step.in_shape(),
                    &step.out_shape,
                    &mut out,
                ),
            },
            StepKind::BatchNorm { gamma, beta, mean, variance, eps } => {
                let c = *step.in_shape().last().expect("batch_norm rank >= 1");
                norm::batch_norm_into(
                    ctx,
                    gamma,
                    beta,
                    mean,
                    variance,
                    *eps,
                    &arena.bufs[step.inputs[0]],
                    c,
                    &mut out,
                )
            }
            StepKind::Softmax => {
                let n = *step.in_shape().last().expect("softmax rank >= 1");
                activation::softmax_into(
                    ctx,
                    n,
                    &arena.bufs[step.inputs[0]],
                    &mut arena.scratch,
                    &mut out,
                )
            }
            // Out-of-place shape/copy fallbacks for the rare case the
            // aliasing precondition fails (the value has other readers).
            StepKind::Flatten => out.extend_from_slice(&arena.bufs[step.inputs[0]]),
            StepKind::Act(a) => {
                out.extend_from_slice(&arena.bufs[step.inputs[0]]);
                apply_act_inplace(ctx, a, &mut out);
            }
            StepKind::Add => {
                out.extend_from_slice(&arena.bufs[step.inputs[0]]);
                for &b in &step.inputs[1..] {
                    merge::add_assign_into(ctx, &mut out, &arena.bufs[b]);
                }
            }
            StepKind::Concat { rows, widths } => {
                for r in 0..*rows {
                    for (i, &w) in widths.iter().enumerate() {
                        merge::concat_row_into(r, w, &arena.bufs[step.inputs[i]], &mut out);
                    }
                }
            }
        }
        if let Some(a) = &step.fused_act {
            apply_act_inplace(ctx, a, &mut out);
        }
        arena.bufs[step.out] = out;
        debug_assert_eq!(arena.bufs[step.out].len(), step.out_len(), "step {idx} output");
    }

    /// Execute the whole plan over a **batch** of samples in one pass.
    /// `input` holds `batch` samples sample-major
    /// (`input.len() == batch * input_len`); the returned borrow of the
    /// output pool buffer holds `batch * output_len` values, sample `s`'s
    /// output at `[s * output_len, (s + 1) * output_len)`.
    ///
    /// Per-sample results are **bit-identical** to `batch` independent
    /// [`Plan::execute`] calls for every scalar arithmetic: the batched
    /// kernels perform the same operations in the same per-sample order,
    /// only interleaved across (independent) samples — and at
    /// `batch == 1` they degenerate to exactly the single-sample kernels.
    ///
    /// ```
    /// use rigor::model::zoo;
    /// use rigor::plan::{Arena, Plan};
    ///
    /// let plan = Plan::for_reference(&zoo::tiny_mlp(3))?;
    /// let a: Vec<f64> = (0..8).map(|i| i as f64 / 8.0).collect();
    /// let b: Vec<f64> = (0..8).map(|i| (7 - i) as f64 / 8.0).collect();
    ///
    /// let mut arena = Arena::new();
    /// let single = plan.execute::<f64>(&(), &a, &mut arena)?.to_vec();
    ///
    /// let flat: Vec<f64> = a.iter().chain(&b).copied().collect();
    /// let mut batch_arena = Arena::new();
    /// let both = plan.execute_batch::<f64>(&(), &flat, 2, &mut batch_arena)?;
    /// assert_eq!(&both[..plan.output_len()], single.as_slice());
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn execute_batch<'a, S: Scalar>(
        &self,
        ctx: &S::Ctx,
        input: &[S],
        batch: usize,
        arena: &'a mut Arena<S>,
    ) -> Result<&'a [S]> {
        self.execute_batch_path(ctx, input, batch, arena, self.kernel_path())
    }

    /// [`Plan::execute_batch`] with an explicit kernel path (see
    /// [`Plan::execute_path`] for the degradation rules; per-element
    /// results are bit-identical across paths).
    pub fn execute_batch_path<'a, S: Scalar>(
        &self,
        ctx: &S::Ctx,
        input: &[S],
        batch: usize,
        arena: &'a mut Arena<S>,
        path: KernelPath,
    ) -> Result<&'a [S]> {
        if batch == 0 {
            bail!("plan '{}': batch must be >= 1", self.model_name());
        }
        if input.len() != batch * self.input_len() {
            bail!(
                "plan '{}' expects {batch} x {:?} ({} values sample-major), got {}",
                self.model_name(),
                self.input_shape(),
                batch * self.input_len(),
                input.len()
            );
        }
        let t_drive = obs::mark();
        arena.load_batch(self, input, batch);
        // Per-step instrumentation lives in this drive loop, not inside
        // `execute_step_batch_path` (see `execute_path`).
        let span_path = if S::BLOCKED_ELIGIBLE { path } else { KernelPath::Scalar };
        for idx in 0..self.steps().len() {
            let t0 = obs::mark();
            self.execute_step_batch_path(idx, batch, ctx, arena, path);
            obs::step_done(t0, self.steps()[idx].kind.name(), span_path, batch, 0, 1);
        }
        obs::drive_done(t_drive, "serial", batch, self.steps().len());
        Ok(&arena.bufs[self.output_buf()])
    }

    /// Execute one step for a `batch` of samples against the arena pool
    /// (buffers hold `batch * len` values, sample-major). Elementwise and
    /// row-structured kernels (activations, batch norm, softmax, `Add`)
    /// are batch-transparent — one call covers every sample, which also
    /// amortizes per-call parameter embedding (batch norm's per-channel
    /// affine form is built once per batch instead of once per sample,
    /// with identical values); dense/conv/pool dispatch once and loop the
    /// samples inside the kernel. Per-sample operation order matches
    /// [`Plan::execute_step`] exactly.
    pub fn execute_step_batch<S: Scalar>(
        &self,
        idx: usize,
        batch: usize,
        ctx: &S::Ctx,
        arena: &mut Arena<S>,
    ) {
        self.execute_step_batch_path(idx, batch, ctx, arena, self.kernel_path());
    }

    /// [`Plan::execute_step_batch`] with an explicit kernel path (see
    /// [`Plan::execute_path`] for the degradation rules).
    pub fn execute_step_batch_path<S: Scalar>(
        &self,
        idx: usize,
        batch: usize,
        ctx: &S::Ctx,
        arena: &mut Arena<S>,
        path: KernelPath,
    ) {
        let path = if S::BLOCKED_ELIGIBLE { path } else { KernelPath::Scalar };
        let step = &self.steps()[idx];
        debug_assert_eq!(
            arena.bufs[step.inputs[0]].len(),
            batch * step.in_len(),
            "step {idx} batched input"
        );

        // In-place alias (see `execute_step`): `Flatten` stays a no-op and
        // `Act` mutates elementwise — both are batch-transparent.
        if step.out == step.inputs[0] {
            debug_assert!(step.fused_act.is_none(), "in-place steps never carry a fused act");
            match &step.kind {
                StepKind::Flatten => {}
                StepKind::Act(a) => apply_act_inplace(ctx, a, &mut arena.bufs[step.out]),
                other => unreachable!("{} steps are never in-place-aliased", other.name()),
            }
            return;
        }

        let mut out = std::mem::take(&mut arena.bufs[step.out]);
        out.clear();
        let Arena { bufs, scratch, pack, pack_mask, .. } = arena;
        self.run_step_kernel(idx, batch, ctx, bufs, pack, pack_mask, scratch, &mut out, path);
        arena.bufs[step.out] = out;
        debug_assert_eq!(
            arena.bufs[step.out].len(),
            batch * step.out_len(),
            "step {idx} batched output"
        );
    }

    /// The batched kernel dispatch for one (non-in-place) step, decoupled
    /// from the [`Arena`]: inputs are read from the shared pool slice
    /// `bufs` (the step's output `Vec` has already been taken out, so its
    /// pool slot is empty and never read), the result lands in `out`, and
    /// the panel/mask/row scratch comes from the caller — `Arena` fields
    /// on the serial path, a per-worker [`TileScratch`] on the pooled
    /// one. The fused activation is applied here too. `path` must already
    /// be resolved for the arithmetic's eligibility.
    #[allow(clippy::too_many_arguments)]
    fn run_step_kernel<S: Scalar>(
        &self,
        idx: usize,
        batch: usize,
        ctx: &S::Ctx,
        bufs: &[Vec<S>],
        pack: &mut Vec<S>,
        pack_mask: &mut Vec<bool>,
        scratch: &mut Vec<S>,
        out: &mut Vec<S>,
        path: KernelPath,
    ) {
        let step = &self.steps()[idx];
        debug_assert_ne!(step.out, step.inputs[0], "in-place steps bypass the kernel dispatch");
        match &step.kind {
            StepKind::Dense { w, b } => match self.blocked_step(idx, path) {
                Some(BlockedStep::Dense(pd)) => {
                    gemm::dense_blocked(ctx, pd, b, &bufs[step.inputs[0]], batch, pack, out)
                }
                _ => {
                    let wt = self.scalar_dense_w(idx, w);
                    dense::apply_batch_into(ctx, &wt, b, &bufs[step.inputs[0]], batch, out)
                }
            },
            StepKind::Conv2D { kernel, bias, stride, padding } => {
                match self.blocked_step(idx, path) {
                    Some(BlockedStep::Conv(ic)) => gemm::conv_blocked(
                        ctx,
                        ic,
                        kernel.data(),
                        bias,
                        &bufs[step.inputs[0]],
                        batch,
                        pack,
                        pack_mask,
                        out,
                    ),
                    _ => conv::conv2d_batch_into(
                        ctx,
                        kernel,
                        bias,
                        *stride,
                        *padding,
                        &bufs[step.inputs[0]],
                        step.in_shape(),
                        &step.out_shape,
                        batch,
                        out,
                    ),
                }
            }
            StepKind::DepthwiseConv2D { kernel, bias, stride, padding } => {
                match self.blocked_step(idx, path) {
                    Some(BlockedStep::Depthwise(dw)) => gemm::depthwise_blocked(
                        ctx,
                        dw,
                        kernel.data(),
                        bias,
                        &bufs[step.inputs[0]],
                        batch,
                        pack,
                        out,
                    ),
                    _ => conv::depthwise_batch_into(
                        ctx,
                        kernel,
                        bias,
                        *stride,
                        *padding,
                        &bufs[step.inputs[0]],
                        step.in_shape(),
                        &step.out_shape,
                        batch,
                        out,
                    ),
                }
            }
            StepKind::MaxPool2D { ph, pw } => pool::max_pool_batch_into(
                ctx,
                *ph,
                *pw,
                &bufs[step.inputs[0]],
                step.in_shape(),
                &step.out_shape,
                batch,
                out,
            ),
            StepKind::AvgPool2D { ph, pw } => match self.blocked_step(idx, path) {
                Some(BlockedStep::AvgPool(pt)) => {
                    gemm::avg_pool_blocked(ctx, pt, &bufs[step.inputs[0]], batch, pack, out)
                }
                _ => pool::avg_pool_batch_into(
                    ctx,
                    *ph,
                    *pw,
                    &bufs[step.inputs[0]],
                    step.in_shape(),
                    &step.out_shape,
                    batch,
                    out,
                ),
            },
            StepKind::BatchNorm { gamma, beta, mean, variance, eps } => {
                // Batch-transparent: the flat layout is a longer
                // channels-last slice, and `i % c` picks the same channel
                // for every sample's element.
                let c = *step.in_shape().last().expect("batch_norm rank >= 1");
                norm::batch_norm_into(
                    ctx,
                    gamma,
                    beta,
                    mean,
                    variance,
                    *eps,
                    &bufs[step.inputs[0]],
                    c,
                    out,
                )
            }
            StepKind::Softmax => {
                // Batch-transparent: softmax is row-structured and the
                // batched buffer is just `batch x` as many rows.
                let n = *step.in_shape().last().expect("softmax rank >= 1");
                activation::softmax_into(ctx, n, &bufs[step.inputs[0]], scratch, out)
            }
            StepKind::Flatten => out.extend_from_slice(&bufs[step.inputs[0]]),
            StepKind::Act(a) => {
                out.extend_from_slice(&bufs[step.inputs[0]]);
                apply_act_inplace(ctx, a, out);
            }
            StepKind::Add => {
                // Elementwise over the whole sample-major buffer: per
                // sample this is exactly the single-sample accumulation.
                out.extend_from_slice(&bufs[step.inputs[0]]);
                for &b in &step.inputs[1..] {
                    merge::add_assign_into(ctx, out, &bufs[b]);
                }
            }
            StepKind::Concat { rows, widths } => {
                let srcs: Vec<&[S]> = step.inputs.iter().map(|&b| bufs[b].as_slice()).collect();
                merge::concat_batch_into(batch, *rows, widths, &srcs, out);
            }
        }
        if let Some(a) = &step.fused_act {
            apply_act_inplace(ctx, a, out);
        }
    }

    /// [`Plan::execute_batch_path`] fanned out over a worker [`Pool`] —
    /// one plan drive uses the whole machine, **bit-identical** to the
    /// serial path.
    ///
    /// Two layers of parallelism, both pure reorderings of *independent*
    /// work (never the inside of a reduction chain, so every output
    /// element sees the same operations in the same order as the serial
    /// blocked path, and hence as scalar):
    ///
    /// * **Intra-op**: a blocked compute step's output is partitioned at
    ///   tile boundaries ([`gemm::DensePanel::tile_out_start`] and
    ///   friends) into up to [`Parallelism::workers`] contiguous chunks,
    ///   each computed by a `*_blocked_tiles` range kernel as a scoped
    ///   job with per-worker [`TileScratch`]. Steps with
    ///   `out_len * batch < min_work` (or no blocked lowering, or a
    ///   single tile) run serially — sharding tiny steps costs more than
    ///   it saves.
    /// * **Inter-op**: steps with no RAW/WAW/WAR hazard between them
    ///   ([`Plan::step_deps`]) — independent residual/branchy graph
    ///   branches — run as concurrent scoped jobs, each writing its own
    ///   (taken-out) pool buffer.
    ///
    /// Execution uses the caller-helps [`Pool::scope`] primitive, so it
    /// is deadlock-free from any context (including from inside a pool
    /// job — the serve flush path) and under a racing pool shutdown.
    /// `par.workers <= 1` runs exactly the serial
    /// [`Plan::execute_batch_path`].
    pub fn execute_batch_pooled<'a, S>(
        &self,
        ctx: &S::Ctx,
        input: &[S],
        batch: usize,
        arena: &'a mut Arena<S>,
        path: KernelPath,
        pool: &Pool,
        par: Parallelism,
    ) -> Result<&'a [S]>
    where
        S: Scalar + Send + Sync + 'static,
    {
        if par.workers <= 1 {
            return self.execute_batch_path(ctx, input, batch, arena, path);
        }
        if batch == 0 {
            bail!("plan '{}': batch must be >= 1", self.model_name());
        }
        if input.len() != batch * self.input_len() {
            bail!(
                "plan '{}' expects {batch} x {:?} ({} values sample-major), got {}",
                self.model_name(),
                self.input_shape(),
                batch * self.input_len(),
                input.len()
            );
        }
        let path = if S::BLOCKED_ELIGIBLE { path } else { KernelPath::Scalar };
        let t_drive = obs::mark();
        arena.load_batch(self, input, batch);

        // Wave scheduler: repeatedly run the set of steps whose
        // predecessors have all completed. Serial execution is the
        // degenerate all-waves-of-one schedule, so any wave order is
        // hazard-free by construction of `step_deps`.
        let n = self.steps().len();
        let deps = self.step_deps();
        let mut done = vec![false; n];
        let mut wave: Vec<usize> = Vec::new();
        let mut n_done = 0;
        let mut wave_idx = 0usize;
        while n_done < n {
            wave.clear();
            for (i, d) in deps.iter().enumerate() {
                if !done[i] && d.iter().all(|&p| done[p]) {
                    wave.push(i);
                }
            }
            debug_assert!(!wave.is_empty(), "step dependency cycle");
            let t_wave = obs::mark();
            let busy = if wave.len() == 1 {
                self.execute_step_wide(wave[0], batch, ctx, arena, path, pool, par)
            } else {
                self.execute_wave_concurrent(&wave, batch, ctx, arena, path, pool, par)
            };
            obs::wave_done(t_wave, batch, wave.len(), busy, wave_idx);
            wave_idx += 1;
            n_done += wave.len();
            for &i in &wave {
                done[i] = true;
            }
        }
        obs::drive_done(t_drive, "pooled", batch, n);
        Ok(&arena.bufs[self.output_buf()])
    }

    /// One step of a pooled drive, intra-op sharded across the pool when
    /// it is a blocked step with enough work (see
    /// [`Plan::execute_batch_pooled`]); everything else falls through to
    /// the serial step executor. Returns the busy-worker count (tile
    /// groups actually sharded; `1` for the serial fallback) for the
    /// caller's wave gauge.
    #[allow(clippy::too_many_arguments)]
    fn execute_step_wide<S>(
        &self,
        idx: usize,
        batch: usize,
        ctx: &S::Ctx,
        arena: &mut Arena<S>,
        path: KernelPath,
        pool: &Pool,
        par: Parallelism,
    ) -> usize
    where
        S: Scalar + Send + Sync + 'static,
    {
        let step = &self.steps()[idx];
        let bs = self.blocked_step(idx, path);
        let units = match bs {
            Some(BlockedStep::Dense(pd)) => pd.tiles(batch),
            Some(BlockedStep::Conv(ic)) => ic.tiles(batch),
            Some(BlockedStep::Depthwise(dw)) => dw.tiles(batch),
            Some(BlockedStep::AvgPool(pt)) => pt.tiles(batch),
            None => 0,
        };
        if units < 2 || step.out == step.inputs[0] || step.out_len() * batch < par.min_work {
            let t0 = obs::mark();
            self.execute_step_batch_path(idx, batch, ctx, arena, path);
            obs::step_done(t0, step.kind.name(), path, batch, units, 1);
            return 1;
        }
        let bs = bs.expect("units > 0 implies blocked data");
        let groups = par.workers.min(units);
        let fused = step.fused_act;
        let t0 = obs::mark();

        let mut out = std::mem::take(&mut arena.bufs[step.out]);
        out.clear();
        out.resize(batch * step.out_len(), S::exact(ctx, 0.0));
        let x = arena.bufs[step.inputs[0]].as_slice();

        pool.scope(|s| {
            // Hand each group its contiguous output chunk: tile ranges
            // partition the output (`tile_out_start` is the boundary map),
            // so `split_at_mut` carves disjoint `&mut` chunks — no
            // aliasing, no unsafe.
            let mut rest: &mut [S] = &mut out;
            let mut covered = 0usize;
            for g in 0..groups {
                let (u0, u1) = (g * units / groups, (g + 1) * units / groups);
                if u0 == u1 {
                    continue;
                }
                let end = match bs {
                    BlockedStep::Dense(pd) => pd.tile_out_start(batch, u1),
                    BlockedStep::Conv(ic) => ic.tile_out_start(batch, u1),
                    BlockedStep::Depthwise(dw) => dw.tile_out_start(batch, u1),
                    BlockedStep::AvgPool(pt) => pt.tile_out_start(batch, u1),
                };
                let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(end - covered);
                rest = tail;
                covered = end;
                s.spawn(move || {
                    with_worker_scratch(|ts: &mut TileScratch<S>| match (&step.kind, bs) {
                        (StepKind::Dense { b, .. }, BlockedStep::Dense(pd)) => {
                            gemm::dense_blocked_tiles(
                                ctx,
                                pd,
                                b,
                                x,
                                batch,
                                u0,
                                u1,
                                &mut ts.pack,
                                chunk,
                            );
                        }
                        (StepKind::Conv2D { kernel, bias, .. }, BlockedStep::Conv(ic)) => {
                            gemm::conv_blocked_tiles(
                                ctx,
                                ic,
                                kernel.data(),
                                bias,
                                x,
                                batch,
                                u0,
                                u1,
                                &mut ts.pack,
                                &mut ts.mask,
                                chunk,
                            );
                        }
                        (
                            StepKind::DepthwiseConv2D { kernel, bias, .. },
                            BlockedStep::Depthwise(dw),
                        ) => {
                            gemm::depthwise_blocked_tiles(
                                ctx,
                                dw,
                                kernel.data(),
                                bias,
                                x,
                                batch,
                                u0,
                                u1,
                                &mut ts.pack,
                                chunk,
                            );
                        }
                        (StepKind::AvgPool2D { .. }, BlockedStep::AvgPool(pt)) => {
                            gemm::avg_pool_blocked_tiles(
                                ctx,
                                pt,
                                x,
                                batch,
                                u0,
                                u1,
                                &mut ts.pack,
                                chunk,
                            );
                        }
                        _ => unreachable!("blocked data always matches its step kind"),
                    });
                    // The fused activation is elementwise — applying it
                    // per chunk is the same per-element operation order.
                    if let Some(a) = &fused {
                        apply_act_inplace(ctx, a, chunk);
                    }
                });
            }
            debug_assert!(rest.is_empty(), "tile groups must cover the whole output");
        });
        obs::step_done(t0, step.kind.name(), path, batch, units, groups);

        arena.bufs[step.out] = out;
        debug_assert_eq!(
            arena.bufs[step.out].len(),
            batch * step.out_len(),
            "step {idx} sharded output"
        );
        groups
    }

    /// Run an independent wave of 2+ steps as concurrent scoped jobs —
    /// the inter-op layer. Every wave step's output buffer is taken out
    /// of the pool first (hazard-free by `step_deps`: no wave member
    /// reads or writes another member's output buffer), each job runs
    /// the full serial step kernel with per-worker scratch, and the
    /// buffers go back after the scope barrier. Waves whose total work
    /// is below `min_work` run serially in step order instead. Returns
    /// the busy-worker count (concurrent jobs capped by the pool width;
    /// `1` for the serial fallback) for the caller's wave gauge.
    #[allow(clippy::too_many_arguments)]
    fn execute_wave_concurrent<S>(
        &self,
        wave: &[usize],
        batch: usize,
        ctx: &S::Ctx,
        arena: &mut Arena<S>,
        path: KernelPath,
        pool: &Pool,
        par: Parallelism,
    ) -> usize
    where
        S: Scalar + Send + Sync + 'static,
    {
        let work: usize = wave.iter().map(|&i| self.steps()[i].out_len() * batch).sum();
        if work < par.min_work {
            for &i in wave {
                let t0 = obs::mark();
                self.execute_step_batch_path(i, batch, ctx, arena, path);
                obs::step_done(t0, self.steps()[i].kind.name(), path, batch, 0, 1);
            }
            return 1;
        }
        let mut outs: Vec<(usize, Vec<S>)> = wave
            .iter()
            .map(|&i| {
                let step = &self.steps()[i];
                let mut v = std::mem::take(&mut arena.bufs[step.out]);
                if step.out != step.inputs[0] {
                    v.clear();
                }
                (i, v)
            })
            .collect();
        let bufs: &[Vec<S>] = &arena.bufs;
        pool.scope(|s| {
            for (i, out) in outs.iter_mut() {
                let i = *i;
                let step = &self.steps()[i];
                s.spawn(move || {
                    let t0 = obs::mark();
                    if step.out == step.inputs[0] {
                        // In-place alias: the job owns the taken buffer.
                        debug_assert!(step.fused_act.is_none());
                        match &step.kind {
                            StepKind::Flatten => {}
                            StepKind::Act(a) => apply_act_inplace(ctx, a, out),
                            other => {
                                unreachable!("{} steps are never in-place-aliased", other.name())
                            }
                        }
                    } else {
                        with_worker_scratch(|ts: &mut TileScratch<S>| {
                            self.run_step_kernel(
                                i,
                                batch,
                                ctx,
                                bufs,
                                &mut ts.pack,
                                &mut ts.mask,
                                &mut ts.scratch,
                                out,
                                path,
                            );
                        });
                    }
                    obs::step_done(t0, step.kind.name(), path, batch, 0, 1);
                });
            }
        });
        for (i, v) in outs {
            debug_assert_eq!(v.len(), batch * self.steps()[i].out_len(), "wave step {i} output");
            arena.bufs[self.steps()[i].out] = v;
        }
        par.workers.min(wave.len())
    }

    /// Convenience tensor-in/tensor-out execution with a throwaway arena —
    /// the compatibility path behind [`crate::model::Model::forward`].
    /// Hot paths should hold an [`Arena`] and call [`Plan::execute`].
    pub fn forward<S: Scalar>(&self, ctx: &S::Ctx, input: Tensor<S>) -> Result<Tensor<S>> {
        if input.shape() != self.input_shape() {
            bail!(
                "model '{}' expects input {:?}, got {:?}",
                self.model_name(),
                self.input_shape(),
                input.shape()
            );
        }
        let mut arena = Arena::new();
        let out = self.execute(ctx, input.data(), &mut arena)?.to_vec();
        Ok(Tensor::new(self.output_shape().to_vec(), out))
    }
}

/// Apply an elementwise activation in place, mirroring the unfused
/// per-element operation order exactly (bit-identical CAA bounds).
fn apply_act_inplace<S: Scalar>(ctx: &S::Ctx, act: &Act, buf: &mut [S]) {
    match act {
        Act::Relu => {
            for v in buf.iter_mut() {
                let y = v.relu(ctx);
                *v = y;
            }
        }
        Act::LeakyRelu { alpha } => {
            // Same form as layers::activation::leaky_relu:
            // leaky(x) = max(x, alpha * x) with alpha embedded once.
            let a = S::param(ctx, *alpha);
            for v in buf.iter_mut() {
                let scaled = v.mul(&a, ctx);
                let y = v.max(&scaled, ctx);
                *v = y;
            }
        }
        Act::Tanh => {
            for v in buf.iter_mut() {
                let y = v.tanh(ctx);
                *v = y;
            }
        }
        Act::Sigmoid => {
            for v in buf.iter_mut() {
                let y = v.sigmoid(ctx);
                *v = y;
            }
        }
    }
}
