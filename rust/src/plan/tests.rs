//! Unit tests for the plan IR: build-time shape resolution, fusion
//! structure, folding math, executor equivalence against the legacy
//! interpreter, and arena reuse.

#![allow(deprecated)] // the legacy interpreter is the equivalence oracle

use super::*;
use crate::caa::{Caa, Ctx};
use crate::interval::Interval;
use crate::layers::Layer;
use crate::model::{zoo, Model};
use crate::quant::EmulatedFp;
use crate::tensor::{EmuCtx, Tensor};
use crate::util::Rng;

fn zoo_models() -> Vec<Model> {
    vec![
        zoo::tiny_mlp(11),
        zoo::tiny_cnn(12),
        zoo::tiny_pendulum(13),
        zoo::scaled_mlp(14, 16, 24, 5),
    ]
}

fn rand_input(model: &Model, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let n: usize = model.input_shape.iter().product();
    (0..n).map(|_| rng.range(0.0, 1.0)).collect()
}

#[test]
fn unfused_steps_match_legacy_shape_path() {
    for model in zoo_models() {
        let plan = Plan::unfused(&model).unwrap();
        assert_eq!(plan.steps().len(), model.layers.len());
        let mut shape = model.input_shape.clone();
        for (step, layer) in plan.steps().iter().zip(&model.layers) {
            assert_eq!(step.in_shape(), shape.as_slice());
            shape = layer.output_shape(&shape).unwrap();
            assert_eq!(step.out_shape, shape, "{}: {}", model.name, step.kind.name());
        }
        assert_eq!(plan.output_shape(), model.output_shape().unwrap().as_slice());
    }
}

#[test]
fn step_shapes_chain_at_every_fusion_level() {
    for model in zoo_models() {
        for fusion in [Fusion::None, Fusion::Pair, Fusion::Full] {
            let plan = Plan::build(&model, fusion).unwrap();
            let mut shape = model.input_shape.clone();
            let mut next_layer = 0;
            for step in plan.steps() {
                assert_eq!(step.in_shape(), shape.as_slice(), "{:?} {}", fusion, step.kind.name());
                assert_eq!(step.layer_range.0, next_layer, "layer provenance is contiguous");
                assert!(step.layer_range.1 > step.layer_range.0);
                next_layer = step.layer_range.1;
                shape = step.out_shape.clone();
            }
            assert_eq!(next_layer, model.layers.len(), "every layer is covered");
            assert_eq!(plan.output_shape(), shape.as_slice());
            assert!(plan.max_buffer_len() > 0);
        }
    }
}

#[test]
fn pairing_attaches_activations() {
    let plan = Plan::for_analysis(&zoo::tiny_mlp(1)).unwrap();
    // dense+relu, dense+relu, dense, softmax -> 4 steps.
    assert_eq!(plan.steps().len(), 4);
    assert_eq!(plan.steps()[0].fused_act, Some(Act::Relu));
    assert_eq!(plan.steps()[1].fused_act, Some(Act::Relu));
    assert!(plan.steps()[2].fused_act.is_none());
    assert!(matches!(plan.steps()[3].kind, StepKind::Softmax));
}

#[test]
fn full_fusion_folds_batch_norm() {
    let model = zoo::tiny_cnn(2);
    let unfused = Plan::unfused(&model).unwrap();
    let fused = Plan::for_reference(&model).unwrap();
    assert!(unfused
        .steps()
        .iter()
        .any(|s| matches!(s.kind, StepKind::BatchNorm { .. })));
    assert!(
        !fused
            .steps()
            .iter()
            .any(|s| matches!(s.kind, StepKind::BatchNorm { .. })),
        "conv-adjacent batch norm must fold away at Fusion::Full"
    );
    assert!(fused.steps().len() < unfused.steps().len());
}

#[test]
fn f64_plan_matches_interpreter_bitwise_when_unfused_or_paired() {
    for model in zoo_models() {
        let x = rand_input(&model, 7);
        let reference = model
            .forward_interpreted::<f64>(&(), Tensor::new(model.input_shape.clone(), x.clone()))
            .unwrap();
        for fusion in [Fusion::None, Fusion::Pair] {
            let plan = Plan::build(&model, fusion).unwrap();
            let mut arena = Arena::new();
            let got = plan.execute::<f64>(&(), &x, &mut arena).unwrap();
            assert_eq!(
                got,
                reference.data(),
                "{}: {fusion:?} must be arithmetically identical",
                model.name
            );
        }
    }
}

#[test]
fn folded_f64_stays_within_ulp_scale() {
    let model = zoo::tiny_cnn(23);
    let x = rand_input(&model, 9);
    let unfused = Plan::unfused(&model).unwrap();
    let fused = Plan::for_reference(&model).unwrap();
    let mut a1 = Arena::new();
    let mut a2 = Arena::new();
    let y1 = unfused.execute::<f64>(&(), &x, &mut a1).unwrap().to_vec();
    let y2 = fused.execute::<f64>(&(), &x, &mut a2).unwrap();
    for (u, f) in y1.iter().zip(y2) {
        let scale = u.abs().max(1.0);
        assert!(
            (u - f).abs() <= 1e-10 * scale,
            "fused {f:e} vs unfused {u:e}: folding must only re-associate f64 rounding"
        );
    }
}

#[test]
fn caa_bounds_bit_identical_to_interpreter() {
    // The soundness contract of Fusion::Pair: same ops in the same order,
    // so every CAA entry is bit-identical to the per-layer interpreter.
    for model in [zoo::tiny_mlp(42), zoo::tiny_cnn(5)] {
        let ctx = Ctx::new();
        let x = rand_input(&model, 3);
        let mk_input = || {
            Tensor::new(
                model.input_shape.clone(),
                x.iter().map(|&v| Caa::input(&ctx, Interval::point(v), v)).collect::<Vec<Caa>>(),
            )
        };
        let reference = model.forward_interpreted::<Caa>(&ctx, mk_input()).unwrap();
        let plan = Plan::for_analysis(&model).unwrap();
        let mut arena = Arena::new();
        let got = plan.execute::<Caa>(&ctx, mk_input().data(), &mut arena).unwrap();
        assert_eq!(got.len(), reference.len());
        for (g, r) in got.iter().zip(reference.data()) {
            assert_eq!(g.fp().to_bits(), r.fp().to_bits(), "{}", model.name);
            assert_eq!(g.abs_bound().to_bits(), r.abs_bound().to_bits(), "{}", model.name);
            assert_eq!(g.rel_bound().to_bits(), r.rel_bound().to_bits(), "{}", model.name);
            assert_eq!(g.ideal().lo().to_bits(), r.ideal().lo().to_bits());
            assert_eq!(g.ideal().hi().to_bits(), r.ideal().hi().to_bits());
            assert_eq!(g.rounded().lo().to_bits(), r.rounded().lo().to_bits());
            assert_eq!(g.rounded().hi().to_bits(), r.rounded().hi().to_bits());
        }
    }
}

#[test]
fn emulated_witness_matches_interpreter_bitwise() {
    let model = zoo::tiny_cnn(8);
    let x = rand_input(&model, 4);
    for k in [8u32, 12, 20] {
        let ec = EmuCtx { k };
        let xe: Vec<EmulatedFp> = x.iter().map(|&v| EmulatedFp::new(v, k)).collect();
        let reference = model
            .forward_interpreted::<EmulatedFp>(
                &ec,
                Tensor::new(model.input_shape.clone(), xe.clone()),
            )
            .unwrap();
        let plan = Plan::for_analysis(&model).unwrap();
        let mut arena = Arena::new();
        let got = plan.execute::<EmulatedFp>(&ec, &xe, &mut arena).unwrap();
        for (g, r) in got.iter().zip(reference.data()) {
            assert_eq!(g.v.to_bits(), r.v.to_bits(), "k={k}");
        }
    }
}

#[test]
fn arena_steady_state_does_not_reallocate() {
    // Sequential and residual models alike: after the first run, the
    // warmed pool buffers are reused verbatim.
    for model in [zoo::tiny_cnn(6), zoo::residual_cnn(6)] {
        let plan = Plan::for_analysis(&model).unwrap();
        let x = rand_input(&model, 2);
        let mut arena: Arena<f64> = Arena::new();
        let first = plan.execute::<f64>(&(), &x, &mut arena).unwrap().to_vec();
        let caps: Vec<usize> = arena.bufs.iter().map(Vec::capacity).collect();
        let scratch_cap = arena.scratch.capacity();
        for _ in 0..5 {
            let again = plan.execute::<f64>(&(), &x, &mut arena).unwrap();
            assert_eq!(again, first.as_slice());
        }
        assert_eq!(
            arena.bufs.iter().map(Vec::capacity).collect::<Vec<usize>>(),
            caps,
            "{}: repeat executions must reuse the warmed buffers",
            model.name
        );
        assert_eq!(arena.scratch.capacity(), scratch_cap);
    }
}

#[test]
fn execute_checks_input_length() {
    let plan = Plan::for_analysis(&zoo::tiny_mlp(1)).unwrap();
    let mut arena = Arena::new();
    let err = plan.execute::<f64>(&(), &[0.0; 3], &mut arena).unwrap_err();
    assert!(err.to_string().contains("expects input"), "{err}");
}

#[test]
fn build_rejects_incompatible_stacks() {
    let mut rng = Rng::new(1);
    let model = Model {
        name: "bad".into(),
        input_shape: vec![8],
        layers: vec![zoo::dense(&mut rng, 8, 6), zoo::dense(&mut rng, 7, 3)],
        graph: None,
    };
    let err = Plan::unfused(&model).unwrap_err();
    assert!(format!("{err:#}").contains("layer 1"), "{err:#}");
}

#[test]
fn uncommon_step_kinds_match_interpreter() {
    // Covers the kinds the zoo nets omit: AvgPool2D, LeakyRelu, Sigmoid,
    // and an activation directly after Flatten (standalone in-place Act).
    let mut rng = Rng::new(17);
    let model = Model {
        name: "exotic".into(),
        input_shape: vec![4, 4, 2],
        layers: vec![
            zoo::conv2d(&mut rng, 3, 3, 2, 3, 1, crate::layers::Padding::Same),
            Layer::LeakyRelu { alpha: 0.1 },
            Layer::AvgPool2D { ph: 2, pw: 2 },
            Layer::Sigmoid,
            Layer::Flatten,
            Layer::Tanh,
            zoo::dense(&mut rng, 12, 4),
            Layer::Softmax,
        ],
        graph: None,
    };
    let x = rand_input(&model, 21);
    let reference = model
        .forward_interpreted::<f64>(&(), Tensor::new(model.input_shape.clone(), x.clone()))
        .unwrap();
    for fusion in [Fusion::None, Fusion::Pair] {
        let plan = Plan::build(&model, fusion).unwrap();
        let mut arena = Arena::new();
        let got = plan.execute::<f64>(&(), &x, &mut arena).unwrap();
        assert_eq!(got, reference.data(), "{fusion:?}");
    }
    // CAA agrees bitwise as well (the exotic kinds keep the contract).
    let ctx = Ctx::new();
    let mk = |vals: &[f64]| {
        Tensor::new(
            model.input_shape.clone(),
            vals.iter().map(|&v| Caa::input(&ctx, Interval::point(v), v)).collect::<Vec<Caa>>(),
        )
    };
    let oracle = model.forward_interpreted::<Caa>(&ctx, mk(&x)).unwrap();
    let plan = Plan::for_analysis(&model).unwrap();
    let mut arena = Arena::new();
    let got = plan.execute::<Caa>(&ctx, mk(&x).data(), &mut arena).unwrap();
    for (g, r) in got.iter().zip(oracle.data()) {
        assert_eq!(g.abs_bound().to_bits(), r.abs_bound().to_bits());
        assert_eq!(g.rel_bound().to_bits(), r.rel_bound().to_bits());
    }
}

// (The sequential-models-compile-to-exactly-two-buffers regression lives
// in `rust/tests/plan.rs`, next to the other graph-IR acceptance tests.)

#[test]
fn residual_models_use_three_pool_buffers() {
    // One extra buffer holds the live skip/branch value across the merge.
    for model in [zoo::residual_mlp(3), zoo::residual_cnn(4)] {
        for fusion in [Fusion::None, Fusion::Pair, Fusion::Full] {
            let plan = Plan::build(&model, fusion).unwrap();
            assert_eq!(plan.buffer_count(), 3, "{} at {fusion:?}", model.name);
            assert!(plan.max_buffer_len() > 0);
        }
    }
}

#[test]
fn graph_buffer_wiring_is_consistent() {
    // Structural invariants of the register allocation, on every zoo
    // model and fusion level: inputs are written before read, an output
    // buffer never aliases a live input (except the sanctioned in-place
    // Act/Flatten case), and the output buffer holds the final value.
    let mut models = zoo_models();
    models.push(zoo::residual_mlp(5));
    models.push(zoo::residual_cnn(6));
    for model in models {
        for fusion in [Fusion::None, Fusion::Pair, Fusion::Full] {
            let plan = Plan::build(&model, fusion).unwrap();
            let nbufs = plan.buffer_count();
            let mut written = vec![false; nbufs];
            written[plan.input_buf()] = true;
            for step in plan.steps() {
                assert_eq!(step.inputs.len(), step.in_shapes.len());
                for &b in &step.inputs {
                    assert!(b < nbufs);
                    assert!(written[b], "{}: read-before-write", model.name);
                }
                if step.out == step.inputs[0] {
                    assert!(
                        matches!(step.kind, StepKind::Act(_) | StepKind::Flatten),
                        "{}: only Act/Flatten may alias in place",
                        model.name
                    );
                } else {
                    // Compute steps read while writing: no input aliasing.
                    assert!(
                        !step.inputs.contains(&step.out),
                        "{}: output aliases a live input",
                        model.name
                    );
                }
                // Buffer capacities cover every placement.
                assert!(plan.buffer_lens()[step.out] >= step.out_len());
                written[step.out] = true;
            }
            assert!(written[plan.output_buf()]);
        }
    }
}

#[test]
fn residual_fusion_respects_skip_liveness() {
    // In residual_mlp the first ReLU's output feeds both the second dense
    // and the merge: pairing must still fuse it (its *producer's* value
    // has a single consumer), while the post-merge ReLU fuses onto Add.
    let plan = Plan::for_analysis(&zoo::residual_mlp(7)).unwrap();
    let kinds: Vec<&str> = plan.steps().iter().map(|s| s.kind.name()).collect();
    assert_eq!(kinds, vec!["dense", "dense", "add", "dense", "softmax"]);
    assert_eq!(plan.steps()[0].fused_act, Some(Act::Relu), "stem dense+relu");
    assert_eq!(plan.steps()[2].fused_act, Some(Act::Relu), "add+relu");
    let add = &plan.steps()[2];
    assert_eq!(add.inputs.len(), 2);
    assert_eq!(
        add.inputs[1],
        plan.steps()[0].out,
        "the skip edge reads the stem's output buffer"
    );
}

#[test]
fn concat_step_geometry_resolved_at_build() {
    let plan = Plan::for_analysis(&zoo::residual_cnn(8)).unwrap();
    let concat = plan
        .steps()
        .iter()
        .find(|s| matches!(s.kind, StepKind::Concat { .. }))
        .expect("residual_cnn has a concat");
    let StepKind::Concat { rows, widths } = &concat.kind else { unreachable!() };
    assert_eq!(*rows, 36, "6x6 spatial positions");
    assert_eq!(widths.as_slice(), &[2, 2], "two 2-channel branches");
    assert_eq!(concat.out_shape, vec![6, 6, 4]);
}

#[test]
fn residual_plans_execute_in_every_arithmetic() {
    // End-to-end: f64, CAA and emulated runs over both residual models,
    // with the CAA bound dominating the emulated deviation (the soundness
    // sandwich, now across merge points).
    for model in [zoo::residual_mlp(21), zoo::residual_cnn(22)] {
        let x = rand_input(&model, 13);
        let plan = Plan::for_analysis(&model).unwrap();
        let mut arena = Arena::new();
        let yr = plan.execute::<f64>(&(), &x, &mut arena).unwrap().to_vec();
        assert_eq!(yr.len(), plan.output_len());
        assert!(yr.iter().all(|v| v.is_finite()));

        let ctx = Ctx::new();
        let xc: Vec<Caa> =
            x.iter().map(|&v| Caa::input(&ctx, Interval::point(v), v)).collect();
        let mut caa_arena = Arena::new();
        let yc = plan.execute::<Caa>(&ctx, &xc, &mut caa_arena).unwrap().to_vec();
        for k in [10u32, 16] {
            let ec = EmuCtx { k };
            let xe: Vec<EmulatedFp> = x.iter().map(|&v| EmulatedFp::new(v, k)).collect();
            let mut emu_arena = Arena::new();
            let ye = plan.execute::<EmulatedFp>(&ec, &xe, &mut emu_arena).unwrap();
            for i in 0..yr.len() {
                crate::quant::check_against_bounds(&yc[i], yr[i], ye[i].v, k, 1e-12)
                    .unwrap_or_else(|e| panic!("{} k={k} output {i}: {e}", model.name));
            }
        }
    }
}

#[test]
fn model_forward_routes_through_plan() {
    // Model::forward (the compat path) and the explicit plan executor
    // agree bitwise.
    let model = zoo::tiny_cnn(31);
    let x = rand_input(&model, 5);
    let via_model = model
        .forward::<f64>(&(), Tensor::new(model.input_shape.clone(), x.clone()))
        .unwrap();
    let plan = model.compile(Fusion::None).unwrap();
    let mut arena = Arena::new();
    let via_plan = plan.execute::<f64>(&(), &x, &mut arena).unwrap();
    assert_eq!(via_model.data(), via_plan);
}
