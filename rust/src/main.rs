//! `rigor` — the analysis tool's command-line front end (L3 leader).
//!
//! Every analysis command is a thin shell over [`rigor::api::Session`]:
//! the CLI parses flags into an [`rigor::api::AnalysisRequest`] and
//! renders the returned [`rigor::api::AnalysisOutcome`].
//!
//! Commands:
//! * `analyze` — per-class CAA analysis of a model JSON + dataset JSON,
//!   fanned out over the session pool; prints the Table-I row and the
//!   minimum safe precision (`--json` emits the versioned outcome JSON).
//! * `table1`  — regenerate the paper's Table I over all trained artifact
//!   models.
//! * `tune`    — mixed-precision tuning: per-layer minimal formats (§VI).
//! * `sweep`   — accuracy-vs-precision sweep over the AOT k-variants
//!   (needs the `pjrt` feature).
//! * `run`     — execute a model: the default `--variant engine` drives
//!   the model JSON through the session's compiled plan and, with
//!   `--batch N` and/or `--data`, through the `serve` micro-batcher
//!   (batched plan drives on the worker pool); any other variant executes
//!   the matching AOT artifact via PJRT (needs `pjrt`).
//! * `fleet`   — multi-model serving demo: deploys several model JSONs
//!   into one `fleet::Fleet`, pushes an interleaved f64 + emulated-k
//!   load through the per-(model, format) queues, and prints the
//!   per-queue metrics and the fleet snapshot.
//! * `plan`    — print the canonical textual IR of the compiled plan for
//!   a model JSON (steps, buffer liveness, hazard edges, memory report):
//!   `rigor plan model.json [--format f64|emu-k<k>] [--kernels
//!   blocked|scalar]`. The same text the golden snapshot suite pins.
//! * `stats`   — serve a synthetic load under an `obs::ObsPolicy` and
//!   print the unified observability snapshot (pool/queue counters,
//!   latency percentiles, executor gauges); `--trace full --trace-out
//!   t.json` exports the run's Chrome-trace JSON.
//! * `profile` — one CAA pass with the per-step bound probe: each
//!   step's absolute/relative bound width next to its wall-clock cost
//!   (the paper's conv-widens / activation-recontracts profile).

use rigor::api::{AnalysisRequest, ExecMode, Session};
use rigor::cli::{App, CmdSpec, OptSpec};
use rigor::report::{per_class_console, table1_console, table1_markdown, TableRow};
use std::path::Path;

fn app() -> App {
    let analysis_opts = vec![
        OptSpec { name: "model", help: "model JSON path", default: Some("artifacts/models/digits.json".into()) },
        OptSpec { name: "data", help: "dataset JSON path", default: Some("artifacts/data/digits_eval.json".into()) },
        OptSpec { name: "p-star", help: "top-1 confidence floor p*", default: Some("0.60".into()) },
        OptSpec { name: "u-max-log2", help: "-log2 of u_max (paper: 7)", default: Some("7".into()) },
        OptSpec { name: "radius", help: "input box radius", default: Some("0".into()) },
        OptSpec { name: "exact-inputs", help: "inputs exactly representable", default: None },
        OptSpec { name: "workers", help: "pool workers (0 = host)", default: Some("0".into()) },
        OptSpec { name: "per-class", help: "print per-class detail", default: None },
        OptSpec { name: "progress", help: "stream per-class results as they finish", default: None },
        OptSpec { name: "json", help: "emit the versioned outcome JSON", default: None },
    ];
    App {
        name: "rigor",
        about: "semi-automatic precision & accuracy analysis for deep learning (CAA + IA)",
        commands: vec![
            CmdSpec { name: "analyze", help: "analyze one model", opts: analysis_opts },
            CmdSpec {
                name: "table1",
                help: "regenerate the paper's Table I over the artifact models",
                opts: vec![
                    OptSpec { name: "artifacts", help: "artifacts dir", default: Some("artifacts".into()) },
                    OptSpec { name: "p-star", help: "confidence floor", default: Some("0.60".into()) },
                    OptSpec { name: "markdown", help: "emit markdown", default: None },
                ],
            },
            CmdSpec {
                name: "sweep",
                help: "accuracy vs precision over AOT k-variants (PJRT)",
                opts: vec![
                    OptSpec { name: "artifacts", help: "artifacts dir", default: Some("artifacts".into()) },
                    OptSpec { name: "model", help: "model name", default: Some("digits".into()) },
                ],
            },
            CmdSpec {
                name: "tune",
                help: "mixed-precision tuning: per-layer minimal formats (paper §VI)",
                opts: vec![
                    OptSpec { name: "model", help: "model JSON path", default: Some("artifacts/models/digits.json".into()) },
                    OptSpec { name: "data", help: "dataset JSON path", default: Some("artifacts/data/digits_eval.json".into()) },
                    OptSpec { name: "p-star", help: "confidence floor", default: Some("0.60".into()) },
                    OptSpec { name: "k-floor", help: "smallest k to try", default: Some("4".into()) },
                    OptSpec { name: "exact-inputs", help: "inputs exactly representable", default: None },
                ],
            },
            CmdSpec {
                name: "fleet",
                help: "serve several models through one precision-tagged fleet",
                opts: vec![
                    OptSpec {
                        name: "models",
                        help: "comma-separated model JSON paths",
                        default: Some(
                            "artifacts/models/digits.json,artifacts/models/pendulum.json".into(),
                        ),
                    },
                    OptSpec { name: "k", help: "emulated mantissa bits for the low-precision lane", default: Some("12".into()) },
                    OptSpec { name: "requests", help: "tickets per (model, format) queue", default: Some("64".into()) },
                    OptSpec { name: "batch", help: "micro-batch size", default: Some("8".into()) },
                    OptSpec { name: "max-wait-ms", help: "flush timer for partial batches", default: Some("2".into()) },
                    OptSpec { name: "workers", help: "pool workers (0 = host)", default: Some("0".into()) },
                ],
            },
            CmdSpec {
                name: "plan",
                help: "print the compiled plan IR + memory report for a model JSON",
                opts: vec![
                    OptSpec { name: "format", help: "serve format: f64 | emu-k<k>", default: Some("f64".into()) },
                    OptSpec { name: "kernels", help: "kernel family: blocked | scalar", default: Some("blocked".into()) },
                ],
            },
            CmdSpec {
                name: "stats",
                help: "serve a load and print the unified observability snapshot",
                opts: vec![
                    OptSpec { name: "model", help: "model JSON path (overrides --zoo)", default: Some(String::new()) },
                    OptSpec { name: "zoo", help: "built-in zoo model name", default: Some("residual_cnn".into()) },
                    OptSpec { name: "requests", help: "samples to serve", default: Some("64".into()) },
                    OptSpec { name: "batch", help: "micro-batch size", default: Some("8".into()) },
                    OptSpec { name: "workers", help: "pool workers (0 = host)", default: Some("0".into()) },
                    OptSpec { name: "trace", help: "observability policy: disabled | counters | full", default: Some("counters".into()) },
                    OptSpec { name: "trace-out", help: "write the Chrome-trace JSON here (needs --trace full)", default: Some(String::new()) },
                    OptSpec { name: "json", help: "emit the snapshot as JSON", default: None },
                ],
            },
            CmdSpec {
                name: "profile",
                help: "per-step CAA error-bound profile (bound widths next to wall-clock)",
                opts: vec![
                    OptSpec { name: "model", help: "model JSON path (overrides --zoo)", default: Some(String::new()) },
                    OptSpec { name: "zoo", help: "built-in zoo model name", default: Some("tiny_cnn".into()) },
                    OptSpec { name: "u-max-log2", help: "-log2 of u_max (paper: 7)", default: Some("7".into()) },
                    OptSpec { name: "radius", help: "input box radius", default: Some("0".into()) },
                    OptSpec { name: "json", help: "emit the profile as JSON", default: None },
                ],
            },
            CmdSpec {
                name: "run",
                help: "execute a model on input vectors (engine plan or PJRT artifact)",
                opts: vec![
                    OptSpec { name: "artifacts", help: "artifacts dir", default: Some("artifacts".into()) },
                    OptSpec { name: "model", help: "model name", default: Some("pendulum".into()) },
                    OptSpec { name: "variant", help: "engine (compiled plan), f32 or k<bits> (PJRT)", default: Some("engine".into()) },
                    OptSpec { name: "input", help: "comma-separated values", default: Some("1.0,-2.0".into()) },
                    OptSpec { name: "batch", help: "micro-batch size for the engine path", default: Some("1".into()) },
                    OptSpec { name: "data", help: "dataset JSON to serve in bulk (engine path)", default: Some(String::new()) },
                ],
            },
        ],
    }
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parsed = app().parse(&args)?;
    match parsed.command.as_str() {
        "analyze" => cmd_analyze(&parsed),
        "table1" => cmd_table1(&parsed),
        "sweep" => cmd_sweep(&parsed),
        "tune" => cmd_tune(&parsed),
        "fleet" => cmd_fleet(&parsed),
        "plan" => cmd_plan(&parsed),
        "stats" => cmd_stats(&parsed),
        "profile" => cmd_profile(&parsed),
        "run" => cmd_run(&parsed),
        _ => unreachable!(),
    }
}

fn session_from(p: &rigor::cli::Parsed) -> Session {
    let w = p.get_usize("workers").unwrap_or(0);
    if w == 0 {
        Session::new()
    } else {
        Session::builder().workers(w).build()
    }
}

fn cmd_analyze(p: &rigor::cli::Parsed) -> anyhow::Result<()> {
    let session = session_from(p);
    let u_log2 = p.get_usize("u-max-log2")?;
    let mut builder = AnalysisRequest::builder()
        .model_path(p.get("model").unwrap())
        .data_path(p.get("data").unwrap())
        .p_star(p.get_f64("p-star")?)
        .u_max_log2(u_log2 as u32)
        .input_radius(p.get_f64("radius")?)
        .exact_inputs(p.flag("exact-inputs"))
        .mode(ExecMode::Pooled { workers: 0 });
    if p.flag("progress") {
        // Stream on stderr: stdout must stay a clean document when
        // combined with --json.
        builder = builder.on_class(|c| {
            eprintln!(
                "class {:>3}: abs {:>10.3e}u  rel {:>10.3e}u  predicted {}  ({:.2} s)",
                c.class, c.max_abs_u, c.max_rel_u, c.predicted, c.secs
            );
        });
    }
    let req = builder.build()?;
    let outcome = session.run(&req)?;
    if p.flag("json") {
        println!("{}", outcome.to_json_string());
        return Ok(());
    }
    if p.flag("per-class") {
        println!("{}", per_class_console(&outcome.analysis));
    }
    println!("{}", table1_console(&[outcome.table_row()], req.p_star()));
    match outcome.required_k() {
        Some(k) => println!("minimum safe precision: k = {k}"),
        None => println!("no finite bound — cannot certify a precision"),
    }
    Ok(())
}

fn cmd_table1(p: &rigor::cli::Parsed) -> anyhow::Result<()> {
    let dir = Path::new(p.get("artifacts").unwrap());
    let p_star = p.get_f64("p-star")?;
    let session = Session::new();
    let mut reqs = Vec::new();
    for (name, radius) in [("digits", 0.0), ("mobilenet_mini", 0.0), ("pendulum", 6.0)] {
        let builder = AnalysisRequest::builder()
            .model_path(dir.join("models").join(format!("{name}.json")))
            .p_star(p_star)
            .exact_inputs(true)
            .mode(ExecMode::Pooled { workers: 0 });
        let builder = if radius > 0.0 {
            // Whole-box verification workload (Pendulum).
            builder.input_box().input_radius(radius)
        } else {
            builder.data_path(dir.join("data").join(format!("{name}_eval.json")))
        };
        reqs.push(builder.build()?);
    }
    let outcomes = session.run_all(&reqs)?;
    let rows: Vec<TableRow> = outcomes.iter().map(|o| o.table_row()).collect();
    if p.flag("markdown") {
        println!("{}", table1_markdown(&rows, p_star, -7));
    } else {
        println!("{}", table1_console(&rows, p_star));
    }
    Ok(())
}

fn cmd_tune(p: &rigor::cli::Parsed) -> anyhow::Result<()> {
    let session = Session::new();
    let req = AnalysisRequest::builder()
        .model_path(p.get("model").unwrap())
        .data_path(p.get("data").unwrap())
        .p_star(p.get_f64("p-star")?)
        .exact_inputs(p.flag("exact-inputs"))
        .build()?;
    let k_floor = p.get_usize("k-floor")? as u32;
    let Some((k0, _)) = session.certify_min_precision(&req, 8..=30)? else {
        anyhow::bail!("no uniform k in [8, 30] certifies at p* = {}", req.p_star());
    };
    println!("uniform certified baseline: k = {k0}");
    let model = session.load_model(Path::new(p.get("model").unwrap()))?;
    let tuned = session.tune_mixed(&req, k0, k_floor)?;
    println!("tuned per-layer formats (layer: type = k):");
    for (i, (layer, k)) in model.layers.iter().zip(&tuned.ks).enumerate() {
        println!("  {i:2}: {:<18} k = {k}", layer.type_name());
    }
    let saved: i64 = tuned.ks.iter().map(|&k| k0 as i64 - k as i64).sum();
    println!(
        "certified: {} | max abs {:.3e} | max rel {:.3e} | {} mantissa bits saved vs uniform",
        tuned.certified, tuned.max_abs, tuned.max_rel, saved
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_sweep(p: &rigor::cli::Parsed) -> anyhow::Result<()> {
    use rigor::data::Dataset;
    use rigor::runtime::Runtime;
    let dir = Path::new(p.get("artifacts").unwrap()).to_path_buf();
    let name = p.get("model").unwrap().to_string();
    let mut rt = Runtime::open(&dir)?;
    let data = Dataset::load(&dir.join("data").join(format!("{name}_eval.json")))?;
    println!("{:>4} {:>16} {:>16}", "k", "top-1 agreement", "max |dev|");
    for k in rt.precision_variants(&name) {
        let mut agree = 0;
        let mut max_dev = 0.0f32;
        for sample in &data.inputs {
            let s: Vec<f32> = sample.iter().map(|&v| v as f32).collect();
            let r = rt.run(&name, "f32", &s)?;
            let e = rt.run(&name, &format!("k{k}"), &s)?;
            let am = |xs: &[f32]| {
                xs.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0
            };
            if am(&r) == am(&e) {
                agree += 1;
            }
            for (a, b) in r.iter().zip(&e) {
                max_dev = max_dev.max((a - b).abs());
            }
        }
        println!("{k:>4} {:>13}/{:<3} {max_dev:>16.3e}", agree, data.len());
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_sweep(_p: &rigor::cli::Parsed) -> anyhow::Result<()> {
    anyhow::bail!(
        "the 'sweep' command executes AOT artifacts and needs the `pjrt` \
         feature: rebuild with `cargo build --features pjrt` (requires the \
         `xla` crate; see rust/Cargo.toml)"
    );
}

/// The multi-model serving demo: every model JSON is deployed into one
/// [`rigor::fleet::Fleet`] (content-hash versioned via the session cache),
/// then an interleaved load — one f64 and one emulated-k lane per model —
/// is pushed through the per-(model, format) queues and the per-queue
/// metrics are printed. Submission round-robins across the lanes so the
/// fair flusher has real multiplexing to do.
fn cmd_fleet(p: &rigor::cli::Parsed) -> anyhow::Result<()> {
    use rigor::fleet::FleetPolicy;
    use rigor::plan::ServeFormat;
    use std::time::Duration;

    let k = p.get_usize("k")? as u32;
    let reqs = p.get_usize("requests")?.max(1);
    let batch = p.get_usize("batch")?.max(1);
    let wait_ms = p.get_usize("max-wait-ms")? as u64;
    let session = session_from(p);
    let fleet = session.fleet_with(FleetPolicy {
        max_batch: batch,
        max_wait: Duration::from_millis(wait_ms),
        ..FleetPolicy::default()
    });

    // Deploy every model and build its two serving lanes.
    let mut lanes: Vec<(String, ServeFormat, usize)> = Vec::new();
    for path in p.get("models").unwrap().split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let path = Path::new(path);
        let id = path
            .file_stem()
            .and_then(|s| s.to_str())
            .ok_or_else(|| anyhow::anyhow!("bad model path {}", path.display()))?
            .to_string();
        let version = fleet.deploy_path(&id, path)?;
        let n: usize = session.load_model(path)?.input_shape.iter().product();
        println!("deployed {id} v{version} ({} inputs) from {}", n, path.display());
        lanes.push((id.clone(), ServeFormat::F64, n));
        lanes.push((id, ServeFormat::Emulated { k }, n));
    }

    let sw = rigor::util::Stopwatch::start();
    let mut tickets: Vec<Vec<rigor::serve::Ticket>> = lanes.iter().map(|_| Vec::new()).collect();
    for i in 0..reqs {
        for (lane, (id, fmt, n)) in lanes.iter().enumerate() {
            let sample: Vec<f64> = (0..*n).map(|j| ((i * n + j) % 17) as f64 / 17.0).collect();
            let t = fleet
                .submit_blocking(id, *fmt, sample)
                .map_err(|e| anyhow::anyhow!("admission: {e}"))?;
            tickets[lane].push(t);
        }
    }
    let mut served = 0usize;
    for lane in tickets {
        for t in lane {
            t.wait()?;
            served += 1;
        }
    }
    let secs = sw.secs();
    println!(
        "\nserved {served} tickets across {} queues in {secs:.3} s ({:.0} tickets/s)",
        lanes.len(),
        served as f64 / secs.max(1e-9)
    );

    // The unified observability snapshot replaces the old ad-hoc
    // per-queue printout: same counters, plus the coordinator pool and
    // the registry's latency histograms / executor gauges.
    let snap = fleet.snapshot();
    let mut obs_snap = rigor::obs::Snapshot::capture().with_pool(snap.pool).with_fleet(
        rigor::obs::FleetStat {
            models: snap.models.len(),
            total_pending: snap.total_pending,
            swaps: snap.swaps,
            rejected: snap.rejected,
            quarantined: snap.quarantined,
        },
    );
    for q in &snap.queues {
        obs_snap = obs_snap.with_queue(
            format!("{}/{}", q.key.model, q.key.format),
            q.depth,
            q.metrics,
        );
    }
    print!("{}", obs_snap.to_text());
    Ok(())
}

/// Print the canonical textual IR of the plan the engine would serve
/// for a model JSON: buffer liveness, steps with hazard edges and
/// lowering choices, and the memory report — the exact text the golden
/// snapshot suite (`rust/tests/golden/`) pins.
fn cmd_plan(p: &rigor::cli::Parsed) -> anyhow::Result<()> {
    use rigor::plan::{KernelPath, Plan, ServeFormat};
    let path = p.positionals.first().ok_or_else(|| {
        anyhow::anyhow!(
            "usage: rigor plan <model.json> [--format f64|emu-k<k>] [--kernels blocked|scalar]"
        )
    })?;
    let format: ServeFormat = p.get("format").unwrap().parse()?;
    let kernels = match p.get("kernels").unwrap() {
        "blocked" => KernelPath::Blocked,
        "scalar" => KernelPath::Scalar,
        other => anyhow::bail!("unknown --kernels '{other}' (blocked | scalar)"),
    };
    let session = Session::new();
    let model = session.load_model(Path::new(path))?;
    let plan = Plan::for_format_with_kernels(&model, format, kernels)?;
    print!("{}", plan.to_text());
    Ok(())
}

/// Resolve `--model <path>` (through the session cache) or `--zoo <name>`
/// (built-in generator) into a model, path winning when both are set.
fn model_arg(p: &rigor::cli::Parsed) -> anyhow::Result<std::sync::Arc<rigor::model::Model>> {
    use rigor::model::zoo;
    let path = p.get("model").unwrap_or("");
    if !path.is_empty() {
        return Session::new().load_model(Path::new(path));
    }
    let name = p.get("zoo").unwrap_or("");
    Ok(std::sync::Arc::new(match name {
        "tiny_mlp" => zoo::tiny_mlp(7),
        "tiny_cnn" => zoo::tiny_cnn(5),
        "avgpool_cnn" => zoo::avgpool_cnn(5),
        "tiny_pendulum" => zoo::tiny_pendulum(3),
        "residual_mlp" => zoo::residual_mlp(9),
        "residual_cnn" => zoo::residual_cnn(5),
        other => anyhow::bail!(
            "unknown zoo model '{other}' (tiny_mlp | tiny_cnn | avgpool_cnn | \
             tiny_pendulum | residual_mlp | residual_cnn)"
        ),
    }))
}

/// Serve a synthetic load through a micro-batcher under the requested
/// [`rigor::obs::ObsPolicy`] and print the unified snapshot — the
/// runtime window into the registry `rigor fleet` also reports through.
/// `--trace full --trace-out <path>` additionally writes the run's
/// Chrome-trace JSON (request/flush/drive/wave/step spans).
fn cmd_stats(p: &rigor::cli::Parsed) -> anyhow::Result<()> {
    use rigor::coordinator::Pool;
    use rigor::obs::{self, ObsPolicy, Snapshot, TraceSink};
    use rigor::plan::Plan;
    use rigor::serve::{BatchPolicy, MicroBatcher};
    use std::sync::Arc;

    let policy: ObsPolicy = p.get("trace").unwrap().parse()?;
    obs::set_policy(policy);
    let trace_out = p.get("trace-out").unwrap_or("").to_string();
    if !trace_out.is_empty() && policy != ObsPolicy::Full {
        anyhow::bail!("--trace-out needs --trace full (no spans are recorded otherwise)");
    }

    let model = model_arg(p)?;
    let plan = Arc::new(Plan::for_reference(&model)?);
    let workers = match p.get_usize("workers")? {
        0 => std::thread::available_parallelism().map_or(2, |n| n.get()),
        w => w,
    };
    let pool = Arc::new(Pool::new(workers, 64));
    let reqs = p.get_usize("requests")?.max(1);
    let batch = p.get_usize("batch")?.max(1);
    let n = plan.input_len();
    let batcher = MicroBatcher::new(
        Arc::clone(&plan),
        Arc::clone(&pool),
        BatchPolicy { max_batch: batch, ..BatchPolicy::default() },
    );
    let tickets: Vec<rigor::serve::Ticket> = (0..reqs)
        .map(|i| {
            let sample: Vec<f64> = (0..n).map(|j| ((i * n + j) % 17) as f64 / 17.0).collect();
            batcher.submit(sample)
        })
        .collect::<anyhow::Result<_>>()?;
    for t in tickets {
        t.wait()?;
    }

    let snap = Snapshot::capture()
        .with_pool(pool.metrics())
        .with_queue(plan.model_name(), batcher.pending(), batcher.metrics());
    if p.flag("json") {
        println!("{}", rigor::json::to_string_pretty(&snap.to_json()));
    } else {
        print!("{}", snap.to_text());
    }
    if !trace_out.is_empty() {
        std::fs::write(&trace_out, TraceSink::export())?;
        println!("wrote Chrome trace to {trace_out}");
    }
    Ok(())
}

/// One CAA pass with the per-step bound probe: prints each step's max
/// absolute/relative bound width (units of u) next to its wall-clock
/// cost — the paper's per-layer profile, where conv steps widen the
/// relative bound and well-conditioned activations re-contract it. Uses
/// the **unfused** plan so activation steps get their own rows.
fn cmd_profile(p: &rigor::cli::Parsed) -> anyhow::Result<()> {
    use rigor::analysis::{bound_profile_with_plan, AnalysisConfig};
    use rigor::caa::Ctx;
    use rigor::plan::Plan;

    let model = model_arg(p)?;
    let plan = Plan::unfused(&model)?;
    let u_log2 = p.get_usize("u-max-log2")? as i32;
    let cfg = AnalysisConfig {
        ctx: Ctx::with_u_max(2f64.powi(-u_log2)),
        input_radius: p.get_f64("radius")?,
        ..AnalysisConfig::default()
    };
    let n = plan.input_len();
    let sample: Vec<f64> = (0..n).map(|j| (j % 17) as f64 / 17.0).collect();
    let profile = bound_profile_with_plan(&plan, &cfg, &sample)?;
    if p.flag("json") {
        let snap = rigor::obs::Snapshot::capture();
        println!("{}", rigor::json::to_string_pretty(&snap.to_json()));
        return Ok(());
    }
    println!(
        "bound profile: {} (u_max = 2^-{u_log2}, {} steps)",
        profile.model,
        profile.steps.len()
    );
    println!("{:>4} {:<18} {:>9} {:>12} {:>12} {:>6} {:>10}", "step", "kind", "out", "abs_u", "rel_u", "Δrel", "time");
    let mut prev_rel = f64::NAN;
    for s in &profile.steps {
        let trend = if !prev_rel.is_finite() || !s.rel_u.is_finite() {
            "  —"
        } else if s.rel_u > prev_rel {
            "  ↑" // widening (conv/dense accumulation)
        } else if s.rel_u < prev_rel {
            "  ↓" // re-contracting (well-conditioned activation)
        } else {
            "  ="
        };
        println!(
            "{:>4} {:<18} {:>9} {:>12.3e} {:>12.3e} {:>6} {:>8.1}µs",
            s.index,
            s.kind,
            s.out_len,
            s.abs_u,
            s.rel_u,
            trend,
            s.secs * 1e6
        );
        prev_rel = s.rel_u;
    }
    Ok(())
}

fn cmd_run(p: &rigor::cli::Parsed) -> anyhow::Result<()> {
    if p.get("variant") == Some("engine") {
        cmd_run_engine(p)
    } else {
        cmd_run_artifact(p)
    }
}

/// The engine run path: load the model JSON through the session cache and
/// serve inputs through the micro-batcher — `--batch N` sizes the
/// micro-batches (each one batched plan drive on the session pool),
/// `--data` serves a whole dataset in bulk. Works without `pjrt`.
fn cmd_run_engine(p: &rigor::cli::Parsed) -> anyhow::Result<()> {
    use rigor::data::Dataset;
    let dir = Path::new(p.get("artifacts").unwrap());
    let model_path = dir.join("models").join(format!("{}.json", p.get("model").unwrap()));
    let batch = p.get_usize("batch")?.max(1);
    let session = Session::new();
    let req = AnalysisRequest::builder()
        .model_path(&model_path)
        .input_box() // serving traffic needs no dataset reference
        .max_batch(batch)
        .max_wait_ms(2)
        .build()?;
    let batcher = session.serve(&req)?;

    let data_path = p.get("data").unwrap_or("");
    if data_path.is_empty() {
        let input: Vec<f64> = p
            .get("input")
            .unwrap()
            .split(',')
            .map(|s| s.trim().parse::<f64>())
            .collect::<Result<_, _>>()
            .map_err(|e| anyhow::anyhow!("bad --input: {e}"))?;
        let out = batcher.submit(input)?.wait()?;
        println!("{out:?}");
        return Ok(());
    }

    let data = Dataset::load(Path::new(data_path))?;
    let sw = rigor::util::Stopwatch::start();
    let tickets: Vec<_> = data
        .inputs
        .iter()
        .map(|s| batcher.submit(s.clone()))
        .collect::<anyhow::Result<_>>()?;
    let outputs: Vec<Vec<f64>> = tickets
        .into_iter()
        .map(|t| t.wait())
        .collect::<anyhow::Result<_>>()?;
    let secs = sw.secs();
    println!(
        "served {} samples in {secs:.3} s ({:.0} samples/s) in micro-batches of <= {batch}",
        outputs.len(),
        outputs.len() as f64 / secs.max(1e-9)
    );
    let m = batcher.metrics();
    println!(
        "micro-batches: {} ({} flushed full, {} by timer; largest {})",
        m.batches, m.flushed_full, m.flushed_timer, m.max_batch_observed
    );
    for (i, out) in outputs.iter().take(3).enumerate() {
        println!("  sample {i}: {out:?}");
    }
    if outputs.len() > 3 {
        println!("  ... ({} more)", outputs.len() - 3);
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_run_artifact(p: &rigor::cli::Parsed) -> anyhow::Result<()> {
    use rigor::runtime::Runtime;
    let dir = Path::new(p.get("artifacts").unwrap()).to_path_buf();
    let mut rt = Runtime::open(&dir)?;
    let input: Vec<f32> = p
        .get("input")
        .unwrap()
        .split(',')
        .map(|s| s.trim().parse::<f32>())
        .collect::<Result<_, _>>()
        .map_err(|e| anyhow::anyhow!("bad --input: {e}"))?;
    let out = rt.run(p.get("model").unwrap(), p.get("variant").unwrap(), &input)?;
    println!("{out:?}");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_run_artifact(_p: &rigor::cli::Parsed) -> anyhow::Result<()> {
    anyhow::bail!(
        "non-engine 'run' variants execute AOT artifacts and need the `pjrt` \
         feature: rebuild with `cargo build --features pjrt` (requires the \
         `xla` crate; see rust/Cargo.toml), or use `--variant engine`"
    );
}
