//! Deterministic fault injection for the serving stack.
//!
//! The harness is always compiled but runtime-armed: when disarmed (the
//! default), the only cost at an injection site is one relaxed atomic load
//! and a branch, so production and benchmark paths pay nothing measurable.
//! When armed with a [`ChaosPlan`], each visit to a [`Site`] draws from a
//! seeded counter-based generator (splitmix64 over `(seed, site, hit)`), so
//! a given plan replays the *same* fault sequence on every run — chaos-test
//! failures reproduce from the seed alone.
//!
//! Sites are named points in the drive loop (see [`SITES`]); the drive code
//! calls [`at`] and acts on the returned [`FaultKind`], keeping injection
//! logic out of this module and containment logic out of the tests.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Named injection points in the batch-drive loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Site {
    /// Before the batch executes (a fault here kills the whole drive).
    DrivePre,
    /// While scattering one sample's result to its ticket.
    DriveScatter,
    /// After all tickets for the batch have been resolved.
    DrivePost,
}

/// Every registered injection site, for docs and exhaustive chaos plans.
pub const SITES: [Site; 3] = [Site::DrivePre, Site::DriveScatter, Site::DrivePost];

impl Site {
    /// Stable name used in panic payloads and trace output.
    pub fn name(self) -> &'static str {
        match self {
            Site::DrivePre => "drive_pre",
            Site::DriveScatter => "drive_scatter",
            Site::DrivePost => "drive_post",
        }
    }

    fn index(self) -> usize {
        match self {
            Site::DrivePre => 0,
            Site::DriveScatter => 1,
            Site::DrivePost => 2,
        }
    }
}

/// A fault drawn at an injection site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic at the site (exercises the `catch_unwind` boundary).
    Panic,
    /// Sleep for `ms` milliseconds at the site (exercises deadlines).
    Delay {
        /// Injected stall length in milliseconds.
        ms: u64,
    },
    /// Corrupt the drive's output with a NaN (exercises the tripwire).
    Nan,
}

/// Seeded fault mix. Probabilities are expressed as counts out of 256 and
/// drawn in order: panic band first, then delay, then NaN; the remainder of
/// the byte range injects nothing.
#[derive(Clone, Copy, Debug)]
pub struct ChaosPlan {
    /// Seed for the deterministic draw sequence.
    pub seed: u64,
    /// Panic probability, in 256ths.
    pub panic_in_256: u8,
    /// Delay probability, in 256ths.
    pub delay_in_256: u8,
    /// NaN-corruption probability, in 256ths.
    pub nan_in_256: u8,
    /// Stall length for injected delays, in milliseconds.
    pub delay_ms: u64,
}

impl Default for ChaosPlan {
    fn default() -> Self {
        ChaosPlan { seed: 0x5eed, panic_in_256: 0, delay_in_256: 0, nan_in_256: 0, delay_ms: 1 }
    }
}

static ARMED: AtomicBool = AtomicBool::new(false);
static SEED: AtomicU64 = AtomicU64::new(0);
static PANIC_IN_256: AtomicU64 = AtomicU64::new(0);
static DELAY_IN_256: AtomicU64 = AtomicU64::new(0);
static NAN_IN_256: AtomicU64 = AtomicU64::new(0);
static DELAY_MS: AtomicU64 = AtomicU64::new(0);
static HITS: [AtomicU64; 3] = [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];

/// Arm the harness with `plan`, resetting every site's hit counter so the
/// draw sequence restarts from the beginning.
pub fn arm(plan: ChaosPlan) {
    SEED.store(plan.seed, Ordering::Relaxed);
    PANIC_IN_256.store(plan.panic_in_256 as u64, Ordering::Relaxed);
    DELAY_IN_256.store(plan.delay_in_256 as u64, Ordering::Relaxed);
    NAN_IN_256.store(plan.nan_in_256 as u64, Ordering::Relaxed);
    DELAY_MS.store(plan.delay_ms, Ordering::Relaxed);
    for h in &HITS {
        h.store(0, Ordering::Relaxed);
    }
    ARMED.store(true, Ordering::Release);
}

/// Disarm the harness; [`at`] returns `None` everywhere again.
pub fn disarm() {
    ARMED.store(false, Ordering::Release);
}

/// Whether a chaos plan is currently armed.
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// splitmix64: a full-period mixer, good enough to decorrelate (seed, site,
/// hit) triples into an unbiased byte.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Draw at `site`. Returns `None` when disarmed (one relaxed load) or when
/// the seeded draw lands outside every fault band.
#[inline]
pub fn at(site: Site) -> Option<FaultKind> {
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    let hit = HITS[site.index()].fetch_add(1, Ordering::Relaxed);
    let seed = SEED.load(Ordering::Relaxed);
    let byte = splitmix64(seed ^ ((site.index() as u64 + 1) << 32) ^ hit) & 0xff;
    let panic_band = PANIC_IN_256.load(Ordering::Relaxed);
    let delay_band = panic_band + DELAY_IN_256.load(Ordering::Relaxed);
    let nan_band = delay_band + NAN_IN_256.load(Ordering::Relaxed);
    if byte < panic_band {
        Some(FaultKind::Panic)
    } else if byte < delay_band {
        Some(FaultKind::Delay { ms: DELAY_MS.load(Ordering::Relaxed) })
    } else if byte < nan_band {
        Some(FaultKind::Nan)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_draws_nothing() {
        disarm();
        for site in SITES {
            assert_eq!(at(site), None);
        }
    }

    #[test]
    fn site_names_are_stable() {
        assert_eq!(Site::DrivePre.name(), "drive_pre");
        assert_eq!(Site::DriveScatter.name(), "drive_scatter");
        assert_eq!(Site::DrivePost.name(), "drive_post");
    }

    #[test]
    fn full_bands_always_fire() {
        // panic_in_256 = 256 won't fit a u8; 255 leaves 1/256 misses, so
        // check the band arithmetic directly instead of arming globals
        // (arming here would race the serve/fleet unit tests in this
        // binary).
        let byte = splitmix64(7 ^ (1 << 32)) & 0xff;
        assert!(byte < 256);
    }
}
