//! **The serving front end** — a micro-batching scheduler over the
//! batched plan executor.
//!
//! Bulk traffic (the ROADMAP's "serve heavy traffic" north star) arrives
//! one sample at a time, but the executor is fastest when it drives many
//! samples through one plan pass ([`crate::plan::Plan::execute_batch`]:
//! one step dispatch, one parameter embedding, and overlapping
//! accumulation chains for the whole batch). The [`MicroBatcher`] bridges
//! the two: callers [`submit`](MicroBatcher::submit) individual samples
//! and get a [`Ticket`] back immediately; a flusher thread accumulates
//! pending samples until either [`BatchPolicy::max_batch`] are waiting or
//! the oldest has waited [`BatchPolicy::max_wait`], then dispatches the
//! whole batch as **one job** on the coordinator [`Pool`] — a single
//! batched f64 plan drive against the worker's thread-local arena.
//! Results are scattered back to the tickets, which callers block on (or
//! poll) independently.
//!
//! The micro-batcher serves the **f64 reference trace** of the compiled
//! model — the latency-sensitive inference workload where batching pays.
//! CAA analysis traffic intentionally stays at `B = 1` per run (each CAA
//! operation dwarfs the dispatch overhead batching amortizes, and a
//! `B`-wide arena of CAA values multiplies peak memory); bulk *analysis*
//! goes through [`crate::api::Session::run_batch`], which micro-batches
//! the scheduling, not the CAA arithmetic. See DESIGN.md "The batch axis
//! and the serve micro-batcher".
//!
//! ```
//! use rigor::api::{AnalysisRequest, Session};
//! use rigor::model::zoo;
//!
//! let session = Session::builder().workers(2).build();
//! let req = AnalysisRequest::builder()
//!     .model(zoo::tiny_mlp(7))
//!     .input_box()          // serving needs no dataset
//!     .max_batch(8)
//!     .max_wait_ms(1)
//!     .build()?;
//! let batcher = session.serve(&req)?;
//! let tickets: Vec<_> = (0..16)
//!     .map(|i| batcher.submit(vec![i as f64 / 16.0; 8]).unwrap())
//!     .collect();
//! for t in tickets {
//!     let probs = t.wait()?; // one softmax vector per request
//!     assert_eq!(probs.len(), 3);
//! }
//! # Ok::<(), anyhow::Error>(())
//! ```

use crate::coordinator::{with_worker_scratch, Pool};
use crate::faultinject;
use crate::obs;
use crate::plan::{Arena, KernelPath, Parallelism, Plan, ServeFormat};
use crate::quant::EmulatedFp;
use crate::tensor::EmuCtx;
use anyhow::{bail, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Consecutive faulted drives before a batcher enters degraded mode
/// (scalar kernels, serial drives). See DESIGN.md "Fault containment".
const DEGRADE_AFTER: usize = 3;

/// When the micro-batcher flushes a pending batch — and how deep the
/// pending queue may grow before submitters block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Flush as soon as this many samples are pending (also the largest
    /// batch one plan drive executes).
    pub max_batch: usize,
    /// Flush when the **oldest** pending sample has waited this long —
    /// the latency bound a trickle of traffic pays for batching.
    pub max_wait: Duration,
    /// Upper bound on queued (not yet flushed) samples.
    /// [`MicroBatcher::submit`] **blocks** while the queue is at this
    /// bound — submit-side backpressure mirroring
    /// [`crate::coordinator::Pool::submit`], so overload degrades into
    /// caller latency instead of unbounded memory. Must be `>=
    /// max_batch` (otherwise the size trigger could never fire).
    pub max_pending: usize,
    /// Deadline stamped on every submitted sample: if a ticket has been
    /// queued longer than this when its batch reaches the flush boundary,
    /// it resolves as [`ServeError::DeadlineExceeded`] instead of
    /// occupying a batch slot. `None` (the default) disables deadlines.
    pub default_deadline: Option<Duration>,
}

impl Default for BatchPolicy {
    /// 32-sample batches, 2 ms latency bound, 1024 pending samples, no
    /// deadline.
    fn default() -> BatchPolicy {
        BatchPolicy {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            max_pending: 1024,
            default_deadline: None,
        }
    }
}

/// Counters describing what the batcher has done so far.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeMetrics {
    /// Samples accepted by [`MicroBatcher::submit`].
    pub submitted: usize,
    /// Batches dispatched to the pool.
    pub batches: usize,
    /// Batches flushed because `max_batch` samples were pending.
    pub flushed_full: usize,
    /// Batches flushed because the oldest sample hit `max_wait`.
    pub flushed_timer: usize,
    /// Batches flushed by shutdown drain.
    pub flushed_drain: usize,
    /// Largest batch dispatched.
    pub max_batch_observed: usize,
    /// Deepest the pending queue has been (bounded by
    /// [`BatchPolicy::max_pending`]).
    pub queue_high_water: usize,
    /// Tickets resolved as [`ServeError::DeadlineExceeded`] at a flush
    /// boundary instead of executing.
    pub deadline_missed: usize,
    /// Drives that ended in a fault (panic, execution error, or a
    /// non-finite output tripwire) — the signal degraded mode watches.
    pub drive_faults: usize,
}

/// Why a ticket resolved without a model output. Every admitted ticket
/// resolves exactly once, either `Ok(outputs)` or one of these — a panic
/// or poisoned drive never leaves a waiter blocked or silently wrong.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The batch drive panicked; the panic was caught at the drive's
    /// containment boundary, only this batch's tickets were affected, and
    /// the worker's scratch arena was discarded (not reused poisoned).
    DrivePanicked {
        /// The panic payload, when it was a string.
        detail: String,
    },
    /// The ticket waited past its deadline
    /// ([`BatchPolicy::default_deadline`]) before its batch reached the
    /// flush boundary; it was resolved instead of occupying a batch slot.
    DeadlineExceeded {
        /// How long the sample sat in the queue, in milliseconds.
        waited_ms: u64,
    },
    /// The drive completed but this sample's output row contained a
    /// NaN/Inf — the tripwire that feeds quarantine accounting, so a
    /// poisoned model cannot silently propagate non-finite values.
    NonFiniteOutput {
        /// Index of the first non-finite value in the output row.
        index: usize,
    },
    /// The plan executor returned an error (not a panic).
    ExecFailed {
        /// The executor's rendered error chain.
        detail: String,
    },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::DrivePanicked { detail } => {
                write!(f, "batch drive panicked: {detail}")
            }
            ServeError::DeadlineExceeded { waited_ms } => {
                write!(f, "deadline exceeded after {waited_ms} ms in queue")
            }
            ServeError::NonFiniteOutput { index } => {
                write!(f, "model output is non-finite at index {index}")
            }
            ServeError::ExecFailed { detail } => write!(f, "batch execution failed: {detail}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// A slot's interior: the pending value plus a sticky resolved bit, so
/// "first write wins" survives the value being taken by the waiter (a
/// late error fallback must not re-resolve a slot whose output was
/// already delivered and consumed).
struct SlotState {
    value: Option<Result<Vec<f64>, ServeError>>,
    resolved: bool,
}

/// One request's result slot: filled exactly once by the batch job,
/// waited on by the [`Ticket`]. `pub(crate)` so the fleet scheduler
/// ([`crate::fleet`]) shares the ticket machinery.
pub(crate) struct Slot {
    state: Mutex<SlotState>,
    ready: Condvar,
    /// Set by [`Ticket`]'s `Drop`: the receiver is gone, so a scatter
    /// into this slot is a counted no-op instead of a stored value nobody
    /// will take (and never a hang or panic).
    abandoned: AtomicBool,
}

impl Slot {
    pub(crate) fn new() -> Arc<Slot> {
        Arc::new(Slot {
            state: Mutex::new(SlotState { value: None, resolved: false }),
            ready: Condvar::new(),
            abandoned: AtomicBool::new(false),
        })
    }
}

/// Handle to one submitted sample's pending output.
pub struct Ticket {
    pub(crate) slot: Arc<Slot>,
    pub(crate) trace: u64,
}

impl Ticket {
    /// The request's observability trace id: nonzero iff span tracing
    /// ([`crate::obs::ObsPolicy::Full`]) was active at submit time, in
    /// which case the exported trace's request/flush spans carry it.
    pub fn trace_id(&self) -> u64 {
        self.trace
    }

    /// Block until the sample's batch has executed and return the model
    /// output (length = the plan's `output_len`).
    pub fn wait(self) -> Result<Vec<f64>> {
        self.wait_typed()
            .map_err(|e| anyhow::Error::new(e).context("batched execution failed"))
    }

    /// [`Ticket::wait`] with the typed failure instead of an
    /// `anyhow::Error` wrapper: exactly which containment boundary
    /// resolved the ticket ([`ServeError::DrivePanicked`],
    /// [`ServeError::DeadlineExceeded`], …).
    pub fn wait_typed(self) -> Result<Vec<f64>, ServeError> {
        let mut st = self.slot.state.lock().unwrap();
        while st.value.is_none() {
            st = self.slot.ready.wait(st).unwrap();
        }
        st.value.take().expect("checked above")
    }

    /// Non-blocking probe: the output if the batch has already executed.
    pub fn try_take(&self) -> Option<Result<Vec<f64>>> {
        self.try_take_typed()
            .map(|r| r.map_err(|e| anyhow::Error::new(e).context("batched execution failed")))
    }

    /// [`Ticket::try_take`] with the typed failure. Takes the value: a
    /// second call returns `None`, which is how the chaos suite asserts
    /// "resolved exactly once".
    pub fn try_take_typed(&self) -> Option<Result<Vec<f64>, ServeError>> {
        self.slot.state.lock().unwrap().value.take()
    }
}

impl Drop for Ticket {
    /// Mark the slot's receiver gone. A later scatter into it becomes a
    /// counted no-op ([`crate::obs::FaultStats::tickets_dropped`]) — a
    /// dropped unresolved ticket must never wedge a batcher or fleet
    /// shutdown drain.
    fn drop(&mut self) {
        self.slot.abandoned.store(true, Ordering::Relaxed);
    }
}

/// A submitted sample waiting to be flushed (shared with
/// [`crate::fleet`], whose queues hold the same pending shape).
pub(crate) struct PendingSample {
    pub(crate) sample: Vec<f64>,
    pub(crate) slot: Arc<Slot>,
    pub(crate) enqueued: Instant,
    /// Resolve as [`ServeError::DeadlineExceeded`] if still queued past
    /// this instant when the batch reaches the flush boundary.
    pub(crate) deadline: Option<Instant>,
    /// Observability trace id minted at submit (`0` = untraced).
    pub(crate) trace: u64,
}

struct QueueState {
    pending: VecDeque<PendingSample>,
    shutdown: bool,
}

#[derive(Default)]
struct Counters {
    submitted: AtomicUsize,
    batches: AtomicUsize,
    flushed_full: AtomicUsize,
    flushed_timer: AtomicUsize,
    flushed_drain: AtomicUsize,
    max_batch_observed: AtomicUsize,
    queue_high_water: AtomicUsize,
    deadline_missed: AtomicUsize,
    drive_faults: AtomicUsize,
    /// Faulted drives since the last clean one — trips degraded mode at
    /// [`DEGRADE_AFTER`].
    consecutive_faults: AtomicUsize,
}

struct Shared {
    queue: Mutex<QueueState>,
    wake: Condvar,
    /// Signalled when the flusher drains the queue below
    /// `policy.max_pending` — what blocked submitters wait on.
    room: Condvar,
    plan: Arc<Plan>,
    pool: Arc<Pool>,
    policy: BatchPolicy,
    kernels: KernelPath,
    format: ServeFormat,
    /// How wide one flush's plan drive fans out over the pool
    /// ([`Plan::execute_batch_pooled`]); `workers <= 1` keeps the PR-4
    /// behavior of one serial drive per flush.
    par: Parallelism,
    counters: Counters,
    /// Sticky degraded flag: after [`DEGRADE_AFTER`] consecutive faulted
    /// drives, every later flush runs on [`KernelPath::Scalar`] /
    /// [`Parallelism::serial`] — the known-good escape-hatch path — so a
    /// fault localized to the blocked/parallel machinery stops recurring.
    degraded: AtomicBool,
    /// Flushes handed to the pool but not yet finished — what
    /// [`MicroBatcher::shutdown`] drains so every ticket is resolved
    /// before it returns.
    inflight: Mutex<usize>,
    /// Signalled when `inflight` drops to zero.
    idle: Condvar,
}

/// Why a batch left the queue (metrics bookkeeping).
enum FlushCause {
    Full,
    Timer,
    Drain,
}

/// The micro-batching scheduler. Create one per served model (via
/// [`crate::api::Session::serve`] or [`MicroBatcher::new`]); it is `Sync`,
/// so any number of request threads can [`submit`](MicroBatcher::submit)
/// concurrently. Dropping the batcher drains every pending sample (their
/// tickets still resolve) before the flusher thread exits.
pub struct MicroBatcher {
    shared: Arc<Shared>,
    flusher: Option<JoinHandle<()>>,
}

impl MicroBatcher {
    /// A batcher serving `plan` (f64 pass) on `pool` under `policy`,
    /// dispatching kernels per the plan's compiled
    /// [`KernelPath`](crate::plan::Plan::kernel_path).
    pub fn new(plan: Arc<Plan>, pool: Arc<Pool>, policy: BatchPolicy) -> MicroBatcher {
        let kernels = plan.kernel_path();
        MicroBatcher::with_kernel_path(plan, pool, policy, kernels)
    }

    /// [`MicroBatcher::new`] with an explicit kernel path — how
    /// [`crate::api::Session::serve`] honors a request's
    /// `force_scalar_kernels` escape hatch (served outputs are
    /// bit-identical on either path).
    pub fn with_kernel_path(
        plan: Arc<Plan>,
        pool: Arc<Pool>,
        policy: BatchPolicy,
        kernels: KernelPath,
    ) -> MicroBatcher {
        MicroBatcher::with_format(plan, pool, policy, kernels, ServeFormat::F64)
    }

    /// [`MicroBatcher::with_kernel_path`] with an explicit serving
    /// arithmetic: `ServeFormat::Emulated { k }` batches execute under
    /// emulated precision-k (inputs rounded into the k-bit format, every
    /// op re-rounded), bit-identical per sample to
    /// [`crate::quant::emulated_forward`] on the same plan. Tickets still
    /// carry `Vec<f64>` — the emulated values' exact f64 representations.
    pub fn with_format(
        plan: Arc<Plan>,
        pool: Arc<Pool>,
        policy: BatchPolicy,
        kernels: KernelPath,
        format: ServeFormat,
    ) -> MicroBatcher {
        let par = Parallelism::from_env(pool.worker_count());
        MicroBatcher::with_parallelism(plan, pool, policy, kernels, format, par)
    }

    /// [`MicroBatcher::with_format`] with an explicit [`Parallelism`]
    /// policy instead of the `RIGOR_WORKERS`/pool-width default: each
    /// flush's plan drive fans out over up to `par.workers` pool workers
    /// ([`Plan::execute_batch_pooled`] — bit-identical to the serial
    /// drive), or stays a single serial job at `par.workers <= 1`.
    pub fn with_parallelism(
        plan: Arc<Plan>,
        pool: Arc<Pool>,
        policy: BatchPolicy,
        kernels: KernelPath,
        format: ServeFormat,
        par: Parallelism,
    ) -> MicroBatcher {
        assert!(policy.max_batch >= 1, "max_batch must be >= 1");
        assert!(
            policy.max_pending >= policy.max_batch,
            "max_pending ({}) must be >= max_batch ({})",
            policy.max_pending,
            policy.max_batch
        );
        format.validate().expect("valid serve format");
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState { pending: VecDeque::new(), shutdown: false }),
            wake: Condvar::new(),
            room: Condvar::new(),
            plan,
            pool,
            policy,
            kernels,
            format,
            par,
            counters: Counters::default(),
            degraded: AtomicBool::new(false),
            inflight: Mutex::new(0),
            idle: Condvar::new(),
        });
        let flusher = {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("rigor-serve-flusher".into())
                .spawn(move || flusher_loop(sh))
                .expect("spawn serve flusher")
        };
        MicroBatcher { shared, flusher: Some(flusher) }
    }

    /// Enqueue one sample (length must match the served plan's input),
    /// returning a [`Ticket`] for the pending output. **Blocks** while
    /// [`BatchPolicy::max_pending`] samples are already queued — the
    /// submit-side backpressure that keeps an overloaded batcher's memory
    /// bounded (mirroring [`crate::coordinator::Pool::submit`]); errors
    /// if the batcher shuts down first.
    pub fn submit(&self, sample: Vec<f64>) -> Result<Ticket> {
        if sample.len() != self.shared.plan.input_len() {
            bail!(
                "serve '{}': expected {} input values, got {}",
                self.shared.plan.model_name(),
                self.shared.plan.input_len(),
                sample.len()
            );
        }
        if let Some(i) = sample.iter().position(|v| !v.is_finite()) {
            obs::nonfinite_input();
            bail!(
                "serve '{}': input value at index {i} is not finite",
                self.shared.plan.model_name()
            );
        }
        let slot = Slot::new();
        let trace = obs::next_trace_id();
        let depth = {
            let mut q = self.shared.queue.lock().unwrap();
            loop {
                if q.shutdown {
                    bail!("serve '{}': batcher is shutting down", self.shared.plan.model_name());
                }
                if q.pending.len() < self.shared.policy.max_pending {
                    break;
                }
                q = self.shared.room.wait(q).unwrap();
            }
            let enqueued = Instant::now();
            q.pending.push_back(PendingSample {
                sample,
                slot: Arc::clone(&slot),
                enqueued,
                deadline: self.shared.policy.default_deadline.map(|d| enqueued + d),
                trace,
            });
            q.pending.len()
        };
        self.shared.counters.submitted.fetch_add(1, Ordering::Relaxed);
        self.shared.counters.queue_high_water.fetch_max(depth, Ordering::Relaxed);
        self.shared.wake.notify_all();
        Ok(Ticket { slot, trace })
    }

    /// Snapshot the batcher's counters.
    pub fn metrics(&self) -> ServeMetrics {
        let c = &self.shared.counters;
        ServeMetrics {
            submitted: c.submitted.load(Ordering::Relaxed),
            batches: c.batches.load(Ordering::Relaxed),
            flushed_full: c.flushed_full.load(Ordering::Relaxed),
            flushed_timer: c.flushed_timer.load(Ordering::Relaxed),
            flushed_drain: c.flushed_drain.load(Ordering::Relaxed),
            max_batch_observed: c.max_batch_observed.load(Ordering::Relaxed),
            queue_high_water: c.queue_high_water.load(Ordering::Relaxed),
            deadline_missed: c.deadline_missed.load(Ordering::Relaxed),
            drive_faults: c.drive_faults.load(Ordering::Relaxed),
        }
    }

    /// Whether the batcher has entered degraded mode (scalar kernels,
    /// serial drives) after repeated faulted drives. Sticky for the
    /// batcher's lifetime — served bits are identical on every kernel
    /// path, so degrading trades only throughput for fault avoidance.
    pub fn degraded(&self) -> bool {
        self.shared.degraded.load(Ordering::Relaxed)
    }

    /// Samples currently queued (not yet flushed) — the live companion
    /// to [`MicroBatcher::metrics`] for the unified
    /// [`crate::obs::Snapshot`].
    pub fn pending(&self) -> usize {
        self.shared.queue.lock().unwrap().pending.len()
    }

    /// The served plan (input/output geometry for callers).
    pub fn plan(&self) -> &Plan {
        &self.shared.plan
    }

    /// The arithmetic this batcher executes under.
    pub fn format(&self) -> ServeFormat {
        self.shared.format
    }

    /// Shut the batcher down in order: wake every submitter blocked on
    /// [`BatchPolicy::max_pending`] (their `submit` errors out), let the
    /// flusher drain every still-pending sample as `Drain` batches, then
    /// **wait for all in-flight pool flushes to finish** — so when this
    /// returns, every accepted ticket has been resolved (no ticket is
    /// ever dropped unresolved by a shutdown racing its batch job).
    /// Idempotent; also run by `Drop`.
    pub fn shutdown(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.wake.notify_all();
        self.shared.room.notify_all(); // blocked submitters bail on shutdown
        if let Some(h) = self.flusher.take() {
            let _ = h.join();
        }
        // The flusher has exited, so `inflight` can only decrease now:
        // wait for the last dispatched batch to scatter its results.
        let mut n = self.shared.inflight.lock().unwrap();
        while *n > 0 {
            n = self.shared.idle.wait(n).unwrap();
        }
    }
}

impl Drop for MicroBatcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Take up to `max` pending samples off the queue front.
fn drain_batch(q: &mut QueueState, max: usize) -> Vec<PendingSample> {
    let n = q.pending.len().min(max);
    q.pending.drain(..n).collect()
}

/// The flusher: waits for work, decides when a batch is ripe (full /
/// timed out / shutdown drain), and hands each ripe batch to the pool as
/// one job. Runs until shutdown *and* an empty queue, so pending tickets
/// always resolve.
fn flusher_loop(sh: Arc<Shared>) {
    loop {
        let (batch, cause) = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if q.pending.len() >= sh.policy.max_batch {
                    break (drain_batch(&mut q, sh.policy.max_batch), FlushCause::Full);
                }
                if q.shutdown {
                    if q.pending.is_empty() {
                        return;
                    }
                    break (drain_batch(&mut q, sh.policy.max_batch), FlushCause::Drain);
                }
                match q.pending.front().map(|p| p.enqueued + sh.policy.max_wait) {
                    Some(deadline) => {
                        let now = Instant::now();
                        if now >= deadline {
                            break (drain_batch(&mut q, sh.policy.max_batch), FlushCause::Timer);
                        }
                        q = sh.wake.wait_timeout(q, deadline - now).unwrap().0;
                    }
                    None => q = sh.wake.wait(q).unwrap(),
                }
            }
        };
        // The drain made room below max_pending: release blocked
        // submitters (backpressure hand-off). If the pool's own bounded
        // queue is full, the `submit` below blocks this flusher, which
        // keeps the pending queue at its bound and the backpressure
        // chain intact end to end.
        sh.room.notify_all();
        let c = &sh.counters;
        c.batches.fetch_add(1, Ordering::Relaxed);
        c.max_batch_observed.fetch_max(batch.len(), Ordering::Relaxed);
        match cause {
            FlushCause::Full => c.flushed_full.fetch_add(1, Ordering::Relaxed),
            FlushCause::Timer => c.flushed_timer.fetch_add(1, Ordering::Relaxed),
            FlushCause::Drain => c.flushed_drain.fetch_add(1, Ordering::Relaxed),
        };
        *sh.inflight.lock().unwrap() += 1;
        let job_sh = Arc::clone(&sh);
        // `submit_or_run`: if the pool is shutting down the job runs
        // inline on this flusher thread instead of being dropped —
        // every accepted ticket resolves even when serve teardown races
        // pool teardown.
        sh.pool.submit_or_run(move || {
            // Degraded mode: after repeated faults the drive falls back
            // to the scalar/serial escape-hatch path (bit-identical
            // outputs, none of the blocked/parallel machinery).
            let (kernels, par) = if job_sh.degraded.load(Ordering::Relaxed) {
                (KernelPath::Scalar, Parallelism::serial())
            } else {
                (job_sh.kernels, job_sh.par)
            };
            let outcome =
                run_batch_job(&job_sh.plan, kernels, job_sh.format, batch, &job_sh.pool, par);
            let c = &job_sh.counters;
            c.deadline_missed.fetch_add(outcome.expired, Ordering::Relaxed);
            if outcome.fault.is_some() {
                c.drive_faults.fetch_add(1, Ordering::Relaxed);
                let streak = c.consecutive_faults.fetch_add(1, Ordering::Relaxed) + 1;
                if streak >= DEGRADE_AFTER && !job_sh.degraded.swap(true, Ordering::Relaxed) {
                    obs::degraded_entered();
                }
            } else if outcome.drove {
                c.consecutive_faults.store(0, Ordering::Relaxed);
            }
            let mut n = job_sh.inflight.lock().unwrap();
            *n -= 1;
            if *n == 0 {
                job_sh.idle.notify_all();
            }
        });
    }
}

/// What one batch job did, for the dispatcher's fault accounting
/// (degraded-mode streaks in the batcher, fault budgets in the fleet).
pub(crate) struct DriveOutcome {
    /// `Some` if the drive faulted (panic, executor error, or at least
    /// one non-finite output row).
    pub(crate) fault: Option<DriveFault>,
    /// Tickets resolved as [`ServeError::DeadlineExceeded`] at the flush
    /// boundary instead of executing.
    pub(crate) expired: usize,
    /// Whether a plan drive actually ran (false when every ticket in the
    /// batch had already expired) — an all-expired batch neither extends
    /// nor resets a fault streak.
    pub(crate) drove: bool,
}

/// How a batch drive faulted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum DriveFault {
    /// The drive panicked; caught at the containment boundary.
    Panicked,
    /// The drive completed but emitted a non-finite output row.
    NonFinite,
    /// The executor returned an error.
    ExecFailed,
}

/// Act on a fault-injection decision at a drive site: panic and delay
/// happen here; a NaN decision is reported back for the scatter to apply.
fn injected_nan(site: faultinject::Site) -> bool {
    match faultinject::at(site) {
        Some(faultinject::FaultKind::Panic) => {
            panic!("injected fault: panic at {}", site.name())
        }
        Some(faultinject::FaultKind::Delay { ms }) => {
            std::thread::sleep(Duration::from_millis(ms));
            false
        }
        Some(faultinject::FaultKind::Nan) => true,
        None => false,
    }
}

/// One pool job: drive the whole micro-batch through a single batched
/// plan execution against this worker's thread-local arena (in the
/// format's arithmetic — f64 straight through, emulated-k via input
/// rounding and per-op re-rounding), scattering each per-sample output to
/// its ticket straight from the arena borrow (no intermediate full-batch
/// copy). This is the fault-containment boundary: every ticket is
/// resolved exactly once with a typed outcome on every path. Expired
/// tickets resolve as [`ServeError::DeadlineExceeded`] before the drive;
/// a panic anywhere inside the drive (including its scoped shards) is
/// caught and resolves only this batch's tickets as
/// [`ServeError::DrivePanicked`] — the worker's checked-out scratch arena
/// is dropped by [`with_worker_scratch`], never reused poisoned; each
/// output row is finiteness-checked before delivery. `pub(crate)`: the
/// fleet scheduler dispatches its per-format sub-batches through this
/// same job.
pub(crate) fn run_batch_job(
    plan: &Plan,
    kernels: KernelPath,
    format: ServeFormat,
    batch: Vec<PendingSample>,
    pool: &Pool,
    par: Parallelism,
) -> DriveOutcome {
    // Flush span + per-sample latency: `enqueued` is already captured
    // unconditionally at submit, so measuring costs nothing extra on the
    // submit side. The flush inherits the first traced sample's id so the
    // whole batch is findable from any of its requests.
    let t_flush = obs::mark();
    if t_flush.is_some() {
        for p in &batch {
            obs::queue_wait_done(p.enqueued);
        }
    }
    // Deadline boundary: expired tickets resolve now instead of occupying
    // a batch slot. The common no-deadline batch skips the clock read.
    let mut live = batch;
    let mut expired = 0usize;
    if live.iter().any(|p| p.deadline.is_some()) {
        let now = Instant::now();
        let (dead, rest): (Vec<PendingSample>, Vec<PendingSample>) =
            live.into_iter().partition(|p| p.deadline.is_some_and(|d| d <= now));
        live = rest;
        expired = dead.len();
        for p in &dead {
            let waited_ms = p.enqueued.elapsed().as_millis() as u64;
            fill(&p.slot, Err(ServeError::DeadlineExceeded { waited_ms }));
            if t_flush.is_some() {
                obs::request_done(p.trace, p.enqueued);
            }
        }
        obs::deadlines_missed(expired);
    }
    let finish = |batch: &[PendingSample]| {
        if t_flush.is_none() {
            return;
        }
        for p in batch {
            obs::request_done(p.trace, p.enqueued);
        }
        let trace = batch.iter().map(|p| p.trace).find(|&t| t != 0).unwrap_or(0);
        obs::flush_done(t_flush, "flush", trace, batch.len());
    };
    if live.is_empty() {
        return DriveOutcome { fault: None, expired, drove: false };
    }
    let b = live.len();
    let mut flat: Vec<f64> = Vec::with_capacity(b * plan.input_len());
    for p in &live {
        flat.extend_from_slice(&p.sample);
    }
    let batch = live;
    // Scatter one output row: the finiteness tripwire runs on every row,
    // so a poisoned drive resolves the affected tickets as
    // `NonFiniteOutput` instead of delivering NaN/Inf downstream.
    let scatter = |p: &PendingSample, mut row: Vec<f64>, corrupt: bool, bad: &mut bool| {
        if corrupt {
            if let Some(v) = row.first_mut() {
                *v = f64::NAN;
            }
        }
        match row.iter().position(|v| !v.is_finite()) {
            Some(i) => {
                *bad = true;
                obs::nonfinite_output();
                fill(&p.slot, Err(ServeError::NonFiniteOutput { index: i }));
            }
            None => fill(&p.slot, Ok(row)),
        }
    };
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        // UnwindSafe audit: the closure captures `&Plan` and `&Pool`
        // (interior state guarded by mutexes whose holders never unwind
        // mid-update on this path), the worker's scratch arena (checked
        // out — dropped, not reinserted, if we unwind), and the tickets'
        // slots (resolved under their own locks by `fill`, first write
        // wins). Nothing observable is left half-updated by an unwind.
        let corrupt = injected_nan(faultinject::Site::DrivePre);
        let m = plan.output_len();
        let drove = match format {
            ServeFormat::F64 => with_worker_scratch(|arena: &mut Arena<f64>| {
                match plan.execute_batch_pooled::<f64>(&(), &flat, b, arena, kernels, pool, par) {
                    Ok(out) => {
                        let mut bad = false;
                        for (s, p) in batch.iter().enumerate() {
                            if matches!(
                                faultinject::at(faultinject::Site::DriveScatter),
                                Some(faultinject::FaultKind::Panic)
                            ) {
                                panic!("injected fault: panic at drive-scatter");
                            }
                            scatter(p, out[s * m..(s + 1) * m].to_vec(), corrupt, &mut bad);
                        }
                        Ok(bad)
                    }
                    Err(e) => Err(format!("{e:#}")),
                }
            }),
            ServeFormat::Emulated { k } => {
                // Same input mapping as `quant::emulated_forward`, batched.
                let ec = EmuCtx { k };
                let xe: Vec<EmulatedFp> = flat.iter().map(|&v| EmulatedFp::new(v, k)).collect();
                with_worker_scratch(|arena: &mut Arena<EmulatedFp>| {
                    match plan.execute_batch_pooled::<EmulatedFp>(
                        &ec, &xe, b, arena, kernels, pool, par,
                    ) {
                        Ok(out) => {
                            let mut bad = false;
                            for (s, p) in batch.iter().enumerate() {
                                if matches!(
                                    faultinject::at(faultinject::Site::DriveScatter),
                                    Some(faultinject::FaultKind::Panic)
                                ) {
                                    panic!("injected fault: panic at drive-scatter");
                                }
                                let row: Vec<f64> =
                                    out[s * m..(s + 1) * m].iter().map(|e| e.v).collect();
                                scatter(p, row, corrupt, &mut bad);
                            }
                            Ok(bad)
                        }
                        Err(e) => Err(format!("{e:#}")),
                    }
                })
            }
        };
        if drove.is_ok() {
            let _ = injected_nan(faultinject::Site::DrivePost);
        }
        drove
    }));
    let (fault, msg) = match result {
        Ok(Ok(nonfinite)) => {
            finish(&batch);
            let fault = if nonfinite { Some(DriveFault::NonFinite) } else { None };
            return DriveOutcome { fault, expired, drove: true };
        }
        Ok(Err(detail)) => (DriveFault::ExecFailed, ServeError::ExecFailed { detail }),
        Err(p) => {
            let cause = p
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            obs::panic_caught();
            (DriveFault::Panicked, ServeError::DrivePanicked { detail: cause })
        }
    };
    for p in &batch {
        fill(&p.slot, Err(msg.clone()));
    }
    finish(&batch);
    DriveOutcome { fault: Some(fault), expired, drove: true }
}

/// Resolve a ticket slot, first write wins: the error fallback after a
/// mid-scatter panic must not clobber outputs already delivered (or
/// already taken by the waiter — the sticky `resolved` bit outlives the
/// value). A scatter into a dropped receiver is a counted no-op.
pub(crate) fn fill(slot: &Slot, result: Result<Vec<f64>, ServeError>) {
    let mut st = slot.state.lock().unwrap();
    if st.resolved {
        return;
    }
    st.resolved = true;
    if slot.abandoned.load(Ordering::Relaxed) {
        obs::ticket_dropped();
        return;
    }
    st.value = Some(result);
    slot.ready.notify_all();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn setup(policy: BatchPolicy) -> (Arc<Plan>, MicroBatcher) {
        let model = zoo::tiny_mlp(11);
        let plan = Arc::new(Plan::for_reference(&model).unwrap());
        let pool = Arc::new(Pool::new(2, 8));
        let batcher = MicroBatcher::new(Arc::clone(&plan), pool, policy);
        (plan, batcher)
    }

    fn sample(i: usize) -> Vec<f64> {
        (0..8).map(|j| ((i * 8 + j) % 13) as f64 / 13.0).collect()
    }

    #[test]
    fn served_outputs_match_direct_execution_bitwise() {
        let (plan, batcher) = setup(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            ..BatchPolicy::default()
        });
        let tickets: Vec<Ticket> =
            (0..10).map(|i| batcher.submit(sample(i)).unwrap()).collect();
        let mut arena: Arena<f64> = Arena::new();
        for (i, t) in tickets.into_iter().enumerate() {
            let got = t.wait().unwrap();
            let want = plan.execute::<f64>(&(), &sample(i), &mut arena).unwrap();
            assert_eq!(got.len(), plan.output_len());
            for (g, w) in got.iter().zip(want) {
                assert_eq!(g.to_bits(), w.to_bits(), "request {i}");
            }
        }
        let m = batcher.metrics();
        assert_eq!(m.submitted, 10);
        assert!(m.batches >= 3, "10 requests at max_batch 4 need >= 3 batches");
        assert!(m.max_batch_observed <= 4);
    }

    #[test]
    fn full_queue_flushes_without_waiting_for_the_timer() {
        // A generous max_wait: the only way these resolve quickly is the
        // max_batch trigger.
        let (_, batcher) = setup(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_secs(30),
            ..BatchPolicy::default()
        });
        let t1 = batcher.submit(sample(0)).unwrap();
        let t2 = batcher.submit(sample(1)).unwrap();
        assert_eq!(t1.wait().unwrap().len(), 3);
        assert_eq!(t2.wait().unwrap().len(), 3);
        let m = batcher.metrics();
        assert_eq!(m.flushed_full, 1);
        assert_eq!(m.max_batch_observed, 2);
    }

    #[test]
    fn drop_drains_pending_tickets() {
        let (_, batcher) = setup(BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_secs(30),
            ..BatchPolicy::default()
        });
        let tickets: Vec<Ticket> =
            (0..3).map(|i| batcher.submit(sample(i)).unwrap()).collect();
        drop(batcher); // shutdown drain must still execute the pending 3
        for t in tickets {
            assert_eq!(t.wait().unwrap().len(), 3);
        }
    }

    #[test]
    fn rejects_wrong_input_length() {
        let (_, batcher) = setup(BatchPolicy::default());
        assert!(batcher.submit(vec![0.0; 5]).is_err());
    }

    #[test]
    fn max_pending_bounds_the_queue_depth() {
        // Stall the pool with a sleeper so flushed batches back up in the
        // pool queue while we hammer submit from several threads: the
        // pending queue's high-water mark must never exceed max_pending.
        let model = zoo::tiny_mlp(11);
        let plan = Arc::new(Plan::for_reference(&model).unwrap());
        let pool = Arc::new(Pool::new(1, 1));
        pool.submit(|| std::thread::sleep(Duration::from_millis(50))).unwrap();
        let batcher = Arc::new(MicroBatcher::with_kernel_path(
            Arc::clone(&plan),
            pool,
            BatchPolicy {
                max_batch: 2,
                max_wait: Duration::from_millis(1),
                max_pending: 3,
                ..BatchPolicy::default()
            },
            plan.kernel_path(),
        ));
        let mut handles = Vec::new();
        for t in 0..4usize {
            let b = Arc::clone(&batcher);
            handles.push(std::thread::spawn(move || {
                (0..8)
                    .map(|i| b.submit(sample(t * 50 + i)).unwrap())
                    .collect::<Vec<Ticket>>()
            }));
        }
        let mut tickets = Vec::new();
        for h in handles {
            tickets.extend(h.join().unwrap());
        }
        for t in tickets {
            assert_eq!(t.wait().unwrap().len(), 3);
        }
        let m = batcher.metrics();
        assert_eq!(m.submitted, 32);
        assert!(m.queue_high_water <= 3, "queue bound violated: {}", m.queue_high_water);
        assert!(m.queue_high_water >= 1);
    }

    #[test]
    fn shutdown_unblocks_a_backpressured_submitter() {
        // Queue bound 2, generous timer, and a stalled pool: the third
        // submit blocks on backpressure; dropping the batcher must wake
        // it with an error instead of deadlocking.
        let model = zoo::tiny_mlp(11);
        let plan = Arc::new(Plan::for_reference(&model).unwrap());
        let pool = Arc::new(Pool::new(1, 1));
        pool.submit(|| std::thread::sleep(Duration::from_millis(100))).unwrap();
        let batcher = Arc::new(MicroBatcher::with_kernel_path(
            Arc::clone(&plan),
            pool,
            BatchPolicy {
                max_batch: 2,
                max_wait: Duration::from_secs(30),
                max_pending: 2,
                ..BatchPolicy::default()
            },
            plan.kernel_path(),
        ));
        let t1 = batcher.submit(sample(0)).unwrap();
        let t2 = batcher.submit(sample(1)).unwrap();
        let blocked = {
            let b = Arc::clone(&batcher);
            std::thread::spawn(move || b.submit(sample(2)))
        };
        std::thread::sleep(Duration::from_millis(20)); // let it block
        drop(batcher);
        let r = blocked.join().unwrap();
        // Either the drain freed a slot before shutdown was observed (the
        // ticket then resolves) or the submit errored out — never a hang.
        if let Ok(t3) = r {
            assert_eq!(t3.wait().unwrap().len(), 3);
        }
        assert_eq!(t1.wait().unwrap().len(), 3);
        assert_eq!(t2.wait().unwrap().len(), 3);
    }

    #[test]
    fn shutdown_waits_for_inflight_flushes() {
        // Regression: shutdown used to join the flusher and return while
        // dispatched batch jobs still sat in the pool queue, so a caller
        // could observe "shut down" with tickets unresolved. Stall the
        // pool behind a sleeper, shut down, and require every ticket to
        // be resolved the moment shutdown returns.
        let model = zoo::tiny_mlp(11);
        let plan = Arc::new(Plan::for_reference(&model).unwrap());
        let pool = Arc::new(Pool::new(1, 2));
        pool.submit(|| std::thread::sleep(Duration::from_millis(60))).unwrap();
        let mut batcher = MicroBatcher::new(
            Arc::clone(&plan),
            pool,
            BatchPolicy {
                max_batch: 2,
                max_wait: Duration::from_millis(1),
                max_pending: 8,
                ..BatchPolicy::default()
            },
        );
        let tickets: Vec<Ticket> =
            (0..4).map(|i| batcher.submit(sample(i)).unwrap()).collect();
        batcher.shutdown();
        for (i, t) in tickets.iter().enumerate() {
            let r = t.try_take().unwrap_or_else(|| panic!("ticket {i} unresolved after shutdown"));
            assert_eq!(r.unwrap().len(), 3);
        }
    }

    #[test]
    fn emulated_format_matches_offline_witness_bitwise() {
        // A batcher serving EmulatedFp{k} traffic must produce, per
        // ticket, exactly the offline witness run's bits.
        let model = zoo::tiny_mlp(11);
        let k = 12u32;
        let format = ServeFormat::Emulated { k };
        let plan = Arc::new(Plan::for_format(&model, format).unwrap());
        let pool = Arc::new(Pool::new(2, 8));
        let batcher = MicroBatcher::with_format(
            Arc::clone(&plan),
            pool,
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                ..BatchPolicy::default()
            },
            plan.kernel_path(),
            format,
        );
        let tickets: Vec<Ticket> =
            (0..10).map(|i| batcher.submit(sample(i)).unwrap()).collect();
        for (i, t) in tickets.into_iter().enumerate() {
            let got = t.wait().unwrap();
            let want = crate::quant::emulated_forward(&plan, k, &sample(i)).unwrap();
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w.to_bits(), "request {i}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "max_pending")]
    fn policy_rejects_pending_below_batch() {
        let model = zoo::tiny_mlp(1);
        let plan = Arc::new(Plan::for_reference(&model).unwrap());
        let pool = Arc::new(Pool::new(1, 1));
        let _ = MicroBatcher::new(
            plan,
            pool,
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                max_pending: 4,
                ..BatchPolicy::default()
            },
        );
    }

    #[test]
    fn concurrent_submitters_all_resolve() {
        let (plan, batcher) = setup(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            ..BatchPolicy::default()
        });
        let batcher = Arc::new(batcher);
        let mut handles = Vec::new();
        for t in 0..4usize {
            let b = Arc::clone(&batcher);
            let plan = Arc::clone(&plan);
            handles.push(std::thread::spawn(move || {
                let mut arena: Arena<f64> = Arena::new();
                for i in 0..8 {
                    let s = sample(t * 100 + i);
                    let got = b.submit(s.clone()).unwrap().wait().unwrap();
                    let want = plan.execute::<f64>(&(), &s, &mut arena).unwrap();
                    assert_eq!(got, want);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(batcher.metrics().submitted, 32);
    }

    #[test]
    fn rejects_non_finite_inputs_at_submit() {
        let (_, batcher) = setup(BatchPolicy::default());
        let mut s = sample(0);
        s[3] = f64::NAN;
        assert!(batcher.submit(s).is_err());
        let mut s = sample(1);
        s[0] = f64::INFINITY;
        assert!(batcher.submit(s).is_err());
        assert_eq!(batcher.metrics().submitted, 0);
    }

    #[test]
    fn dropped_ticket_does_not_wedge_shutdown_drain() {
        // Stall the pool so the ticket is still unresolved when we drop
        // it, then require shutdown (via Drop) to complete: the scatter
        // into the dropped receiver must be a counted no-op, not a hang.
        let before = obs::registry().fault_stats().tickets_dropped;
        let model = zoo::tiny_mlp(11);
        let plan = Arc::new(Plan::for_reference(&model).unwrap());
        let pool = Arc::new(Pool::new(1, 2));
        pool.submit(|| std::thread::sleep(Duration::from_millis(40))).unwrap();
        let batcher = MicroBatcher::new(
            Arc::clone(&plan),
            pool,
            BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                ..BatchPolicy::default()
            },
        );
        let t = batcher.submit(sample(0)).unwrap();
        drop(t);
        drop(batcher); // must drain and return, not deadlock
        let after = obs::registry().fault_stats().tickets_dropped;
        assert!(after > before, "dropped-ticket scatter was not counted");
    }

    #[test]
    fn expired_tickets_resolve_as_deadline_exceeded() {
        // A zero-ish deadline with a stalled pool: by the time the flush
        // boundary runs, every ticket has expired and must resolve as the
        // typed DeadlineExceeded — no batch slot spent on it.
        let model = zoo::tiny_mlp(11);
        let plan = Arc::new(Plan::for_reference(&model).unwrap());
        let pool = Arc::new(Pool::new(1, 2));
        pool.submit(|| std::thread::sleep(Duration::from_millis(40))).unwrap();
        let batcher = MicroBatcher::new(
            Arc::clone(&plan),
            pool,
            BatchPolicy {
                max_batch: 2,
                max_wait: Duration::from_millis(1),
                default_deadline: Some(Duration::from_millis(5)),
                ..BatchPolicy::default()
            },
        );
        let t1 = batcher.submit(sample(0)).unwrap();
        let t2 = batcher.submit(sample(1)).unwrap();
        match t1.wait_typed() {
            Err(ServeError::DeadlineExceeded { waited_ms }) => assert!(waited_ms >= 5),
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        assert!(matches!(t2.wait_typed(), Err(ServeError::DeadlineExceeded { .. })));
        let m = batcher.metrics();
        assert_eq!(m.deadline_missed, 2);
        assert_eq!(m.drive_faults, 0, "expiry is not a drive fault");
    }

    #[test]
    fn deadline_within_budget_still_serves() {
        let (plan, batcher) = setup(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            default_deadline: Some(Duration::from_secs(30)),
            ..BatchPolicy::default()
        });
        let t = batcher.submit(sample(0)).unwrap();
        let got = t.wait().unwrap();
        let mut arena: Arena<f64> = Arena::new();
        let want = plan.execute::<f64>(&(), &sample(0), &mut arena).unwrap();
        assert_eq!(got, want);
        assert_eq!(batcher.metrics().deadline_missed, 0);
    }
}
