//! The analysis **coordinator**: a thread-pool job runtime (std::thread +
//! condvars; the registry snapshot has no tokio) that fans analysis jobs
//! out over workers with a bounded, backpressured queue and collects
//! ordered results. One job per (model, class) pair; Python is never
//! involved.
//!
//! The [`Pool`] is the serving substrate; request-level orchestration
//! lives in [`crate::api::Session`]. The free functions here remain as
//! deprecated shims for old callers.

mod pool;

pub use pool::{with_worker_scratch, Pool, PoolMetrics, Scope, SubmitError};

use crate::analysis::{
    aggregate, analyze_class_with_plan, representatives, AnalysisConfig, ClassAnalysis,
    ModelAnalysis,
};
use crate::data::Dataset;
use crate::model::Model;
use crate::plan::Plan;
use crate::util::Stopwatch;
use anyhow::Result;
use std::sync::Arc;

/// Analyze a model with per-class jobs fanned out over the pool —
/// the parallel version of [`crate::analysis::analyze_model`].
#[deprecated(
    since = "0.2.0",
    note = "use `api::Session::run` with an `api::AnalysisRequest` (ExecMode::Pooled)"
)]
pub fn analyze_model_parallel(
    model: &Model,
    data: &Dataset,
    cfg: &AnalysisConfig,
    pool: &Pool,
) -> Result<ModelAnalysis> {
    analyze_model_parallel_impl(model, data, cfg, pool)
}

/// Pooled analysis loop — the engine behind the deprecated
/// [`analyze_model_parallel`] shim and the [`crate::api`] service layer.
pub(crate) fn analyze_model_parallel_impl(
    model: &Model,
    data: &Dataset,
    cfg: &AnalysisConfig,
    pool: &Pool,
) -> Result<ModelAnalysis> {
    let sw = Stopwatch::start();
    // Compile once; every worker executes the same shared plan with its
    // own thread-local arena.
    let plan = Arc::new(Plan::for_analysis(model)?);
    let jobs: Vec<(usize, Vec<f64>)> = representatives(data)
        .into_iter()
        .map(|(class, idx)| (class, data.inputs[idx].clone()))
        .collect();
    let results: Vec<Result<ClassAnalysis>> = pool.run_batch(jobs, {
        let plan = Arc::clone(&plan);
        let cfg = cfg.clone();
        move |(class, sample)| analyze_class_with_plan(&plan, &cfg, class, &sample)
    });
    let mut per_class = Vec::with_capacity(results.len());
    for r in results {
        per_class.push(r?);
    }
    per_class.sort_by_key(|c| c.class);
    Ok(aggregate(model, cfg, per_class, sw.secs()))
}

/// A multi-model analysis request (what the CLI's `analyze` command and the
/// Table-I bench used to submit).
#[deprecated(since = "0.2.0", note = "use `api::Session::run_all` with `api::AnalysisRequest`s")]
pub struct BatchRequest {
    /// The `(model, data, config)` triples to analyze, in order.
    pub models: Vec<(Model, Dataset, AnalysisConfig)>,
}

/// Run a batch of model analyses, each internally parallel over classes.
#[deprecated(since = "0.2.0", note = "use `api::Session::run_all`")]
pub fn run_batch_request(req: &BatchRequest, pool: &Pool) -> Result<Vec<ModelAnalysis>> {
    req.models
        .iter()
        .map(|(m, d, c)| analyze_model_parallel_impl(m, d, c, pool))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    // The unit tests exercise the engine loops directly (the public shims
    // are deprecated in favor of `api::Session`).
    use super::analyze_model_parallel_impl as analyze_model_parallel;
    use crate::analysis::analyze_model_impl as analyze_model;
    use crate::model::zoo;
    use crate::util::Rng;

    fn digits_like() -> (Model, Dataset) {
        let m = zoo::tiny_mlp(42);
        let mut rng = Rng::new(1);
        let inputs: Vec<Vec<f64>> = (0..6)
            .map(|_| (0..8).map(|_| rng.range(0.0, 1.0)).collect())
            .collect();
        let data = Dataset { input_shape: vec![8], inputs, labels: vec![0, 1, 2, 0, 1, 2] };
        (m, data)
    }

    #[test]
    fn parallel_matches_sequential() {
        let (m, data) = digits_like();
        let cfg = AnalysisConfig::default();
        let seq = analyze_model(&m, &data, &cfg).unwrap();
        let pool = Pool::new(4, 16);
        let par = analyze_model_parallel(&m, &data, &cfg, &pool).unwrap();
        assert_eq!(seq.per_class.len(), par.per_class.len());
        // CAA runs are deterministic: bounds must agree exactly.
        for (a, b) in seq.per_class.iter().zip(&par.per_class) {
            assert_eq!(a.class, b.class);
            assert_eq!(a.max_abs_u, b.max_abs_u, "class {}", a.class);
            assert_eq!(a.max_rel_u, b.max_rel_u);
            assert_eq!(a.predicted, b.predicted);
        }
        assert_eq!(seq.max_abs_u, par.max_abs_u);
        assert_eq!(seq.required_k, par.required_k);
    }

    #[test]
    #[allow(deprecated)]
    fn batch_request_shim_runs_multiple_models() {
        let (m1, d1) = digits_like();
        let m2 = zoo::tiny_pendulum(3);
        let d2 = crate::data::synthetic::pendulum_grid(3);
        let req = BatchRequest {
            models: vec![
                (m1, d1, AnalysisConfig::default()),
                (m2, d2, AnalysisConfig::default()),
            ],
        };
        let pool = Pool::new(2, 8);
        let out = run_batch_request(&req, &pool).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].model_name, "tiny_mlp");
        assert_eq!(out[1].model_name, "tiny_pendulum");
    }
}
