//! Worker pool with a bounded, backpressured job queue.
//!
//! Invariants (property-tested below):
//! * every submitted job runs **exactly once**,
//! * `run_batch` returns results in submission order,
//! * the queue never holds more than its bound (submitters block),
//! * shutdown drains the queue before joining workers,
//! * a panicking job does not take the pool down (it is reported to the
//!   submitter).

use std::any::{Any, TypeId};
use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

thread_local! {
    /// Per-thread scratch registry, keyed by type. This is how each worker
    /// owns long-lived execution state (e.g. a [`crate::plan::Arena`])
    /// without the job closures having to thread it through: jobs running
    /// on the same worker reuse the same scratch across submissions.
    static WORKER_SCRATCH: RefCell<HashMap<TypeId, Box<dyn Any>>> = RefCell::new(HashMap::new());
}

/// Run `f` with this thread's scratch value of type `T`, creating it with
/// `Default` on first use. Each pool worker (and any caller thread, for
/// `ExecMode::Serial`) keeps its own `T` for the lifetime of the thread —
/// the plan executor uses this to reuse its preallocated double-buffer
/// arena across jobs. Reentrant calls for the same `T` see a fresh value
/// (the held one is checked out for the duration of `f`); a panic inside
/// `f` drops the scratch rather than poisoning it.
pub fn with_worker_scratch<T, R, F>(f: F) -> R
where
    T: Any + Default,
    F: FnOnce(&mut T) -> R,
{
    let mut slot: Box<dyn Any> = WORKER_SCRATCH
        .with(|s| s.borrow_mut().remove(&TypeId::of::<T>()))
        .unwrap_or_else(|| Box::<T>::default());
    let r = f(slot.downcast_mut::<T>().expect("scratch keyed by TypeId"));
    WORKER_SCRATCH.with(|s| s.borrow_mut().insert(TypeId::of::<T>(), slot));
    r
}

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    bound: usize,
    metrics: Metrics,
}

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

#[derive(Default)]
struct Metrics {
    submitted: AtomicUsize,
    completed: AtomicUsize,
    panicked: AtomicUsize,
    queue_high_water: AtomicUsize,
}

/// Snapshot of pool metrics.
#[derive(Clone, Copy, Debug)]
pub struct PoolMetrics {
    /// Jobs submitted since pool start.
    pub submitted: usize,
    /// Jobs completed.
    pub completed: usize,
    /// Jobs that panicked.
    pub panicked: usize,
    /// Deepest the queue has been.
    pub queue_high_water: usize,
    /// Worker thread count.
    pub workers: usize,
}

/// A fixed-size worker pool.
pub struct Pool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl Pool {
    /// `workers` threads, queue bounded at `queue_bound` pending jobs.
    pub fn new(workers: usize, queue_bound: usize) -> Pool {
        assert!(workers > 0 && queue_bound > 0);
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            bound: queue_bound,
            metrics: Metrics::default(),
        });
        let handles = (0..workers)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("rigor-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        Pool { shared, workers: handles }
    }

    /// A pool with one worker per available core
    /// (`std::thread::available_parallelism`, falling back to 4) and a
    /// 4x-deep queue — the zero-config default the `api::Session` builder
    /// uses.
    pub fn with_default_workers() -> Pool {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Pool::new(n, n * 4)
    }

    /// A pool sized to the machine (for CLI use).
    #[deprecated(since = "0.2.0", note = "use `Pool::with_default_workers`")]
    pub fn default_for_host() -> Pool {
        Pool::with_default_workers()
    }

    /// Number of worker threads.
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job; blocks while the queue is at its bound (backpressure).
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        let mut q = self.shared.queue.lock().unwrap();
        while q.jobs.len() >= self.shared.bound {
            q = self.shared.not_full.wait(q).unwrap();
        }
        assert!(!q.shutdown, "submit after shutdown");
        q.jobs.push_back(Box::new(job));
        let depth = q.jobs.len();
        self.shared.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        self.shared
            .metrics
            .queue_high_water
            .fetch_max(depth, Ordering::Relaxed);
        drop(q);
        self.shared.not_empty.notify_one();
    }

    /// Run a function over every item, in parallel, returning results in
    /// submission order. Panics inside `f` are captured and re-raised here
    /// (with the item index), not on the worker.
    pub fn run_batch<I, T, F>(&self, items: Vec<I>, f: F) -> Vec<T>
    where
        I: Send + 'static,
        T: Send + 'static,
        F: Fn(I) -> T + Send + Sync + 'static,
    {
        let n = items.len();
        let results: Arc<Mutex<Vec<Option<std::thread::Result<T>>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        let done = Arc::new((Mutex::new(0usize), Condvar::new()));
        let f = Arc::new(f);

        for (i, item) in items.into_iter().enumerate() {
            let results = Arc::clone(&results);
            let done = Arc::clone(&done);
            let f = Arc::clone(&f);
            self.submit(move || {
                let r = std::panic::catch_unwind(AssertUnwindSafe(|| f(item)));
                results.lock().unwrap()[i] = Some(r);
                let (lock, cv) = &*done;
                *lock.lock().unwrap() += 1;
                cv.notify_all();
            });
        }

        let (lock, cv) = &*done;
        let mut completed = lock.lock().unwrap();
        while *completed < n {
            completed = cv.wait(completed).unwrap();
        }
        drop(completed);

        let slots = Arc::try_unwrap(results)
            .map(|m| m.into_inner().unwrap())
            .unwrap_or_else(|arc| {
                // Workers have all signalled completion; remaining Arc
                // clones are gone. Fallback: clone out under the lock.
                let mut g = arc.lock().unwrap();
                std::mem::take(&mut *g)
            });
        slots
            .into_iter()
            .enumerate()
            .map(|(i, slot)| match slot.expect("job completed") {
                Ok(v) => v,
                Err(p) => {
                    let msg = p
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "<non-string panic>".into());
                    panic!("batch job {i} panicked: {msg}");
                }
            })
            .collect()
    }

    /// Snapshot the pool counters.
    pub fn metrics(&self) -> PoolMetrics {
        PoolMetrics {
            submitted: self.shared.metrics.submitted.load(Ordering::Relaxed),
            completed: self.shared.metrics.completed.load(Ordering::Relaxed),
            panicked: self.shared.metrics.panicked.load(Ordering::Relaxed),
            queue_high_water: self.shared.metrics.queue_high_water.load(Ordering::Relaxed),
            workers: self.workers.len(),
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.not_empty.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(job) = q.jobs.pop_front() {
                    sh.not_full.notify_one();
                    break job;
                }
                if q.shutdown {
                    return;
                }
                q = sh.not_empty.wait(q).unwrap();
            }
        };
        let r = std::panic::catch_unwind(AssertUnwindSafe(job));
        if r.is_err() {
            sh.metrics.panicked.fetch_add(1, Ordering::Relaxed);
        }
        sh.metrics.completed.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn every_job_runs_exactly_once() {
        prop::check_with(
            prop::Config { cases: 24, base_seed: 0xB00 },
            "pool-exactly-once",
            |rng| {
                let workers = 1 + rng.below(8);
                let bound = 1 + rng.below(16);
                let n = 1 + rng.below(200);
                let pool = Pool::new(workers, bound);
                let counter = Arc::new(AtomicU64::new(0));
                let hits: Vec<Arc<AtomicU64>> =
                    (0..n).map(|_| Arc::new(AtomicU64::new(0))).collect();
                for h in &hits {
                    let h = Arc::clone(h);
                    let c = Arc::clone(&counter);
                    pool.submit(move || {
                        h.fetch_add(1, Ordering::SeqCst);
                        c.fetch_add(1, Ordering::SeqCst);
                    });
                }
                drop(pool); // graceful shutdown drains the queue
                assert_eq!(counter.load(Ordering::SeqCst), n as u64);
                for (i, h) in hits.iter().enumerate() {
                    assert_eq!(h.load(Ordering::SeqCst), 1, "job {i} ran != once");
                }
            },
        );
    }

    #[test]
    fn run_batch_preserves_order() {
        prop::check_with(
            prop::Config { cases: 16, base_seed: 0xB01 },
            "pool-batch-order",
            |rng| {
                let pool = Pool::new(1 + rng.below(6), 1 + rng.below(8));
                let n = rng.below(100);
                let items: Vec<usize> = (0..n).collect();
                let out = pool.run_batch(items, |i| i * 3);
                assert_eq!(out, (0..n).map(|i| i * 3).collect::<Vec<_>>());
            },
        );
    }

    #[test]
    fn metrics_track_submissions() {
        let pool = Pool::new(2, 4);
        let _ = pool.run_batch((0..10).collect::<Vec<_>>(), |i| i);
        // The worker-side completion counter can lag the batch's result
        // barrier by a few instructions.
        for _ in 0..1000 {
            if pool.metrics().completed == 10 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_micros(100));
        }
        let m = pool.metrics();
        assert_eq!(m.submitted, 10);
        assert_eq!(m.completed, 10);
        assert_eq!(m.panicked, 0);
        assert!(m.queue_high_water <= 4, "queue bound violated: {}", m.queue_high_water);
        assert_eq!(m.workers, 2);
    }

    #[test]
    fn backpressure_bounds_queue() {
        // One slow worker, tiny queue: high-water must never exceed bound.
        let pool = Pool::new(1, 2);
        let _ = pool.run_batch((0..50).collect::<Vec<_>>(), |i| {
            std::thread::sleep(std::time::Duration::from_micros(200));
            i
        });
        assert!(pool.metrics().queue_high_water <= 2);
    }

    #[test]
    #[should_panic(expected = "batch job 3 panicked")]
    fn batch_propagates_panics() {
        let pool = Pool::new(2, 4);
        let _ = pool.run_batch((0..8).collect::<Vec<_>>(), |i| {
            if i == 3 {
                panic!("boom {i}");
            }
            i
        });
    }

    #[test]
    fn pool_survives_panicking_jobs() {
        let pool = Pool::new(2, 4);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let _ = pool.run_batch(vec![0, 1], |i| {
                if i == 0 {
                    panic!("die");
                }
                i
            });
        }));
        assert!(r.is_err());
        // The pool still works afterwards.
        let out = pool.run_batch(vec![5, 6], |i| i + 1);
        assert_eq!(out, vec![6, 7]);
        // Batch panics are *captured as results* (re-raised at the
        // collector), so the worker-level panic metric stays 0.
        assert_eq!(pool.metrics().panicked, 0);
    }

    #[test]
    fn raw_submit_panic_counted_in_metrics() {
        let pool = Pool::new(1, 4);
        pool.submit(|| panic!("raw boom"));
        pool.submit(|| {}); // ensure the panicking job has been consumed
        // Drain by shutdown.
        let shared_metrics = {
            let m;
            loop {
                let cur = pool.metrics();
                if cur.completed >= 2 {
                    m = cur;
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            m
        };
        assert_eq!(shared_metrics.panicked, 1);
        assert_eq!(shared_metrics.completed, 2);
    }

    #[test]
    fn worker_scratch_persists_per_thread() {
        // Same thread, same type -> same scratch instance (state persists).
        with_worker_scratch(|v: &mut Vec<u32>| v.push(7));
        let len = with_worker_scratch(|v: &mut Vec<u32>| {
            v.push(8);
            v.len()
        });
        assert_eq!(len, 2);
        // Different type -> independent scratch.
        let other = with_worker_scratch(|v: &mut Vec<u64>| v.len());
        assert_eq!(other, 0);
        // Another thread -> its own scratch.
        let remote = std::thread::spawn(|| with_worker_scratch(|v: &mut Vec<u32>| v.len()))
            .join()
            .unwrap();
        assert_eq!(remote, 0);
    }

    #[test]
    fn worker_scratch_reentrant_same_type() {
        // A nested checkout of the same type must not panic; the inner call
        // sees a fresh value while the outer one is held out.
        let outer = with_worker_scratch(|v: &mut Vec<u8>| {
            v.push(1);
            let inner = with_worker_scratch(|w: &mut Vec<u8>| {
                w.push(2);
                w.len()
            });
            (v.len(), inner)
        });
        assert_eq!(outer.0, 1);
        assert_eq!(outer.1, 1);
    }

    #[test]
    fn concurrent_batches_from_multiple_threads() {
        let pool = Arc::new(Pool::new(4, 8));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let p = Arc::clone(&pool);
            handles.push(std::thread::spawn(move || {
                let out = p.run_batch((0..25u64).collect::<Vec<_>>(), move |i| i + t * 100);
                assert_eq!(out.len(), 25);
                assert_eq!(out[3], 3 + t * 100);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(pool.metrics().completed, 100);
    }
}
