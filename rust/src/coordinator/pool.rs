//! Worker pool with a bounded, backpressured job queue and a scoped
//! borrowed-job API.
//!
//! Invariants (property-tested below):
//! * every submitted job runs **exactly once**,
//! * `run_batch` returns results in submission order,
//! * the queue never holds more than its bound (submitters block),
//! * shutdown drains the queue before joining workers; submits racing a
//!   shutdown get a typed [`SubmitError`] instead of aborting the process,
//! * a panicking job does not take the pool down (it is reported to the
//!   submitter),
//! * [`Pool::scope`] never returns (even by unwind) before every spawned
//!   job has run to completion, and makes progress on any pool — including
//!   when called *from* a pool worker or on a 1-worker pool — because the
//!   scoping thread drains scope jobs itself while it waits (helpers
//!   recruited from the pool only add parallelism, never correctness).

use std::any::{Any, TypeId};
use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::marker::PhantomData;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

thread_local! {
    /// Per-thread scratch registry, keyed by type. This is how each worker
    /// owns long-lived execution state (e.g. a [`crate::plan::Arena`])
    /// without the job closures having to thread it through: jobs running
    /// on the same worker reuse the same scratch across submissions.
    static WORKER_SCRATCH: RefCell<HashMap<TypeId, Box<dyn Any>>> = RefCell::new(HashMap::new());
}

/// Run `f` with this thread's scratch value of type `T`, creating it with
/// `Default` on first use. Each pool worker (and any caller thread, for
/// `ExecMode::Serial`) keeps its own `T` for the lifetime of the thread —
/// the plan executor uses this to reuse its preallocated double-buffer
/// arena across jobs. Reentrant calls for the same `T` see a fresh value
/// (the held one is checked out for the duration of `f`); a panic inside
/// `f` drops the scratch rather than poisoning it.
pub fn with_worker_scratch<T, R, F>(f: F) -> R
where
    T: Any + Default,
    F: FnOnce(&mut T) -> R,
{
    let mut slot: Box<dyn Any> = WORKER_SCRATCH
        .with(|s| s.borrow_mut().remove(&TypeId::of::<T>()))
        .unwrap_or_else(|| Box::<T>::default());
    let r = f(slot.downcast_mut::<T>().expect("scratch keyed by TypeId"));
    WORKER_SCRATCH.with(|s| s.borrow_mut().insert(TypeId::of::<T>(), slot));
    r
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Why a [`Pool::submit`] was rejected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The pool is shutting down (or already shut down); the job was
    /// dropped without running. Teardown paths treat this as "run the work
    /// inline or skip it" — it must never abort the process.
    ShutDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::ShutDown => write!(f, "pool is shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

struct Shared {
    queue: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    bound: usize,
    metrics: Metrics,
}

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

#[derive(Default)]
struct Metrics {
    submitted: AtomicUsize,
    completed: AtomicUsize,
    panicked: AtomicUsize,
    queue_high_water: AtomicUsize,
}

/// Snapshot of pool metrics.
#[derive(Clone, Copy, Debug)]
pub struct PoolMetrics {
    /// Jobs submitted since pool start.
    pub submitted: usize,
    /// Jobs completed.
    pub completed: usize,
    /// Jobs that panicked.
    pub panicked: usize,
    /// Deepest the queue has been.
    pub queue_high_water: usize,
    /// Worker thread count.
    pub workers: usize,
}

/// A fixed-size worker pool.
pub struct Pool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl Pool {
    /// `workers` threads, queue bounded at `queue_bound` pending jobs.
    pub fn new(workers: usize, queue_bound: usize) -> Pool {
        assert!(workers > 0 && queue_bound > 0);
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            bound: queue_bound,
            metrics: Metrics::default(),
        });
        let handles = (0..workers)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("rigor-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        Pool { shared, workers: handles }
    }

    /// A pool with one worker per available core
    /// (`std::thread::available_parallelism`, falling back to 4) and a
    /// 4x-deep queue — the zero-config default the `api::Session` builder
    /// uses.
    pub fn with_default_workers() -> Pool {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Pool::new(n, n * 4)
    }

    /// Number of worker threads.
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue an already-boxed job; on a shut-down pool the job is handed
    /// back so the caller can run it inline instead of losing it.
    fn enqueue(&self, job: Job) -> Result<(), (SubmitError, Job)> {
        let mut q = self.shared.queue.lock().unwrap();
        while q.jobs.len() >= self.shared.bound {
            if q.shutdown {
                return Err((SubmitError::ShutDown, job));
            }
            q = self.shared.not_full.wait(q).unwrap();
        }
        if q.shutdown {
            return Err((SubmitError::ShutDown, job));
        }
        q.jobs.push_back(job);
        let depth = q.jobs.len();
        self.shared.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        self.shared
            .metrics
            .queue_high_water
            .fetch_max(depth, Ordering::Relaxed);
        drop(q);
        self.shared.not_empty.notify_one();
        Ok(())
    }

    /// Like [`enqueue`](Self::enqueue) but never blocks: a full (or shut
    /// down) queue is a `false`, not a wait. Used to recruit scope helpers
    /// — if the pool has no room the recruiting thread simply keeps the
    /// work for itself.
    fn try_enqueue(&self, job: Job) -> bool {
        let mut q = self.shared.queue.lock().unwrap();
        if q.shutdown || q.jobs.len() >= self.shared.bound {
            return false;
        }
        q.jobs.push_back(job);
        let depth = q.jobs.len();
        self.shared.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        self.shared
            .metrics
            .queue_high_water
            .fetch_max(depth, Ordering::Relaxed);
        drop(q);
        self.shared.not_empty.notify_one();
        true
    }

    /// Submit a job; blocks while the queue is at its bound (backpressure).
    ///
    /// Returns [`SubmitError::ShutDown`] — dropping the job — if the pool
    /// is shutting down, including when the shutdown lands while this call
    /// is blocked on backpressure. Callers that must not lose the work
    /// (serve/fleet flushers resolving tickets) run it inline on `Err`.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) -> Result<(), SubmitError> {
        self.enqueue(Box::new(job)).map_err(|(e, _job)| e)
    }

    /// Submit a job that must run **exactly once, no matter what**: on a
    /// live pool it is queued like [`Pool::submit`]; if the pool is
    /// shutting down (or shuts down while this call is blocked on
    /// backpressure) the job runs inline on the calling thread instead of
    /// being dropped. The serve/fleet flushers use this so every admitted
    /// ticket resolves even when a flush races pool teardown.
    pub fn submit_or_run(&self, job: impl FnOnce() + Send + 'static) {
        if let Err((_, job)) = self.enqueue(Box::new(job)) {
            job();
        }
    }

    /// Stop accepting jobs and wake every blocked submitter and idle
    /// worker. Queued jobs still drain; worker threads exit once the queue
    /// is empty and are joined by `Drop`. Idempotent.
    pub fn shutdown(&self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
    }

    /// Run a set of **borrowed** jobs to completion on the pool: the
    /// closure gets a [`Scope`] whose `spawn` accepts non-`'static` jobs
    /// (they may borrow anything that outlives the `scope` call), and
    /// `scope` does not return until every spawned job has finished — a
    /// per-call barrier.
    ///
    /// Execution is cooperative: each spawn tries to recruit one idle pool
    /// worker as a helper (never blocking on a full queue), and the calling
    /// thread drains scope jobs itself while it waits at the barrier. That
    /// makes `scope` deadlock-free from any context — from a pool worker
    /// (the batched-execution jobs fan out from inside the pool), on a
    /// 1-worker pool, under a racing shutdown, or nested inside another
    /// scope — the caller alone is always enough to finish the work.
    ///
    /// Panics in spawned jobs are captured; the first one is re-raised on
    /// the calling thread after the barrier (so borrowed data is never
    /// freed while a job still runs, even on unwind).
    pub fn scope<'env, F, R>(&self, f: F) -> R
    where
        F: FnOnce(&Scope<'env>) -> R,
    {
        let state = Arc::new(ScopeState {
            jobs: Mutex::new(VecDeque::new()),
            completed: Mutex::new(0),
            all_done: Condvar::new(),
            panic: Mutex::new(None),
            spawned: AtomicUsize::new(0),
            helpers: AtomicUsize::new(0),
        });
        let scope = Scope { state: Arc::clone(&state), pool: self, _env: PhantomData };
        // If the body itself panics we must still reach the barrier below
        // before unwinding: spawned jobs may hold borrows into the caller's
        // frame.
        let body = std::panic::catch_unwind(AssertUnwindSafe(|| f(&scope)));

        // Barrier: help run scope jobs until all of them have completed.
        let spawned = state.spawned.load(Ordering::Acquire);
        loop {
            if scope_run_one(&state) {
                continue;
            }
            // Queue empty — either done, or helpers still hold in-flight
            // jobs; `completed` is bumped under this lock, so no wakeup is
            // lost between the check and the wait.
            let done = state.completed.lock().unwrap();
            if *done >= spawned {
                break;
            }
            drop(state.all_done.wait(done).unwrap());
        }

        if let Some(p) = state.panic.lock().unwrap().take() {
            std::panic::resume_unwind(p);
        }
        match body {
            Ok(r) => r,
            Err(p) => std::panic::resume_unwind(p),
        }
    }

    /// Run a function over every item, in parallel, returning results in
    /// submission order. Panics inside `f` are captured and re-raised here
    /// (with the item index), not on the worker. If the pool is shutting
    /// down, remaining items run inline on the calling thread — the batch
    /// always completes.
    pub fn run_batch<I, T, F>(&self, items: Vec<I>, f: F) -> Vec<T>
    where
        I: Send + 'static,
        T: Send + 'static,
        F: Fn(I) -> T + Send + Sync + 'static,
    {
        let n = items.len();
        let results: Arc<Mutex<Vec<Option<std::thread::Result<T>>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        let done = Arc::new((Mutex::new(0usize), Condvar::new()));
        let f = Arc::new(f);

        for (i, item) in items.into_iter().enumerate() {
            let results = Arc::clone(&results);
            let done = Arc::clone(&done);
            let f = Arc::clone(&f);
            let job: Job = Box::new(move || {
                let r = std::panic::catch_unwind(AssertUnwindSafe(|| f(item)));
                results.lock().unwrap()[i] = Some(r);
                let (lock, cv) = &*done;
                *lock.lock().unwrap() += 1;
                cv.notify_all();
            });
            if let Err((_, job)) = self.enqueue(job) {
                job(); // pool raced shutdown: resolve the slot inline
            }
        }

        let (lock, cv) = &*done;
        let mut completed = lock.lock().unwrap();
        while *completed < n {
            completed = cv.wait(completed).unwrap();
        }
        drop(completed);

        let slots = Arc::try_unwrap(results)
            .map(|m| m.into_inner().unwrap())
            .unwrap_or_else(|arc| {
                // Workers have all signalled completion; remaining Arc
                // clones are gone. Fallback: clone out under the lock.
                let mut g = arc.lock().unwrap();
                std::mem::take(&mut *g)
            });
        slots
            .into_iter()
            .enumerate()
            .map(|(i, slot)| match slot.expect("job completed") {
                Ok(v) => v,
                Err(p) => {
                    let msg = p
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "<non-string panic>".into());
                    panic!("batch job {i} panicked: {msg}");
                }
            })
            .collect()
    }

    /// Snapshot the pool counters.
    pub fn metrics(&self) -> PoolMetrics {
        PoolMetrics {
            submitted: self.shared.metrics.submitted.load(Ordering::Relaxed),
            completed: self.shared.metrics.completed.load(Ordering::Relaxed),
            panicked: self.shared.metrics.panicked.load(Ordering::Relaxed),
            queue_high_water: self.shared.metrics.queue_high_water.load(Ordering::Relaxed),
            workers: self.workers.len(),
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shutdown();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Handle for spawning borrowed jobs inside a [`Pool::scope`] call.
///
/// `'env` is the lifetime of the environment the jobs may borrow: anything
/// that strictly outlives the `scope` call. The lifetime is invariant (via
/// the marker) so it cannot be shortened to smuggle in shorter-lived
/// borrows.
pub struct Scope<'env> {
    state: Arc<ScopeState>,
    pool: *const Pool,
    _env: PhantomData<&'env mut &'env ()>,
}

struct ScopeState {
    /// Lifetime-erased jobs; sound because `Pool::scope` barriers before
    /// returning (see `spawn`).
    jobs: Mutex<VecDeque<Job>>,
    completed: Mutex<usize>,
    all_done: Condvar,
    panic: Mutex<Option<Box<dyn Any + Send>>>,
    spawned: AtomicUsize,
    helpers: AtomicUsize,
}

impl<'env> Scope<'env> {
    /// Queue a borrowed job on the scope. It runs on a recruited pool
    /// worker or on the scoping thread itself, exactly once, before
    /// [`Pool::scope`] returns.
    pub fn spawn(&self, job: impl FnOnce() + Send + 'env) {
        let boxed: Box<dyn FnOnce() + Send + 'env> = Box::new(job);
        // SAFETY: the erased borrow never outlives its referents —
        // `Pool::scope` does not return (even when unwinding) until every
        // spawned job has run to completion, and `'env` outlives the scope
        // call. Helper closures that survive the scope capture only the
        // (then empty) `Arc<ScopeState>` queue, never a job.
        let erased: Job = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Box<dyn FnOnce() + Send>>(boxed)
        };
        self.state.spawned.fetch_add(1, Ordering::AcqRel);
        self.state.jobs.lock().unwrap().push_back(erased);

        // Recruit at most one helper per spawn, capped at the pool's worker
        // count, never blocking on a full queue: helpers only add
        // parallelism, the scoping thread guarantees completion.
        let pool = unsafe { &*self.pool };
        if self.state.helpers.load(Ordering::Relaxed) < pool.worker_count() {
            let st = Arc::clone(&self.state);
            if pool.try_enqueue(Box::new(move || while scope_run_one(&st) {})) {
                self.state.helpers.fetch_add(1, Ordering::Relaxed);
                crate::obs::helper_recruited();
            }
        }
    }
}

// SAFETY: a Scope is shared with spawned jobs only by reference and all
// its state is behind sync primitives; the raw pool pointer is valid for
// the whole scope (the pool is borrowed by `Pool::scope`).
unsafe impl Sync for Scope<'_> {}
unsafe impl Send for Scope<'_> {}

/// Pop and run one scope job; `false` when the scope queue is empty. The
/// first panic is parked in the scope's panic slot for the barrier to
/// re-raise.
fn scope_run_one(st: &ScopeState) -> bool {
    let job = st.jobs.lock().unwrap().pop_front();
    let Some(job) = job else { return false };
    if let Err(p) = std::panic::catch_unwind(AssertUnwindSafe(job)) {
        let mut slot = st.panic.lock().unwrap();
        if slot.is_none() {
            *slot = Some(p);
        }
    }
    let mut done = st.completed.lock().unwrap();
    *done += 1;
    drop(done);
    st.all_done.notify_all();
    true
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(job) = q.jobs.pop_front() {
                    sh.not_full.notify_one();
                    break job;
                }
                if q.shutdown {
                    return;
                }
                q = sh.not_empty.wait(q).unwrap();
            }
        };
        let r = std::panic::catch_unwind(AssertUnwindSafe(job));
        if r.is_err() {
            sh.metrics.panicked.fetch_add(1, Ordering::Relaxed);
        }
        sh.metrics.completed.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn every_job_runs_exactly_once() {
        prop::check_with(
            prop::Config { cases: 24, base_seed: 0xB00 },
            "pool-exactly-once",
            |rng| {
                let workers = 1 + rng.below(8);
                let bound = 1 + rng.below(16);
                let n = 1 + rng.below(200);
                let pool = Pool::new(workers, bound);
                let counter = Arc::new(AtomicU64::new(0));
                let hits: Vec<Arc<AtomicU64>> =
                    (0..n).map(|_| Arc::new(AtomicU64::new(0))).collect();
                for h in &hits {
                    let h = Arc::clone(h);
                    let c = Arc::clone(&counter);
                    pool.submit(move || {
                        h.fetch_add(1, Ordering::SeqCst);
                        c.fetch_add(1, Ordering::SeqCst);
                    })
                    .expect("pool is live");
                }
                drop(pool); // graceful shutdown drains the queue
                assert_eq!(counter.load(Ordering::SeqCst), n as u64);
                for (i, h) in hits.iter().enumerate() {
                    assert_eq!(h.load(Ordering::SeqCst), 1, "job {i} ran != once");
                }
            },
        );
    }

    #[test]
    fn run_batch_preserves_order() {
        prop::check_with(
            prop::Config { cases: 16, base_seed: 0xB01 },
            "pool-batch-order",
            |rng| {
                let pool = Pool::new(1 + rng.below(6), 1 + rng.below(8));
                let n = rng.below(100);
                let items: Vec<usize> = (0..n).collect();
                let out = pool.run_batch(items, |i| i * 3);
                assert_eq!(out, (0..n).map(|i| i * 3).collect::<Vec<_>>());
            },
        );
    }

    #[test]
    fn metrics_track_submissions() {
        let pool = Pool::new(2, 4);
        let _ = pool.run_batch((0..10).collect::<Vec<_>>(), |i| i);
        // The worker-side completion counter can lag the batch's result
        // barrier by a few instructions.
        for _ in 0..1000 {
            if pool.metrics().completed == 10 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_micros(100));
        }
        let m = pool.metrics();
        assert_eq!(m.submitted, 10);
        assert_eq!(m.completed, 10);
        assert_eq!(m.panicked, 0);
        assert!(m.queue_high_water <= 4, "queue bound violated: {}", m.queue_high_water);
        assert_eq!(m.workers, 2);
    }

    #[test]
    fn backpressure_bounds_queue() {
        // One slow worker, tiny queue: high-water must never exceed bound.
        let pool = Pool::new(1, 2);
        let _ = pool.run_batch((0..50).collect::<Vec<_>>(), |i| {
            std::thread::sleep(std::time::Duration::from_micros(200));
            i
        });
        assert!(pool.metrics().queue_high_water <= 2);
    }

    #[test]
    #[should_panic(expected = "batch job 3 panicked")]
    fn batch_propagates_panics() {
        let pool = Pool::new(2, 4);
        let _ = pool.run_batch((0..8).collect::<Vec<_>>(), |i| {
            if i == 3 {
                panic!("boom {i}");
            }
            i
        });
    }

    #[test]
    fn pool_survives_panicking_jobs() {
        let pool = Pool::new(2, 4);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let _ = pool.run_batch(vec![0, 1], |i| {
                if i == 0 {
                    panic!("die");
                }
                i
            });
        }));
        assert!(r.is_err());
        // The pool still works afterwards.
        let out = pool.run_batch(vec![5, 6], |i| i + 1);
        assert_eq!(out, vec![6, 7]);
        // Batch panics are *captured as results* (re-raised at the
        // collector), so the worker-level panic metric stays 0.
        assert_eq!(pool.metrics().panicked, 0);
    }

    #[test]
    fn raw_submit_panic_counted_in_metrics() {
        let pool = Pool::new(1, 4);
        pool.submit(|| panic!("raw boom")).unwrap();
        pool.submit(|| {}).unwrap(); // ensure the panicking job has been consumed
        // Drain by shutdown.
        let shared_metrics = {
            let m;
            loop {
                let cur = pool.metrics();
                if cur.completed >= 2 {
                    m = cur;
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            m
        };
        assert_eq!(shared_metrics.panicked, 1);
        assert_eq!(shared_metrics.completed, 2);
    }

    #[test]
    fn submit_after_shutdown_is_a_typed_error_not_a_panic() {
        // Regression: this used to be `assert!(!q.shutdown)` — a submit
        // racing teardown aborted the process.
        let pool = Pool::new(1, 2);
        pool.shutdown();
        let hit = Arc::new(AtomicU64::new(0));
        let h = Arc::clone(&hit);
        let r = pool.submit(move || {
            h.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(r, Err(SubmitError::ShutDown));
        assert_eq!(hit.load(Ordering::SeqCst), 0, "rejected job must not run");
        // Shutdown is idempotent and the pool still drops cleanly.
        pool.shutdown();
    }

    #[test]
    fn submit_or_run_runs_inline_after_shutdown() {
        let pool = Pool::new(1, 2);
        pool.shutdown();
        let hit = Arc::new(AtomicU64::new(0));
        let h = Arc::clone(&hit);
        pool.submit_or_run(move || {
            h.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hit.load(Ordering::SeqCst), 1, "job must run inline, not drop");
    }

    #[test]
    fn shutdown_wakes_blocked_submitters_with_an_error() {
        // Fill the queue behind a slow job so a submitter blocks on
        // backpressure, then shut down: the submitter must return
        // Err(ShutDown), not hang or panic.
        let pool = Arc::new(Pool::new(1, 1));
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g = Arc::clone(&gate);
        pool.submit(move || {
            let (lock, cv) = &*g;
            let mut open = lock.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
        })
        .unwrap();
        // Occupy the single queue slot. A live submit may legitimately race
        // the worker popping it, so retry until the queue is genuinely full.
        while !pool.try_enqueue(Box::new(|| {})) {
            std::thread::sleep(std::time::Duration::from_micros(50));
        }
        let p = Arc::clone(&pool);
        let blocked = std::thread::spawn(move || p.submit(|| {}));
        std::thread::sleep(std::time::Duration::from_millis(10));
        pool.shutdown();
        assert_eq!(blocked.join().unwrap(), Err(SubmitError::ShutDown));
        // Unblock the gated job so Drop can join the worker.
        let (lock, cv) = &*gate;
        *lock.lock().unwrap() = true;
        cv.notify_all();
    }

    #[test]
    fn scope_runs_borrowed_jobs_to_completion() {
        let pool = Pool::new(4, 8);
        let data: Vec<u64> = (0..64).collect(); // not 'static
        let total = AtomicU64::new(0);
        pool.scope(|s| {
            for chunk in data.chunks(8) {
                let total = &total;
                s.spawn(move || {
                    total.fetch_add(chunk.iter().sum::<u64>(), Ordering::SeqCst);
                });
            }
        });
        assert_eq!(total.load(Ordering::SeqCst), data.iter().sum::<u64>());
    }

    #[test]
    fn scope_jobs_write_disjoint_output_chunks() {
        // The executor's sharding pattern: split one output buffer into
        // disjoint &mut chunks, one per job.
        let pool = Pool::new(3, 8);
        let mut out = vec![0u64; 30];
        pool.scope(|s| {
            for (c, chunk) in out.chunks_mut(7).enumerate() {
                s.spawn(move || {
                    for (i, v) in chunk.iter_mut().enumerate() {
                        *v = (c * 100 + i) as u64;
                    }
                });
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, ((i / 7) * 100 + i % 7) as u64);
        }
    }

    #[test]
    fn scope_makes_progress_on_a_one_worker_pool() {
        // The single worker may be busy or may itself be the scoping
        // thread; the caller-helps rule means the scope always finishes.
        let pool = Pool::new(1, 1);
        let n = AtomicU64::new(0);
        pool.scope(|s| {
            for _ in 0..32 {
                let n = &n;
                s.spawn(move || {
                    n.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        assert_eq!(n.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn scope_from_inside_a_pool_job() {
        // The serve path runs batch jobs *on* a worker and fans out from
        // there — a scope opened on a worker must not deadlock.
        let pool = Arc::new(Pool::new(2, 4));
        let p = Arc::clone(&pool);
        let (tx, rx) = std::sync::mpsc::channel::<u64>();
        pool.submit(move || {
            let local: Vec<u64> = (0..16).collect();
            let sum = AtomicU64::new(0);
            p.scope(|s| {
                for chunk in local.chunks(4) {
                    let sum = &sum;
                    s.spawn(move || {
                        sum.fetch_add(chunk.iter().sum::<u64>(), Ordering::SeqCst);
                    });
                }
            });
            tx.send(sum.load(Ordering::SeqCst)).unwrap();
        })
        .unwrap();
        assert_eq!(rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap(), 120);
    }

    #[test]
    fn scopes_nest() {
        let pool = Pool::new(2, 4);
        let n = AtomicU64::new(0);
        pool.scope(|outer| {
            for _ in 0..4 {
                let n = &n;
                let pool = &pool;
                outer.spawn(move || {
                    pool.scope(|inner| {
                        for _ in 0..4 {
                            inner.spawn(move || {
                                n.fetch_add(1, Ordering::SeqCst);
                            });
                        }
                    });
                });
            }
        });
        assert_eq!(n.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn scope_reraises_job_panics_after_the_barrier() {
        let pool = Pool::new(2, 4);
        let ran = Arc::new(AtomicU64::new(0));
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scope(|s| {
                for i in 0..8 {
                    let ran = Arc::clone(&ran);
                    s.spawn(move || {
                        if i == 3 {
                            panic!("scope boom");
                        }
                        ran.fetch_add(1, Ordering::SeqCst);
                    });
                }
            });
        }));
        assert!(r.is_err(), "panic must propagate to the scoping thread");
        // Barrier-before-unwind: every non-panicking job still ran.
        assert_eq!(ran.load(Ordering::SeqCst), 7);
        // The pool survives.
        assert_eq!(pool.run_batch(vec![1, 2], |i| i), vec![1, 2]);
    }

    #[test]
    fn scope_panic_on_a_one_worker_pool_reraises_without_deadlock() {
        // Regression pin: on a 1-worker pool the scoping thread may be the
        // only thread draining scope jobs. A panicking job must still hit
        // the barrier (every sibling runs) and re-raise on the caller — not
        // deadlock, not kill the worker.
        let pool = Pool::new(1, 1);
        let ran = Arc::new(AtomicU64::new(0));
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scope(|s| {
                for i in 0..16 {
                    let ran = Arc::clone(&ran);
                    s.spawn(move || {
                        if i == 5 {
                            panic!("one-worker scope boom");
                        }
                        ran.fetch_add(1, Ordering::SeqCst);
                    });
                }
            });
        }));
        assert!(r.is_err(), "panic must re-raise on the scoping thread");
        assert_eq!(ran.load(Ordering::SeqCst), 15, "siblings run before the unwind");
        // The pool (and its single worker) still serve work afterwards.
        assert_eq!(pool.run_batch(vec![1, 2, 3], |i| i * 2), vec![2, 4, 6]);
    }

    #[test]
    fn scope_panic_from_inside_a_pool_job_is_contained() {
        // The serve flusher's shape: a scope opened *on* a pool worker
        // whose spawns panic. The panic must surface to the in-job
        // `catch_unwind` (after the barrier) and leave the pool usable.
        let pool = Arc::new(Pool::new(2, 4));
        let p = Arc::clone(&pool);
        let (tx, rx) = std::sync::mpsc::channel::<(bool, u64)>();
        pool.submit(move || {
            let ran = AtomicU64::new(0);
            let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
                p.scope(|s| {
                    for i in 0..8 {
                        let ran = &ran;
                        s.spawn(move || {
                            if i == 2 {
                                panic!("worker scope boom");
                            }
                            ran.fetch_add(1, Ordering::SeqCst);
                        });
                    }
                });
            }));
            tx.send((r.is_err(), ran.load(Ordering::SeqCst))).unwrap();
        })
        .unwrap();
        let (panicked, ran) = rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        assert!(panicked, "the in-job catch_unwind sees the scope panic");
        assert_eq!(ran, 7, "barrier-before-unwind holds on a worker too");
        // The pool survives the contained panic.
        assert_eq!(pool.run_batch(vec![4, 5], |i| i + 1), vec![5, 6]);
    }

    #[test]
    fn scope_under_shutdown_still_completes_on_the_caller() {
        let pool = Pool::new(2, 4);
        pool.shutdown();
        // No helpers can be recruited; the scoping thread runs everything.
        let n = AtomicU64::new(0);
        pool.scope(|s| {
            for _ in 0..8 {
                let n = &n;
                s.spawn(move || {
                    n.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        assert_eq!(n.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn worker_scratch_persists_per_thread() {
        // Same thread, same type -> same scratch instance (state persists).
        with_worker_scratch(|v: &mut Vec<u32>| v.push(7));
        let len = with_worker_scratch(|v: &mut Vec<u32>| {
            v.push(8);
            v.len()
        });
        assert_eq!(len, 2);
        // Different type -> independent scratch.
        let other = with_worker_scratch(|v: &mut Vec<u64>| v.len());
        assert_eq!(other, 0);
        // Another thread -> its own scratch.
        let remote = std::thread::spawn(|| with_worker_scratch(|v: &mut Vec<u32>| v.len()))
            .join()
            .unwrap();
        assert_eq!(remote, 0);
    }

    #[test]
    fn worker_scratch_reentrant_same_type() {
        // A nested checkout of the same type must not panic; the inner call
        // sees a fresh value while the outer one is held out.
        let outer = with_worker_scratch(|v: &mut Vec<u8>| {
            v.push(1);
            let inner = with_worker_scratch(|w: &mut Vec<u8>| {
                w.push(2);
                w.len()
            });
            (v.len(), inner)
        });
        assert_eq!(outer.0, 1);
        assert_eq!(outer.1, 1);
    }

    #[test]
    fn concurrent_batches_from_multiple_threads() {
        let pool = Arc::new(Pool::new(4, 8));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let p = Arc::clone(&pool);
            handles.push(std::thread::spawn(move || {
                let out = p.run_batch((0..25u64).collect::<Vec<_>>(), move |i| i + t * 100);
                assert_eq!(out.len(), 25);
                assert_eq!(out[3], 3 + t * 100);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(pool.metrics().completed, 100);
    }
}
