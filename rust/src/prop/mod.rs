//! Tiny property-based testing driver (replaces proptest, unavailable in
//! the offline registry snapshot).
//!
//! A property is a closure over a [`crate::util::Rng`]; [`check`] runs it
//! for `cases` seeds derived deterministically from a base seed and reports
//! the first failing seed so a failure reproduces with
//! `check_one(base, failing_case, f)`. No shrinking — generators are kept
//! small-biased instead (mixing tiny magnitudes, zeros and sign flips),
//! which in practice pinpoints failures as well for numeric code.

use crate::util::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: u32,
    /// Seed of the first case (case i uses `base_seed + i`).
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 256, base_seed: 0xC0FFEE }
    }
}

/// Run `f` on `cfg.cases` independent deterministic RNGs. Panics with the
/// case index and seed on first failure (so `cargo test` reports it).
pub fn check_with<F: FnMut(&mut Rng)>(cfg: Config, name: &str, mut f: F) {
    for case in 0..cfg.cases {
        let seed = cfg.base_seed ^ ((case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}\n\
                 reproduce with prop::check_one({seed:#x}, ..)"
            );
        }
    }
}

/// Run with the default config.
pub fn check<F: FnMut(&mut Rng)>(name: &str, f: F) {
    check_with(Config::default(), name, f);
}

/// Re-run a single failing case by seed.
pub fn check_one<F: FnMut(&mut Rng)>(seed: u64, mut f: F) {
    let mut rng = Rng::new(seed);
    f(&mut rng);
}

/// Small-biased float generator: mixes exact zeros, tiny magnitudes, unit
/// range, large magnitudes and sign flips — the corner cases numeric code
/// actually trips on.
pub fn gen_f64(rng: &mut Rng) -> f64 {
    let sign = if rng.bool(0.5) { 1.0 } else { -1.0 };
    match rng.below(10) {
        0 => 0.0,
        1 => sign * rng.range(1e-300, 1e-280),
        2 => sign * rng.range(1e-10, 1e-6),
        3 | 4 | 5 => sign * rng.range(0.0, 1.0),
        6 | 7 => sign * rng.range(1.0, 100.0),
        8 => sign * rng.range(100.0, 1e6),
        _ => sign * rng.range(1e6, 1e12),
    }
}

/// Float in a caller-given band, small-biased within it.
pub fn gen_f64_in(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
    debug_assert!(lo < hi);
    if rng.bool(0.1) && lo <= 0.0 && 0.0 <= hi {
        0.0
    } else {
        rng.range(lo, hi)
    }
}

/// A random interval within [lo, hi], occasionally degenerate (a point).
pub fn gen_interval(rng: &mut Rng, lo: f64, hi: f64) -> crate::interval::Interval {
    let a = gen_f64_in(rng, lo, hi);
    if rng.bool(0.15) {
        crate::interval::Interval::point(a)
    } else {
        let b = gen_f64_in(rng, lo, hi);
        crate::interval::Interval::new(a.min(b), a.max(b))
    }
}

/// A random shape with bounded rank and elements.
pub fn gen_shape(rng: &mut Rng, max_rank: usize, max_dim: usize) -> Vec<usize> {
    let rank = 1 + rng.below(max_rank);
    (0..rank).map(|_| 1 + rng.below(max_dim)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("commutativity", |rng| {
            let a = gen_f64(rng);
            let b = gen_f64(rng);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_seed() {
        check_with(
            Config { cases: 3, base_seed: 1 },
            "always-fails",
            |_| panic!("boom"),
        );
    }

    #[test]
    fn deterministic_generation() {
        let mut out1 = Vec::new();
        let mut out2 = Vec::new();
        check_with(Config { cases: 5, base_seed: 9 }, "collect1", |rng| {
            out1.push(gen_f64(rng))
        });
        check_with(Config { cases: 5, base_seed: 9 }, "collect2", |rng| {
            out2.push(gen_f64(rng))
        });
        assert_eq!(out1, out2);
    }

    #[test]
    fn gen_interval_well_formed() {
        check("interval-wf", |rng| {
            let i = gen_interval(rng, -100.0, 100.0);
            assert!(i.lo() <= i.hi());
        });
    }

    #[test]
    fn gen_shape_bounds() {
        check("shape-bounds", |rng| {
            let s = gen_shape(rng, 4, 8);
            assert!(!s.is_empty() && s.len() <= 4);
            assert!(s.iter().all(|&d| (1..=8).contains(&d)));
        });
    }
}
