//! Datasets.
//!
//! Trained-model evaluation data is produced by `python/compile/aot.py`
//! (the same synthetic generators that trained the models) and exported to
//! JSON next to the model files; [`Dataset::load`] reads it. For tests and
//! ablation benches that must run without artifacts, [`synthetic`] provides
//! Rust-side generators of the same flavor.

pub mod synthetic;

use crate::json::Value;
use anyhow::{anyhow, bail, Context, Result};

/// A labeled dataset: row-major inputs (one flat vector per sample) plus
/// integer labels (empty for regression data).
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Shape of one sample (channels-last for images).
    pub input_shape: Vec<usize>,
    /// One flat row-major vector per sample.
    pub inputs: Vec<Vec<f64>>,
    /// Integer class labels (empty for regression/verification data).
    pub labels: Vec<usize>,
}

impl Dataset {
    /// Number of samples.
    pub fn len(&self) -> usize {
        self.inputs.len()
    }

    /// Whether the dataset holds no samples.
    pub fn is_empty(&self) -> bool {
        self.inputs.is_empty()
    }

    /// One representative sample index per class (first occurrence), in
    /// class order — the paper analyzes "one representative of the class".
    pub fn class_representatives(&self) -> Vec<(usize, usize)> {
        let mut reps: Vec<(usize, usize)> = Vec::new();
        for (i, &l) in self.labels.iter().enumerate() {
            if !reps.iter().any(|&(c, _)| c == l) {
                reps.push((l, i));
            }
        }
        reps.sort_unstable();
        reps
    }

    /// Load from the JSON the Python exporter writes:
    /// `{"input_shape": [...], "inputs": [[...], ...], "labels": [...]}`.
    pub fn from_json(v: &Value) -> Result<Dataset> {
        let input_shape = v
            .get("input_shape")
            .and_then(|s| s.as_usize_vec())
            .ok_or_else(|| anyhow!("dataset missing 'input_shape'"))?;
        let n: usize = input_shape.iter().product();
        let inputs_v = v
            .get("inputs")
            .and_then(|s| s.as_array())
            .ok_or_else(|| anyhow!("dataset missing 'inputs'"))?;
        let mut inputs = Vec::with_capacity(inputs_v.len());
        for (i, row) in inputs_v.iter().enumerate() {
            let row = row
                .as_f64_vec()
                .ok_or_else(|| anyhow!("dataset input {i} not numeric"))?;
            if row.len() != n {
                bail!("dataset input {i}: expected {n} values, got {}", row.len());
            }
            inputs.push(row);
        }
        let labels = match v.get("labels") {
            Some(l) => l
                .as_usize_vec()
                .ok_or_else(|| anyhow!("dataset 'labels' must be integers"))?,
            None => Vec::new(),
        };
        if !labels.is_empty() && labels.len() != inputs.len() {
            bail!("dataset: {} labels for {} inputs", labels.len(), inputs.len());
        }
        Ok(Dataset { input_shape, inputs, labels })
    }

    /// Load a dataset JSON file (see [`Dataset::from_json`]).
    pub fn load(path: &std::path::Path) -> Result<Dataset> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading dataset {}", path.display()))?;
        Dataset::from_json(&crate::json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    #[test]
    fn from_json_and_representatives() {
        let v = json::parse(
            r#"{"input_shape": [2], "inputs": [[1,2],[3,4],[5,6],[7,8]],
                "labels": [1, 0, 1, 0]}"#,
        )
        .unwrap();
        let d = Dataset::from_json(&v).unwrap();
        assert_eq!(d.len(), 4);
        assert_eq!(d.class_representatives(), vec![(0, 1), (1, 0)]);
    }

    #[test]
    fn rejects_mismatches() {
        for bad in [
            r#"{"inputs": [[1]]}"#,
            r#"{"input_shape": [2], "inputs": [[1]]}"#,
            r#"{"input_shape": [1], "inputs": [[1],[2]], "labels": [0]}"#,
        ] {
            assert!(Dataset::from_json(&json::parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn regression_data_has_no_labels() {
        let v = json::parse(r#"{"input_shape": [1], "inputs": [[0.5]]}"#).unwrap();
        let d = Dataset::from_json(&v).unwrap();
        assert!(d.labels.is_empty());
    }
}
