//! Rust-side synthetic data generators (self-contained tests/benches).
//!
//! The digit generator draws crude stroke prototypes per class and
//! perturbs them with noise and shifts — enough structure for a small MLP
//! to learn, which is all the error analysis needs (DESIGN.md
//! §Substitutions: Table I measures arithmetic error, not learning
//! quality). The pendulum generator samples the 2-D input box `[-6, 6]²`.

use super::Dataset;
use crate::util::Rng;

/// Render the prototype of digit `d` on an `s x s` grid (values in [0,1]).
pub fn digit_prototype(d: usize, s: usize) -> Vec<f64> {
    let mut img = vec![0.0f64; s * s];
    let set = |x: usize, y: usize, img: &mut Vec<f64>| {
        if x < s && y < s {
            img[y * s + x] = 1.0;
        }
    };
    let (lo, hi, mid) = (s / 5, s - 1 - s / 5, s / 2);
    // Stroke segments per digit on a 7-segment-style layout.
    //   a: top, b: top-right, c: bottom-right, d: bottom, e: bottom-left,
    //   f: top-left, g: middle
    let segs: [&[usize]; 10] = [
        &[0, 1, 2, 3, 4, 5],    // 0
        &[1, 2],                // 1
        &[0, 1, 6, 4, 3],       // 2
        &[0, 1, 6, 2, 3],       // 3
        &[5, 6, 1, 2],          // 4
        &[0, 5, 6, 2, 3],       // 5
        &[0, 5, 4, 3, 2, 6],    // 6
        &[0, 1, 2],             // 7
        &[0, 1, 2, 3, 4, 5, 6], // 8
        &[6, 5, 0, 1, 2, 3],    // 9
    ];
    for &seg in segs[d % 10] {
        match seg {
            0 => (lo..=hi).for_each(|x| set(x, lo, &mut img)),       // a
            1 => (lo..=mid).for_each(|y| set(hi, y, &mut img)),      // b
            2 => (mid..=hi).for_each(|y| set(hi, y, &mut img)),      // c
            3 => (lo..=hi).for_each(|x| set(x, hi, &mut img)),       // d
            4 => (mid..=hi).for_each(|y| set(lo, y, &mut img)),      // e
            5 => (lo..=mid).for_each(|y| set(lo, y, &mut img)),      // f
            6 => (lo..=hi).for_each(|x| set(x, mid, &mut img)),      // g
            _ => unreachable!(),
        }
    }
    img
}

/// Noisy, shifted digit samples: `n` per class, `s x s` pixels.
pub fn digits(rng: &mut Rng, s: usize, n_per_class: usize, noise: f64) -> Dataset {
    let mut inputs = Vec::with_capacity(10 * n_per_class);
    let mut labels = Vec::with_capacity(10 * n_per_class);
    for class in 0..10usize {
        let proto = digit_prototype(class, s);
        for _ in 0..n_per_class {
            let (dx, dy) = (rng.int_range(-1, 1), rng.int_range(-1, 1));
            let mut img = vec![0.0f64; s * s];
            for y in 0..s {
                for x in 0..s {
                    let (sx, sy) = (x as i64 - dx, y as i64 - dy);
                    if (0..s as i64).contains(&sx) && (0..s as i64).contains(&sy) {
                        img[y * s + x] = proto[sy as usize * s + sx as usize];
                    }
                }
            }
            for p in img.iter_mut() {
                *p = (*p + noise * rng.normal()).clamp(0.0, 1.0);
            }
            inputs.push(img);
            labels.push(class);
        }
    }
    Dataset { input_shape: vec![s * s], inputs, labels }
}

/// Pendulum-state samples over the Lyapunov-verification box `[-6, 6]²`.
pub fn pendulum_grid(per_axis: usize) -> Dataset {
    let mut inputs = Vec::with_capacity(per_axis * per_axis);
    for i in 0..per_axis {
        for j in 0..per_axis {
            let x = -6.0 + 12.0 * (i as f64) / (per_axis - 1) as f64;
            let y = -6.0 + 12.0 * (j as f64) / (per_axis - 1) as f64;
            inputs.push(vec![x, y]);
        }
    }
    Dataset { input_shape: vec![2], inputs, labels: Vec::new() }
}

/// Random low-resolution RGB "image" samples with class-dependent color
/// statistics (for CNN smoke tests).
pub fn color_blobs(rng: &mut Rng, s: usize, classes: usize, n_per_class: usize) -> Dataset {
    let mut inputs = Vec::new();
    let mut labels = Vec::new();
    for class in 0..classes {
        let phase = class as f64 / classes as f64;
        for _ in 0..n_per_class {
            let mut img = Vec::with_capacity(s * s * 3);
            let (cx, cy) = (rng.range(0.3, 0.7) * s as f64, rng.range(0.3, 0.7) * s as f64);
            for y in 0..s {
                for x in 0..s {
                    let d = ((x as f64 - cx).powi(2) + (y as f64 - cy).powi(2)).sqrt() / s as f64;
                    let base = (1.0 - d).max(0.0);
                    img.push((base * (0.3 + 0.7 * phase) + 0.05 * rng.normal()).clamp(0.0, 1.0));
                    img.push((base * (1.0 - phase) + 0.05 * rng.normal()).clamp(0.0, 1.0));
                    img.push((0.5 * base + 0.05 * rng.normal()).clamp(0.0, 1.0));
                }
            }
            inputs.push(img);
            labels.push(class);
        }
    }
    Dataset { input_shape: vec![s, s, 3], inputs, labels }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prototypes_distinct() {
        let s = 12;
        let protos: Vec<Vec<f64>> = (0..10).map(|d| digit_prototype(d, s)).collect();
        for i in 0..10 {
            for j in (i + 1)..10 {
                assert_ne!(protos[i], protos[j], "digits {i} and {j} identical");
            }
        }
    }

    #[test]
    fn digits_shapes_and_ranges() {
        let mut rng = Rng::new(3);
        let d = digits(&mut rng, 12, 4, 0.1);
        assert_eq!(d.len(), 40);
        assert_eq!(d.input_shape, vec![144]);
        assert!(d
            .inputs
            .iter()
            .all(|img| img.iter().all(|&p| (0.0..=1.0).contains(&p))));
        assert_eq!(d.class_representatives().len(), 10);
    }

    #[test]
    fn pendulum_grid_covers_box() {
        let d = pendulum_grid(5);
        assert_eq!(d.len(), 25);
        assert_eq!(d.inputs[0], vec![-6.0, -6.0]);
        assert_eq!(d.inputs[24], vec![6.0, 6.0]);
    }

    #[test]
    fn color_blobs_shape() {
        let mut rng = Rng::new(4);
        let d = color_blobs(&mut rng, 8, 3, 2);
        assert_eq!(d.len(), 6);
        assert_eq!(d.input_shape, vec![8, 8, 3]);
        assert_eq!(d.inputs[0].len(), 192);
    }
}
