//! The [`Session`](super::Session) model cache: a small LRU keyed by file
//! path, validated by content hash. Each entry holds the parsed model
//! **and its compiled analysis [`Plan`]**, so repeated requests against
//! the same model file skip both the JSON parse (the dominant cost for
//! large weight files) and the plan compile; an edited file is
//! transparently re-parsed and re-compiled because its content hash no
//! longer matches.

use crate::model::{model_from_json, Model};
use crate::plan::Plan;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// FNV-1a over the raw file bytes — cheap, dependency-free, and collision
/// resistance far beyond what "did this file change between two requests"
/// needs.
pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Snapshot of cache effectiveness counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Requests answered without re-parsing the model JSON.
    pub hits: u64,
    /// Requests that had to parse (cold, evicted, or content changed).
    pub misses: u64,
    /// Models currently resident.
    pub entries: usize,
    /// Maximum resident models before LRU eviction.
    pub capacity: usize,
}

struct CacheEntry {
    content_hash: u64,
    model: Arc<Model>,
    /// The compiled analysis plan ([`Plan::for_analysis`]) — cached next
    /// to the model so every `Session` request skips recompilation.
    plan: Arc<Plan>,
    /// Content version of this path: 1 on first load, +1 every time the
    /// file's content hash changes. Hot-swap consumers
    /// ([`crate::api::FleetHandle::deploy_path`]) compare versions to
    /// decide whether a redeploy is a real swap or a no-op.
    version: u64,
    last_used: u64,
}

/// LRU model cache. Not internally synchronized — [`super::Session`] wraps
/// it in a `Mutex`.
pub(crate) struct ModelCache {
    capacity: usize,
    tick: u64,
    hits: u64,
    misses: u64,
    entries: HashMap<PathBuf, CacheEntry>,
    /// Content-version ledger `path -> (hash, version)`. Deliberately
    /// *not* LRU-evicted (it holds no model or plan, just two words per
    /// path ever seen), so a path reloaded after eviction resumes its
    /// version sequence instead of restarting at 1 — an edit across an
    /// eviction still reads as a version bump.
    versions: HashMap<PathBuf, (u64, u64)>,
}

/// Read a model file and hash its content — the part of a cached load
/// that must happen *outside* the cache lock (file I/O).
pub(crate) fn read_and_hash(path: &Path) -> Result<(String, u64)> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading model file {}", path.display()))?;
    let hash = fnv1a64(text.as_bytes());
    Ok((text, hash))
}

/// Parse model JSON text — also lock-free work.
pub(crate) fn parse_model(text: &str, path: &Path) -> Result<Arc<Model>> {
    let v = crate::json::parse(text)
        .with_context(|| format!("parsing model file {}", path.display()))?;
    Ok(Arc::new(
        model_from_json(&v).with_context(|| format!("model file {}", path.display()))?,
    ))
}

/// Compile the analysis plan for a freshly parsed model — lock-free work
/// staged outside the cache mutex like the parse itself.
pub(crate) fn compile_analysis(model: &Model, path: &Path) -> Result<Arc<Plan>> {
    Ok(Arc::new(Plan::for_analysis(model).with_context(|| {
        format!("compiling model file {}", path.display())
    })?))
}

impl ModelCache {
    pub(crate) fn new(capacity: usize) -> ModelCache {
        ModelCache {
            capacity: capacity.max(1),
            tick: 0,
            hits: 0,
            misses: 0,
            entries: HashMap::new(),
            versions: HashMap::new(),
        }
    }

    /// Cache probe for a file whose content hash is already known. A
    /// mismatching hash counts as a miss (the file changed — the stale
    /// model must never be served).
    pub(crate) fn lookup(
        &mut self,
        path: &Path,
        content_hash: u64,
    ) -> Option<(Arc<Model>, Arc<Plan>, u64)> {
        self.tick += 1;
        if let Some(e) = self.entries.get_mut(path) {
            if e.content_hash == content_hash {
                e.last_used = self.tick;
                self.hits += 1;
                return Some((Arc::clone(&e.model), Arc::clone(&e.plan), e.version));
            }
        }
        self.misses += 1;
        None
    }

    /// Insert a freshly parsed + compiled model, evicting the
    /// least-recently-used entry when at capacity. Returns the entry's
    /// content version (bumped when the path's content hash changed since
    /// the previous insert, stable across same-content re-inserts).
    pub(crate) fn insert(
        &mut self,
        path: &Path,
        content_hash: u64,
        model: Arc<Model>,
        plan: Arc<Plan>,
    ) -> u64 {
        self.tick += 1;
        let version = match self.versions.get(path) {
            Some((h, v)) if *h == content_hash => *v,
            Some((_, v)) => v + 1,
            None => 1,
        };
        self.versions.insert(path.to_path_buf(), (content_hash, version));
        if !self.entries.contains_key(path) && self.entries.len() >= self.capacity {
            if let Some(lru) = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            {
                self.entries.remove(&lru);
            }
        }
        self.entries.insert(
            path.to_path_buf(),
            CacheEntry { content_hash, model, plan, version, last_used: self.tick },
        );
        version
    }

    /// Single-threaded convenience (unit tests): read + hash + probe +
    /// parse + compile + insert in one call. `Session::load_compiled`
    /// stages these around its mutex instead, so the lock is never held
    /// across I/O.
    #[cfg(test)]
    pub(crate) fn load(&mut self, path: &Path) -> Result<Arc<Model>> {
        let (text, hash) = read_and_hash(path)?;
        if let Some((m, _, _)) = self.lookup(path, hash) {
            return Ok(m);
        }
        let model = parse_model(&text, path)?;
        let plan = compile_analysis(&model, path)?;
        self.insert(path, hash, Arc::clone(&model), plan);
        Ok(model)
    }

    pub(crate) fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            entries: self.entries.len(),
            capacity: self.capacity,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("rigor_api_cache").join(name);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn fnv_distinguishes_contents() {
        assert_ne!(fnv1a64(b"a"), fnv1a64(b"b"));
        assert_eq!(fnv1a64(b"model"), fnv1a64(b"model"));
    }

    #[test]
    fn hit_on_second_load_miss_after_edit() {
        let dir = tmpdir("hits");
        let path = dir.join("m.json");
        zoo::tiny_mlp(1).save(&path).unwrap();

        let mut cache = ModelCache::new(4);
        let a = cache.load(&path).unwrap();
        let b = cache.load(&path).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second load must be served from cache");
        assert_eq!(cache.stats().hits, 1);
        assert_eq!(cache.stats().misses, 1);

        // Rewrite with a different model: the hash changes, so the cache
        // must re-parse rather than serve the stale weights.
        zoo::tiny_mlp(2).save(&path).unwrap();
        let c = cache.load(&path).unwrap();
        assert!(!Arc::ptr_eq(&a, &c), "edited file must not be served stale");
        assert_eq!(cache.stats().misses, 2);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let dir = tmpdir("lru");
        let paths: Vec<PathBuf> = (0..3)
            .map(|i| {
                let p = dir.join(format!("m{i}.json"));
                zoo::tiny_mlp(i as u64).save(&p).unwrap();
                p
            })
            .collect();
        let mut cache = ModelCache::new(2);
        cache.load(&paths[0]).unwrap();
        cache.load(&paths[1]).unwrap();
        cache.load(&paths[0]).unwrap(); // 0 is now most recent
        cache.load(&paths[2]).unwrap(); // evicts 1
        assert_eq!(cache.stats().entries, 2);
        cache.load(&paths[0]).unwrap();
        assert_eq!(cache.stats().hits, 2, "path 0 must still be resident");
        cache.load(&paths[1]).unwrap();
        assert_eq!(cache.stats().misses, 4, "path 1 must have been evicted");
    }

    #[test]
    fn content_versions_bump_on_edit_and_survive_eviction() {
        let dir = tmpdir("versions");
        let path = dir.join("m.json");
        let other = dir.join("other.json");
        zoo::tiny_mlp(1).save(&path).unwrap();
        zoo::tiny_mlp(9).save(&other).unwrap();

        let mut cache = ModelCache::new(1);
        let (text, hash) = read_and_hash(&path).unwrap();
        let model = parse_model(&text, &path).unwrap();
        let plan = compile_analysis(&model, &path).unwrap();
        let v1 = cache.insert(&path, hash, Arc::clone(&model), Arc::clone(&plan));
        assert_eq!(v1, 1);
        // Same content re-inserted (racing loaders): version is stable.
        assert_eq!(cache.insert(&path, hash, Arc::clone(&model), Arc::clone(&plan)), 1);
        assert_eq!(cache.lookup(&path, hash).unwrap().2, 1);

        // Evict the entry (capacity 1), then reload an *edited* file: the
        // ledger survives eviction, so the edit still reads as a bump.
        cache.load(&other).unwrap();
        zoo::tiny_mlp(2).save(&path).unwrap();
        let (text2, hash2) = read_and_hash(&path).unwrap();
        assert_ne!(hash, hash2, "different weights must hash differently");
        let model2 = parse_model(&text2, &path).unwrap();
        let plan2 = compile_analysis(&model2, &path).unwrap();
        assert_eq!(cache.insert(&path, hash2, model2, plan2), 2);
    }

    #[test]
    fn missing_file_is_a_clean_error() {
        let mut cache = ModelCache::new(2);
        let err = cache.load(Path::new("/nonexistent/model.json")).unwrap_err();
        assert!(err.to_string().contains("reading model file"), "{err}");
    }
}
