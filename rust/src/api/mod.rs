//! **The service API — the one public front door to the analysis engine.**
//!
//! Every consumer (CLI, benches, examples, tests, and eventually network
//! front ends) talks to a [`Session`]: a long-lived service object that
//! owns the worker [`Pool`] and an LRU model cache, accepts declarative
//! [`AnalysisRequest`]s, and returns [`AnalysisOutcome`]s with a stable,
//! versioned JSON serialization. Each cached model carries its compiled
//! [`crate::plan::Plan`]; every analysis the session serves executes
//! through that plan's arena-backed executor (one arena per worker
//! thread), not the legacy per-layer interpreter. Bulk workloads have two
//! dedicated doors: [`Session::run_batch`] returns a per-sample
//! [`AnalysisOutcome`] for every dataset sample in micro-batched chunks,
//! and [`Session::serve`] spawns a [`crate::serve::MicroBatcher`] that
//! coalesces individual inference requests into single batched plan
//! drives.
//!
//! ```no_run
//! use rigor::api::{AnalysisRequest, ExecMode, Session};
//!
//! # fn main() -> anyhow::Result<()> {
//! let session = Session::new();
//! let req = AnalysisRequest::builder()
//!     .model_path("artifacts/models/digits.json")
//!     .data_path("artifacts/data/digits_eval.json")
//!     .p_star(0.60)
//!     .exact_inputs(true)
//!     .mode(ExecMode::Pooled { workers: 0 })
//!     .build()?;
//! let outcome = session.run(&req)?;
//! println!("{}", outcome.to_json_string());
//! # Ok(())
//! # }
//! ```
//!
//! The free functions this replaces —
//! [`analysis::analyze_model`](crate::analysis::analyze_model),
//! [`coordinator::analyze_model_parallel`](crate::coordinator::analyze_model_parallel)
//! and [`coordinator::BatchRequest`](crate::coordinator::BatchRequest) —
//! remain as thin `#[deprecated]` shims.

mod cache;
mod outcome;
mod request;

pub use cache::CacheStats;
pub use outcome::{AnalysisOutcome, SCHEMA_VERSION};
pub use request::{AnalysisRequest, AnalysisRequestBuilder, DataRef, ExecMode, ModelRef, ProgressFn};

// Re-exported so API consumers need no imports from the engine layer.
pub use crate::analysis::{ClassAnalysis, ModelAnalysis};

use crate::analysis::{self, mixed};
use crate::coordinator::Pool;
use crate::data::Dataset;
use crate::fleet::{AdmitError, Fleet, FleetPolicy, FleetSnapshot};
use crate::model::Model;
use crate::plan::{Parallelism, Plan, ServeFormat};
use crate::serve::{BatchPolicy, MicroBatcher, Ticket};
use crate::util::Stopwatch;
use anyhow::Result;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A long-lived analysis service: worker pool + model cache. Cheap to keep
/// around, safe to share behind an `Arc` (all methods take `&self`).
pub struct Session {
    /// Shared with [`MicroBatcher`]s spawned by [`Session::serve`], whose
    /// flusher threads submit batch jobs after `&self` borrows end.
    pool: Arc<Pool>,
    cache: Mutex<cache::ModelCache>,
    /// Compiled analysis plans for inline (`ModelRef::Inline`) models,
    /// keyed by the model allocation itself (`Weak<Model>`): repeated
    /// requests against the same `Arc<Model>` — sweep loops, batch
    /// workloads — compile once. Identity is sound because a hit requires
    /// the weak to upgrade to the *same live allocation* as the request's
    /// Arc (no ABA). Bounded; dead entries are evicted on insert.
    inline_plans: Mutex<Vec<(std::sync::Weak<Model>, Arc<Plan>)>>,
}

/// Configures a [`Session`]. Zero-config default: one worker per available
/// core, a 16-model cache.
pub struct SessionBuilder {
    workers: Option<usize>,
    cache_capacity: usize,
}

impl SessionBuilder {
    /// Worker-pool size. Unset = `std::thread::available_parallelism()`.
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = Some(workers.max(1));
        self
    }

    /// Maximum resident models in the LRU cache (minimum 1).
    pub fn cache_capacity(mut self, capacity: usize) -> Self {
        self.cache_capacity = capacity;
        self
    }

    /// Finish the builder and start the session's worker pool.
    pub fn build(self) -> Session {
        let pool = match self.workers {
            Some(w) => Pool::new(w, w * 4),
            None => Pool::with_default_workers(),
        };
        Session {
            pool: Arc::new(pool),
            cache: Mutex::new(cache::ModelCache::new(self.cache_capacity)),
            inline_plans: Mutex::new(Vec::new()),
        }
    }
}

impl Default for Session {
    fn default() -> Session {
        Session::new()
    }
}

impl Session {
    /// A session with default sizing (host-parallel pool, 16-model cache).
    pub fn new() -> Session {
        Session::builder().build()
    }

    /// Start configuring a session (worker count, cache capacity).
    pub fn builder() -> SessionBuilder {
        SessionBuilder { workers: None, cache_capacity: 16 }
    }

    /// The session's shared worker pool (metrics, direct job submission).
    pub fn pool(&self) -> &Pool {
        self.pool.as_ref()
    }

    /// Model-cache effectiveness counters.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.lock().unwrap().stats()
    }

    /// The unified observability snapshot with this session's pool
    /// counters attached — latency percentiles, executor gauges, trace
    /// state and the last bound profile (see [`crate::obs`]). Serve/fleet
    /// queue sections are attached by their owners (e.g.
    /// [`FleetHandle::snapshot`] for fleet queues).
    pub fn obs_snapshot(&self) -> crate::obs::Snapshot {
        crate::obs::Snapshot::capture().with_pool(self.pool.metrics())
    }

    /// Load a model through the session cache (content-hash validated).
    pub fn load_model(&self, path: &Path) -> Result<Arc<Model>> {
        Ok(self.load_compiled(path)?.0)
    }

    /// Load a model **and its compiled analysis plan** through the session
    /// cache (content-hash validated). File I/O, JSON parsing and the plan
    /// compile happen outside the cache lock, so concurrent requests for
    /// different models don't serialize; two threads racing on the same
    /// cold model may both parse+compile it (last insert wins), which is
    /// benign.
    pub fn load_compiled(&self, path: &Path) -> Result<(Arc<Model>, Arc<Plan>)> {
        let (model, plan, _) = self.load_compiled_versioned(path)?;
        Ok((model, plan))
    }

    /// [`Self::load_compiled`] that also returns the cache entry's
    /// **content version** — 1 on first load, bumped each time the file's
    /// content hash changes (stable across eviction). Hot-swap consumers
    /// ([`FleetHandle::deploy_path`]) compare versions to distinguish a
    /// real redeploy from a no-op.
    pub fn load_compiled_versioned(&self, path: &Path) -> Result<(Arc<Model>, Arc<Plan>, u64)> {
        let (text, hash) = cache::read_and_hash(path)?;
        if let Some(hit) = self.cache.lock().unwrap().lookup(path, hash) {
            return Ok(hit);
        }
        let model = cache::parse_model(&text, path)?;
        let plan = cache::compile_analysis(&model, path)?;
        let version = self
            .cache
            .lock()
            .unwrap()
            .insert(path, hash, Arc::clone(&model), Arc::clone(&plan));
        Ok((model, plan, version))
    }

    fn resolve(&self, req: &AnalysisRequest) -> Result<(Arc<Model>, Arc<Plan>, Arc<Dataset>)> {
        let (model, plan) = match &req.model {
            ModelRef::Path(p) => self.load_compiled(p)?,
            ModelRef::Inline(m) => (Arc::clone(m), self.inline_plan(m)?),
        };
        let data = self.resolve_data(req, &model)?;
        Ok((model, plan, data))
    }

    /// Analysis plan for an inline model, memoized by allocation identity
    /// so repeated requests against the same `Arc<Model>` compile once.
    fn inline_plan(&self, model: &Arc<Model>) -> Result<Arc<Plan>> {
        const MAX_INLINE_PLANS: usize = 8;
        {
            let plans = self.inline_plans.lock().unwrap();
            for (weak, plan) in plans.iter() {
                if let Some(live) = weak.upgrade() {
                    if Arc::ptr_eq(&live, model) {
                        return Ok(Arc::clone(plan));
                    }
                }
            }
        }
        // Compile outside the lock (racing threads may both compile; the
        // duplicate insert is benign).
        let plan = Arc::new(Plan::for_analysis(model)?);
        let mut plans = self.inline_plans.lock().unwrap();
        plans.retain(|(weak, _)| weak.strong_count() > 0);
        if plans.len() >= MAX_INLINE_PLANS {
            plans.remove(0);
        }
        plans.push((Arc::downgrade(model), Arc::clone(&plan)));
        Ok(plan)
    }

    /// [`Self::resolve`] without the analysis-plan compile — for paths
    /// that compile their own plan flavor (mixed tuning needs an unfused
    /// one), so no throwaway `Fusion::Pair` compile happens for inline
    /// models. Path-based models still go through the cache (the cached
    /// plan rides along for free).
    fn resolve_uncompiled(&self, req: &AnalysisRequest) -> Result<(Arc<Model>, Arc<Dataset>)> {
        let model = match &req.model {
            ModelRef::Path(p) => self.load_compiled(p)?.0,
            ModelRef::Inline(m) => Arc::clone(m),
        };
        let data = self.resolve_data(req, &model)?;
        Ok((model, data))
    }

    fn resolve_data(&self, req: &AnalysisRequest, model: &Model) -> Result<Arc<Dataset>> {
        Ok(match &req.data {
            DataRef::Path(p) => Arc::new(Dataset::load(p)?),
            DataRef::Inline(d) => Arc::clone(d),
            DataRef::InputBox => Arc::new(Dataset {
                input_shape: model.input_shape.clone(),
                inputs: vec![vec![0.0; model.input_shape.iter().product()]],
                labels: vec![],
            }),
        })
    }

    /// Serve one analysis request: one CAA run per class representative,
    /// serial or fanned out per [`ExecMode`], streamed through the
    /// request's progress callback if one is set. Every run executes
    /// through the compiled analysis [`Plan`] (cached for path-based
    /// models), never the per-layer interpreter — so sequential and graph
    /// (residual/branchy) models take the identical path.
    ///
    /// ```
    /// use rigor::api::{AnalysisRequest, Session};
    /// use rigor::model::zoo;
    ///
    /// let session = Session::builder().workers(1).build();
    /// // Analyze a residual (skip-connection) model over the input box.
    /// let req = AnalysisRequest::builder()
    ///     .model(zoo::residual_mlp(7))
    ///     .input_box()
    ///     .build()?;
    /// let outcome = session.run(&req)?;
    /// assert!(outcome.analysis.max_abs_u.is_finite());
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn run(&self, req: &AnalysisRequest) -> Result<AnalysisOutcome> {
        let (model, plan, data) = self.resolve(req)?;
        self.run_resolved(req, &model, &plan, &data)
    }

    /// [`Self::run`] with model, plan and data already resolved — the
    /// tailoring loop calls this so path-based requests are read, parsed
    /// and compiled once, not once per candidate precision.
    fn run_resolved(
        &self,
        req: &AnalysisRequest,
        model: &Arc<Model>,
        plan: &Arc<Plan>,
        data: &Arc<Dataset>,
    ) -> Result<AnalysisOutcome> {
        let cfg = req.analysis_config();
        let sw = Stopwatch::start();
        let reps = analysis::representatives(&data);
        let per_class = match req.mode {
            ExecMode::Serial => {
                let mut v = Vec::with_capacity(reps.len());
                for (class, idx) in reps {
                    let c =
                        analysis::analyze_class_with_plan(&plan, &cfg, class, &data.inputs[idx])?;
                    if let Some(cb) = &req.progress {
                        (cb.as_ref())(&c);
                    }
                    v.push(c);
                }
                v
            }
            ExecMode::Pooled { workers } => {
                let jobs: Vec<(usize, Vec<f64>)> = reps
                    .into_iter()
                    .map(|(class, idx)| (class, data.inputs[idx].clone()))
                    .collect();
                let job = {
                    let plan = Arc::clone(plan);
                    let cfg = cfg.clone();
                    let progress = req.progress.clone();
                    move |(class, sample): (usize, Vec<f64>)| {
                        let r = analysis::analyze_class_with_plan(&plan, &cfg, class, &sample);
                        if let (Ok(c), Some(cb)) = (&r, &progress) {
                            (cb.as_ref())(c);
                        }
                        r
                    }
                };
                let results = if workers == 0 {
                    self.pool.run_batch(jobs, job)
                } else {
                    Pool::new(workers, workers * 4).run_batch(jobs, job)
                };
                let mut v = Vec::with_capacity(results.len());
                for r in results {
                    v.push(r?);
                }
                v.sort_by_key(|c| c.class);
                v
            }
        };
        Ok(AnalysisOutcome::new(analysis::aggregate(&model, &cfg, per_class, sw.secs())))
    }

    /// Serve a batch of requests (the multi-model workload `BatchRequest`
    /// used to express). Requests run in order; each is internally
    /// parallel per its own [`ExecMode`].
    pub fn run_all(&self, reqs: &[AnalysisRequest]) -> Result<Vec<AnalysisOutcome>> {
        reqs.iter().map(|r| self.run(r)).collect()
    }

    /// Bulk per-sample analysis: one [`AnalysisOutcome`] for **every**
    /// sample of the request's dataset (where [`Session::run`] analyzes
    /// one representative per class), scheduled in micro-batches of
    /// [`AnalysisRequest::max_batch`] samples. Each chunk is one job —
    /// run inline for [`ExecMode::Serial`], fanned over the pool for
    /// [`ExecMode::Pooled`] — inside which the CAA runs stay per-sample
    /// (`B = 1`; see the [`crate::serve`] docs for why CAA does not batch
    /// its *arithmetic*) while the chunking amortizes job dispatch and
    /// keeps each worker's plan/arena hot across consecutive samples.
    /// Outcomes return in dataset order; each outcome's single per-class
    /// entry carries the sample's label as `class` (falling back to the
    /// dataset index when the sample has no label). The request's
    /// progress callback streams every completed sample.
    ///
    /// ```
    /// use rigor::api::{AnalysisRequest, Session};
    /// use rigor::data::Dataset;
    /// use rigor::model::zoo;
    ///
    /// let session = Session::builder().workers(1).build();
    /// let data = Dataset {
    ///     input_shape: vec![8],
    ///     inputs: (0..5).map(|i| vec![i as f64 / 5.0; 8]).collect(),
    ///     labels: vec![0, 1, 2, 0, 1],
    /// };
    /// let req = AnalysisRequest::builder()
    ///     .model(zoo::tiny_mlp(3))
    ///     .data(data)
    ///     .max_batch(2)
    ///     .build()?;
    /// let outcomes = session.run_batch(&req)?;
    /// assert_eq!(outcomes.len(), 5); // one per sample, in dataset order
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn run_batch(&self, req: &AnalysisRequest) -> Result<Vec<AnalysisOutcome>> {
        let (model, plan, data) = self.resolve(req)?;
        let cfg = req.analysis_config();
        // Chunks are built directly from the dataset (each sample cloned
        // once, into its chunk); a missing label falls back to the sample
        // index rather than indexing out of bounds on hand-built datasets.
        let chunk_size = req.max_batch.max(1);
        let jobs: Vec<Vec<(usize, Vec<f64>)>> = (0..data.inputs.len())
            .step_by(chunk_size)
            .map(|start| {
                (start..(start + chunk_size).min(data.inputs.len()))
                    .map(|i| (data.labels.get(i).copied().unwrap_or(i), data.inputs[i].clone()))
                    .collect()
            })
            .collect();
        let run_chunk = {
            let plan = Arc::clone(&plan);
            let cfg = cfg.clone();
            let progress = req.progress.clone();
            move |chunk: Vec<(usize, Vec<f64>)>| -> Vec<Result<analysis::ClassAnalysis>> {
                chunk
                    .into_iter()
                    .map(|(class, sample)| {
                        let r = analysis::analyze_class_with_plan(&plan, &cfg, class, &sample);
                        if let (Ok(c), Some(cb)) = (&r, &progress) {
                            (cb.as_ref())(c);
                        }
                        r
                    })
                    .collect()
            }
        };
        let chunk_results: Vec<Vec<Result<analysis::ClassAnalysis>>> = match req.mode {
            ExecMode::Serial => jobs.into_iter().map(&run_chunk).collect(),
            ExecMode::Pooled { workers } => {
                if workers == 0 {
                    self.pool.run_batch(jobs, run_chunk)
                } else {
                    Pool::new(workers, workers * 4).run_batch(jobs, run_chunk)
                }
            }
        };
        let mut outcomes = Vec::with_capacity(data.inputs.len());
        for r in chunk_results.into_iter().flatten() {
            let c = r?;
            let secs = c.secs;
            outcomes.push(AnalysisOutcome::new(analysis::aggregate(&model, &cfg, vec![c], secs)));
        }
        Ok(outcomes)
    }

    /// A [`MicroBatcher`] serving the request's model on this session's
    /// worker pool: f64 inference traffic accumulated per the request's
    /// [`max_batch`](AnalysisRequest::max_batch) /
    /// [`max_wait`](AnalysisRequest::max_wait) knobs, bounded by
    /// [`max_pending`](AnalysisRequest::max_pending) (submits block at
    /// the bound — backpressure), and executed as single batched plan
    /// drives — through the blocked kernels unless the request set
    /// [`force_scalar_kernels`](AnalysisRequest::force_scalar_kernels)
    /// (bit-identical either way). For f64 traffic the served plan is the
    /// session's cached *analysis* plan, so every served trace is exactly
    /// the computation the CAA bounds cover; a request built with
    /// [`emulated_k`](AnalysisRequestBuilder::emulated_k) serves
    /// **emulated-`k` arithmetic** instead, through the unfused
    /// witness-convention plan ([`Plan::for_format`]) so every served
    /// result is bit-identical to the offline
    /// [`emulated_forward`](crate::quant::emulated_forward) witness. The
    /// request's data reference is ignored — serving traffic arrives
    /// through [`MicroBatcher::submit`](crate::serve::MicroBatcher::submit).
    pub fn serve(&self, req: &AnalysisRequest) -> Result<MicroBatcher> {
        let format = req.serve_format();
        let plan = match format {
            ServeFormat::F64 => match &req.model {
                ModelRef::Path(p) => self.load_compiled(p)?.1,
                ModelRef::Inline(m) => self.inline_plan(m)?,
            },
            ServeFormat::Emulated { .. } => {
                // Emulated serving cannot reuse the cached analysis plan:
                // the certified emulated trace follows the *unfused* step
                // convention, so compile the format's own plan.
                let model = match &req.model {
                    ModelRef::Path(p) => self.load_compiled(p)?.0,
                    ModelRef::Inline(m) => Arc::clone(m),
                };
                Arc::new(Plan::for_format(&model, format)?)
            }
        };
        // The request's kernel escape hatch: serve the same (cached,
        // shared) plan but pin its executions to the scalar kernels.
        let kernels = if req.force_scalar_kernels {
            crate::plan::KernelPath::Scalar
        } else {
            plan.kernel_path()
        };
        // Per-drive parallelism: the request knob wins, otherwise the
        // `RIGOR_WORKERS` environment default (pool-sized fallback).
        let par = match req.parallel_workers {
            Some(w) => Parallelism::with_workers(w),
            None => Parallelism::from_env(self.pool.worker_count()),
        };
        Ok(MicroBatcher::with_parallelism(
            plan,
            Arc::clone(&self.pool),
            BatchPolicy {
                max_batch: req.max_batch,
                max_wait: req.max_wait,
                max_pending: req.max_pending,
                default_deadline: req.deadline_ms.map(Duration::from_millis),
            },
            kernels,
            format,
            par,
        ))
    }

    /// A multi-model serving [`FleetHandle`] on this session's worker
    /// pool with the default [`FleetPolicy`]: deploy models under string
    /// ids, submit precision-tagged samples, hot-swap under traffic. See
    /// [`crate::fleet`] for the scheduling semantics.
    pub fn fleet(&self) -> FleetHandle<'_> {
        self.fleet_with(FleetPolicy::default())
    }

    /// [`Session::fleet`] with explicit batching/admission knobs.
    pub fn fleet_with(&self, policy: FleetPolicy) -> FleetHandle<'_> {
        FleetHandle {
            session: self,
            fleet: Fleet::new(Arc::clone(&self.pool), policy),
            deployed: Mutex::new(HashMap::new()),
        }
    }

    /// The paper's §V semi-automatic precision-tailoring loop: re-run the
    /// analysis at `u_max = 2^(1-k)` for each candidate `k` and return the
    /// smallest `k` whose own bounds certify at the request's `p*`, with
    /// that certifying outcome. Candidates below `k = 3` are skipped
    /// (`u_max` would exceed the CAA validity range).
    pub fn certify_min_precision(
        &self,
        req: &AnalysisRequest,
        k_range: std::ops::RangeInclusive<u32>,
    ) -> Result<Option<(u32, AnalysisOutcome)>> {
        // Resolve once: path-based model/data are read, parsed and
        // compiled a single time for the whole loop, not once per
        // candidate k (the plan is shape-only, so it is valid at every
        // u_max).
        let (model, plan, data) = self.resolve(req)?;
        for k in k_range {
            if k < 3 {
                continue;
            }
            let outcome = self.run_resolved(&req.at_precision(k), &model, &plan, &data)?;
            if let Some(rk) = outcome.required_k() {
                if rk <= k {
                    return Ok(Some((k, outcome)));
                }
            }
        }
        Ok(None)
    }

    /// Greedy per-layer mixed-precision tuning (paper §VI) starting from a
    /// certified uniform precision `k_uniform`, lowering layers toward
    /// `k_floor`.
    pub fn tune_mixed(
        &self,
        req: &AnalysisRequest,
        k_uniform: u32,
        k_floor: u32,
    ) -> Result<mixed::MixedAnalysis> {
        // The mixed path compiles its own *unfused* plan internally: per
        // layer format boundaries need the 1:1 step-per-layer mapping, not
        // the session's fused analysis plan.
        let (model, data) = self.resolve_uncompiled(req)?;
        let cfg = req.analysis_config();
        mixed::tune_mixed(&model, &data, &cfg, k_uniform, k_floor)
    }
}

/// The session's multi-model serving front end: a [`Fleet`] on the
/// session's worker pool, plus cache-integrated deployment. Where the
/// bare fleet deploys in-memory [`Model`]s, the handle also deploys from
/// model *files* through the session's content-hash LRU
/// ([`FleetHandle::deploy_path`]): the cache's content **versions** make
/// redeploying an unchanged file a no-op and an edited file a real hot
/// swap — in-flight tickets drain on the old plans either way.
///
/// Dropping the handle shuts the fleet down (drains every queue, resolves
/// every admitted ticket).
pub struct FleetHandle<'s> {
    session: &'s Session,
    fleet: Fleet,
    /// `model_id -> (file, cache content version)` of the last path-based
    /// deploy — the no-op-redeploy ledger.
    deployed: Mutex<HashMap<String, (PathBuf, u64)>>,
}

impl FleetHandle<'_> {
    /// Deploy (or hot-swap) an in-memory model under `model_id`. Returns
    /// the fleet's deployment version. See [`Fleet::deploy`].
    pub fn deploy(&self, model_id: &str, model: &Model) -> Result<u64> {
        self.fleet.deploy(model_id, model)
    }

    /// Deploy (or hot-swap) the model stored at `path` under `model_id`,
    /// loaded through the session's content-hash LRU cache. Redeploying
    /// the same path with unchanged content is a **no-op** (no swap, no
    /// recompile beyond the cache probe); an edited file bumps the cache's
    /// content version and performs a real hot swap. Returns the fleet's
    /// deployment version either way.
    pub fn deploy_path(&self, model_id: &str, path: &Path) -> Result<u64> {
        let (model, _plan, cache_version) = self.session.load_compiled_versioned(path)?;
        let mut deployed = self.deployed.lock().unwrap();
        if let Some((p, v)) = deployed.get(model_id) {
            if p == path && *v == cache_version {
                if let Some(fv) = self.fleet.version(model_id) {
                    return Ok(fv);
                }
            }
        }
        let fv = self.fleet.deploy(model_id, &model)?;
        deployed.insert(model_id.to_string(), (path.to_path_buf(), cache_version));
        Ok(fv)
    }

    /// Submit one `format`-tagged sample for `model_id` (non-blocking
    /// typed admission). See [`Fleet::submit`].
    pub fn submit(
        &self,
        model_id: &str,
        format: ServeFormat,
        sample: Vec<f64>,
    ) -> std::result::Result<Ticket, AdmitError> {
        self.fleet.submit(model_id, format, sample)
    }

    /// Blocking submit (backpressure instead of typed rejection on the
    /// queue caps). See [`Fleet::submit_blocking`].
    pub fn submit_blocking(
        &self,
        model_id: &str,
        format: ServeFormat,
        sample: Vec<f64>,
    ) -> std::result::Result<Ticket, AdmitError> {
        self.fleet.submit_blocking(model_id, format, sample)
    }

    /// Per-queue and fleet-wide counters. See [`Fleet::snapshot`].
    pub fn snapshot(&self) -> FleetSnapshot {
        self.fleet.snapshot()
    }

    /// Lift the quarantine on the `(model_id, format)` queue — the manual
    /// operator escape hatch after a fault-budget trip. Returns `false` if
    /// the queue does not exist or is not quarantined. Hot-swapping the
    /// model ([`FleetHandle::deploy`]) clears quarantines too. See
    /// [`Fleet::reinstate`].
    pub fn reinstate(&self, model_id: &str, format: ServeFormat) -> bool {
        self.fleet.reinstate(model_id, format)
    }

    /// The underlying scheduler, for knobs the handle doesn't re-export.
    pub fn fleet(&self) -> &Fleet {
        &self.fleet
    }

    /// Drain and stop the fleet (also run on drop). See
    /// [`Fleet::shutdown`].
    pub fn shutdown(&self) {
        self.fleet.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::util::Rng;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn digits_like() -> Dataset {
        let mut rng = Rng::new(1);
        let inputs: Vec<Vec<f64>> = (0..6)
            .map(|_| (0..8).map(|_| rng.range(0.0, 1.0)).collect())
            .collect();
        Dataset { input_shape: vec![8], inputs, labels: vec![0, 1, 2, 0, 1, 2] }
    }

    #[test]
    fn serial_and_pooled_agree_exactly() {
        let session = Session::builder().workers(4).build();
        let base = AnalysisRequest::builder()
            .model(zoo::tiny_mlp(42))
            .data(digits_like());
        let req_serial = base.build().unwrap();
        let req_pooled = AnalysisRequest::builder()
            .model(zoo::tiny_mlp(42))
            .data(digits_like())
            .mode(ExecMode::Pooled { workers: 0 })
            .build()
            .unwrap();
        let a = session.run(&req_serial).unwrap().analysis;
        let b = session.run(&req_pooled).unwrap().analysis;
        assert_eq!(a.per_class.len(), b.per_class.len());
        for (x, y) in a.per_class.iter().zip(&b.per_class) {
            assert_eq!(x.class, y.class);
            assert_eq!(x.max_abs_u, y.max_abs_u);
            assert_eq!(x.max_rel_u, y.max_rel_u);
            assert_eq!(x.predicted, y.predicted);
        }
        assert_eq!(a.required_k, b.required_k);
    }

    #[test]
    fn run_batch_matches_per_sample_analysis_in_both_modes() {
        let session = Session::builder().workers(2).build();
        let data = digits_like();
        let req = AnalysisRequest::builder()
            .model(zoo::tiny_mlp(42))
            .data(digits_like())
            .max_batch(2)
            .build()
            .unwrap();
        let outcomes = session.run_batch(&req).unwrap();
        assert_eq!(outcomes.len(), data.inputs.len(), "one outcome per sample");

        // Reference: the same per-sample analysis through a hand-compiled
        // analysis plan.
        let plan = crate::plan::Plan::for_analysis(&zoo::tiny_mlp(42)).unwrap();
        let cfg = req.analysis_config();
        for (i, out) in outcomes.iter().enumerate() {
            let c = crate::analysis::analyze_class_with_plan(
                &plan,
                &cfg,
                data.labels[i],
                &data.inputs[i],
            )
            .unwrap();
            assert_eq!(out.analysis.per_class.len(), 1);
            assert_eq!(out.analysis.per_class[0].class, data.labels[i], "sample {i}");
            assert_eq!(
                out.analysis.max_abs_u.to_bits(),
                c.max_abs_u.to_bits(),
                "sample {i}: abs bound"
            );
            assert_eq!(
                out.analysis.max_rel_u.to_bits(),
                c.max_rel_u.to_bits(),
                "sample {i}: rel bound"
            );
        }

        // Pooled chunks agree exactly and preserve dataset order.
        let req_pooled = AnalysisRequest::builder()
            .model(zoo::tiny_mlp(42))
            .data(digits_like())
            .max_batch(2)
            .mode(ExecMode::Pooled { workers: 0 })
            .build()
            .unwrap();
        let pooled = session.run_batch(&req_pooled).unwrap();
        assert_eq!(pooled.len(), outcomes.len());
        for (a, b) in outcomes.iter().zip(&pooled) {
            assert_eq!(a.analysis.per_class[0].class, b.analysis.per_class[0].class);
            assert_eq!(a.analysis.max_abs_u.to_bits(), b.analysis.max_abs_u.to_bits());
        }
    }

    #[test]
    fn run_batch_tolerates_partially_labeled_datasets() {
        // Hand-built datasets can carry fewer labels than samples; the
        // per-sample class falls back to the dataset index instead of
        // panicking on an out-of-bounds label lookup.
        let session = Session::builder().workers(1).build();
        let data = Dataset {
            input_shape: vec![8],
            inputs: (0..3).map(|i| vec![i as f64 / 3.0; 8]).collect(),
            labels: vec![7],
        };
        let req = AnalysisRequest::builder()
            .model(zoo::tiny_mlp(5))
            .data(data)
            .max_batch(2)
            .build()
            .unwrap();
        let outcomes = session.run_batch(&req).unwrap();
        assert_eq!(outcomes.len(), 3);
        assert_eq!(outcomes[0].analysis.per_class[0].class, 7);
        assert_eq!(outcomes[1].analysis.per_class[0].class, 1, "index fallback");
        assert_eq!(outcomes[2].analysis.per_class[0].class, 2, "index fallback");
    }

    #[test]
    fn serve_front_door_matches_plan_trace() {
        let session = Session::builder().workers(2).build();
        let req = AnalysisRequest::builder()
            .model(zoo::tiny_mlp(42))
            .input_box()
            .max_batch(4)
            .max_wait_ms(1)
            .build()
            .unwrap();
        let batcher = session.serve(&req).unwrap();
        let sample: Vec<f64> = (0..8).map(|i| i as f64 / 8.0).collect();
        let got = batcher.submit(sample.clone()).unwrap().wait().unwrap();
        let plan = crate::plan::Plan::for_analysis(&zoo::tiny_mlp(42)).unwrap();
        let mut arena = crate::plan::Arena::new();
        let want = plan.execute::<f64>(&(), &sample, &mut arena).unwrap();
        assert_eq!(got, want, "served trace must equal the analysis plan's f64 trace");
    }

    #[test]
    fn serve_emulated_k_matches_offline_witness_bitwise() {
        let session = Session::builder().workers(2).build();
        let req = AnalysisRequest::builder()
            .model(zoo::tiny_mlp(42))
            .input_box()
            .max_batch(4)
            .max_wait_ms(1)
            .emulated_k(10)
            .build()
            .unwrap();
        let batcher = session.serve(&req).unwrap();
        let sample: Vec<f64> = (0..8).map(|i| i as f64 / 8.0).collect();
        let got = batcher.submit(sample.clone()).unwrap().wait().unwrap();
        let plan = crate::plan::Plan::unfused(&zoo::tiny_mlp(42)).unwrap();
        let want = crate::quant::emulated_forward(&plan, 10, &sample).unwrap();
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.to_bits(), w.to_bits(), "served emulated trace must equal the witness");
        }
    }

    #[test]
    fn fleet_front_door_routes_and_hot_swaps_via_cache_versions() {
        let dir = std::env::temp_dir().join("rigor_api_fleet");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.json");
        zoo::tiny_mlp(61).save(&path).unwrap();

        let session = Session::builder().workers(2).build();
        let fleet = session.fleet();
        assert_eq!(fleet.deploy_path("m", &path).unwrap(), 1);
        // Unchanged file: redeploy is a no-op, not a swap.
        assert_eq!(fleet.deploy_path("m", &path).unwrap(), 1);
        assert_eq!(fleet.snapshot().swaps, 0);

        let sample: Vec<f64> = (0..8).map(|i| i as f64 / 8.0).collect();
        let got = fleet
            .submit("m", ServeFormat::F64, sample.clone())
            .unwrap()
            .wait()
            .unwrap();
        let plan = crate::plan::Plan::for_reference(&zoo::tiny_mlp(61)).unwrap();
        let mut arena = crate::plan::Arena::new();
        let want = plan.execute::<f64>(&(), &sample, &mut arena).unwrap();
        assert_eq!(got, want, "fleet-served f64 trace must equal the reference plan");

        // Edited file: the content version bumps, so this is a real swap.
        zoo::tiny_mlp(62).save(&path).unwrap();
        assert_eq!(fleet.deploy_path("m", &path).unwrap(), 2);
        assert_eq!(fleet.snapshot().swaps, 1);
    }

    #[test]
    fn dedicated_pool_mode_works() {
        let session = Session::builder().workers(1).build();
        let req = AnalysisRequest::builder()
            .model(zoo::tiny_mlp(7))
            .data(digits_like())
            .mode(ExecMode::Pooled { workers: 3 })
            .build()
            .unwrap();
        let out = session.run(&req).unwrap();
        assert_eq!(out.analysis.per_class.len(), 3);
        // The session pool saw none of the jobs.
        assert_eq!(session.pool().metrics().submitted, 0);
    }

    #[test]
    fn progress_callback_streams_every_class() {
        let session = Session::new();
        let seen = Arc::new(AtomicUsize::new(0));
        let seen2 = Arc::clone(&seen);
        let req = AnalysisRequest::builder()
            .model(zoo::tiny_mlp(42))
            .data(digits_like())
            .mode(ExecMode::Pooled { workers: 0 })
            .on_class(move |c| {
                assert!(c.class < 3);
                seen2.fetch_add(1, Ordering::SeqCst);
            })
            .build()
            .unwrap();
        let out = session.run(&req).unwrap();
        assert_eq!(seen.load(Ordering::SeqCst), out.analysis.per_class.len());
    }

    #[test]
    fn input_box_analyzes_whole_box() {
        let session = Session::new();
        let req = AnalysisRequest::builder()
            .model(zoo::tiny_pendulum(7))
            .input_box()
            .input_radius(6.0)
            .exact_inputs(true)
            .build()
            .unwrap();
        let out = session.run(&req).unwrap();
        assert_eq!(out.analysis.per_class.len(), 1);
        assert!(out.analysis.max_abs_u.is_finite());
    }

    #[test]
    fn model_path_requests_hit_the_cache() {
        let dir = std::env::temp_dir().join("rigor_api_session");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mlp.json");
        zoo::tiny_mlp(42).save(&path).unwrap();

        let session = Session::new();
        let req = AnalysisRequest::builder()
            .model_path(&path)
            .data(digits_like())
            .build()
            .unwrap();
        let a = session.run(&req).unwrap();
        let b = session.run(&req).unwrap();
        assert_eq!(a.analysis.max_abs_u, b.analysis.max_abs_u);
        let stats = session.cache_stats();
        assert_eq!(stats.misses, 1);
        assert_eq!(stats.hits, 1);
        assert_eq!(stats.entries, 1);
    }

    #[test]
    fn certify_finds_a_precision() {
        let session = Session::new();
        let req = AnalysisRequest::builder()
            .model(zoo::tiny_mlp(42))
            .data(digits_like())
            .build()
            .unwrap();
        let (k, out) = session
            .certify_min_precision(&req, 4..=30)
            .unwrap()
            .expect("small MLP must certify in [4, 30]");
        assert!(out.required_k().unwrap() <= k);
    }
}
