//! [`AnalysisOutcome`]: the stable result type of the service API — the
//! engine's [`ModelAnalysis`] plus a **versioned** JSON serialization
//! (`schema_version`) through the in-tree [`json`](crate::json) module, so
//! downstream consumers (dashboards, report tooling, other languages) can
//! rely on a stable, evolvable wire shape.

use crate::analysis::{ClassAnalysis, ModelAnalysis};
use crate::json::Value;
use crate::report::TableRow;
use anyhow::{anyhow, bail, Result};

/// Version of [`AnalysisOutcome::to_json`]'s shape. Bump when a field is
/// renamed, removed, or changes meaning; additions are backwards
/// compatible and need no bump.
pub const SCHEMA_VERSION: u32 = 1;

/// Result of one [`AnalysisRequest`](super::AnalysisRequest).
#[derive(Clone, Debug)]
pub struct AnalysisOutcome {
    /// The engine-level analysis (bounds in units of `u`, per-class detail,
    /// required precision).
    pub analysis: ModelAnalysis,
}

impl AnalysisOutcome {
    pub(crate) fn new(analysis: ModelAnalysis) -> AnalysisOutcome {
        AnalysisOutcome { analysis }
    }

    /// Minimum precision that provably preserves the argmax at `p*`.
    pub fn required_k(&self) -> Option<u32> {
        self.analysis.required_k
    }

    /// The Table-I row for this outcome.
    pub fn table_row(&self) -> TableRow {
        TableRow::from_analysis(&self.analysis)
    }

    /// Versioned JSON serialization (`schema_version: 1`). Infinite bounds
    /// (e.g. no relative bound for outputs straddling zero) are emitted as
    /// `1e999`, which the in-tree parser reads back as `+inf`.
    pub fn to_json(&self) -> Value {
        let a = &self.analysis;
        let per_class: Vec<Value> = a.per_class.iter().map(class_to_json).collect();
        Value::obj(vec![
            ("schema_version", Value::from(SCHEMA_VERSION as usize)),
            ("model", Value::from(a.model_name.as_str())),
            ("p_star", Value::Num(a.p_star)),
            ("u_max", Value::Num(a.u_max)),
            ("max_abs_u", Value::Num(a.max_abs_u)),
            ("max_rel_u", Value::Num(a.max_rel_u)),
            (
                "required_k",
                match a.required_k {
                    Some(k) => Value::from(k as usize),
                    None => Value::Null,
                },
            ),
            ("total_secs", Value::Num(a.total_secs)),
            ("per_class", Value::Array(per_class)),
        ])
    }

    /// [`Self::to_json`] rendered as a pretty-printed document.
    pub fn to_json_string(&self) -> String {
        crate::json::to_string_pretty(&self.to_json())
    }

    /// Parse an outcome back from its [`Self::to_json`] form. Rejects
    /// documents with a missing or different `schema_version`.
    pub fn from_json(v: &Value) -> Result<AnalysisOutcome> {
        let version = v
            .get("schema_version")
            .and_then(Value::as_usize)
            .ok_or_else(|| anyhow!("outcome document missing 'schema_version'"))?;
        if version != SCHEMA_VERSION as usize {
            bail!("unsupported outcome schema_version {version} (this build reads {SCHEMA_VERSION})");
        }
        let f = |k: &str| -> Result<f64> {
            v.get(k)
                .and_then(Value::as_f64)
                .ok_or_else(|| anyhow!("outcome missing number '{k}'"))
        };
        let model_name = v
            .get("model")
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow!("outcome missing 'model'"))?
            .to_string();
        let required_k = match v.get("required_k") {
            None | Some(Value::Null) => None,
            Some(x) => Some(
                x.as_usize()
                    .ok_or_else(|| anyhow!("'required_k' must be an integer or null"))?
                    as u32,
            ),
        };
        let per_class = v
            .get("per_class")
            .and_then(Value::as_array)
            .ok_or_else(|| anyhow!("outcome missing 'per_class' array"))?
            .iter()
            .map(class_from_json)
            .collect::<Result<Vec<ClassAnalysis>>>()?;
        Ok(AnalysisOutcome {
            analysis: ModelAnalysis {
                model_name,
                per_class,
                max_abs_u: f("max_abs_u")?,
                max_rel_u: f("max_rel_u")?,
                total_secs: f("total_secs")?,
                required_k,
                p_star: f("p_star")?,
                u_max: f("u_max")?,
            },
        })
    }
}

fn class_to_json(c: &ClassAnalysis) -> Value {
    Value::obj(vec![
        ("class", Value::from(c.class)),
        ("max_abs_u", Value::Num(c.max_abs_u)),
        ("max_rel_u", Value::Num(c.max_rel_u)),
        ("top1_rel_u", Value::Num(c.top1_rel_u)),
        ("predicted", Value::from(c.predicted)),
        ("ambiguous", Value::from(c.ambiguous)),
        ("secs", Value::Num(c.secs)),
    ])
}

fn class_from_json(v: &Value) -> Result<ClassAnalysis> {
    let f = |k: &str| -> Result<f64> {
        v.get(k)
            .and_then(Value::as_f64)
            .ok_or_else(|| anyhow!("per_class entry missing number '{k}'"))
    };
    let u = |k: &str| -> Result<usize> {
        v.get(k)
            .and_then(Value::as_usize)
            .ok_or_else(|| anyhow!("per_class entry missing integer '{k}'"))
    };
    Ok(ClassAnalysis {
        class: u("class")?,
        max_abs_u: f("max_abs_u")?,
        max_rel_u: f("max_rel_u")?,
        top1_rel_u: f("top1_rel_u")?,
        predicted: u("predicted")?,
        ambiguous: v
            .get("ambiguous")
            .and_then(Value::as_bool)
            .ok_or_else(|| anyhow!("per_class entry missing bool 'ambiguous'"))?,
        secs: f("secs")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_outcome() -> AnalysisOutcome {
        AnalysisOutcome::new(ModelAnalysis {
            model_name: "pendulum".into(),
            per_class: vec![ClassAnalysis {
                class: 0,
                max_abs_u: 1.7,
                max_rel_u: f64::INFINITY,
                top1_rel_u: f64::INFINITY,
                predicted: 0,
                ambiguous: false,
                secs: 0.1,
            }],
            max_abs_u: 1.7,
            max_rel_u: f64::INFINITY,
            total_secs: 0.1,
            required_k: None,
            p_star: 0.6,
            u_max: 2f64.powi(-7),
        })
    }

    #[test]
    fn json_carries_schema_version() {
        let v = sample_outcome().to_json();
        assert_eq!(
            v.get("schema_version").and_then(Value::as_usize),
            Some(SCHEMA_VERSION as usize)
        );
        let text = crate::json::to_string_pretty(&v);
        assert!(text.contains("\"schema_version\": 1"), "{text}");
    }

    #[test]
    fn roundtrips_through_parser_including_infinities() {
        let out = sample_outcome();
        let text = out.to_json_string();
        let back = AnalysisOutcome::from_json(&crate::json::parse(&text).unwrap()).unwrap();
        let (a, b) = (&out.analysis, &back.analysis);
        assert_eq!(a.model_name, b.model_name);
        assert_eq!(a.max_abs_u, b.max_abs_u);
        assert!(b.max_rel_u.is_infinite(), "infinite bound must survive the trip");
        assert_eq!(a.required_k, b.required_k);
        assert_eq!(a.p_star, b.p_star);
        assert_eq!(a.u_max, b.u_max);
        assert_eq!(a.per_class.len(), b.per_class.len());
        assert_eq!(a.per_class[0].class, b.per_class[0].class);
        assert_eq!(a.per_class[0].max_abs_u, b.per_class[0].max_abs_u);
        assert!(b.per_class[0].top1_rel_u.is_infinite());
        assert_eq!(a.per_class[0].ambiguous, b.per_class[0].ambiguous);
    }

    #[test]
    fn rejects_wrong_or_missing_schema_version() {
        let mut v = sample_outcome().to_json();
        if let Value::Object(m) = &mut v {
            m.insert("schema_version".into(), Value::from(99usize));
        }
        assert!(AnalysisOutcome::from_json(&v).is_err());
        if let Value::Object(m) = &mut v {
            m.remove("schema_version");
        }
        assert!(AnalysisOutcome::from_json(&v).is_err());
    }
}
