//! [`AnalysisRequest`]: the one description of "analyze this model, this
//! way" that every front end (CLI, benches, examples, tests, future RPC
//! servers) submits to a [`Session`](super::Session).
//!
//! Requests are execution-plan agnostic: the session compiles (and
//! caches) a [`crate::plan::Plan`] per model and serves every request —
//! serial or pooled, point or tailoring loop — through the same compiled
//! steps. A request never needs to know about fusion levels; the session
//! always analyzes with the CAA-sound level ([`crate::plan::Fusion::Pair`]).

use crate::analysis::{AnalysisConfig, ClassAnalysis};
use crate::caa::Ctx;
use crate::data::Dataset;
use crate::model::Model;
use anyhow::{bail, Result};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

/// How a request's per-class jobs are executed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Run all class jobs on the calling thread, in class order.
    Serial,
    /// Fan class jobs out over a worker pool. `workers == 0` uses the
    /// session's shared pool; `workers > 0` spins up a dedicated pool of
    /// that size for this request (useful for scaling experiments).
    Pooled { workers: usize },
}

/// Streaming per-class progress callback: invoked once per completed class
/// (from worker threads under [`ExecMode::Pooled`]).
pub type ProgressFn = dyn Fn(&ClassAnalysis) + Send + Sync;

/// The model a request analyzes.
#[derive(Clone)]
pub enum ModelRef {
    /// Load from a JSON file through the session's LRU cache.
    Path(PathBuf),
    /// An in-memory model (zoo builders, programmatic construction).
    Inline(Arc<Model>),
}

/// The inputs a request analyzes the model over.
#[derive(Clone)]
pub enum DataRef {
    /// Load a dataset JSON file.
    Path(PathBuf),
    /// An in-memory dataset.
    Inline(Arc<Dataset>),
    /// A single unlabeled sample at the input-space origin — combined with
    /// `input_radius` this is the whole-box verification workload (the
    /// paper's Pendulum setting).
    InputBox,
}

/// A validated analysis request. Build with [`AnalysisRequest::builder`].
#[derive(Clone)]
pub struct AnalysisRequest {
    pub(crate) model: ModelRef,
    pub(crate) data: DataRef,
    pub(crate) p_star: f64,
    pub(crate) u_max: f64,
    pub(crate) input_radius: f64,
    pub(crate) exact_inputs: bool,
    pub(crate) mode: ExecMode,
    pub(crate) ctx_override: Option<Ctx>,
    pub(crate) progress: Option<Arc<ProgressFn>>,
    pub(crate) max_batch: usize,
    pub(crate) max_wait: Duration,
    pub(crate) max_pending: usize,
    pub(crate) force_scalar_kernels: bool,
    pub(crate) emulated_k: Option<u32>,
    pub(crate) parallel_workers: Option<usize>,
    pub(crate) deadline_ms: Option<u64>,
}

impl AnalysisRequest {
    /// Start building a request. Defaults mirror the paper's setup; only
    /// a model and a data reference are mandatory.
    ///
    /// ```
    /// use rigor::api::{AnalysisRequest, ExecMode};
    /// use rigor::model::zoo;
    ///
    /// let req = AnalysisRequest::builder()
    ///     .model(zoo::tiny_pendulum(7))
    ///     .input_box()
    ///     .input_radius(6.0)      // the paper's whole-box Pendulum query
    ///     .exact_inputs(true)
    ///     .build()?;
    /// assert_eq!(req.p_star(), 0.60);
    /// assert_eq!(req.mode(), ExecMode::Serial);
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn builder() -> AnalysisRequestBuilder {
        AnalysisRequestBuilder::new()
    }

    /// The top-1 confidence floor `p*` this request certifies against.
    pub fn p_star(&self) -> f64 {
        self.p_star
    }

    /// The upper bound on `u = 2^(1-k)` the analysis covers.
    pub fn u_max(&self) -> f64 {
        self.u_max
    }

    /// How per-class jobs execute (serial or pooled).
    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    /// Micro-batch size for bulk paths
    /// ([`Session::run_batch`](super::Session::run_batch) chunking,
    /// [`Session::serve`](super::Session::serve)'s
    /// [`BatchPolicy::max_batch`](crate::serve::BatchPolicy)).
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Micro-batch latency bound for
    /// [`Session::serve`](super::Session::serve)'s
    /// [`BatchPolicy::max_wait`](crate::serve::BatchPolicy).
    pub fn max_wait(&self) -> Duration {
        self.max_wait
    }

    /// Pending-queue bound for [`Session::serve`](super::Session::serve)'s
    /// [`BatchPolicy::max_pending`](crate::serve::BatchPolicy): submits
    /// block (backpressure) once this many samples are queued.
    pub fn max_pending(&self) -> usize {
        self.max_pending
    }

    /// Whether this request's **serving** executions
    /// ([`Session::serve`](super::Session::serve)'s f64 plan drives) are
    /// pinned to the scalar kernels
    /// ([`KernelPath::Scalar`](crate::plan::KernelPath)) — the
    /// per-request debugging escape hatch. Results are bit-identical
    /// either way; only throughput differs. The analysis doors
    /// (`run`/`run_batch`/`certify`/`tune`) execute CAA, which takes the
    /// scalar kernels unconditionally; to force scalar kernels on *every*
    /// f64/witness execution in the process, set `RIGOR_FORCE_SCALAR=1`
    /// instead (read at plan compile time).
    pub fn force_scalar_kernels(&self) -> bool {
        self.force_scalar_kernels
    }

    /// Explicit per-drive worker count for this request's served batches
    /// ([`Session::serve`](super::Session::serve)): `Some(1)` pins every
    /// flush to the serial drive, `Some(n)` shards each drive across `n`
    /// scoped jobs. `None` (the default) defers to the `RIGOR_WORKERS`
    /// environment variable, falling back to the session pool's worker
    /// count. Parallel drives are bit-identical to serial ones — the knob
    /// only changes throughput.
    pub fn parallel_workers(&self) -> Option<usize> {
        self.parallel_workers
    }

    /// Per-ticket deadline for this request's served traffic
    /// ([`Session::serve`](super::Session::serve)'s
    /// [`BatchPolicy::default_deadline`](crate::serve::BatchPolicy)):
    /// samples still queued when the deadline expires resolve as
    /// [`ServeError::DeadlineExceeded`](crate::serve::ServeError) instead
    /// of occupying a batch slot. `None` (the default) disables deadlines.
    pub fn deadline_ms(&self) -> Option<u64> {
        self.deadline_ms
    }

    /// The serving arithmetic this request resolves to:
    /// [`ServeFormat::Emulated`](crate::plan::ServeFormat) at the
    /// requested `k` when [`emulated_k`](AnalysisRequestBuilder::emulated_k)
    /// was set, the f64 reference otherwise. Read by
    /// [`Session::serve`](super::Session::serve) to pick the served plan
    /// and batch arithmetic.
    pub fn serve_format(&self) -> crate::plan::ServeFormat {
        match self.emulated_k {
            Some(k) => crate::plan::ServeFormat::Emulated { k },
            None => crate::plan::ServeFormat::F64,
        }
    }

    /// The engine-level configuration this request resolves to. Together
    /// with [`AnalysisRequestBuilder::build_config`] (which shares the same
    /// derivation) this is the single place an [`AnalysisConfig`] is
    /// manufactured; layer-level tools (baselines, ablations, mixed tuning)
    /// that still speak the engine vocabulary obtain their config here
    /// instead of constructing one.
    pub fn analysis_config(&self) -> AnalysisConfig {
        derive_config(
            self.ctx_override.clone(),
            self.u_max,
            self.p_star,
            self.input_radius,
            self.exact_inputs,
        )
    }

    /// A copy of this request re-targeted at precision `k`
    /// (`u_max = 2^(1-k)`) — the precision-tailoring loop's step.
    pub(crate) fn at_precision(&self, k: u32) -> AnalysisRequest {
        let u = 2f64.powi(1 - k as i32);
        let mut req = self.clone();
        req.u_max = u;
        if let Some(ctx) = &mut req.ctx_override {
            ctx.u_max = u;
        }
        req
    }
}

/// Builder for [`AnalysisRequest`]. Defaults mirror the paper's setup:
/// `p* = 0.60`, `u_max = 2^-7`, point inputs, rounded input representation,
/// serial execution.
pub struct AnalysisRequestBuilder {
    model: Option<ModelRef>,
    data: Option<DataRef>,
    p_star: f64,
    u_max: f64,
    input_radius: f64,
    exact_inputs: bool,
    mode: ExecMode,
    ctx_override: Option<Ctx>,
    progress: Option<Arc<ProgressFn>>,
    max_batch: usize,
    max_wait: Duration,
    max_pending: Option<usize>,
    force_scalar_kernels: bool,
    emulated_k: Option<u32>,
    parallel_workers: Option<usize>,
    deadline_ms: Option<u64>,
}

impl AnalysisRequestBuilder {
    fn new() -> AnalysisRequestBuilder {
        AnalysisRequestBuilder {
            model: None,
            data: None,
            p_star: 0.60,
            u_max: 2f64.powi(-7),
            input_radius: 0.0,
            exact_inputs: false,
            mode: ExecMode::Serial,
            ctx_override: None,
            progress: None,
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            max_pending: None,
            force_scalar_kernels: false,
            emulated_k: None,
            parallel_workers: None,
            deadline_ms: None,
        }
    }

    /// Analyze the model stored at `path` (served through the session's
    /// LRU cache).
    pub fn model_path(mut self, path: impl Into<PathBuf>) -> Self {
        self.model = Some(ModelRef::Path(path.into()));
        self
    }

    /// Analyze an in-memory model.
    pub fn model(mut self, model: Model) -> Self {
        self.model = Some(ModelRef::Inline(Arc::new(model)));
        self
    }

    /// Analyze an already-shared in-memory model.
    pub fn model_arc(mut self, model: Arc<Model>) -> Self {
        self.model = Some(ModelRef::Inline(model));
        self
    }

    /// Evaluate over the dataset stored at `path`.
    pub fn data_path(mut self, path: impl Into<PathBuf>) -> Self {
        self.data = Some(DataRef::Path(path.into()));
        self
    }

    /// Evaluate over an in-memory dataset.
    pub fn data(mut self, data: Dataset) -> Self {
        self.data = Some(DataRef::Inline(Arc::new(data)));
        self
    }

    /// Evaluate over an already-shared in-memory dataset.
    pub fn data_arc(mut self, data: Arc<Dataset>) -> Self {
        self.data = Some(DataRef::Inline(data));
        self
    }

    /// Evaluate the whole input box: one unlabeled sample at the origin,
    /// widened by [`input_radius`](Self::input_radius).
    pub fn input_box(mut self) -> Self {
        self.data = Some(DataRef::InputBox);
        self
    }

    /// Top-1 confidence floor `p*` for precision tailoring (must satisfy
    /// `0.5 < p* < 1`).
    pub fn p_star(mut self, p_star: f64) -> Self {
        self.p_star = p_star;
        self
    }

    /// Upper bound on `u = 2^(1-k)`; bounds hold for all `u <= u_max`.
    pub fn u_max(mut self, u_max: f64) -> Self {
        self.u_max = u_max;
        self
    }

    /// Convenience: `u_max = 2^-log2` (the paper's Table I uses `log2 = 7`).
    pub fn u_max_log2(mut self, log2: u32) -> Self {
        self.u_max = 2f64.powi(-(log2 as i32));
        self
    }

    /// Radius of the input box around each sample (0 = point analysis).
    pub fn input_radius(mut self, radius: f64) -> Self {
        self.input_radius = radius;
        self
    }

    /// Treat inputs as exactly representable in every analyzed format
    /// (integer pixel data, verification queries at representable points).
    pub fn exact_inputs(mut self, exact: bool) -> Self {
        self.exact_inputs = exact;
        self
    }

    /// Execution mode (default [`ExecMode::Serial`]).
    pub fn mode(mut self, mode: ExecMode) -> Self {
        self.mode = mode;
        self
    }

    /// Replace the derived CAA context entirely — the ablation escape hatch
    /// (feature-toggled contexts like `Ctx::new().no_labels()`). Production
    /// requests should set [`u_max`](Self::u_max) instead.
    pub fn ctx(mut self, ctx: Ctx) -> Self {
        self.ctx_override = Some(ctx);
        self
    }

    /// Per-class streaming callback, invoked as each class analysis
    /// completes (possibly from a worker thread).
    pub fn on_class(mut self, f: impl Fn(&ClassAnalysis) + Send + Sync + 'static) -> Self {
        self.progress = Some(Arc::new(f));
        self
    }

    /// Micro-batch size (default 32): how many samples one
    /// [`Session::run_batch`](super::Session::run_batch) chunk or one
    /// [`Session::serve`](super::Session::serve) plan drive covers.
    pub fn max_batch(mut self, max_batch: usize) -> Self {
        self.max_batch = max_batch;
        self
    }

    /// Micro-batch latency bound in milliseconds (default 2): how long
    /// [`Session::serve`](super::Session::serve)'s scheduler lets the
    /// oldest pending sample wait for batch-mates.
    pub fn max_wait_ms(mut self, ms: u64) -> Self {
        self.max_wait = Duration::from_millis(ms);
        self
    }

    /// Pending-queue bound for [`Session::serve`](super::Session::serve)
    /// (default: `32 * max_batch`, at least 1024): once this many samples
    /// are queued, further submits block until the flusher drains —
    /// submit-side backpressure that keeps an overloaded batcher's
    /// memory bounded. Must be `>= max_batch`.
    pub fn max_pending(mut self, max_pending: usize) -> Self {
        self.max_pending = Some(max_pending);
        self
    }

    /// Pin this request's served f64 executions
    /// ([`Session::serve`](super::Session::serve)) to the scalar kernels
    /// instead of the blocked path
    /// ([`KernelPath::Blocked`](crate::plan::KernelPath)) — the
    /// per-request debugging escape hatch. Outputs are bit-identical on
    /// both paths. The analysis doors run CAA (scalar kernels always);
    /// for a process-wide scalar pin covering every f64/witness
    /// execution, set the `RIGOR_FORCE_SCALAR` env var instead.
    pub fn force_scalar_kernels(mut self, force: bool) -> Self {
        self.force_scalar_kernels = force;
        self
    }

    /// Serve this request's traffic in **emulated-`k` arithmetic** instead
    /// of f64: [`Session::serve`](super::Session::serve) compiles the
    /// unfused witness-convention plan
    /// ([`Plan::for_format`](crate::plan::Plan::for_format)) and batches
    /// execute as `EmulatedFp { k }`, so every served result is
    /// bit-identical to the offline
    /// [`emulated_forward`](crate::quant::emulated_forward) witness at the
    /// same `k` — serve what you certified. `k` must be in `[2, 53]`.
    pub fn emulated_k(mut self, k: u32) -> Self {
        self.emulated_k = Some(k);
        self
    }

    /// Shard each served plan drive
    /// ([`Session::serve`](super::Session::serve)) across `workers`
    /// coordinator jobs (`1` = serial drives, the pre-parallel behavior).
    /// Overrides the `RIGOR_WORKERS` environment default for this request
    /// only; results stay bit-identical to the serial path. Must be in
    /// `[1, 4096]`.
    pub fn parallel_workers(mut self, workers: usize) -> Self {
        self.parallel_workers = Some(workers);
        self
    }

    /// Per-ticket deadline in milliseconds for served traffic
    /// ([`Session::serve`](super::Session::serve)): a sample still queued
    /// `ms` after submission resolves as
    /// [`ServeError::DeadlineExceeded`](crate::serve::ServeError) instead
    /// of occupying a batch slot. Must be `>= 1`; the default (no
    /// deadline) lets tickets wait indefinitely.
    pub fn deadline_ms(mut self, ms: u64) -> Self {
        self.deadline_ms = Some(ms);
        self
    }

    fn validate(&self) -> Result<()> {
        if !(self.p_star > 0.5 && self.p_star < 1.0) {
            bail!("p_star must be in (0.5, 1.0), got {}", self.p_star);
        }
        if self.ctx_override.is_none() && !(self.u_max > 0.0 && self.u_max <= 0.25) {
            bail!("u_max must be in (0, 0.25], got {}", self.u_max);
        }
        if !(self.input_radius >= 0.0 && self.input_radius.is_finite()) {
            bail!("input_radius must be finite and >= 0, got {}", self.input_radius);
        }
        if let ExecMode::Pooled { workers } = self.mode {
            if workers > 4096 {
                bail!("unreasonable worker count {workers}");
            }
        }
        if self.max_batch == 0 || self.max_batch > 4096 {
            bail!("max_batch must be in [1, 4096], got {}", self.max_batch);
        }
        if let Some(p) = self.max_pending {
            if p < self.max_batch || p > 1 << 20 {
                bail!("max_pending must be in [max_batch ({}), 2^20], got {p}", self.max_batch);
            }
        }
        if let Some(k) = self.emulated_k {
            crate::plan::ServeFormat::Emulated { k }.validate()?;
        }
        if let Some(w) = self.parallel_workers {
            if w == 0 || w > 4096 {
                bail!("parallel_workers must be in [1, 4096], got {w}");
            }
        }
        if self.deadline_ms == Some(0) {
            bail!("deadline_ms must be >= 1 (omit it to disable deadlines)");
        }
        Ok(())
    }

    /// Finish the request. Fails on out-of-range parameters or a missing
    /// model/data reference.
    pub fn build(self) -> Result<AnalysisRequest> {
        self.validate()?;
        let Some(model) = self.model else {
            bail!("analysis request needs a model (model_path / model / model_arc)");
        };
        let Some(data) = self.data else {
            bail!("analysis request needs data (data_path / data / data_arc / input_box)");
        };
        Ok(AnalysisRequest {
            model,
            data,
            p_star: self.p_star,
            u_max: self.u_max,
            input_radius: self.input_radius,
            exact_inputs: self.exact_inputs,
            mode: self.mode,
            ctx_override: self.ctx_override,
            progress: self.progress,
            max_batch: self.max_batch,
            max_wait: self.max_wait,
            max_pending: self.max_pending.unwrap_or_else(|| (32 * self.max_batch).max(1024)),
            force_scalar_kernels: self.force_scalar_kernels,
            emulated_k: self.emulated_k,
            parallel_workers: self.parallel_workers,
            deadline_ms: self.deadline_ms,
        })
    }

    /// Build only the engine-level [`AnalysisConfig`] — for layer-level
    /// tools (baselines, ablation benches) that drive `analyze_class`
    /// directly and need no model/data reference in the request.
    pub fn build_config(self) -> Result<AnalysisConfig> {
        self.validate()?;
        Ok(derive_config(
            self.ctx_override,
            self.u_max,
            self.p_star,
            self.input_radius,
            self.exact_inputs,
        ))
    }
}

/// The one derivation of an engine config from request-level parameters
/// (shared by [`AnalysisRequest::analysis_config`] and
/// [`AnalysisRequestBuilder::build_config`]).
fn derive_config(
    ctx_override: Option<Ctx>,
    u_max: f64,
    p_star: f64,
    input_radius: f64,
    exact_inputs: bool,
) -> AnalysisConfig {
    let ctx = ctx_override.unwrap_or_else(|| Ctx::with_u_max(u_max));
    AnalysisConfig { ctx, p_star, input_radius, exact_inputs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn builder_validates_ranges() {
        let ok = AnalysisRequest::builder()
            .model(zoo::tiny_mlp(1))
            .input_box()
            .build();
        assert!(ok.is_ok());

        assert!(AnalysisRequest::builder()
            .model(zoo::tiny_mlp(1))
            .input_box()
            .p_star(0.5)
            .build()
            .is_err());
        assert!(AnalysisRequest::builder()
            .model(zoo::tiny_mlp(1))
            .input_box()
            .u_max(0.5)
            .build()
            .is_err());
        assert!(AnalysisRequest::builder()
            .model(zoo::tiny_mlp(1))
            .input_box()
            .input_radius(f64::NAN)
            .build()
            .is_err());
        assert!(AnalysisRequest::builder().input_box().build().is_err(), "missing model");
        assert!(AnalysisRequest::builder().model(zoo::tiny_mlp(1)).build().is_err(), "missing data");
    }

    #[test]
    fn batching_knobs_validate_and_flow_through() {
        let req = AnalysisRequest::builder()
            .model(zoo::tiny_mlp(1))
            .input_box()
            .max_batch(8)
            .max_wait_ms(5)
            .build()
            .unwrap();
        assert_eq!(req.max_batch(), 8);
        assert_eq!(req.max_wait(), Duration::from_millis(5));
        // Defaults: 32-sample chunks, 2 ms latency bound.
        let dflt = AnalysisRequest::builder()
            .model(zoo::tiny_mlp(1))
            .input_box()
            .build()
            .unwrap();
        assert_eq!(dflt.max_batch(), 32);
        assert_eq!(dflt.max_wait(), Duration::from_millis(2));
        assert!(AnalysisRequest::builder()
            .model(zoo::tiny_mlp(1))
            .input_box()
            .max_batch(0)
            .build()
            .is_err());
    }

    #[test]
    fn backpressure_and_kernel_knobs_validate_and_flow_through() {
        // Defaults: derived pending bound, blocked kernels.
        let dflt = AnalysisRequest::builder()
            .model(zoo::tiny_mlp(1))
            .input_box()
            .build()
            .unwrap();
        assert_eq!(dflt.max_pending(), 1024);
        assert!(!dflt.force_scalar_kernels());

        let req = AnalysisRequest::builder()
            .model(zoo::tiny_mlp(1))
            .input_box()
            .max_batch(4)
            .max_pending(16)
            .force_scalar_kernels(true)
            .build()
            .unwrap();
        assert_eq!(req.max_pending(), 16);
        assert!(req.force_scalar_kernels());

        // Large batches raise the derived default with them.
        let big = AnalysisRequest::builder()
            .model(zoo::tiny_mlp(1))
            .input_box()
            .max_batch(4096)
            .build()
            .unwrap();
        assert_eq!(big.max_pending(), 32 * 4096);

        // A pending bound below max_batch could never trip the size
        // trigger — rejected.
        assert!(AnalysisRequest::builder()
            .model(zoo::tiny_mlp(1))
            .input_box()
            .max_batch(8)
            .max_pending(4)
            .build()
            .is_err());
    }

    #[test]
    fn emulated_k_knob_validates_and_flows_through() {
        use crate::plan::ServeFormat;
        let dflt = AnalysisRequest::builder()
            .model(zoo::tiny_mlp(1))
            .input_box()
            .build()
            .unwrap();
        assert_eq!(dflt.serve_format(), ServeFormat::F64);

        let req = AnalysisRequest::builder()
            .model(zoo::tiny_mlp(1))
            .input_box()
            .emulated_k(12)
            .build()
            .unwrap();
        assert_eq!(req.serve_format(), ServeFormat::Emulated { k: 12 });

        // k outside the representable mantissa range is rejected at build.
        assert!(AnalysisRequest::builder()
            .model(zoo::tiny_mlp(1))
            .input_box()
            .emulated_k(1)
            .build()
            .is_err());
        assert!(AnalysisRequest::builder()
            .model(zoo::tiny_mlp(1))
            .input_box()
            .emulated_k(54)
            .build()
            .is_err());
    }

    #[test]
    fn parallel_workers_knob_validates_and_flows_through() {
        let dflt = AnalysisRequest::builder()
            .model(zoo::tiny_mlp(1))
            .input_box()
            .build()
            .unwrap();
        assert_eq!(dflt.parallel_workers(), None, "default defers to RIGOR_WORKERS");

        let req = AnalysisRequest::builder()
            .model(zoo::tiny_mlp(1))
            .input_box()
            .parallel_workers(4)
            .build()
            .unwrap();
        assert_eq!(req.parallel_workers(), Some(4));

        assert!(AnalysisRequest::builder()
            .model(zoo::tiny_mlp(1))
            .input_box()
            .parallel_workers(0)
            .build()
            .is_err());
        assert!(AnalysisRequest::builder()
            .model(zoo::tiny_mlp(1))
            .input_box()
            .parallel_workers(5000)
            .build()
            .is_err());
    }

    #[test]
    fn deadline_knob_validates_and_flows_through() {
        let dflt = AnalysisRequest::builder()
            .model(zoo::tiny_mlp(1))
            .input_box()
            .build()
            .unwrap();
        assert_eq!(dflt.deadline_ms(), None, "default: no deadline");

        let req = AnalysisRequest::builder()
            .model(zoo::tiny_mlp(1))
            .input_box()
            .deadline_ms(250)
            .build()
            .unwrap();
        assert_eq!(req.deadline_ms(), Some(250));

        assert!(AnalysisRequest::builder()
            .model(zoo::tiny_mlp(1))
            .input_box()
            .deadline_ms(0)
            .build()
            .is_err());
    }

    #[test]
    fn u_max_log2_matches_paper_default() {
        let req = AnalysisRequest::builder()
            .model(zoo::tiny_mlp(1))
            .input_box()
            .u_max_log2(7)
            .build()
            .unwrap();
        assert_eq!(req.u_max(), 2f64.powi(-7));
        let cfg = req.analysis_config();
        assert_eq!(cfg.ctx.u_max, 2f64.powi(-7));
        assert_eq!(cfg.p_star, 0.60);
    }

    #[test]
    fn at_precision_retargets_u_max() {
        let req = AnalysisRequest::builder()
            .model(zoo::tiny_mlp(1))
            .input_box()
            .build()
            .unwrap();
        let req8 = req.at_precision(8);
        assert_eq!(req8.u_max(), 2f64.powi(-7));
        let req12 = req.at_precision(12);
        assert_eq!(req12.u_max(), 2f64.powi(-11));
    }

    #[test]
    fn build_config_applies_ctx_override() {
        let cfg = AnalysisRequest::builder()
            .ctx(crate::caa::Ctx::with_u_max(2f64.powi(-21)))
            .p_star(0.7)
            .exact_inputs(true)
            .build_config()
            .unwrap();
        assert_eq!(cfg.ctx.u_max, 2f64.powi(-21));
        assert_eq!(cfg.p_star, 0.7);
        assert!(cfg.exact_inputs);
    }
}
