//! DNN layer semantics (paper §II), generic over [`Scalar`].
//!
//! This replaces frugally-deep's evaluation engine: the same layer code
//! runs the plain f64 reference trace, the emulated precision-k witness
//! runs, and the CAA analysis, depending on the scalar type bound in.
//! Computational layers: Dense, Conv2D, DepthwiseConv2D, Pooling,
//! BatchNormalization. Activation layers: ReLU, LeakyReLU, Tanh, Sigmoid,
//! Softmax. Merge layers (graph models only, see [`crate::model::Graph`]):
//! Add, Concat.

// Scalar kernel modules are crate-visible: the plan executor
// (`crate::plan::exec`) drives the slice-level `*_into` kernels directly
// against its arena buffers. `gemm` (the blocked f64/EmulatedFp kernel
// path) is public — its tile constants and bit-identity contract are
// part of the documented performance surface.
pub(crate) mod activation;
pub(crate) mod conv;
pub(crate) mod dense;
pub mod gemm;
pub(crate) mod merge;
pub(crate) mod norm;
pub(crate) mod pool;

pub use activation::softmax_vec;

use crate::tensor::{Scalar, Tensor};
use anyhow::{bail, Result};
use std::sync::Arc;

/// Padding mode for convolution (Keras semantics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Padding {
    /// No padding: output spatial size `(in - kernel)/stride + 1`.
    Valid,
    /// Zero padding such that output size is `ceil(in/stride)`.
    Same,
}

impl Padding {
    /// Parse the JSON padding string (`"valid"` / `"same"`).
    pub fn parse(s: &str) -> Result<Padding> {
        match s {
            "valid" => Ok(Padding::Valid),
            "same" => Ok(Padding::Same),
            _ => bail!("unknown padding '{s}'"),
        }
    }

    /// The JSON padding string this mode serializes to.
    pub fn as_str(&self) -> &'static str {
        match self {
            Padding::Valid => "valid",
            Padding::Same => "same",
        }
    }
}

/// A network layer with its learned parameters (held as f64; every `apply`
/// embeds them into the target arithmetic as rounded parameters).
///
/// Weight tensors sit behind `Arc` so a compiled [`crate::plan::Plan`] can
/// share them instead of cloning (the plan memory diet): cloning a `Layer`
/// or lowering it into a plan step bumps a refcount, it does not copy the
/// parameters. Fusion passes that rewrite weights (batch-norm folding) take
/// a private copy-on-write copy via `Arc::make_mut`, so the model's own
/// parameters are never mutated behind its back.
#[derive(Clone, Debug)]
pub enum Layer {
    /// Fully connected: `y = W x + b`, `W: [units, in]`.
    Dense { w: Arc<Tensor<f64>>, b: Vec<f64> },
    /// 2-D convolution, kernel `[kh, kw, cin, cout]`, input `[h, w, cin]`.
    Conv2D { kernel: Arc<Tensor<f64>>, bias: Vec<f64>, stride: usize, padding: Padding },
    /// Depthwise 2-D convolution, kernel `[kh, kw, c]`.
    DepthwiseConv2D { kernel: Arc<Tensor<f64>>, bias: Vec<f64>, stride: usize, padding: Padding },
    /// Max pooling over `[ph, pw]` windows with stride = pool size.
    MaxPool2D { ph: usize, pw: usize },
    /// Average pooling over `[ph, pw]` windows with stride = pool size.
    AvgPool2D { ph: usize, pw: usize },
    /// Inference-mode batch normalization over the last axis (channels).
    BatchNorm { gamma: Vec<f64>, beta: Vec<f64>, mean: Vec<f64>, variance: Vec<f64>, eps: f64 },
    /// Reshape to 1-D.
    Flatten,
    /// Rectified linear unit, `max(x, 0)`, elementwise.
    Relu,
    /// Leaky ReLU, `max(x, alpha * x)`, elementwise.
    LeakyRelu {
        /// Negative-side slope.
        alpha: f64,
    },
    /// Hyperbolic tangent, elementwise.
    Tanh,
    /// Logistic sigmoid `1 / (1 + e^-x)`, elementwise.
    Sigmoid,
    /// Numerically-stable softmax over the last axis.
    Softmax,
    /// Elementwise sum of two or more equal-shape inputs (the residual
    /// skip connection). Merge layer: only valid in graph models
    /// ([`crate::model::Graph`]) with at least two inbound nodes.
    Add,
    /// Concatenation of two or more inputs along the last (channel) axis.
    /// Merge layer: only valid in graph models with at least two inbound
    /// nodes.
    Concat,
}

impl Layer {
    /// Short type tag (matches the JSON model format).
    pub fn type_name(&self) -> &'static str {
        match self {
            Layer::Dense { .. } => "dense",
            Layer::Conv2D { .. } => "conv2d",
            Layer::DepthwiseConv2D { .. } => "depthwise_conv2d",
            Layer::MaxPool2D { .. } => "max_pool2d",
            Layer::AvgPool2D { .. } => "avg_pool2d",
            Layer::BatchNorm { .. } => "batch_norm",
            Layer::Flatten => "flatten",
            Layer::Relu => "relu",
            Layer::LeakyRelu { .. } => "leaky_relu",
            Layer::Tanh => "tanh",
            Layer::Sigmoid => "sigmoid",
            Layer::Softmax => "softmax",
            Layer::Add => "add",
            Layer::Concat => "concat",
        }
    }

    /// Number of learned parameters.
    pub fn param_count(&self) -> usize {
        match self {
            Layer::Dense { w, b } => w.len() + b.len(),
            Layer::Conv2D { kernel, bias, .. } => kernel.len() + bias.len(),
            Layer::DepthwiseConv2D { kernel, bias, .. } => kernel.len() + bias.len(),
            Layer::BatchNorm { gamma, beta, mean, variance, .. } => {
                gamma.len() + beta.len() + mean.len() + variance.len()
            }
            _ => 0,
        }
    }

    /// Output shape for a given input shape (validates compatibility).
    pub fn output_shape(&self, input: &[usize]) -> Result<Vec<usize>> {
        match self {
            Layer::Dense { w, .. } => {
                let (m, n) = (w.shape()[0], w.shape()[1]);
                if input != [n] {
                    bail!("dense expects input [{n}], got {input:?}");
                }
                Ok(vec![m])
            }
            Layer::Conv2D { kernel, stride, padding, .. } => {
                conv::conv2d_output_shape(kernel.shape(), *stride, *padding, input)
            }
            Layer::DepthwiseConv2D { kernel, stride, padding, .. } => {
                conv::depthwise_output_shape(kernel.shape(), *stride, *padding, input)
            }
            Layer::MaxPool2D { ph, pw } | Layer::AvgPool2D { ph, pw } => {
                pool::pool_output_shape(*ph, *pw, input)
            }
            Layer::BatchNorm { gamma, .. } => {
                let c = *input.last().ok_or_else(|| anyhow::anyhow!("batch_norm on scalar"))?;
                if c != gamma.len() {
                    bail!("batch_norm expects {} channels, got {c}", gamma.len());
                }
                Ok(input.to_vec())
            }
            Layer::Flatten => Ok(vec![input.iter().product()]),
            Layer::Add | Layer::Concat => bail!(
                "{} is a merge layer: it takes 2+ inputs and needs graph wiring \
                 (`Model::graph` / per-layer `inbound` in the JSON format)",
                self.type_name()
            ),
            _ => Ok(input.to_vec()),
        }
    }

    /// Output shape given **all** input shapes — the merge-aware version of
    /// [`Layer::output_shape`] the graph compiler
    /// ([`crate::plan::Plan::build`]) and [`crate::model::Model::output_shape`]
    /// use. Non-merge layers require exactly one input.
    pub fn output_shape_multi(&self, inputs: &[&[usize]]) -> Result<Vec<usize>> {
        match self {
            Layer::Add => merge::add_output_shape(inputs),
            Layer::Concat => merge::concat_output_shape(inputs),
            _ => {
                if inputs.len() != 1 {
                    bail!("{} takes exactly 1 input, got {}", self.type_name(), inputs.len());
                }
                self.output_shape(inputs[0])
            }
        }
    }

    /// Evaluate the layer in the arithmetic `S`.
    pub fn apply<S: Scalar>(&self, ctx: &S::Ctx, x: &Tensor<S>) -> Result<Tensor<S>> {
        // Shape check once here; the per-layer code can then index freely.
        let out_shape = self.output_shape(x.shape())?;
        let out = match self {
            Layer::Dense { w, b } => dense::apply(ctx, w, b, x),
            Layer::Conv2D { kernel, bias, stride, padding } => {
                conv::conv2d(ctx, kernel, bias, *stride, *padding, x, &out_shape)
            }
            Layer::DepthwiseConv2D { kernel, bias, stride, padding } => {
                conv::depthwise(ctx, kernel, bias, *stride, *padding, x, &out_shape)
            }
            Layer::MaxPool2D { ph, pw } => pool::max_pool(ctx, *ph, *pw, x, &out_shape),
            Layer::AvgPool2D { ph, pw } => pool::avg_pool(ctx, *ph, *pw, x, &out_shape),
            Layer::BatchNorm { gamma, beta, mean, variance, eps } => {
                norm::batch_norm(ctx, gamma, beta, mean, variance, *eps, x)
            }
            Layer::Flatten => x.clone().reshape(out_shape),
            Layer::Relu => x.map(|v| v.relu(ctx)),
            Layer::LeakyRelu { alpha } => activation::leaky_relu(ctx, *alpha, x),
            Layer::Tanh => x.map(|v| v.tanh(ctx)),
            Layer::Sigmoid => x.map(|v| v.sigmoid(ctx)),
            Layer::Softmax => activation::softmax(ctx, x),
            // Merge layers take multiple inputs; `output_shape` above
            // already rejected them for the single-input interpreter path.
            Layer::Add | Layer::Concat => unreachable!("merge layers rejected by output_shape"),
        };
        debug_assert_eq!(out.shape(), self.output_shape(x.shape())?.as_slice());
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn type_names_and_param_counts() {
        let d =
            Layer::Dense { w: Arc::new(Tensor::new(vec![2, 3], vec![0.0; 6])), b: vec![0.0; 2] };
        assert_eq!(d.type_name(), "dense");
        assert_eq!(d.param_count(), 8);
        assert_eq!(Layer::Softmax.param_count(), 0);
    }

    #[test]
    fn output_shapes() {
        let d =
            Layer::Dense { w: Arc::new(Tensor::new(vec![4, 3], vec![0.0; 12])), b: vec![0.0; 4] };
        assert_eq!(d.output_shape(&[3]).unwrap(), vec![4]);
        assert!(d.output_shape(&[5]).is_err());
        assert_eq!(Layer::Flatten.output_shape(&[2, 3, 4]).unwrap(), vec![24]);
        assert_eq!(Layer::Relu.output_shape(&[7, 7, 3]).unwrap(), vec![7, 7, 3]);
    }

    #[test]
    fn padding_parse() {
        assert_eq!(Padding::parse("same").unwrap(), Padding::Same);
        assert_eq!(Padding::parse("valid").unwrap(), Padding::Valid);
        assert!(Padding::parse("bogus").is_err());
        assert_eq!(Padding::Same.as_str(), "same");
    }
}
