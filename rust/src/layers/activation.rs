//! Activation layers (paper §II): elementwise ReLU/LeakyReLU/Tanh/Sigmoid
//! and the softmax (eq. (3)) in its numerically-stable max-subtracted form.
//!
//! Softmax is *the* showcase of the paper's machinery: the max subtraction
//! is a textbook decorrelation/control-flow hazard (`x_i - max(x)` with
//! correlated operands), solved by the bound labels that
//! [`Scalar::max_many`] attaches; and §IV proves the layer converts the
//! absolute error of the preceding convolutional summation into a relative
//! error of comparable size (eq. (11)).

use crate::tensor::{Scalar, Tensor};

pub fn leaky_relu<S: Scalar>(ctx: &S::Ctx, alpha: f64, x: &Tensor<S>) -> Tensor<S> {
    // max(x, 0) + alpha * min(x, 0), evaluated per element via the scalar's
    // primitives: relu(x) - alpha * relu(-x) needs a negation; use
    // x.max(ax) for alpha in [0,1): leaky(x) = max(x, alpha*x).
    let a = S::param(ctx, alpha);
    x.map(|v| {
        let scaled = v.mul(&a, ctx);
        v.max(&scaled, ctx)
    })
}

/// Softmax over the last axis of `x`.
pub fn softmax<S: Scalar>(ctx: &S::Ctx, x: &Tensor<S>) -> Tensor<S> {
    let n = *x.shape().last().expect("softmax needs rank >= 1");
    let mut out = Vec::with_capacity(x.len());
    let mut scratch = Vec::with_capacity(n);
    softmax_into(ctx, n, x.data(), &mut scratch, &mut out);
    Tensor::new(x.shape().to_vec(), out)
}

/// Slice-level softmax behind [`softmax`]: rows of length `n`, appended to
/// `out`. `scratch` holds the max-labelled row copy ([`Scalar::max_many`]
/// mutates its operands to attach CAA bound labels); both buffers keep
/// their capacity across calls, so the plan executor's steady state does
/// not allocate. The operation order is identical to [`softmax_vec`].
pub fn softmax_into<S: Scalar>(
    ctx: &S::Ctx,
    n: usize,
    x: &[S],
    scratch: &mut Vec<S>,
    out: &mut Vec<S>,
) {
    debug_assert!(n > 0 && x.len() % n == 0);
    let rows = x.len() / n;
    for r in 0..rows {
        let row = &x[r * n..(r + 1) * n];
        scratch.clear();
        scratch.extend_from_slice(row);
        let m = S::max_many(ctx, scratch);
        let base = out.len();
        for xv in scratch.iter() {
            out.push(xv.sub(&m, ctx).exp(ctx));
        }
        let mut sum = out[base].clone();
        for e in &out[base + 1..] {
            sum = sum.add(e, ctx);
        }
        for slot in out[base..].iter_mut() {
            let y = slot.div(&sum, ctx).clamp01(ctx);
            *slot = y;
        }
    }
}

/// Numerically-stable softmax of one vector:
/// `m = max(x); e_i = exp(x_i - m); y_i = e_i / sum(e)`.
pub fn softmax_vec<S: Scalar>(ctx: &S::Ctx, xs: &[S]) -> Vec<S> {
    let mut xs: Vec<S> = xs.to_vec();
    // max_many labels each x_i with the max (CAA), so x_i - m is known
    // nonpositive and exp stays in (0, 1].
    let m = S::max_many(ctx, &mut xs);
    let exps: Vec<S> = xs.iter().map(|x| x.sub(&m, ctx).exp(ctx)).collect();
    let mut sum = exps[0].clone();
    for e in &exps[1..] {
        sum = sum.add(e, ctx);
    }
    // Probabilities are in [0, 1] by construction: every denominator
    // summand is nonnegative, RN summation of nonnegatives dominates each
    // summand, and RN division/rounding are monotone — so both the ideal
    // and the computed quotient are <= 1. clamp01 injects that insight
    // (no-op for concrete scalars).
    exps.iter().map(|e| e.div(&sum, ctx).clamp01(ctx)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::caa::{Caa, Ctx};
    use crate::interval::Interval;
    use crate::quant::EmulatedFp;
    use crate::tensor::EmuCtx;

    #[test]
    fn softmax_f64_matches_definition() {
        let x = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]);
        let y = softmax::<f64>(&(), &x);
        let raw: Vec<f64> = [1.0f64, 2.0, 3.0].iter().map(|v| f64::exp(*v)).collect();
        let s: f64 = raw.iter().sum();
        for i in 0..3 {
            assert!((y.data()[i] - raw[i] / s).abs() < 1e-14);
        }
        let total: f64 = y.data().iter().sum();
        assert!((total - 1.0).abs() < 1e-14);
    }

    #[test]
    fn softmax_rows_independent() {
        let x = Tensor::new(vec![2, 2], vec![0.0, 0.0, 100.0, 0.0]);
        let y = softmax::<f64>(&(), &x);
        assert!((y.data()[0] - 0.5).abs() < 1e-14);
        assert!(y.data()[2] > 0.999);
    }

    #[test]
    fn softmax_caa_probabilities_bounded() {
        let ctx = Ctx::new();
        let x = Tensor::new(
            vec![4],
            [2.0, -1.0, 0.0, 1.0]
                .iter()
                .map(|&v| Caa::param(&ctx, v))
                .collect(),
        );
        let y = softmax::<Caa>(&ctx, &x);
        for v in y.data() {
            assert!(v.ideal().lo() >= 0.0);
            assert!(v.ideal().hi() <= 1.0 + 1e-9);
            assert!(v.rel_bound().is_finite(), "softmax output needs rel bound");
        }
    }

    #[test]
    fn softmax_caa_sound_vs_emulated() {
        let ctx = Ctx::new();
        let logits = [1.2, -0.3, 0.8, 2.5, -1.0];
        let xc = Tensor::new(vec![5], logits.iter().map(|&v| Caa::param(&ctx, v)).collect());
        let yc = softmax::<Caa>(&ctx, &xc);
        let yr = softmax::<f64>(&(), &Tensor::new(vec![5], logits.to_vec()));
        for k in [8u32, 10, 14, 20] {
            let ec = EmuCtx { k };
            let xe = Tensor::new(vec![5], logits.iter().map(|&v| EmulatedFp::new(v, k)).collect());
            let ye = softmax::<EmulatedFp>(&ec, &xe);
            for i in 0..5 {
                crate::quant::check_against_bounds(
                    &yc.data()[i],
                    yr.data()[i],
                    ye.data()[i].v,
                    k,
                    1e-12,
                )
                .unwrap_or_else(|e| panic!("k={k} i={i}: {e}"));
            }
        }
    }

    #[test]
    fn leaky_relu_values() {
        let x = Tensor::new(vec![3], vec![-2.0, 0.0, 3.0]);
        let y = leaky_relu::<f64>(&(), 0.1, &x);
        assert_eq!(y.data(), &[-0.2, 0.0, 3.0]);
    }

    #[test]
    fn softmax_caa_with_input_ranges() {
        // Per-class analysis feeds input *boxes*; softmax must stay finite.
        let ctx = Ctx::new();
        let x = Tensor::new(
            vec![3],
            vec![
                Caa::input(&ctx, Interval::new(1.5, 2.5), 2.0),
                Caa::input(&ctx, Interval::new(-1.0, 0.0), -0.5),
                Caa::input(&ctx, Interval::new(0.0, 1.0), 0.5),
            ],
        );
        let y = softmax::<Caa>(&ctx, &x);
        for v in y.data() {
            assert!(v.ideal().lo() >= 0.0 && v.ideal().hi() <= 1.0 + 1e-9);
            assert!(v.abs_bound().is_finite());
        }
    }
}
