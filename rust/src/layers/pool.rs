//! Pooling layers. Max pooling is comparison-only (error-free, the `if`s
//! the paper's control-flow discussion covers); average pooling is a small
//! summation followed by a division by the (exact) window size.

use crate::tensor::{Scalar, Tensor};
use anyhow::{bail, Result};

pub fn pool_output_shape(ph: usize, pw: usize, input: &[usize]) -> Result<Vec<usize>> {
    let [h, w, c] = input else {
        bail!("pooling expects input [h, w, c], got {input:?}");
    };
    if ph == 0 || pw == 0 {
        bail!("pool window must be nonzero");
    }
    if h % ph != 0 || w % pw != 0 {
        bail!("pool window {ph}x{pw} must tile input {h}x{w} (Keras 'valid' with matching stride)");
    }
    Ok(vec![h / ph, w / pw, *c])
}

pub fn max_pool<S: Scalar>(
    ctx: &S::Ctx,
    ph: usize,
    pw: usize,
    x: &Tensor<S>,
    out_shape: &[usize],
) -> Tensor<S> {
    let mut out = Vec::with_capacity(out_shape.iter().product());
    max_pool_into(ctx, ph, pw, x.data(), x.shape(), out_shape, &mut out);
    Tensor::new(out_shape.to_vec(), out)
}

/// Slice-level kernel behind [`max_pool`] (arena buffer variant).
pub fn max_pool_into<S: Scalar>(
    ctx: &S::Ctx,
    ph: usize,
    pw: usize,
    xd: &[S],
    in_shape: &[usize],
    out_shape: &[usize],
    out: &mut Vec<S>,
) {
    let (w, c) = (in_shape[1], in_shape[2]);
    let (oh, ow) = (out_shape[0], out_shape[1]);
    for oy in 0..oh {
        for ox in 0..ow {
            for ch in 0..c {
                let mut m: Option<S> = None;
                for ky in 0..ph {
                    for kx in 0..pw {
                        let v = &xd[((oy * ph + ky) * w + (ox * pw + kx)) * c + ch];
                        m = Some(match m {
                            None => v.clone(),
                            Some(acc) => acc.max(v, ctx),
                        });
                    }
                }
                out.push(m.expect("nonempty window"));
            }
        }
    }
}

/// Batched [`max_pool_into`]: `xd` holds `batch` sample-major inputs;
/// appends sample-major outputs, pooling the samples one after another
/// inside the single step dispatch (comparison-only, so the per-sample
/// result is trivially identical to the single-sample kernel's).
#[allow(clippy::too_many_arguments)]
pub fn max_pool_batch_into<S: Scalar>(
    ctx: &S::Ctx,
    ph: usize,
    pw: usize,
    xd: &[S],
    in_shape: &[usize],
    out_shape: &[usize],
    batch: usize,
    out: &mut Vec<S>,
) {
    let in_len: usize = in_shape.iter().product();
    debug_assert_eq!(xd.len(), batch * in_len, "batched max_pool input");
    for s in 0..batch {
        max_pool_into(ctx, ph, pw, &xd[s * in_len..(s + 1) * in_len], in_shape, out_shape, out);
    }
}

pub fn avg_pool<S: Scalar>(
    ctx: &S::Ctx,
    ph: usize,
    pw: usize,
    x: &Tensor<S>,
    out_shape: &[usize],
) -> Tensor<S> {
    let mut out = Vec::with_capacity(out_shape.iter().product());
    avg_pool_into(ctx, ph, pw, x.data(), x.shape(), out_shape, &mut out);
    Tensor::new(out_shape.to_vec(), out)
}

/// Slice-level kernel behind [`avg_pool`] (arena buffer variant).
pub fn avg_pool_into<S: Scalar>(
    ctx: &S::Ctx,
    ph: usize,
    pw: usize,
    xd: &[S],
    in_shape: &[usize],
    out_shape: &[usize],
    out: &mut Vec<S>,
) {
    let (w, c) = (in_shape[1], in_shape[2]);
    let (oh, ow) = (out_shape[0], out_shape[1]);
    let n = S::exact(ctx, (ph * pw) as f64); // small integer: exact
    for oy in 0..oh {
        for ox in 0..ow {
            for ch in 0..c {
                let mut acc: Option<S> = None;
                for ky in 0..ph {
                    for kx in 0..pw {
                        let v = &xd[((oy * ph + ky) * w + (ox * pw + kx)) * c + ch];
                        acc = Some(match acc {
                            None => v.clone(),
                            Some(a) => a.add(v, ctx),
                        });
                    }
                }
                out.push(acc.expect("nonempty window").div(&n, ctx));
            }
        }
    }
}

/// Batched [`avg_pool_into`] (same layout and per-sample-identity
/// contract as [`max_pool_batch_into`]; the window-size divisor is exact,
/// so it is shared across samples with identical values).
#[allow(clippy::too_many_arguments)]
pub fn avg_pool_batch_into<S: Scalar>(
    ctx: &S::Ctx,
    ph: usize,
    pw: usize,
    xd: &[S],
    in_shape: &[usize],
    out_shape: &[usize],
    batch: usize,
    out: &mut Vec<S>,
) {
    let in_len: usize = in_shape.iter().product();
    debug_assert_eq!(xd.len(), batch * in_len, "batched avg_pool input");
    for s in 0..batch {
        avg_pool_into(ctx, ph, pw, &xd[s * in_len..(s + 1) * in_len], in_shape, out_shape, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::caa::{Caa, Ctx};
    use crate::interval::Interval;

    #[test]
    fn shapes() {
        assert_eq!(pool_output_shape(2, 2, &[4, 6, 3]).unwrap(), vec![2, 3, 3]);
        assert!(pool_output_shape(2, 2, &[5, 6, 3]).is_err());
        assert!(pool_output_shape(0, 2, &[4, 6, 3]).is_err());
        assert!(pool_output_shape(2, 2, &[4, 6]).is_err());
    }

    #[test]
    fn max_pool_f64() {
        let x = Tensor::new(vec![2, 2, 1], vec![1.0, 5.0, 3.0, 2.0]);
        let y = max_pool::<f64>(&(), 2, 2, &x, &[1, 1, 1]);
        assert_eq!(y.data(), &[5.0]);
    }

    #[test]
    fn avg_pool_f64() {
        let x = Tensor::new(vec![2, 2, 2], vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0, 4.0, 40.0]);
        let y = avg_pool::<f64>(&(), 2, 2, &x, &[1, 1, 2]);
        assert_eq!(y.data(), &[2.5, 25.0]);
    }

    #[test]
    fn max_pool_channels_independent() {
        let x = Tensor::new(vec![2, 2, 2], vec![1.0, 40.0, 2.0, 30.0, 3.0, 20.0, 4.0, 10.0]);
        let y = max_pool::<f64>(&(), 2, 2, &x, &[1, 1, 2]);
        assert_eq!(y.data(), &[4.0, 40.0]);
    }

    #[test]
    fn max_pool_caa_keeps_abs_bound() {
        let ctx = Ctx::new();
        let mk = |v: f64| Caa::input(&ctx, Interval::new(v - 0.1, v + 0.1), v);
        let x = Tensor::new(vec![2, 2, 1], vec![mk(1.0), mk(5.0), mk(3.0), mk(2.0)]);
        let y = max_pool::<Caa>(&ctx, 2, 2, &x, &[1, 1, 1]);
        assert_eq!(y.data()[0].fp(), 5.0);
        assert!(y.data()[0].abs_bound().is_finite());
        assert!(y.data()[0].ideal().contains(5.1));
    }

    #[test]
    fn avg_pool_caa_divides_by_exact_count() {
        let ctx = Ctx::new();
        let mk = |v: f64| Caa::param(&ctx, v);
        let x = Tensor::new(vec![2, 2, 1], vec![mk(1.0), mk(2.0), mk(3.0), mk(4.0)]);
        let y = avg_pool::<Caa>(&ctx, 2, 2, &x, &[1, 1, 1]);
        assert!((y.data()[0].fp() - 2.5).abs() < 1e-15);
        assert!(y.data()[0].rel_bound().is_finite());
        // 4 params (1/2 each, α-weighted) + 3 add roundings + div rounding:
        // comfortably under a few u.
        assert!(y.data()[0].rel_bound() < 4.0, "rel = {}", y.data()[0].rel_bound());
    }
}
