//! Blocked, SIMD-friendly compute kernels for the f64/EmulatedFp hot path:
//! a register-tiled `MR x NR` micro-kernel over packed panels, used for
//! dense steps (tiled GEMM) and standard convolutions (im2col-as-GEMM).
//!
//! ## The bit-identity contract
//!
//! These kernels are *reorderings*, never *rewrites*, of the scalar
//! kernels in `super::dense` / `super::conv`. Every output element is
//! one reduction chain — `acc = param(bias); acc = acc + x_i * w_i` over
//! the taps in a fixed order, with exact-zero weights and padded taps
//! skipped — and different output elements' chains are mathematically
//! (and floating-point-wise) independent. Tiling therefore interleaves
//! work **across** chains only: each chain still accumulates the same
//! terms, in the same left-to-right order, through the same
//! [`Scalar::mul_param`]/[`Scalar::add`] calls. The result is
//! bit-identical to the scalar kernels for every deterministic scalar —
//! the property `rust/tests/kernels.rs` pins across the model zoo for
//! both `f64` and `EmulatedFp`.
//!
//! What the tiles buy: `MR * NR` accumulator chains advance in lockstep,
//! so the inner loop is throughput-bound (independent FMAs the compiler
//! can keep in registers and autovectorize over the `NR` lanes) instead
//! of latency-bound on one serial add chain; packed panels make every
//! inner-loop operand stream contiguous. The reduction *within* a chain
//! is never split, so no extra rounding, no changed summation tree.
//!
//! Dispatch lives in the plan executor ([`crate::plan`]): the blocked
//! path is compiled per step at `Plan::build` ([`DensePanel`] /
//! [`Im2col`] / [`DwTable`]) and taken only for scalars with
//! [`Scalar::BLOCKED_ELIGIBLE`] — CAA/interval analysis always runs the
//! scalar kernels. Depthwise convolutions get a tap-table kernel rather
//! than a GEMM lowering (their per-channel reduction is 9-ish taps —
//! too short for panel packing to pay — but channels-last layout makes
//! the channel axis a perfect contiguous SIMD lane set).

use super::conv::pad_offsets;
use super::Padding;
use crate::tensor::{Scalar, Tensor};

/// Register-tile rows: output units (dense) / output channels (conv) per
/// micro-kernel invocation. With [`NR`] this sizes the accumulator block
/// at `4 x 8 = 32` f64 values — 8 AVX2 vectors, comfortably inside a
/// 16-register budget with room for the operand streams.
pub const MR: usize = 4;

/// Register-tile lanes: independent chains the inner loop advances per
/// row — batch samples (dense) or output pixels (conv). The lane loop is
/// the autovectorization target (8 f64 = two AVX2 / one AVX-512 vector).
pub const NR: usize = 8;

/// Sentinel in an [`Im2col`] patch table: this tap falls in the zero
/// padding and is skipped, exactly like the scalar kernel's bounds
/// `continue`.
pub const PAD: usize = usize::MAX;

/// A dense step's weights re-packed for the blocked kernel, built once at
/// plan compile time.
#[derive(Clone, Debug)]
pub struct DensePanel {
    m: usize,
    n: usize,
    /// Row-tile-major panels: tile `jt` occupies
    /// `wp[jt*n*MR .. (jt+1)*n*MR]`, laid out `[i][r]` so the micro-kernel
    /// reads `MR` row weights per reduction index `i` from one contiguous
    /// quad. Rows past `m` in the last tile are zero-filled — the
    /// exact-zero skip makes them contribute nothing.
    wp: Vec<f64>,
}

impl DensePanel {
    /// Pack `w: [m, n]` into row-tile panels.
    pub fn pack(w: &Tensor<f64>) -> DensePanel {
        let (m, n) = (w.shape()[0], w.shape()[1]);
        let wd = w.data();
        let tiles = m.div_ceil(MR).max(1);
        let mut wp = vec![0.0; tiles * n * MR];
        for j in 0..m {
            let (jt, r) = (j / MR, j % MR);
            let tile = &mut wp[jt * n * MR..(jt + 1) * n * MR];
            for i in 0..n {
                tile[i * MR + r] = wd[j * n + i];
            }
        }
        DensePanel { m, n, wp }
    }

    /// Independent sample tiles at batch `batch` — the intra-op work units
    /// the parallel executor shards across workers. Tile `t` covers
    /// samples `t*NR .. min((t+1)*NR, batch)`.
    pub fn tiles(&self, batch: usize) -> usize {
        batch.div_ceil(NR)
    }

    /// Flat output element (sample-major, `m` per sample) where tile `t`'s
    /// contiguous output range starts; `t == tiles(batch)` gives the total
    /// output length. Consecutive tiles cover adjacent ranges, so any
    /// tile-range split partitions the output into disjoint contiguous
    /// chunks.
    pub fn tile_out_start(&self, batch: usize, t: usize) -> usize {
        (t * NR).min(batch) * self.m
    }

    /// Reconstruct the row-major `[m, n]` weight tensor from the panels.
    /// Packing only permutes the `f64` values, so this is exact: the
    /// scalar-path escape hatch derives its weights through here instead
    /// of a plan keeping a third dense copy alongside the panel.
    pub fn unpack(&self) -> Tensor<f64> {
        let (m, n) = (self.m, self.n);
        let mut wd = vec![0.0; m * n];
        for j in 0..m {
            let (jt, r) = (j / MR, j % MR);
            let tile = &self.wp[jt * n * MR..(jt + 1) * n * MR];
            for i in 0..n {
                wd[j * n + i] = tile[i * MR + r];
            }
        }
        Tensor::new(vec![m, n], wd)
    }

    /// Resident bytes of the packed panels (zero-filled tail rows
    /// included) — what [`crate::plan::Plan::memory_report`] charges a
    /// blocked dense step for.
    pub fn panel_bytes(&self) -> usize {
        self.wp.len() * std::mem::size_of::<f64>()
    }
}

/// A standard convolution lowered to GEMM geometry at plan compile time:
/// the per-output-pixel patch-index table (the "im2col" gather, resolved
/// once instead of re-deriving `iy`/`ix` per tap per execution) plus the
/// reduction extents. The kernel tensor itself needs no repacking — the
/// Keras `[kh, kw, cin, cout]` layout is already `[K][cout]` row-major
/// over the patch index `p = (ky*kw + kx)*cin + ci`.
///
/// The table is stored per output *row class*, not per output pixel
/// (`O(ow * k)` per class rather than `O(oh * ow * k)` total): the
/// horizontal padding pattern depends only on `ox`, and for every
/// vertically-unclipped ("interior") row the tap offsets are a pure
/// vertical translation of the first interior row's — offset plus
/// `(oy - oy_ref) * stride * w * cin`. So interior rows share one class
/// table reused down the image with a per-row delta, and only the few
/// edge rows that lose taps to vertical padding get class tables of
/// their own. See DESIGN.md "Textual Plan IR" for the memory math.
#[derive(Clone, Debug)]
pub struct Im2col {
    /// Reduction length `kh * kw * cin`.
    k: usize,
    /// Output channels.
    cout: usize,
    /// Output pixels `oh * ow`.
    op: usize,
    /// Output row width (pixels per output row).
    ow: usize,
    /// Input elements per sample (`h * w * cin`).
    in_len: usize,
    /// Concatenated row-class tables: class `cl` occupies
    /// `rows[cl*ow*k .. (cl+1)*ow*k]`, and `rows[(cl*ow + ox)*k + p]` =
    /// flat input offset of tap `p` at column `ox` (before the per-row
    /// delta), or [`PAD`].
    rows: Vec<usize>,
    /// `row_map[oy]` = `(class, delta)`: the class table for output row
    /// `oy` and the offset added to every non-[`PAD`] entry.
    row_map: Vec<(usize, usize)>,
}

impl Im2col {
    /// Build the patch table for one `Conv2D` step. Geometry was already
    /// validated by shape inference; tap order matches the scalar kernel
    /// exactly (`ky`, then `kx`, then `ci`).
    pub fn build(
        kshape: &[usize],
        stride: usize,
        padding: Padding,
        in_shape: &[usize],
        out_shape: &[usize],
    ) -> Im2col {
        let (kh, kw, cin, cout) = (kshape[0], kshape[1], kshape[2], kshape[3]);
        let (h, w) = (in_shape[0], in_shape[1]);
        let (oh, ow) = (out_shape[0], out_shape[1]);
        let (pad_top, pad_left, _, _) = pad_offsets(h, w, kh, kw, stride, padding);
        let k = kh * kw * cin;
        let op = oh * ow;
        // A row is "interior" when no tap is vertically clipped; all
        // interior rows share the first one's class table via a delta.
        let interior =
            |oy: usize| oy * stride >= pad_top && oy * stride + kh <= h + pad_top;
        let mut rows: Vec<usize> = Vec::new();
        let mut row_map: Vec<(usize, usize)> = Vec::with_capacity(oh);
        let mut interior_ref: Option<(usize, usize)> = None; // (class, oy_ref)
        for oy in 0..oh {
            if interior(oy) {
                if let Some((class, oy_ref)) = interior_ref {
                    row_map.push((class, (oy - oy_ref) * stride * w * cin));
                    continue;
                }
            }
            let class = rows.len() / (ow * k);
            rows.resize(rows.len() + ow * k, PAD);
            for ox in 0..ow {
                let row = &mut rows[(class * ow + ox) * k..(class * ow + ox + 1) * k];
                for ky in 0..kh {
                    let iy = (oy * stride + ky) as isize - pad_top as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = (ox * stride + kx) as isize - pad_left as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let xoff = (iy as usize * w + ix as usize) * cin;
                        let p = (ky * kw + kx) * cin;
                        for ci in 0..cin {
                            row[p + ci] = xoff + ci;
                        }
                    }
                }
            }
            row_map.push((class, 0));
            if interior(oy) {
                interior_ref = Some((class, oy));
            }
        }
        // Exact capacity: the table is plan-resident for the plan's
        // lifetime, so growth slack would be a permanent overcharge.
        rows.shrink_to_fit();
        Im2col { k, cout, op, ow, in_len: h * w * cin, rows, row_map }
    }

    /// Resident bytes of the patch table (row-class tables plus the
    /// per-row map) — the post-diet footprint [`crate::plan::Plan::memory_report`]
    /// accounts for.
    pub fn table_bytes(&self) -> usize {
        self.rows.len() * std::mem::size_of::<usize>()
            + self.row_map.len() * std::mem::size_of::<(usize, usize)>()
    }

    /// Bytes a full per-pixel `O(op * k)` patch table (the pre-diet
    /// layout) would occupy — the baseline [`crate::plan::Plan::memory_report`]
    /// compares against.
    pub fn full_table_bytes(&self) -> usize {
        self.op * self.k * std::mem::size_of::<usize>()
    }

    /// Independent `(sample, pixel-tile)` work units at batch `batch`.
    /// Unit `u` covers sample `u / op.div_ceil(NR)`, pixels
    /// `(u % per) * NR ..` (`NR`-capped).
    pub fn tiles(&self, batch: usize) -> usize {
        batch * self.op.div_ceil(NR)
    }

    /// Flat output element (sample-major, `op * cout` per sample) where
    /// unit `u`'s contiguous output range starts; `u == tiles(batch)`
    /// gives the total output length.
    pub fn tile_out_start(&self, batch: usize, u: usize) -> usize {
        let per = self.op.div_ceil(NR);
        let (s, t) = (u / per, u % per);
        (s * self.op + (t * NR).min(self.op)) * self.cout
    }
}

/// A depthwise convolution's spatial tap table, built once at plan
/// compile time: the per-output-pixel offsets `iy * w + ix` (multiplied
/// by the channel count at use) of tap `t = ky * kw + kx`, or [`PAD`].
///
/// Stored per output *row class* like [`Im2col`]: horizontal padding
/// depends only on `ox`, and every vertically-unclipped ("interior")
/// row's taps are the first interior row's plus a pure vertical delta
/// `(oy - oy_ref) * stride * w` — so interior rows share one class table
/// and only vertically-clipped edge rows get classes of their own
/// (`O(classes * ow * taps)` resident instead of `O(oh * ow * taps)`).
#[derive(Clone, Debug)]
pub struct DwTable {
    /// Spatial taps `kh * kw`.
    taps: usize,
    /// Channels.
    c: usize,
    /// Output pixels `oh * ow`.
    op: usize,
    /// Output row width (pixels per output row).
    ow: usize,
    /// Input elements per sample (`h * w * c`).
    in_len: usize,
    /// Concatenated row-class tables: class `cl` occupies
    /// `rows[cl*ow*taps .. (cl+1)*ow*taps]`, and `rows[(cl*ow + ox)*taps
    /// + t]` = spatial offset of tap `t` at column `ox` (before the
    /// per-row delta), or [`PAD`].
    rows: Vec<usize>,
    /// `row_map[oy]` = `(class, delta)`: the class table for output row
    /// `oy` and the spatial offset added to every non-[`PAD`] entry.
    row_map: Vec<(usize, usize)>,
}

impl DwTable {
    /// Build the tap table for one `DepthwiseConv2D` step (kernel
    /// `[kh, kw, c]`; geometry already validated by shape inference).
    pub fn build(
        kshape: &[usize],
        stride: usize,
        padding: Padding,
        in_shape: &[usize],
        out_shape: &[usize],
    ) -> DwTable {
        let (kh, kw, c) = (kshape[0], kshape[1], kshape[2]);
        let (h, w) = (in_shape[0], in_shape[1]);
        let (oh, ow) = (out_shape[0], out_shape[1]);
        let (pad_top, pad_left, _, _) = pad_offsets(h, w, kh, kw, stride, padding);
        let taps = kh * kw;
        let op = oh * ow;
        // Same interior predicate as `Im2col::build`: no tap vertically
        // clipped, so the row is a pure translation of the reference.
        let interior = |oy: usize| oy * stride >= pad_top && oy * stride + kh <= h + pad_top;
        let mut rows: Vec<usize> = Vec::new();
        let mut row_map: Vec<(usize, usize)> = Vec::with_capacity(oh);
        let mut interior_ref: Option<(usize, usize)> = None; // (class, oy_ref)
        for oy in 0..oh {
            if interior(oy) {
                if let Some((class, oy_ref)) = interior_ref {
                    row_map.push((class, (oy - oy_ref) * stride * w));
                    continue;
                }
            }
            let class = rows.len() / (ow * taps);
            rows.resize(rows.len() + ow * taps, PAD);
            for ox in 0..ow {
                let row = &mut rows[(class * ow + ox) * taps..(class * ow + ox + 1) * taps];
                for ky in 0..kh {
                    let iy = (oy * stride + ky) as isize - pad_top as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = (ox * stride + kx) as isize - pad_left as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        row[ky * kw + kx] = iy as usize * w + ix as usize;
                    }
                }
            }
            row_map.push((class, 0));
            if interior(oy) {
                interior_ref = Some((class, oy));
            }
        }
        rows.shrink_to_fit();
        DwTable { taps, c, op, ow, in_len: h * w * c, rows, row_map }
    }

    /// Independent `(sample, pixel-tile)` work units at batch `batch`
    /// (`MR`-pixel tiles — the kernel's channel-lane tile shape).
    pub fn tiles(&self, batch: usize) -> usize {
        batch * self.op.div_ceil(MR)
    }

    /// Flat output element (sample-major, `op * c` per sample) where unit
    /// `u`'s contiguous output range starts; `u == tiles(batch)` gives the
    /// total output length.
    pub fn tile_out_start(&self, batch: usize, u: usize) -> usize {
        let per = self.op.div_ceil(MR);
        let (s, t) = (u / per, u % per);
        (s * self.op + (t * MR).min(self.op)) * self.c
    }

    /// Resident bytes of the tap table (row-class tables plus the
    /// per-row map) — what [`crate::plan::Plan::memory_report`] charges.
    pub fn table_bytes(&self) -> usize {
        self.rows.len() * std::mem::size_of::<usize>()
            + self.row_map.len() * std::mem::size_of::<(usize, usize)>()
    }

    /// Bytes the full per-pixel `O(op * taps)` layout would occupy — the
    /// baseline [`crate::plan::Plan::memory_report`] compares against.
    pub fn full_table_bytes(&self) -> usize {
        self.op * self.taps * std::mem::size_of::<usize>()
    }
}

/// An average pool's spatial tap table, built once at plan compile time:
/// the per-output-pixel offsets `iy * w + ix` (multiplied by the channel
/// count at use) of tap `t = ky * pw + kx`. Pool windows tile the input
/// exactly (shape inference rejects anything else), so — unlike
/// [`DwTable`] — no entry is ever [`PAD`], and *every* output row is a
/// pure vertical translation of row 0: the row-class factoring
/// degenerates to a single class table of `ow * taps` entries plus a
/// per-row delta `oy * ph * w`.
#[derive(Clone, Debug)]
pub struct PoolTable {
    /// Window taps `ph * pw`.
    taps: usize,
    /// Channels.
    c: usize,
    /// Output pixels `oh * ow`.
    op: usize,
    /// Output row width (pixels per output row).
    ow: usize,
    /// Input elements per sample (`h * w * c`).
    in_len: usize,
    /// The single class table: `rows[ox * taps + t]` = spatial offset of
    /// tap `t` at column `ox` of output row 0.
    rows: Vec<usize>,
    /// `row_map[oy]` = `(0, oy * ph * w)` — kept in the same shape as
    /// [`DwTable::row_map`] so kernels resolve rows identically.
    row_map: Vec<(usize, usize)>,
}

impl PoolTable {
    /// Build the tap table for one `AvgPool2D` step (window `ph x pw`;
    /// geometry already validated by shape inference).
    pub fn build(ph: usize, pw: usize, in_shape: &[usize], out_shape: &[usize]) -> PoolTable {
        let (_h, w, c) = (in_shape[0], in_shape[1], in_shape[2]);
        let (oh, ow) = (out_shape[0], out_shape[1]);
        let taps = ph * pw;
        let op = oh * ow;
        let mut rows = Vec::with_capacity(ow * taps);
        for ox in 0..ow {
            for ky in 0..ph {
                for kx in 0..pw {
                    rows.push(ky * w + (ox * pw + kx));
                }
            }
        }
        let row_map = (0..oh).map(|oy| (0, oy * ph * w)).collect();
        PoolTable { taps, c, op, ow, in_len: in_shape.iter().product(), rows, row_map }
    }

    /// Independent `(sample, pixel-tile)` work units at batch `batch`
    /// (`MR`-pixel tiles, like [`DwTable::tiles`]).
    pub fn tiles(&self, batch: usize) -> usize {
        batch * self.op.div_ceil(MR)
    }

    /// Flat output element (sample-major, `op * c` per sample) where unit
    /// `u`'s contiguous output range starts; `u == tiles(batch)` gives the
    /// total output length.
    pub fn tile_out_start(&self, batch: usize, u: usize) -> usize {
        let per = self.op.div_ceil(MR);
        let (s, t) = (u / per, u % per);
        (s * self.op + (t * MR).min(self.op)) * self.c
    }

    /// Resident bytes of the tap table (the single class table plus the
    /// per-row map), like [`DwTable::table_bytes`].
    pub fn table_bytes(&self) -> usize {
        self.rows.len() * std::mem::size_of::<usize>()
            + self.row_map.len() * std::mem::size_of::<(usize, usize)>()
    }

    /// Bytes the full per-pixel `O(op * taps)` layout would occupy — the
    /// baseline [`crate::plan::Plan::memory_report`] compares against.
    pub fn full_table_bytes(&self) -> usize {
        self.op * self.taps * std::mem::size_of::<usize>()
    }
}

/// Blocked average pool: [`MR`] output pixels advance in lockstep with the
/// (channels-last, contiguous) channel axis as the inner lane set — the
/// same tile shape as [`depthwise_blocked`], minus weights and padding.
/// Each chain is seeded by *cloning* its tap-0 input (exactly the scalar
/// kernel's `None => v.clone()` start), accumulates taps `1..` in window
/// order through the same [`Scalar::add`] calls, and ends with one
/// [`Scalar::div`] by the shared exact window size. Appends
/// `batch * op * c` sample-major outputs, bit-identical to
/// `super::pool::avg_pool_batch_into`. `acc` is the arena's panel scratch,
/// reused as the tile accumulator.
pub fn avg_pool_blocked<S: Scalar>(
    ctx: &S::Ctx,
    pt: &PoolTable,
    x: &[S],
    batch: usize,
    acc: &mut Vec<S>,
    out: &mut Vec<S>,
) {
    let base = out.len();
    out.resize(base + batch * pt.op * pt.c, S::exact(ctx, 0.0));
    avg_pool_blocked_tiles(ctx, pt, x, batch, 0, pt.tiles(batch), acc, &mut out[base..]);
}

/// The tile-range core of [`avg_pool_blocked`]: run work units `u0..u1`,
/// writing into `out`, which must be exactly the contiguous output slice
/// those units cover (`tile_out_start(batch, u0)..tile_out_start(batch,
/// u1)`). Units cross only independent reduction chains, so any partition
/// of the unit range over any set of callers reproduces the full-range
/// result bitwise — the parallel executor's contract.
#[allow(clippy::too_many_arguments)]
pub fn avg_pool_blocked_tiles<S: Scalar>(
    ctx: &S::Ctx,
    pt: &PoolTable,
    x: &[S],
    batch: usize,
    u0: usize,
    u1: usize,
    acc: &mut Vec<S>,
    out: &mut [S],
) {
    let (taps, c, op) = (pt.taps, pt.c, pt.op);
    debug_assert_eq!(x.len(), batch * pt.in_len, "blocked avg_pool input");
    debug_assert_eq!(
        out.len(),
        pt.tile_out_start(batch, u1) - pt.tile_out_start(batch, u0),
        "avg_pool tile-range output slice"
    );
    let n = S::exact(ctx, taps as f64); // small integer: exact
    let per = op.div_ceil(MR);
    let base0 = pt.tile_out_start(batch, u0);
    for u in u0..u1 {
        let (s, t) = (u / per, u % per);
        let p0 = t * MR;
        let mp = MR.min(op - p0);
        let xs = &x[s * pt.in_len..(s + 1) * pt.in_len];
        let rel = pt.tile_out_start(batch, u) - base0;
        // Resolve each lane's row-class table and vertical delta once
        // per tile (same scheme as `conv_blocked_tiles`).
        let mut lane_tab: [&[usize]; MR] = [Default::default(); MR];
        let mut lane_delta = [0usize; MR];
        for r in 0..mp {
            let (oy, ox) = ((p0 + r) / pt.ow, (p0 + r) % pt.ow);
            let (class, delta) = pt.row_map[oy];
            lane_tab[r] = &pt.rows[(class * pt.ow + ox) * taps..(class * pt.ow + ox + 1) * taps];
            lane_delta[r] = delta;
        }
        // Accumulator tile `[pixel][channel]`, seeded from tap 0 —
        // the window is never empty and never padded.
        acc.clear();
        acc.reserve(mp * c);
        for r in 0..mp {
            let off = lane_tab[r][0] + lane_delta[r];
            acc.extend_from_slice(&xs[off * c..(off + 1) * c]);
        }
        for t in 1..taps {
            for r in 0..mp {
                let off = lane_tab[r][t] + lane_delta[r];
                let xrow = &xs[off * c..(off + 1) * c];
                let arow = &mut acc[r * c..(r + 1) * c];
                for (a, xv) in arow.iter_mut().zip(xrow) {
                    *a = a.add(xv, ctx);
                }
            }
        }
        // Channels-last output is exactly the tile layout: divide by
        // the window size and store.
        for (o, a) in out[rel..rel + mp * c].iter_mut().zip(acc.drain(..)) {
            *o = a.div(&n, ctx);
        }
    }
}

/// Blocked depthwise convolution: [`MR`] output pixels advance in
/// lockstep, with the (channels-last, contiguous) channel axis as the
/// inner lane set — `MR * c` independent chains per tile, every operand
/// stream contiguous. Pad taps are skipped per pixel via the precomputed
/// [`DwTable`] (one scalar branch, hoisted out of the channel loop);
/// exact-zero weights are skipped per channel like the scalar kernel.
/// Appends `batch * op * c` sample-major outputs, bit-identical to
/// `super::conv::depthwise_batch_into`. `acc` is the arena's panel
/// scratch, reused as the tile accumulator.
#[allow(clippy::too_many_arguments)]
pub fn depthwise_blocked<S: Scalar>(
    ctx: &S::Ctx,
    dw: &DwTable,
    kd: &[f64],
    bias: &[f64],
    x: &[S],
    batch: usize,
    acc: &mut Vec<S>,
    out: &mut Vec<S>,
) {
    let base = out.len();
    out.resize(base + batch * dw.op * dw.c, S::exact(ctx, 0.0));
    depthwise_blocked_tiles(ctx, dw, kd, bias, x, batch, 0, dw.tiles(batch), acc, &mut out[base..]);
}

/// The tile-range core of [`depthwise_blocked`]: run work units `u0..u1`,
/// writing into `out`, which must be exactly the contiguous output slice
/// those units cover (`tile_out_start(batch, u0)..tile_out_start(batch,
/// u1)`). Units cross only independent reduction chains, so any partition
/// of the unit range over any set of callers reproduces the full-range
/// result bitwise — the parallel executor's contract.
#[allow(clippy::too_many_arguments)]
pub fn depthwise_blocked_tiles<S: Scalar>(
    ctx: &S::Ctx,
    dw: &DwTable,
    kd: &[f64],
    bias: &[f64],
    x: &[S],
    batch: usize,
    u0: usize,
    u1: usize,
    acc: &mut Vec<S>,
    out: &mut [S],
) {
    let (taps, c, op) = (dw.taps, dw.c, dw.op);
    debug_assert_eq!(x.len(), batch * dw.in_len, "blocked depthwise input");
    debug_assert_eq!(kd.len(), taps * c, "depthwise kernel layout");
    debug_assert_eq!(
        out.len(),
        dw.tile_out_start(batch, u1) - dw.tile_out_start(batch, u0),
        "depthwise tile-range output slice"
    );
    let per = op.div_ceil(MR);
    let base0 = dw.tile_out_start(batch, u0);
    for u in u0..u1 {
        let (s, t0) = (u / per, u % per);
        let p0 = t0 * MR;
        let mp = MR.min(op - p0);
        let xs = &x[s * dw.in_len..(s + 1) * dw.in_len];
        let rel = dw.tile_out_start(batch, u) - base0;
        // Resolve each lane's row-class table and vertical delta once
        // per tile (same scheme as `conv_blocked_tiles`). [`PAD`] taps
        // are class-table entries, so the check precedes the delta.
        let mut lane_tab: [&[usize]; MR] = [Default::default(); MR];
        let mut lane_delta = [0usize; MR];
        for r in 0..mp {
            let (oy, ox) = ((p0 + r) / dw.ow, (p0 + r) % dw.ow);
            let (class, delta) = dw.row_map[oy];
            lane_tab[r] = &dw.rows[(class * dw.ow + ox) * taps..(class * dw.ow + ox + 1) * taps];
            lane_delta[r] = delta;
        }
        // Accumulator tile `[pixel][channel]`, seeded with the bias —
        // the same per-chain start as the scalar kernel.
        acc.clear();
        acc.reserve(mp * c);
        for _ in 0..mp {
            acc.extend(bias.iter().map(|&bv| S::param(ctx, bv)));
        }
        for t in 0..taps {
            let wrow = &kd[t * c..(t + 1) * c];
            for r in 0..mp {
                let off = lane_tab[r][t];
                if off == PAD {
                    continue; // zero-padded tap, skipped for every channel
                }
                let off = off + lane_delta[r];
                let xrow = &xs[off * c..(off + 1) * c];
                let arow = &mut acc[r * c..(r + 1) * c];
                for ((a, xv), &wv) in arow.iter_mut().zip(xrow).zip(wrow) {
                    if wv == 0.0 {
                        continue;
                    }
                    let term = xv.mul_param(wv, ctx);
                    *a = a.add(&term, ctx);
                }
            }
        }
        // Channels-last output is exactly the tile layout: store.
        for (o, a) in out[rel..rel + mp * c].iter_mut().zip(acc.drain(..)) {
            *o = a;
        }
    }
}

/// Blocked dense: `out[s*m + j] = b[j] + sum_i x[s*n + i] * w[j,i]` for
/// `batch` sample-major samples, appended to `out` (mirrors
/// `super::dense::apply_batch_into`, bit-identically). `pack` is the
/// arena's panel scratch: per sample tile the inputs are gathered
/// column-major once and reused across every row tile.
pub fn dense_blocked<S: Scalar>(
    ctx: &S::Ctx,
    pd: &DensePanel,
    b: &[f64],
    x: &[S],
    batch: usize,
    pack: &mut Vec<S>,
    out: &mut Vec<S>,
) {
    let base = out.len();
    out.resize(base + batch * pd.m, S::exact(ctx, 0.0));
    dense_blocked_tiles(ctx, pd, b, x, batch, 0, pd.tiles(batch), pack, &mut out[base..]);
}

/// The tile-range core of [`dense_blocked`]: run sample tiles `t0..t1`,
/// writing into `out`, which must be exactly the contiguous output slice
/// those tiles cover (`tile_out_start(batch, t0)..tile_out_start(batch,
/// t1)`). Tiles cross only independent reduction chains, so any partition
/// of the tile range over any set of callers reproduces the full-range
/// result bitwise — the parallel executor's contract.
#[allow(clippy::too_many_arguments)]
pub fn dense_blocked_tiles<S: Scalar>(
    ctx: &S::Ctx,
    pd: &DensePanel,
    b: &[f64],
    x: &[S],
    batch: usize,
    t0: usize,
    t1: usize,
    pack: &mut Vec<S>,
    out: &mut [S],
) {
    let (m, n) = (pd.m, pd.n);
    debug_assert_eq!(x.len(), batch * n, "blocked dense input");
    debug_assert_eq!(
        out.len(),
        pd.tile_out_start(batch, t1) - pd.tile_out_start(batch, t0),
        "dense tile-range output slice"
    );
    let s_base = t0 * NR;
    for t in t0..t1 {
        let s0 = t * NR;
        let nrc = NR.min(batch - s0);
        // Pack the sample panel `[i][c]`: contiguous lane reads in the
        // micro-kernel, amortized over all m/MR row tiles.
        pack.clear();
        pack.reserve(n * nrc);
        for i in 0..n {
            for c in 0..nrc {
                pack.push(x[(s0 + c) * n + i].clone());
            }
        }
        for jt in 0..m.div_ceil(MR) {
            let j0 = jt * MR;
            let mrc = MR.min(m - j0);
            let wp = &pd.wp[jt * n * MR..(jt + 1) * n * MR];
            // MR x nrc accumulator chains in lockstep over i. Rows past
            // `m` carry zero-filled weights, so every tap is skipped and
            // their (unwritten) lanes stay at the dummy bias.
            let mut acc: [S; MR * NR] = std::array::from_fn(|idx| {
                let r = idx / NR;
                S::param(ctx, if r < mrc { b[j0 + r] } else { 0.0 })
            });
            for i in 0..n {
                let ws = &wp[i * MR..i * MR + MR];
                let xs = &pack[i * nrc..i * nrc + nrc];
                for (r, &wv) in ws.iter().enumerate() {
                    if wv == 0.0 {
                        continue; // same exact-zero skip as dot_bias
                    }
                    for (a, xv) in acc[r * NR..r * NR + nrc].iter_mut().zip(xs) {
                        let term = xv.mul_param(wv, ctx);
                        *a = a.add(&term, ctx);
                    }
                }
            }
            for r in 0..mrc {
                for c in 0..nrc {
                    out[(s0 - s_base + c) * m + j0 + r] = acc[r * NR + c].clone();
                }
            }
        }
    }
}

/// Blocked standard convolution via im2col-as-GEMM: per pixel tile the
/// patch values are gathered once through the precomputed index table
/// into a `[p][lane]` panel (padded taps masked), then the micro-kernel
/// runs `MR` output channels x `NR` pixels of independent chains over the
/// patch. Appends `batch * op * cout` sample-major outputs, bit-identical
/// to `super::conv::conv2d_batch_into`.
#[allow(clippy::too_many_arguments)]
pub fn conv_blocked<S: Scalar>(
    ctx: &S::Ctx,
    ic: &Im2col,
    kd: &[f64],
    bias: &[f64],
    x: &[S],
    batch: usize,
    pack: &mut Vec<S>,
    mask: &mut Vec<bool>,
    out: &mut Vec<S>,
) {
    let base = out.len();
    out.resize(base + batch * ic.op * ic.cout, S::exact(ctx, 0.0));
    conv_blocked_tiles(ctx, ic, kd, bias, x, batch, 0, ic.tiles(batch), pack, mask, &mut out[base..]);
}

/// The tile-range core of [`conv_blocked`]: run `(sample, pixel-tile)`
/// work units `u0..u1`, writing into `out`, which must be exactly the
/// contiguous output slice those units cover (`tile_out_start(batch,
/// u0)..tile_out_start(batch, u1)`). Units cross only independent
/// reduction chains, so any partition of the unit range over any set of
/// callers reproduces the full-range result bitwise — the parallel
/// executor's contract.
#[allow(clippy::too_many_arguments)]
pub fn conv_blocked_tiles<S: Scalar>(
    ctx: &S::Ctx,
    ic: &Im2col,
    kd: &[f64],
    bias: &[f64],
    x: &[S],
    batch: usize,
    u0: usize,
    u1: usize,
    pack: &mut Vec<S>,
    mask: &mut Vec<bool>,
    out: &mut [S],
) {
    let (k, cout, op) = (ic.k, ic.cout, ic.op);
    debug_assert_eq!(x.len(), batch * ic.in_len, "blocked conv input");
    debug_assert_eq!(kd.len(), k * cout, "conv kernel layout");
    debug_assert_eq!(
        out.len(),
        ic.tile_out_start(batch, u1) - ic.tile_out_start(batch, u0),
        "conv tile-range output slice"
    );
    let per = op.div_ceil(NR);
    let base0 = ic.tile_out_start(batch, u0);
    for u in u0..u1 {
        let (s, t) = (u / per, u % per);
        let p0 = t * NR;
        let nrc = NR.min(op - p0);
        let xs = &x[s * ic.in_len..(s + 1) * ic.in_len];
        let rel = ic.tile_out_start(batch, u) - base0;
        // Gather the patch panel for these pixels (the "im2col"
        // materialization — K*NR values in arena scratch, never a
        // full patch matrix). Each lane resolves its pixel's row class
        // and vertical delta once; interior tiles see no padding and
        // take the mask-free inner loop below.
        let mut lane_tab: [&[usize]; NR] = [Default::default(); NR];
        let mut lane_delta = [0usize; NR];
        for c in 0..nrc {
            let (oy, ox) = ((p0 + c) / ic.ow, (p0 + c) % ic.ow);
            let (class, delta) = ic.row_map[oy];
            lane_tab[c] = &ic.rows[(class * ic.ow + ox) * k..(class * ic.ow + ox + 1) * k];
            lane_delta[c] = delta;
        }
        pack.clear();
        mask.clear();
        pack.reserve(k * nrc);
        mask.reserve(k * nrc);
        let mut all_valid = true;
        for p in 0..k {
            for c in 0..nrc {
                let off = lane_tab[c][p];
                if off == PAD {
                    pack.push(S::exact(ctx, 0.0));
                    mask.push(false);
                    all_valid = false;
                } else {
                    pack.push(xs[off + lane_delta[c]].clone());
                    mask.push(true);
                }
            }
        }
        let mut c0 = 0;
        while c0 < cout {
            let mrc = MR.min(cout - c0);
            let mut acc: [S; MR * NR] = std::array::from_fn(|idx| {
                let r = idx / NR;
                S::param(ctx, if r < mrc { bias[c0 + r] } else { 0.0 })
            });
            for p in 0..k {
                let ws = &kd[p * cout + c0..p * cout + c0 + mrc];
                let xrow = &pack[p * nrc..(p + 1) * nrc];
                if all_valid {
                    for (r, &wv) in ws.iter().enumerate() {
                        if wv == 0.0 {
                            continue; // same exact-zero skip as the scalar kernel
                        }
                        for (a, xv) in acc[r * NR..r * NR + nrc].iter_mut().zip(xrow) {
                            let term = xv.mul_param(wv, ctx);
                            *a = a.add(&term, ctx);
                        }
                    }
                } else {
                    let ms = &mask[p * nrc..(p + 1) * nrc];
                    for (r, &wv) in ws.iter().enumerate() {
                        if wv == 0.0 {
                            continue;
                        }
                        let lanes = acc[r * NR..r * NR + nrc].iter_mut().zip(xrow).zip(ms);
                        for ((a, xv), &ok) in lanes {
                            if ok {
                                let term = xv.mul_param(wv, ctx);
                                *a = a.add(&term, ctx);
                            }
                        }
                    }
                }
            }
            for r in 0..mrc {
                for c in 0..nrc {
                    out[rel + c * cout + c0 + r] = acc[r * NR + c].clone();
                }
            }
            c0 += mrc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layers::{conv, dense, pool};
    use crate::quant::EmulatedFp;
    use crate::tensor::EmuCtx;
    use crate::util::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.range(-1.0, 1.0)).collect()
    }

    #[test]
    fn dense_blocked_bitwise_matches_scalar_all_tail_shapes() {
        let mut rng = Rng::new(3);
        // m and batch chosen to hit full tiles, row tails and lane tails.
        for (m, n) in [(1usize, 1usize), (3, 5), (4, 8), (13, 17), (32, 7)] {
            let w = Tensor::new(vec![m, n], rand_vec(&mut rng, m * n));
            let b = rand_vec(&mut rng, m);
            let pd = DensePanel::pack(&w);
            for batch in [1usize, 2, 7, 8, 9, 32] {
                let x = rand_vec(&mut rng, batch * n);
                let mut scalar = Vec::new();
                dense::apply_batch_into::<f64>(&(), &w, &b, &x, batch, &mut scalar);
                let mut blocked = Vec::new();
                let mut pack = Vec::new();
                dense_blocked::<f64>(&(), &pd, &b, &x, batch, &mut pack, &mut blocked);
                assert_eq!(scalar.len(), blocked.len());
                for (i, (a, c)) in scalar.iter().zip(&blocked).enumerate() {
                    assert_eq!(a.to_bits(), c.to_bits(), "m={m} n={n} B={batch} out {i}");
                }
            }
        }
    }

    #[test]
    fn dense_blocked_skips_zero_weights_exactly() {
        // A zero weight must contribute *nothing* — even against an
        // infinite activation (the overflowed-witness scenario) or a
        // negative-zero accumulator.
        let w = Tensor::new(vec![2, 3], vec![1.0, 0.0, 2.0, 0.0, 0.0, 0.0]);
        let b = vec![0.5, -0.0];
        let x = vec![1.0, f64::INFINITY, 0.25];
        let pd = DensePanel::pack(&w);
        let mut scalar = Vec::new();
        dense::apply_batch_into::<f64>(&(), &w, &b, &x, 1, &mut scalar);
        let mut blocked = Vec::new();
        let mut pack = Vec::new();
        dense_blocked::<f64>(&(), &pd, &b, &x, 1, &mut pack, &mut blocked);
        assert!(scalar.iter().all(|v| v.is_finite()), "zero rows skip the inf tap");
        for (a, c) in scalar.iter().zip(&blocked) {
            assert_eq!(a.to_bits(), c.to_bits());
        }
        // Row 1 is all zeros: the output is exactly the -0.0 bias.
        assert_eq!(blocked[1].to_bits(), (-0.0f64).to_bits());
    }

    #[test]
    fn conv_blocked_bitwise_matches_scalar_odd_geometry() {
        let mut rng = Rng::new(11);
        // Odd spatial sizes, prime cout, both paddings, stride 2.
        for (h, w, kh, kw, cin, cout, stride, padding) in [
            (5usize, 7usize, 3usize, 3usize, 3usize, 5usize, 1usize, Padding::Same),
            (7, 5, 3, 2, 2, 3, 2, Padding::Valid),
            (6, 6, 1, 1, 4, 1, 1, Padding::Same),
            (4, 4, 3, 3, 1, 4, 2, Padding::Same),
        ] {
            let kernel =
                Tensor::new(vec![kh, kw, cin, cout], rand_vec(&mut rng, kh * kw * cin * cout));
            let bias = rand_vec(&mut rng, cout);
            let in_shape = vec![h, w, cin];
            let out_shape =
                conv::conv2d_output_shape(kernel.shape(), stride, padding, &in_shape).unwrap();
            let ic = Im2col::build(kernel.shape(), stride, padding, &in_shape, &out_shape);
            for batch in [1usize, 3] {
                let x = rand_vec(&mut rng, batch * h * w * cin);
                let mut scalar = Vec::new();
                conv::conv2d_batch_into::<f64>(
                    &(),
                    &kernel,
                    &bias,
                    stride,
                    padding,
                    &x,
                    &in_shape,
                    &out_shape,
                    batch,
                    &mut scalar,
                );
                let mut blocked = Vec::new();
                let (mut pack, mut mask) = (Vec::new(), Vec::new());
                conv_blocked::<f64>(
                    &(),
                    &ic,
                    kernel.data(),
                    &bias,
                    &x,
                    batch,
                    &mut pack,
                    &mut mask,
                    &mut blocked,
                );
                assert_eq!(scalar.len(), blocked.len());
                for (i, (a, c)) in scalar.iter().zip(&blocked).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        c.to_bits(),
                        "{h}x{w} k{kh}x{kw} cin{cin} cout{cout} s{stride} B{batch} out {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn depthwise_blocked_bitwise_matches_scalar() {
        let mut rng = Rng::new(7);
        for (h, w, kh, kw, c, stride, padding) in [
            (5usize, 7usize, 3usize, 3usize, 3usize, 1usize, Padding::Same),
            (6, 6, 3, 3, 4, 1, Padding::Same),
            (7, 5, 2, 3, 2, 2, Padding::Valid),
        ] {
            let kernel = Tensor::new(vec![kh, kw, c], rand_vec(&mut rng, kh * kw * c));
            let bias = rand_vec(&mut rng, c);
            let in_shape = vec![h, w, c];
            let out_shape =
                conv::depthwise_output_shape(kernel.shape(), stride, padding, &in_shape).unwrap();
            let dw = DwTable::build(kernel.shape(), stride, padding, &in_shape, &out_shape);
            for batch in [1usize, 3] {
                let x = rand_vec(&mut rng, batch * h * w * c);
                let mut scalar = Vec::new();
                conv::depthwise_batch_into::<f64>(
                    &(),
                    &kernel,
                    &bias,
                    stride,
                    padding,
                    &x,
                    &in_shape,
                    &out_shape,
                    batch,
                    &mut scalar,
                );
                let mut blocked = Vec::new();
                let mut acc = Vec::new();
                depthwise_blocked::<f64>(
                    &(),
                    &dw,
                    kernel.data(),
                    &bias,
                    &x,
                    batch,
                    &mut acc,
                    &mut blocked,
                );
                assert_eq!(scalar.len(), blocked.len());
                for (i, (a, b)) in scalar.iter().zip(&blocked).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{h}x{w} k{kh}x{kw} c{c} s{stride} B{batch} out {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn avg_pool_blocked_bitwise_matches_scalar() {
        let mut rng = Rng::new(13);
        // Window/input combos that hit full pixel tiles and MR tails, with
        // prime-ish channel counts.
        for (h, w, ph, pw, c) in [
            (4usize, 4usize, 2usize, 2usize, 3usize),
            (6, 6, 2, 3, 5),
            (6, 4, 3, 2, 1),
            (8, 8, 2, 2, 7),
            (5, 5, 5, 5, 2),
        ] {
            let in_shape = vec![h, w, c];
            let out_shape = pool::pool_output_shape(ph, pw, &in_shape).unwrap();
            let pt = PoolTable::build(ph, pw, &in_shape, &out_shape);
            for batch in [1usize, 3, 8] {
                let x = rand_vec(&mut rng, batch * h * w * c);
                let mut scalar = Vec::new();
                pool::avg_pool_batch_into::<f64>(
                    &(),
                    ph,
                    pw,
                    &x,
                    &in_shape,
                    &out_shape,
                    batch,
                    &mut scalar,
                );
                let mut blocked = Vec::new();
                let mut acc = Vec::new();
                avg_pool_blocked::<f64>(&(), &pt, &x, batch, &mut acc, &mut blocked);
                assert_eq!(scalar.len(), blocked.len());
                for (i, (a, b)) in scalar.iter().zip(&blocked).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{h}x{w} pool {ph}x{pw} c{c} B{batch} out {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn avg_pool_blocked_emulated_matches_scalar_bitwise() {
        let mut rng = Rng::new(17);
        let (h, w, ph, pw, c, batch) = (6usize, 6usize, 2usize, 2usize, 3usize, 4usize);
        let in_shape = vec![h, w, c];
        let out_shape = pool::pool_output_shape(ph, pw, &in_shape).unwrap();
        let pt = PoolTable::build(ph, pw, &in_shape, &out_shape);
        for k in [6u32, 10, 16] {
            let ec = EmuCtx { k };
            let x: Vec<EmulatedFp> =
                (0..batch * h * w * c).map(|_| EmulatedFp::new(rng.range(-2.0, 2.0), k)).collect();
            let mut scalar = Vec::new();
            pool::avg_pool_batch_into::<EmulatedFp>(
                &ec,
                ph,
                pw,
                &x,
                &in_shape,
                &out_shape,
                batch,
                &mut scalar,
            );
            let mut blocked = Vec::new();
            let mut acc = Vec::new();
            avg_pool_blocked::<EmulatedFp>(&ec, &pt, &x, batch, &mut acc, &mut blocked);
            for (i, (a, b)) in scalar.iter().zip(&blocked).enumerate() {
                assert_eq!(a.v.to_bits(), b.v.to_bits(), "k={k} out {i}");
            }
        }
    }

    #[test]
    fn tile_range_partitions_reproduce_full_range_bitwise() {
        // The parallel executor's contract in one place: splitting the
        // work-unit range at arbitrary boundaries and running the pieces
        // independently (each with its own scratch, as different workers
        // would) must assemble bitwise the full-range output.
        let mut rng = Rng::new(23);
        let (m, n, batch) = (13usize, 17usize, 19usize);
        let w = Tensor::new(vec![m, n], rand_vec(&mut rng, m * n));
        let b = rand_vec(&mut rng, m);
        let pd = DensePanel::pack(&w);
        let x = rand_vec(&mut rng, batch * n);
        let mut full = Vec::new();
        let mut pack = Vec::new();
        dense_blocked::<f64>(&(), &pd, &b, &x, batch, &mut pack, &mut full);
        let tiles = pd.tiles(batch);
        for split in 1..tiles {
            let mut parts = vec![0.0f64; full.len()];
            let cut = pd.tile_out_start(batch, split);
            let (lo, hi) = parts.split_at_mut(cut);
            let mut pack_a = Vec::new();
            dense_blocked_tiles::<f64>(&(), &pd, &b, &x, batch, 0, split, &mut pack_a, lo);
            let mut pack_b = Vec::new();
            dense_blocked_tiles::<f64>(&(), &pd, &b, &x, batch, split, tiles, &mut pack_b, hi);
            for (i, (a, c)) in full.iter().zip(&parts).enumerate() {
                assert_eq!(a.to_bits(), c.to_bits(), "dense split {split} out {i}");
            }
        }

        // Conv: (sample, pixel-tile) units, including splits mid-sample.
        let (h, wd, kh, kw, cin, cout, stride, padding) =
            (5usize, 7usize, 3usize, 3usize, 3usize, 5usize, 1usize, Padding::Same);
        let kernel = Tensor::new(vec![kh, kw, cin, cout], rand_vec(&mut rng, kh * kw * cin * cout));
        let bias = rand_vec(&mut rng, cout);
        let in_shape = vec![h, wd, cin];
        let out_shape =
            conv::conv2d_output_shape(kernel.shape(), stride, padding, &in_shape).unwrap();
        let ic = Im2col::build(kernel.shape(), stride, padding, &in_shape, &out_shape);
        let cb = 3usize;
        let cx = rand_vec(&mut rng, cb * h * wd * cin);
        let mut cfull = Vec::new();
        let (mut cp, mut cm) = (Vec::new(), Vec::new());
        conv_blocked::<f64>(&(), &ic, kernel.data(), &bias, &cx, cb, &mut cp, &mut cm, &mut cfull);
        let units = ic.tiles(cb);
        for split in [1, units / 3, units / 2, units - 1] {
            if split == 0 || split >= units {
                continue;
            }
            let mut parts = vec![0.0f64; cfull.len()];
            let cut = ic.tile_out_start(cb, split);
            let (lo, hi) = parts.split_at_mut(cut);
            let (mut pa, mut ma) = (Vec::new(), Vec::new());
            conv_blocked_tiles::<f64>(
                &(),
                &ic,
                kernel.data(),
                &bias,
                &cx,
                cb,
                0,
                split,
                &mut pa,
                &mut ma,
                lo,
            );
            let (mut pb, mut mb) = (Vec::new(), Vec::new());
            conv_blocked_tiles::<f64>(
                &(),
                &ic,
                kernel.data(),
                &bias,
                &cx,
                cb,
                split,
                units,
                &mut pb,
                &mut mb,
                hi,
            );
            for (i, (a, c)) in cfull.iter().zip(&parts).enumerate() {
                assert_eq!(a.to_bits(), c.to_bits(), "conv split {split} out {i}");
            }
        }
    }

    #[test]
    fn emulated_fp_blocked_matches_scalar_bitwise() {
        let mut rng = Rng::new(5);
        let (m, n, batch) = (7usize, 13usize, 5usize);
        let w = Tensor::new(vec![m, n], rand_vec(&mut rng, m * n));
        let b = rand_vec(&mut rng, m);
        let pd = DensePanel::pack(&w);
        for k in [6u32, 10, 16] {
            let ec = EmuCtx { k };
            let x: Vec<EmulatedFp> =
                (0..batch * n).map(|_| EmulatedFp::new(rng.range(-2.0, 2.0), k)).collect();
            let mut scalar = Vec::new();
            dense::apply_batch_into::<EmulatedFp>(&ec, &w, &b, &x, batch, &mut scalar);
            let mut blocked = Vec::new();
            let mut pack = Vec::new();
            dense_blocked::<EmulatedFp>(&ec, &pd, &b, &x, batch, &mut pack, &mut blocked);
            for (i, (a, c)) in scalar.iter().zip(&blocked).enumerate() {
                assert_eq!(a.v.to_bits(), c.v.to_bits(), "k={k} out {i}");
            }
        }
    }
}
