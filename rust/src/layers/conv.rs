//! 2-D convolution layers (Keras semantics, channels-last).
//!
//! "Same" padding contributes zeros; zero terms are *skipped* rather than
//! multiplied in, which is arithmetically identical (the product and the
//! subsequent addition of an exact 0 are error-free) and keeps the CAA
//! analysis tight at borders.

use super::Padding;
use crate::tensor::{Scalar, Tensor};
use anyhow::{bail, Result};

/// Padding offsets (top, left) for the given geometry. Crate-visible:
/// the blocked im2col lowering ([`super::gemm::Im2col`]) resolves the
/// same geometry into its patch-index table at plan compile time.
pub(crate) fn pad_offsets(
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    padding: Padding,
) -> (usize, usize, usize, usize) {
    match padding {
        Padding::Valid => (0, 0, (h - kh) / stride + 1, (w - kw) / stride + 1),
        Padding::Same => {
            let oh = h.div_ceil(stride);
            let ow = w.div_ceil(stride);
            let pad_h = ((oh - 1) * stride + kh).saturating_sub(h);
            let pad_w = ((ow - 1) * stride + kw).saturating_sub(w);
            (pad_h / 2, pad_w / 2, oh, ow)
        }
    }
}

pub fn conv2d_output_shape(
    kshape: &[usize],
    stride: usize,
    padding: Padding,
    input: &[usize],
) -> Result<Vec<usize>> {
    if kshape.len() != 4 {
        bail!("conv2d kernel must be [kh, kw, cin, cout], got {kshape:?}");
    }
    let (kh, kw, cin, cout) = (kshape[0], kshape[1], kshape[2], kshape[3]);
    let [h, w, c] = input else {
        bail!("conv2d expects input [h, w, c], got {input:?}");
    };
    if *c != cin {
        bail!("conv2d expects {cin} input channels, got {c}");
    }
    if padding == Padding::Valid && (*h < kh || *w < kw) {
        bail!("conv2d valid padding: input {h}x{w} smaller than kernel {kh}x{kw}");
    }
    let (_, _, oh, ow) = pad_offsets(*h, *w, kh, kw, stride, padding);
    Ok(vec![oh, ow, cout])
}

pub fn depthwise_output_shape(
    kshape: &[usize],
    stride: usize,
    padding: Padding,
    input: &[usize],
) -> Result<Vec<usize>> {
    if kshape.len() != 3 {
        bail!("depthwise kernel must be [kh, kw, c], got {kshape:?}");
    }
    let (kh, kw, kc) = (kshape[0], kshape[1], kshape[2]);
    let [h, w, c] = input else {
        bail!("depthwise expects input [h, w, c], got {input:?}");
    };
    if *c != kc {
        bail!("depthwise expects {kc} channels, got {c}");
    }
    if padding == Padding::Valid && (*h < kh || *w < kw) {
        bail!("depthwise valid padding: input {h}x{w} smaller than kernel {kh}x{kw}");
    }
    let (_, _, oh, ow) = pad_offsets(*h, *w, kh, kw, stride, padding);
    Ok(vec![oh, ow, *c])
}

/// Standard convolution. `kernel: [kh, kw, cin, cout]`, `x: [h, w, cin]`,
/// output `[oh, ow, cout]` (precomputed by the caller).
pub fn conv2d<S: Scalar>(
    ctx: &S::Ctx,
    kernel: &Tensor<f64>,
    bias: &[f64],
    stride: usize,
    padding: Padding,
    x: &Tensor<S>,
    out_shape: &[usize],
) -> Tensor<S> {
    let mut out = Vec::with_capacity(out_shape.iter().product());
    conv2d_into(ctx, kernel, bias, stride, padding, x.data(), x.shape(), out_shape, &mut out);
    Tensor::new(out_shape.to_vec(), out)
}

/// Slice-level kernel behind [`conv2d`]: appends the `oh*ow*cout` outputs
/// to `out` (arena buffer; geometry is validated by the caller, so the
/// inner loop is check-free).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_into<S: Scalar>(
    ctx: &S::Ctx,
    kernel: &Tensor<f64>,
    bias: &[f64],
    stride: usize,
    padding: Padding,
    xd: &[S],
    in_shape: &[usize],
    out_shape: &[usize],
    out: &mut Vec<S>,
) {
    let (kh, kw, cin, cout) = (
        kernel.shape()[0],
        kernel.shape()[1],
        kernel.shape()[2],
        kernel.shape()[3],
    );
    let (h, w) = (in_shape[0], in_shape[1]);
    let (oh, ow) = (out_shape[0], out_shape[1]);
    let (pad_top, pad_left, _, _) = pad_offsets(h, w, kh, kw, stride, padding);
    let kd = kernel.data();
    for oy in 0..oh {
        for ox in 0..ow {
            for co in 0..cout {
                let mut acc = S::param(ctx, bias[co]);
                for ky in 0..kh {
                    let iy = (oy * stride + ky) as isize - pad_top as isize;
                    if iy < 0 || iy >= h as isize {
                        continue; // zero-padded row
                    }
                    for kx in 0..kw {
                        let ix = (ox * stride + kx) as isize - pad_left as isize;
                        if ix < 0 || ix >= w as isize {
                            continue; // zero-padded column
                        }
                        let xoff = (iy as usize * w + ix as usize) * cin;
                        let koff = ((ky * kw + kx) * cin) * cout + co;
                        for ci in 0..cin {
                            let wv = kd[koff + ci * cout];
                            if wv == 0.0 {
                                continue;
                            }
                            let term = xd[xoff + ci].mul_param(wv, ctx);
                            acc = acc.add(&term, ctx);
                        }
                    }
                }
                out.push(acc);
            }
        }
    }
}

/// Batched [`conv2d_into`]: `xd` holds `batch` sample-major inputs
/// (`batch * h * w * cin` values); appends sample-major outputs. The
/// samples are convolved one after another inside the single step dispatch
/// — the conv kernel tensor is small and stays cache-resident across
/// samples, so no cross-sample interleave is needed; per-sample arithmetic
/// is exactly [`conv2d_into`]'s.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_batch_into<S: Scalar>(
    ctx: &S::Ctx,
    kernel: &Tensor<f64>,
    bias: &[f64],
    stride: usize,
    padding: Padding,
    xd: &[S],
    in_shape: &[usize],
    out_shape: &[usize],
    batch: usize,
    out: &mut Vec<S>,
) {
    let in_len: usize = in_shape.iter().product();
    debug_assert_eq!(xd.len(), batch * in_len, "batched conv input");
    for s in 0..batch {
        conv2d_into(
            ctx,
            kernel,
            bias,
            stride,
            padding,
            &xd[s * in_len..(s + 1) * in_len],
            in_shape,
            out_shape,
            out,
        );
    }
}

/// Depthwise convolution. `kernel: [kh, kw, c]`, output `[oh, ow, c]`.
pub fn depthwise<S: Scalar>(
    ctx: &S::Ctx,
    kernel: &Tensor<f64>,
    bias: &[f64],
    stride: usize,
    padding: Padding,
    x: &Tensor<S>,
    out_shape: &[usize],
) -> Tensor<S> {
    let mut out = Vec::with_capacity(out_shape.iter().product());
    depthwise_into(ctx, kernel, bias, stride, padding, x.data(), x.shape(), out_shape, &mut out);
    Tensor::new(out_shape.to_vec(), out)
}

/// Slice-level kernel behind [`depthwise`] (arena buffer variant).
#[allow(clippy::too_many_arguments)]
pub fn depthwise_into<S: Scalar>(
    ctx: &S::Ctx,
    kernel: &Tensor<f64>,
    bias: &[f64],
    stride: usize,
    padding: Padding,
    xd: &[S],
    in_shape: &[usize],
    out_shape: &[usize],
    out: &mut Vec<S>,
) {
    let (kh, kw, c) = (kernel.shape()[0], kernel.shape()[1], kernel.shape()[2]);
    let (h, w) = (in_shape[0], in_shape[1]);
    let (oh, ow) = (out_shape[0], out_shape[1]);
    let (pad_top, pad_left, _, _) = pad_offsets(h, w, kh, kw, stride, padding);
    let kd = kernel.data();
    for oy in 0..oh {
        for ox in 0..ow {
            for ch in 0..c {
                let mut acc = S::param(ctx, bias[ch]);
                for ky in 0..kh {
                    let iy = (oy * stride + ky) as isize - pad_top as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = (ox * stride + kx) as isize - pad_left as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let wv = kd[(ky * kw + kx) * c + ch];
                        if wv == 0.0 {
                            continue;
                        }
                        let xv = &xd[(iy as usize * w + ix as usize) * c + ch];
                        let term = xv.mul_param(wv, ctx);
                        acc = acc.add(&term, ctx);
                    }
                }
                out.push(acc);
            }
        }
    }
}

/// Batched [`depthwise_into`] (see [`conv2d_batch_into`] for the layout
/// and the per-sample-identity contract).
#[allow(clippy::too_many_arguments)]
pub fn depthwise_batch_into<S: Scalar>(
    ctx: &S::Ctx,
    kernel: &Tensor<f64>,
    bias: &[f64],
    stride: usize,
    padding: Padding,
    xd: &[S],
    in_shape: &[usize],
    out_shape: &[usize],
    batch: usize,
    out: &mut Vec<S>,
) {
    let in_len: usize = in_shape.iter().product();
    debug_assert_eq!(xd.len(), batch * in_len, "batched depthwise input");
    for s in 0..batch {
        depthwise_into(
            ctx,
            kernel,
            bias,
            stride,
            padding,
            &xd[s * in_len..(s + 1) * in_len],
            in_shape,
            out_shape,
            out,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ident3x3(cin: usize, cout: usize) -> Tensor<f64> {
        // Kernel that copies the center pixel of channel 0 into every out
        // channel.
        let mut k = vec![0.0; 9 * cin * cout];
        for co in 0..cout {
            k[((1 * 3 + 1) * cin) * cout + co] = 1.0; // center tap, ci = 0
        }
        Tensor::new(vec![3, 3, cin, cout], k)
    }

    #[test]
    fn shapes_same_vs_valid() {
        let k = vec![3, 3, 2, 5];
        assert_eq!(
            conv2d_output_shape(&k, 1, Padding::Same, &[8, 8, 2]).unwrap(),
            vec![8, 8, 5]
        );
        assert_eq!(
            conv2d_output_shape(&k, 1, Padding::Valid, &[8, 8, 2]).unwrap(),
            vec![6, 6, 5]
        );
        assert_eq!(
            conv2d_output_shape(&k, 2, Padding::Same, &[8, 8, 2]).unwrap(),
            vec![4, 4, 5]
        );
        assert!(conv2d_output_shape(&k, 1, Padding::Same, &[8, 8, 3]).is_err());
        assert!(conv2d_output_shape(&[3, 3, 2], 1, Padding::Same, &[8, 8, 2]).is_err());
    }

    #[test]
    fn identity_kernel_copies_center() {
        let x = Tensor::new(vec![4, 4, 1], (0..16).map(|v| v as f64).collect());
        let k = ident3x3(1, 1);
        let shape = conv2d_output_shape(k.shape(), 1, Padding::Same, x.shape()).unwrap();
        let y = conv2d::<f64>(&(), &k, &[0.0], 1, Padding::Same, &x, &shape);
        assert_eq!(y.shape(), &[4, 4, 1]);
        // Center-tap identity: output == input everywhere.
        assert_eq!(y.data(), x.data());
    }

    #[test]
    fn averaging_kernel_manual_check() {
        // 2x2 valid conv with all-0.25 kernel = window average.
        let x = Tensor::new(vec![2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]);
        let k = Tensor::new(vec![2, 2, 1, 1], vec![0.25; 4]);
        let shape = conv2d_output_shape(k.shape(), 1, Padding::Valid, x.shape()).unwrap();
        let y = conv2d::<f64>(&(), &k, &[0.5], 1, Padding::Valid, &x, &shape);
        assert_eq!(y.shape(), &[1, 1, 1]);
        assert_eq!(y.data()[0], 2.5 + 0.5);
    }

    #[test]
    fn multi_channel_sums_channels() {
        // 1x1 kernel summing 3 input channels into 1 output.
        let x = Tensor::new(vec![1, 1, 3], vec![1.0, 10.0, 100.0]);
        let k = Tensor::new(vec![1, 1, 3, 1], vec![1.0, 1.0, 1.0]);
        let shape = conv2d_output_shape(k.shape(), 1, Padding::Valid, x.shape()).unwrap();
        let y = conv2d::<f64>(&(), &k, &[0.0], 1, Padding::Valid, &x, &shape);
        assert_eq!(y.data()[0], 111.0);
    }

    #[test]
    fn depthwise_keeps_channels_separate() {
        let x = Tensor::new(vec![1, 2, 2], vec![1.0, 10.0, 2.0, 20.0]);
        // 1x2 depthwise kernel [[1, 1]] per channel.
        let k = Tensor::new(vec![1, 2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let shape = depthwise_output_shape(k.shape(), 1, Padding::Valid, x.shape()).unwrap();
        let y = depthwise::<f64>(&(), &k, &[0.0, 0.0], 1, Padding::Valid, &x, &shape);
        assert_eq!(y.shape(), &[1, 1, 2]);
        assert_eq!(y.data(), &[3.0, 30.0]);
    }

    #[test]
    fn strided_same_padding_geometry() {
        // 5x5 input, 3x3 kernel, stride 2, same: output 3x3; corners see
        // the padded region. Use an all-ones kernel on an all-ones image:
        // the corner output counts the in-bounds taps (4), center 9.
        let x = Tensor::new(vec![5, 5, 1], vec![1.0; 25]);
        let k = Tensor::new(vec![3, 3, 1, 1], vec![1.0; 9]);
        let shape = conv2d_output_shape(k.shape(), 2, Padding::Same, x.shape()).unwrap();
        let y = conv2d::<f64>(&(), &k, &[0.0], 2, Padding::Same, &x, &shape);
        assert_eq!(y.shape(), &[3, 3, 1]);
        assert_eq!(*y.at(&[0, 0, 0]), 4.0);
        assert_eq!(*y.at(&[1, 1, 0]), 9.0);
        assert_eq!(*y.at(&[0, 1, 0]), 6.0);
        assert_eq!(*y.at(&[2, 2, 0]), 4.0);
    }
}
