//! Dense (fully connected) layer: `y = W x + b`.
//!
//! The dot products accumulate left-to-right, matching the straightforward
//! summation the original tool analyzes (Kahan or pairwise variants would
//! need the code-generation phase the paper lists as future work).

use crate::tensor::{Scalar, Tensor};

/// Apply `y_j = sum_i W[j,i] * x_i + b_j`. `w: [m, n]`, `x: [n]`.
pub fn apply<S: Scalar>(ctx: &S::Ctx, w: &Tensor<f64>, b: &[f64], x: &Tensor<S>) -> Tensor<S> {
    let mut out = Vec::with_capacity(w.shape()[0]);
    apply_into(ctx, w, b, x.data(), &mut out);
    Tensor::new(vec![w.shape()[0]], out)
}

/// Slice-level kernel behind [`apply`]: appends the `m` outputs to `out`
/// (the plan executor's arena buffer — callers clear it, capacity is
/// reused so steady-state runs do not allocate).
pub fn apply_into<S: Scalar>(ctx: &S::Ctx, w: &Tensor<f64>, b: &[f64], x: &[S], out: &mut Vec<S>) {
    let m = w.shape()[0];
    let n = w.shape()[1];
    let wd = w.data();
    for j in 0..m {
        let row = &wd[j * n..(j + 1) * n];
        out.push(dot_bias(ctx, row, b[j], x));
    }
}

/// Batched [`apply_into`]: `x` holds `batch` samples sample-major
/// (`batch * n` values); appends `batch * m` outputs, sample-major.
///
/// Per-sample arithmetic is identical to [`dot_bias`] — the same terms in
/// the same left-to-right accumulation order, zero weights skipped the
/// same way — but the *independent* accumulator chains of the samples
/// advance in lockstep over each weight row. For cheap scalars (f64
/// reference, emulated-k witness) that turns one latency-bound serial dot
/// product into `batch` overlapping chains and reuses each weight row
/// while it is cache-hot; for CAA the interleave is merely order-neutral.
/// At `batch == 1` the loop degenerates to exactly [`apply_into`].
pub fn apply_batch_into<S: Scalar>(
    ctx: &S::Ctx,
    w: &Tensor<f64>,
    b: &[f64],
    x: &[S],
    batch: usize,
    out: &mut Vec<S>,
) {
    let m = w.shape()[0];
    let n = w.shape()[1];
    debug_assert_eq!(x.len(), batch * n, "batched dense input");
    let wd = w.data();
    let base = out.len();
    out.resize(base + batch * m, S::exact(ctx, 0.0));
    let mut accs: Vec<S> = Vec::with_capacity(batch);
    for j in 0..m {
        let row = &wd[j * n..(j + 1) * n];
        accs.extend(std::iter::repeat_with(|| S::param(ctx, b[j])).take(batch));
        for (i, wi) in row.iter().enumerate() {
            if *wi == 0.0 {
                continue; // matches dot_bias: exact-zero terms contribute nothing
            }
            for (s, acc) in accs.iter_mut().enumerate() {
                let term = x[s * n + i].mul_param(*wi, ctx);
                *acc = acc.add(&term, ctx);
            }
        }
        for (s, acc) in accs.drain(..).enumerate() {
            out[base + s * m + j] = acc;
        }
    }
}

/// One dot product plus bias in the scalar arithmetic `S` (sequential
/// accumulation). Exposed for the conv layer (a convolution is a strided
/// dot product) and for microbenchmarks.
pub fn dot_bias<S: Scalar>(ctx: &S::Ctx, weights: &[f64], bias: f64, xs: &[S]) -> S {
    debug_assert_eq!(weights.len(), xs.len());
    let mut acc = S::param(ctx, bias);
    for (wi, xi) in weights.iter().zip(xs) {
        if *wi == 0.0 {
            continue; // w=0 contributes exactly nothing (and stays sound)
        }
        let term = xi.mul_param(*wi, ctx);
        acc = acc.add(&term, ctx);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::caa::{Caa, Ctx};
    use crate::interval::Interval;
    use crate::quant::EmulatedFp;
    use crate::tensor::EmuCtx;

    #[test]
    fn f64_matches_manual() {
        let w = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, -1.0, 0.5, 0.0]);
        let b = vec![0.5, -0.5];
        let x = Tensor::new(vec![3], vec![1.0, 1.0, 2.0]);
        let y = apply::<f64>(&(), &w, &b, &x);
        assert_eq!(y.data(), &[1.0 + 2.0 + 6.0 + 0.5, -1.0 + 0.5 - 0.5]);
    }

    #[test]
    fn caa_bounds_enclose_emulated_runs() {
        let ctx = Ctx::new();
        let w = Tensor::new(vec![2, 4], vec![0.3, -0.7, 0.1, 0.9, 0.2, 0.4, -0.6, 0.05]);
        let b = vec![0.1, -0.2];
        let xs_f = [0.5, 1.5, -0.25, 2.0];

        let x_caa = Tensor::new(
            vec![4],
            xs_f.iter().map(|&v| Caa::input(&ctx, Interval::point(v), v)).collect(),
        );
        let y_caa = apply::<Caa>(&ctx, &w, &b, &x_caa);

        let y_ref = apply::<f64>(&(), &w, &b, &Tensor::new(vec![4], xs_f.to_vec()));

        for k in [8u32, 12, 16, 24] {
            let ec = EmuCtx { k };
            let x_emu = Tensor::new(
                vec![4],
                xs_f.iter().map(|&v| EmulatedFp::new(v, k)).collect(),
            );
            let y_emu = apply::<EmulatedFp>(&ec, &w, &b, &x_emu);
            for j in 0..2 {
                crate::quant::check_against_bounds(
                    &y_caa.data()[j],
                    y_ref.data()[j],
                    y_emu.data()[j].v,
                    k,
                    1e-12,
                )
                .unwrap_or_else(|e| panic!("k={k} j={j}: {e}"));
            }
        }
    }

    #[test]
    fn batched_matches_per_sample_bitwise() {
        // The lockstep accumulator interleave must not change any
        // per-sample value — f64 bits and CAA bounds alike.
        let ctx = Ctx::new();
        let w = Tensor::new(
            vec![3, 4],
            vec![0.3, -0.7, 0.1, 0.9, 0.2, 0.4, -0.6, 0.05, 0.0, 1.1, -0.2, 0.7],
        );
        let b = vec![0.1, -0.2, 0.05];
        let samples = [[0.5, 1.5, -0.25, 2.0], [1.0, -1.0, 0.125, 0.75]];
        let flat: Vec<f64> = samples.concat();

        let mut batched = Vec::new();
        apply_batch_into::<f64>(&(), &w, &b, &flat, 2, &mut batched);
        for (s, sample) in samples.iter().enumerate() {
            let mut single = Vec::new();
            apply_into::<f64>(&(), &w, &b, sample, &mut single);
            for (j, v) in single.iter().enumerate() {
                assert_eq!(v.to_bits(), batched[s * 3 + j].to_bits(), "sample {s} out {j}");
            }
        }

        let mk = |v: f64| Caa::input(&ctx, Interval::point(v), v);
        let flat_caa: Vec<Caa> = flat.iter().map(|&v| mk(v)).collect();
        let mut batched_caa = Vec::new();
        apply_batch_into::<Caa>(&ctx, &w, &b, &flat_caa, 2, &mut batched_caa);
        for (s, sample) in samples.iter().enumerate() {
            let xs: Vec<Caa> = sample.iter().map(|&v| mk(v)).collect();
            let mut single = Vec::new();
            apply_into::<Caa>(&ctx, &w, &b, &xs, &mut single);
            for j in 0..3 {
                let (a, c) = (&single[j], &batched_caa[s * 3 + j]);
                assert_eq!(a.fp().to_bits(), c.fp().to_bits(), "sample {s} out {j}: trace");
                assert_eq!(
                    a.abs_bound().to_bits(),
                    c.abs_bound().to_bits(),
                    "sample {s} out {j}: abs bound"
                );
                assert_eq!(
                    a.rel_bound().to_bits(),
                    c.rel_bound().to_bits(),
                    "sample {s} out {j}: rel bound"
                );
            }
        }
    }

    #[test]
    fn zero_weights_skipped_exactly() {
        let ctx = Ctx::new();
        let w = Tensor::new(vec![1, 2], vec![0.0, 0.0]);
        let b = vec![1.0];
        let x = Tensor::new(
            vec![2],
            vec![
                Caa::input(&ctx, Interval::new(-1e6, 1e6), 0.0),
                Caa::input(&ctx, Interval::new(-1e6, 1e6), 0.0),
            ],
        );
        let y = apply::<Caa>(&ctx, &w, &b, &x);
        // Output is just the bias: huge inputs must not leak in.
        assert!(y.data()[0].ideal().contains(1.0));
        assert!(y.data()[0].ideal().mag() < 1.1);
    }
}
