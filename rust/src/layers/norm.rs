//! Inference-mode batch normalization (paper §II, eq. (4)).
//!
//! At inference the batch statistics are the stored moving averages, so the
//! layer is `y = γ (x - μ) / sqrt(σ² + ε) + β` per channel. We evaluate the
//! per-channel scale `γ / sqrt(σ² + ε)` *in the analyzed arithmetic* (one
//! add, sqrt, div per channel) rather than folding it at load time: the
//! folding itself is FP work the target device would perform, and its error
//! belongs in the analysis.

use crate::tensor::{Scalar, Tensor};

pub fn batch_norm<S: Scalar>(
    ctx: &S::Ctx,
    gamma: &[f64],
    beta: &[f64],
    mean: &[f64],
    variance: &[f64],
    eps: f64,
    x: &Tensor<S>,
) -> Tensor<S> {
    let c = *x.shape().last().expect("batch_norm input rank >= 1");
    let mut out = Vec::with_capacity(x.len());
    batch_norm_into(ctx, gamma, beta, mean, variance, eps, x.data(), c, &mut out);
    Tensor::new(x.shape().to_vec(), out)
}

/// Slice-level kernel behind [`batch_norm`] (arena buffer variant). The
/// per-channel affine parameters are small `O(channels)` temporaries,
/// recomputed *in the analyzed arithmetic* on every run — the folding is FP
/// work whose error belongs in the analysis (see module docs).
#[allow(clippy::too_many_arguments)]
pub fn batch_norm_into<S: Scalar>(
    ctx: &S::Ctx,
    gamma: &[f64],
    beta: &[f64],
    mean: &[f64],
    variance: &[f64],
    eps: f64,
    xd: &[S],
    c: usize,
    out: &mut Vec<S>,
) {
    // Per-channel affine parameters, computed once in S.
    let mut scale = Vec::with_capacity(c);
    let mut shift_mu = Vec::with_capacity(c);
    let mut shift_beta = Vec::with_capacity(c);
    for ch in 0..c {
        let var = S::param(ctx, variance[ch]);
        let e = S::param(ctx, eps);
        let denom = var.add(&e, ctx).sqrt(ctx);
        let g = S::param(ctx, gamma[ch]);
        scale.push(g.div(&denom, ctx));
        shift_mu.push(S::param(ctx, mean[ch]));
        shift_beta.push(S::param(ctx, beta[ch]));
    }
    for (i, v) in xd.iter().enumerate() {
        let ch = i % c;
        let y = v
            .sub(&shift_mu[ch], ctx)
            .mul(&scale[ch], ctx)
            .add(&shift_beta[ch], ctx);
        out.push(y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::caa::{Caa, Ctx};
    use crate::interval::Interval;

    #[test]
    fn f64_matches_formula() {
        let x = Tensor::new(vec![2, 2], vec![1.0, 10.0, 3.0, 20.0]);
        let y = batch_norm::<f64>(
            &(),
            &[2.0, 1.0],   // gamma
            &[0.5, -1.0],  // beta
            &[1.0, 10.0],  // mean
            &[4.0, 25.0],  // variance
            0.0,
            &x,
        );
        // ch0: 2*(x-1)/2 + 0.5 ; ch1: (x-10)/5 - 1
        assert_eq!(y.data()[0], 0.5);
        assert_eq!(y.data()[1], -1.0);
        assert_eq!(y.data()[2], 2.5);
        assert_eq!(y.data()[3], 1.0);
    }

    #[test]
    fn caa_bounds_finite_and_enclosing() {
        let ctx = Ctx::new();
        let x = Tensor::new(
            vec![1, 2],
            vec![
                Caa::input(&ctx, Interval::new(0.0, 2.0), 1.0),
                Caa::input(&ctx, Interval::new(5.0, 15.0), 10.0),
            ],
        );
        let y = batch_norm::<Caa>(
            &ctx,
            &[2.0, 1.0],
            &[0.5, -1.0],
            &[1.0, 10.0],
            &[4.0, 25.0],
            1e-3,
            &x,
        );
        for v in y.data() {
            assert!(v.abs_bound().is_finite(), "batch norm abs bound");
            assert!(v.ideal().is_finite());
        }
        // fp trace sits inside the ideal enclosure.
        assert!(y.data()[0].ideal().contains(y.data()[0].fp()));
    }
}
